"""End-to-end driver: co-design training of the IP2 analog front-end with a
patch-token transformer backend (the paper's classification study, §1).

    PYTHONPATH=src python examples/train_ip2_classifier.py --preset cpu-small
    PYTHONPATH=src python examples/train_ip2_classifier.py --preset 100m \\
        --steps 300        # ~100M-param backend; sized for real hardware

Trains the in-pixel weight matrix A jointly with the backend through the
STE-quantized analog path, with fault-tolerant checkpointing (kill and
rerun: it resumes from the last commit).
"""

import argparse

import jax
import jax.numpy as jnp

import repro.optim as O
from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit, vit_loss
from repro.train.trainer import Trainer, TrainerConfig

PRESETS = {
    # ~0.5M backend: trains to high accuracy on CPU in ~2 min
    "cpu-small": dict(image=64, patch=16, n_vectors=32, n_layers=2,
                      d_model=64, n_heads=4, d_ff=128, batch=32),
    # ~100M backend at the paper's 32x32/400-vector design point (for TPU)
    "100m": dict(image=256, patch=32, n_vectors=400, n_layers=12,
                 d_model=768, n_heads=12, d_ff=3072, batch=64),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="cpu-small", choices=PRESETS)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--active", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default="/tmp/ip2_classifier_ckpt")
    args = ap.parse_args()
    p = PRESETS[args.preset]

    cfg = ViTConfig(
        frontend=FrontendConfig(
            image_h=p["image"], image_w=p["image"],
            patch=PatchSpec(patch_h=p["patch"], patch_w=p["patch"],
                            n_vectors=p["n_vectors"]),
            active_fraction=args.active,
        ),
        n_classes=4, n_layers=p["n_layers"], d_model=p["d_model"],
        n_heads=p["n_heads"], d_ff=p["d_ff"],
    )
    params = init_vit(jax.random.PRNGKey(0), cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"preset={args.preset}: {n_params / 1e6:.1f}M params, "
          f"{cfg.frontend.n_patches} patches, {args.active:.0%} active")

    opt = O.AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt_state = O.init_opt_state(params, opt)
    stream = SceneStream(image=p["image"])

    @jax.jit
    def train_step(params, opt_state, batch):
        (loss, acc), g = jax.value_and_grad(vit_loss, has_aux=True)(
            params, batch["rgb"], batch["labels"], cfg
        )
        params, opt_state, m = O.adamw_update(
            g, opt_state, params, opt, jnp.float32(opt.lr)
        )
        return params, opt_state, {"loss": loss, "acc": acc, **m}

    def data_fn(step):
        rgb, labels = stream.batch(step, p["batch"])
        return {"rgb": jnp.asarray(rgb), "labels": jnp.asarray(labels)}

    trainer = Trainer(
        train_step, data_fn,
        TrainerConfig(total_steps=args.steps, ckpt_every=50,
                      ckpt_dir=args.ckpt_dir, log_every=20),
    )
    params, opt_state, history = trainer.run(params, opt_state)
    for h in history:
        print(f"step {h['step']:4d}  loss {h['loss']:.3f}  {h['dt'] * 1e3:.0f} ms")

    # held-out eval
    accs = []
    for j in range(8):
        rgb, labels = stream.batch(10_000 + j, p["batch"])
        _, acc = vit_loss(params, jnp.asarray(rgb), jnp.asarray(labels), cfg)
        accs.append(float(acc))
    print(f"held-out accuracy: {sum(accs) / len(accs):.3f} "
          f"(stragglers observed: {trainer.n_stragglers})")


if __name__ == "__main__":
    main()
