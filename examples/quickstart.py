"""Quickstart: one frame through the IP2 in-pixel analog front-end.

    PYTHONPATH=src python examples/quickstart.py

Shows: scene -> AA optics -> Bayer -> salient patch selection -> analog
PWM/switched-cap projection (6-bit) -> edge ADC -> compact feature stream,
plus the sensor's power/area/throughput report (paper Table 1 / Fig. 3).
"""

import jax
import jax.numpy as jnp

import repro.core as c
from repro.data.pipeline import SceneStream
from repro.kernels import ops


def main():
    # --- configure the sensor (the paper's 32x32/400-vector design scaled
    # to a 128px demo frame with 16x16 patches) ---
    fcfg = c.FrontendConfig(
        image_h=128, image_w=128,
        patch=c.PatchSpec(patch_h=16, patch_w=16, n_vectors=48),
        active_fraction=0.25, aa_cutoff=0.5,
    )
    params = c.init_frontend_params(jax.random.PRNGKey(0), fcfg)

    rgb, labels = SceneStream(image=128).batch(0, 2)
    rgb = jnp.asarray(rgb)

    feats, mask = c.apply_frontend(params, rgb, fcfg)
    compact, idx = c.compact_features(feats, mask, fcfg)
    print(f"frame {rgb.shape} -> {fcfg.n_patches} patches, "
          f"{int(mask[0].sum())} active ({fcfg.active_fraction:.0%})")
    print(f"features: {feats.shape} -> compact ADC stream {compact.shape}")
    n_in = rgb[0].size
    n_out = compact[0].size
    print(f"data reduction this frame: {n_in / n_out:.1f}x vs RGB")

    # the same projection through the Pallas TPU kernel (interpret on CPU)
    patches = c.extract_patches(c.mosaic(rgb), 16, 16)
    w = c.strike_columns(params["a_rgb"], 16, 16)
    k_out = ops.ip2_project(patches, w, fcfg.patch)
    ref = c.analog_project_patches(patches, w, fcfg.patch)
    print(f"pallas kernel vs analog reference max |diff|: "
          f"{float(jnp.abs(k_out - ref).max()):.2e}")

    # --- sensor-level reports (paper Table 1, §2.1.3, Fig. 3) ---
    rep = c.power_report(c.SensorConfig())
    print(f"\n2Mpix@30Hz front-end power: {rep.total_w * 1e3:.1f} mW "
          f"({rep.mw_per_mpix:.1f} mW/Mpix, ADC share "
          f"{rep.share()['adc']:.0%})")
    p = c.rate_point("1080p", 2, 32, 400)
    print(f"1080p, C=2 weight lines, 400 vec/32x32 patch: {p.frame_hz:.0f} Hz")
    area = c.AreaBudget().totals()
    print(f"in-pixel circuit: {area['Total']['total_um2']:.0f} um^2 -> "
          f"{area['Total']['pitch_um']:.1f} um pitch (65nm)")


if __name__ == "__main__":
    main()
