"""LM training driver for the assigned architectures.

    PYTHONPATH=src python examples/train_lm.py --arch smollm-135m --smoke \\
        --steps 30                       # reduced config, CPU
    PYTHONPATH=src python examples/train_lm.py --arch llama3-8b   # full (TPU)

Any of the 10 assigned archs is selectable; --smoke swaps in the reduced
same-family config so the full loop (data -> sharded train step -> ckpt ->
resume) runs on this CPU container. The full configs are exercised by the
multi-pod dry-run (launch/dryrun.py).
"""

import argparse

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.optim import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{args.arch}{' (smoke)' if args.smoke else ''}: {n / 1e6:.1f}M params")

    opt = AdamWConfig(lr=1e-3)
    opt_state = init_opt_state(params, opt)
    step = jax.jit(make_train_step(
        cfg, M.DEFAULT_PLAN, opt,
        compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16,
    ))
    stream = TokenStream(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch
    ))

    def data_fn(s):
        b = {"tokens": jnp.asarray(stream.batch(s)["tokens"])}
        if cfg.is_vlm:
            b["image_embeds"] = jnp.zeros((args.batch, cfg.n_image_tokens, 1024))
        if cfg.is_encoder_decoder:
            b["frames"] = jnp.zeros((args.batch, cfg.n_encoder_frames, cfg.d_model))
        return b

    trainer = Trainer(step, data_fn, TrainerConfig(
        total_steps=args.steps, ckpt_every=10, ckpt_dir=args.ckpt_dir, log_every=5,
    ))
    _, _, history = trainer.run(params, opt_state)
    for h in history:
        print(f"step {h['step']:4d}  loss {h['loss']:.4f}  {h['dt'] * 1e3:.0f} ms")
    print("first->last logged loss: "
          f"{history[0]['loss']:.3f} -> {history[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
