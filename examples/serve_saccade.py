"""Saccadic serving on the multi-stream engine (paper §1 'shifted
attention'; DESIGN.md §5).

    PYTHONPATH=src python examples/serve_saccade.py

Two scenarios, both entirely on the compact path (frame t's patch
selection comes from the backend's attention on frame t-1; only those
~25 % of patches are gathered, projected, and ADC-converted — the paper's
10x bandwidth reduction — and the backend attends over exactly k compact
tokens, O(k²) instead of O(P²)):

1. **Single camera** through a capacity-1 engine — the PR-1 demo, now on
   the engine API.
2. **Multi-camera fleet**: four slots, cameras joining and leaving
   mid-serve. Slot-based state means churn never changes a tensor shape,
   so the batched step compiles exactly once for the whole scenario.

3. **Temporal reuse** (DESIGN.md §6): a mostly-static surveillance
   camera on the temporal delta gate — held charge on the summing caps
   serves unchanged patches, so after the bootstrap frame almost nothing
   is re-projected or ADC-converted until the scene actually changes
   (or droop forces a refresh). The temporal savings multiply the
   spatial ones.

4. **Device-resident rollout** (DESIGN.md §15): when T ticks of frames
   are known up front (a recorded clip), ``step_rollout`` serves all of
   them in ONE dispatch — the whole closed loop runs under a
   ``lax.scan`` on device, bitwise identical to T sequential ``step``
   calls but without the per-tick host round-trip. The scenario replays
   the same schedule both ways, checks the logits match exactly, and
   reports the per-tick walls plus the async ``block=False`` handle.

Every scenario also surfaces the LIVE energy meter (DESIGN.md §10): the
engine prices the events each stream actually executed — ADC
conversions, cap charges, DAC loads, CDS — so the demo reports measured
frontend milliwatts next to the conversion counts: full-motion scenes
pay for every frame, the static lobby collapses to the fixed frame
costs, and the intruder shows up as a power spike.
"""

import dataclasses
import time

import jax
import numpy as np

from repro.core.temporal import TemporalSpec
from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit
from repro.serve.engine import SaccadeEngine
from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec


def make_cfg():
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    return ViTConfig(frontend=fcfg, n_layers=2, d_model=64, n_heads=4, d_ff=128)


def single_camera(cfg, params):
    print("=== scenario 1: single camera, closed saccade loop ===")
    fcfg = cfg.frontend
    stream = SceneStream(image=64)
    engine = SaccadeEngine(cfg, params, capacity=1)
    engine.admit("cam0")

    k = fcfg.n_active
    t0 = time.time()
    hits = 0
    for t in range(10):
        rgb, labels = stream.batch(t, 1)
        logits = engine.step({"cam0": rgb[0]})["cam0"]
        hits += int(np.argmax(logits) == labels[0])
        print(f"frame {t}: {k}/{fcfg.n_patches} patches ADC-converted "
              f"({k / fcfg.n_patches:.0%}), gaze -> {sorted(map(int, engine.gaze('cam0')))}")
    dt = (time.time() - t0) / 10
    feats = k * fcfg.patch.n_vectors
    pixels = 64 * 64 * 3
    print(f"{dt * 1e3:.0f} ms/frame (CPU sim); stream: {feats} features vs "
          f"{pixels} RGB px = {pixels / feats:.1f}x reduction; backend attends "
          f"{k} tokens instead of {fcfg.n_patches} "
          f"({(fcfg.n_patches / k) ** 2:.0f}x fewer attention scores); "
          f"acc(untrained)={hits / 10:.2f}")
    print(f"live power meter (full motion, every frame a new scene): "
          f"{engine.power_mw('cam0', 'mean'):.3f} mW measured from "
          f"{engine.events('cam0', 'total').adc_conversions:.0f} ADC "
          f"conversions + fixed frame costs (DESIGN.md §10)\n")


def multi_camera(cfg, params):
    print("=== scenario 2: camera fleet with join/leave, one compilation ===")
    stream = SceneStream(seed=11, image=64)
    engine = SaccadeEngine(cfg, params, capacity=4, ema_decay=0.5)

    # a little schedule: (frame, action, camera)
    schedule = {0: [("admit", "lobby"), ("admit", "dock")],
                3: [("admit", "gate")],
                6: [("evict", "dock"), ("admit", "roof")]}
    t0 = time.time()
    frames_served = 0
    for t in range(10):
        for op, cam in schedule.get(t, []):
            getattr(engine, op)(cam)
            print(f"frame {t}: {op} {cam!r:8} "
                  f"({engine.capacity - engine.free_slots}/{engine.capacity} slots)")
        rgb, _ = stream.batch(t, engine.capacity)
        frames = {cam: rgb[engine.slot_of(cam)] for cam in engine.stream_ids}
        out = engine.step(frames)
        frames_served += len(out)
    dt = time.time() - t0
    ages = {cam: int(engine.state.frame_age[engine.slot_of(cam)])
            for cam in engine.stream_ids}
    print(f"served {frames_served} stream-frames in {dt * 1e3:.0f} ms "
          f"({frames_served / dt:.0f} stream-frames/s CPU sim)")
    print(f"per-camera frame ages: {ages}")
    watts = {cam: round(engine.power_mw(cam), 3) for cam in engine.stream_ids}
    print(f"live per-camera power meter: {watts} mW "
          f"(fleet {engine.fleet_power_mw():.3f} mW measured from events)")
    print(f"batched step compiled {engine.n_traces}x across the whole "
          f"admit/evict schedule (slot-based state: shapes never change)")
    assert engine.n_traces == 1


def temporal_reuse(cfg):
    print("=== scenario 3: static camera, temporal delta gate ===")
    fcfg = dataclasses.replace(
        cfg.frontend, temporal=TemporalSpec(delta_threshold=1e-4))
    tcfg = dataclasses.replace(cfg, frontend=fcfg)
    params = init_vit(jax.random.PRNGKey(0), tcfg)
    engine = SaccadeEngine(tcfg, params, capacity=1, temporal=True)
    engine.admit("lobby")

    stream = SceneStream(seed=3, image=64)
    still, _ = stream.batch(0, 1)          # the lobby, empty
    intruder, _ = stream.batch(1, 1)       # someone walks in at frame 6
    k, p = fcfg.n_active, fcfg.n_patches
    converted = 0
    static_mw = spike_mw = 0.0
    for t in range(10):
        frame = still[0] if t < 6 else intruder[0]
        engine.step({"lobby": frame})
        frac = engine.recompute_fraction("lobby")
        mw = engine.power_mw("lobby")
        if t == 5:
            static_mw = mw
        if t == 6:
            spike_mw = mw
        converted += int(round(frac * k))
        tag = " <- scene change" if t == 6 else ""
        print(f"frame {t}: {int(round(frac * k))}/{k} selected patches "
              f"re-converted (recompute fraction {frac:.2f}), "
              f"{mw:.3f} mW{tag}")
    always = 10 * k
    print(f"ADC conversions over 10 frames: {converted} vs {always} "
          f"always-recompute ({always / max(converted, 1):.1f}x fewer); "
          f"spatial gate already keeps {k}/{p} patches — the temporal gate "
          f"multiplies that saving on static scenes")
    print(f"live power meter: static lobby {static_mw:.3f} mW (fixed frame "
          f"costs only — holds are free) vs intruder spike {spike_mw:.3f} mW; "
          f"{engine.power_mw('lobby', 'mean'):.3f} mW mean over the run "
          f"(DESIGN.md §10)\n")


def device_rollout(cfg, params):
    print("=== scenario 4: device-resident rollout, one dispatch for T "
          "ticks ===")
    stream = SceneStream(seed=7, image=64)
    eng_loop = SaccadeEngine(cfg, params, capacity=3)
    eng_roll = SaccadeEngine(cfg, params, capacity=3)
    cams = ["lobby", "dock", "gate"]
    for eng in (eng_loop, eng_roll):
        for cam in cams:
            eng.admit(cam)

    # a T=8 recorded clip with frame-rate skew: lobby every tick, dock
    # every 2nd, gate every 4th (partial-fed ticks hold in-scan)
    T = 8
    rgb, _ = stream.batch(0, T * len(cams))
    sched = []
    for t in range(T):
        fr = {"lobby": rgb[3 * t]}
        if t % 2 == 0:
            fr["dock"] = rgb[3 * t + 1]
        if t % 4 == 0:
            fr["gate"] = rgb[3 * t + 2]
        sched.append(fr)

    # warm both paths (compile step + the T-trace) by replaying the clip
    # once on each — bitwise parity means both engines land in the SAME
    # state, so the timed second pass still compares like with like
    for fr in sched:
        eng_loop.step(fr)
    eng_roll.step_rollout(sched)
    t0 = time.time()
    seq = [eng_loop.step(fr) for fr in sched]
    dt_loop = time.time() - t0
    t0 = time.time()
    handle = eng_roll.step_rollout(sched, block=False)   # returns at dispatch
    dt_dispatch = time.time() - t0
    roll = handle.result()                               # one (T,S,C) fetch
    dt_roll = time.time() - t0

    exact = all(
        np.array_equal(seq[t][cam], roll[t][cam])
        for t in range(T) for cam in seq[t])
    served = sum(len(d) for d in roll)
    print(f"replayed {served} stream-frames over T={T} ticks: "
          f"looped step {dt_loop / T * 1e3:.1f} ms/tick vs rollout "
          f"{dt_roll / T * 1e3:.1f} ms/tick "
          f"({dt_loop / max(dt_roll, 1e-9):.1f}x; host dispatch "
          f"{dt_dispatch * 1e3:.1f} ms for all {T} ticks)")
    print(f"rollout logits bitwise equal to {T} sequential steps: {exact} "
          f"(the scan body IS the engine step — DESIGN.md §15); "
          f"rollout traces: {eng_roll.n_rollout_traces} "
          f"(one per distinct T, reuse hits the jit cache)")
    assert exact


def main():
    cfg = make_cfg()
    params = init_vit(jax.random.PRNGKey(0), cfg)
    single_camera(cfg, params)
    multi_camera(cfg, params)
    temporal_reuse(cfg)
    device_rollout(cfg, params)


if __name__ == "__main__":
    main()
