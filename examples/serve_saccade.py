"""Serving loop with saccadic attention (paper §1 'shifted attention').

    PYTHONPATH=src python examples/serve_saccade.py

Simulates the sensor<->backend closed loop over a video stream of batched
requests, entirely on the compact path: frame t's patch selection comes
from the backend's attention on frame t-1 (the saccade), only those ~25 %
of patches are gathered, projected, and ADC-converted — the paper's 10x
bandwidth reduction — and the backend attends over exactly k compact
tokens (O(k²) instead of O(P²) attention). The dense (P, M) feature grid
is never materialized anywhere in the loop.
"""

import time

import jax
import jax.numpy as jnp

from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit
from repro.serve.serve_step import make_bootstrap_indices, make_saccade_step
from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec


def main():
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    cfg = ViTConfig(frontend=fcfg, n_layers=2, d_model=64, n_heads=4, d_ff=128)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    stream = SceneStream(image=64)
    batch_size = 16

    bootstrap = jax.jit(make_bootstrap_indices(cfg))
    step = jax.jit(make_saccade_step(cfg, explore=0.1))

    indices = None
    n_total = fcfg.n_patches * batch_size
    k = fcfg.n_active
    t0 = time.time()
    for t in range(10):
        rgb, labels = stream.batch(t, batch_size)
        rgb = jnp.asarray(rgb)
        if indices is None:
            indices = bootstrap(params, rgb)       # frame 0: in-pixel energy
        logits, indices, aux = step(params, rgb, indices)
        acc = float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(labels))))
        active = int(aux["valid"].sum())
        print(f"frame {t}: {active}/{n_total} patches ADC-converted "
              f"({active / n_total:.0%}), acc(untrained)={acc:.2f}")
    dt = (time.time() - t0) / 10
    feats_per_frame = k * fcfg.patch.n_vectors * batch_size
    pixels_per_frame = batch_size * 64 * 64 * 3
    print(f"\n{dt * 1e3:.0f} ms/frame (CPU sim); stream: {feats_per_frame} "
          f"features vs {pixels_per_frame} RGB px = "
          f"{pixels_per_frame / feats_per_frame:.1f}x reduction; "
          f"backend attends {k} tokens instead of {fcfg.n_patches} "
          f"({(fcfg.n_patches / k) ** 2:.0f}x fewer attention scores)")


if __name__ == "__main__":
    main()
