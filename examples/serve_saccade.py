"""Serving loop with saccadic attention (paper §1 'shifted attention').

    PYTHONPATH=src python examples/serve_saccade.py

Simulates the sensor<->backend closed loop over a video stream of batched
requests: frame t's salient-patch mask comes from the backend's attention
on frame t-1 (the saccade), so only ~25% of patches are ADC-converted and
streamed — the paper's 10x bandwidth reduction — while classification
quality tracks the full-frame oracle.
"""

import time

import jax
import jax.numpy as jnp

import repro.core as c
from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit, vit_forward
from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec


def main():
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    cfg = ViTConfig(frontend=fcfg, n_layers=2, d_model=64, n_heads=4, d_ff=128)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    stream = SceneStream(image=64)
    batch_size = 16

    @jax.jit
    def serve(params, rgb, mask):
        logits = vit_forward(params, rgb, cfg, mask=mask)
        # next-frame saccade: energy of current features per patch (stand-in
        # for backend attention rollout; same interface)
        patches = c.extract_patches(c.mosaic(rgb), 16, 16)
        scores = c.patch_energy(patches)
        next_mask = c.topk_patch_mask(scores, fcfg.active_fraction)
        return logits, next_mask

    mask = None
    n_total = fcfg.n_patches * batch_size
    t0 = time.time()
    for t in range(10):
        rgb, labels = stream.batch(t, batch_size)
        rgb = jnp.asarray(rgb)
        logits, mask = serve(params, rgb, mask)
        acc = float(jnp.mean((jnp.argmax(logits, -1) == jnp.asarray(labels))))
        active = int(mask.sum())
        print(f"frame {t}: {active}/{n_total} patches ADC-converted "
              f"({active / n_total:.0%}), acc(untrained)={acc:.2f}")
    dt = (time.time() - t0) / 10
    feats_per_frame = fcfg.n_active * fcfg.patch.n_vectors * batch_size
    pixels_per_frame = batch_size * 64 * 64 * 3
    print(f"\n{dt * 1e3:.0f} ms/frame (CPU sim); stream: {feats_per_frame} "
          f"features vs {pixels_per_frame} RGB px = "
          f"{pixels_per_frame / feats_per_frame:.1f}x reduction")


if __name__ == "__main__":
    main()
