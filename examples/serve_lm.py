"""Batched LM serving: prefill a prompt batch, then greedy/temperature
decode with the KV cache (bf16 or int8).

    PYTHONPATH=src python examples/serve_lm.py --arch smollm-135m --smoke \\
        --prompt-len 32 --gen 32 --cache int8
"""

import argparse
import time

import jax
import jax.numpy as jnp

from repro import models as M
from repro.configs import ARCH_IDS, get_config, smoke_config
from repro.serve.serve_step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m", choices=list(ARCH_IDS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache", default="bfloat16", choices=["bfloat16", "int8", "float32"])
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = smoke_config(args.arch) if args.smoke else get_config(args.arch)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    max_len = args.prompt_len + args.gen
    cache_dtype = {"bfloat16": jnp.bfloat16, "int8": jnp.int8,
                   "float32": jnp.float32}[args.cache]

    prompts = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab
    )
    batch = {"tokens": prompts}
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.zeros((args.batch, cfg.n_encoder_frames, cfg.d_model))
    if cfg.is_vlm:
        batch["image_embeds"] = jnp.zeros((args.batch, cfg.n_image_tokens, 1024))

    state = M.init_decode_state(cfg, M.DEFAULT_PLAN, args.batch, max_len,
                                cache_dtype=cache_dtype)
    prefill = jax.jit(make_prefill_step(cfg, M.DEFAULT_PLAN))
    decode = jax.jit(make_decode_step(cfg, M.DEFAULT_PLAN, args.temperature))

    t0 = time.time()
    logits, state = prefill(params, batch, state)
    nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [nxt]
    rng = jax.random.PRNGKey(2)
    t0 = time.time()
    for i in range(args.gen - 1):
        rng, sub = jax.random.split(rng)
        pos = jnp.int32(args.prompt_len + i)
        nxt, logits, state = decode(params, state, nxt, pos, sub)
        out_tokens.append(nxt)
    jax.block_until_ready(nxt)
    t_dec = time.time() - t0

    gen = jnp.stack(out_tokens, axis=1)
    print(f"{args.arch} ({'smoke' if args.smoke else 'full'}), cache={args.cache}")
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill * 1e3:.0f} ms")
    print(f"decode  {args.gen - 1} steps: {t_dec * 1e3:.0f} ms "
          f"({args.batch * (args.gen - 1) / max(t_dec, 1e-9):.0f} tok/s, CPU)")
    print("sample:", gen[0, :16].tolist())


if __name__ == "__main__":
    main()
