"""Bench-smoke guard for the reconfigurable-mode power rows (DESIGN.md
§13) — mirroring ``check_power_accounting.py`` (§10): every per-mode
milliwatt figure in ``BENCH_throughput.json`` must be priced by the event
meter, the mode claims must hold, and the numbers are re-derived LIVE from
the meter so the artifact can never drift from the pricing code.

Three layers of defence:

1. Schema: every mode row carries a ``power`` record with
   ``source == "event-meter"``.
2. Claims: the ADC-less sign readout lands WELL under the patch-bank+ADC
   baseline (< half of it — the ADC is the majority consumer, deleting it
   must show); conv kernel-cycling costs strictly more than a
   program-once bank; the governed sign tier serves BELOW the finest
   k tier's floor allocation.
3. Live re-derivation: each mode's mW/MP is recomputed here from
   ``steady_state_events`` / ``conv_frame_events`` + ``EnergyMeter`` and
   compared to the artifact, and the conv reprogram delta is checked
   against its closed form (C·K² DAC rewrites per frame).

Run after ``benchmarks/run.py`` (needs src and the repo root on the
path): ``PYTHONPATH=src:. python benchmarks/check_modes_accounting.py``.
"""

import json
import sys

MODE_ROWS = (
    "power_mode_patchbank_adc",
    "power_mode_sign_readout",
    "power_mode_conv_program_once_vs_reprogram",
    "power_governed_sign_tier",
)


def main(path: str = "BENCH_throughput.json") -> None:
    with open(path) as f:
        results = json.load(f)
    pw = next(v for k, v in results.items() if k.startswith("power"))
    rows = {r["name"]: r for r in pw if "name" in r}

    missing = [n for n in MODE_ROWS if n not in rows]
    assert not missing, f"mode rows missing from the artifact: {missing}"
    for name in MODE_ROWS:
        rec = rows[name].get("power")
        assert isinstance(rec, dict), f"{name}: no power record"
        assert rec.get("source") == "event-meter", (
            f"{name}: power not priced by the event meter "
            f"(source={rec.get('source')!r})"
        )

    # --- claims, re-checked against the record
    adc = rows["power_mode_patchbank_adc"]["power"]["mw_per_mpix"]
    sign = rows["power_mode_sign_readout"]["power"]["mw_per_mpix"]
    assert sign < 0.5 * adc, (
        f"ADC-less sign readout {sign:.1f} mW/MP is not well under the "
        f"ADC baseline {adc:.1f} — the ADC majority should be gone"
    )
    conv = rows["power_mode_conv_program_once_vs_reprogram"]["power"]
    assert conv["reprogram_mw_per_mpix"] > conv["mw_per_mpix"], (
        "kernel-cycling conv does not cost more than a program-once bank"
    )
    gov = rows["power_governed_sign_tier"]["power"]
    assert gov["measured_mw"] < gov["floor_mw"], (
        f"governed sign tier {gov['measured_mw']:.4f} mW not under the "
        f"finest-k-tier floor {gov['floor_mw']:.4f}"
    )
    assert gov["budget_mw"] < gov["floor_mw"], (
        "sign-tier bench budget is servable by a k tier — it does not "
        "exercise the ADC-less floor"
    )

    # --- live re-derivation from the meter (artifact can't drift)
    from repro.core.power import (
        EnergyMeter, SensorConfig, conv_frame_events, steady_state_events,
    )

    meter = EnergyMeter()
    scfg = SensorConfig()
    mpix = scfg.n_pixels / 1e6

    def per_mpix(ev):
        return meter.power_mw(ev, scfg.frame_hz) / mpix

    live_adc = per_mpix(steady_state_events(scfg))
    live_sign = per_mpix(steady_state_events(scfg, readout="sign"))
    assert abs(adc - live_adc) < 1e-9 * live_adc, (
        f"artifact says {adc} mW/MP for patch-bank+ADC but the live meter "
        f"derives {live_adc}"
    )
    assert abs(sign - live_sign) < 1e-9 * live_sign, (
        f"artifact says {sign} mW/MP for the sign readout but the live "
        f"meter derives {live_sign}"
    )

    k2, ch = conv["pixels_per_window"], conv["n_channels"]
    kw = dict(n_pixels=scfg.n_pixels, pixels_per_window=k2,
              n_channels=ch, n_windows=scfg.n_pixels / k2)
    live_once = per_mpix(conv_frame_events(**kw))
    live_cyc = per_mpix(conv_frame_events(reprogram=True, **kw))
    assert abs(conv["mw_per_mpix"] - live_once) < 1e-9 * live_once
    assert abs(conv["reprogram_mw_per_mpix"] - live_cyc) < 1e-9 * live_cyc
    delta_claim = (ch * k2 * meter.k.e_dac_reprogram_j * scfg.frame_hz
                   * 1e3 / mpix)
    assert abs((live_cyc - live_once) - delta_claim) \
        < 1e-9 * max(delta_claim, 1.0), (
        "conv reprogram delta is not C*K^2 DAC rewrites per frame"
    )

    print(f"mode accounting OK: {len(MODE_ROWS)} event-metered rows; "
          f"sign {sign:.1f} mW/MP vs ADC baseline {adc:.1f} "
          f"({sign / adc:.0%}); conv reprogram +{live_cyc - live_once:.4f} "
          f"mW/MP == C*K^2 closed form; governed sign tier "
          f"{gov['measured_mw']:.4f} < floor {gov['floor_mw']:.4f} mW")


if __name__ == "__main__":
    main(*sys.argv[1:])
