"""Bench-smoke guard: BENCH_throughput.json power rows must be priced by
the event meter (``source == "event-meter"``), the paper's power claims
must hold, and the governed budget tracking must stay inside 10 %
(DESIGN.md §10) — mirroring the §9 measured-bytes guard
(check_bytes_accounting.py).

Three layers of defence:

1. Schema: every power-reporting row carries a ``power`` record with
   ``source == "event-meter"`` (no hand-computed milliwatts can sneak
   back into the artifact).
2. Claims: <30 mW/MP at the 2 Mpix AND 1 Mpix operating points, the
   measured-runtime row matching the meter, and the governed
   ``tracking_error <= 0.10``.
3. Live re-derivation: ``power_report`` is recomputed here and compared
   against both the artifact AND the meter evaluated on the analytical
   steady-state events — if someone forks the closed-form report away
   from the meter, this breaks loudly.

Run after ``benchmarks/run.py`` (needs src and the repo root on the
path): ``PYTHONPATH=src:. python benchmarks/check_power_accounting.py``.
"""

import json
import sys

POWER_ROWS = (
    "power_2mpix_30hz_mw",
    "power_mw_per_mpix",
    "power_1mpix_mw",
    "power_meter_equals_analytical",
    "power_measured_2mpix_runtime",
    "power_engine_demand_full_vs_static",
    "power_governed_full_motion_budget_tracking",
    "power_governed_slack_budget_static",
)


def main(path: str = "BENCH_throughput.json") -> None:
    with open(path) as f:
        results = json.load(f)
    pw = next(v for k, v in results.items() if k.startswith("power"))
    rows = {r["name"]: r for r in pw if "name" in r}

    missing = [n for n in POWER_ROWS if n not in rows]
    assert not missing, f"power rows missing from the artifact: {missing}"
    for name in POWER_ROWS:
        rec = rows[name].get("power")
        assert isinstance(rec, dict), f"{name}: no power record"
        assert rec.get("source") == "event-meter", (
            f"{name}: power not priced by the event meter "
            f"(source={rec.get('source')!r})"
        )

    # the claims the artifact asserts, re-checked against the record
    assert rows["power_mw_per_mpix"]["power"]["mw_per_mpix"] < 30.0
    assert rows["power_1mpix_mw"]["power"]["mw_per_mpix"] < 30.0
    assert rows["power_measured_2mpix_runtime"]["power"]["mw_per_mpix"] < 30.0
    err = rows["power_governed_full_motion_budget_tracking"]["power"]
    assert err["tracking_error"] <= 0.10, (
        f"governed tracking error {err['tracking_error']:.1%} > 10%"
    )
    assert err["measured_mw"] <= err["budget_mw"] * 1.10

    # live re-derivation: closed form == meter, and == the artifact
    from repro.core.power import (
        EnergyMeter, SensorConfig, power_report, steady_state_events,
    )

    rep = power_report(SensorConfig())
    bd = EnergyMeter().power_w(
        steady_state_events(SensorConfig()), SensorConfig().frame_hz)
    assert rep.components == bd.components and rep.total_w == bd.total_w, (
        "power_report no longer IS the meter on steady-state events"
    )
    art = rows["power_mw_per_mpix"]["power"]["mw_per_mpix"]
    assert abs(art - rep.mw_per_mpix) < 1e-9, (
        f"artifact says {art} mW/MP but the live meter derives "
        f"{rep.mw_per_mpix} — power is not being event-metered"
    )
    print(f"power accounting OK: {len(POWER_ROWS)} event-metered rows, "
          f"{rep.mw_per_mpix:.1f} mW/MP live == artifact, governed "
          f"tracking error {err['tracking_error']:.1%} <= 10%")


if __name__ == "__main__":
    main(*sys.argv[1:])
