"""Paper §1/§2.1.3/§2.1.5 — accuracy co-design study (the paper's central
claims, run end-to-end on the synthetic shape-classification task):

  A. patch-based linear projection backend ≈ CNN baseline;
  B. 25 % salient-patch partial observation ≈ full-frame observation;
  C. 6-bit in-pixel quantization ≈ float frontend (bit sweep);
  D. §2.1.5 anti-aliasing: 0.5/0.25-Nyquist optics do not hurt accuracy.

Each arm trains the same small backbone for a fixed budget on CPU; numbers
are accuracy on held-out procedurally-generated batches.
"""

from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

import repro.optim as O
from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.core.pwm import QuantSpec
from repro.data.pipeline import SceneStream
from repro.models.cnn import cnn_loss, init_cnn
from repro.models.vit import ViTConfig, init_vit, vit_loss

STEPS = 220
BATCH = 32
EVAL_BATCHES = 6


def _train_vit(cfg: ViTConfig, seed=0, steps=STEPS) -> float:
    params = init_vit(jax.random.PRNGKey(seed), cfg)
    opt = O.AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt_state = O.init_opt_state(params, opt)
    stream = SceneStream(image=cfg.frontend.image_h)

    @jax.jit
    def step(params, opt_state, rgb, labels):
        (loss, acc), g = jax.value_and_grad(vit_loss, has_aux=True)(
            params, rgb, labels, cfg
        )
        params, opt_state, _ = O.adamw_update(
            g, opt_state, params, opt, jnp.float32(opt.lr)
        )
        return params, opt_state, loss

    for i in range(steps):
        rgb, labels = stream.batch(i, BATCH)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(rgb), jnp.asarray(labels))

    accs = []
    for j in range(EVAL_BATCHES):
        rgb, labels = stream.batch(100_000 + j, BATCH)
        _, acc = vit_loss(params, jnp.asarray(rgb), jnp.asarray(labels), cfg)
        accs.append(float(acc))
    return sum(accs) / len(accs)


def _train_cnn(seed=0, steps=STEPS) -> float:
    params = init_cnn(jax.random.PRNGKey(seed))
    opt = O.AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt_state = O.init_opt_state(params, opt)
    stream = SceneStream(image=64)

    @jax.jit
    def step(params, opt_state, rgb, labels):
        (loss, acc), g = jax.value_and_grad(cnn_loss, has_aux=True)(params, rgb, labels)
        params, opt_state, _ = O.adamw_update(
            g, opt_state, params, opt, jnp.float32(opt.lr)
        )
        return params, opt_state, loss

    for i in range(steps):
        rgb, labels = stream.batch(i, BATCH)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(rgb), jnp.asarray(labels))
    accs = []
    for j in range(EVAL_BATCHES):
        rgb, labels = stream.batch(100_000 + j, BATCH)
        _, acc = cnn_loss(params, jnp.asarray(rgb), jnp.asarray(labels))
        accs.append(float(acc))
    return sum(accs) / len(accs)


def _fcfg(**kw) -> FrontendConfig:
    base = dict(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25, aa_cutoff=0.5,
    )
    base.update(kw)
    return FrontendConfig(**base)


def run() -> list[dict]:
    rows = []

    def add(name, t0, acc, note=""):
        rows.append({
            "name": name, "us_per_call": (time.perf_counter_ns() - t0) / 1e3,
            "derived": f"acc={acc:.3f}{note}",
        })
        return acc

    t0 = time.perf_counter_ns()
    acc_ip2 = add("acc_ip2_25pct_6bit", t0, _train_vit(ViTConfig(frontend=_fcfg())))
    t0 = time.perf_counter_ns()
    acc_cnn = add("acc_cnn_baseline_fullframe", t0, _train_cnn(), " (paper: patch≈CNN)")
    t0 = time.perf_counter_ns()
    acc_full = add(
        "acc_ip2_full_observation", t0,
        _train_vit(ViTConfig(frontend=_fcfg(active_fraction=1.0))),
        " (paper: 25%≈full)",
    )
    t0 = time.perf_counter_ns()
    acc_float = add(
        "acc_float_frontend_sim", t0,
        _train_vit(ViTConfig(frontend=_fcfg(analog=False, bayer=False))),
    )
    # bit sweep (C)
    for bits in (4, 6, 8):
        t0 = time.perf_counter_ns()
        q = QuantSpec(pwm_bits=bits, weight_bits=bits)
        add(f"acc_ip2_{bits}bit", t0, _train_vit(ViTConfig(
            frontend=_fcfg(patch=PatchSpec(
                patch_h=16, patch_w=16, n_vectors=32, quant=q))
        )))
    # anti-aliasing (D) — §2.1.5
    for cutoff, name in ((None, "none"), (0.5, "0p5nyq"), (0.25, "0p25nyq")):
        t0 = time.perf_counter_ns()
        add(f"acc_ip2_aa_{name}", t0,
            _train_vit(ViTConfig(frontend=_fcfg(aa_cutoff=cutoff))))
    # Fig. 4 QTH pow-2 attention backend
    t0 = time.perf_counter_ns()
    add("acc_ip2_qth_pow2_attention", t0,
        _train_vit(ViTConfig(frontend=_fcfg(), qth=True)))
    return rows
