"""Paper §1/§2.1.3/§2.1.5 — accuracy co-design study (the paper's central
claims, run end-to-end on the synthetic shape-classification task):

  A. patch-based linear projection backend ≈ CNN baseline;
  B. 25 % salient-patch partial observation ≈ full-frame observation;
  C. 6-bit in-pixel quantization ≈ float frontend (bit sweep);
  D. §2.1.5 anti-aliasing: 0.5/0.25-Nyquist optics do not hurt accuracy;
  E. delta-gated incremental backend (DESIGN.md §14): served accuracy of
     the exact (eps=0) and budgeted (eps=0.5) reuse modes on drift clips.

Each arm trains the same small backbone for a fixed budget on CPU; numbers
are accuracy on held-out procedurally-generated batches.
"""

from __future__ import annotations

import dataclasses
import time

import numpy as np

import jax
import jax.numpy as jnp

import repro.optim as O
from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.core.pwm import QuantSpec
from repro.data.pipeline import SceneStream
from repro.models.cnn import cnn_loss, init_cnn
from repro.models.vit import ViTConfig, init_vit, vit_forward_compact, vit_loss

STEPS = 220
BATCH = 32
EVAL_BATCHES = 6


def _eval_wire(params, cfg: ViTConfig, wire: str) -> float:
    """Accuracy through the SERVED path: ``apply_frontend(mode="compact")``
    via ``vit_forward_compact`` on an explicit wire format. The dense
    float eval above is the oracle; this is what the chip actually ships
    (int8 codes — or 1-bit comparator decisions on ``wire="sign"``)."""
    stream = SceneStream(image=cfg.frontend.image_h)
    accs = []
    for j in range(EVAL_BATCHES):
        rgb, labels = stream.batch(100_000 + j, BATCH)
        logits, _ = vit_forward_compact(params, jnp.asarray(rgb), cfg,
                                        wire=wire)
        accs.append(float(np.mean(np.argmax(np.asarray(logits), -1)
                                  == labels)))
    return sum(accs) / len(accs)


def _eval_delta(params, cfg: ViTConfig, eps_val: float,
                frames: int = 4) -> float:
    """Accuracy through the delta-gated incremental backend (DESIGN.md
    §14): each eval batch becomes a short slow-contrast-drift clip served
    frame by frame through the temporal frontend + BackendCache at the
    given eps snap budget (passive droop-free summer, the reuse
    precondition). eps=0 is the dense-served gated path bit for bit, so
    its row doubles as the oracle for the eps>0 rows. Accuracy is over
    every served frame."""
    from repro.core.switched_cap import SummerSpec
    from repro.core.temporal import TemporalSpec, init_feature_cache
    from repro.models.backend_delta import init_backend_cache

    fcfg = dataclasses.replace(
        cfg.frontend,
        patch=dataclasses.replace(
            cfg.frontend.patch,
            summer=SummerSpec(mode="passive", hold_time_s=0.0)),
        temporal=TemporalSpec(delta_threshold=1e-3),
    )
    dcfg = dataclasses.replace(cfg, frontend=fcfg)
    stream = SceneStream(image=fcfg.image_h)
    eps = jnp.full((BATCH,), eps_val, jnp.float32)
    accs = []
    for j in range(EVAL_BATCHES):
        rgb, labels = stream.batch(100_000 + j, BATCH)
        tcache = init_feature_cache(fcfg, (BATCH,))
        bc = init_backend_cache(dcfg, fcfg.n_active, (BATCH,),
                                dtype=fcfg.adc.code_dtype)
        for t in range(frames):
            frame = jnp.asarray(
                np.clip(rgb * (1.0 + 0.005 * t), 0.0, 1.0).astype(np.float32))
            logits, aux = vit_forward_compact(
                params, frame, dcfg, cache=tcache,
                backend_cache=bc, backend_eps=eps)
            tcache, bc = aux["cache"], aux["backend_cache"]
            accs.append(float(np.mean(np.argmax(np.asarray(logits), -1)
                                      == labels)))
    return sum(accs) / len(accs)


def _train_vit(cfg: ViTConfig, seed=0, steps=STEPS,
               return_params: bool = False):
    params = init_vit(jax.random.PRNGKey(seed), cfg)
    opt = O.AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt_state = O.init_opt_state(params, opt)
    stream = SceneStream(image=cfg.frontend.image_h)

    @jax.jit
    def step(params, opt_state, rgb, labels):
        (loss, acc), g = jax.value_and_grad(vit_loss, has_aux=True)(
            params, rgb, labels, cfg
        )
        params, opt_state, _ = O.adamw_update(
            g, opt_state, params, opt, jnp.float32(opt.lr)
        )
        return params, opt_state, loss

    for i in range(steps):
        rgb, labels = stream.batch(i, BATCH)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(rgb), jnp.asarray(labels))

    accs = []
    for j in range(EVAL_BATCHES):
        rgb, labels = stream.batch(100_000 + j, BATCH)
        _, acc = vit_loss(params, jnp.asarray(rgb), jnp.asarray(labels), cfg)
        accs.append(float(acc))
    acc = sum(accs) / len(accs)
    if return_params:
        return params, acc
    return acc


def _train_cnn(seed=0, steps=STEPS) -> float:
    params = init_cnn(jax.random.PRNGKey(seed))
    opt = O.AdamWConfig(lr=2e-3, weight_decay=0.01)
    opt_state = O.init_opt_state(params, opt)
    stream = SceneStream(image=64)

    @jax.jit
    def step(params, opt_state, rgb, labels):
        (loss, acc), g = jax.value_and_grad(cnn_loss, has_aux=True)(params, rgb, labels)
        params, opt_state, _ = O.adamw_update(
            g, opt_state, params, opt, jnp.float32(opt.lr)
        )
        return params, opt_state, loss

    for i in range(steps):
        rgb, labels = stream.batch(i, BATCH)
        params, opt_state, _ = step(params, opt_state, jnp.asarray(rgb), jnp.asarray(labels))
    accs = []
    for j in range(EVAL_BATCHES):
        rgb, labels = stream.batch(100_000 + j, BATCH)
        _, acc = cnn_loss(params, jnp.asarray(rgb), jnp.asarray(labels))
        accs.append(float(acc))
    return sum(accs) / len(accs)


def _fcfg(**kw) -> FrontendConfig:
    base = dict(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25, aa_cutoff=0.5,
    )
    base.update(kw)
    return FrontendConfig(**base)


def run() -> list[dict]:
    rows = []

    def add(name, t0, acc, note=""):
        rows.append({
            "name": name, "us_per_call": (time.perf_counter_ns() - t0) / 1e3,
            "derived": f"acc={acc:.3f}{note}",
        })
        return acc

    t0 = time.perf_counter_ns()
    cfg_b = ViTConfig(frontend=_fcfg())
    params_b, acc_ip2 = _train_vit(cfg_b, return_params=True)
    add("acc_ip2_25pct_6bit", t0, acc_ip2)
    # arm B served: the SAME trained model, evaluated through the compact
    # int8 code wire (the payload the chip ships) — the dense float eval
    # above stays as the oracle it must match
    t0 = time.perf_counter_ns()
    acc_codes = add("acc_ip2_25pct_code_wire", t0,
                    _eval_wire(params_b, cfg_b, wire="codes"),
                    f" (dense oracle {acc_ip2:.3f})")
    assert abs(acc_codes - acc_ip2) <= 0.05, (
        f"compact code-wire eval {acc_codes:.3f} diverged from the dense "
        f"oracle {acc_ip2:.3f}"
    )
    # delta-gated incremental backend (DESIGN.md §14): the SAME trained
    # model served over slow-drift clips through the BackendCache — the
    # eps=0 row is exact (it IS the gated-dense serve) and the coarse-eps
    # row prices the reuse budget in accuracy
    t0 = time.perf_counter_ns()
    acc_eps0 = add("acc_ip2_delta_backend_eps0", t0,
                   _eval_delta(params_b, cfg_b, 0.0),
                   " (delta serve, exact)")
    t0 = time.perf_counter_ns()
    acc_eps5 = add("acc_ip2_delta_backend_eps0p5", t0,
                   _eval_delta(params_b, cfg_b, 0.5),
                   f" (eps=0.5 snap budget; eps=0 {acc_eps0:.3f})")
    assert acc_eps0 >= acc_codes - 0.08, (
        f"delta-served eps=0 accuracy {acc_eps0:.3f} fell away from the "
        f"code-wire serve {acc_codes:.3f}")
    assert abs(acc_eps5 - acc_eps0) <= 0.15, (
        f"eps=0.5 accuracy {acc_eps5:.3f} vs exact {acc_eps0:.3f}: the "
        f"snap budget should bend accuracy, not break it")
    # the ADC-less sign wire: 1 bit per feature — the accuracy cost of
    # the governor's last-resort tier, measured on the same model
    t0 = time.perf_counter_ns()
    add("acc_ip2_25pct_sign_wire", t0,
        _eval_wire(params_b, cfg_b, wire="sign"),
        f" (1-bit ADC-less; code wire {acc_codes:.3f})")
    t0 = time.perf_counter_ns()
    acc_cnn = add("acc_cnn_baseline_fullframe", t0, _train_cnn(), " (paper: patch≈CNN)")
    t0 = time.perf_counter_ns()
    acc_full = add(
        "acc_ip2_full_observation", t0,
        _train_vit(ViTConfig(frontend=_fcfg(active_fraction=1.0))),
        " (paper: 25%≈full)",
    )
    t0 = time.perf_counter_ns()
    acc_float = add(
        "acc_float_frontend_sim", t0,
        _train_vit(ViTConfig(frontend=_fcfg(analog=False, bayer=False))),
    )
    # bit sweep (C)
    for bits in (4, 6, 8):
        t0 = time.perf_counter_ns()
        q = QuantSpec(pwm_bits=bits, weight_bits=bits)
        add(f"acc_ip2_{bits}bit", t0, _train_vit(ViTConfig(
            frontend=_fcfg(patch=PatchSpec(
                patch_h=16, patch_w=16, n_vectors=32, quant=q))
        )))
    # anti-aliasing (D) — §2.1.5
    for cutoff, name in ((None, "none"), (0.5, "0p5nyq"), (0.25, "0p25nyq")):
        t0 = time.perf_counter_ns()
        add(f"acc_ip2_aa_{name}", t0,
            _train_vit(ViTConfig(frontend=_fcfg(aa_cutoff=cutoff))))
    # Fig. 4 QTH pow-2 attention backend
    t0 = time.perf_counter_ns()
    add("acc_ip2_qth_pow2_attention", t0,
        _train_vit(ViTConfig(frontend=_fcfg(), qth=True)))
    return rows


def run_quick() -> list[dict]:
    """``--quick`` smoke arm (benchmarks/run.py): one short arm-B train
    plus the served-wire evals, so the accuracy seams (dense oracle vs
    int8 code wire vs 1-bit sign wire) stay exercised in the bench-smoke
    CI lane without the full 10-model training sweep."""
    rows = []
    cfg = ViTConfig(frontend=_fcfg())
    t0 = time.perf_counter_ns()
    params, acc = _train_vit(cfg, steps=40, return_params=True)
    rows.append({
        "name": "acc_smoke_ip2_25pct_dense",
        "us_per_call": (time.perf_counter_ns() - t0) / 1e3,
        "derived": f"acc={acc:.3f} (40-step smoke, dense oracle)",
    })
    for wire in ("codes", "sign"):
        t0 = time.perf_counter_ns()
        a = _eval_wire(params, cfg, wire=wire)
        rows.append({
            "name": f"acc_smoke_ip2_25pct_{wire}_wire",
            "us_per_call": (time.perf_counter_ns() - t0) / 1e3,
            "derived": f"acc={a:.3f} ({wire} wire, same params)",
        })
        if wire == "codes":
            assert abs(a - acc) <= 0.08, (
                f"smoke: code-wire eval {a:.3f} diverged from dense "
                f"oracle {acc:.3f}"
            )
    # the delta-gated serve seam (DESIGN.md §14), eval-only: exact vs
    # coarse snap budget on the same smoke params
    for eps_val in (0.0, 0.5):
        t0 = time.perf_counter_ns()
        a = _eval_delta(params, cfg, eps_val, frames=2)
        rows.append({
            "name": f"acc_smoke_ip2_delta_eps{eps_val:g}".replace(".", "p"),
            "us_per_call": (time.perf_counter_ns() - t0) / 1e3,
            "derived": f"acc={a:.3f} (delta-gated serve, eps={eps_val:g})",
        })
    return rows
