"""Bench-smoke guard: the delta-gated backend rows in
BENCH_throughput.json (DESIGN.md §14) must be MAC-metered, internally
consistent, and still true of the live code — mirroring the §9 bytes and
§10 power guards (check_bytes_accounting.py / check_power_accounting.py).

Three layers of defence:

1. Schema: every ``backend_delta_*`` row carries a ``backend`` record
   with ``source == "mac-meter"`` and the full eps grid — recompute
   fractions and logit-error bounds come from the forward's MAC meter
   and a live dense comparison, never hand math.
2. Claims re-checked from the stored records: eps=0 is exact on every
   scene (stored worst-case logit error exactly 0), a static scene's
   steady-state recompute is exactly 0, a larger snap budget never
   recomputes more, and the stored dense backend milliwatts re-price
   from the stored MAC count with a FRESH ``EnergyMeter`` — if someone
   edits the artifact or forks the pricing away from the meter, this
   breaks loudly.
3. Live re-derivation: a small standalone-programs harness (the
   tests/test_backend_delta.py bitwise discipline: materialized wire
   block, separately-jitted dense/delta encoders) re-runs cold + warm
   frames — the cold frame's measured MACs must equal the
   ``dense_backend_macs`` closed form, a warm static frame must skip to
   exactly 0 MACs while serving BITWISE-identical logits, and the fused
   claim chain (frac==0 <=> macs==0 <=> logits cached) stays closed.

Run after ``benchmarks/run.py`` (needs src and the repo root on the
path): ``PYTHONPATH=src:. python benchmarks/check_backend_accounting.py``.
"""

import json
import sys

EPS_GRID = ("0", "0.1", "0.5")
KINDS = ("static", "drift", "panning", "full_motion")


def main(path: str = "BENCH_throughput.json") -> None:
    with open(path) as f:
        results = json.load(f)
    tp = next(v for k, v in results.items() if k.startswith("throughput"))
    rows = {r["name"]: r for r in tp if "name" in r}

    # --- 1. schema: MAC-metered records on every backend row
    names = [f"backend_delta_{kind}" for kind in KINDS]
    names.append("backend_walltime_breakdown_static")
    missing = [n for n in names if n not in rows]
    assert not missing, f"backend rows missing from the artifact: {missing}"
    for name in names:
        rec = rows[name].get("backend")
        assert isinstance(rec, dict), f"{name}: no backend record"
        assert rec.get("source") == "mac-meter", (
            f"{name}: backend MACs not metered (source={rec.get('source')!r})"
        )
    for kind in KINDS:
        rec = rows[f"backend_delta_{kind}"]["backend"]
        for field in ("recompute_frac", "max_logit_err"):
            got = set(rec[field])
            assert got == set(EPS_GRID), (
                f"backend_delta_{kind}.{field}: eps grid {sorted(got)} != "
                f"{sorted(EPS_GRID)}"
            )

    # --- 2. stored claims reproduce from the records
    for kind in KINDS:
        rec = rows[f"backend_delta_{kind}"]["backend"]
        assert rec["max_logit_err"]["0"] == 0.0, (
            f"{kind}: eps=0 is not exact in the artifact "
            f"(err={rec['max_logit_err']['0']})"
        )
        fr = rec["recompute_frac"]
        assert fr["0.5"] <= fr["0.1"] + 1e-9 <= fr["0"] + 2e-9, (
            f"{kind}: a larger snap budget recomputed more: {fr}"
        )
    st = rows["backend_delta_static"]["backend"]["recompute_frac"]
    assert all(v == 0.0 for v in st.values()), (
        f"static scene recompute fraction not 0: {st}"
    )

    from repro.core.power import EnergyMeter, dense_backend_macs

    bd = rows["backend_walltime_breakdown_static"]["backend"]
    meter = EnergyMeter()
    repriced = bd["dense_macs_per_frame"] * meter.k.e_backend_mac_j * 30.0 * 1e3
    assert abs(repriced - bd["dense_backend_mw_30hz"]) <= 1e-9 * max(
        repriced, 1.0), (
        f"artifact says {bd['dense_backend_mw_30hz']} mW but the stored "
        f"MACs re-price to {repriced} with a fresh meter"
    )
    speedup = bd["speedup"]

    # --- 3. live standalone-programs harness: closed form + bitwise gate
    import numpy as np

    import jax
    import jax.numpy as jnp

    from repro.core import saliency as sal  # noqa: F401  (import check)
    import repro.core as c
    from repro.core.frontend import FrontendConfig, apply_frontend
    from repro.core.projection import PatchSpec
    from repro.core.switched_cap import SummerSpec
    from repro.core.temporal import TemporalSpec, init_feature_cache
    from repro.models import vit as vit_mod
    from repro.models.backend_delta import delta_forward, init_backend_cache
    from repro.models.vit import ViTConfig, init_vit

    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32,
                        summer=SummerSpec(mode="passive", hold_time_s=0.0)),
        aa_cutoff=None, active_fraction=0.5,
        temporal=TemporalSpec(delta_threshold=1e-3),
    )
    cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2,
                    d_ff=64)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    k = fcfg.n_active

    @jax.jit
    def front_step(rgb, cache):
        patches, weights = c.sensor_patches(params["ip2"], rgb, fcfg)
        idx = c.topk_patch_indices(c.patch_energy(patches), k)
        return apply_frontend(params["ip2"], None, fcfg, indices=idx,
                              mode="compact", precomputed=(patches, weights),
                              cache=cache)

    def _embed(cf):
        return (vit_mod._embed_tokens(params, cf, cfg)
                + params["pos"][cf.indices])

    @jax.jit
    def dense_enc(cf):
        return vit_mod._encoder(params, _embed(cf), cfg, cf.valid)

    @jax.jit
    def delta_enc(cf, bc, eps):
        return delta_forward(params, cfg, cf, lambda: _embed(cf), bc, eps)

    rgb = jax.random.uniform(jax.random.PRNGKey(1), (2, 64, 64, 3))
    tcache = init_feature_cache(fcfg, (2,))
    bc = init_backend_cache(cfg, k, (2,), dtype=fcfg.adc.code_dtype)
    eps0 = jnp.zeros((2,), jnp.float32)
    closed = float(dense_backend_macs(
        k, cfg.n_layers, fcfg.patch.n_vectors, cfg.d_model, cfg.d_ff,
        cfg.n_classes))
    cold_macs = warm_macs = None
    for t in range(3):
        cf, tcache = front_step(rgb, tcache)
        jax.block_until_ready(cf)
        ld, _ = dense_enc(cf)
        lb, _, bc, macs = delta_enc(cf, bc, eps0)
        assert np.array_equal(np.asarray(ld), np.asarray(lb)), (
            f"frame {t}: eps=0 delta logits are not bitwise dense logits"
        )
        if t == 0:
            cold_macs = float(np.asarray(macs).mean())
        warm_macs = float(np.asarray(macs).sum())
    assert cold_macs == closed, (
        f"cold-frame measured MACs {cold_macs} != dense_backend_macs "
        f"closed form {closed}"
    )
    assert warm_macs == 0.0, (
        f"warm static frame still executed {warm_macs} backend MACs"
    )

    print(f"backend accounting OK: {len(names)} mac-metered rows, eps=0 "
          f"exact on {len(KINDS)} scenes, dense backend "
          f"{bd['dense_backend_mw_30hz']:.3f} mW re-priced live, cold MACs "
          f"== closed form ({closed:.0f}), warm skip bitwise + 0 MACs, "
          f"static e2e speedup {speedup:.2f}x in artifact")


if __name__ == "__main__":
    main(*sys.argv[1:])
