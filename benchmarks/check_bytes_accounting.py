"""Bench-smoke guard: BENCH_throughput.json streamed-bytes rows must be
MEASURED (ndarray.nbytes of the actual wire payload), never hand-computed
bit math (DESIGN.md §9).

Two layers of defence:

1. Schema: every bytes-reporting throughput row carries a ``bytes`` record
   with ``source == "ndarray.nbytes"`` and an integer
   ``measured_nbytes_per_frame``.
2. Live re-derivation: the af=0.25 compact-wire figure is recomputed here
   by actually running the frontend at the bench's operating point and
   reading ``features.nbytes`` off the emitted array — if someone swaps
   the bench back to ``k * M * BITS // 8`` constants and the wire format
   ever drifts (dtype, layout), this comparison breaks loudly.

Run after ``benchmarks/run.py`` (needs both src and the repo root on the
path, like run.py itself):
``PYTHONPATH=src:. python benchmarks/check_bytes_accounting.py``.
"""

import json
import sys

BYTES_ROWS = (
    "wire_bytes_compact_af0.25",
    "frontend_dense_vs_compact_af1",
    "frontend_dense_vs_compact_af0.5",
    "frontend_dense_vs_compact_af0.25",
    "frontend_dense_vs_compact_af0.1",
    "temporal_demand_static",
    "temporal_demand_panning",
    "temporal_demand_full_motion",
    "temporal_walltime_static_budget_k8",
)


def main(path: str = "BENCH_throughput.json") -> None:
    with open(path) as f:
        results = json.load(f)
    tp = next(v for k, v in results.items() if k.startswith("throughput"))
    rows = {r["name"]: r for r in tp if "name" in r}

    missing = [n for n in BYTES_ROWS if n not in rows]
    assert not missing, f"bytes rows missing from the artifact: {missing}"
    for name in BYTES_ROWS:
        rec = rows[name].get("bytes")
        assert isinstance(rec, dict), f"{name}: no bytes record"
        assert rec.get("source") == "ndarray.nbytes", (
            f"{name}: bytes not measured from the wire array "
            f"(source={rec.get('source')!r})"
        )
        assert isinstance(rec.get("measured_nbytes_per_frame"), int), name

    # live re-derivation at the bench's compact-sweep operating point —
    # imported from the bench itself so checker and bench cannot drift
    import jax

    from benchmarks.bench_throughput import compact_operating_point
    from repro.core.frontend import apply_frontend, init_frontend_params

    cfg = compact_operating_point()
    params = init_frontend_params(jax.random.PRNGKey(0), cfg)
    rgb = jax.random.uniform(
        jax.random.PRNGKey(1), (1, cfg.image_h, cfg.image_w, 3))
    cf = apply_frontend(params, rgb, cfg, mode="compact")
    live = int(cf.features.nbytes)
    rec = rows["wire_bytes_compact_af0.25"]["bytes"]
    assert rec["measured_nbytes_per_frame"] == live, (
        f"artifact says {rec['measured_nbytes_per_frame']} B/frame but the "
        f"live wire emits {live} B/frame — bytes are not being measured"
    )
    drop = rec["float32_nbytes_per_frame"] / rec["measured_nbytes_per_frame"]
    assert drop >= 3.5, f"measured code-wire drop only {drop:.2f}x vs float32"
    print(f"bytes accounting OK: {len(BYTES_ROWS)} measured rows, "
          f"{live} B/frame live == artifact, {drop:.1f}x vs float32")


if __name__ == "__main__":
    main(*sys.argv[1:])
