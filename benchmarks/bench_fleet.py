"""Sustained-load fleet serving bench (DESIGN.md §12).

Drives the multi-host :class:`repro.serve.fleet.SaccadeFleet` the way
production traffic would: streams join and leave at rate λ (Poisson churn
through the per-host admit queues), with MIXED frame rates (30/15/7.5 Hz
→ frame periods 1/2/4 ticks, served as partial-frame async steps) and
mixed priority classes. Reports per-stream p50/p99 serve latency,
aggregate streams/s, the per-engine compile count (the fleet contract is
ONE trace per engine across all churn and rate skew), and the measured
fleet mW (DESIGN.md §10).

Methodology notes, mirrored by ``check_fleet_accounting.py``:

* Latency samples are per-tick wall times of ``fleet.step`` (a stream's
  serve latency — its frame is done when the tick's logits land on the
  host); the warm-up/compile ticks are excluded. Every tick is metered
  through the ASYNC path (DESIGN.md §15) and split into the
  non-blocking dispatch (staging + upload + launch across all fed
  hosts) and the blocking fetch (device compute + D2H), stored as
  separate per-sample fields whose sum IS the total serve sample. The
  raw samples ship in the artifact row so the smoke guard re-derives
  p50/p99 for all three series instead of trusting the stored
  percentiles.
* Fleet mW is priced from the per-slot MEAN event meters summed over the
  served streams; pricing is linear in the event counts, so the guard
  re-prices the stored summed counts with a fresh ``EnergyMeter`` and
  must land on the stored milliwatt figure exactly.
* Churn coalescing is counted live: every admit/evict between two frames
  must fold into at most one jitted churn flush per engine per tick.

Runs in a subprocess so XLA_FLAGS can force a multi-device CPU host
(2 hosts x 2 devices), like the §5 multistream sweep.
"""

import json
import os
import subprocess
import sys
import textwrap
import time

# operating point shared with check_fleet_accounting.py's re-derivation
N_DEVICES = 4
N_HOSTS = 2
CAPACITY_PER_HOST = 32          # fleet capacity 64 = the acceptance floor
TICKS = 48
LAMBDA = 1.5                    # expected joins (= leaves) per tick
PERIODS = (1, 2, 4)             # mixed frame rates: 30 / 15 / 7.5 Hz
FRAME_HZ = 30.0
# sensor operating point (shared with the guard's event-law re-derivation)
IMAGE = 32
PATCH = 8
N_VECTORS = 16
ACTIVE_FRACTION = 0.25

_FLEET_CODE = """
    import json, time
    import numpy as np
    import jax
    from repro.core.frontend import FrontendConfig
    from repro.core.power import EventCounts
    from repro.core.projection import PatchSpec
    from repro.core.temporal import TemporalSpec
    from repro.data.pipeline import SceneStream
    from repro.models.vit import ViTConfig, init_vit
    from repro.serve.fleet import SaccadeFleet, make_fleet_meshes
    from repro.serve.governor import GovernorSpec

    N_HOSTS = %(n_hosts)d
    CAP = %(cap)d
    TICKS = %(ticks)d
    LAM = %(lam)f
    PERIODS = %(periods)s
    FRAME_HZ = %(frame_hz)f

    # serving-rate operating point (small sensor, 1-layer backend): the
    # regime where host-side routing/ingest overhead is visible
    fcfg = FrontendConfig(image_h=%(image)d, image_w=%(image)d,
                          aa_cutoff=None,
                          patch=PatchSpec(patch_h=%(patch)d,
                                          patch_w=%(patch)d,
                                          n_vectors=%(n_vectors)d),
                          active_fraction=%(active_fraction)f,
                          temporal=TemporalSpec(delta_threshold=1e-4))
    cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    stream = SceneStream(image=%(image)d)
    pool = stream.batch(0, 64)[0]

    meshes = make_fleet_meshes(N_HOSTS)
    fleet = SaccadeFleet(cfg, params, n_hosts=N_HOSTS, capacity=CAP,
                         meshes=meshes, temporal=True, frame_hz=FRAME_HZ,
                         governor=GovernorSpec(budget_mw=50.0))

    # count churn flushes per engine: k admits/evicts between two frames
    # must coalesce into <= 1 flush per engine per tick
    flushes = [0] * N_HOSTS
    for h, eng in enumerate(fleet.engines):
        inner = eng._churn_fn
        def wrap(inner=inner, h=h):
            def f(*a):
                flushes[h] += 1
                return inner(*a)
            return f
        eng._churn_fn = wrap()

    rng = np.random.default_rng(0)
    classes = ["realtime", "standard", "background"]
    period_of, phase_of = {}, {}
    next_id = 0
    churn_ops = 0

    def join(n):
        global next_id, churn_ops
        for _ in range(n):
            sid = f"s{next_id}"
            fleet.submit(sid, classes[next_id %% len(classes)])
            period_of[sid] = PERIODS[next_id %% len(PERIODS)]
            phase_of[sid] = next_id %% period_of[sid]
            next_id += 1
            churn_ops += 1

    join(N_HOSTS * CAP)                      # fill the fleet: 64 streams
    # warm-up ticks: drain queues, compile both engines, and absorb the
    # first post-compile executions (the first couple of calls after a
    # compile run slow on CPU; steady state is what we meter)
    frames = {sid: pool[i %% len(pool)]
              for i, sid in enumerate(period_of)}
    for _ in range(3):
        out = fleet.step(frames)
        for v in out.values():
            np.asarray(v)
    assert fleet.queued == 0 and fleet.free_slots == 0
    peak = len(fleet.stream_ids)

    samples_ms, dispatch_ms, fetch_ms = [], [], []
    served, fed_hist = 0, []
    t_wall0 = time.perf_counter()
    for t in range(TICKS):
        # lambda-churn: Poisson leaves then the same number of joins, so
        # the fleet stays saturated at 64 concurrent streams
        n_churn = int(rng.poisson(LAM))
        live = fleet.stream_ids
        for sid in rng.choice(live, size=min(n_churn, len(live) - 1),
                              replace=False):
            fleet.evict(str(sid))
            del period_of[str(sid)]; del phase_of[str(sid)]
            churn_ops += 1
        join(n_churn)
        base = {h: f for h, f in enumerate(flushes)}

        # mixed frame rates: only streams whose period divides this tick
        frames = {sid: pool[(hash(sid) + t) %% len(pool)]
                  for sid in list(period_of)
                  if sid in fleet._host_of
                  and t %% period_of[sid] == phase_of[sid]}
        # async split (DESIGN.md 15): meter the non-blocking dispatch
        # (staging + upload + launch, all hosts in flight) separately
        # from the blocking fetch (device compute + D2H). Total serve
        # latency is their sum by construction.
        t0 = time.perf_counter()
        handle = fleet.step(frames, block=False)
        t1 = time.perf_counter()
        out = handle.result()
        for v in out.values():
            np.asarray(v)                    # frames done when on host
        t2 = time.perf_counter()
        d_ms = (t1 - t0) * 1e3
        f_ms = (t2 - t1) * 1e3
        # queued joins admitted by this step serve from the NEXT tick;
        # count only what this tick actually served
        dispatch_ms.append(d_ms)
        fetch_ms.append(f_ms)
        samples_ms.append(d_ms + f_ms)
        served += len(out)
        fed_hist.append(len(out))
        peak = max(peak, len(fleet.stream_ids))
        for h in range(N_HOSTS):
            assert flushes[h] - base[h] <= 1, (h, flushes, base)
    t_wall = time.perf_counter() - t_wall0

    # fleet mW from the per-slot mean meters, plus the summed counts so
    # the smoke guard can re-price them (pricing is linear in events)
    fleet_mw = fleet.fleet_power_mw("mean")
    ev_sum = None
    for eng in fleet.engines:
        host, ages = eng._fetch_meters("mean")
        occ = np.array([s is not None for s in eng._slots]) & (ages > 0)
        s = [float(np.where(occ, np.asarray(leaf), 0.0).sum())
             for leaf in host]
        ev_sum = s if ev_sum is None else [a + b for a, b in zip(ev_sum, s)]

    print(json.dumps({
        "n_dev": len(jax.devices()),
        "samples_ms": samples_ms,
        "dispatch_ms": dispatch_ms,
        "fetch_ms": fetch_ms,
        "served_frames": served,
        "wall_s": t_wall,
        "peak_streams": peak,
        "churn_ops": churn_ops,
        "flushes": flushes,
        "n_traces": fleet.n_traces,
        "fed_min": min(fed_hist), "fed_max": max(fed_hist),
        "fleet_mw_mean": fleet_mw,
        "events_mean_sum": ev_sum,
        "event_fields": list(EventCounts._fields),
    }))
"""


def sustained_load(n_devices: int = N_DEVICES) -> list[dict]:
    """Run the λ-churn fleet simulation on forced multi-device CPU."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _FLEET_CODE % {
        "n_hosts": N_HOSTS, "cap": CAPACITY_PER_HOST, "ticks": TICKS,
        "lam": LAMBDA, "periods": repr(list(PERIODS)),
        "frame_hz": FRAME_HZ, "image": IMAGE, "patch": PATCH,
        "n_vectors": N_VECTORS, "active_fraction": ACTIVE_FRACTION,
    }
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"fleet subprocess failed: {proc.stderr[-3000:]}")
    r = json.loads(proc.stdout.strip().splitlines()[-1])

    import numpy as np

    samples = np.asarray(r["samples_ms"])
    p50 = float(np.percentile(samples, 50))
    p99 = float(np.percentile(samples, 99))
    disp = np.asarray(r["dispatch_ms"])
    fetch = np.asarray(r["fetch_ms"])
    streams_per_s = r["served_frames"] / r["wall_s"]

    # hard contracts (data properties, never relaxed): one compile per
    # engine across all churn + rate skew; the fleet really saturated
    if any(n != 1 for n in r["n_traces"]):
        raise AssertionError(
            f"fleet engines recompiled under churn: n_traces={r['n_traces']}")
    assert r["peak_streams"] >= N_HOSTS * CAPACITY_PER_HOST, r["peak_streams"]
    assert r["fed_min"] < r["fed_max"], "frame rates did not actually mix"

    fleet_rec = {
        "source": "perf_counter+EnergyMeter",
        "n_hosts": N_HOSTS, "capacity_per_host": CAPACITY_PER_HOST,
        "ticks": TICKS, "lam": LAMBDA, "periods": list(PERIODS),
        "frame_hz": FRAME_HZ,
        "latency_ms_samples": r["samples_ms"],
        "dispatch_ms_samples": r["dispatch_ms"],
        "fetch_ms_samples": r["fetch_ms"],
        "p50_ms": p50, "p99_ms": p99,
        "dispatch_p50_ms": float(np.percentile(disp, 50)),
        "dispatch_p99_ms": float(np.percentile(disp, 99)),
        "fetch_p50_ms": float(np.percentile(fetch, 50)),
        "fetch_p99_ms": float(np.percentile(fetch, 99)),
        "served_frames": r["served_frames"], "wall_s": r["wall_s"],
        "streams_per_s": streams_per_s,
        "peak_streams": r["peak_streams"],
        "churn_ops": r["churn_ops"], "flushes": r["flushes"],
        "n_traces": r["n_traces"],
        "fleet_mw_mean": r["fleet_mw_mean"],
        "events_mean_sum": dict(zip(r["event_fields"],
                                    r["events_mean_sum"])),
    }
    rows = [{
        "name": f"fleet_sustained_s{N_HOSTS * CAPACITY_PER_HOST}"
                f"_h{N_HOSTS}_lam{LAMBDA:g}",
        "us_per_call": p50 * 1e3,
        "fleet": fleet_rec,
        "derived": (
            f"{r['peak_streams']} streams over {N_HOSTS} hosts, "
            f"lam={LAMBDA:g} churn x{r['churn_ops']} ops -> "
            f"{sum(r['flushes'])} flushes, mixed rates "
            f"{'/'.join(str(p) for p in PERIODS)}; p50 {p50:.2f}ms "
            f"p99 {p99:.2f}ms (dispatch p50 "
            f"{float(np.percentile(disp, 50)):.2f}ms / fetch p50 "
            f"{float(np.percentile(fetch, 50)):.2f}ms), "
            f"{streams_per_s:.0f} streams/s, "
            f"{r['fleet_mw_mean']:.3f} mW fleet, "
            f"traces {r['n_traces']}"
        ),
    }]
    return rows


def run() -> list[dict]:
    t0 = time.perf_counter()
    rows = sustained_load()
    dt = time.perf_counter() - t0
    rows.append({
        "name": "fleet_bench_wall",
        "us_per_call": dt * 1e6,
        "derived": f"sustained-load simulation wall {dt:.1f}s",
    })
    return rows
