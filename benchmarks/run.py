"""Benchmark harness — one module per paper table/figure (DESIGN.md §8).

Prints ``name,us_per_call,derived`` CSV and writes ``BENCH_throughput.json``
(all rows, keyed by module) so successive PRs accumulate a perf trajectory.
``--quick`` swaps the full accuracy study (bench_accuracy trains 10 small
models and dominates wall time) for its smoke arm: one short train plus
the served-wire evals (dense oracle vs int8 code wire vs 1-bit sign wire).
"""

import argparse
import json
import sys
import traceback
import types


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--json-out", default="BENCH_throughput.json")
    args = ap.parse_args()

    from benchmarks import (
        bench_fleet, bench_kernels, bench_leakage, bench_power,
        bench_roofline, bench_rollout, bench_throughput,
    )

    modules = [
        ("leakage(§2.1.2)", bench_leakage),
        ("power+area(Table1,§2.1.3)", bench_power),
        ("throughput(Fig.3,§2.1.4)", bench_throughput),
        ("kernels", bench_kernels),
        ("roofline(§11)", bench_roofline),
        ("fleet(§12)", bench_fleet),
        ("rollout(§15)", bench_rollout),
    ]
    from benchmarks import bench_accuracy

    if args.quick:
        # smoke arm: one short train + served-wire evals (code/sign), so
        # the accuracy seams stay covered in the bench-smoke CI lane
        modules.append((
            "accuracy-smoke(§13)",
            types.SimpleNamespace(run=bench_accuracy.run_quick),
        ))
    else:
        modules.append(("accuracy(§1,§2.1.3,§2.1.5,Fig.4)", bench_accuracy))

    print("name,us_per_call,derived")
    failures = 0
    results: dict[str, list[dict]] = {}
    for label, mod in modules:
        try:
            rows = mod.run()
            results[label] = rows
            for row in rows:
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
        except Exception as e:
            failures += 1
            # a module may attach the rows it collected before failing
            # (bench_throughput does): keep them in the artifact so one
            # failed sweep doesn't erase the others' perf trajectory
            kept = list(getattr(e, "rows", []))
            for row in kept:
                print(f"{row['name']},{row['us_per_call']:.1f},{row['derived']}")
            results[label] = kept + [
                {"name": label, "error": f"{type(e).__name__}: {e}"}]
            print(f"{label},FAIL,{type(e).__name__}: {e}", file=sys.stderr)
            traceback.print_exc()
    if args.json_out:
        with open(args.json_out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"wrote {args.json_out}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
