"""Paper Fig. 3 — processing rate vs weight lines C ∈ {1,2,4,8} for
720p/1080p sensors at 400/768 vectors per 32×32 patch, + the 8×8/192-vector
operating point. Reproduces the ~90 Hz 1080p C=2 claim and >30 Hz for 8×8,
and the 10x/30x data-dimensionality reduction (§1, §2.1.4)."""

import time

from repro.core.power import SensorConfig, data_reduction
from repro.core.throughput import figure3_sweep, frame_rate, rate_point


def run() -> list[dict]:
    t0 = time.perf_counter_ns()
    sweep = figure3_sweep()
    us = (time.perf_counter_ns() - t0) / 1e3
    rows = []
    for p in sweep:
        rows.append({
            "name": f"fig3_{p.fmt}_{p.n_vectors}vec_C{p.c_lines}",
            "us_per_call": us / len(sweep),
            "derived": f"{p.frame_hz:.1f}Hz {p.mpix_per_s:.0f}Mpix/s",
        })
    op = rate_point("1080p", 2, 32, 400)
    rows.append({
        "name": "fig3_operating_point_1080p_C2_400vec",
        "us_per_call": us, "derived": f"{op.frame_hz:.1f}Hz (paper ~90Hz)",
    })
    hz8 = frame_rate(8, 192, 2)
    rows.append({
        "name": "fig3_8x8_192vec", "us_per_call": us,
        "derived": f"{hz8:.0f}Hz (paper >30Hz)",
    })
    red = data_reduction(SensorConfig())
    red_rgb = data_reduction(SensorConfig(), vs_rgb=True)
    rows.append({"name": "data_reduction_vs_bayer", "us_per_call": us,
                 "derived": f"{red:.1f}x (paper 10x)"})
    rows.append({"name": "data_reduction_vs_rgb", "us_per_call": us,
                 "derived": f"{red_rgb:.1f}x (paper 30x)"})
    assert 85 <= op.frame_hz <= 95 and hz8 > 30 and red >= 10 and red_rgb >= 30
    return rows
