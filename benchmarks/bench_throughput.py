"""Paper Fig. 3 — processing rate vs weight lines C ∈ {1,2,4,8} for
720p/1080p sensors at 400/768 vectors per 32×32 patch, + the 8×8/192-vector
operating point. Reproduces the ~90 Hz 1080p C=2 claim and >30 Hz for 8×8,
and the 10x/30x data-dimensionality reduction (§1, §2.1.4).

Also sweeps the dense vs compact execution modes (DESIGN.md §3) over
active_fraction ∈ {1.0, 0.5, 0.25, 0.1}: wall time of the selectable
frontend compute (CDS patch voltages -> projection -> ADC readout; the
optics/mosaic stage integrates photons regardless of selection and is
excluded from both sides) and the streamed feature bytes vs full-frame raw.

Streamed-bytes methodology (DESIGN.md §9): every bytes figure is MEASURED
from the ``nbytes``/``itemsize`` of the actual wire arrays the frontend
emits (int8 ADC codes by default), never hand-computed from assumed bit
widths — rows carry a ``bytes`` record with ``source: "ndarray.nbytes"``
and the bench-smoke job re-derives them from a live frontend run
(benchmarks/check_bytes_accounting.py) to keep it that way.

The delta-gated backend sweep (DESIGN.md §14) crosses the same motion
levels with an eps reuse-budget grid at a backend-heavy operating point:
steady-state backend recompute fraction + worst-case logit error per cell,
a frontend/backend wall-time breakdown, and the tentpole claim — the
end-to-end gated step (frontend + fully-cached backend skip) beats the
dense step >= 2x on a static scene at eps=0.

And the multi-stream serving sweep (DESIGN.md §5): the slot-based
SaccadeEngine over 1/8/32 concurrent camera streams on forced multi-device
CPU (slot axis shard_map'd over 4 host devices where capacity divides),
streams/sec + per-stream latency per row, vs sequentially looping the
single-stream saccade step — asserts the batched engine wins ≥4x at 8
streams. Runs in a subprocess so XLA_FLAGS can force the device count.
"""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time

from repro.core.power import SensorConfig, data_reduction
from repro.core.throughput import figure3_sweep, frame_rate, rate_point

RAW_PIXEL_BITS = 10     # column SAR raw readout
FEATURE_BITS = 8        # edge-ADC feature samples (paper's 8-bit point)


def compact_operating_point(image: int = 256, patch: int = 16,
                            n_vectors: int = 400):
    """The compact-sweep frontend config — THE shared definition of the
    bench's operating point, also imported by check_bytes_accounting.py so
    the live bytes re-derivation can never drift from what the bench
    measured."""
    from repro.core.frontend import FrontendConfig
    from repro.core.projection import PatchSpec

    return FrontendConfig(
        image_h=image, image_w=image,
        patch=PatchSpec(patch_h=patch, patch_w=patch, n_vectors=n_vectors),
        aa_cutoff=None, active_fraction=0.25,
    )


def _best_of(f, *args, n: int = 7) -> float:
    """Best-of-n wall time in seconds for a jitted fn (CPU sim timing)."""
    import jax

    jax.tree_util.tree_leaves(f(*args))[0].block_until_ready()   # compile
    best = float("inf")
    for _ in range(n):
        t0 = time.perf_counter()
        out = f(*args)
        jax.tree_util.tree_leaves(out)[0].block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def compact_sweep(
    image: int = 256, patch: int = 16, n_vectors: int = 400, batch: int = 8
) -> list[dict]:
    """Dense-then-mask vs select->gather->project, same weights/selection."""
    import jax

    import repro.core as c
    from repro.core import saliency as sal
    from repro.core.frontend import (
        apply_frontend, project_readout, init_frontend_params,
    )

    base = compact_operating_point(image, patch, n_vectors)
    params = init_frontend_params(jax.random.PRNGKey(0), base)
    rgb = jax.random.uniform(jax.random.PRNGKey(1), (batch, image, image, 3))
    patches = c.extract_patches(c.mosaic(rgb), patch, patch)
    weights = c.strike_columns(params["a_rgb"], patch, patch)
    energy = c.patch_energy(patches)
    raw_bytes = image * image * RAW_PIXEL_BITS // 8

    # projection+readout is independent of active_fraction: one jitted fn
    # each (compact re-traces per k from the index shape; dense compiles once)
    dense = jax.jit(lambda pp, mm: sal.apply_patch_mask(
        project_readout(pp, weights, params, base, None), mm))
    compact = jax.jit(lambda pp, ii: project_readout(
        sal.gather_patches(pp, ii), weights, params, base, None))
    # the full wire-format step (select -> gather -> project -> encode):
    # what actually crosses the imager boundary, timed AND weighed
    # (re-traces per k via the index shape, like ``compact`` above)
    def make_wire(cfg, wire):
        def fn(pp, ii):
            return apply_frontend(
                params, None, cfg, indices=ii, mode="compact",
                precomputed=(pp, weights), wire=wire,
            ).features
        return jax.jit(fn)

    rows = []
    speedup_at_25 = None
    for af in (1.0, 0.5, 0.25, 0.1):
        cfg = dataclasses.replace(base, active_fraction=af)
        k = cfg.n_active
        mask = c.topk_patch_mask(energy, af)
        idx = c.topk_patch_indices(energy, k)

        t_dense = _best_of(dense, patches, mask)
        t_compact = _best_of(compact, patches, idx)
        speedup = t_dense / t_compact
        if af == 0.25:
            speedup_at_25 = speedup
        # measured wire traffic: nbytes of the actual emitted payload
        stream_bytes = int(make_wire(cfg, "codes")(patches, idx).nbytes) // batch
        rows.append({
            "name": f"frontend_dense_vs_compact_af{af:g}",
            "us_per_call": t_compact * 1e6,
            "bytes": {"measured_nbytes_per_frame": stream_bytes,
                      "source": "ndarray.nbytes"},
            "derived": (
                f"dense {t_dense * 1e3:.2f}ms compact {t_compact * 1e3:.2f}ms "
                f"{speedup:.2f}x; stream {stream_bytes / 1024:.0f}KiB "
                f"vs raw {raw_bytes / 1024:.0f}KiB "
                f"({raw_bytes / stream_bytes:.1f}x fewer bytes)"
            ),
        })

    # ADC-code-native wire (DESIGN.md §9) at the 25 % operating point:
    # measured nbytes + wall time, int8 codes vs the float32 compact wire
    idx25 = c.topk_patch_indices(energy, base.n_active)
    wire_code = make_wire(base, "codes")
    wire_float = make_wire(base, "float")
    codes_arr = wire_code(patches, idx25)
    float_arr = wire_float(patches, idx25)
    t_code = _best_of(wire_code, patches, idx25)
    t_float = _best_of(wire_float, patches, idx25)
    b_code = int(codes_arr.nbytes) // batch
    b_float = int(float_arr.nbytes) // batch
    byte_drop = b_float / b_code
    rows.append({
        "name": "wire_bytes_compact_af0.25",
        "us_per_call": t_code * 1e6,
        "bytes": {"measured_nbytes_per_frame": b_code,
                  "float32_nbytes_per_frame": b_float,
                  "source": "ndarray.nbytes"},
        "derived": (
            f"{codes_arr.dtype} wire {b_code / 1024:.0f}KiB/frame vs float32 "
            f"{b_float / 1024:.0f}KiB ({byte_drop:.1f}x fewer bytes measured); "
            f"code step {t_code * 1e3:.2f}ms vs float step {t_float * 1e3:.2f}ms"
        ),
    })
    # the wire claim is byte accounting, not wall clock: always hard
    assert byte_drop >= 3.5, (
        f"code wire only {byte_drop:.2f}x smaller than float32 measured")

    # the paper's streamed-bytes claim at its own operating point:
    # 2 Mpix / 32x32 / 400 vec / 25 % active, 8-bit features vs 10-bit raw
    op = SensorConfig()
    byte_reduction = data_reduction(op) * RAW_PIXEL_BITS / FEATURE_BITS
    rows.append({
        "name": "compact_streamed_bytes_reduction_paper_point",
        "us_per_call": 0.0,
        "derived": f"{byte_reduction:.1f}x vs full-frame raw (paper ~10x)",
    })
    # wall-clock asserts are meaningless on noisy shared runners; CI sets
    # IP2_BENCH_RELAX=1 to log instead of fail (byte accounting stays hard)
    if speedup_at_25 is None or speedup_at_25 < 2.0:
        msg = f"compact path only {speedup_at_25:.2f}x faster at 25% activity"
        if os.environ.get("IP2_BENCH_RELAX"):
            print(f"WARNING: {msg}", file=sys.stderr)
        else:
            raise AssertionError(msg)
    assert byte_reduction >= 10.0
    return rows


def motion_sweep(
    image: int = 512, patch: int = 32, n_vectors: int = 400, batch: int = 8,
    frames: int = 8,
) -> list[dict]:
    """Temporal delta gate (DESIGN.md §6) over motion levels.

    Three synthetic T-frame scenes — static (frozen frame), panning (the
    frame translates a few pixels per frame), full-motion (an unrelated
    scene every frame) — each served by the gated compact frontend with an
    unlimited recompute budget to measure the true per-frame recompute
    *demand* (stale fraction of the k selected patches) and the streamed
    feature bytes (held patches never leave the sensor).

    Wall time: the budget j is the hardware's provisioned per-frame
    conversion capacity. A static scene's steady demand is ~0, so j = k/8
    comfortably covers droop refresh + novelty; the gated step projecting
    j rows must beat the always-recompute step (k rows) by >= 2x. A
    full-motion scene needs j = k and the gate degenerates to the
    always-recompute path. Like the dense-vs-compact sweep, the timed
    quantity is the selectable frontend compute: the optics/mosaic stage
    and the in-pixel energy proxy run regardless of gating (photodiodes
    integrate light; the proxy is a free analog signal) and are excluded
    from both sides, and the weights are closed over as constants — the
    DAC is programmed once, not per frame.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.core as c
    from repro.core.frontend import (
        FrontendConfig, apply_frontend, init_frontend_params,
    )
    from repro.core.projection import PatchSpec
    from repro.core.temporal import TemporalSpec, init_feature_cache
    from repro.data.pipeline import SceneStream

    base = FrontendConfig(
        image_h=image, image_w=image,
        patch=PatchSpec(patch_h=patch, patch_w=patch, n_vectors=n_vectors),
        aa_cutoff=None, active_fraction=0.25,
        temporal=TemporalSpec(delta_threshold=2e-4),
    )
    params = init_frontend_params(jax.random.PRNGKey(0), base)
    k = base.n_active
    stream = SceneStream(image=image)
    frame0 = stream.batch(0, batch)[0]

    def scene_frames(kind: str) -> list:
        if kind == "static":
            return [frame0] * frames
        if kind == "panning":
            return [np.roll(frame0, 3 * t, axis=2) for t in range(frames)]
        return [stream.batch(t, batch)[0] for t in range(frames)]

    # --- recompute demand + streamed bytes per motion level (full API path,
    # budget None => j = k so the gate reports true per-frame demand)
    @jax.jit
    def demand_step(patches, weights, idx, cache):
        cf, cache = apply_frontend(
            params, None, base, indices=idx, mode="compact",
            precomputed=(patches, weights), cache=cache,
        )
        return cf.features, cache

    rows = []
    demand = {}
    for kind in ("static", "panning", "full_motion"):
        cache = init_feature_cache(base, (batch,))
        fracs, bytes_gated = [], 0
        row_nbytes = None
        t0 = time.perf_counter()
        for rgb in scene_frames(kind):
            patches, weights = c.sensor_patches(params, jnp.asarray(rgb), base)
            idx = c.topk_patch_indices(c.patch_energy(patches), k)
            feats, cache = demand_step(patches, weights, idx, cache)
            n_stale = np.asarray(cache.n_stale)
            fracs.append(float(n_stale.mean()) / k)
            # measured: bytes per converted row straight from the wire
            # payload the step emitted (int8 codes), not assumed bit math
            row_nbytes = int(feats.nbytes) // (batch * k)
            bytes_gated += int(n_stale.sum()) * row_nbytes
        dt = time.perf_counter() - t0
        bytes_always = frames * batch * k * row_nbytes
        steady = fracs[1:]
        demand[kind] = steady
        rows.append({
            "name": f"temporal_demand_{kind}",
            "us_per_call": dt / frames * 1e6,
            "bytes": {"measured_nbytes_per_frame": bytes_gated // frames,
                      "always_recompute_nbytes_per_frame": bytes_always // frames,
                      "source": "ndarray.nbytes"},
            "derived": (
                f"recompute fraction: frame0 {fracs[0]:.2f}, then "
                f"mean {sum(steady) / len(steady):.3f} max {max(steady):.3f}; "
                f"streamed {bytes_gated / 1024:.0f}KiB vs always-recompute "
                f"{bytes_always / 1024:.0f}KiB "
                f"({bytes_always / max(bytes_gated, 1):.1f}x fewer bytes)"
            ),
        })

    # --- wall time at provisioned capacity: j = k/8 (static-scene regime),
    # in the code wire end to end (DESIGN.md §9). Built from the gate's
    # primitives so the timed quantity stays the *selectable* frontend
    # compute: the energy proxy is precomputed (a free analog signal that
    # runs regardless of gating) and the weights are closed over (the DAC
    # is programmed once, not per frame) — same exclusions as PR 1/PR 3.
    from repro.core.frontend import project_wire
    from repro.core.saliency import gather_patches
    from repro.core.temporal import held_gain, select_stale, refresh, take_rows

    j = max(1, k // 8)
    spec_j = TemporalSpec(delta_threshold=2e-4, recompute_budget=j)
    patches, weights = c.sensor_patches(params, jnp.asarray(frame0), base)
    energy = c.patch_energy(patches)
    idx = c.topk_patch_indices(energy, k)

    @jax.jit
    def gated_tick(patches, energy, idx, cache):
        si, ne, ns = select_stale(
            energy, idx, cache, spec_j, base.patch.summer, base.adc)
        codes = project_wire(
            gather_patches(patches, si), weights, params, base, None, "codes")
        cache = refresh(cache, si, ne, codes, energy, ns)
        served = take_rows(cache.features, idx)          # int8 codes
        return served, held_gain(cache, idx, base.patch.summer), cache

    @jax.jit
    def always_tick(patches, idx):
        return project_wire(
            gather_patches(patches, idx), weights, params, base, None, "codes")

    cache = init_feature_cache(base, (batch,))
    for _ in range(frames):                  # converge to steady state
        *_, cache = gated_tick(patches, energy, idx, cache)

    t_gated = _best_of(gated_tick, patches, energy, idx, cache)
    t_always = _best_of(always_tick, patches, idx)
    speedup = t_always / t_gated
    held_payload, _, _ = gated_tick(patches, energy, idx, cache)
    rows.append({
        "name": "temporal_walltime_static_budget_k8",
        "us_per_call": t_gated * 1e6,
        "bytes": {
            # steady-state static scene: conversions track the true stale
            # count (droop refresh only) — measured from the emitted rows
            "measured_nbytes_per_frame":
                int(np.asarray(cache.n_stale).sum()) * n_vectors
                * held_payload.dtype.itemsize // batch,
            "always_recompute_nbytes_per_frame": int(held_payload.nbytes) // batch,
            "source": "ndarray.nbytes"},
        "derived": (
            f"always {t_always * 1e3:.2f}ms vs gated(j={j}/{k}) "
            f"{t_gated * 1e3:.2f}ms = {speedup:.2f}x on the static scene "
            f"({held_payload.dtype} wire)"
        ),
    })
    # demand sanity: the gate must be quiet on static scenes and saturated
    # on full motion — these are data properties, asserted hard
    assert max(demand["static"]) <= 0.10, demand["static"]
    assert sum(demand["full_motion"]) / len(demand["full_motion"]) >= 0.5
    if speedup < 2.0:
        msg = f"gated path only {speedup:.2f}x faster on the static scene"
        if os.environ.get("IP2_BENCH_RELAX"):
            print(f"WARNING: {msg}", file=sys.stderr)
        else:
            raise AssertionError(msg)
    return rows


def backend_delta_sweep(
    image: int = 128, patch: int = 16, frames: int = 8, batch: int = 2,
) -> list[dict]:
    """Delta-gated incremental backend (DESIGN.md §14) over motion levels
    and reuse budgets.

    A backend-heavy operating point (4-layer d128 encoder over 32 active
    tokens: ~25M backend MACs vs ~0.8M frontend MACs) served through the
    same three synthetic scenes as ``motion_sweep`` — static, panning,
    full-motion — crossed with an eps grid. Per cell: the steady-state
    backend recompute fraction (delta MACs / dense MACs, measured from the
    MAC meter the forward emits) and the worst-case logit error vs the
    dense encoder run on the SAME materialized wire block.

    Wall time is reported as a frontend/backend breakdown (gated frontend
    step, dense encoder, delta encoder on a warm cache) plus the
    end-to-end step comparison the tentpole claims: on a static scene at
    eps=0 the gated step (frontend + fully-cached backend skip) must beat
    the dense step (frontend + full encoder) by >= 2x. Selection is
    per-frame energy top-k — deterministic, so a static scene converges
    without the saccade policy in the loop (the engine-level policy path
    is exercised in tests/test_backend_delta.py).
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    import repro.core as c
    from repro.core.frontend import FrontendConfig, apply_frontend
    from repro.core.projection import PatchSpec
    from repro.core.switched_cap import SummerSpec
    from repro.core.temporal import TemporalSpec, init_feature_cache
    from repro.data.pipeline import SceneStream
    from repro.models import vit as vit_mod
    from repro.models.backend_delta import delta_forward, init_backend_cache
    from repro.models.vit import ViTConfig, init_vit

    # passive droop-free summer: held wire rows are bitwise stable across
    # frames — the reuse precondition (DESIGN.md §14)
    fcfg = FrontendConfig(
        image_h=image, image_w=image,
        patch=PatchSpec(patch_h=patch, patch_w=patch, n_vectors=32,
                        summer=SummerSpec(mode="passive", hold_time_s=0.0)),
        aa_cutoff=None, active_fraction=0.5,
        temporal=TemporalSpec(delta_threshold=1e-3),
    )
    cfg = ViTConfig(frontend=fcfg, n_layers=4, d_model=128, n_heads=4,
                    d_ff=512)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    k = fcfg.n_active
    stream = SceneStream(image=image)
    frame0 = stream.batch(0, batch)[0]

    def scene_frames(kind: str) -> list:
        if kind == "static":
            return [frame0] * frames
        if kind == "drift":
            # slow contrast creep (multiplicative — a DC offset would be
            # erased by CDS): every row is *slightly* stale each frame,
            # the regime the eps snap budget is built to absorb
            return [np.clip(frame0 * (1.0 + 0.005 * t), 0.0, 1.0)
                    .astype(np.float32) for t in range(frames)]
        if kind == "panning":
            return [np.roll(frame0, 3 * t, axis=2) for t in range(frames)]
        return [stream.batch(t, batch)[0] for t in range(frames)]

    @jax.jit
    def front_step(rgb, cache):
        patches, weights = c.sensor_patches(params["ip2"], rgb, fcfg)
        idx = c.topk_patch_indices(c.patch_energy(patches), k)
        return apply_frontend(params["ip2"], None, fcfg, indices=idx,
                              mode="compact", precomputed=(patches, weights),
                              cache=cache)

    def _embed(cf):
        return (vit_mod._embed_tokens(params, cf, cfg)
                + params["pos"][cf.indices])

    # standalone encoder programs over the materialized wire block — the
    # only formulation where eps=0 dense/delta equality is bitwise
    # (tests/test_backend_delta.py documents the XLA fusion-drift rationale)
    @jax.jit
    def dense_enc(cf):
        return vit_mod._encoder(params, _embed(cf), cfg, cf.valid)

    @jax.jit
    def delta_enc(cf, bc, eps):
        return delta_forward(params, cfg, cf, lambda: _embed(cf), bc, eps)

    wire_dtype = fcfg.adc.code_dtype
    rows = []
    frac = {}       # (kind, eps) -> steady-state mean recompute fraction
    err = {}        # (kind, eps) -> worst-case |delta - dense| logit error
    dense_macs = None
    kinds = ("static", "drift", "panning", "full_motion")
    for kind in kinds:
        for eps_val in (0.0, 1e-1, 5e-1):
            tcache = init_feature_cache(fcfg, (batch,))
            bc = init_backend_cache(cfg, k, (batch,), dtype=wire_dtype)
            eps = jnp.full((batch,), eps_val, jnp.float32)
            fr, er = [], 0.0
            for rgb in scene_frames(kind):
                cf, tcache = front_step(jnp.asarray(rgb), tcache)
                jax.block_until_ready(cf)
                ld, _ = dense_enc(cf)
                l, _, bc, macs = delta_enc(cf, bc, eps)
                if dense_macs is None:       # cold frame computes everything
                    dense_macs = float(np.asarray(macs).mean())
                fr.append(float(np.asarray(macs).mean()) / dense_macs)
                er = max(er, float(jnp.max(jnp.abs(l - ld))))
            frac[kind, eps_val] = sum(fr[1:]) / len(fr[1:])
            err[kind, eps_val] = er
        rows.append({
            "name": f"backend_delta_{kind}",
            "us_per_call": 0.0,
            # machine-readable record for check_backend_accounting.py:
            # MACs straight from the forward's MAC meter, never hand math
            "backend": {
                "dense_macs_per_frame": dense_macs,
                "recompute_frac": {f"{e:g}": frac[kind, e]
                                   for e in (0.0, 1e-1, 5e-1)},
                "max_logit_err": {f"{e:g}": err[kind, e]
                                  for e in (0.0, 1e-1, 5e-1)},
                "source": "mac-meter",
            },
            "derived": "; ".join(
                f"eps={e:g}: recompute {frac[kind, e]:.3f} "
                f"err {err[kind, e]:.2e}"
                for e in (0.0, 1e-1, 5e-1)
            ),
        })

    # the measured cold frame must reproduce the closed-form dense MAC
    # count — the same identity the engine's governor pricing relies on
    from repro.core.power import EnergyMeter, dense_backend_macs
    closed = dense_backend_macs(k, cfg.n_layers, fcfg.patch.n_vectors,
                                cfg.d_model, cfg.d_ff, cfg.n_classes)
    assert dense_macs == float(closed), (dense_macs, closed)

    # data properties, asserted hard: eps=0 is exact (same wire block,
    # standalone programs -> bitwise); a static scene fully caches; full
    # motion saturates; a larger eps never recomputes more; on the drift
    # scene the budget visibly trades recompute for bounded logit error
    assert all(err[kind, 0.0] == 0.0 for kind in kinds), err
    assert frac["static", 0.0] == 0.0, frac
    assert frac["full_motion", 0.0] >= 0.9, frac
    for kind in kinds:
        assert (frac[kind, 5e-1] <= frac[kind, 1e-1] + 1e-9
                <= frac[kind, 0.0] + 2e-9), (kind, frac)
    assert frac["drift", 5e-1] < frac["drift", 0.0], frac
    assert 0.0 < err["drift", 5e-1] <= 0.5, err

    # --- wall-time breakdown + the tentpole's end-to-end claim: converge
    # the caches on the static scene, then time the pieces and the
    # composed steps (the delta program must actually be on the skip path)
    tcache = init_feature_cache(fcfg, (batch,))
    bc = init_backend_cache(cfg, k, (batch,), dtype=wire_dtype)
    eps0 = jnp.zeros((batch,), jnp.float32)
    rgb0 = jnp.asarray(frame0)
    for _ in range(3):
        cf, tcache = front_step(rgb0, tcache)
        _, _, bc, macs = delta_enc(cf, bc, eps0)
    assert float(np.asarray(macs).sum()) == 0.0, "warm cache must fully skip"

    t_front = _best_of(front_step, rgb0, tcache)
    t_dense = _best_of(dense_enc, cf)
    t_delta = _best_of(delta_enc, cf, bc, eps0)
    t_e2e_dense = _best_of(lambda: dense_enc(front_step(rgb0, tcache)[0]))
    t_e2e_gated = _best_of(
        lambda: delta_enc(front_step(rgb0, tcache)[0], bc, eps0))
    speedup = t_e2e_dense / t_e2e_gated
    # backend milliwatts priced by the event meter's MAC constant at the
    # paper's 30 Hz serving point — re-derived live by the CI guard
    mw_30hz = dense_macs * EnergyMeter().k.e_backend_mac_j * 30.0 * 1e3
    rows.append({
        "name": "backend_walltime_breakdown_static",
        "us_per_call": t_e2e_gated * 1e6,
        "backend": {
            "dense_macs_per_frame": dense_macs,
            "dense_backend_mw_30hz": mw_30hz,
            "e2e_dense_ms": t_e2e_dense * 1e3,
            "e2e_gated_ms": t_e2e_gated * 1e3,
            "speedup": speedup,
            "source": "mac-meter",
        },
        "derived": (
            f"frontend {t_front * 1e3:.2f}ms, dense backend "
            f"{t_dense * 1e3:.2f}ms, delta backend (warm skip) "
            f"{t_delta * 1e3:.2f}ms"
        ),
    })
    rows.append({
        "name": "backend_delta_speedup_static_eps0",
        "us_per_call": t_e2e_gated * 1e6,
        "derived": (
            f"end-to-end dense {t_e2e_dense * 1e3:.2f}ms vs gated "
            f"{t_e2e_gated * 1e3:.2f}ms = {speedup:.2f}x on the static scene"
        ),
    })
    if speedup < 2.0:
        msg = f"gated backend step only {speedup:.2f}x on the static scene"
        if os.environ.get("IP2_BENCH_RELAX"):
            print(f"WARNING: {msg}", file=sys.stderr)
        else:
            raise AssertionError(msg)
    return rows


_MULTISTREAM_CODE = """
    import json, time
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.core.frontend import FrontendConfig
    from repro.core.projection import PatchSpec
    from repro.data.pipeline import SceneStream
    from repro.launch.mesh import make_host_mesh
    from repro.models.vit import ViTConfig, init_vit
    from repro.serve.engine import SaccadeEngine
    from repro.serve.serve_step import make_bootstrap_indices, make_saccade_step

    # serving-rate operating point: small sensor, 1-layer backend — the
    # regime where per-stream dispatch overhead (what slot batching
    # removes) is visible against per-frame compute
    fcfg = FrontendConfig(image_h=32, image_w=32, aa_cutoff=None,
                          patch=PatchSpec(patch_h=8, patch_w=8, n_vectors=16),
                          active_fraction=0.25)
    cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    stream = SceneStream(image=32)
    n_dev = len(jax.devices())

    def best_of(f, n=15):
        best = float("inf")
        for _ in range(n):
            t0 = time.perf_counter()
            f()
            best = min(best, time.perf_counter() - t0)
        return best

    out = {"n_dev": n_dev}
    rgb, _ = stream.batch(0, 32)

    # sequential baseline: loop the single-stream step, batch 1, 8 streams
    boot = jax.jit(make_bootstrap_indices(cfg))
    step = jax.jit(make_saccade_step(cfg))
    idx = [boot(params, jnp.asarray(rgb[i:i + 1])) for i in range(8)]

    def seq_tick():
        for i in range(8):
            logits, idx[i], _ = step(params, jnp.asarray(rgb[i:i + 1]), idx[i])
            np.asarray(logits)          # stream's frame is done when it lands on host
    seq_tick()                          # compile
    out["seq_8"] = best_of(seq_tick)

    # batched engine at 1 / 8 / 32 streams, plus the shard_map'd slot axis
    # at 32 (on real accelerators sharding divides the work; on forced host
    # devices it measures the emulation's transfer overhead)
    mesh = make_host_mesh(data=n_dev, model=1)
    for n, m in ((1, None), (8, None), (32, None), (32, mesh)):
        eng = SaccadeEngine(cfg, params, capacity=n, mesh=m)
        for s in range(n):
            eng.admit(s)
        frames = {s: rgb[s] for s in range(n)}
        eng.step(frames)                # compile + bootstrap frame
        key = f"engine_{n}" + ("_sharded" if m is not None else "")
        out[key] = best_of(lambda: eng.step(frames))
        out[key + "_traces"] = eng.n_traces

    print(json.dumps(out))
"""


def multistream_sweep(n_devices: int = 4) -> list[dict]:
    """Engine vs sequential-loop serving on forced multi-device CPU."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={n_devices}"
    ).strip()
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(_MULTISTREAM_CODE)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(f"multistream subprocess failed: {proc.stderr[-3000:]}")
    r = json.loads(proc.stdout.strip().splitlines()[-1])

    rows = []
    for key, n in (("engine_1", 1), ("engine_8", 8), ("engine_32", 32),
                   ("engine_32_sharded", 32)):
        t = r[key]
        sharded = key.endswith("_sharded")
        rows.append({
            "name": f"multistream_{key.replace('engine_', 'engine_s')}",
            "us_per_call": t * 1e6,
            "derived": (
                f"{n / t:.0f} streams/s, {t * 1e3:.2f}ms/frame per-stream "
                f"latency, {r[key + '_traces']} compile(s)"
                + (f", slot axis shard_map'd over {r['n_dev']} host devices"
                   if sharded else "")
            ),
        })
    t_seq, t_eng = r["seq_8"], r["engine_8"]
    speedup = t_seq / t_eng
    rows.append({
        "name": "multistream_seq_loop_s8",
        "us_per_call": t_seq * 1e6,
        "derived": f"{8 / t_seq:.0f} streams/s looping the single-stream step",
    })
    rows.append({
        "name": "multistream_batched_speedup_s8",
        "us_per_call": t_eng * 1e6,
        "derived": f"{speedup:.2f}x streams/s, batched engine vs sequential loop",
    })
    traces = {k: v for k, v in r.items() if k.endswith("_traces")}
    if any(v != 1 for v in traces.values()):
        raise AssertionError(f"engine recompiled during steady-state serving: {traces}")
    if speedup < 4.0:
        msg = f"batched engine only {speedup:.2f}x vs sequential loop at 8 streams"
        if os.environ.get("IP2_BENCH_RELAX"):
            print(f"WARNING: {msg}", file=sys.stderr)
        else:
            raise AssertionError(msg)
    return rows


def run() -> list[dict]:
    t0 = time.perf_counter_ns()
    sweep = figure3_sweep()
    us = (time.perf_counter_ns() - t0) / 1e3
    rows = []
    for p in sweep:
        rows.append({
            "name": f"fig3_{p.fmt}_{p.n_vectors}vec_C{p.c_lines}",
            "us_per_call": us / len(sweep),
            "derived": f"{p.frame_hz:.1f}Hz {p.mpix_per_s:.0f}Mpix/s",
        })
    op = rate_point("1080p", 2, 32, 400)
    rows.append({
        "name": "fig3_operating_point_1080p_C2_400vec",
        "us_per_call": us, "derived": f"{op.frame_hz:.1f}Hz (paper ~90Hz)",
    })
    hz8 = frame_rate(8, 192, 2)
    rows.append({
        "name": "fig3_8x8_192vec", "us_per_call": us,
        "derived": f"{hz8:.0f}Hz (paper >30Hz)",
    })
    red = data_reduction(SensorConfig())
    red_rgb = data_reduction(SensorConfig(), vs_rgb=True)
    rows.append({"name": "data_reduction_vs_bayer", "us_per_call": us,
                 "derived": f"{red:.1f}x (paper 10x)"})
    rows.append({"name": "data_reduction_vs_rgb", "us_per_call": us,
                 "derived": f"{red_rgb:.1f}x (paper 30x)"})
    assert 85 <= op.frame_hz <= 95 and hz8 > 30 and red >= 10 and red_rgb >= 30
    # the sweeps are independent experiments: collect every row we can,
    # then fail loudly — one sweep's assert must not erase the others'
    # rows from the artifact (run.py keeps ``e.rows`` on failure)
    failures = []
    for sweep in (compact_sweep, motion_sweep, backend_delta_sweep,
                  multistream_sweep):
        try:
            rows.extend(sweep())
        except Exception as e:
            failures.append(f"{sweep.__name__}: {type(e).__name__}: {e}")
    if failures:
        err = AssertionError("; ".join(failures))
        err.rows = rows
        raise err
    return rows
