"""Paper §2.1.2 — switch-leakage simulation: 768 caps @1V + 768 @0V.

Reproduces: passive summer droops ~10% in under 10 µs at 65 nm; the OpAmp
feedback summer holds the 0.5 V result; 22 nm FDSOI needs no amplifier.
"""

import time

import jax.numpy as jnp

from repro.core.switched_cap import (
    SummerSpec,
    TAU_LEAK_22NM_FDX_S,
    TAU_LEAK_65NM_S,
    charge_share_sum,
    passive_droop_trace,
)


def run() -> list[dict]:
    v = jnp.concatenate([jnp.ones(768), jnp.zeros(768)])
    t0 = time.perf_counter_ns()
    passive_65 = float(charge_share_sum(v, SummerSpec(mode="passive")))
    opamp_65 = float(charge_share_sum(v, SummerSpec(mode="opamp")))
    passive_22 = float(
        charge_share_sum(v, SummerSpec(mode="passive", tau_leak_s=TAU_LEAK_22NM_FDX_S))
    )
    us = (time.perf_counter_ns() - t0) / 1e3

    trace = passive_droop_trace(jnp.array(0.5), jnp.linspace(0, 10e-6, 11))
    rows = [
        {"name": "leakage_passive_65nm_10us", "us_per_call": us,
         "derived": f"V={passive_65:.4f} (expect 0.45=10% droop of 0.5)"},
        {"name": "leakage_opamp_65nm_10us", "us_per_call": us,
         "derived": f"V={opamp_65:.4f} (expect ~0.5, gain error only)"},
        {"name": "leakage_passive_22nmFDX_10us", "us_per_call": us,
         "derived": f"V={passive_22:.4f} (low-leak node: amp removable)"},
        {"name": "leakage_droop_trace_t10us", "us_per_call": us,
         "derived": f"V(10us)={float(trace[-1]):.4f}"},
    ]
    assert abs(passive_65 - 0.45) < 1e-3
    assert abs(opamp_65 - 0.5) < 1e-3
    assert passive_22 > 0.499
    return rows
