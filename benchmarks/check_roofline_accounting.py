"""Bench-smoke guard: BENCH_throughput.json roofline rows must be priced
by the roofline extractor + analytic megakernel model (DESIGN.md §11) —
mirroring the §9 measured-bytes guard (check_bytes_accounting.py) and the
§10 power guard (check_power_accounting.py).

Three layers of defence:

1. Schema: every per-config sweep row carries a ``roofline`` record with
   ``source == "cost_point+megakernel_cost"`` and a full
   ``RooflineTerms.as_dict()`` under ``model`` (no hand-typed occupancy
   numbers can sneak into the artifact), the pick row names a candidate
   that exists, and the fused-vs-staged row is ``source == "measured-wall"``.
2. Claims: the fused-vs-staged speedup in the artifact is >= 1.5x and its
   stored walls reproduce the stored ratio; the ragged tier delta rows
   satisfy the FLOPs/bytes cuts the bench asserts (>= 3.5x / >= 2.0x).
3. Live re-derivation: ``megakernel_cost`` + ``RooflineTerms`` are re-run
   here at every block shape the artifact reports and compared field by
   field — if someone forks the analytic model away from what the sweep
   recorded (or edits the JSON by hand), this breaks loudly. The ragged
   tier delta is re-derived the same way.

Run after ``benchmarks/run.py`` (needs src and the repo root on the
path): ``PYTHONPATH=src:. python benchmarks/check_roofline_accounting.py``.
"""

import json
import sys

SWEEP_SOURCE = "cost_point+megakernel_cost"


def main(path: str = "BENCH_throughput.json") -> None:
    with open(path) as f:
        results = json.load(f)
    rf = next(v for k, v in results.items() if k.startswith("roofline"))
    rows = {r["name"]: r for r in rf if "name" in r}

    sweep = {n: r for n, r in rows.items()
             if n.startswith("roofline_megakernel_")}
    assert sweep, "no roofline_megakernel_* sweep rows in the artifact"

    # --- layer 1: schema ---------------------------------------------------
    for name, row in sweep.items():
        rec = row.get("roofline")
        assert isinstance(rec, dict), f"{name}: no roofline record"
        assert rec.get("source") == SWEEP_SOURCE, (
            f"{name}: not priced by the extractor+model "
            f"(source={rec.get('source')!r})"
        )
        for key in ("block", "xla", "model"):
            assert key in rec, f"{name}: roofline record missing {key!r}"
        assert "mxu_occupancy" in rec["model"], (
            f"{name}: model record has no mxu_occupancy"
        )

    pick = rows["roofline_block_pick"]["roofline"]
    picked = f"roofline_megakernel_r{pick['block'][0]}" \
             f"_m{pick['block'][1]}_k{pick['block'][2]}"
    assert picked in sweep, f"pick {picked} names no sweep row"
    best_occ = max(r["roofline"]["model"]["mxu_occupancy"]
                   for r in sweep.values())
    assert sweep[picked]["roofline"]["model"]["mxu_occupancy"] == best_occ, (
        f"pick {picked} is not the max-occupancy candidate"
    )

    vs = rows["roofline_fused_vs_staged_af0.25"]["roofline"]
    assert vs.get("source") == "measured-wall"

    # --- layer 2: claims ---------------------------------------------------
    ratio = vs["t_staged_us"] / vs["t_fused_us"]
    assert abs(ratio - vs["speedup"]) < 1e-9, (
        f"stored speedup {vs['speedup']} != stored walls ratio {ratio}"
    )
    assert vs["speedup"] >= 1.5, (
        f"artifact fused-vs-staged speedup {vs['speedup']:.2f}x < 1.5x"
    )

    tier_name = next(n for n in rows if n.startswith("roofline_ragged_tier"))
    tier = rows[tier_name]["roofline"]
    assert tier["source"] == "megakernel_cost"
    flops_ratio = tier["flops_full"] / tier["flops_tier"]
    bytes_ratio = tier["bytes_full"] / tier["bytes_tier"]
    assert flops_ratio >= 3.5, f"ragged FLOPs cut only {flops_ratio:.2f}x"
    assert bytes_ratio >= 2.0, f"ragged bytes cut only {bytes_ratio:.2f}x"

    # --- layer 3: live re-derivation --------------------------------------
    from benchmarks.bench_roofline import TIER_FRACTION, _operating_point
    from repro.roofline.analysis import RooflineTerms, megakernel_cost

    cfg, _, _, _, _, _, k, d = _operating_point()
    n2, m = cfg.patch.pixels_per_patch, cfg.patch.n_vectors
    batch = 4
    for name, row in sweep.items():
        br, bm, bk = row["roofline"]["block"]
        model = megakernel_cost([k] * batch, k, n2, m, d=d,
                                block_r=br, block_m=bm, block_k=bk)
        live = RooflineTerms(
            flops_per_chip=model["flops"], bytes_per_chip=model["bytes"],
            coll_bytes_per_chip=0.0).as_dict()
        art = row["roofline"]["model"]
        for key, val in live.items():
            got = art.get(key)
            ok = (got == val) if isinstance(val, str) \
                else abs(got - val) < 1e-9 * max(1.0, abs(val))
            assert ok, (
                f"{name}.{key}: artifact {got!r} != live model {val!r} — "
                f"the analytic roofline model drifted from the artifact"
            )

    br, bm, bk = tier["block"]
    k_eff = max(1, int(round(k * TIER_FRACTION)))
    c_full = megakernel_cost([k] * batch, k, n2, m, d=d,
                             block_r=br, block_m=bm, block_k=bk)
    c_tier = megakernel_cost([k_eff] * batch, k, n2, m, d=d,
                             block_r=br, block_m=bm, block_k=bk)
    for key, have in (("flops_full", c_full["flops"]),
                      ("flops_tier", c_tier["flops"]),
                      ("bytes_full", c_full["bytes"]),
                      ("bytes_tier", c_tier["bytes"])):
        assert abs(tier[key] - have) < 1e-9 * max(1.0, abs(have)), (
            f"ragged delta {key}: artifact {tier[key]} != live {have}"
        )

    print(f"roofline accounting OK: {len(sweep)} modeled sweep rows, pick "
          f"{picked} (occ {best_occ:.3f}) live == artifact, fused vs staged "
          f"{vs['speedup']:.2f}x >= 1.5x, ragged tier cut "
          f"{flops_ratio:.2f}x FLOPs / {bytes_ratio:.2f}x bytes")


if __name__ == "__main__":
    main(*sys.argv[1:])
