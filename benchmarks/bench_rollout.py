"""Dispatch-overhead bench: device-resident rollouts vs per-tick steps
(DESIGN.md §15).

The per-tick host round-trip — python staging, H2D upload, dispatch,
blocking D2H fetch — bounds the fleet bench long before device compute
does. ``SaccadeEngine.step_rollout`` folds T ticks into ONE ``lax.scan``
dispatch; this bench sweeps T ∈ {1, 4, 16, 64} at the fleet-bench
operating point (32×32 sensor, 8×8 patches, 32 governed temporal
streams) and meters, from raw per-repeat samples:

* the LOOPED baseline: T sequential blocking ``step()`` calls,
  per-tick wall;
* the ROLLOUT path, split into host dispatch (staging + upload +
  launch; the rollout's entire host-side cost) and blocking fetch
  (device compute + D2H of the (T, S, C) logits), whose sum is the
  rollout wall. Per-tick wall = sum / T.

Methodology notes, mirrored by ``check_rollout_accounting.py``:

* Raw samples ship in the artifact row; the guard re-derives every
  stored per-tick median and speedup from them instead of trusting the
  stored numbers, and re-checks the bitwise-parity claim LIVE on a
  fresh engine pair.
* The acceptance floor — rollout ≥ 2× faster per tick than the looped
  step at T=16 — is asserted here (soft, ``IP2_BENCH_RELAX`` relaxes it
  on noisy shared runners; the artifact records whether it was relaxed).
* Trace discipline is a hard contract, never relaxed: ONE engine step
  trace and one rollout trace per distinct T across the whole sweep.
* Bitwise parity is re-checked in-bench on a twin engine pair (T=4,
  governed temporal mode): rollout logits and final state must equal T
  sequential steps exactly — the speedup is only meaningful if the two
  paths compute the same thing.

Runs in a subprocess (CPU-pinned, like the fleet bench) so results are
comparable with the fleet row's operating point.
"""

import json
import os
import subprocess
import sys
import textwrap
import time

# operating point shared with bench_fleet.py and the accounting guard
IMAGE = 32
PATCH = 8
N_VECTORS = 16
ACTIVE_FRACTION = 0.25
CAPACITY = 32                   # one fleet host's worth of streams
FRAME_HZ = 30.0
BUDGET_MW = 50.0
T_SWEEP = (1, 4, 16, 64)
REPEATS = 7
PARITY_T = 4
SPEEDUP_T = 16                  # the acceptance-floor sweep point
SPEEDUP_FLOOR = 2.0

_ROLLOUT_CODE = """
    import json, time
    import numpy as np
    import jax
    from repro.core.frontend import FrontendConfig
    from repro.core.projection import PatchSpec
    from repro.core.temporal import TemporalSpec
    from repro.data.pipeline import SceneStream
    from repro.models.vit import ViTConfig, init_vit
    from repro.serve.engine import SaccadeEngine
    from repro.serve.governor import GovernorSpec

    CAP = %(cap)d
    T_SWEEP = %(t_sweep)s
    REPEATS = %(repeats)d
    PARITY_T = %(parity_t)d

    fcfg = FrontendConfig(image_h=%(image)d, image_w=%(image)d,
                          aa_cutoff=None,
                          patch=PatchSpec(patch_h=%(patch)d,
                                          patch_w=%(patch)d,
                                          n_vectors=%(n_vectors)d),
                          active_fraction=%(active_fraction)f,
                          temporal=TemporalSpec(delta_threshold=1e-4))
    cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2,
                    d_ff=64)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    pool = np.asarray(SceneStream(image=%(image)d).batch(0, 64)[0])

    def build():
        eng = SaccadeEngine(cfg, params, capacity=CAP, temporal=True,
                            frame_hz=%(frame_hz)f,
                            governor=GovernorSpec(budget_mw=%(budget_mw)f))
        for i in range(CAP):
            eng.admit(f"s{i}")
        return eng

    eng = build()
    sids = eng.stream_ids

    def frames_at(t):
        return {s: pool[(i + t) %% len(pool)] for i, s in enumerate(sids)}

    # warm-up: compile the step once and the rollout once per distinct T,
    # then absorb the first post-compile executions
    for t in range(3):
        eng.step(frames_at(t))
    for T in T_SWEEP:
        eng.step_rollout([frames_at(t) for t in range(T)])

    loop_ms = {T: [] for T in T_SWEEP}       # total wall of T looped steps
    dispatch_ms = {T: [] for T in T_SWEEP}   # rollout host-side dispatch
    fetch_ms = {T: [] for T in T_SWEEP}      # rollout blocking fetch
    for rep in range(REPEATS):
        for T in T_SWEEP:
            sched = [frames_at(rep + t) for t in range(T)]
            t0 = time.perf_counter()
            for fr in sched:
                eng.step(fr)
            t1 = time.perf_counter()
            loop_ms[T].append((t1 - t0) * 1e3)
            t0 = time.perf_counter()
            h = eng.step_rollout(sched, block=False)
            t1 = time.perf_counter()
            h.result()
            t2 = time.perf_counter()
            dispatch_ms[T].append((t1 - t0) * 1e3)
            fetch_ms[T].append((t2 - t1) * 1e3)

    # in-bench bitwise parity on a fresh twin pair: the two timed paths
    # must compute the SAME thing (logits + full carried state)
    e_seq, e_roll = build(), build()
    sched = [frames_at(100 + t) for t in range(PARITY_T)]
    seq = [e_seq.step(fr) for fr in sched]
    roll = e_roll.step_rollout(sched)
    parity = True
    for t in range(PARITY_T):
        for sid in seq[t]:
            parity &= bool(np.array_equal(seq[t][sid], roll[t][sid]))
    for a, b in zip(jax.tree.leaves(e_seq.state), jax.tree.leaves(e_roll.state)):
        parity &= bool(np.array_equal(np.asarray(a), np.asarray(b)))

    print(json.dumps({
        "n_dev": len(jax.devices()),
        "loop_ms": loop_ms,
        "dispatch_ms": dispatch_ms,
        "fetch_ms": fetch_ms,
        "n_traces": eng.n_traces,
        "n_rollout_traces": eng.n_rollout_traces,
        "parity_bitwise": parity,
        "parity_T": PARITY_T,
    }))
"""


def _relaxed() -> bool:
    return bool(os.environ.get("IP2_BENCH_RELAX"))


def dispatch_sweep() -> list[dict]:
    """Run the T-sweep on a CPU-pinned subprocess and derive speedups."""
    env = dict(os.environ)
    env["JAX_PLATFORMS"] = "cpu"
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
    code = _ROLLOUT_CODE % {
        "cap": CAPACITY, "t_sweep": repr(list(T_SWEEP)),
        "repeats": REPEATS, "parity_t": PARITY_T, "image": IMAGE,
        "patch": PATCH, "n_vectors": N_VECTORS,
        "active_fraction": ACTIVE_FRACTION, "frame_hz": FRAME_HZ,
        "budget_mw": BUDGET_MW,
    }
    proc = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=900,
    )
    if proc.returncode != 0:
        raise RuntimeError(
            f"rollout subprocess failed: {proc.stderr[-3000:]}")
    r = json.loads(proc.stdout.strip().splitlines()[-1])

    import numpy as np

    # hard contracts (data properties, never relaxed)
    assert r["parity_bitwise"], (
        "rollout is NOT bitwise the looped step — the timed paths "
        "diverged, the speedup is meaningless")
    assert r["n_traces"] == 1, (
        f"engine step retraced during the sweep: n_traces={r['n_traces']}")
    assert r["n_rollout_traces"] == len(T_SWEEP), (
        f"expected one rollout trace per distinct T "
        f"({len(T_SWEEP)}), got {r['n_rollout_traces']}")

    per_t = {}
    for T in T_SWEEP:
        loop = np.asarray(r["loop_ms"][str(T)], np.float64)
        disp = np.asarray(r["dispatch_ms"][str(T)], np.float64)
        fetch = np.asarray(r["fetch_ms"][str(T)], np.float64)
        loop_tick = float(np.median(loop)) / T
        roll_tick = float(np.median(disp + fetch)) / T
        per_t[T] = {
            "loop_ms_samples": list(map(float, loop)),
            "dispatch_ms_samples": list(map(float, disp)),
            "fetch_ms_samples": list(map(float, fetch)),
            "loop_tick_ms": loop_tick,
            "rollout_tick_ms": roll_tick,
            "dispatch_tick_ms": float(np.median(disp)) / T,
            "fetch_tick_ms": float(np.median(fetch)) / T,
            "speedup": loop_tick / roll_tick,
        }

    speedup16 = per_t[SPEEDUP_T]["speedup"]
    if speedup16 < SPEEDUP_FLOOR and not _relaxed():
        raise AssertionError(
            f"rollout speedup at T={SPEEDUP_T} is {speedup16:.2f}x < "
            f"{SPEEDUP_FLOOR:g}x (set IP2_BENCH_RELAX=1 on noisy runners)")

    rec = {
        "source": "perf_counter",
        "capacity": CAPACITY, "t_sweep": list(T_SWEEP),
        "repeats": REPEATS, "frame_hz": FRAME_HZ,
        "speedup_t": SPEEDUP_T, "speedup_floor": SPEEDUP_FLOOR,
        "relaxed": _relaxed(),
        "per_t": {str(T): per_t[T] for T in T_SWEEP},
        "n_traces": r["n_traces"],
        "n_rollout_traces": r["n_rollout_traces"],
        "parity_bitwise": r["parity_bitwise"],
        "parity_T": r["parity_T"],
    }
    rows = [{
        "name": f"rollout_dispatch_s{CAPACITY}"
                f"_T{'x'.join(str(t) for t in T_SWEEP)}",
        "us_per_call": per_t[SPEEDUP_T]["rollout_tick_ms"] * 1e3,
        "rollout": rec,
        "derived": (
            f"{CAPACITY} governed temporal streams; per-tick "
            + ", ".join(
                f"T={T}: {per_t[T]['loop_tick_ms']:.2f}->"
                f"{per_t[T]['rollout_tick_ms']:.2f}ms "
                f"({per_t[T]['speedup']:.2f}x)"
                for T in T_SWEEP)
            + f"; dispatch/fetch at T={SPEEDUP_T}: "
              f"{per_t[SPEEDUP_T]['dispatch_tick_ms']:.2f}/"
              f"{per_t[SPEEDUP_T]['fetch_tick_ms']:.2f} ms/tick, "
              f"parity bitwise at T={r['parity_T']}, traces "
              f"1+{r['n_rollout_traces']}"
        ),
    }]
    return rows


def run() -> list[dict]:
    t0 = time.perf_counter()
    rows = dispatch_sweep()
    dt = time.perf_counter() - t0
    rows.append({
        "name": "rollout_bench_wall",
        "us_per_call": dt * 1e6,
        "derived": f"dispatch-overhead sweep wall {dt:.1f}s",
    })
    return rows
