"""Roofline-guided megakernel block-shape sweep + fused-vs-staged timing
(DESIGN.md §11).

Wires the roofline extractor (``repro.roofline.analysis``) into the bench
artifact: every (block_r, block_m, block_k) candidate for the fused
frontend megakernel gets a per-config row with

* XLA's static ``cost_point`` of the compiled entry (flops / bytes as the
  compiler prices them — on the CPU sim this prices the interpret-mode
  lowering, reported for trend tracking, never asserted), and
* the analytic ``megakernel_cost`` model fed through ``RooflineTerms``
  (TPU v5e constants): MXU occupancy (t_compute / t_bound) and the
  roofline bottleneck per config. The analytic model is the one that sees
  runtime raggedness — XLA's static analysis prices every grid step, so
  ``pl.when``-skipped banks and pipeliner-elided DMAs are invisible to it.

The sweep picks the occupancy-maximizing block shape (wall time breaks
ties on the sim), and the fused megakernel at that shape is timed against
the staged ``ip2_project_sparse(codes=True) -> quant_matmul_pre`` seam at
the standard 25 % operating point (same selection; outputs asserted
bitwise-equal first). The ragged-k claim — a governed stream at tier
k_eff < k does proportionally less kernel work — is asserted on the
analytic flops/bytes delta, which is a data property of the kernel's
gating, not a wall-clock measurement, and therefore always hard.
"""

import os
import sys

from benchmarks.bench_throughput import _best_of, compact_operating_point

# the candidate grid: sublane-aligned row banks and vector banks from one
# MXU tile (128) up to m_steps=1 (512 covers the padded M at the operating
# point — every extra m step re-gathers all patch-row blocks). block_r is
# capped at the FINEST governor tier's k_eff (0.25 * k = 16 here): a row
# bank wider than the smallest tier would compute waste rows when the
# governor sheds, defeating ragged-k's zero-FLOP contract.
BLOCK_CANDIDATES = (
    (8, 128, 256),
    (8, 256, 256),
    (8, 512, 256),
    (16, 128, 256),
    (16, 512, 256),
)

TIER_FRACTION = 0.25     # the governor tier exercised by the ragged delta


def _operating_point(batch: int = 4, d_model: int = 128):
    """The §11 bench operating point: the shared 25 % compact config, its
    DAC-programmed weights, int8 embed weights, and an energy-ranked
    selection — everything both the staged and fused paths consume."""
    import jax
    import jax.numpy as jnp

    import repro.core as c
    from repro.core.frontend import init_frontend_params
    from repro.kernels import ops

    cfg = compact_operating_point()
    params = init_frontend_params(jax.random.PRNGKey(0), cfg)
    rgb = jax.random.uniform(
        jax.random.PRNGKey(1), (batch, cfg.image_h, cfg.image_w, 3))
    patches, weights = c.sensor_patches(params, rgb, cfg)
    k = cfg.n_active
    idx = c.topk_patch_indices(c.patch_energy(patches), k)
    programmed = ops.program_weights(weights, cfg.patch)

    embed = jax.random.normal(
        jax.random.PRNGKey(2),
        (cfg.patch.n_vectors, d_model), jnp.float32) * 0.05
    w8, s_w = ops.quantize_weights_int8(embed)
    return cfg, patches, programmed, idx, w8, s_w, k, d_model


def sweep_blocks() -> list[dict]:
    """Per-candidate roofline rows + the fused-vs-staged operating-point
    timing at the occupancy-picked shape."""
    import jax
    import numpy as np

    from repro.kernels import ops
    from repro.roofline.analysis import RooflineTerms, cost_point, megakernel_cost

    cfg, patches, programmed, idx, w8, s_w, k, d = _operating_point()
    spec, adc = cfg.patch, cfg.adc
    n2, m = spec.pixels_per_patch, spec.n_vectors
    batch = patches.shape[0]
    full = [k] * batch

    rows = []
    best = None            # (occupancy, -wall, name, blocks)
    for br, bm, bk in BLOCK_CANDIDATES:
        def fused_fn(pp, ii, _br=br, _bm=bm, _bk=bk):
            return ops.ip2_fused_embed(
                pp, programmed, ii, spec, adc, w8, s_w,
                block_r=_br, block_m=_bm, block_k=_bk)

        jitted = jax.jit(fused_fn)
        compiled = jitted.lower(patches, idx).compile()
        xla = cost_point(compiled)
        model = megakernel_cost(full, k, n2, m, d=d,
                                block_r=br, block_m=bm, block_k=bk)
        terms = RooflineTerms(
            flops_per_chip=model["flops"], bytes_per_chip=model["bytes"],
            coll_bytes_per_chip=0.0)
        wall = _best_of(jitted, patches, idx)
        occ = terms.mxu_occupancy
        name = f"roofline_megakernel_r{br}_m{bm}_k{bk}"
        rows.append({
            "name": name,
            "us_per_call": wall * 1e6,
            "roofline": {
                "source": "cost_point+megakernel_cost",
                "block": [br, bm, bk],
                "xla": {kk: xla[kk] for kk in ("flops", "bytes", "coll_bytes")},
                "model": terms.as_dict(),
            },
            "derived": (
                f"occ {occ:.3f} {terms.bottleneck}-bound "
                f"(model {model['flops'] / 1e6:.1f}MFLOP "
                f"{model['bytes'] / 1e6:.2f}MB) wall {wall * 1e3:.2f}ms"
            ),
        })
        key = (occ, -wall)
        if best is None or key > best[0]:
            best = (key, name, (br, bm, bk))

    (_, _), pick_name, (br, bm, bk) = best
    rows.append({
        "name": "roofline_block_pick",
        "us_per_call": 0.0,
        "roofline": {"source": "cost_point+megakernel_cost",
                     "block": [br, bm, bk]},
        "derived": f"picked {pick_name} (max MXU occupancy, wall tiebreak)",
    })

    # --- fused vs staged at the 25 % operating point, roofline-picked shape
    import jax.numpy as jnp
    lsb = jnp.float32(adc.lsb)

    def staged_fn(pp, ii):
        codes = ops.ip2_project_sparse(
            pp, programmed, ii, spec, adc=adc, codes=True)
        return ops.quant_matmul_pre(codes, lsb, w8, s_w)

    def fused_pick(pp, ii):
        return ops.ip2_fused_embed(
            pp, programmed, ii, spec, adc, w8, s_w,
            block_r=br, block_m=bm, block_k=bk)

    staged = jax.jit(staged_fn)
    fused = jax.jit(fused_pick)
    # parity first (the ISSUE's correctness gate): identical selection,
    # bitwise-identical output — always hard, never relaxed
    np.testing.assert_array_equal(
        np.asarray(staged(patches, idx)), np.asarray(fused(patches, idx)))

    t_staged = _best_of(staged, patches, idx)
    t_fused = _best_of(fused, patches, idx)
    speedup = t_staged / t_fused
    rows.append({
        "name": "roofline_fused_vs_staged_af0.25",
        "us_per_call": t_fused * 1e6,
        "roofline": {
            "source": "measured-wall",
            "block": [br, bm, bk],
            "t_staged_us": t_staged * 1e6,
            "t_fused_us": t_fused * 1e6,
            "speedup": speedup,
        },
        "derived": (
            f"staged (shipped defaults) {t_staged * 1e3:.2f}ms vs fused "
            f"(picked r{br}_m{bm}_k{bk}) {t_fused * 1e3:.2f}ms "
            f"= {speedup:.2f}x (bitwise-equal outputs, k={k})"
        ),
    })
    if speedup < 1.5:
        msg = (f"fused megakernel only {speedup:.2f}x vs staged seam "
               f"at the 25% operating point")
        if os.environ.get("IP2_BENCH_RELAX"):
            print(f"WARNING: {msg}", file=sys.stderr)
        else:
            raise AssertionError(msg)

    # --- ragged delta: tier k_eff = 0.25k does proportionally less kernel
    # work. A data property of the bank gating (analytic model), not a
    # wall-clock claim — asserted hard even under IP2_BENCH_RELAX.
    k_eff = max(1, int(round(k * TIER_FRACTION)))
    tier = [k_eff] * batch
    c_full = megakernel_cost(full, k, n2, m, d=d,
                             block_r=br, block_m=bm, block_k=bk)
    c_tier = megakernel_cost(tier, k, n2, m, d=d,
                             block_r=br, block_m=bm, block_k=bk)
    flops_ratio = c_full["flops"] / c_tier["flops"]
    bytes_ratio = c_full["bytes"] / c_tier["bytes"]
    rows.append({
        "name": f"roofline_ragged_tier{TIER_FRACTION:g}_delta",
        "us_per_call": 0.0,
        "roofline": {
            "source": "megakernel_cost",
            "block": [br, bm, bk],
            "flops_full": c_full["flops"], "flops_tier": c_tier["flops"],
            "bytes_full": c_full["bytes"], "bytes_tier": c_tier["bytes"],
            "active_banks_full": c_full["detail"]["active_banks"],
            "active_banks_tier": c_tier["detail"]["active_banks"],
        },
        "derived": (
            f"k_eff={k_eff}/{k}: {flops_ratio:.2f}x fewer FLOPs, "
            f"{bytes_ratio:.2f}x fewer bytes "
            f"({c_tier['detail']['active_banks']}/"
            f"{c_full['detail']['active_banks']} active banks)"
        ),
    })
    assert flops_ratio >= 3.5, (
        f"ragged tier {TIER_FRACTION} only cut FLOPs {flops_ratio:.2f}x")
    assert bytes_ratio >= 2.0, (
        f"ragged tier {TIER_FRACTION} only cut bytes {bytes_ratio:.2f}x")
    return rows


def run() -> list[dict]:
    return sweep_blocks()
