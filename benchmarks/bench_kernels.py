"""Kernel micro-bench: ip2_project Pallas kernel (interpret mode on CPU —
wall time is NOT TPU-representative; the derived column reports the
arithmetic the kernel performs per call, which feeds the §Roofline VMEM
working-set check) + the jnp reference for the same op."""

import time

import jax
import jax.numpy as jnp

from repro.core import projection as proj
from repro.kernels import ops


def _time(f, *args, n=3):
    f(*args).block_until_ready()
    t0 = time.perf_counter_ns()
    for _ in range(n):
        out = f(*args)
    out.block_until_ready()
    return (time.perf_counter_ns() - t0) / 1e3 / n


def run() -> list[dict]:
    rows = []
    for patch, n_vec, n_patches in [(32, 400, 64), (8, 192, 256)]:
        n2 = patch * patch
        spec = proj.PatchSpec(patch_h=patch, patch_w=patch, n_vectors=n_vec)
        patches = jax.random.uniform(jax.random.PRNGKey(0), (n_patches, n2))
        w = jax.random.normal(jax.random.PRNGKey(1), (n_vec, n2))
        flops = 2 * n_patches * n2 * n_vec
        vmem_kib = (128 * 256 + 256 * 128 + 128 * 128 * 2) * 4 / 1024

        us_k = _time(
            lambda p, ww: ops.ip2_project(p, ww, spec, interpret=True), patches, w
        )
        us_r = _time(
            jax.jit(lambda p, ww: proj.analog_project_patches(p, ww, spec)), patches, w
        )
        rows.append({
            "name": f"ip2_project_pallas_{patch}x{patch}_{n_vec}v_{n_patches}p",
            "us_per_call": us_k,
            "derived": f"{flops / 1e6:.0f}MFLOP vmem~{vmem_kib:.0f}KiB/tile (interpret)",
        })
        rows.append({
            "name": f"ip2_project_jnpref_{patch}x{patch}_{n_vec}v_{n_patches}p",
            "us_per_call": us_r,
            "derived": f"{flops / 1e6:.0f}MFLOP",
        })
    return rows
