"""Bench-smoke guard: the BENCH_throughput.json fleet row must be a real
sustained-load measurement (DESIGN.md §12) — mirroring the §10 power
guard (check_power_accounting.py) and §11 roofline guard
(check_roofline_accounting.py).

Three layers of defence:

1. Schema: the sustained-load row carries a ``fleet`` record with
   ``source == "perf_counter+EnergyMeter"``, raw per-tick latency
   samples, per-engine compile counts, the churn ledger and the summed
   mean event counts — no hand-typed percentiles or milliwatts can sneak
   into the artifact. The acceptance shape is pinned: peak streams >= 64
   over >= 2 hosts, >= 2 distinct frame periods, one compile per engine,
   and admit/evict churn coalesced into fewer flushes than churn ops.
2. Claims: the stored p50/p99 reproduce from the stored samples — for
   the total serve latency AND the async dispatch/fetch split series
   (DESIGN.md §15), whose per-tick sum must equal the total serve
   sample exactly — the stored streams/s reproduces from
   served_frames / wall_s, and the sample counts match the tick count.
3. Live re-derivation: the stored summed mean event counts are re-priced
   here with a fresh :class:`EnergyMeter` — pricing is linear in the
   counts, so the re-priced total must land on the stored fleet mW. The
   per-frame event laws of DESIGN.md §10 are re-checked against the
   bench's operating point: dac_loads, cds_samples and pixel_dumps are
   per-frame constants per served slot, so each summed mean must be an
   identical integer multiple (the number of metered slots) of its
   per-frame constant.

Run after ``benchmarks/run.py`` (needs src and the repo root on the
path): ``PYTHONPATH=src:. python benchmarks/check_fleet_accounting.py``.
"""

import json
import sys

FLEET_SOURCE = "perf_counter+EnergyMeter"


def main(path: str = "BENCH_throughput.json") -> None:
    import numpy as np

    with open(path) as f:
        results = json.load(f)
    ff = next(v for k, v in results.items() if k.startswith("fleet"))
    rows = {r["name"]: r for r in ff if "name" in r}

    name = next(n for n in rows if n.startswith("fleet_sustained_"))
    rec = rows[name].get("fleet")

    # --- layer 1: schema ---------------------------------------------------
    assert isinstance(rec, dict), f"{name}: no fleet record"
    assert rec.get("source") == FLEET_SOURCE, (
        f"{name}: not a measured row (source={rec.get('source')!r})")
    for key in ("latency_ms_samples", "p50_ms", "p99_ms", "served_frames",
                "wall_s", "streams_per_s", "peak_streams", "churn_ops",
                "flushes", "n_traces", "fleet_mw_mean", "events_mean_sum",
                "ticks", "periods", "frame_hz", "n_hosts",
                # async split (DESIGN.md §15): raw dispatch/fetch samples
                # plus their stored percentiles
                "dispatch_ms_samples", "fetch_ms_samples",
                "dispatch_p50_ms", "dispatch_p99_ms",
                "fetch_p50_ms", "fetch_p99_ms"):
        assert key in rec, f"{name}: fleet record missing {key!r}"
    assert rec["peak_streams"] >= 64, (
        f"sustained load peaked at {rec['peak_streams']} streams < 64")
    assert rec["n_hosts"] >= 2, f"fleet ran on {rec['n_hosts']} host(s)"
    assert len(set(rec["periods"])) >= 2, (
        f"frame rates not mixed: periods {rec['periods']}")
    assert all(n == 1 for n in rec["n_traces"]), (
        f"engines recompiled under churn: n_traces={rec['n_traces']}")
    assert 0 < sum(rec["flushes"]) < rec["churn_ops"], (
        f"churn not coalesced: {rec['churn_ops']} admit/evict ops "
        f"-> {sum(rec['flushes'])} flushes")

    # --- layer 2: claims ---------------------------------------------------
    samples = np.asarray(rec["latency_ms_samples"], dtype=np.float64)
    assert samples.size == rec["ticks"], (
        f"{samples.size} latency samples for {rec['ticks']} ticks")
    for q, key in ((50, "p50_ms"), (99, "p99_ms")):
        have = float(np.percentile(samples, q))
        assert abs(have - rec[key]) < 1e-9 * max(1.0, have), (
            f"stored {key} {rec[key]} != samples percentile {have}")
    # async split: each series' stored percentiles reproduce from ITS
    # raw samples, and dispatch + fetch sums to the total serve sample
    # tick by tick (the bench computes the total as the sum, so the
    # identity is exact)
    disp = np.asarray(rec["dispatch_ms_samples"], dtype=np.float64)
    fetch = np.asarray(rec["fetch_ms_samples"], dtype=np.float64)
    assert disp.size == fetch.size == samples.size, (
        f"sample series disagree: {disp.size}/{fetch.size}/{samples.size}")
    np.testing.assert_allclose(
        disp + fetch, samples, rtol=0, atol=1e-9,
        err_msg="dispatch + fetch samples do not sum to the serve samples")
    for series, prefix in ((disp, "dispatch"), (fetch, "fetch")):
        for q in (50, 99):
            key = f"{prefix}_p{q}_ms"
            have = float(np.percentile(series, q))
            assert abs(have - rec[key]) < 1e-9 * max(1.0, have), (
                f"stored {key} {rec[key]} != samples percentile {have}")
    sps = rec["served_frames"] / rec["wall_s"]
    assert abs(sps - rec["streams_per_s"]) < 1e-9 * max(1.0, sps), (
        f"stored streams/s {rec['streams_per_s']} != "
        f"served/wall {sps}")

    # --- layer 3: live re-derivation --------------------------------------
    from benchmarks.bench_fleet import (
        ACTIVE_FRACTION, IMAGE, N_VECTORS, PATCH)
    from repro.core.power import EnergyMeter, EventCounts

    ev = EventCounts(**rec["events_mean_sum"])
    live_mw = float(EnergyMeter().power_mw(ev, rec["frame_hz"]))
    assert abs(live_mw - rec["fleet_mw_mean"]) < 1e-5 * max(1.0, live_mw), (
        f"re-priced fleet mW {live_mw} != artifact {rec['fleet_mw_mean']} — "
        f"the EnergyMeter drifted from what the bench recorded")

    # per-frame event laws at the bench operating point (DESIGN.md §10):
    # the per-frame constants divide their summed means exactly, and all
    # three agree on how many slots were metered
    n_pixels = float(IMAGE * IMAGE)
    n2 = float(PATCH * PATCH)
    n_sel = (n_pixels / n2) * ACTIVE_FRACTION
    per_frame = {
        "dac_loads": N_VECTORS * n2,
        "cds_samples": 2.0 * n_pixels,
        "pixel_dumps": n_pixels - n_sel * n2,
    }
    slot_counts = set()
    for field, const in per_frame.items():
        n_slots = rec["events_mean_sum"][field] / const
        assert abs(n_slots - round(n_slots)) < 1e-6, (
            f"{field} sum {rec['events_mean_sum'][field]} is not a whole "
            f"multiple of the per-frame constant {const}")
        slot_counts.add(round(n_slots))
    assert len(slot_counts) == 1, (
        f"per-frame event laws disagree on the metered slot count: "
        f"{sorted(slot_counts)}")
    n_metered = slot_counts.pop()
    assert 0 < n_metered <= rec["peak_streams"], n_metered

    print(f"fleet accounting OK: {rec['peak_streams']} streams / "
          f"{rec['n_hosts']} hosts, p50/p99 reproduce from "
          f"{samples.size} samples ({rec['p50_ms']:.2f}/"
          f"{rec['p99_ms']:.2f} ms), {rec['churn_ops']} churn ops -> "
          f"{sum(rec['flushes'])} flushes, traces {rec['n_traces']}, "
          f"re-priced {live_mw:.3f} mW == artifact, event laws hold over "
          f"{n_metered} metered slots")


if __name__ == "__main__":
    main(*sys.argv[1:])
