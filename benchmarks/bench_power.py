"""Paper Table 1 + §2.1.3 — in-pixel area budget and front-end power.

Reproduces: 485 µm² -> 22 µm pitch at 65 nm; < 60 mW for 2 Mpix @ 30 Hz;
< 30 mW/Mpix including ADC+DAC; ADC conversion is the majority consumer;
25 % active patches assumed.
"""

import time

from repro.core.power import AreaBudget, EnergyConstants, SensorConfig, power_report


def run() -> list[dict]:
    t0 = time.perf_counter_ns()
    area = AreaBudget().totals()
    rep = power_report(SensorConfig())
    rep_1mpix = power_report(SensorConfig(n_pixels=1e6))
    us = (time.perf_counter_ns() - t0) / 1e3

    share = {k: v / rep["total"] for k, v in rep.items()
             if isinstance(v, float) and k not in ("total", "mw_per_mpix")}
    top = max(share, key=share.get)
    rows = [
        {"name": "table1_pitch_um", "us_per_call": us,
         "derived": f"{area['Total']['pitch_um']:.1f} (paper: 22.0)"},
        {"name": "table1_total_um2", "us_per_call": us,
         "derived": f"{area['Total']['total_um2']:.0f} (paper: 485)"},
        {"name": "power_2mpix_30hz_mw", "us_per_call": us,
         "derived": f"{rep['total'] * 1e3:.1f} (<60 claim)"},
        {"name": "power_mw_per_mpix", "us_per_call": us,
         "derived": f"{rep['mw_per_mpix']:.1f} (<30 claim)"},
        {"name": "power_dominant_component", "us_per_call": us,
         "derived": f"{top} {share[top] * 100:.0f}% (paper: ADC majority)"},
        {"name": "power_1mpix_mw", "us_per_call": us,
         "derived": f"{rep_1mpix['total'] * 1e3:.1f}"},
    ]
    assert area["Total"]["total_um2"] == 485.0
    assert rep["total"] < 0.060 and rep["mw_per_mpix"] < 30.0
    assert top == "adc"
    return rows
