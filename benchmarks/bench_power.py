"""Paper Table 1 + §2.1.3 — in-pixel area budget and front-end power,
now event-metered end to end (DESIGN.md §10).

Reproduces: 485 µm² -> 22 µm pitch at 65 nm; < 60 mW for 2 Mpix @ 30 Hz;
< 30 mW/Mpix including ADC+DAC (asserted at BOTH the 2 Mpix and 1 Mpix
operating points); ADC conversion is the majority consumer; 25 % active
patches assumed.

Three layers of evidence, strongest last:

1. **Analytical** — ``power_report`` (the meter on the closed-form
   steady-state event counts).
2. **Measured** — a REAL 2 Mpix compact frontend run: the events the
   runtime actually executed, priced by the same meter; asserted equal
   to the analytical view at the matched operating point and < 30 mW/MP.
3. **Governed** — the serving engine under a chip budget set below the
   ungoverned full-motion demand: measured power must track the budget
   within 10 % (hard assert — event counts are deterministic, this is
   not a wall-clock number), while a slack budget stays bitwise
   identical to the ungoverned engine and the static scene's power
   collapses to the fixed frame costs.

Every power row carries a ``power`` record with ``source:
"event-meter"`` — mirroring the §9 measured-bytes schema — and
``benchmarks/check_power_accounting.py`` re-derives the claims in CI.
"""

import time

import numpy as np

from repro.core.power import (
    AreaBudget, EnergyMeter, SensorConfig, conv_frame_events, power_report,
    steady_state_events,
)

FRAME_HZ = 30.0


def _timed(fn):
    t0 = time.perf_counter_ns()
    out = fn()
    return out, (time.perf_counter_ns() - t0) / 1e3


def area_rows() -> list[dict]:
    area, us = _timed(lambda: AreaBudget().totals())
    assert area["Total"]["total_um2"] == 485.0
    return [
        {"name": "table1_pitch_um", "us_per_call": us,
         "derived": f"{area['Total']['pitch_um']:.1f} (paper: 22.0)"},
        {"name": "table1_total_um2", "us_per_call": us,
         "derived": f"{area['Total']['total_um2']:.0f} (paper: 485)"},
    ]


def analytical_rows() -> list[dict]:
    rep, us = _timed(lambda: power_report(SensorConfig()))
    rep_1mpix, us1 = _timed(lambda: power_report(SensorConfig(n_pixels=1e6)))
    share = rep.share()
    top = rep.dominant
    rows = [
        {"name": "power_2mpix_30hz_mw", "us_per_call": us,
         "power": {"mw": rep.total_w * 1e3, "source": "event-meter"},
         "derived": f"{rep.total_w * 1e3:.1f} (<60 claim)"},
        {"name": "power_mw_per_mpix", "us_per_call": us,
         "power": {"mw_per_mpix": rep.mw_per_mpix, "source": "event-meter"},
         "derived": f"{rep.mw_per_mpix:.1f} (<30 claim)"},
        {"name": "power_dominant_component", "us_per_call": us,
         "derived": f"{top} {share[top] * 100:.0f}% (paper: ADC majority)"},
        {"name": "power_1mpix_mw", "us_per_call": us1,
         "power": {"mw": rep_1mpix.total_w * 1e3,
                   "mw_per_mpix": rep_1mpix.mw_per_mpix,
                   "source": "event-meter"},
         "derived": (f"{rep_1mpix.total_w * 1e3:.1f} "
                     f"({rep_1mpix.mw_per_mpix:.1f} mW/MP, <30 claim)")},
    ]
    assert rep.total_w < 0.060 and rep.mw_per_mpix < 30.0
    # the <30 mW/MP claim is per-megapixel: it must hold at 1 Mpix too,
    # not only at the 2 Mpix point where the DAC broadcast amortizes more
    assert rep_1mpix.mw_per_mpix < 30.0
    assert top == "adc"

    # meter == closed form, by construction — pinned here so the artifact
    # records it next to the numbers it guarantees
    def consistency():
        bd = EnergyMeter().power_w(
            steady_state_events(SensorConfig()), SensorConfig().frame_hz)
        assert bd.components == rep.components and bd.total_w == rep.total_w
        return bd
    bd, usc = _timed(consistency)
    rows.append({
        "name": "power_meter_equals_analytical", "us_per_call": usc,
        "power": {"mw": bd.total_w * 1e3, "source": "event-meter"},
        "derived": (f"meter(steady-state events) == power_report exactly, "
                    f"{len(bd.components)} components"),
    })
    return rows


def measured_runtime_row() -> list[dict]:
    """Run the real compact frontend at the paper's 2 Mpix / 32x32 /
    400-vector / 25 % operating point and price the events it EXECUTED."""
    import jax

    from repro.core.frontend import (
        FrontendConfig, apply_frontend, init_frontend_params,
    )
    from repro.core.projection import PatchSpec

    cfg = FrontendConfig(
        image_h=1024, image_w=2048, aa_cutoff=None,
        patch=PatchSpec(patch_h=32, patch_w=32, n_vectors=400),
        active_fraction=0.25,
    )
    params = init_frontend_params(jax.random.PRNGKey(0), cfg)
    rgb = jax.random.uniform(jax.random.PRNGKey(1), (1, 1024, 2048, 3))

    def run():
        cf = apply_frontend(params, rgb, cfg, mode="compact")
        return jax.tree.map(lambda e: float(np.asarray(e)[0]), cf.events)
    ev, us = _timed(run)

    mpix = 1024 * 2048 / 1e6
    mw = EnergyMeter().power_mw(ev, FRAME_HZ)
    measured_per_mpix = mw / mpix
    rep = power_report(SensorConfig(n_pixels=float(1024 * 2048)))
    # measured-from-events must reproduce the analytical claim exactly
    # (same operating point, same meter) and stay inside the paper budget
    assert abs(measured_per_mpix - rep.mw_per_mpix) / rep.mw_per_mpix < 1e-6
    assert measured_per_mpix < 30.0
    return [{
        "name": "power_measured_2mpix_runtime",
        "us_per_call": us,
        "power": {"mw": mw, "mw_per_mpix": measured_per_mpix,
                  "adc_conversions_per_frame": ev.adc_conversions,
                  "source": "event-meter"},
        "derived": (f"{mw:.1f} mW measured from executed events "
                    f"({measured_per_mpix:.1f} mW/MP, <30 claim; "
                    f"{ev.adc_conversions:.0f} conversions/frame)"),
    }]


def mode_rows() -> list[dict]:
    """DESIGN.md §13 — per-mode power at the paper's 2 Mpix operating
    point, all priced by the ONE event meter over each mode's analytical
    event counts (``check_modes_accounting.py`` re-derives every number):

    * patch-bank + ADC: the baseline mW/MP figure (the <30 claim);
    * ADC-less sign readout: same analog work, comparator conversion —
      must land WELL under the baseline, since ADC is the majority
      consumer;
    * conv-in-pixel: program-once vs reprogram-per-frame kernel banks —
      the delta is exactly C·K² DAC register rewrites per frame.
    """
    meter = EnergyMeter()
    scfg = SensorConfig()
    mpix = scfg.n_pixels / 1e6

    def per_mpix(ev):
        return meter.power_mw(ev, scfg.frame_hz) / mpix

    t0 = time.perf_counter_ns()
    adc_mw = per_mpix(steady_state_events(scfg))
    sign_mw = per_mpix(steady_state_events(scfg, readout="sign"))
    us = (time.perf_counter_ns() - t0) / 1e3
    rows = [
        {"name": "power_mode_patchbank_adc", "us_per_call": us,
         "power": {"mw_per_mpix": adc_mw, "source": "event-meter"},
         "derived": f"{adc_mw:.1f} mW/MP patch-bank + edge ADC (baseline)"},
        {"name": "power_mode_sign_readout", "us_per_call": us,
         "power": {"mw_per_mpix": sign_mw, "source": "event-meter"},
         "derived": (f"{sign_mw:.1f} mW/MP ADC-less sign readout "
                     f"({sign_mw / adc_mw:.0%} of baseline — the ADC "
                     f"majority is gone)")},
    ]
    # the sign tier exists because the ADC is the majority consumer:
    # deleting it must cut the budget by more than half
    assert sign_mw < 0.5 * adc_mw, (
        f"sign readout {sign_mw:.1f} mW/MP not well under ADC baseline "
        f"{adc_mw:.1f}")

    # conv-in-pixel: K=8 stride 8, 16 channels over the same 2 Mpix frame
    k2, ch = 64, 16
    n_windows = scfg.n_pixels / k2
    kw = dict(n_pixels=scfg.n_pixels, pixels_per_window=k2, n_channels=ch,
              n_windows=n_windows)
    t0 = time.perf_counter_ns()
    once_mw = per_mpix(conv_frame_events(**kw))
    cyc_mw = per_mpix(conv_frame_events(reprogram=True, **kw))
    us = (time.perf_counter_ns() - t0) / 1e3
    delta_claim = (ch * k2 * meter.k.e_dac_reprogram_j * scfg.frame_hz
                   * 1e3 / mpix)
    rows.append({
        "name": "power_mode_conv_program_once_vs_reprogram",
        "us_per_call": us,
        "power": {"mw_per_mpix": once_mw, "reprogram_mw_per_mpix": cyc_mw,
                  "n_channels": ch, "pixels_per_window": k2,
                  "source": "event-meter"},
        "derived": (f"conv 8x8/s8/C16: {once_mw:.1f} mW/MP program-once, "
                    f"{cyc_mw:.1f} mW/MP cycling kernels "
                    f"(+{cyc_mw - once_mw:.4f} = C·K² DAC rewrites)"),
    })
    assert cyc_mw > once_mw
    assert abs((cyc_mw - once_mw) - delta_claim) < 1e-9 * max(delta_claim, 1)
    return rows


def governed_sweep(frames: int = 16) -> list[dict]:
    """The closed loop (DESIGN.md §10): a reduced engine config, measured
    power from executed events, a budget below the ungoverned full-motion
    demand — budget tracking and the accuracy cost of degradation."""
    import jax

    from repro.core.frontend import FrontendConfig
    from repro.core.projection import PatchSpec
    from repro.core.temporal import TemporalSpec
    from repro.models.vit import ViTConfig, init_vit
    from repro.serve.engine import SaccadeEngine
    from repro.serve.governor import GovernorSpec

    fcfg = FrontendConfig(
        image_h=64, image_w=64, aa_cutoff=None,
        patch=PatchSpec(patch_h=8, patch_w=8, n_vectors=64),
        active_fraction=0.25, temporal=TemporalSpec(delta_threshold=1e-4),
    )
    cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    scenes = np.asarray(
        jax.random.uniform(jax.random.PRNGKey(1), (frames, 64, 64, 3)))

    def serve(governor=None, motion=True):
        eng = SaccadeEngine(cfg, params, capacity=1, temporal=True,
                            frame_hz=FRAME_HZ, governor=governor)
        eng.admit("cam")
        mws, logits = [], []
        for t in range(frames):
            frame = scenes[t] if motion else scenes[0]
            logits.append(eng.step({"cam": frame})["cam"])
            mws.append(eng.power_mw("cam"))
        return eng, np.asarray(mws), np.asarray(logits)

    rows = []
    t0 = time.perf_counter_ns()
    _, mw_full, logits_full = serve(motion=True)
    _, mw_static, logits_static = serve(motion=False)
    demand = float(mw_full[-5:].mean())
    static_mw = float(mw_static[-5:].mean())
    us = (time.perf_counter_ns() - t0) / 1e3
    rows.append({
        "name": "power_engine_demand_full_vs_static",
        "us_per_call": us / (2 * frames),
        "power": {"full_motion_mw": demand, "static_mw": static_mw,
                  "source": "event-meter"},
        "derived": (f"ungoverned demand: full-motion {demand:.3f} mW vs "
                    f"static {static_mw:.3f} mW "
                    f"({demand / static_mw:.1f}x — holds are free)"),
    })

    # --- governed full motion: budget below demand, tracking within 10 %
    budget = 0.66 * demand
    t0 = time.perf_counter_ns()
    eng_g, mw_gov, logits_gov = serve(GovernorSpec(budget_mw=budget))
    us = (time.perf_counter_ns() - t0) / 1e3
    steady = mw_gov[-5:]
    err = float(np.abs(steady - budget).max() / budget)
    agree = float(np.mean(
        np.argmax(logits_gov, -1) == np.argmax(logits_full, -1)))
    k = fcfg.n_active
    rows.append({
        "name": "power_governed_full_motion_budget_tracking",
        "us_per_call": us / frames,
        "power": {"budget_mw": budget, "measured_mw": float(steady.mean()),
                  "tracking_error": err, "source": "event-meter"},
        "derived": (f"budget {budget:.3f} mW (66% of demand) -> measured "
                    f"{steady.mean():.3f} mW, tracking error {err:.1%} "
                    f"(<=10% asserted); cap {eng_g.recompute_cap('cam')}/{k} "
                    f"tier {eng_g.k_tier('cam')}/{k}; argmax agreement vs "
                    f"ungoverned {agree:.0%} (accuracy cost of degradation)"),
    })
    # deterministic event arithmetic, not wall clock: always hard
    assert err <= 0.10, f"governed tracking error {err:.1%} > 10%"

    # --- slack budget on the static scene: bitwise no-op
    t0 = time.perf_counter_ns()
    _, mw_slack, logits_slack = serve(
        GovernorSpec(budget_mw=4.0 * demand), motion=False)
    us = (time.perf_counter_ns() - t0) / 1e3
    identical = bool(np.array_equal(logits_slack, logits_static))
    rows.append({
        "name": "power_governed_slack_budget_static",
        "us_per_call": us / frames,
        "power": {"budget_mw": 4.0 * demand,
                  "measured_mw": float(mw_slack[-5:].mean()),
                  "source": "event-meter"},
        "derived": (f"slack budget: governed static scene bitwise-identical "
                    f"to ungoverned = {identical}; steady "
                    f"{mw_slack[-5:].mean():.3f} mW"),
    })
    assert identical, "slack-budget governed path diverged from ungoverned"

    # --- ADC-less sign tier (DESIGN.md §13): a budget BELOW the finest
    # k tier's floor allocation — unservable by any k tier — degrades the
    # readout instead of the selection, and lands under the floor
    import jax.numpy as jnp

    from repro.serve.governor import fixed_power_mw

    meter = EnergyMeter()
    slot_mw = 1e3 * meter.slot_recompute_power_w(64, 64, FRAME_HZ)
    spec0 = GovernorSpec(budget_mw=1.0, sign_tier=True)
    k_min = spec0.tier_tokens(fcfg.n_active)[-1]
    floor_mw = float(fixed_power_mw(
        meter, 64.0 * 64.0, 64, 64, jnp.asarray([k_min], jnp.float32),
        FRAME_HZ)[0]) + spec0.floor * slot_mw
    budget_s = 0.8 * floor_mw
    t0 = time.perf_counter_ns()
    eng_s, mw_sign, logits_sign = serve(
        GovernorSpec(budget_mw=budget_s, sign_tier=True))
    us = (time.perf_counter_ns() - t0) / 1e3
    steady_sign = float(mw_sign[-5:].mean())
    agree_s = float(np.mean(
        np.argmax(logits_sign, -1) == np.argmax(logits_full, -1)))
    rows.append({
        "name": "power_governed_sign_tier",
        "us_per_call": us / frames,
        "power": {"budget_mw": budget_s, "floor_mw": floor_mw,
                  "measured_mw": steady_sign, "source": "event-meter"},
        "derived": (f"budget {budget_s:.4f} mW (80% of the finest-tier "
                    f"floor {floor_mw:.4f}) -> sign readout "
                    f"{eng_s.sign_readout('cam')}, measured "
                    f"{steady_sign:.4f} mW (< floor); argmax agreement vs "
                    f"ungoverned {agree_s:.0%}"),
    })
    assert eng_s.sign_readout("cam"), "sign tier never engaged"
    assert steady_sign < floor_mw, (
        f"sign tier {steady_sign:.4f} mW not under the finest-tier floor "
        f"{floor_mw:.4f}")
    return rows


def run() -> list[dict]:
    rows = area_rows() + analytical_rows() + mode_rows()
    rows += measured_runtime_row()
    rows += governed_sweep()
    return rows
