"""Bench-smoke guard: the BENCH_throughput.json rollout row must be a
real dispatch-overhead measurement (DESIGN.md §15) — mirroring the §12
fleet guard (check_fleet_accounting.py).

Three layers of defence:

1. Schema: the rollout row carries a ``rollout`` record with ``source ==
   "perf_counter"``, raw per-repeat samples (looped baseline, rollout
   dispatch, rollout fetch) for EVERY sweep point, the trace ledger (one
   engine trace + one rollout trace per distinct T) and the in-bench
   bitwise-parity verdict — no hand-typed speedups can sneak into the
   artifact.
2. Claims: every stored per-tick median (loop / rollout / dispatch /
   fetch) and every stored speedup reproduce from the raw samples, and
   the acceptance floor (rollout ≥ 2× the looped step per tick at T=16)
   holds unless the artifact says the bench ran relaxed.
3. Live re-derivation: a fresh engine pair re-checks the BITWISE-parity
   claim here (rollout vs sequential steps, logits + full carried
   state), and a short live timing re-checks that a rollout actually
   beats the looped step on this machine (soft, ``IP2_BENCH_RELAX``
   relaxes the live timing only — parity is never relaxed).

Run after ``benchmarks/run.py`` (needs src and the repo root on the
path): ``PYTHONPATH=src:. python benchmarks/check_rollout_accounting.py``.
"""

import json
import os
import sys

ROLLOUT_SOURCE = "perf_counter"


def _relaxed() -> bool:
    return bool(os.environ.get("IP2_BENCH_RELAX"))


def check_artifact(path: str) -> dict:
    import numpy as np

    with open(path) as f:
        results = json.load(f)
    rr = next(v for k, v in results.items() if k.startswith("rollout"))
    rows = {r["name"]: r for r in rr if "name" in r}
    name = next(n for n in rows if n.startswith("rollout_dispatch_"))
    rec = rows[name].get("rollout")

    # --- layer 1: schema ---------------------------------------------------
    assert isinstance(rec, dict), f"{name}: no rollout record"
    assert rec.get("source") == ROLLOUT_SOURCE, (
        f"{name}: not a measured row (source={rec.get('source')!r})")
    for key in ("capacity", "t_sweep", "repeats", "per_t", "n_traces",
                "n_rollout_traces", "parity_bitwise", "parity_T",
                "speedup_t", "speedup_floor", "relaxed"):
        assert key in rec, f"{name}: rollout record missing {key!r}"
    assert rec["n_traces"] == 1, (
        f"engine retraced during the sweep: n_traces={rec['n_traces']}")
    assert rec["n_rollout_traces"] == len(rec["t_sweep"]), (
        f"one rollout trace per distinct T: expected {len(rec['t_sweep'])}, "
        f"got {rec['n_rollout_traces']}")
    assert rec["parity_bitwise"] is True, (
        "the bench's in-run parity check failed — the stored speedups "
        "compare two DIFFERENT computations")

    # --- layer 2: claims ---------------------------------------------------
    for T in rec["t_sweep"]:
        p = rec["per_t"][str(T)]
        for key in ("loop_ms_samples", "dispatch_ms_samples",
                    "fetch_ms_samples"):
            assert len(p[key]) == rec["repeats"], (
                f"T={T}: {len(p[key])} {key} for {rec['repeats']} repeats")
        loop = np.asarray(p["loop_ms_samples"], np.float64)
        disp = np.asarray(p["dispatch_ms_samples"], np.float64)
        fetch = np.asarray(p["fetch_ms_samples"], np.float64)
        derived = {
            "loop_tick_ms": float(np.median(loop)) / T,
            "rollout_tick_ms": float(np.median(disp + fetch)) / T,
            "dispatch_tick_ms": float(np.median(disp)) / T,
            "fetch_tick_ms": float(np.median(fetch)) / T,
        }
        derived["speedup"] = (
            derived["loop_tick_ms"] / derived["rollout_tick_ms"])
        for key, want in derived.items():
            assert abs(p[key] - want) < 1e-9 * max(1.0, want), (
                f"T={T}: stored {key} {p[key]} != re-derived {want}")
    floor_speedup = rec["per_t"][str(rec["speedup_t"])]["speedup"]
    if not rec["relaxed"]:
        assert floor_speedup >= rec["speedup_floor"], (
            f"artifact claims an unrelaxed run but speedup at "
            f"T={rec['speedup_t']} is {floor_speedup:.2f}x < "
            f"{rec['speedup_floor']:g}x")
    return {"name": name, "rec": rec, "floor_speedup": floor_speedup}


def check_live() -> tuple[bool, float]:
    """Re-derive the two claims live on a small operating point: bitwise
    parity (hard) and rollout-beats-loop (soft under IP2_BENCH_RELAX)."""
    import time

    import numpy as np
    import jax

    from repro.core.frontend import FrontendConfig
    from repro.core.projection import PatchSpec
    from repro.core.temporal import TemporalSpec
    from repro.data.pipeline import SceneStream
    from repro.models.vit import ViTConfig, init_vit
    from repro.serve.engine import SaccadeEngine
    from repro.serve.governor import GovernorSpec

    fcfg = FrontendConfig(
        image_h=32, image_w=32, aa_cutoff=None,
        patch=PatchSpec(patch_h=8, patch_w=8, n_vectors=16),
        active_fraction=0.25,
        temporal=TemporalSpec(delta_threshold=1e-4))
    cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2,
                    d_ff=64)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    pool = np.asarray(SceneStream(image=32).batch(0, 32)[0])
    cap, T = 8, 8

    def build():
        eng = SaccadeEngine(cfg, params, capacity=cap, temporal=True,
                            governor=GovernorSpec(budget_mw=50.0))
        for i in range(cap):
            eng.admit(f"s{i}")
        return eng

    def frames_at(t):
        return {f"s{i}": pool[(i + t) % len(pool)] for i in range(cap)}

    # live bitwise parity: rollout vs T sequential steps, logits + state
    e_seq, e_roll = build(), build()
    sched = [frames_at(t) for t in range(T)]
    seq = [e_seq.step(fr) for fr in sched]
    roll = e_roll.step_rollout(sched)
    for t in range(T):
        assert set(seq[t]) == set(roll[t])
        for sid in seq[t]:
            assert np.array_equal(seq[t][sid], roll[t][sid]), (
                f"LIVE parity failed: tick {t} stream {sid} logits differ "
                f"between rollout and sequential steps")
    for i, (a, b) in enumerate(zip(jax.tree.leaves(e_seq.state),
                                   jax.tree.leaves(e_roll.state))):
        assert np.array_equal(np.asarray(a), np.asarray(b)), (
            f"LIVE parity failed: state leaf {i} differs")

    # live timing: one warm engine, rollout vs loop at T ticks
    eng = build()
    for t in range(2):
        eng.step(frames_at(t))
    eng.step_rollout(sched)                       # compile the T trace
    best_loop, best_roll = float("inf"), float("inf")
    for rep in range(3):
        t0 = time.perf_counter()
        for fr in sched:
            eng.step(fr)
        best_loop = min(best_loop, time.perf_counter() - t0)
        t0 = time.perf_counter()
        eng.step_rollout(sched)
        best_roll = min(best_roll, time.perf_counter() - t0)
    live_speedup = best_loop / best_roll
    if not _relaxed():
        assert live_speedup > 1.0, (
            f"LIVE timing: rollout ({best_roll * 1e3:.2f} ms) did not beat "
            f"the looped step ({best_loop * 1e3:.2f} ms) at T={T} "
            f"(set IP2_BENCH_RELAX=1 on noisy runners)")
    return True, live_speedup


def main(path: str = "BENCH_throughput.json") -> None:
    art = check_artifact(path)
    rec = art["rec"]
    _, live_speedup = check_live()
    print(f"rollout accounting OK: {art['name']} — per-tick medians and "
          f"speedups reproduce from {rec['repeats']} raw samples over "
          f"T={rec['t_sweep']}, stored speedup at T={rec['speedup_t']} "
          f"{art['floor_speedup']:.2f}x"
          f"{' (relaxed)' if rec['relaxed'] else ''}, traces "
          f"1+{rec['n_rollout_traces']}; LIVE parity bitwise, live "
          f"rollout speedup {live_speedup:.2f}x")


if __name__ == "__main__":
    main(*sys.argv[1:])
