"""Data pipeline: deterministic, seekable, host-sharded.

Production shape: each host materializes only its shard of the global
batch (``host_slice``), the stream is a pure function of (seed, step) so a
restarted/rescaled job resumes exactly (fault tolerance requirement — no
stateful iterators to lose), and batches are built on CPU then device_put
against the target sharding.

Sources:
  * ``TokenStream`` — synthetic LM tokens (zipf-ish unigram + markov mix so
    the loss has learnable structure).
  * ``SceneStream`` — procedurally generated RGB scenes with K shape
    classes for the IP2 classification co-design experiments (paper §1).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    seed: int = 1234
    vocab: int = 512
    seq_len: int = 128
    global_batch: int = 8


class TokenStream:
    """Deterministic synthetic token batches: batch(step) is pure."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        root = np.random.default_rng(cfg.seed)
        v = cfg.vocab
        # fixed unigram (zipf) + first-order markov structure
        self.unigram = 1.0 / np.arange(1, v + 1)
        self.unigram /= self.unigram.sum()
        self.shift = root.integers(1, v, size=v)

    def batch(self, step: int, host_id: int = 0, n_hosts: int = 1) -> dict:
        cfg = self.cfg
        per_host = cfg.global_batch // n_hosts
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 65_537 + host_id
        )
        first = rng.choice(cfg.vocab, size=(per_host, 1), p=self.unigram)
        noise = rng.random((per_host, cfg.seq_len - 1))
        toks = [first[:, 0]]
        for t in range(cfg.seq_len - 1):
            nxt = np.where(
                noise[:, t] < 0.75,
                self.shift[toks[-1]],                       # learnable transition
                rng.choice(cfg.vocab, size=per_host, p=self.unigram),
            )
            toks.append(nxt)
        tokens = np.stack(toks, axis=1).astype(np.int32)
        return {"tokens": tokens}


class SceneStream:
    """Procedural K-class shape scenes for the IP2 accuracy experiments.

    Each image: dark textured background + one bright shape (class id in
    {0..n_classes-1}: squares/discs/crosses/stripes of varying scale) at a
    random position — classification requires localized patch features,
    which is exactly the regime the paper's salient-patch gating targets.
    """

    def __init__(self, seed: int = 7, image: int = 64, n_classes: int = 4):
        self.seed, self.image, self.n_classes = seed, image, n_classes

    def batch(self, step: int, batch_size: int) -> tuple[np.ndarray, np.ndarray]:
        rng = np.random.default_rng(self.seed * 999_983 + step)
        h = w = self.image
        imgs = rng.uniform(0.0, 0.25, size=(batch_size, h, w, 3)).astype(np.float32)
        labels = rng.integers(0, self.n_classes, size=batch_size)
        yy, xx = np.mgrid[0:h, 0:w]
        for i in range(batch_size):
            c = int(labels[i])
            size = rng.integers(h // 8, h // 4)
            cy = rng.integers(size, h - size)
            cx = rng.integers(size, w - size)
            color = rng.uniform(0.7, 1.0, size=3).astype(np.float32)
            dy, dx = yy - cy, xx - cx
            if c == 0:      # square
                m = (np.abs(dy) < size) & (np.abs(dx) < size)
            elif c == 1:    # disc
                m = dy * dy + dx * dx < size * size
            elif c == 2:    # cross
                m = ((np.abs(dy) < size // 3) | (np.abs(dx) < size // 3)) & \
                    (np.abs(dy) < size) & (np.abs(dx) < size)
            else:           # diagonal stripes patch
                m = (np.abs(dy) < size) & (np.abs(dx) < size) & (((yy + xx) // 3) % 2 == 0)
            imgs[i][m] = color
        return imgs, labels.astype(np.int32)
