"""Pallas TPU kernel — w8a8 quantized matmul (DESIGN.md §7, §9).

The paper's derived digital optimization: the same "quantize-the-multiply"
insight applied to backend projections and KV-cache dequant-matmuls.
Weights arrive as int8 codes with a per-output-channel scale (exactly the
weight-DAC abstraction). Activations arrive ALREADY quantized — this
kernel never quantizes them itself. The two entry points in ops.py differ
only in who did that quantization:

* ``ops.quant_matmul`` — float activations; the *wrapper* quantizes them
  per-row on the host (``ref.quantize_activations_ref``) before the call.
* ``ops.quant_matmul_pre`` — pre-quantized int8 codes + scales straight
  in. This is the ADC-code consumption path (DESIGN.md §9): the edge ADC
  already performed the activation quantization at conversion time, so
  feeding its codes through here incurs no second rounding.

    y[p, m] = (sum_k a8[p,k] * w8[k,m]) * s_a[p] * s_w[m]

int32 accumulation on the MXU, fused dequant epilogue.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ip2_project import COMPILER_PARAMS_CLS


def _qmm_kernel(a_ref, sa_ref, w_ref, sw_ref, o_ref, acc_ref, *, k_steps: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    a8 = a_ref[...].astype(jnp.int32)
    w8 = w_ref[...].astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        a8, w8, (((1,), (0,)), ((), ())), preferred_element_type=jnp.int32
    )

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        sa = sa_ref[...][:, None]
        sw = sw_ref[...][None, :]
        o_ref[...] = (acc_ref[...].astype(jnp.float32) * sa * sw).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("block_p", "block_m", "block_k", "out_dtype", "interpret")
)
def quant_matmul_pallas(
    a8: jnp.ndarray,        # (P, K) int8 activations
    s_a: jnp.ndarray,       # (P,) float32 per-row scales
    w8: jnp.ndarray,        # (K, M) int8 weights
    s_w: jnp.ndarray,       # (M,) float32 per-col scales
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 512,
    out_dtype=jnp.float32,
    interpret: bool = False,
) -> jnp.ndarray:
    P, K = a8.shape
    K2, M = w8.shape
    assert K == K2 and s_a.shape == (P,) and s_w.shape == (M,)
    assert P % block_p == 0 and M % block_m == 0 and K % block_k == 0
    k_steps = K // block_k
    grid = (P // block_p, M // block_m, k_steps)

    return pl.pallas_call(
        functools.partial(_qmm_kernel, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_p,), lambda i, j, k: (i,)),
            pl.BlockSpec((block_k, block_m), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_p, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((P, M), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_p, block_m), jnp.int32)],
        compiler_params=COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a8, s_a, w8, s_w)
