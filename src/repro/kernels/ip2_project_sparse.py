"""Pallas TPU kernel — sparse (active-patch-only) IP2 projection.

The compact-first dataflow (DESIGN.md §3): the saccade selector produces
the indices of the k active patches, and this kernel projects *only* those
rows of the dense patch array. The gather is not a separate XLA pass —
it happens in the kernel's index_maps: the active-patch row indices are
scalar-prefetched (``pltpu.PrefetchScalarGridSpec``), so before each grid
step the DMA engine fetches exactly the patch rows the step needs, straight
from the dense (P, K) array in HBM into VMEM. FLOPs and VMEM traffic both
scale with ``k / P`` (the active fraction); deselected patches are never
touched — the digital twin of "deselected patches drain their photodiodes
and power down".

Grid = (active row banks, vector banks, K banks). One grid step processes
``block_r`` *arbitrary* (non-contiguous) dense rows: the patch operand is
passed ``block_r`` times with single-row BlockSpecs whose index_maps each
read their own slot of the prefetched row table (``idx[i*block_r + r]``),
and the kernel body stacks the gathered rows into one (block_r, block_k)
tile for the MXU. Selection therefore stays patch-granular for any saccade
pattern while the matmul and the grid amortize over a sublane-aligned row
bank — multi-row stale batches (the temporal gate's j rows, DESIGN.md §6)
no longer serialize one 1×K×M matmul per row. The full PWM / charge-share /
droop / 2T / edge-ADC epilogue stays fused exactly as in the dense kernel
(shared helpers), including the ``adc_out_codes`` wire format (int8 codes
out, DESIGN.md §9).

The wrapper in ops.py pads the row table to a multiple of ``block_r``
(clipped duplicate rows, sliced off after the call) and defaults
``block_r`` to the sublane-aligned row count, mirroring how
``ops.ip2_project`` clamps ``block_p``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ip2_project import (
    COMPILER_PARAMS_CLS,
    IP2KernelParams,
    analog_epilogue_tile,
    pwm_quantize_tile,
)


def _ip2_sparse_kernel(
    idx_ref, *refs, p: IP2KernelParams, k_steps: int, block_r: int
):
    """Grid = (row banks, vector banks, K banks); K innermost/arbitrary.

    ``idx_ref`` is the scalar-prefetched row table; it already steered the
    per-row BlockSpec index_maps, so ``refs[:block_r]`` hold the gathered
    rows of this bank."""
    del idx_ref  # consumed by the index_maps, not the body
    x_refs = refs[:block_r]
    w_ref, b_ref, o_ref, acc_ref = refs[block_r:]

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = jnp.concatenate([r[...] for r in x_refs], axis=0)   # (block_r, block_k)
    xq = pwm_quantize_tile(x, p)
    acc_ref[...] += jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = analog_epilogue_tile(acc_ref[...], b_ref[...], p).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "block_r", "block_m", "block_k", "interpret"),
)
def ip2_project_sparse_pallas(
    row_idx: jnp.ndarray,      # (R,) int32 dense row indices of active patches
    patches: jnp.ndarray,      # (P_rows, K) dense pixel voltages in [0,1]
    w_q: jnp.ndarray,          # (K, M) DAC-quantized weights (pre-quantized)
    bias: jnp.ndarray,         # (M,)
    params: IP2KernelParams,
    block_r: int = 8,
    block_m: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Padded-shape kernel entry; use repro.kernels.ops.ip2_project_sparse.

    Returns (R, M): output row i holds the projection of dense patch row
    ``row_idx[i]`` (rows within a bank may come from anywhere in the dense
    array). ``R`` must be a multiple of ``block_r``.
    """
    p_rows, K = patches.shape
    K2, M = w_q.shape
    (R,) = row_idx.shape
    assert K == K2 and bias.shape == (M,)
    assert R % block_r == 0 and M % block_m == 0 and K % block_k == 0, (
        f"pad shapes to blocks: {(R, K, M)} vs {(block_r, block_k, block_m)}"
    )
    k_steps = K // block_k
    grid = (R // block_r, M // block_m, k_steps)

    def _row_map(r):
        # the gather: slot r of row bank i loads dense row idx[i*block_r + r]
        return lambda i, j, k, idx: (idx[i * block_r + r], k)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            *(pl.BlockSpec((1, block_k), _row_map(r)) for r in range(block_r)),
            pl.BlockSpec((block_k, block_m), lambda i, j, k, idx: (k, j)),
            pl.BlockSpec((block_m,), lambda i, j, k, idx: (j,)),
        ],
        out_specs=pl.BlockSpec((block_r, block_m), lambda i, j, k, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_r, block_m), jnp.float32)],
    )

    return pl.pallas_call(
        functools.partial(
            _ip2_sparse_kernel, p=params, k_steps=k_steps, block_r=block_r
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, M), params.out_dtype),
        compiler_params=COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(row_idx.astype(jnp.int32), *([patches] * block_r), w_q, bias)
