"""Pallas TPU kernel — sparse (active-patch-only) IP2 projection.

The compact-first dataflow (DESIGN.md §3): the saccade selector produces
the indices of the k active patches, and this kernel projects *only* those
rows of the dense patch array. The gather is not a separate XLA pass —
it happens in the kernel's index_map: the active-patch indices are
scalar-prefetched (``pltpu.PrefetchScalarGridSpec``), so before each grid
step the DMA engine fetches exactly the patch bank the step needs, straight
from the dense (P, K) array in HBM into VMEM. FLOPs and VMEM traffic both
scale with ``k / P`` (the active fraction); deselected patches are never
touched — the digital twin of "deselected patches drain their photodiodes
and power down".

Grid = (active patch banks, vector banks, K banks). The patch BlockSpec's
index_map reads ``idx_ref[i]``, the prefetched dense bank index for compact
output bank ``i``; the full PWM / charge-share / droop / 2T / edge-ADC
epilogue stays fused exactly as in the dense kernel (shared helpers).

Bank granularity: ``block_r`` patches per bank. The wrapper in ops.py uses
``block_r=1`` so selection is patch-granular for any saccade pattern (the
sublane dimension is padded internally; on TPU a bank of 8 amortizes the
DMA better when the selector emits 8-aligned banks — see DESIGN.md §3.2).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ip2_project import (
    COMPILER_PARAMS_CLS,
    IP2KernelParams,
    analog_epilogue_tile,
    pwm_quantize_tile,
)


def _ip2_sparse_kernel(
    idx_ref, x_ref, w_ref, b_ref, o_ref, acc_ref, *, p: IP2KernelParams, k_steps: int
):
    """Grid = (active banks, vector banks, K banks); K innermost/arbitrary.

    ``idx_ref`` is the scalar-prefetched bank table; it already steered the
    BlockSpec index_map, so ``x_ref`` holds the gathered active bank."""
    del idx_ref  # consumed by the index_map, not the body

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xq = pwm_quantize_tile(x_ref[...], p)
    acc_ref[...] += jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = analog_epilogue_tile(acc_ref[...], b_ref[...], p).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "block_r", "block_m", "block_k", "interpret"),
)
def ip2_project_sparse_pallas(
    bank_idx: jnp.ndarray,     # (R,) int32 dense bank indices of active banks
    patches: jnp.ndarray,      # (P_rows, K) dense pixel voltages in [0,1]
    w_q: jnp.ndarray,          # (K, M) DAC-quantized weights (pre-quantized)
    bias: jnp.ndarray,         # (M,)
    params: IP2KernelParams,
    block_r: int = 1,
    block_m: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Padded-shape kernel entry; use repro.kernels.ops.ip2_project_sparse.

    Returns (R * block_r, M): compact output bank i holds the projection of
    dense patch rows [bank_idx[i]*block_r, (bank_idx[i]+1)*block_r).
    """
    p_rows, K = patches.shape
    K2, M = w_q.shape
    (R,) = bank_idx.shape
    assert K == K2 and bias.shape == (M,)
    assert p_rows % block_r == 0 and M % block_m == 0 and K % block_k == 0, (
        f"pad shapes to blocks: {(p_rows, K, M)} vs {(block_r, block_k, block_m)}"
    )
    k_steps = K // block_k
    grid = (R, M // block_m, k_steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            # the gather: compact step i loads dense patch bank idx[i]
            pl.BlockSpec((block_r, block_k), lambda i, j, k, idx: (idx[i], k)),
            pl.BlockSpec((block_k, block_m), lambda i, j, k, idx: (k, j)),
            pl.BlockSpec((block_m,), lambda i, j, k, idx: (j,)),
        ],
        out_specs=pl.BlockSpec((block_r, block_m), lambda i, j, k, idx: (i, j)),
        scratch_shapes=[pltpu.VMEM((block_r, block_m), jnp.float32)],
    )

    return pl.pallas_call(
        functools.partial(_ip2_sparse_kernel, p=params, k_steps=k_steps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R * block_r, M), jnp.float32),
        compiler_params=COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(bank_idx.astype(jnp.int32), patches, w_q, bias)
