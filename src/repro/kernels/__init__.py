"""Pallas TPU kernels for the IP2 compute hot-spots.

ip2_project / ip2_project_sparse — the analog patch-projection array's
digital twin (fused PWM quantize + MXU GEMM + charge-share/ADC epilogue;
dense grid vs scalar-prefetch active-row gather), emitting float readout
or the int8 ADC-code wire format (DESIGN.md §9); quant_matmul /
quant_matmul_pre — w8a8 backend projections (host-quantized floats vs
pre-quantized codes, e.g. straight from the edge ADC). ops.py = jit'd
wrappers (padding, CPU interpret fallback); ref.py = pure-jnp oracles
every kernel is tested against.
"""

from repro.kernels.ops import (
    ip2_codes_fn,
    ip2_project,
    ip2_project_fn,
    ip2_project_sparse,
    quant_matmul,
    quant_matmul_pre,
    quantize_weights_int8,
)

__all__ = [
    "ip2_codes_fn", "ip2_project", "ip2_project_fn", "ip2_project_sparse",
    "quant_matmul", "quant_matmul_pre", "quantize_weights_int8",
]
