"""Pallas TPU kernels for the IP2 compute hot-spots.

ip2_project — the analog patch-projection array's digital twin (fused PWM
quantize + MXU GEMM + charge-share/ADC epilogue); quant_matmul — w8a8
backend projections. ops.py = jit'd wrappers (padding, CPU interpret
fallback); ref.py = pure-jnp oracles every kernel is tested against.
"""

from repro.kernels.ops import (
    ip2_project,
    ip2_project_fn,
    quant_matmul,
    quantize_weights_int8,
)

__all__ = ["ip2_project", "ip2_project_fn", "quant_matmul", "quantize_weights_int8"]
