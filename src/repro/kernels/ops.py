"""Public jit'd wrappers around the Pallas kernels.

Handle padding to MXU-aligned blocks, batch flattening, weight
pre-quantization (the DAC programming step), and CPU fallback:
on non-TPU backends the wrappers run the kernels in interpret mode when
``interpret=None`` (auto), so the whole framework is runnable here while
the lowered TPU path keeps the real kernels.

Wire format (DESIGN.md §9): both projection wrappers accept
``codes=True`` (requires ``adc``) to emit the edge-ADC's integer codes
directly from the fused epilogue — the int8 payload the hardware streams —
instead of dequantized float32. The matching ``(scale, zero)`` metadata is
static, from :func:`repro.core.adc.readout_scale_zero`.

Energy accounting (DESIGN.md §10): the conversion count a wrapper's
fused-ADC epilogue performs is :func:`fused_adc_conversions` — M per
REAL input row. MXU padding rows (``block_p``/``block_r`` round-up) are a
simulator artifact: their epilogue outputs are sliced off before the
wrapper returns and the modeled hardware never converts them, so they are
never priced. Adapters expose the same count via ``fn.frame_conversions``
so the frontend's event ledger and the kernel's emitted payload cannot
drift (asserted in tests/test_power.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import projection as proj_mod
from repro.core import pwm as pwm_mod
from repro.kernels import ref
from repro.kernels.ip2_project import IP2KernelParams, ip2_project_pallas
from repro.kernels.ip2_project_sparse import ip2_project_sparse_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def fused_adc_conversions(n_rows, spec: proj_mod.PatchSpec, adc=None):
    """ADC conversions one projection call performs for ``n_rows`` real
    patch rows: M per row when a fused ADC epilogue runs (``adc`` given),
    0 otherwise (the caller's own readout converts, and must count).
    ``n_rows`` may be a traced array — the count is data, not shape.
    Padding rows never count (see module docstring)."""
    if adc is None:
        return 0 * n_rows
    return n_rows * spec.n_vectors


def kernel_params_from_spec(
    spec: proj_mod.PatchSpec, adc=None, codes: bool = False
) -> IP2KernelParams:
    if codes and adc is None:
        raise ValueError("codes=True requires an ADCSpec (the codes ARE the ADC output)")
    return IP2KernelParams(
        n2=spec.pixels_per_patch,
        pwm_levels=spec.quant.pwm_levels,
        droop=spec.summer.droop_factor(),
        v_ref=spec.summer.v_ref,
        nl_kind=spec.nl.kind if spec.nl.kind in ("relu",) else "none",
        v_sat=spec.nl.v_sat,
        adc_bits=adc.bits if adc is not None else 8,
        adc_vmin=adc.v_min if adc is not None else -1.0,
        adc_vmax=adc.v_max if adc is not None else 1.0,
        adc_enable=adc is not None,
        adc_out_codes=codes,
    )


def ip2_project(
    patches: jnp.ndarray,          # (..., P, N2) in [0,1]
    weights: jnp.ndarray,          # (M, N2) float (pre-DAC)
    spec: proj_mod.PatchSpec,
    adc=None,
    bias: jnp.ndarray | None = None,
    codes: bool = False,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-backed equivalent of core.projection.analog_project_patches
    (+ fused ADC readout when ``adc`` is given). Returns (..., P, M) —
    float32 readout, or the int code payload when ``codes=True`` (the bias
    then lives in the ``zero`` metadata, not the payload)."""
    m, n2 = weights.shape
    lead = patches.shape[:-1]
    flat = patches.reshape(-1, n2)
    # small row batches (the compact path's k rows, or the temporal gate's
    # j-stale rows — DESIGN.md §6) would otherwise pad up to a full
    # 128-row MXU tile; clamp to the sublane-aligned row count instead.
    block_p = max(8, min(block_p, -(-flat.shape[0] // 8) * 8))

    w_q, _ = pwm_mod.quantize_weights(weights, spec.quant)  # DAC programming
    w_t = w_q.T                                             # (N2, M)
    b = jnp.zeros((m,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    p_pad = _pad_to(flat.astype(jnp.float32), 0, block_p)
    k_in = _pad_to(p_pad, 1, block_k)
    w_pad = _pad_to(_pad_to(w_t.astype(jnp.float32), 0, block_k), 1, block_m)
    b_pad = _pad_to(b, 0, block_m)

    params = kernel_params_from_spec(spec, adc, codes)
    out = ip2_project_pallas(
        k_in, w_pad, b_pad, params,
        block_p=block_p, block_m=block_m, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
    out = out[: flat.shape[0], :m]
    return out.reshape(*lead, m)


def ip2_project_fn(spec: proj_mod.PatchSpec, **kw):
    """Adapter matching core.frontend.ProjectFn (no fused ADC: the frontend
    applies its own readout; used to drop the kernel into apply_frontend).
    Works on both frontend modes — in compact mode the frontend hands it
    the already-gathered (..., k, N2) active patches."""

    def fn(patches, weights, _spec):
        return ip2_project(patches, weights, _spec, adc=None, **kw)

    # no fused ADC: conversions happen in the caller's readout, not here
    fn.frame_conversions = lambda n_rows: fused_adc_conversions(n_rows, spec)
    return fn


def ip2_codes_fn(spec: proj_mod.PatchSpec, adc, **kw):
    """Adapter matching core.frontend.ProjectFn whose output is the wire
    format: int codes straight from the kernel's fused ADC epilogue
    (DESIGN.md §9). The frontend detects ``emits_codes`` and skips its own
    jnp re-quantization — the conversion happens exactly once, at the
    array edge, inside the kernel."""

    def fn(patches, weights, _spec):
        return ip2_project(patches, weights, _spec, adc=adc, codes=True, **kw)

    fn.emits_codes = True
    # the fused epilogue converts every real row's M outputs exactly once
    fn.frame_conversions = lambda n_rows: fused_adc_conversions(
        n_rows, spec, adc)
    return fn


def ip2_project_sparse(
    patches: jnp.ndarray,          # (..., P, N2) dense patch grid in [0,1]
    weights: jnp.ndarray,          # (M, N2) float (pre-DAC)
    indices: jnp.ndarray,          # (..., k) active patch indices
    spec: proj_mod.PatchSpec,
    adc=None,
    bias: jnp.ndarray | None = None,
    codes: bool = False,
    block_r: int | None = None,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Compact-first projection: compute features for ONLY the ``indices``
    rows of the dense patch grid (+ fused ADC readout when ``adc`` is
    given; int code payload when ``codes=True``). The gather happens inside
    the kernel via scalar-prefetched index_maps (DESIGN.md §3.2), so
    deselected patches cost no FLOPs and no VMEM traffic. Returns
    (..., k, M) in the order of ``indices``.

    ``block_r`` rows are batched per grid step (arbitrary, non-contiguous
    rows — selection stays patch-granular); ``None`` picks the
    sublane-aligned row count, mirroring ``ip2_project``'s ``block_p``
    clamp, so multi-row batches don't serialize one matmul per row.
    """
    m, n2 = weights.shape
    lead = patches.shape[:-2]
    n_patches = patches.shape[-2]
    if indices.shape[:-1] != lead:
        raise ValueError(f"indices lead {indices.shape[:-1]} != patches lead {lead}")
    k = indices.shape[-1]

    flat_p = patches.reshape(-1, n2).astype(jnp.float32)   # (B*P, N2)
    batch = flat_p.shape[0] // n_patches
    # fold the batch into the row index: row_idx addresses (B*P) dense rows
    offsets = jnp.arange(batch, dtype=jnp.int32) * n_patches
    flat_idx = (indices.reshape(batch, k).astype(jnp.int32) + offsets[:, None]).reshape(-1)
    flat_idx = jnp.clip(flat_idx, 0, flat_p.shape[0] - 1)

    n_rows = flat_idx.shape[0]
    if block_r is None:
        block_r = 8                       # sublane-aligned default
    block_r = max(1, min(block_r, n_rows))
    # pad the row table to a bank multiple with clipped duplicates (their
    # output rows are computed and discarded by the slice below)
    flat_idx = _pad_to(flat_idx, 0, block_r, value=0)

    w_q, _ = pwm_mod.quantize_weights(weights, spec.quant)  # DAC programming
    b = jnp.zeros((m,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    k_in = _pad_to(flat_p, 1, block_k)
    w_pad = _pad_to(_pad_to(w_q.T.astype(jnp.float32), 0, block_k), 1, block_m)
    b_pad = _pad_to(b, 0, block_m)

    params = kernel_params_from_spec(spec, adc, codes)
    out = ip2_project_sparse_pallas(
        flat_idx, k_in, w_pad, b_pad, params,
        block_r=block_r, block_m=block_m, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
    return out[:n_rows, :m].reshape(*lead, k, m)


def quant_matmul_pre(
    a8: jnp.ndarray,               # (..., K) int8 pre-quantized activations
    s_a: jnp.ndarray,              # (...,) float32 per-row scales
    w8: jnp.ndarray,               # (K, M) int8 codes
    s_w: jnp.ndarray,              # (M,) scales
    out_dtype=jnp.float32,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y = (a8 @ w8) * s_a * s_w for ALREADY-quantized activations.

    The ADC-code consumption entry (DESIGN.md §9): edge-ADC codes are the
    activation quantization — feeding them here incurs no second rounding.
    ``s_a`` broadcasts against the row dims of ``a8`` (a scalar works for
    the ADC's single static LSB scale)."""
    k, m = w8.shape
    lead = a8.shape[:-1]
    flat = a8.reshape(-1, k)
    s_flat = jnp.broadcast_to(jnp.asarray(s_a, jnp.float32), lead).reshape(-1)

    a_pad = _pad_to(_pad_to(flat, 0, block_p), 1, block_k)
    sa_pad = _pad_to(s_flat, 0, block_p)
    w_pad = _pad_to(_pad_to(w8, 0, block_k), 1, block_m)
    sw_pad = _pad_to(s_w.astype(jnp.float32), 0, block_m)

    out = quant_matmul_pallas(
        a_pad, sa_pad, w_pad, sw_pad,
        block_p=block_p, block_m=block_m, block_k=block_k,
        out_dtype=jnp.float32, interpret=_auto_interpret(interpret),
    )
    out = out[: flat.shape[0], :m].astype(out_dtype)
    return out.reshape(*lead, m)


def quant_matmul(
    a: jnp.ndarray,                # (..., K) float activations
    w8: jnp.ndarray,               # (K, M) int8 codes
    s_w: jnp.ndarray,              # (M,) scales
    out_dtype=None,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y = a @ dequant(w8): quantizes ``a`` per-row to int8 on the host
    (``ref.quantize_activations_ref``) and defers to
    :func:`quant_matmul_pre`. Activations that are already int8 codes
    (e.g. edge-ADC output) should call ``quant_matmul_pre`` directly."""
    out_dtype = out_dtype or a.dtype
    k, _ = w8.shape
    lead = a.shape[:-1]
    flat = a.reshape(-1, k)
    a8, s_a = ref.quantize_activations_ref(flat)
    out = quant_matmul_pre(
        a8, s_a, w8, s_w, out_dtype=out_dtype,
        block_p=block_p, block_m=block_m, block_k=block_k, interpret=interpret,
    )
    return out.reshape(*lead, w8.shape[1])


def quantize_weights_int8(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(K, M) float -> int8 codes + per-col scale (offline weight prep)."""
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    w8 = jnp.clip(jnp.round(w / scale[None, :]), -127, 127).astype(jnp.int8)
    return w8, scale.astype(jnp.float32)
