"""Public jit'd wrappers around the Pallas kernels.

Handle padding to MXU-aligned blocks, batch flattening, weight
pre-quantization (the DAC programming step), and CPU fallback:
on non-TPU backends the wrappers run the kernels in interpret mode when
``interpret=None`` (auto), so the whole framework is runnable here while
the lowered TPU path keeps the real kernels.

Wire format (DESIGN.md §9): both projection wrappers accept
``codes=True`` (requires ``adc``) to emit the edge-ADC's integer codes
directly from the fused epilogue — the int8 payload the hardware streams —
instead of dequantized float32. The matching ``(scale, zero)`` metadata is
static, from :func:`repro.core.adc.readout_scale_zero`.

Energy accounting (DESIGN.md §10): the conversion count a wrapper's
fused-ADC epilogue performs is :func:`fused_adc_conversions` — M per
REAL input row. MXU padding rows (``block_p``/``block_r`` round-up) are a
simulator artifact: their epilogue outputs are sliced off before the
wrapper returns and the modeled hardware never converts them, so they are
never priced. Adapters expose the same count via ``fn.frame_conversions``
so the frontend's event ledger and the kernel's emitted payload cannot
drift (asserted in tests/test_power.py).
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import projection as proj_mod
from repro.core import pwm as pwm_mod
from repro.kernels import ref
from repro.kernels.ip2_megakernel import (
    ip2_fused_embed_pallas,
    ip2_ragged_pallas,
)
from repro.kernels.ip2_project import IP2KernelParams, ip2_project_pallas
from repro.kernels.ip2_project_sparse import ip2_project_sparse_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas
from repro.kernels.vit_delta_attention import delta_attention_pallas


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


class ProgrammedWeights(NamedTuple):
    """Offline DAC-programmed projection weights (satellite of DESIGN.md
    §11): the output of :func:`repro.core.pwm.quantize_weights`, computed
    once at deploy time — the hardware programs its weight DACs once, not
    per exposure. Every projection wrapper accepts this in place of raw
    float ``weights`` and skips the per-call re-quantization; the per-call
    path stays as the fallback and is bitwise-equal (the STE grid is
    deterministic)."""

    w_q: jnp.ndarray     # (M, N2) float weights ON the DAC grid
    scale: jnp.ndarray   # per-output scale (diagnostic; kernels ignore it)


def program_weights(
    weights: jnp.ndarray, spec: proj_mod.PatchSpec
) -> ProgrammedWeights:
    """Offline DAC programming entry, mirroring ``vit.prepare_quant_embed``
    for the backend's embed weights: run the weight-DAC quantization once
    and reuse the programmed array across every projection call.
    Idempotent: already-programmed weights pass through unchanged (the DAC
    grid is a fixed point of its own quantizer)."""
    if isinstance(weights, ProgrammedWeights):
        return weights
    w_q, scale = pwm_mod.quantize_weights(weights, spec.quant)
    return ProgrammedWeights(w_q=w_q, scale=scale)


def _dac_weights(weights, spec: proj_mod.PatchSpec) -> jnp.ndarray:
    """Resolve raw-or-programmed weights to the DAC-grid array."""
    if isinstance(weights, ProgrammedWeights):
        return weights.w_q
    w_q, _ = pwm_mod.quantize_weights(weights, spec.quant)  # DAC programming
    return w_q


def fused_adc_conversions(n_rows, spec: proj_mod.PatchSpec, adc=None):
    """ADC conversions one projection call performs for ``n_rows`` real
    patch rows: M per row when a fused ADC epilogue runs (``adc`` given),
    0 otherwise (the caller's own readout converts, and must count).
    ``n_rows`` may be a traced array — the count is data, not shape.
    Padding rows never count (see module docstring)."""
    if adc is None:
        return 0 * n_rows
    return n_rows * spec.n_vectors


def fused_sign_comparisons(n_rows, spec: proj_mod.PatchSpec):
    """Comparator firings of one sign-readout projection call: one per
    (real row, vector) — the ADC-less counterpart of
    :func:`fused_adc_conversions` (priced as ``sign_comparisons``, not
    ``adc_conversions``, DESIGN.md §13)."""
    return n_rows * spec.n_vectors


def kernel_params_from_spec(
    spec: proj_mod.PatchSpec, adc=None, codes: bool = False,
    readout: str = "adc",
) -> IP2KernelParams:
    if codes and adc is None:
        raise ValueError("codes=True requires an ADCSpec (the codes ARE the ADC output)")
    if readout == "sign" and codes:
        raise ValueError(
            "readout='sign' emits the 1-bit sign wire; the int code wire "
            "(codes=True) only exists on the ADC readout"
        )
    return IP2KernelParams(
        readout=readout,
        n2=spec.pixels_per_patch,
        pwm_levels=spec.quant.pwm_levels,
        droop=spec.summer.droop_factor(),
        v_ref=spec.summer.v_ref,
        nl_kind=spec.nl.kind if spec.nl.kind in ("relu",) else "none",
        v_sat=spec.nl.v_sat,
        adc_bits=adc.bits if adc is not None else 8,
        adc_vmin=adc.v_min if adc is not None else -1.0,
        adc_vmax=adc.v_max if adc is not None else 1.0,
        adc_enable=adc is not None,
        adc_out_codes=codes,
    )


def ip2_project(
    patches: jnp.ndarray,          # (..., P, N2) in [0,1]
    weights: jnp.ndarray,          # (M, N2) float (pre-DAC)
    spec: proj_mod.PatchSpec,
    adc=None,
    bias: jnp.ndarray | None = None,
    codes: bool = False,
    readout: str = "adc",
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-backed equivalent of core.projection.analog_project_patches
    (+ fused ADC readout when ``adc`` is given). Returns (..., P, M) —
    float32 readout, or the int code payload when ``codes=True`` (the bias
    then lives in the ``zero`` metadata, not the payload), or the bool
    sign wire when ``readout="sign"`` (DESIGN.md §13; metadata from
    :func:`repro.core.adc.sign_scale_zero`)."""
    w_q = _dac_weights(weights, spec)
    m, n2 = w_q.shape
    lead = patches.shape[:-1]
    flat = patches.reshape(-1, n2)
    # small row batches (the compact path's k rows, or the temporal gate's
    # j-stale rows — DESIGN.md §6) would otherwise pad up to a full
    # 128-row MXU tile; clamp to the sublane-aligned row count instead.
    block_p = max(8, min(block_p, -(-flat.shape[0] // 8) * 8))

    w_t = w_q.T                                             # (N2, M)
    b = jnp.zeros((m,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    p_pad = _pad_to(flat.astype(jnp.float32), 0, block_p)
    k_in = _pad_to(p_pad, 1, block_k)
    w_pad = _pad_to(_pad_to(w_t.astype(jnp.float32), 0, block_k), 1, block_m)
    b_pad = _pad_to(b, 0, block_m)

    params = kernel_params_from_spec(spec, adc, codes, readout)
    out = ip2_project_pallas(
        k_in, w_pad, b_pad, params,
        block_p=block_p, block_m=block_m, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
    out = out[: flat.shape[0], :m]
    if readout == "sign":
        out = out.astype(bool)     # kernels emit int8 {0,1}; the wire is 1-bit
    return out.reshape(*lead, m)


def _identity_indices(patches: jnp.ndarray) -> jnp.ndarray:
    """(..., j, N2) gathered patches -> (..., j) identity row indices, the
    ragged adapter path's selection (rows are already in slot order)."""
    j = patches.shape[-2]
    return jnp.broadcast_to(
        jnp.arange(j, dtype=jnp.int32), patches.shape[:-2] + (j,)
    )


def ip2_project_fn(spec: proj_mod.PatchSpec, programmed=None, **kw):
    """Adapter matching core.frontend.ProjectFn (no fused ADC: the frontend
    applies its own readout; used to drop the kernel into apply_frontend).
    Works on both frontend modes — in compact mode the frontend hands it
    the already-gathered (..., k, N2) active patches.

    ``programmed``: optional :class:`ProgrammedWeights` to use instead of
    DAC-quantizing the passed weights on every call (offline programming).

    Ragged k (DESIGN.md §11): the frontend passes ``row_counts`` when it
    knows how many leading rows per slot are real; the adapter then routes
    through the ragged megakernel so shed rows cost zero FLOPs/bytes.
    Rows at positions >= their slot's count come back ZERO."""

    def fn(patches, weights, _spec, row_counts=None):
        w = programmed if programmed is not None else weights
        if row_counts is None:
            return ip2_project(patches, w, _spec, adc=None, **kw)
        return ip2_project_sparse(
            patches, w, _identity_indices(patches), _spec, adc=None,
            row_counts=row_counts, **kw)

    fn.supports_row_counts = True
    # no fused ADC: conversions happen in the caller's readout, not here
    fn.frame_conversions = lambda n_rows: fused_adc_conversions(n_rows, spec)
    return fn


def ip2_codes_fn(spec: proj_mod.PatchSpec, adc, programmed=None, **kw):
    """Adapter matching core.frontend.ProjectFn whose output is the wire
    format: int codes straight from the kernel's fused ADC epilogue
    (DESIGN.md §9). The frontend detects ``emits_codes`` and skips its own
    jnp re-quantization — the conversion happens exactly once, at the
    array edge, inside the kernel. ``programmed``/``row_counts`` as in
    :func:`ip2_project_fn` (shed rows are ZERO codes; the ledger's
    ``frame_conversions`` is priced on real rows by the caller)."""

    def fn(patches, weights, _spec, row_counts=None):
        w = programmed if programmed is not None else weights
        if row_counts is None:
            return ip2_project(patches, w, _spec, adc=adc, codes=True, **kw)
        return ip2_project_sparse(
            patches, w, _identity_indices(patches), _spec, adc=adc,
            codes=True, row_counts=row_counts, **kw)

    fn.supports_row_counts = True
    fn.emits_codes = True
    # the fused epilogue converts every real row's M outputs exactly once
    fn.frame_conversions = lambda n_rows: fused_adc_conversions(
        n_rows, spec, adc)
    return fn


def ip2_sign_fn(spec: proj_mod.PatchSpec, programmed=None, **kw):
    """Adapter matching core.frontend.ProjectFn whose output is the 1-bit
    sign wire (DESIGN.md §13): bool comparator bits straight from the
    kernel's ADC-less epilogue. The frontend detects ``emits_sign`` and
    attaches :func:`repro.core.adc.sign_scale_zero` metadata instead of the
    ADC affine. ``programmed``/``row_counts`` as in :func:`ip2_project_fn`
    (shed rows come back as bit 0 with gain 0)."""

    def fn(patches, weights, _spec, row_counts=None):
        w = programmed if programmed is not None else weights
        if row_counts is None:
            return ip2_project(patches, w, _spec, readout="sign", **kw)
        return ip2_project_sparse(
            patches, w, _identity_indices(patches), _spec,
            readout="sign", row_counts=row_counts, **kw)

    fn.supports_row_counts = True
    fn.emits_sign = True
    # no ADC ramp runs: the epilogue fires one comparator per (row, vector)
    fn.frame_conversions = lambda n_rows: fused_adc_conversions(n_rows, spec)
    fn.frame_sign_comparisons = lambda n_rows: fused_sign_comparisons(
        n_rows, spec)
    return fn


def ip2_conv(
    frame: jnp.ndarray,            # (H, W) or (B, H, W) pixel voltages [0,1]
    weights: jnp.ndarray,          # (C, K²) float (pre-DAC) or ProgrammedWeights
    conv: proj_mod.ConvSpec,
    adc=None,
    bias: jnp.ndarray | None = None,
    codes: bool = False,
    readout: str = "adc",
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Conv-in-pixel mode (DESIGN.md §13): strided K×K in-pixel convolution
    reusing the PWM/charge-share projection fabric — the frame's windows
    are the 'patches' (``extract_windows``), the C output channels are the
    'vectors', and the whole mode-selectable epilogue (fused ADC, code
    wire, sign readout) applies per window. Returns (..., gh·gw, C) in
    row-major window order, dtype per the chosen epilogue.

    The energy difference from patch-bank projection is the weight DAC:
    conv holds ONE K²×C kernel bank, so a static kernel is programmed once
    at deploy (``dac_reprograms`` ≈ 0 per frame) while cycling kernels
    through the bank reprograms per frame — priced by
    :func:`repro.core.power.conv_frame_events`, never by this wrapper."""
    windows = proj_mod.extract_windows(frame, conv.kernel, conv.stride)
    return ip2_project(
        windows, weights, conv.patch_spec(), adc=adc, bias=bias,
        codes=codes, readout=readout, block_m=block_m, block_k=block_k,
        interpret=interpret,
    )


def _ragged_tables(
    indices: jnp.ndarray,          # (..., k) active patch indices
    n_patches: int,
    row_counts,                    # scalar/broadcastable int counts, or None
    block_r: int,
):
    """Slot-major tables for the ragged megakernel entries.

    Returns ``(table, counts, n_banks)`` where ``table`` is
    (slots * n_banks * block_r,) int32 dense row indices — slot s's k
    indices (batch offset folded in), extended to a whole number of
    ``block_r`` banks by repeating the slot's LAST index (the clamp the
    kernel's row index_maps apply anyway, so the pipeliner sees unchanged
    block indices on pad rows and elides their copies) — and ``counts`` is
    (slots,) int32 real-row counts clipped to [0, k]. Counts are DATA:
    block shapes and the table length depend only on k, so one compile
    serves every governor tier."""
    lead = indices.shape[:-1]
    k = indices.shape[-1]
    idx2 = indices.reshape(-1, k).astype(jnp.int32)
    batch = idx2.shape[0]
    offsets = jnp.arange(batch, dtype=jnp.int32) * n_patches
    flat2 = jnp.clip(idx2 + offsets[:, None], 0, batch * n_patches - 1)
    n_banks = -(-k // block_r)
    rps = n_banks * block_r
    pos = jnp.minimum(jnp.arange(rps), k - 1)
    table = flat2[:, pos].reshape(-1)
    if row_counts is None:
        counts = jnp.full((batch,), k, jnp.int32)
    else:
        counts = jnp.broadcast_to(jnp.asarray(row_counts), lead)
        counts = jnp.clip(counts.reshape(-1).astype(jnp.int32), 0, k)
    return table, counts, n_banks


def _mask_ragged_rows(out, counts, k):
    """Zero rows at positions >= their slot's count. The kernel already
    zeroes whole inactive banks; this masks the partial last active bank,
    whose tail rows hold clamped duplicates of the slot's last real row —
    making 'rows past counts are zero' exact per row."""
    mask = jnp.arange(k, dtype=jnp.int32)[None, :] < counts[:, None]
    return jnp.where(mask[..., None], out, jnp.zeros((), out.dtype))


def ip2_project_sparse(
    patches: jnp.ndarray,          # (..., P, N2) dense patch grid in [0,1]
    weights: jnp.ndarray,          # (M, N2) float (pre-DAC) or ProgrammedWeights
    indices: jnp.ndarray,          # (..., k) active patch indices
    spec: proj_mod.PatchSpec,
    adc=None,
    bias: jnp.ndarray | None = None,
    codes: bool = False,
    readout: str = "adc",
    row_counts=None,               # (...,) int real rows per slot, or None
    block_r: int | None = None,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Compact-first projection: compute features for ONLY the ``indices``
    rows of the dense patch grid (+ fused ADC readout when ``adc`` is
    given; int code payload when ``codes=True``). The gather happens inside
    the kernel via scalar-prefetched index_maps (DESIGN.md §3.2), so
    deselected patches cost no FLOPs and no VMEM traffic. Returns
    (..., k, M) in the order of ``indices``.

    ``row_counts`` (DESIGN.md §11) switches to the ragged megakernel: per
    batch slot, only the leading ``row_counts`` rows of ``indices`` are
    computed — banks of ``block_r`` rows past a slot's count skip the MXU
    and their DMAs are elided, so governor-shed tokens cost zero FLOPs and
    zero VMEM traffic (not masked-but-computed work). Counts are data
    (one compile across tiers); rows at positions >= the count return
    ZERO. With ``row_counts=None`` the dense-k sparse kernel runs and
    output is bitwise-identical to the ragged path at full counts.

    ``block_r`` rows are batched per grid step (arbitrary, non-contiguous
    rows — selection stays patch-granular); ``None`` picks the
    sublane-aligned row count, mirroring ``ip2_project``'s ``block_p``
    clamp, so multi-row batches don't serialize one matmul per row.
    """
    w_q = _dac_weights(weights, spec)
    m, n2 = w_q.shape
    lead = patches.shape[:-2]
    n_patches = patches.shape[-2]
    if indices.shape[:-1] != lead:
        raise ValueError(f"indices lead {indices.shape[:-1]} != patches lead {lead}")
    k = indices.shape[-1]

    flat_p = patches.reshape(-1, n2).astype(jnp.float32)   # (B*P, N2)
    batch = flat_p.shape[0] // n_patches

    b = jnp.zeros((m,), jnp.float32) if bias is None else bias.astype(jnp.float32)
    k_in = _pad_to(flat_p, 1, block_k)
    w_pad = _pad_to(_pad_to(w_q.T.astype(jnp.float32), 0, block_k), 1, block_m)
    b_pad = _pad_to(b, 0, block_m)
    params = kernel_params_from_spec(spec, adc, codes, readout)

    if row_counts is not None:
        br = 8 if block_r is None else block_r
        br = max(1, min(br, k))
        table, counts, n_banks = _ragged_tables(indices, n_patches, row_counts, br)
        out = ip2_ragged_pallas(
            table, counts, k_in, w_pad, b_pad, params, n_banks=n_banks,
            block_r=br, block_m=block_m, block_k=block_k,
            interpret=_auto_interpret(interpret),
        )
        out = out.reshape(batch, n_banks * br, -1)[:, :k, :m]
        out = _mask_ragged_rows(out, counts, k)
        if readout == "sign":
            out = out.astype(bool)
        return out.reshape(*lead, k, m)

    # fold the batch into the row index: row_idx addresses (B*P) dense rows
    offsets = jnp.arange(batch, dtype=jnp.int32) * n_patches
    flat_idx = (indices.reshape(batch, k).astype(jnp.int32) + offsets[:, None]).reshape(-1)
    flat_idx = jnp.clip(flat_idx, 0, flat_p.shape[0] - 1)

    n_rows = flat_idx.shape[0]
    if block_r is None:
        block_r = 8                       # sublane-aligned default
    block_r = max(1, min(block_r, n_rows))
    # pad the row table to a bank multiple with clipped duplicates (their
    # output rows are computed and discarded by the slice below)
    flat_idx = _pad_to(flat_idx, 0, block_r, value=0)

    out = ip2_project_sparse_pallas(
        flat_idx, k_in, w_pad, b_pad, params,
        block_r=block_r, block_m=block_m, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
    out = out[:n_rows, :m]
    if readout == "sign":
        out = out.astype(bool)
    return out.reshape(*lead, k, m)


def ip2_fused_embed(
    patches: jnp.ndarray,          # (..., P, N2) dense patch grid in [0,1]
    weights: jnp.ndarray,          # (M, N2) float (pre-DAC) or ProgrammedWeights
    indices: jnp.ndarray,          # (..., k) active patch indices
    spec: proj_mod.PatchSpec,
    adc,                           # ADCSpec — the fused seam IS code space
    w8: jnp.ndarray,               # (M, D) int8 embed weight codes
    s_w: jnp.ndarray,              # (D,) float32 per-col embed scales
    row_counts=None,               # (...,) int real rows per slot, or None
    block_r: int = 8,
    block_m: int | None = None,    # None = roofline pick: m_steps=1 up to 512
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Fused frontend megakernel (DESIGN.md §11): projection + fused ADC +
    the backend's w8a8 first-layer embed matmul in ONE kernel — the int8
    codes go straight from the epilogue's VMEM scratch into the MXU,
    never round-tripping through HBM between frontend and backend.

    Returns (..., k, D) float32 — the ``y = (codes @ w8) * lsb * s_w``
    term of ``vit._embed_tokens``'s quant-embed affine, bitwise-equal the
    staged ``ip2_project_sparse(codes=True)`` → ``quant_matmul_pre`` path
    for the same selection (asserted in tests/test_megakernel.py). The
    caller adds :func:`fused_embed_zero_term` and the per-token gain
    exactly as the staged path does. ``row_counts`` behaves as in
    :func:`ip2_project_sparse` (shed rows are zero).
    """
    if adc is None:
        raise ValueError("ip2_fused_embed requires an ADCSpec: the fused "
                         "seam only exists in ADC code space (DESIGN.md §9)")
    w_q = _dac_weights(weights, spec)
    m, n2 = w_q.shape
    if w8.shape[0] != m:
        raise ValueError(f"embed rows {w8.shape[0]} != n_vectors {m}")
    d = w8.shape[1]
    lead = patches.shape[:-2]
    n_patches = patches.shape[-2]
    if indices.shape[:-1] != lead:
        raise ValueError(f"indices lead {indices.shape[:-1]} != patches lead {lead}")
    k = indices.shape[-1]

    flat_p = patches.reshape(-1, n2).astype(jnp.float32)
    batch = flat_p.shape[0] // n_patches
    br = max(1, min(block_r, k))
    table, counts, n_banks = _ragged_tables(indices, n_patches, row_counts, br)

    # roofline-picked default (benchmarks/bench_roofline.py): one vector-bank
    # step per row bank (m_steps=1 up to a 512-lane block) minimizes grid
    # steps — each extra m step re-gathers every patch-row block
    if block_m is None:
        block_m = min(512, -(-m // 128) * 128)

    k_in = _pad_to(flat_p, 1, block_k)
    w_pad = _pad_to(_pad_to(w_q.T.astype(jnp.float32), 0, block_k), 1, block_m)
    # embed weight pad rows MUST be zero: projection pad columns carry junk
    # codes (epilogue of an empty accumulator) and the zero rows annihilate
    # them exactly in the int32 sum — the bitwise-parity keystone.
    w8_pad = _pad_to(_pad_to(w8, 0, block_m, value=0), 1, 128, value=0)
    sw_pad = _pad_to(s_w.astype(jnp.float32), 0, 128)

    # per-row activation scale = the ADC's single static LSB, materialized
    # as a buffer so the kernel epilogue multiplies in quant_matmul order
    sa_rows = jnp.full((table.shape[0],), adc.lsb, jnp.float32)

    params = kernel_params_from_spec(spec, adc, codes=True)
    out = ip2_fused_embed_pallas(
        table, counts, k_in, w_pad, w8_pad, sw_pad, sa_rows, params,
        n_banks=n_banks, block_r=br, block_m=block_m, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
    out = out.reshape(batch, n_banks * br, -1)[:, :k, :d]
    if row_counts is not None:
        out = _mask_ragged_rows(out, counts, k)
    return out.reshape(*lead, k, d)


def fused_embed_zero_term(zero, w8: jnp.ndarray, s_w: jnp.ndarray):
    """The affine ``zero @ dequant(w8)`` term the fused kernel does NOT
    compute (it is selection-independent): identical expression to
    ``vit._embed_tokens``'s staged ``zero_term`` so fused = staged holds
    bitwise. ``zero`` broadcasts over (..., M)."""
    return zero @ (w8.astype(jnp.float32) * s_w[None, :])


def quant_matmul_pre(
    a8: jnp.ndarray,               # (..., K) int8 pre-quantized activations
    s_a: jnp.ndarray,              # (...,) float32 per-row scales
    w8: jnp.ndarray,               # (K, M) int8 codes
    s_w: jnp.ndarray,              # (M,) scales
    out_dtype=jnp.float32,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y = (a8 @ w8) * s_a * s_w for ALREADY-quantized activations.

    The ADC-code consumption entry (DESIGN.md §9): edge-ADC codes are the
    activation quantization — feeding them here incurs no second rounding.
    ``s_a`` broadcasts against the row dims of ``a8`` (a scalar works for
    the ADC's single static LSB scale)."""
    k, m = w8.shape
    lead = a8.shape[:-1]
    flat = a8.reshape(-1, k)
    s_flat = jnp.broadcast_to(jnp.asarray(s_a, jnp.float32), lead).reshape(-1)

    a_pad = _pad_to(_pad_to(flat, 0, block_p), 1, block_k)
    sa_pad = _pad_to(s_flat, 0, block_p)
    w_pad = _pad_to(_pad_to(w8, 0, block_k), 1, block_m)
    sw_pad = _pad_to(s_w.astype(jnp.float32), 0, block_m)

    # thread the requested out_dtype into the kernel: the epilogue casts
    # from its f32 accumulator exactly once, so bf16 consumers don't pay a
    # second materialization (accumulation itself stays int32 -> f32)
    out = quant_matmul_pallas(
        a_pad, sa_pad, w_pad, sw_pad,
        block_p=block_p, block_m=block_m, block_k=block_k,
        out_dtype=out_dtype, interpret=_auto_interpret(interpret),
    )
    out = out[: flat.shape[0], :m]
    return out.reshape(*lead, m)


def quant_matmul(
    a: jnp.ndarray,                # (..., K) float activations
    w8: jnp.ndarray,               # (K, M) int8 codes
    s_w: jnp.ndarray,              # (M,) scales
    out_dtype=None,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y = a @ dequant(w8): quantizes ``a`` per-row to int8 on the host
    (``ref.quantize_activations_ref``) and defers to
    :func:`quant_matmul_pre`. Activations that are already int8 codes
    (e.g. edge-ADC output) should call ``quant_matmul_pre`` directly."""
    out_dtype = out_dtype or a.dtype
    k, _ = w8.shape
    lead = a.shape[:-1]
    flat = a.reshape(-1, k)
    a8, s_a = ref.quantize_activations_ref(flat)
    out = quant_matmul_pre(
        a8, s_a, w8, s_w, out_dtype=out_dtype,
        block_p=block_p, block_m=block_m, block_k=block_k, interpret=interpret,
    )
    return out.reshape(*lead, w8.shape[1])


def quantize_weights_int8(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(K, M) float -> int8 codes + per-col scale (offline weight prep)."""
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    w8 = jnp.clip(jnp.round(w / scale[None, :]), -127, 127).astype(jnp.int8)
    return w8, scale.astype(jnp.float32)


def delta_attention(
    attn_params: dict,
    h: jnp.ndarray,                # (B, S, d) normed layer input
    token_valid: jnp.ndarray,      # (B, S) bool key mask
    q_counts: jnp.ndarray,         # (B,) int32 stale prefix length (DATA)
    n_heads: int,
    block_q: int = 8,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Ragged stale-Q attention for the delta-gated backend (DESIGN.md
    §14): Q/K/V projections in plain einsums (per-row work — XLA handles
    it), then the Pallas kernel scores ONLY the ``q_counts`` stale query
    rows per slot against the full key set, then the output projection.
    Rows past a slot's count come back zero; the delta gate keeps their
    cached values, so they never reach the residual stream."""
    del n_heads  # shape-carried by the projection weights
    q = jnp.einsum("bsd,dhk->bshk", h, attn_params["wq"]) + attn_params["bq"]
    k = jnp.einsum("bsd,dhk->bshk", h, attn_params["wk"]) + attn_params["bk"]
    v = jnp.einsum("bsd,dhk->bshk", h, attn_params["wv"]) + attn_params["bv"]
    o = delta_attention_pallas(
        q, k, v, token_valid, q_counts,
        block_q=block_q, interpret=_auto_interpret(interpret),
    )
    return jnp.einsum("bshk,hkd->bsd", o, attn_params["wo"])
