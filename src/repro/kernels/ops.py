"""Public jit'd wrappers around the Pallas kernels.

Handle padding to MXU-aligned blocks, batch flattening, weight
pre-quantization (the DAC programming step), and CPU fallback:
on non-TPU backends the wrappers run the kernels in interpret mode when
``interpret=None`` (auto), so the whole framework is runnable here while
the lowered TPU path keeps the real kernels.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import projection as proj_mod
from repro.core import pwm as pwm_mod
from repro.kernels import ref
from repro.kernels.ip2_project import IP2KernelParams, ip2_project_pallas
from repro.kernels.ip2_project_sparse import ip2_project_sparse_pallas
from repro.kernels.quant_matmul import quant_matmul_pallas


def _auto_interpret(interpret: bool | None) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _pad_to(x: jnp.ndarray, axis: int, mult: int, value=0.0) -> jnp.ndarray:
    size = x.shape[axis]
    pad = (-size) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


def kernel_params_from_spec(spec: proj_mod.PatchSpec, adc=None) -> IP2KernelParams:
    return IP2KernelParams(
        n2=spec.pixels_per_patch,
        pwm_levels=spec.quant.pwm_levels,
        droop=spec.summer.droop_factor(),
        v_ref=spec.summer.v_ref,
        nl_kind=spec.nl.kind if spec.nl.kind in ("relu",) else "none",
        v_sat=spec.nl.v_sat,
        adc_bits=adc.bits if adc is not None else 8,
        adc_vmin=adc.v_min if adc is not None else -1.0,
        adc_vmax=adc.v_max if adc is not None else 1.0,
        adc_enable=adc is not None,
    )


def ip2_project(
    patches: jnp.ndarray,          # (..., P, N2) in [0,1]
    weights: jnp.ndarray,          # (M, N2) float (pre-DAC)
    spec: proj_mod.PatchSpec,
    adc=None,
    bias: jnp.ndarray | None = None,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Kernel-backed equivalent of core.projection.analog_project_patches
    (+ fused ADC readout when ``adc`` is given). Returns (..., P, M)."""
    m, n2 = weights.shape
    lead = patches.shape[:-1]
    flat = patches.reshape(-1, n2)
    # small row batches (the compact path's k rows, or the temporal gate's
    # j-stale rows — DESIGN.md §6) would otherwise pad up to a full
    # 128-row MXU tile; clamp to the sublane-aligned row count instead.
    block_p = max(8, min(block_p, -(-flat.shape[0] // 8) * 8))

    w_q, _ = pwm_mod.quantize_weights(weights, spec.quant)  # DAC programming
    w_t = w_q.T                                             # (N2, M)
    b = jnp.zeros((m,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    p_pad = _pad_to(flat.astype(jnp.float32), 0, block_p)
    k_in = _pad_to(p_pad, 1, block_k)
    w_pad = _pad_to(_pad_to(w_t.astype(jnp.float32), 0, block_k), 1, block_m)
    b_pad = _pad_to(b, 0, block_m)

    params = kernel_params_from_spec(spec, adc)
    out = ip2_project_pallas(
        k_in, w_pad, b_pad, params,
        block_p=block_p, block_m=block_m, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
    out = out[: flat.shape[0], :m]
    return out.reshape(*lead, m)


def ip2_project_fn(spec: proj_mod.PatchSpec, **kw):
    """Adapter matching core.frontend.ProjectFn (no fused ADC: the frontend
    applies its own readout; used to drop the kernel into apply_frontend).
    Works on both frontend modes — in compact mode the frontend hands it
    the already-gathered (..., k, N2) active patches."""

    def fn(patches, weights, _spec):
        return ip2_project(patches, weights, _spec, adc=None, **kw)

    return fn


def ip2_project_sparse(
    patches: jnp.ndarray,          # (..., P, N2) dense patch grid in [0,1]
    weights: jnp.ndarray,          # (M, N2) float (pre-DAC)
    indices: jnp.ndarray,          # (..., k) active patch indices
    spec: proj_mod.PatchSpec,
    adc=None,
    bias: jnp.ndarray | None = None,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """Compact-first projection: compute features for ONLY the ``indices``
    rows of the dense patch grid (+ fused ADC readout when ``adc`` is
    given). The gather happens inside the kernel via scalar-prefetched
    index_maps (DESIGN.md §3.2), so deselected patches cost no FLOPs and no
    VMEM traffic. Returns (..., k, M) in the order of ``indices``.
    """
    m, n2 = weights.shape
    lead = patches.shape[:-2]
    n_patches = patches.shape[-2]
    if indices.shape[:-1] != lead:
        raise ValueError(f"indices lead {indices.shape[:-1]} != patches lead {lead}")
    k = indices.shape[-1]

    flat_p = patches.reshape(-1, n2).astype(jnp.float32)   # (B*P, N2)
    batch = flat_p.shape[0] // n_patches
    # fold the batch into the row index: bank_idx addresses (B*P) dense rows
    offsets = jnp.arange(batch, dtype=jnp.int32) * n_patches
    flat_idx = (indices.reshape(batch, k).astype(jnp.int32) + offsets[:, None]).reshape(-1)
    flat_idx = jnp.clip(flat_idx, 0, flat_p.shape[0] - 1)

    w_q, _ = pwm_mod.quantize_weights(weights, spec.quant)  # DAC programming
    b = jnp.zeros((m,), jnp.float32) if bias is None else bias.astype(jnp.float32)

    k_in = _pad_to(flat_p, 1, block_k)
    w_pad = _pad_to(_pad_to(w_q.T.astype(jnp.float32), 0, block_k), 1, block_m)
    b_pad = _pad_to(b, 0, block_m)

    params = kernel_params_from_spec(spec, adc)
    out = ip2_project_sparse_pallas(
        flat_idx, k_in, w_pad, b_pad, params,
        block_r=1, block_m=block_m, block_k=block_k,
        interpret=_auto_interpret(interpret),
    )
    return out[:, :m].reshape(*lead, k, m)


def quant_matmul(
    a: jnp.ndarray,                # (..., K) float activations
    w8: jnp.ndarray,               # (K, M) int8 codes
    s_w: jnp.ndarray,              # (M,) scales
    out_dtype=None,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jnp.ndarray:
    """y = a @ dequant(w8) with in-kernel per-row int8 activation quant."""
    out_dtype = out_dtype or a.dtype
    k, m = w8.shape
    lead = a.shape[:-1]
    flat = a.reshape(-1, k)
    a8, s_a = ref.quantize_activations_ref(flat)

    a_pad = _pad_to(_pad_to(a8, 0, block_p), 1, block_k)
    sa_pad = _pad_to(s_a, 0, block_p)
    w_pad = _pad_to(_pad_to(w8, 0, block_k), 1, block_m)
    sw_pad = _pad_to(s_w.astype(jnp.float32), 0, block_m)

    out = quant_matmul_pallas(
        a_pad, sa_pad, w_pad, sw_pad,
        block_p=block_p, block_m=block_m, block_k=block_k,
        out_dtype=jnp.float32, interpret=_auto_interpret(interpret),
    )
    out = out[: flat.shape[0], :m].astype(out_dtype)
    return out.reshape(*lead, m)


def quantize_weights_int8(w: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(K, M) float -> int8 codes + per-col scale (offline weight prep)."""
    amax = jnp.max(jnp.abs(w), axis=0)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    w8 = jnp.clip(jnp.round(w / scale[None, :]), -127, 127).astype(jnp.int8)
    return w8, scale.astype(jnp.float32)
