"""Pallas TPU megakernel — fused frontend with ragged per-slot k (DESIGN.md §11).

Two entries share one slot-major ragged banking scheme:

* :func:`ip2_ragged_pallas` — the sparse projection of
  ``ip2_project_sparse_pallas`` re-gridded with an explicit SLOT axis and a
  scalar-prefetched per-slot ROW-COUNT table. Grid = (slots, row banks per
  slot, vector banks, K banks); a row bank is *active* iff its first row
  index is below its slot's count. Inactive banks skip the MXU entirely
  (``pl.when``) and their patch/weight index_maps collapse onto the
  previous block index, so Pallas' pipeliner elides the DMA copies — shed
  rows cost zero FLOPs and zero VMEM traffic, not masked-but-computed
  work. Raggedness is therefore quantized to ``block_r`` (one sublane-
  aligned bank), and the counts are DATA: one compile serves every
  per-slot count the governor's ``k_eff`` tiers can produce.

* :func:`ip2_fused_embed_pallas` — the full frontend seam in one kernel:
  scalar-prefetched gather of the active patch rows, PWM / charge-share
  projection, fused edge-ADC epilogue, and the w8a8 first-layer embed
  matmul of the backend — ``(codes @ W8) * lsb * s_w`` — consuming the
  int8 codes straight out of a VMEM scratch. The codes never round-trip
  through HBM between the frontend and the backend's first matmul
  (DESIGN.md §9's one-dequant-site contract holds: the epilogue here IS
  that site, bit-for-bit the arithmetic of ``quant_matmul_pallas``).

Bitwise contract (asserted in tests/test_megakernel.py): for the same
selection, the fused output equals the staged
``ip2_project_sparse(codes=True)`` → ``quant_matmul_pre`` path exactly —
same ``adc._code_grid`` epilogue, same int32 accumulation, same
``acc_f32 * s_a * s_w`` multiply order. Rows at positions >= their slot's
count are zero (the ops wrappers additionally mask the partial bank's
clamped-duplicate rows, so the contract is exact per row).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.ip2_project import (
    COMPILER_PARAMS_CLS,
    IP2KernelParams,
    analog_epilogue_tile,
    pwm_quantize_tile,
)


def _bank_active(i, s, cnt_ref, block_r):
    """A row bank computes iff its first row position is a real row of its
    slot — the ragged-k predicate shared by the kernel bodies."""
    return (i * block_r) < cnt_ref[s]


def _row_map(r, rows_per_slot, block_r):
    """Gather index_map for row slot ``r`` of a bank: clamp the position
    into the slot's VALID prefix (``min(pos, cnt-1)``) so every row of an
    inactive bank maps to the same dense row as the slot's last real row —
    consecutive inactive grid steps then present an unchanged block index
    and the pipeliner elides their copies (zero VMEM traffic)."""

    def m(s, i, j, k, idx, cnt):
        lim = jnp.maximum(jnp.minimum(cnt[s], rows_per_slot) - 1, 0)
        pos = jnp.minimum(i * block_r + r, lim)
        return (idx[s * rows_per_slot + pos], k)

    return m


def _w_map(block_r):
    """Weight index_map: inactive banks pin the block to (0, 0) so their
    steps stream no weight bytes either (same elision mechanism)."""

    def m(s, i, j, k, idx, cnt):
        act = (i * block_r) < cnt[s]
        return (jnp.where(act, k, 0), jnp.where(act, j, 0))

    return m


# ---------------------------------------------------------------------------
# ragged sparse projection
# ---------------------------------------------------------------------------

def _ragged_kernel(
    idx_ref, cnt_ref, *refs, p: IP2KernelParams, k_steps: int, block_r: int
):
    """Grid = (slots, row banks, vector banks, K banks); K innermost."""
    del idx_ref  # consumed by the index_maps, not the body
    x_refs = refs[:block_r]
    w_ref, b_ref, o_ref, acc_ref = refs[block_r:]
    s, i = pl.program_id(0), pl.program_id(1)
    act = _bank_active(i, s, cnt_ref, block_r)

    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(act)
    def _mac():
        x = jnp.concatenate([r[...] for r in x_refs], axis=0)
        acc_ref[...] += jnp.dot(
            pwm_quantize_tile(x, p), w_ref[...],
            preferred_element_type=jnp.float32,
        )

    @pl.when(pl.program_id(3) == k_steps - 1)
    def _epilogue():
        out = analog_epilogue_tile(acc_ref[...], b_ref[...], p)
        # inactive banks write zeros: shed rows are defined, never garbage
        o_ref[...] = jnp.where(act, out, 0.0).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "n_banks", "block_r", "block_m", "block_k",
                     "interpret"),
)
def ip2_ragged_pallas(
    row_idx: jnp.ndarray,     # (S * n_banks * block_r,) int32 dense row table
    row_counts: jnp.ndarray,  # (S,) int32 — real rows per slot (DATA)
    patches: jnp.ndarray,     # (P_rows, K) dense pixel voltages in [0,1]
    w_q: jnp.ndarray,         # (K, M) DAC-quantized weights
    bias: jnp.ndarray,        # (M,)
    params: IP2KernelParams,
    n_banks: int,
    block_r: int = 8,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Padded-shape entry; use ``ops.ip2_project_sparse(row_counts=...)``.

    Returns (S * n_banks * block_r, M): slot s owns rows
    ``[s * n_banks * block_r, (s+1) * n_banks * block_r)``; within a slot,
    row r holds the projection of dense row ``row_idx[s * rps + r]`` when
    ``r`` falls in an active bank, else zeros.
    """
    p_rows, K = patches.shape
    K2, M = w_q.shape
    (R,) = row_idx.shape
    (S,) = row_counts.shape
    rps = n_banks * block_r
    assert K == K2 and bias.shape == (M,) and R == S * rps
    assert M % block_m == 0 and K % block_k == 0, (
        f"pad shapes to blocks: {(K, M)} vs {(block_k, block_m)}"
    )
    k_steps = K // block_k
    grid = (S, n_banks, M // block_m, k_steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            *(pl.BlockSpec((1, block_k), _row_map(r, rps, block_r))
              for r in range(block_r)),
            pl.BlockSpec((block_k, block_m), _w_map(block_r)),
            pl.BlockSpec((block_m,), lambda s, i, j, k, idx, cnt: (j,)),
        ],
        out_specs=pl.BlockSpec(
            (block_r, block_m),
            lambda s, i, j, k, idx, cnt: (s * n_banks + i, j),
        ),
        scratch_shapes=[pltpu.VMEM((block_r, block_m), jnp.float32)],
    )

    return pl.pallas_call(
        functools.partial(
            _ragged_kernel, p=params, k_steps=k_steps, block_r=block_r
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, M), params.out_dtype),
        compiler_params=COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")
        ),
        interpret=interpret,
    )(row_idx.astype(jnp.int32), row_counts.astype(jnp.int32),
      *([patches] * block_r), w_q, bias)


# ---------------------------------------------------------------------------
# fused projection + ADC + w8a8 embed
# ---------------------------------------------------------------------------

def _fused_kernel(
    idx_ref, cnt_ref, *refs,
    p: IP2KernelParams, k_steps: int, m_steps: int, block_r: int,
    block_m: int,
):
    """Projection accumulates per (bank, vector bank); the fused ADC
    epilogue lands each vector bank's codes in a per-bank VMEM codes
    scratch; the final (vector, K) step feeds the whole code row bank to
    the embed matmul — int32 accumulate then ``acc_f32 * lsb * s_w``,
    bit-for-bit the ``quant_matmul_pallas`` epilogue."""
    del idx_ref
    x_refs = refs[:block_r]
    w_ref, we_ref, swe_ref, sae_ref, o_ref, acc_ref, codes_ref = refs[block_r:]
    s, i = pl.program_id(0), pl.program_id(1)
    j, kk = pl.program_id(2), pl.program_id(3)
    act = _bank_active(i, s, cnt_ref, block_r)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(act)
    def _mac():
        x = jnp.concatenate([r[...] for r in x_refs], axis=0)
        acc_ref[...] += jnp.dot(
            pwm_quantize_tile(x, p), w_ref[...],
            preferred_element_type=jnp.float32,
        )

    @pl.when(kk == k_steps - 1)
    def _codes():
        # fused edge ADC: centered integer code values (f32 grid, exact)
        code = analog_epilogue_tile(acc_ref[...], 0.0, p)
        codes_ref[:, pl.ds(j * block_m, block_m)] = jnp.where(act, code, 0.0)

    @pl.when((j == m_steps - 1) & (kk == k_steps - 1))
    def _embed():
        @pl.when(act)
        def _active():
            c8 = codes_ref[...].astype(jnp.int32)       # (block_r, M_pad)
            w8 = we_ref[...].astype(jnp.int32)          # (M_pad, D_pad)
            acc = jax.lax.dot_general(
                c8, w8, (((1,), (0,)), ((), ())),
                preferred_element_type=jnp.int32,
            )
            # per-row activation scale (the ADC LSB) loaded from memory,
            # NOT baked as a constant: keeps the multiply association
            # identical to quant_matmul's _qmm_kernel (bitwise parity)
            sa = sae_ref[...][:, None]
            sw = swe_ref[...][None, :]
            o_ref[...] = (acc.astype(jnp.float32) * sa * sw).astype(o_ref.dtype)

        @pl.when(jnp.logical_not(act))
        def _inactive():
            o_ref[...] = jnp.zeros_like(o_ref)


@functools.partial(
    jax.jit,
    static_argnames=("params", "n_banks", "block_r", "block_m", "block_k",
                     "interpret"),
)
def ip2_fused_embed_pallas(
    row_idx: jnp.ndarray,     # (S * n_banks * block_r,) int32 dense row table
    row_counts: jnp.ndarray,  # (S,) int32 — real rows per slot (DATA)
    patches: jnp.ndarray,     # (P_rows, K) dense pixel voltages in [0,1]
    w_q: jnp.ndarray,         # (K, M) DAC-quantized projection weights
    w8_embed: jnp.ndarray,    # (M, D) int8 embed codes (pad rows ZERO)
    sw_embed: jnp.ndarray,    # (D,) float32 per-col embed scales
    sa_rows: jnp.ndarray,     # (R,) float32 per-row code scales (the ADC LSB)
    params: IP2KernelParams,
    n_banks: int,
    block_r: int = 8,
    block_m: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    """Padded-shape entry; use ``ops.ip2_fused_embed``.

    Returns (S * n_banks * block_r, D) float32:
    ``(ADC_codes(project(patches[row_idx])) @ w8_embed) * lsb * sw_embed``
    — the ``y`` term of the backend's quant-embed affine (the caller adds
    the ``zero @ dequant(W8)`` term and the per-token gain, exactly as
    ``models.vit._embed_tokens`` does on the staged path). Requires
    ``params.adc_out_codes`` (the fused seam only exists in code space).
    Padding rows of ``w8_embed`` (beyond the real M) MUST be zero: the
    codes of padded projection columns are junk (the epilogue of an empty
    accumulator), and the zero rows annihilate them in the int32 sum.
    """
    if not (params.readout == "adc" and params.adc_enable
            and params.adc_out_codes):
        raise ValueError(
            "ip2_fused_embed_pallas consumes its own fused-ADC codes; "
            "params must have readout='adc', adc_enable=True and "
            "adc_out_codes=True (the sign wire has no w8a8 embed seam)"
        )
    p_rows, K = patches.shape
    K2, M = w_q.shape
    M2, D = w8_embed.shape
    (R,) = row_idx.shape
    (S,) = row_counts.shape
    rps = n_banks * block_r
    assert K == K2 and M == M2 and sw_embed.shape == (D,) and R == S * rps
    assert sa_rows.shape == (R,)
    assert M % block_m == 0 and K % block_k == 0 and D % 128 == 0, (
        f"pad shapes to blocks: {(K, M, D)} vs {(block_k, block_m, 128)}"
    )
    k_steps = K // block_k
    m_steps = M // block_m
    grid = (S, n_banks, m_steps, k_steps)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=grid,
        in_specs=[
            *(pl.BlockSpec((1, block_k), _row_map(r, rps, block_r))
              for r in range(block_r)),
            pl.BlockSpec((block_k, block_m), _w_map(block_r)),
            # embed weights/scales: one constant block, fetched once
            pl.BlockSpec((M, D), lambda s, i, j, k, idx, cnt: (0, 0)),
            pl.BlockSpec((D,), lambda s, i, j, k, idx, cnt: (0,)),
            pl.BlockSpec(
                (block_r,), lambda s, i, j, k, idx, cnt: (s * n_banks + i,)
            ),
        ],
        out_specs=pl.BlockSpec(
            (block_r, D), lambda s, i, j, k, idx, cnt: (s * n_banks + i, 0)
        ),
        scratch_shapes=[
            pltpu.VMEM((block_r, block_m), jnp.float32),   # projection acc
            pltpu.VMEM((block_r, M), jnp.float32),         # code row bank
        ],
    )

    return pl.pallas_call(
        functools.partial(
            _fused_kernel, p=params, k_steps=k_steps, m_steps=m_steps,
            block_r=block_r, block_m=block_m,
        ),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((R, D), jnp.float32),
        compiler_params=COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary",
                                 "arbitrary")
        ),
        interpret=interpret,
    )(row_idx.astype(jnp.int32), row_counts.astype(jnp.int32),
      *([patches] * block_r), w_q, w8_embed, sw_embed,
      sa_rows.astype(jnp.float32))
