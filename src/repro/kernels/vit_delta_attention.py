"""Pallas TPU kernel — ragged stale-Q compact attention (DESIGN.md §14).

The delta-gated backend (``models/backend_delta.py``) re-attends only the
``j`` stale query rows of each slot against the FULL cached key/value set
(one changed key perturbs every query, but an unchanged query row only
needs recomputing when its own input changed — at eps > 0 the held rows
keep their cached outputs). Stale rows are ranked stale-first by the
temporal frontend, so per-slot stale counts are a PREFIX length — the
same scalar-prefetched ragged banking scheme as the §11 megakernel
transfers directly:

* grid = (slots, heads, query banks); a query bank is active iff its
  first row position is below its slot's count (``pl.when`` — inactive
  banks skip the MXU entirely);
* the query index_map clamps inactive banks onto the slot's last active
  bank and inactive K/V/mask blocks pin to slot 0, so consecutive
  inactive steps present unchanged block indices and the pipeliner
  elides their DMA copies — held rows cost zero FLOPs and zero VMEM
  traffic, not masked-but-computed work;
* counts are DATA: one compile serves every stale pattern the gate can
  produce, including count 0 (a fully-held slot streams nothing).

The body mirrors ``vit._encoder_attention``'s arithmetic exactly — same
contraction order, divide-by-sqrt(dh) (not multiply-by-reciprocal), mask
via ``where(mask, scores, NEG_INF)`` — so the kernel's rows match the
dense einsum path on the stale prefix (asserted in
tests/test_backend_delta.py). Rows at positions >= their slot's count
are zero, never garbage.

Block shapes come from :func:`pick_block_q`, which minimizes the
roofline cost model's attention terms
(:func:`repro.roofline.analysis.delta_attention_cost`) over candidate
bank heights at the expected stale prefix.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30  # matches models/vit.py — the masking constant is part
                 # of the parity contract


def _q_map(block_q):
    """Query index_map: clamp inactive banks onto the slot's last active
    bank so their DMA copies are elided (§11 idiom)."""

    def m(b, h, qb, cnt):
        n_act = (cnt[b] + block_q - 1) // block_q
        return (b, h, jnp.minimum(qb, jnp.maximum(n_act - 1, 0)), 0)

    return m


def _kv_map(block_q):
    """K/V index_map: a fully-inactive step pins the block to slot 0 so
    held slots stream no key/value bytes at all."""

    def m(b, h, qb, cnt):
        act = (qb * block_q) < cnt[b]
        return (jnp.where(act, b, 0), jnp.where(act, h, 0), 0, 0)

    return m


def _mask_map(block_q):
    def m(b, h, qb, cnt):
        act = (qb * block_q) < cnt[b]
        return (jnp.where(act, b, 0), 0)

    return m


def _delta_attn_kernel(
    cnt_ref, q_ref, k_ref, v_ref, m_ref, o_ref, *, block_q: int, dh: int
):
    """One (slot, head, query bank) step: scores over the full key set,
    masked softmax, value mix — the exact dense arithmetic on the bank's
    rows. ``dh`` is the REAL head dim (the refs may be lane-padded; the
    pad columns are zero so the contractions are value-preserving, but
    the softmax scale must use the true dimension)."""
    b, qb = pl.program_id(0), pl.program_id(2)
    cnt = cnt_ref[b]
    act = (qb * block_q) < cnt

    @pl.when(act)
    def _compute():
        qq = q_ref[0, 0]   # (block_q, dh_p)
        kk = k_ref[0, 0]   # (S_p, dh_p)
        vv = v_ref[0, 0]
        sc = jax.lax.dot_general(
            qq, kk, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        sc = sc / jnp.sqrt(jnp.asarray(dh, sc.dtype))
        msk = m_ref[0] > 0.5
        sc = jnp.where(msk[None, :], sc, NEG_INF)
        probs = jax.nn.softmax(sc, axis=-1)
        o = jax.lax.dot_general(
            probs.astype(vv.dtype), vv, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        # the bank may straddle the count: rows past it are zero, never
        # garbage (the gate masks on them)
        row = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, o.shape, 0)
        o_ref[0, 0] = jnp.where(row < cnt, o, 0.0).astype(o_ref.dtype)

    @pl.when(~act)
    def _zero():
        o_ref[0, 0] = jnp.zeros_like(o_ref[0, 0])


def _pad_axis(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


@functools.partial(
    jax.jit, static_argnames=("block_q", "lane", "interpret")
)
def delta_attention_pallas(
    q: jnp.ndarray,         # (B, S, H, dh) stale-prefix query rows
    k: jnp.ndarray,         # (B, S, H, dh) full key set
    v: jnp.ndarray,         # (B, S, H, dh)
    key_mask: jnp.ndarray,  # (B, S) bool — valid key tokens
    q_counts: jnp.ndarray,  # (B,) int32 stale prefix length (DATA)
    block_q: int = 8,
    lane: int = 128,
    interpret: bool = False,
) -> jnp.ndarray:
    """Returns (B, S, H, dh): row r of slot b holds the attention output
    of query r when ``r < q_counts[b]``, else zeros."""
    B, S, H, dh = q.shape
    assert k.shape == q.shape and v.shape == q.shape
    assert key_mask.shape == (B, S)

    def prep(x):  # (B,S,H,dh) -> lane-padded (B,H,S_p,dh_p)
        x = jnp.transpose(x, (0, 2, 1, 3))
        return _pad_axis(_pad_axis(x, 3, lane), 2, block_q)

    qt, kt, vt = prep(q), prep(k), prep(v)
    s_p, dh_p = qt.shape[2], qt.shape[3]
    # padded key rows are invalid: they mask to NEG_INF and mix nothing
    mask_f = _pad_axis(key_mask.astype(jnp.float32), 1, block_q)

    grid = (B, H, s_p // block_q)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, dh_p), _q_map(block_q)),
            pl.BlockSpec((1, 1, s_p, dh_p), _kv_map(block_q)),
            pl.BlockSpec((1, 1, s_p, dh_p), _kv_map(block_q)),
            pl.BlockSpec((1, s_p), _mask_map(block_q)),
        ],
        # output map is NOT clamped: every bank owns its own block
        out_specs=pl.BlockSpec(
            (1, 1, block_q, dh_p), lambda b, h, qb, cnt: (b, h, qb, 0)
        ),
    )
    out = pl.pallas_call(
        functools.partial(_delta_attn_kernel, block_q=block_q, dh=dh),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, s_p, dh_p), q.dtype),
        interpret=interpret,
    )(q_counts.astype(jnp.int32), qt, kt, vt, mask_f)
    return jnp.transpose(out[:, :, :S, :dh], (0, 2, 1, 3))


def pick_block_q(
    k_tokens: int, d_model: int, n_heads: int,
    expect_stale: int | None = None,
    candidates: tuple = (4, 8, 16, 32),
) -> int:
    """Roofline-picked query bank height: minimize the modeled cost of
    the kernel grid at the expected stale prefix (default half the
    tokens — the gate's break-even regime). Larger banks amortize K/V
    streaming but round the prefix up harder; the §11 cost model arbitrates."""
    from repro.roofline import analysis  # lazy: keep kernels import-light

    j = min(expect_stale if expect_stale is not None else k_tokens // 2,
            k_tokens) or 1
    best, best_cost = candidates[0], None
    for bq in candidates:
        if bq > max(k_tokens, 1):
            break
        c = analysis.delta_attention_cost(
            j, k_tokens, d_model, n_heads, block_q=bq)
        t = c["time_s"]
        if best_cost is None or t < best_cost:
            best, best_cost = bq, t
    return best
