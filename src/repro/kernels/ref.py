"""Pure-jnp oracles for every Pallas kernel (shape/dtype-exact)."""

from __future__ import annotations

import jax.numpy as jnp

from repro.core import adc as adc_mod
from repro.kernels.ip2_project import IP2KernelParams


def ip2_project_ref(
    patches: jnp.ndarray, w_q: jnp.ndarray, bias: jnp.ndarray, params: IP2KernelParams
) -> jnp.ndarray:
    """Oracle for ip2_project_pallas (same padded shapes), including the
    ``adc_out_codes`` wire-format output (DESIGN.md §9) and the ADC-less
    ``readout="sign"`` comparator epilogue (DESIGN.md §13, int8 {0,1} to
    match the kernel's out_dtype; the ops wrapper re-types to bool)."""
    n = params.pwm_levels - 1
    xq = jnp.round(jnp.clip(patches, 0.0, 1.0) * n) * (1.0 / n)
    acc = xq.astype(jnp.float32) @ w_q.astype(jnp.float32)
    out = acc * (params.droop / params.n2) + params.v_ref
    if params.nl_kind == "relu":
        out = jnp.clip(out, 0.0, params.v_sat)
    if params.readout == "sign":
        return adc_mod.sign_encode(out, params.v_ref).astype(jnp.int8)
    if not params.adc_enable:
        return out - (params.v_ref - bias[None, :])
    spec = params.adc_spec()
    if params.adc_out_codes:
        return adc_mod.encode(out, spec)
    return adc_mod.digital_readout(out, params.v_ref, bias[None, :], spec)


def ip2_project_sparse_ref(
    row_idx: jnp.ndarray,
    patches: jnp.ndarray,
    w_q: jnp.ndarray,
    bias: jnp.ndarray,
    params: IP2KernelParams,
) -> jnp.ndarray:
    """Oracle for ip2_project_sparse_pallas (same padded shapes, any
    block_r): an explicit row gather followed by the dense projection."""
    return ip2_project_ref(patches[row_idx], w_q, bias, params)


def ip2_conv_ref(
    frame: jnp.ndarray,
    w_q: jnp.ndarray,
    bias: jnp.ndarray,
    conv,                          # core.projection.ConvSpec (geometry only)
    params: IP2KernelParams,
) -> jnp.ndarray:
    """Oracle for ops.ip2_conv: explicit python-loop strided K×K window
    slicing (independent of the wrapper's im2col gather) followed by the
    dense projection oracle — (..., gh*gw, C) in row-major window order.
    ``w_q`` is (K², C) on the DAC grid, as in :func:`ip2_project_ref`."""
    k, s = conv.kernel, conv.stride
    frames = frame if frame.ndim == 3 else frame[None]
    b, h, w = frames.shape
    gh = (h - k) // s + 1
    gw = (w - k) // s + 1
    wins = [
        frames[:, i * s:i * s + k, j * s:j * s + k].reshape(b, k * k)
        for i in range(gh) for j in range(gw)
    ]
    windows = jnp.stack(wins, axis=1)                    # (b, gh*gw, K²)
    out = ip2_project_ref(windows.reshape(-1, k * k), w_q, bias, params)
    out = out.reshape(b, gh * gw, -1)
    return out if frame.ndim == 3 else out[0]


def ip2_fused_embed_ref(
    row_idx: jnp.ndarray,
    patches: jnp.ndarray,
    w_q: jnp.ndarray,
    w8: jnp.ndarray,
    s_w: jnp.ndarray,
    params: IP2KernelParams,
) -> jnp.ndarray:
    """Oracle for ip2_fused_embed_pallas (same padded shapes): the staged
    composition — sparse projection to ADC codes, then the w8a8 embed
    matmul with the ADC LSB as the (single, static) activation scale."""
    bias = jnp.zeros((w_q.shape[1],), jnp.float32)
    codes = ip2_project_sparse_ref(row_idx, patches, w_q, bias, params)
    lsb = jnp.full((codes.shape[0],), params.adc_spec().lsb, jnp.float32)
    return quant_matmul_ref(codes, lsb, w8, s_w, jnp.float32)


def quant_matmul_ref(
    a8: jnp.ndarray, s_a: jnp.ndarray, w8: jnp.ndarray, s_w: jnp.ndarray, out_dtype=jnp.float32
) -> jnp.ndarray:
    acc = a8.astype(jnp.int32) @ w8.astype(jnp.int32)
    return (acc.astype(jnp.float32) * s_a[:, None] * s_w[None, :]).astype(out_dtype)


def quantize_activations_ref(a: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Per-row symmetric int8 activation quantization (the 'PWM' side)."""
    amax = jnp.max(jnp.abs(a), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    a8 = jnp.clip(jnp.round(a / scale[..., None]), -127, 127).astype(jnp.int8)
    return a8, scale.astype(jnp.float32)
