"""Pallas TPU kernel — the IP2 analog patch-projection array's digital twin.

TPU adaptation of the paper's in-pixel compute fabric (DESIGN.md §2): the
analog array performs, for a bank of patches in parallel,

    Out[p, v] = VR + droop * (sum_i PWM(P[p,i]) * Wq[i,v]) / N2
    feat[p, v] = ADC(NL(Out[p, v])) - (VR - bias[v])

One pallas grid step computes one (patch-bank x vector-bank) macro-op —
the moral equivalent of one charge-share/readout cycle — with:

  * activations PWM-quantized at tile load (the pixel->pulse-width
    converter lives next to the data, not in a separate pass);
  * the MXU doing the W x P multiply-accumulate (K-tiled, fp32 scratch
    accumulator in VMEM);
  * the full analog epilogue (charge-share /N2, OpAmp droop, 2T clip,
    edge-ADC quantization, VR-b digital subtraction) fused into the final
    K step, so features never round-trip to HBM in analog form.

Block sizes default to MXU-aligned (128) tiles; the wrapper in ops.py pads
inputs so every dimension divides its block.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import adc as adc_mod

# renamed across jax releases: CompilerParams (new) vs TPUCompilerParams (old)
COMPILER_PARAMS_CLS = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams


@dataclasses.dataclass(frozen=True)
class IP2KernelParams:
    """Static analog-model constants baked into the kernel."""

    n2: int                      # true pixels/patch (charge-share divisor)
    pwm_levels: int = 64         # 6-bit PWM
    droop: float = 1.0           # summer retention factor (OpAmp: ~A0/(1+A0))
    v_ref: float = 0.0
    nl_kind: str = "none"        # "none" | "relu" (2T stage), clip at v_sat
    v_sat: float = 1.0
    adc_bits: int = 8
    adc_vmin: float = -1.0
    adc_vmax: float = 1.0
    adc_enable: bool = True
    adc_out_codes: bool = False  # emit int codes (the wire format, DESIGN.md §9)
    readout: str = "adc"         # "adc" | "sign" — epilogue mode (DESIGN.md §13)

    def __post_init__(self):
        if self.readout not in ("adc", "sign"):
            raise ValueError(f"unknown readout mode {self.readout!r}")

    def adc_spec(self) -> adc_mod.ADCSpec:
        return adc_mod.ADCSpec(
            bits=self.adc_bits, v_min=self.adc_vmin, v_max=self.adc_vmax
        )

    @property
    def out_dtype(self):
        if self.readout == "sign":
            return jnp.int8  # {0,1} sign bits; the wrapper re-types to bool
        if self.adc_enable and self.adc_out_codes:
            return self.adc_spec().code_dtype
        return jnp.float32


def pwm_quantize_tile(x: jnp.ndarray, p: IP2KernelParams) -> jnp.ndarray:
    """Pixel -> pulse width on the PWM clock grid (time quantization),
    applied at tile load so the converter lives next to the data."""
    n = p.pwm_levels - 1
    return jnp.round(jnp.clip(x, 0.0, 1.0) * n) * (1.0 / n)


def analog_epilogue_tile(acc: jnp.ndarray, b: jnp.ndarray, p: IP2KernelParams) -> jnp.ndarray:
    """The fused analog readout: charge-share /N2 + droop + VR, the 2T
    nonlinearity, then one of the mode-selectable conversion epilogues
    (DESIGN.md §13). Shared by the dense, sparse, ragged and fused kernels
    — ``p.readout`` is static, so the default ``"adc"`` path lowers to
    exactly the pre-mode pipeline (asserted bitwise in tests).

    * ``readout="adc"`` (default) — the edge ADC. With ``adc_out_codes``
      the tile leaves in wire format — centered integer code values (cast
      to the code dtype by the caller); the bias is NOT applied (it lives
      in the ``zero`` metadata of
      :func:`repro.core.adc.readout_scale_zero`). Otherwise the
      dequantized float readout including the VR-b digital subtraction is
      produced, on exactly the grid of
      :func:`repro.core.adc.digital_readout` so kernel and jnp paths stay
      bit-identical.
    * ``readout="sign"`` — ADC-less comparator readout: one bit per
      vector, ``out >= V_R``, emitted as {0, 1} on the f32 grid (the
      caller casts to int8; the ops wrapper re-types the wire to bool).
      As on the code wire, the bias is metadata
      (:func:`repro.core.adc.sign_scale_zero`), never payload.
    """
    out = acc * (p.droop / p.n2) + p.v_ref
    if p.nl_kind == "relu":
        out = jnp.clip(out, 0.0, p.v_sat)
    if p.readout == "sign":
        return jnp.where(out >= p.v_ref, 1.0, 0.0)
    if not p.adc_enable:
        return out - (p.v_ref - b)
    spec = p.adc_spec()
    code = adc_mod._code_grid(out, spec)           # f32 centered codes
    if p.adc_out_codes:
        return code
    scale, zero = adc_mod.readout_scale_zero(p.v_ref, b, spec)
    return adc_mod.dequantize(code, scale, zero)


def _ip2_kernel(x_ref, w_ref, b_ref, o_ref, acc_ref, *, p: IP2KernelParams, k_steps: int):
    """Grid = (patch banks, vector banks, K banks); K innermost/arbitrary."""

    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    xq = pwm_quantize_tile(x_ref[...], p)
    acc_ref[...] += jnp.dot(xq, w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _epilogue():
        o_ref[...] = analog_epilogue_tile(acc_ref[...], b_ref[...], p).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("params", "block_p", "block_m", "block_k", "interpret"),
)
def ip2_project_pallas(
    patches: jnp.ndarray,      # (P, K) pixel voltages in [0,1]; K = padded N2
    w_q: jnp.ndarray,          # (K, M) DAC-quantized weights (pre-quantized)
    bias: jnp.ndarray,         # (M,)
    params: IP2KernelParams,
    block_p: int = 128,
    block_m: int = 128,
    block_k: int = 512,
    interpret: bool = False,
) -> jnp.ndarray:
    """Padded-shape kernel entry; use repro.kernels.ops.ip2_project."""
    P, K = patches.shape
    K2, M = w_q.shape
    assert K == K2 and bias.shape == (M,)
    assert P % block_p == 0 and M % block_m == 0 and K % block_k == 0, (
        f"pad shapes to blocks: {(P, K, M)} vs {(block_p, block_k, block_m)}"
    )
    k_steps = K // block_k
    grid = (P // block_p, M // block_m, k_steps)

    return pl.pallas_call(
        functools.partial(_ip2_kernel, p=params, k_steps=k_steps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_p, block_k), lambda i, j, k: (i, k)),
            pl.BlockSpec((block_k, block_m), lambda i, j, k: (k, j)),
            pl.BlockSpec((block_m,), lambda i, j, k: (j,)),
        ],
        out_specs=pl.BlockSpec((block_p, block_m), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((P, M), params.out_dtype),
        scratch_shapes=[pltpu.VMEM((block_p, block_m), jnp.float32)],
        compiler_params=COMPILER_PARAMS_CLS(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(patches, w_q, bias)
