"""GPipe-style pipeline parallelism over the slow ("pod") mesh axis.

Motivation: across pods the DCN link is far slower than ICI, so instead of
stretching the DP all-reduce across it, the layer stack can be split into
one stage per pod and microbatches streamed through — cross-pod traffic
becomes O(activations · microbatches) point-to-point instead of
O(params) all-reduce.

Implementation: shard_map over the stage axis; every stage runs the same
scan over T = n_micro + n_stages - 1 ticks:

    tick t: x_in  <- ppermute(+1)(x_out_prev)      # receive from left
            if stage == 0: x_in = microbatch[t]    # inject at the head
            x_out = stage_fn(stage_params, x_in)   # bubble ticks compute
                                                   # garbage, masked later
    outputs: last stage's x_out at ticks >= n_stages - 1

The whole schedule is differentiable (ppermute transposes to the reverse
permute), so training backprops through the pipe — GPipe semantics with
re-forward on the backward pass (remat inside stage_fn).

Microbatch tensors are staged on the FIRST stage only; other stages carry
zeros of the same shape (SPMD requires a uniform program).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def pipeline_forward(
    stage_params,                # pytree, leaves (n_stages, ...) sharded on axis
    microbatches: jnp.ndarray,   # (n_micro, mb, ...) replicated
    stage_fn: Callable,          # (params_for_stage, x) -> y (same shape)
    mesh: Mesh,
    axis: str = "pod",
):
    """Returns (n_micro, mb, ...) outputs of the final stage."""
    n_stages = mesh.devices.shape[list(mesh.axis_names).index(axis)]
    n_micro = microbatches.shape[0]
    ticks = n_micro + n_stages - 1
    mb_shape = microbatches.shape[1:]

    def per_stage(params_blk, mbs):
        # params_blk leaves: (1, ...) — this stage's slice
        params_local = jax.tree.map(lambda x: x[0], params_blk)
        stage = jax.lax.axis_index(axis)

        def tick(carry, t):
            x_prev = carry
            # receive from the previous stage (ring shift +1)
            perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]
            x_in = jax.lax.ppermute(x_prev, axis, perm)
            inject = jax.lax.dynamic_index_in_dim(
                mbs, jnp.clip(t, 0, n_micro - 1), axis=0, keepdims=False
            )
            x_in = jnp.where(stage == 0, jnp.where(t < n_micro, inject, x_in), x_in)
            x_out = stage_fn(params_local, x_in)
            return x_out, x_out

        x0 = jnp.zeros(mb_shape, microbatches.dtype)
        # newer jax requires the carry marked device-varying for shard_map's
        # varying-manual-axes check; older releases have no pvary (and no check)
        if hasattr(jax.lax, "pvary"):
            x0 = jax.lax.pvary(x0, (axis,))
        _, ys = jax.lax.scan(tick, x0, jnp.arange(ticks))
        # final-stage outputs live at ticks n_stages-1 .. ticks-1
        out = jax.lax.dynamic_slice_in_dim(ys, n_stages - 1, n_micro, axis=0)
        # broadcast the last stage's result to all stages so out_specs can
        # be replicated (psum of masked contributions). Mask by SELECT, not
        # multiply: non-final stages hold bubble-tick garbage here, and if
        # a stage_fn turns the zero-carry bubble input into NaN/inf then
        # `garbage * 0 = NaN` would poison the real output through the
        # psum — where() never evaluates arithmetic on the untaken branch
        is_last = stage == n_stages - 1
        return jax.lax.psum(jnp.where(is_last, out, jnp.zeros_like(out)),
                            axis)

    pspecs = jax.tree.map(lambda _: P(axis), stage_params)
    return shard_map(
        per_stage,
        mesh=mesh,
        in_specs=(pspecs, P()),
        out_specs=P(),
    )(stage_params, microbatches)


def split_layers_to_stages(stacked_params, n_stages: int):
    """(L, ...) stacked layer params -> (n_stages, L//n_stages, ...)."""
    def re(x):
        l = x.shape[0]
        assert l % n_stages == 0, (l, n_stages)
        return x.reshape(n_stages, l // n_stages, *x.shape[1:])

    return jax.tree.map(re, stacked_params)
