"""Sharded, atomic, async checkpointing with elastic restore.

Layout (one directory per step):
    <dir>/step_000100.tmp/         # written first
        manifest.json              # tree structure, shapes, dtypes, specs
        arr_<idx>.npy              # one file per leaf (addressable global)
    <dir>/step_000100/             # atomic rename on completion = commit

Fault-tolerance properties:
  * atomic commit — a crash mid-write leaves only a .tmp dir, which restore
    ignores and the next save overwrites;
  * elastic restore — arrays are saved as full logical values and re-placed
    against the *restore-time* mesh/shardings, so a job can come back on a
    different chip count (ZeRO-style reshard-on-restore);
  * async — saves run on a background thread off the training loop
    (double-buffered: at most one pending save; the trainer joins before
    starting another).

On a multi-host deployment each host writes only the shards it owns
(process_allgather-free: addressable_shards); in this single-process
container that degrades to full arrays, same format.
"""

from __future__ import annotations

import json
import os
import shutil
import threading

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [v for _, v in flat]
    return paths, leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3):
        self.dir = directory
        self.keep = keep
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------

    def save(self, step: int, tree, blocking: bool = False) -> None:
        self.wait()
        # snapshot to host memory synchronously (cheap), write async
        paths, leaves, _ = _flatten_with_paths(tree)
        host_leaves = [np.asarray(x) for x in leaves]

        def write():
            tmp = os.path.join(self.dir, f"step_{step:08d}.tmp")
            final = os.path.join(self.dir, f"step_{step:08d}")
            os.makedirs(tmp, exist_ok=True)
            manifest = {"step": step, "paths": paths}
            for i, arr in enumerate(host_leaves):
                np.save(os.path.join(tmp, f"arr_{i}.npy"), arr)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(tmp, final)          # atomic commit
            self._gc()

        if blocking:
            write()
        else:
            self._pending = threading.Thread(target=write, daemon=True)
            self._pending.start()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"), ignore_errors=True)

    # -- restore --------------------------------------------------------------

    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: int | None = None, shardings=None):
        """Restore into the structure of ``tree_like``; if ``shardings``
        (same-structure pytree of Shardings) is given, device_put each leaf
        against it — this is the elastic re-shard path."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:08d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        paths, _, treedef = _flatten_with_paths(tree_like)
        if paths != manifest["paths"]:
            raise ValueError(
                "checkpoint tree mismatch: "
                f"{set(paths) ^ set(manifest['paths'])}"
            )
        arrs = [np.load(os.path.join(d, f"arr_{i}.npy")) for i in range(len(paths))]
        if shardings is not None:
            sh_leaves = jax.tree_util.tree_leaves(
                shardings, is_leaf=lambda x: hasattr(x, "device_set")
            )
            arrs = [jax.device_put(a, s) for a, s in zip(arrs, sh_leaves)]
        else:
            arrs = [jax.device_put(a) for a in arrs]
        return jax.tree_util.tree_unflatten(treedef, arrs), step
