"""Top-level models: causal LM, whisper-style enc-dec, VLM (+ IP2 frontend).

Public API (all pure functions of (cfg, plan)):

  init_params(key, cfg, plan, dtype)        -> params pytree
  param_specs(cfg, plan)                    -> PartitionSpec pytree
  forward(params, batch, cfg, plan)         -> (logits, aux)       # train
  loss_fn(params, batch, cfg, plan)         -> (loss, metrics)
  init_decode_state(cfg, plan, B, max_len)  -> state pytree
  decode_state_specs(cfg, plan)             -> PartitionSpec pytree
  prefill(params, batch, cfg, plan, state)  -> (logits_last, state)
  decode_step(params, state, tokens, pos, cfg, plan) -> (logits, state)

Layer stacking: full repeats of ``block_pattern`` run under one lax.scan
(one stack per pattern position), remainder layers unrolled (blocks.py).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks as blk
from repro.models.layers import ParallelPlan, DEFAULT_PLAN, dense_init, embed_init, rms_norm
from repro.models.sharding_ctx import constrain

from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# structure helpers
# ---------------------------------------------------------------------------

def _pattern_layout(cfg: ModelConfig) -> tuple[int, tuple[str, ...], tuple[str, ...]]:
    """(n_repeats, pattern, tail_kinds)."""
    pat = tuple(cfg.block_pattern)
    n_rep = cfg.n_layers // len(pat)
    tail = cfg.layer_kinds[n_rep * len(pat):]
    return n_rep, pat, tuple(tail)


def _stack(trees: list) -> dict:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees) if trees else None


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def init_params(key, cfg: ModelConfig, plan: ParallelPlan = DEFAULT_PLAN,
                dtype=jnp.float32) -> dict:
    n_rep, pat, tail = _pattern_layout(cfg)
    keys = jax.random.split(key, 8)
    p: dict = {}
    if cfg.vocab:
        p["embed"] = embed_init(keys[0], cfg.vocab, cfg.d_model, dtype)
        if not cfg.tie_embeddings:
            p["lm_head"] = embed_init(keys[1], cfg.vocab, cfg.d_model, dtype)
    p["final_norm"] = jnp.ones((cfg.d_model,), dtype)

    kb = jax.random.split(keys[2], n_rep * len(pat) + len(tail))
    stacks = []
    for pi, kind in enumerate(pat):
        layers = [
            blk.init_block(kb[r * len(pat) + pi], kind, cfg, plan, dtype)
            for r in range(n_rep)
        ]
        stacks.append(_stack(layers))
    p["stacks"] = stacks
    p["tail"] = [
        blk.init_block(kb[n_rep * len(pat) + i], kind, cfg, plan, dtype)
        for i, kind in enumerate(tail)
    ]

    if cfg.is_encoder_decoder:
        ke = jax.random.split(keys[3], cfg.n_encoder_layers + 2)
        p["encoder"] = [
            blk.init_block(ke[i], "attn", cfg, plan, dtype)
            for i in range(cfg.n_encoder_layers)
        ]
        p["enc_norm"] = jnp.ones((cfg.d_model,), dtype)
        # decoder cross-attention, one per decoder layer
        from repro.models.attention import init_attention

        kc = jax.random.split(keys[4], cfg.n_layers)
        p["cross"] = _stack(
            [
                {
                    "norm": jnp.ones((cfg.d_model,), dtype),
                    "attn": init_attention(kc[i], cfg, plan, dtype),
                }
                for i in range(cfg.n_layers)
            ]
        )
    if cfg.is_vlm:
        vis_in = cfg.ip2_vectors if cfg.vision_frontend == "ip2" else 1024
        p["vision_adapter"] = dense_init(keys[5], vis_in, cfg.d_model, dtype)
        if cfg.vision_frontend == "ip2":
            from repro.core.frontend import init_frontend_params

            p["ip2"] = init_frontend_params(keys[6], _ip2_cfg(cfg))
    return p


def _ip2_cfg(cfg: ModelConfig):
    from repro.core.frontend import FrontendConfig
    from repro.core.projection import PatchSpec

    return FrontendConfig(
        patch=PatchSpec(
            patch_h=cfg.ip2_patch, patch_w=cfg.ip2_patch, n_vectors=cfg.ip2_vectors
        )
    )


def param_specs(cfg: ModelConfig, plan: ParallelPlan = DEFAULT_PLAN) -> dict:
    n_rep, pat, tail = _pattern_layout(cfg)
    w_in = plan.fsdp_axis if plan.fsdp else None
    s: dict = {}
    if cfg.vocab:
        s["embed"] = plan.spec_embed()
        if not cfg.tie_embeddings:
            s["lm_head"] = plan.spec_embed()
    s["final_norm"] = P(None)

    def with_layer_dim(spec_tree):
        return jax.tree.map(
            lambda sp: P(None, *sp), spec_tree,
            is_leaf=lambda x: isinstance(x, P),
        )

    s["stacks"] = [with_layer_dim(blk.spec_block(k, cfg, plan)) for k in pat]
    s["tail"] = [blk.spec_block(k, cfg, plan) for k in tail]

    if cfg.is_encoder_decoder:
        s["encoder"] = [blk.spec_block("attn", cfg, plan) for _ in range(cfg.n_encoder_layers)]
        s["enc_norm"] = P(None)
        from repro.models.attention import spec_attention

        s["cross"] = with_layer_dim(
            {"norm": P(None), "attn": spec_attention(cfg, plan)}
        )
    if cfg.is_vlm:
        s["vision_adapter"] = P(None, plan.tp_axis)
        if cfg.vision_frontend == "ip2":
            s["ip2"] = {"a_rgb": P(plan.tp_axis, None), "bias": P(plan.tp_axis)}
    return s


# ---------------------------------------------------------------------------
# embedding of mixed inputs
# ---------------------------------------------------------------------------

def embed_inputs(params: dict, batch: dict, cfg: ModelConfig) -> jnp.ndarray:
    """Returns x (B, S, D). For VLM, image tokens are prepended; for
    enc-dec, this embeds the *decoder* tokens only."""
    x = params["embed"][batch["tokens"]] if cfg.vocab else None
    if cfg.is_vlm:
        if cfg.vision_frontend == "ip2":
            from repro.core.frontend import apply_frontend

            feats, _ = apply_frontend(params["ip2"], batch["images_rgb"], _ip2_cfg(cfg))
            vis = feats
        else:
            vis = batch["image_embeds"]                    # (B, n_img, 1024)
        vis = vis.astype(params["vision_adapter"].dtype) @ params["vision_adapter"]
        x = vis if x is None else jnp.concatenate([vis, x.astype(vis.dtype)], axis=1)
    return x


# ---------------------------------------------------------------------------
# forward (training / prefill)
# ---------------------------------------------------------------------------

def _run_stacks(params, x, cfg, plan, states=None, causal=True, decode_pos=None):
    """Scan over pattern repeats + unrolled tail. states mirrors params
    layout: {"stacks": [stacked state per position], "tail": [state]}."""
    n_rep, pat, tail = _pattern_layout(cfg)
    aux_total = jnp.zeros((), jnp.float32)
    s = x.shape[1]
    positions = jnp.arange(s) if decode_pos is None else None

    def body(carry, xs):
        xx, aux = carry
        layer_params, layer_states = xs
        new_states = []
        for pi, kind in enumerate(pat):
            st = None if layer_states is None else layer_states[pi]
            xx, st_new, a = blk.apply_block(
                layer_params[pi], kind, xx, cfg, positions, st,
                causal=causal, decode_pos=decode_pos,
            )
            new_states.append(st_new)
            aux = aux + a
        return (xx, aux), (tuple(new_states) if layer_states is not None else 0)

    if cfg.remat:
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if cfg.remat_policy == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        body = jax.checkpoint(body, policy=policy)

    stack_states = None
    if n_rep > 0:
        xs_states = (
            tuple(states["stacks"]) if states is not None else None
        )
        if cfg.unroll_layers:
            carry = (x, aux_total)
            ys_list = []
            for r in range(n_rep):
                xs_r = jax.tree.map(lambda a: a[r], (tuple(params["stacks"]), xs_states))
                carry, y = body(carry, xs_r)
                ys_list.append(y)
            (x, aux_total) = carry
            ys = _stack(ys_list) if states is not None else None
        else:
            (x, aux_total), ys = jax.lax.scan(
                body,
                (x, aux_total),
                (tuple(params["stacks"]), xs_states),
            )
        if states is not None:
            stack_states = list(ys)

    tail_states = []
    for i, kind in enumerate(tail):
        st = None if states is None else states["tail"][i]
        x, st_new, a = blk.apply_block(
            params["tail"][i], kind, x, cfg, positions, st,
            causal=causal, decode_pos=decode_pos,
        )
        tail_states.append(st_new)
        aux_total = aux_total + a

    new_states = None
    if states is not None:
        new_states = {"stacks": stack_states, "tail": tail_states}
    return x, new_states, aux_total


def _encode(params, frames, cfg, plan):
    """Whisper encoder over precomputed frame embeddings (stub frontend)."""
    x = frames
    pos = jnp.arange(x.shape[1])
    for p in params["encoder"]:
        x, _, _ = blk.apply_block(p, "attn", x, cfg, pos, None, causal=False)
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _cross_attend(params_cross_i, x, enc_kv, cfg):
    from repro.models.attention import attention_forward

    h = rms_norm(x, params_cross_i["norm"], cfg.norm_eps)
    out, _ = attention_forward(
        params_cross_i["attn"], h, cfg, jnp.arange(x.shape[1]),
        causal=False, kv_override=enc_kv, use_rope=False,
    )
    return constrain(x + out, "act")


def forward(params: dict, batch: dict, cfg: ModelConfig,
            plan: ParallelPlan = DEFAULT_PLAN) -> tuple[jnp.ndarray, dict]:
    """Full-sequence forward -> (logits (B,S,V), aux dict)."""
    x = embed_inputs(params, batch, cfg)
    aux = jnp.zeros((), jnp.float32)

    if cfg.is_encoder_decoder:
        enc = _encode(params, batch["frames"], cfg, plan)
        # interleave cross-attention after each decoder block (unstacked scan
        # is fine at whisper depth; cross params are stacked for uniformity)
        n_rep, pat, tail = _pattern_layout(cfg)
        pos = jnp.arange(x.shape[1])
        for i in range(cfg.n_layers):
            lp = (
                jax.tree.map(lambda a: a[i], params["stacks"][0])
                if i < n_rep else params["tail"][i - n_rep]
            )
            x, _, _ = blk.apply_block(lp, "attn", x, cfg, pos, None, causal=True)
            cp = jax.tree.map(lambda a: a[i], params["cross"])
            x = _cross_attend(cp, x, enc, cfg)
    else:
        x, _, a = _run_stacks(params, x, cfg, plan)
        aux = aux + a

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = constrain(jnp.einsum("bsd,vd->bsv", x, head), "logits")
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits, {"moe_aux": aux}


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            plan: ParallelPlan = DEFAULT_PLAN) -> tuple[jnp.ndarray, dict]:
    """Next-token CE over text tokens (image/frame positions excluded)."""
    logits, aux = forward(params, batch, cfg, plan)
    tokens = batch["tokens"]
    n_prefix = logits.shape[1] - tokens.shape[1]   # image tokens prepended
    logits_text = logits[:, n_prefix:, :]
    tgt = tokens[:, 1:]
    lg = logits_text[:, :-1, :].astype(jnp.float32)
    mask = batch.get("loss_mask")
    mask = jnp.ones_like(tgt, jnp.float32) if mask is None else mask[:, 1:]
    logz = jax.nn.logsumexp(lg, axis=-1)
    # one-hot contraction instead of take_along_axis: stays vocab-sharded
    # (a gather over the TP-sharded vocab dim would all-gather the logits)
    onehot = jax.nn.one_hot(tgt, lg.shape[-1], dtype=lg.dtype)
    gold = jnp.einsum("bsv,bsv->bs", lg, onehot)
    ce = jnp.sum((logz - gold) * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    loss = ce + aux["moe_aux"]
    return loss, {"ce": ce, "moe_aux": aux["moe_aux"]}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_decode_state(cfg: ModelConfig, plan: ParallelPlan, batch: int,
                      max_len: int, cache_dtype=jnp.bfloat16) -> dict:
    n_rep, pat, tail = _pattern_layout(cfg)

    def stacked_state(kind):
        one = blk.init_block_state(kind, cfg, plan, batch, max_len, cache_dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a[None], (n_rep, *a.shape)), one
        )

    state = {
        "stacks": [stacked_state(k) for k in pat],
        "tail": [blk.init_block_state(k, cfg, plan, batch, max_len, cache_dtype)
                 for k in tail],
    }
    if cfg.is_encoder_decoder:
        state["enc"] = jnp.zeros(
            (batch, cfg.n_encoder_frames, cfg.d_model), jnp.float32
        )
    return state


def decode_state_specs(cfg: ModelConfig, plan: ParallelPlan,
                       cache_dtype=jnp.bfloat16) -> dict:
    n_rep, pat, tail = _pattern_layout(cfg)

    def with_layer_dim(tree):
        return jax.tree.map(
            lambda sp: P(None, *sp), tree, is_leaf=lambda x: isinstance(x, P)
        )

    s = {
        "stacks": [with_layer_dim(blk.state_specs(k, cfg, plan, cache_dtype))
                   for k in pat],
        "tail": [blk.state_specs(k, cfg, plan, cache_dtype) for k in tail],
    }
    if cfg.is_encoder_decoder:
        s["enc"] = P(plan.dp_axes, None, None)
    return s


def prefill(params: dict, batch: dict, cfg: ModelConfig, plan: ParallelPlan,
            state: dict) -> tuple[jnp.ndarray, dict]:
    """Run the prompt through the model, filling caches/states.
    Returns (last-position logits (B, V), state)."""
    x = embed_inputs(params, batch, cfg)
    if cfg.is_encoder_decoder:
        enc = _encode(params, batch["frames"], cfg, plan)
        state = dict(state, enc=enc)
        n_rep, pat, tail = _pattern_layout(cfg)
        pos = jnp.arange(x.shape[1])
        new_stack = []
        for i in range(cfg.n_layers):
            lp = (
                jax.tree.map(lambda a: a[i], params["stacks"][0])
                if i < n_rep else params["tail"][i - n_rep]
            )
            st = jax.tree.map(lambda a: a[i], state["stacks"][0]) if i < n_rep \
                else state["tail"][i - n_rep]
            x, st_new, _ = blk.apply_block(lp, "attn", x, cfg, pos, st, causal=True)
            if i < n_rep:
                new_stack.append(st_new)
            else:
                state["tail"][i - n_rep] = st_new
            cp = jax.tree.map(lambda a: a[i], params["cross"])
            x = _cross_attend(cp, x, enc, cfg)
        state["stacks"] = [_stack(new_stack)]
    else:
        x, state, _ = _run_stacks(params, x, cfg, plan, states=state)

    x = rms_norm(x[:, -1:, :], params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)[:, 0]
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, state


def decode_step(params: dict, state: dict, tokens: jnp.ndarray,
                pos: jnp.ndarray, cfg: ModelConfig,
                plan: ParallelPlan = DEFAULT_PLAN) -> tuple[jnp.ndarray, dict]:
    """One token step. tokens (B,) int32, pos scalar int32 (absolute).
    Returns (logits (B, V), new state)."""
    x = params["embed"][tokens][:, None, :]                    # (B, 1, D)

    if cfg.is_encoder_decoder:
        enc = state["enc"]
        n_rep, pat, tail = _pattern_layout(cfg)
        new_stack = []
        for i in range(cfg.n_layers):
            lp = (
                jax.tree.map(lambda a: a[i], params["stacks"][0])
                if i < n_rep else params["tail"][i - n_rep]
            )
            st = jax.tree.map(lambda a: a[i], state["stacks"][0]) if i < n_rep \
                else state["tail"][i - n_rep]
            x, st_new, _ = blk.apply_block(
                lp, "attn", x, cfg, None, st, decode_pos=pos
            )
            if i < n_rep:
                new_stack.append(st_new)
            else:
                state["tail"][i - n_rep] = st_new
            cp = jax.tree.map(lambda a: a[i], params["cross"])
            x = _cross_attend(cp, x, enc, cfg)
        state = dict(state)
        state["stacks"] = [_stack(new_stack)]
        new_states = state
    else:
        x, new_states, _ = _run_stacks(
            params, x, cfg, plan, states=state, decode_pos=pos
        )

    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)[:, 0]
    if cfg.logit_softcap:
        logits = cfg.logit_softcap * jnp.tanh(logits / cfg.logit_softcap)
    return logits, new_states
