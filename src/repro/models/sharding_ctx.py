"""Activation-sharding context.

Model code is mesh-agnostic; the launcher installs a constrainer that maps
logical names to ``jax.lax.with_sharding_constraint`` on the live mesh.
On a single CPU device (tests) nothing is installed and ``constrain`` is a
no-op. Names: act (B,S,D), tokens (B,S), logits (B,S,V), moe_buf (E,C,D),
kv (B,T,H,dh), heads (B,S,H,dh).
"""

from __future__ import annotations

from typing import Callable

import jax.numpy as jnp

_CONSTRAINER: Callable[[jnp.ndarray, str], jnp.ndarray] | None = None
_MOE_CTX: dict | None = None   # {"mesh", "dp", "tp"} -> shard_map a2a dispatch


def set_constrainer(fn: Callable[[jnp.ndarray, str], jnp.ndarray] | None) -> None:
    global _CONSTRAINER
    _CONSTRAINER = fn


def constrain(x: jnp.ndarray, name: str) -> jnp.ndarray:
    if _CONSTRAINER is None:
        return x
    return _CONSTRAINER(x, name)


def set_moe_ctx(info: dict | None) -> None:
    """Enable the explicit all_to_all MoE dispatch (§Perf A2) under a mesh."""
    global _MOE_CTX
    _MOE_CTX = info


def get_moe_ctx() -> dict | None:
    return _MOE_CTX
