"""Explicit all_to_all MoE dispatch (§Perf A2) — shard_map island.

Why: under pure GSPMD the sort-based dispatch's scatter/gather over the
token dim cannot be partitioned; the partitioner falls back to
all-gathering the (T·K, D) permutation buffers — measured 378 GiB/chip
PER LAYER on qwen3-moe train_4k. The physical traffic a switch dispatch
needs is one all_to_all of the dispatched rows: ~0.27 GiB/chip/layer.

Design (GShard/Switch semantics, one shard_map per MoE layer):

  * tokens arrive sharded (B over dp, S over tp) — each chip routes its
    own T_loc tokens with a LOCAL sort into an (E, C_loc, D) buffer;
  * lax.all_to_all over the tp/EP axis regroups expert-major:
    (E, C_loc, D) -> (E/tp, tp·C_loc, D) — rows land on their expert's
    owner chip (experts are sharded E over tp);
  * batched expert GEMMs with the LOCAL expert slice (weights enter the
    shard_map with spec P(tp, None, None): FSDP'd masters are re-gathered
    over data at entry, exactly weight-gather semantics);
  * reverse all_to_all, local combine with router gates.

Differentiable end-to-end (all_to_all transposes to all_to_all; routing
indices are integer -> no grads). Capacity is per-shard, so token drops
match the reference only when capacity_factor is generous — the
train-quality impact of per-shard capacity is standard (Switch) and
covered by tests at cf=2.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _local_dispatch(flat, ids, k, e, cap):
    """Sort-based dispatch of local tokens -> (E, cap, D) + combine info."""
    t = flat.shape[0]
    flat_ids = ids.reshape(t * k)
    order = jnp.argsort(flat_ids)
    sorted_ids = flat_ids[order]
    tok_of = order // k
    start = jnp.searchsorted(sorted_ids, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * k) - start[sorted_ids]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_ids * cap + pos_in_e, e * cap)
    buf = jnp.zeros((e * cap + 1, flat.shape[1]), flat.dtype)
    buf = buf.at[dest].set(flat[tok_of])
    return buf[: e * cap], (order, tok_of, dest, keep)


def apply_moe_a2a(
    p: dict, x: jnp.ndarray, cfg: ModelConfig, mesh, dp_axes, tp_axis: str,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Drop-in replacement for moe.apply_moe under a live mesh."""
    m = cfg.moe
    tp = mesh.devices.shape[list(mesh.axis_names).index(tp_axis)]
    assert m.n_experts % tp == 0, (m.n_experts, tp)

    w_specs = {
        "router": P(None, None),
        "w_gate": P(tp_axis, None, None),
        "w_up": P(tp_axis, None, None),
        "w_down": P(tp_axis, None, None),
    }
    if "shared" in p:
        w_specs["shared"] = {
            "w_gate": P(None, tp_axis),
            "w_up": P(None, tp_axis),
            "w_down": P(tp_axis, None),
        }
    # local shapes must divide the mesh axes exactly inside shard_map
    # (microbatched train steps can shrink the batch below the dp size) —
    # drop an axis to replication when it doesn't divide; the psum'd aux
    # ratios are replication-invariant (numerator and denominator scale).
    dp_tuple = dp_axes if isinstance(dp_axes, tuple) else (dp_axes,)
    dp_size = 1
    for a in dp_tuple:
        dp_size *= mesh.devices.shape[list(mesh.axis_names).index(a)]
    dp_used = dp_axes if x.shape[0] % dp_size == 0 else None
    seq_used = tp_axis if x.shape[1] % tp == 0 else None
    x_spec = P(dp_used, seq_used, None)

    def inner(p_loc, x_loc):
        b, s, d = x_loc.shape
        t = b * s
        k, e = m.top_k, m.n_experts
        flat = x_loc.reshape(t, d)

        logits = (flat @ p_loc["router"]).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gates, ids = jax.lax.top_k(probs, k)
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

        # Switch aux loss over the GLOBAL token population
        me_sum = jnp.sum(probs, axis=0)
        ce_sum = jnp.sum(jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), 1), 0)
        axes = (*dp_axes, tp_axis) if isinstance(dp_axes, tuple) else (dp_axes, tp_axis)
        me_sum = jax.lax.psum(me_sum, axes)
        ce_sum = jax.lax.psum(ce_sum, axes)
        n_tok = jax.lax.psum(jnp.float32(t), axes)
        aux = e * jnp.sum((me_sum / n_tok) * (ce_sum / n_tok)) * m.router_aux_loss

        cap = int(t * k / e * m.capacity_factor)
        cap = max(8, -(-cap // 8) * 8)
        ebuf, (order, tok_of, dest, keep) = _local_dispatch(flat, ids, k, e, cap)
        ebuf = ebuf.reshape(e, cap, d)

        # dispatch rows to the expert owners: (E, C, D) -> (E/tp, tp*C, D)
        ebuf = jax.lax.all_to_all(
            ebuf, tp_axis, split_axis=0, concat_axis=1, tiled=True
        )

        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ebuf, p_loc["w_gate"]))
        h = h * jnp.einsum("ecd,edf->ecf", ebuf, p_loc["w_up"])
        out_e = jnp.einsum("ecf,efd->ecd", h, p_loc["w_down"])

        # return rows: (E/tp, tp*C, D) -> (E, C, D)
        out_e = jax.lax.all_to_all(
            out_e, tp_axis, split_axis=1, concat_axis=0, tiled=True
        )

        out_flat = out_e.reshape(e * cap, d)
        gathered = jnp.where(
            keep[:, None], out_flat[jnp.clip(dest, 0, e * cap - 1)], 0.0
        )
        gate_of = gates.reshape(t * k)[order]
        out_tok = jnp.zeros((t, d), jnp.float32)
        out_tok = out_tok.at[tok_of].add(
            gathered.astype(jnp.float32) * gate_of[:, None]
        )

        if "shared" in p_loc:
            sp = p_loc["shared"]
            hs = jax.nn.silu(flat @ sp["w_gate"]) * (flat @ sp["w_up"])
            out_tok = out_tok + jax.lax.psum(
                (hs @ sp["w_down"]).astype(jnp.float32), tp_axis
            )

        return out_tok.astype(x_loc.dtype).reshape(b, s, d), aux

    out, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(w_specs, x_spec),
        out_specs=(x_spec, P()),
        check_rep=False,
    )({k_: p[k_] for k_ in w_specs}, x)
    return out, aux
