"""Shared layers: norms, dense init, rotary embeddings, parallel plan.

Everything is pure-functional: ``init_*`` builds param pytrees, ``spec_*``
builds the matching PartitionSpec pytrees, apply functions are plain jnp.
Sharding is expressed once, at the jit boundary (launch/), from the spec
pytrees — model code stays mesh-agnostic so the same functions run on one
CPU device in tests and on the 512-chip mesh in the dry-run.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


# ---------------------------------------------------------------------------
# Parallelism plan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ParallelPlan:
    """How one arch maps onto the mesh. tp = size of the tensor axis."""

    tp: int = 1
    fsdp: bool = False                   # ZeRO-3 param shard over the data axis
    tp_axis: str = "model"
    fsdp_axis: str | tuple = "data"
    dp_axes: tuple[str, ...] = ("data",)

    # -- head bookkeeping (DESIGN.md §4) ------------------------------------

    def pad_heads(self, n_heads: int) -> int:
        """Q heads padded up to a multiple of the tensor axis."""
        return int(math.ceil(n_heads / self.tp) * self.tp)

    def stored_kv_heads(self, n_kv: int, n_heads: int) -> int:
        """KV heads physically stored (vLLM-style group replication):
        lcm(n_kv, tp) when it divides the padded Q heads, else full
        MHA-ization (each padded Q head gets its own copy)."""
        padded_q = self.pad_heads(n_heads)
        stored = math.lcm(n_kv, self.tp)
        if padded_q % stored != 0:
            stored = padded_q
        return stored

    # -- common specs --------------------------------------------------------

    @property
    def _w_in(self) -> str | None:
        return self.fsdp_axis if self.fsdp else None

    def spec_embed(self) -> P:          # (V, D)
        return P(self.tp_axis, self._w_in)

    def spec_proj_out_tp(self) -> P:    # (D, inner): inner sharded on tp
        return P(self._w_in, self.tp_axis)

    def spec_proj_in_tp(self) -> P:     # (inner, D): inner sharded on tp
        return P(self.tp_axis, self._w_in)

    def spec_bias_tp(self) -> P:
        return P(self.tp_axis)

    def spec_replicated(self) -> P:
        return P()

    def spec_activations(self) -> P:    # (B, S, D)
        return P(self.dp_axes, None, None)

    def spec_tokens(self) -> P:         # (B, S)
        return P(self.dp_axes, None)


DEFAULT_PLAN = ParallelPlan()


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * gamma.astype(jnp.float32)).astype(dt)


def layer_norm(x, gamma, beta, eps: float = 1e-5):
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    out = (x32 - mu) * jax.lax.rsqrt(var + eps) * gamma + beta
    return out.astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (B, S, H, dh), positions: (B, S) or (S,) int32."""
    dh = x.shape[-1]
    freqs = rope_freqs(dh, theta)                       # (dh/2,)
    if positions.ndim == 1:
        positions = positions[None, :]
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, dh/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, kind: str, dtype=jnp.float32) -> dict:
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(ks[0], d, d_ff, dtype),
            "w_up": dense_init(ks[1], d, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d, dtype),
        }
    if kind == "gelu":
        return {
            "w_up": dense_init(ks[0], d, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": dense_init(ks[1], d_ff, d, dtype),
            "b_down": jnp.zeros((d,), dtype),
        }
    raise ValueError(kind)


def spec_mlp(kind: str, plan: ParallelPlan) -> dict:
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": plan.spec_proj_out_tp(),
            "w_up": plan.spec_proj_out_tp(),
            "w_down": plan.spec_proj_in_tp(),
        }
    return {
        "w_up": plan.spec_proj_out_tp(),
        "b_up": plan.spec_bias_tp(),
        "w_down": plan.spec_proj_in_tp(),
        "b_down": plan.spec_replicated(),
    }


def apply_mlp(p: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        return (jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "geglu":
        return (jax.nn.gelu(x @ p["w_gate"]) * (x @ p["w_up"])) @ p["w_down"]
    if kind == "gelu":
        return (jax.nn.gelu(x @ p["w_up"] + p["b_up"])) @ p["w_down"] + p["b_down"]
    raise ValueError(kind)
