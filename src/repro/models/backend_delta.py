"""Delta-gated incremental backend: cross-frame reuse of ViT work
(DESIGN.md §14).

The temporal frontend (§6) guarantees that a held token's served wire row
is BITWISE unchanged across frames — same int8 codes, same droop gain,
same patch index, same valid bit. Every per-token computation downstream
of the wire is deterministic arithmetic on that row, so an unchanged row
reproduces its layer-0 embedding (and Q/K/V projections) bitwise for
free. Only attention MIXES rows: one changed key perturbs every query's
output. That dichotomy fixes the minimal cache: per-layer block OUTPUTS
(the next layer's inputs), plus the wire key to detect changes and the
final logits/saliency to serve fully-cached frames.

The delta encoder therefore runs one of three regimes per frame:

* **Fully cached** — no valid wire row changed and the valid pattern is
  intact: a whole-batch ``lax.cond`` skips the entire encoder and serves
  the cached logits/saliency bitwise. Zero backend MACs; this is the
  static-scene fast path.
* **Exact (eps <= 0)** — some rows changed: layer inputs that are
  bitwise-unchanged reuse cached outputs EXACTLY; the moment any valid
  row at a layer changed, that layer's attention re-mixes everything
  (``q_stale`` broadcasts to all rows), reproducing the dense encoder
  bitwise over full trajectories — the same discipline as temporal
  threshold 0 (§6).
* **Budgeted (eps > 0)** — rows whose recomputed output moved by at most
  ``eps`` (inf-norm) snap back to their cached value, so small drift
  (droop, low-amplitude motion) stops propagating. The approximation is
  measured, not assumed: tests assert the logit error against the dense
  encoder and its growth in eps.

``BackendCache`` follows the ``StreamState.cache`` playbook (§6): a
slot-major NamedTuple pytree that jits/donates/shards with the slot
axis, admit-wipes via :func:`wipe_rows`, and holds exactly one trace
across churn because every leaf keeps a fixed shape/dtype.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import power as power_mod
from repro.models.layers import apply_mlp, rms_norm


class BackendCache(NamedTuple):
    """Per-slot backend reuse state (leading dims = batch/slot axes).

    ``feats``/``gain``/``indices``/``tvalid`` are the *reuse key*: a row
    is unchanged iff all four match bitwise (gain matters — droop scales
    the dequant, so a decayed hold is a different embedding; index
    matters — the positional embedding rides on it). ``x_out[l]`` is
    layer ``l``'s block output == layer ``l+1``'s input. ``logits`` /
    ``received`` serve fully-cached frames; ``valid`` is False until the
    slot's first computed frame (admit wipes it)."""

    feats: jnp.ndarray     # (..., k, M) wire payload (int8 codes / bool signs)
    gain: jnp.ndarray      # (..., k)    f32 held-charge gain
    indices: jnp.ndarray   # (..., k)    i32 patch indices
    tvalid: jnp.ndarray    # (..., k)    bool token-valid pattern
    x_out: jnp.ndarray     # (..., L, k, d) f32 per-layer block outputs
    logits: jnp.ndarray    # (..., C)    f32 cached class logits
    received: jnp.ndarray  # (..., k)    f32 cached saliency (pre-mask)
    valid: jnp.ndarray     # (...,)      bool slot has a computed frame


def init_backend_cache(
    cfg, k: int, batch_shape: tuple = (), dtype=jnp.int8
) -> BackendCache:
    """Empty cache for ``cfg`` (a ``ViTConfig``) serving ``k`` compact
    tokens per frame. ``dtype`` must match the served wire payload
    (int8 code wire / bool sign wire) — the engine builds it from its
    FeatureCache dtype so the two caches cannot disagree."""
    m = cfg.frontend.patch.n_vectors
    return BackendCache(
        feats=jnp.zeros(batch_shape + (k, m), dtype),
        gain=jnp.zeros(batch_shape + (k,), jnp.float32),
        indices=jnp.zeros(batch_shape + (k,), jnp.int32),
        tvalid=jnp.zeros(batch_shape + (k,), bool),
        x_out=jnp.zeros(batch_shape + (cfg.n_layers, k, cfg.d_model),
                        jnp.float32),
        logits=jnp.zeros(batch_shape + (cfg.n_classes,), jnp.float32),
        received=jnp.zeros(batch_shape + (k,), jnp.float32),
        valid=jnp.zeros(batch_shape, bool),
    )


def wipe_rows(bc: BackendCache, hit: jnp.ndarray) -> BackendCache:
    """Zero every leaf of the slots flagged in ``hit`` (the admit wipe —
    a newly admitted stream must not reuse its predecessor's
    activations). Dtype-preserving broadcast-where, same idiom as the
    engine's FeatureCache wipe, so churn never retraces."""

    def wipe(leaf):
        h = hit.reshape(hit.shape + (1,) * (leaf.ndim - hit.ndim))
        return jnp.where(h, jnp.zeros((), leaf.dtype), leaf)

    return BackendCache(*(wipe(leaf) for leaf in bc))


def _stale_prefix_counts(q_stale: jnp.ndarray) -> jnp.ndarray:
    """Per-slot prefix length covering every stale query row: the ragged
    kernel banks over ``[0, count)`` (§11 machinery), so staleness
    anywhere costs up to its last stale position. Stale-first rankings
    make this exactly the stale count; arbitrary patterns over-cover but
    never under-cover."""
    k = q_stale.shape[-1]
    pos = jnp.arange(1, k + 1, dtype=jnp.int32)
    return jnp.max(jnp.where(q_stale, pos, 0), axis=-1).astype(jnp.int32)


def delta_forward(
    params: dict,
    cfg,
    cf,
    embed_fn,
    bc: BackendCache,
    eps: jnp.ndarray,
    act: jnp.ndarray | None = None,
):
    """Delta-gated encoder over the compact wire ``cf`` (a
    ``CompactFeatures``) against cache ``bc``.

    ``embed_fn()`` produces the embedded token block (B, k, d) — passed
    as a closure so the fully-cached branch never runs the embed matmul.
    ``eps`` is the per-slot (B,) inf-norm snap budget; ``eps <= 0``
    selects the exact regime for that slot.

    Returns ``(logits, received, new_bc, macs)`` — ``received`` is the
    raw (pre-mask) saliency matching ``_encoder``'s contract, ``macs``
    the per-slot executed-MAC count for the event ledger (§14), zero on
    fully-cached frames.

    ``act`` is an optional (B,) bool mask of the slots that actually
    advance this frame (the engine's ``active & fed``): slots outside it
    are excluded from the whole-batch skip predicate — a held or empty
    slot (whose cache rows never match its garbage wire bytes) must not
    force a compute frame on a fleet whose served slots are all cached.
    Those slots' outputs are garbage either way; the caller freezes them.
    """
    from repro.models import vit as vit_mod  # lazy: vit imports this module

    token_valid = cf.valid
    n_layers = len(params["layers"])
    # the reuse key: all four components must match bitwise
    same = (
        jnp.all(cf.features == bc.feats, axis=-1)
        & (cf.gain == bc.gain)
        & (cf.indices == bc.indices)
        & (cf.valid == bc.tvalid)
        & bc.valid[..., None]
    )
    s0 = ~same
    # rows entering OR leaving the valid set both change the logits (the
    # attention mask is part of the computation), so the skip predicate
    # spans the union of the old and new valid patterns
    gate = s0 & (token_valid | bc.tvalid)
    if act is not None:
        gate = gate & act[..., None]
    run = jnp.any(gate)
    # a changed mask re-mixes every layer-0 attention row even when all
    # currently-valid rows held their values
    mask_changed = jnp.any(cf.valid != bc.tvalid, axis=-1) | ~bc.valid

    def _cached(_):
        zero = jnp.zeros(bc.valid.shape, jnp.float32)
        return bc.logits, bc.received, bc, zero

    def _compute(_):
        exact = eps <= 0.0
        x = embed_fn()
        qv = token_valid.astype(jnp.float32)
        n_q = jnp.maximum(jnp.sum(qv, axis=-1, keepdims=True), 1.0)
        received = jnp.zeros(x.shape[:2], jnp.float32)
        s = s0
        outs, j_qkv, q_attn = [], [], []
        for li, lp in enumerate(params["layers"]):
            any_l = jnp.any(s & token_valid, axis=-1)
            if li == 0:
                any_l = any_l | mask_changed
            # exact slots: one changed key re-mixes every query (§14)
            q_stale = s | (any_l & exact)[:, None]
            need = (cfg.saliency_layers == "all") or (li == n_layers - 1)
            h = rms_norm(x, lp["norm1"], cfg.norm_eps)
            use_kernel = cfg.delta_kernel and not need and not cfg.qth
            if use_kernel:
                from repro.kernels import ops  # lazy: keep the model import-light

                counts = _stale_prefix_counts(q_stale)
                out = ops.delta_attention(
                    lp["attn"], h, token_valid, counts, cfg.n_heads)
                probs = None
                covered = jnp.arange(h.shape[1])[None, :] < counts[:, None]
            else:
                out, probs = vit_mod._encoder_attention(
                    lp, h, cfg, token_valid, need_probs=need)
                covered = None
            x_mid = x + out
            full = x_mid + apply_mlp(
                lp["mlp"], rms_norm(x_mid, lp["norm2"], cfg.norm_eps), "gelu")
            cached = bc.x_out[:, li]
            delta = jnp.max(jnp.abs(full - cached), axis=-1)
            # exact: the bitwise q_stale rule; budgeted: snap rows whose
            # TRUE recomputed output moved by <= eps back to the cache
            keep = jnp.where(exact[:, None], q_stale, delta > eps[:, None])
            keep = keep | ~bc.valid[:, None]
            if covered is not None:
                # the kernel only recomputed the stale prefix; rows past
                # it hold garbage and must stay on their cached values
                keep = keep & covered
            x = jnp.where(keep[..., None], full, cached)
            outs.append(x)
            j_qkv.append(jnp.sum(s & token_valid, axis=-1)
                         .astype(jnp.float32))
            q_attn.append(jnp.sum(q_stale & token_valid, axis=-1)
                          .astype(jnp.float32))
            if need:
                per_key = jnp.einsum(
                    "bhqs,bq->bs", probs.astype(jnp.float32), qv)
                received = received + per_key / (n_q * probs.shape[1])
            s = keep
        xf = rms_norm(x, params["final_norm"], cfg.norm_eps)
        w = token_valid.astype(xf.dtype)[..., None]
        pooled = jnp.sum(xf * w, axis=1) / jnp.maximum(jnp.sum(w, axis=1), 1.0)
        logits = pooled @ params["head"]
        if cfg.saliency_layers == "all":
            received = received / n_layers
        macs = power_mod.backend_frame_macs(
            cfg.frontend.patch.n_vectors, cfg.d_model, cfg.d_ff,
            cfg.n_classes,
            j_embed=jnp.sum(s0 & token_valid, axis=-1).astype(jnp.float32),
            j_qkv=j_qkv, q_attn=q_attn,
            n_keys=jnp.sum(token_valid, axis=-1).astype(jnp.float32),
            computed=1.0,
        )
        new_bc = BackendCache(
            feats=cf.features, gain=cf.gain, indices=cf.indices,
            tvalid=cf.valid, x_out=jnp.stack(outs, axis=1),
            logits=logits, received=received,
            valid=jnp.ones(bc.valid.shape, bool),
        )
        return logits, received, new_bc, macs

    return jax.lax.cond(run, _compute, _cached, None)
