from repro.models.layers import DEFAULT_PLAN, ParallelPlan
from repro.models.lm import (
    decode_state_specs,
    decode_step,
    forward,
    init_decode_state,
    init_params,
    loss_fn,
    param_specs,
    prefill,
)

__all__ = [
    "DEFAULT_PLAN", "ParallelPlan",
    "decode_state_specs", "decode_step", "forward", "init_decode_state",
    "init_params", "loss_fn", "param_specs", "prefill",
]
