"""Mixture-of-Experts FFN with sort-based dispatch (expert parallel).

MaxText-style token permutation instead of GShard one-hot einsums: the
(T·K, E, C) dispatch tensor would be ~10^12 elements at our shapes, while
the sort route costs O(T·K log) index work plus two gathers/scatters and
materializes only the (E·C, D) expert buffer — which shards over the
tensor axis (experts) and the data axis (capacity).

Per MoE layer:
  router logits -> top-k -> flatten (T·K) assignments -> argsort by expert
  -> position-in-expert via running count -> capacity-clip -> scatter into
  (E, C, D) -> batched expert GEMMs (E-sharded) -> gather back + combine
  with router gates.  Aux load-balance loss (Switch-style) is returned for
  the trainer.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParallelPlan, dense_init
from repro.models.sharding_ctx import constrain


def init_moe(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    m = cfg.moe
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    def experts(k, a, b, n):
        kk = jax.random.split(k, n)
        return jnp.stack([dense_init(kk[i], a, b, dtype) for i in range(n)])
    p = {
        "router": dense_init(ks[0], d, m.n_experts, dtype),
        "w_gate": experts(ks[1], d, m.d_expert, m.n_experts),
        "w_up": experts(ks[2], d, m.d_expert, m.n_experts),
        "w_down": experts(ks[3], m.d_expert, d, m.n_experts),
    }
    if m.n_shared_experts:
        dsh = m.d_expert * m.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(kk[0], d, dsh, dtype),
            "w_up": dense_init(kk[1], d, dsh, dtype),
            "w_down": dense_init(kk[2], dsh, d, dtype),
        }
    return p


def spec_moe(cfg: ModelConfig, plan: ParallelPlan) -> dict:
    from jax.sharding import PartitionSpec as P

    w_in = plan.fsdp_axis if plan.fsdp else None
    s = {
        "router": P(None, None),
        # experts sharded over the tensor axis (EP == TP axis)
        "w_gate": P(plan.tp_axis, w_in, None),
        "w_up": P(plan.tp_axis, w_in, None),
        "w_down": P(plan.tp_axis, None, w_in),
    }
    if cfg.moe.n_shared_experts:
        s["shared"] = {
            "w_gate": P(w_in, plan.tp_axis),
            "w_up": P(w_in, plan.tp_axis),
            "w_down": P(plan.tp_axis, w_in),
        }
    return s


def apply_moe(p: dict, x: jnp.ndarray, cfg: ModelConfig) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x: (B, S, D) -> (out (B,S,D), aux_loss scalar)."""
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    k = m.top_k
    e = m.n_experts
    flat = x.reshape(t, d)

    logits = (flat @ p["router"]).astype(jnp.float32)          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, ids = jax.lax.top_k(probs, k)                       # (T, K)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # Switch aux loss: E * sum_e f_e * p_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(ids, e, dtype=jnp.float32), axis=1), axis=0
    )
    aux = e * jnp.sum(me * ce) * m.router_aux_loss

    # --- sort-based dispatch -------------------------------------------
    cap = int(t * k / e * m.capacity_factor)
    cap = max(8, -(-cap // 8) * 8)                             # pad to 8
    flat_ids = ids.reshape(t * k)                              # (TK,)
    order = jnp.argsort(flat_ids)                              # stable
    sorted_ids = flat_ids[order]
    tok_of = order // k                                        # source token
    # position within each expert's run
    start = jnp.searchsorted(sorted_ids, jnp.arange(e), side="left")
    pos_in_e = jnp.arange(t * k) - start[sorted_ids]
    keep = pos_in_e < cap
    dest = jnp.where(keep, sorted_ids * cap + pos_in_e, e * cap)  # overflow slot

    permuted = constrain(flat[tok_of], "moe_tokens")           # (TK, D)
    buf = jnp.zeros((e * cap + 1, d), x.dtype)
    buf = buf.at[dest].set(permuted)
    eb = buf[: e * cap].reshape(e, cap, d)                     # (E, C, D)
    eb = constrain(eb, "moe_buf")                # E over tp, C over dp

    # --- expert GEMMs (E-sharded batched matmul) ------------------------
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", eb, p["w_gate"]))
    h = h * jnp.einsum("ecd,edf->ecf", eb, p["w_up"])
    out_e = jnp.einsum("ecf,efd->ecd", h, p["w_down"])          # (E, C, D)

    # --- combine ---------------------------------------------------------
    out_flat = out_e.reshape(e * cap, d)
    out_tok = jnp.zeros((t, d), jnp.float32)
    gathered = jnp.where(keep[:, None], out_flat[jnp.clip(dest, 0, e * cap - 1)], 0.0)
    gathered = constrain(gathered, "moe_tokens")               # (TK, D)
    gate_of = gates.reshape(t * k)[order]
    out_tok = out_tok.at[tok_of].add(gathered.astype(jnp.float32) * gate_of[:, None])

    if "shared" in p:
        sp = p["shared"]
        hs = jax.nn.silu(flat @ sp["w_gate"]) * (flat @ sp["w_up"])
        out_tok = out_tok + (hs @ sp["w_down"]).astype(jnp.float32)

    return out_tok.astype(x.dtype).reshape(b, s, d), aux
