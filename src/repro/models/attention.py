"""GQA attention: flash-style chunked prefill, KV-cached decode, local
windows, RoPE, TP head padding + KV group replication (DESIGN.md §4).

Memory discipline: prefill never materializes the (S, S) score matrix —
keys/values are scanned in chunks with an online-softmax accumulator
(flash attention in pure JAX; on TPU the chunk loop pipelines HBM->VMEM).
Decode attends one query against the cache with a plain einsum.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParallelPlan, apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------

def head_geometry(cfg: ModelConfig, plan: ParallelPlan) -> tuple[int, int]:
    """(padded q heads, stored kv heads) for this arch under this plan."""
    hq = plan.pad_heads(cfg.n_heads)
    hkv = plan.stored_kv_heads(cfg.n_kv_heads, cfg.n_heads)
    return hq, hkv


def init_attention(key, cfg: ModelConfig, plan: ParallelPlan, dtype=jnp.float32) -> dict:
    hq, hkv = head_geometry(cfg, plan)
    d, dh = cfg.d_model, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d, hq * dh, dtype).reshape(d, hq, dh),
        "wk": dense_init(ks[1], d, hkv * dh, dtype).reshape(d, hkv, dh),
        "wv": dense_init(ks[2], d, hkv * dh, dtype).reshape(d, hkv, dh),
        "wo": dense_init(ks[3], hq * dh, d, dtype).reshape(hq, dh, d),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((hq, dh), dtype)
        p["bk"] = jnp.zeros((hkv, dh), dtype)
        p["bv"] = jnp.zeros((hkv, dh), dtype)
    return p


def spec_attention(cfg: ModelConfig, plan: ParallelPlan) -> dict:
    from jax.sharding import PartitionSpec as P

    w_in = plan.fsdp_axis if plan.fsdp else None
    s = {
        "wq": P(w_in, plan.tp_axis, None),
        "wk": P(w_in, plan.tp_axis, None),
        "wv": P(w_in, plan.tp_axis, None),
        "wo": P(plan.tp_axis, None, w_in),
    }
    if cfg.qkv_bias:
        s["bq"] = P(plan.tp_axis, None)
        s["bk"] = P(plan.tp_axis, None)
        s["bv"] = P(plan.tp_axis, None)
    return s


# ---------------------------------------------------------------------------
# Flash-style chunked attention (prefill / training)
# ---------------------------------------------------------------------------

def _flash_attend(
    q: jnp.ndarray,          # (B, S, H, dh) — post-RoPE
    k: jnp.ndarray,          # (B, T, H, dh) — kv already expanded to H
    v: jnp.ndarray,          # (B, T, H, dh)
    causal: bool,
    window: int | None,
    q_offset: int = 0,
    chunk: int = 1024,
) -> jnp.ndarray:
    b, s, h, dh = q.shape
    t = k.shape[1]
    chunk = min(chunk, t)
    n_chunks = -(-t // chunk)
    tpad = n_chunks * chunk
    if tpad != t:
        pad = [(0, 0), (0, tpad - t), (0, 0), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kc = k.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, dh).transpose(1, 0, 2, 3, 4)

    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    q32 = q.astype(jnp.float32) * scale
    qpos = q_offset + jnp.arange(s)

    def step(carry, inp):
        m, l, acc = carry
        idx, kb, vb = inp                      # kb/vb: (B, C, H, dh)
        kpos = idx * chunk + jnp.arange(chunk)
        sc = jnp.einsum("bshd,bchd->bhsc", q32, kb.astype(jnp.float32))
        mask = kpos[None, :] <= (t - 1)        # strip T padding
        if causal:
            mask = mask & (kpos[None, :] <= qpos[:, None])
        if window is not None:
            mask = mask & (kpos[None, :] > qpos[:, None] - window)
        sc = jnp.where(mask[None, None, :, :], sc, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhsc,bchd->bshd", p, vb.astype(jnp.float32)
        ).transpose(0, 2, 1, 3)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, h, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, s), jnp.float32)
    a0 = jnp.zeros((b, h, s, dh), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), (jnp.arange(n_chunks), kc, vc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 2, 1, 3).astype(q.dtype)   # (B, S, H, dh)


def _expand_kv(kv: jnp.ndarray, n_q_heads: int) -> jnp.ndarray:
    """(B, T, Hkv, dh) -> (B, T, Hq, dh): q head i uses kv head i // g.

    Broadcast+reshape, NOT a gather: a gather over the TP-sharded head dim
    makes GSPMD all-gather the cache and replicate attention compute; the
    broadcast keeps the stored-head sharding and fuses into the matmul.
    """
    b, t, hkv, dh = kv.shape
    if hkv == n_q_heads:
        return kv
    assert n_q_heads % hkv == 0, (n_q_heads, hkv)
    g = n_q_heads // hkv
    return jnp.broadcast_to(
        kv[:, :, :, None, :], (b, t, hkv, g, dh)
    ).reshape(b, t, n_q_heads, dh)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def attention_forward(
    p: dict,
    x: jnp.ndarray,                 # (B, S, D)
    cfg: ModelConfig,
    positions: jnp.ndarray,         # (S,) or (B, S)
    causal: bool = True,
    window: int | None = None,
    kv_override: jnp.ndarray | None = None,   # (B, T, D) for cross-attn
    use_rope: bool = True,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Full-sequence attention. Returns (out (B,S,D), (k, v) for caching)."""
    # roofline instrumentation: one KV chunk => the scan body IS the whole
    # attention, so XLA cost_analysis counts its FLOPs exactly once
    chunk = 10**9 if cfg.unroll_layers else 1024
    src = x if kv_override is None else kv_override
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", src, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        if kv_override is None:
            k = apply_rope(k, positions, cfg.rope_theta)
    hq = q.shape[2]
    out = _flash_attend(
        q, _expand_kv(k, hq), _expand_kv(v, hq), causal=causal, window=window,
        chunk=chunk,
    )
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, (k, v)


# ---------------------------------------------------------------------------
# int8 KV cache (§Perf B2): per-(position, head) symmetric quantization
# ---------------------------------------------------------------------------

def quantize_kv(kv: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(B, T, H, dh) -> (int8 codes, (B, T, H) fp32 scales)."""
    amax = jnp.max(jnp.abs(kv.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    codes = jnp.clip(
        jnp.round(kv.astype(jnp.float32) / scale[..., None]), -127, 127
    ).astype(jnp.int8)
    return codes, scale


def _dequant_operand(cache: jnp.ndarray, scales: dict | None, which: str):
    """Matrix to contract against + per-(B,T,H) scale to fold in (or None)."""
    if cache.dtype == jnp.int8:
        return cache.astype(jnp.bfloat16), scales[which]
    return cache, None


def attention_decode(
    p: dict,
    x: jnp.ndarray,                 # (B, 1, D)
    cache_k: jnp.ndarray,           # (B, T, Hkv, dh) rolling or full buffer
    cache_v: jnp.ndarray,
    pos: jnp.ndarray,               # scalar int32 — absolute position
    cfg: ModelConfig,
    window: int | None = None,
    use_rope: bool = True,
    cache_scales: dict | None = None,   # {"k","v"}: (B,T,Hkv) for int8 cache
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray, dict | None]:
    """One decode step. Writes (k,v) at ``pos`` (mod T for local windows),
    attends over valid cache, returns (out (B,1,D), new_k, new_v, scales)."""
    b = x.shape[0]
    t = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    pos_b = jnp.broadcast_to(pos[None], (b,)) if pos.ndim == 0 else pos
    if use_rope:
        q = apply_rope(q, pos_b[:, None], cfg.rope_theta)
        k = apply_rope(k, pos_b[:, None], cfg.rope_theta)

    slot = pos % t if window is not None else pos
    if cache_k.dtype == jnp.int8:
        k8, ks = quantize_kv(k)
        v8, vs = quantize_kv(v)
        cache_scales = {
            "k": jax.lax.dynamic_update_slice_in_dim(cache_scales["k"], ks, slot, 1),
            "v": jax.lax.dynamic_update_slice_in_dim(cache_scales["v"], vs, slot, 1),
        }
        k, v = k8, v8
    new_k = jax.lax.dynamic_update_slice_in_dim(cache_k, k.astype(cache_k.dtype), slot, 1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache_v, v.astype(cache_v.dtype), slot, 1)

    # grouped-query attention without expanding the cache: q heads reshaped
    # to (stored_kv, group) so the einsums contract against the cache
    # directly — the stored-head dim stays TP-sharded, zero comm.
    # Perf (§Perf B1): contract the cache in its STORAGE dtype with fp32
    # accumulation (preferred_element_type) — an explicit .astype(f32) on
    # the cache materializes a cache-sized fp32 copy per layer, doubling
    # the decode step's HBM traffic.
    hq = q.shape[2]
    hkv = new_k.shape[2]
    g = hq // hkv
    dh = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    qg = (q[:, 0] * scale.astype(q.dtype)).reshape(b, hkv, g, dh)
    k_mat, k_scale = _dequant_operand(new_k, cache_scales, "k")
    sc = jnp.einsum(
        "bngd,btnd->bngt", qg.astype(k_mat.dtype), k_mat,
        preferred_element_type=jnp.float32,
    )
    if k_scale is not None:                      # int8 cache: fold scale in
        sc = sc * k_scale.transpose(0, 2, 1)[:, :, None, :]

    tpos = jnp.arange(t)
    if window is not None:
        # rolling buffer: validity = "within the last `window` writes"
        age = (slot - tpos) % t
        valid = age < jnp.minimum(window, pos + 1)
    else:
        valid = tpos <= pos
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    w = jax.nn.softmax(sc, axis=-1)
    v_mat, v_scale = _dequant_operand(new_v, cache_scales, "v")
    if v_scale is not None:                      # fold v scale into weights
        w = w * v_scale.transpose(0, 2, 1)[:, :, None, :]
    out = jnp.einsum(
        "bngt,btnd->bngd", w.astype(v_mat.dtype), v_mat,
        preferred_element_type=jnp.float32,
    )
    out = out.reshape(b, 1, hq, dh).astype(x.dtype)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, new_k, new_v, cache_scales


def make_cache(
    cfg: ModelConfig, plan: ParallelPlan, batch: int, max_len: int,
    window: int | None = None, dtype=jnp.bfloat16,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    _, hkv = head_geometry(cfg, plan)
    t = min(window, max_len) if window is not None else max_len
    shape = (batch, t, hkv, cfg.head_dim)
    return jnp.zeros(shape, dtype), jnp.zeros(shape, dtype)


def make_cache_scales(
    cfg: ModelConfig, plan: ParallelPlan, batch: int, max_len: int,
    window: int | None = None,
) -> dict:
    _, hkv = head_geometry(cfg, plan)
    t = min(window, max_len) if window is not None else max_len
    z = jnp.ones((batch, t, hkv), jnp.float32)
    return {"k": z, "v": z}
