"""IP2-ViT: the paper's backend — patch-token transformer classifier fed by
the IP2 analog frontend (paper §1: "transformer-based backend model for
object classification and detection").

Pipeline per frame:
  RGB scene -> IP2Frontend (AA optics, Bayer, salient-patch analog
  projection, edge ADC) -> per-patch M-dim features == tokens
  -> linear embed -> transformer encoder (optionally with Fig. 4 QTH
  power-of-2 attention) -> masked mean-pool over ACTIVE patches -> classes.

The frontend is differentiable (STE quantizers), so the co-design loop
trains A (the in-pixel weights) jointly with the backend — the study the
paper describes in §1/§2.1.3.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core.frontend import FrontendConfig, apply_frontend, init_frontend_params
from repro.models.layers import DEFAULT_PLAN, apply_mlp, dense_init, init_mlp, rms_norm
from repro.models.attention import init_attention, attention_forward
from repro.configs.base import ModelConfig
from repro.core.qth_attention import QTHSpec, qth_attention


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    frontend: FrontendConfig = FrontendConfig()
    n_classes: int = 4
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    qth: bool = False          # Fig. 4 power-of-2 attention in the backend
    norm_eps: float = 1e-5

    def backbone_cfg(self) -> ModelConfig:
        return ModelConfig(
            name="ip2-vit-backbone", family="vision",
            n_layers=self.n_layers, d_model=self.d_model,
            n_heads=self.n_heads, n_kv_heads=self.n_heads,
            d_ff=self.d_ff, vocab=0, head_dim=self.d_model // self.n_heads,
            mlp_kind="gelu", qkv_bias=True, remat=False,
        )


def init_vit(key, cfg: ViTConfig) -> dict:
    bb = cfg.backbone_cfg()
    ks = jax.random.split(key, cfg.n_layers * 2 + 4)
    p = {
        "ip2": init_frontend_params(ks[0], cfg.frontend),
        "embed": dense_init(ks[1], cfg.frontend.patch.n_vectors, cfg.d_model),
        "pos": jax.random.normal(ks[2], (cfg.frontend.n_patches, cfg.d_model)) * 0.02,
        "layers": [],
        "final_norm": jnp.ones((cfg.d_model,)),
        "head": dense_init(ks[3], cfg.d_model, cfg.n_classes),
    }
    for i in range(cfg.n_layers):
        p["layers"].append({
            "norm1": jnp.ones((cfg.d_model,)),
            "attn": init_attention(ks[4 + 2 * i], bb, DEFAULT_PLAN),
            "norm2": jnp.ones((cfg.d_model,)),
            "mlp": init_mlp(ks[5 + 2 * i], cfg.d_model, cfg.d_ff, "gelu"),
        })
    return p


def vit_forward(params: dict, rgb: jnp.ndarray, cfg: ViTConfig,
                mask=None) -> jnp.ndarray:
    """rgb (B, H, W, 3) -> class logits (B, n_classes)."""
    bb = cfg.backbone_cfg()
    feats, mask = apply_frontend(params["ip2"], rgb, cfg.frontend, mask=mask)
    x = feats @ params["embed"] + params["pos"][None]
    positions = jnp.arange(x.shape[1])
    for lp in params["layers"]:
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        if cfg.qth:
            # Fig. 4: power-of-2 quantized attention coefficients
            d, hd = cfg.d_model, cfg.d_model // cfg.n_heads
            q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"]) + lp["attn"]["bq"]
            k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"]) + lp["attn"]["bk"]
            v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"]) + lp["attn"]["bv"]
            o = qth_attention(
                q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                v.transpose(0, 2, 1, 3), QTHSpec()
            ).transpose(0, 2, 1, 3)
            out = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
        else:
            out, _ = attention_forward(
                lp["attn"], h, bb, positions, causal=False, use_rope=False
            )
        x = x + out
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + apply_mlp(lp["mlp"], h, "gelu")
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    # masked mean pool over the ACTIVE (ADC-converted) patches only
    w = mask.astype(x.dtype)[..., None]
    pooled = jnp.sum(x * w, axis=1) / jnp.maximum(jnp.sum(w, axis=1), 1.0)
    return pooled @ params["head"]


def vit_loss(params, rgb, labels, cfg: ViTConfig):
    logits = vit_forward(params, rgb, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
