"""IP2-ViT: the paper's backend — patch-token transformer classifier fed by
the IP2 analog frontend (paper §1: "transformer-based backend model for
object classification and detection").

Pipeline per frame:
  RGB scene -> IP2Frontend (AA optics, Bayer, salient-patch analog
  projection, edge ADC) -> per-patch M-dim features == tokens
  -> linear embed -> transformer encoder (optionally with Fig. 4 QTH
  power-of-2 attention) -> masked mean-pool over ACTIVE patches -> classes.

Two token layouts feed the same weights (DESIGN.md §4):

* ``vit_forward``          — dense (..., P) token grid with deselected
  patches zero-masked; attention keys are restricted to the active set
  (a powered-down patch stores no charge, so it cannot be attended to).
  Used for training / co-design, where gradients need the full grid.
* ``vit_forward_compact``  — exactly the k active tokens, positional
  embeddings looked up by patch index. Attention cost drops from O(P²) to
  O(k²) (~16x fewer score FLOPs at 25 % activity; ~4x fewer tokens), and
  the two layouts produce identical logits for the same selection.

The compact forward also returns the per-patch attention the backend paid
to each token — the saccade signal that selects the next frame's patches.

The frontend is differentiable (STE quantizers), so the co-design loop
trains A (the in-pixel weights) jointly with the backend — the study the
paper describes in §1/§2.1.3.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import adc as adc_mod
from repro.core import power as power_mod
from repro.core.frontend import (
    CompactFeatures,
    FrontendConfig,
    apply_frontend,
    dequantize_features,
    feature_scale_zero,
    init_frontend_params,
    select_compact,
)
from repro.models.layers import DEFAULT_PLAN, apply_mlp, dense_init, init_mlp, rms_norm
from repro.models.attention import init_attention
from repro.configs.base import ModelConfig
from repro.core.qth_attention import QTHSpec, qth_attention_weights

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class ViTConfig:
    frontend: FrontendConfig = FrontendConfig()
    n_classes: int = 4
    n_layers: int = 4
    d_model: int = 128
    n_heads: int = 4
    d_ff: int = 256
    qth: bool = False          # Fig. 4 power-of-2 attention in the backend
    quant_embed: bool = False  # consume ADC codes via the w8a8 kernel (§9)
    fused_embed: bool = False  # frontend megakernel: project + ADC + embed
                               # in one kernel, codes never leave VMEM (§11);
                               # requires quant_embed and an analog frontend
    saliency_layers: str = "all"  # which layers' attention feeds saccade
                                  # saliency: "all" (mean, the original
                                  # contract) or "last" — serving layers
                                  # before the last then skip materializing
                                  # the (B, H, q, s) probs tensor entirely
    delta_kernel: bool = False    # delta-gated backend only (§14): score
                                  # stale query prefixes with the ragged
                                  # Pallas kernel on layers whose probs are
                                  # not needed (pairs with
                                  # saliency_layers="last"; qth excluded)
    norm_eps: float = 1e-5

    def backbone_cfg(self) -> ModelConfig:
        return ModelConfig(
            name="ip2-vit-backbone", family="vision",
            n_layers=self.n_layers, d_model=self.d_model,
            n_heads=self.n_heads, n_kv_heads=self.n_heads,
            d_ff=self.d_ff, vocab=0, head_dim=self.d_model // self.n_heads,
            mlp_kind="gelu", qkv_bias=True, remat=False,
        )


def init_vit(key, cfg: ViTConfig) -> dict:
    bb = cfg.backbone_cfg()
    ks = jax.random.split(key, cfg.n_layers * 2 + 4)
    p = {
        "ip2": init_frontend_params(ks[0], cfg.frontend),
        "embed": dense_init(ks[1], cfg.frontend.patch.n_vectors, cfg.d_model),
        "pos": jax.random.normal(ks[2], (cfg.frontend.n_patches, cfg.d_model)) * 0.02,
        "layers": [],
        "final_norm": jnp.ones((cfg.d_model,)),
        "head": dense_init(ks[3], cfg.d_model, cfg.n_classes),
    }
    for i in range(cfg.n_layers):
        p["layers"].append({
            "norm1": jnp.ones((cfg.d_model,)),
            "attn": init_attention(ks[4 + 2 * i], bb, DEFAULT_PLAN),
            "norm2": jnp.ones((cfg.d_model,)),
            "mlp": init_mlp(ks[5 + 2 * i], cfg.d_model, cfg.d_ff, "gelu"),
        })
    return p


def _encoder_attention(
    lp: dict, h: jnp.ndarray, cfg: ViTConfig, token_valid: jnp.ndarray,
    need_probs: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray | None]:
    """Bidirectional self-attention over the patch tokens (dense grid or
    compact active set — the sequence axis is whatever it is handed).

    The token sequence is short (P <= a few hundred, k a quarter of that),
    so scores are materialized explicitly; that also yields the attention
    probabilities the saccade loop feeds back as next-frame saliency.
    ``need_probs=False`` (a serving layer whose probs nobody reads)
    returns None in their place so XLA is free to fuse the whole
    softmax→mix chain instead of materializing the (B, H, q, s) tensor
    as a live output — the attention OUTPUT is bitwise identical either
    way (the arithmetic is unchanged; only the extra result is dropped).

    Returns (attn output (B, S, d), probs (B, H, S, S) or None).
    """
    dh = cfg.d_model // cfg.n_heads
    q = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wq"]) + lp["attn"]["bq"]
    k = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wk"]) + lp["attn"]["bk"]
    v = jnp.einsum("bsd,dhk->bshk", h, lp["attn"]["wv"]) + lp["attn"]["bv"]
    scores = jnp.einsum("bqhk,bshk->bhqs", q, k) / jnp.sqrt(jnp.asarray(dh, h.dtype))
    if cfg.qth:
        # Fig. 4: power-of-2 quantized attention coefficients
        probs = qth_attention_weights(scores, QTHSpec(), key_valid=token_valid[:, None])
    else:
        scores = jnp.where(token_valid[:, None, None, :], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhqs,bshk->bqhk", probs.astype(v.dtype), v)
    out = jnp.einsum("bshk,hkd->bsd", o, lp["attn"]["wo"])
    return out, (probs if need_probs else None)


def _encoder(
    params: dict, x: jnp.ndarray, cfg: ViTConfig, token_valid: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Transformer trunk + masked mean pool. Returns (logits, received):
    ``received`` (B, S) is the attention mass each token collected across
    heads/queries — mean over all layers (``cfg.saliency_layers="all"``,
    the original contract) or the last layer alone (``"last"``: earlier
    layers skip the probs materialization entirely; logits are bitwise
    unchanged, only the saliency estimate differs)."""
    if cfg.saliency_layers not in ("all", "last"):
        raise ValueError(
            f"saliency_layers must be 'all' or 'last', "
            f"got {cfg.saliency_layers!r}")
    n_layers = len(params["layers"])
    received = jnp.zeros(x.shape[:2], jnp.float32)
    qv = token_valid.astype(jnp.float32)
    n_q = jnp.maximum(jnp.sum(qv, axis=-1, keepdims=True), 1.0)
    for li, lp in enumerate(params["layers"]):
        need = (cfg.saliency_layers == "all") or (li == n_layers - 1)
        h = rms_norm(x, lp["norm1"], cfg.norm_eps)
        out, probs = _encoder_attention(lp, h, cfg, token_valid,
                                        need_probs=need)
        x = x + out
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        x = x + apply_mlp(lp["mlp"], h, "gelu")
        if need:
            # attention received per key token, averaged over heads and
            # the valid queries (invalid query rows emit garbage probs)
            per_key = jnp.einsum("bhqs,bq->bs", probs.astype(jnp.float32), qv)
            received = received + per_key / (n_q * probs.shape[1])
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    # masked mean pool over the ACTIVE (ADC-converted) patches only
    w = token_valid.astype(x.dtype)[..., None]
    pooled = jnp.sum(x * w, axis=1) / jnp.maximum(jnp.sum(w, axis=1), 1.0)
    logits = pooled @ params["head"]
    if cfg.saliency_layers == "all":
        received = received / n_layers
    return logits, received


def vit_forward(params: dict, rgb: jnp.ndarray, cfg: ViTConfig,
                mask=None, return_aux: bool = False):
    """Dense path: rgb (B, H, W, 3) -> class logits (B, n_classes).

    With ``return_aux=True`` also returns ``{"mask", "saliency"}`` —
    ``saliency`` (B, P) is the backend attention each patch received
    (0 on deselected patches). Together with the selection and a
    ``patch_energy`` pass this lets the dense path act as a saccade
    oracle (see tests/test_system.py, which assembles the full
    ``saccade_scores`` aux from these pieces).
    """
    feats, mask = apply_frontend(params["ip2"], rgb, cfg.frontend, mask=mask)
    x = feats @ params["embed"] + params["pos"][None]
    logits, received = _encoder(params, x, cfg, mask)
    if not return_aux:
        return logits
    saliency = jnp.where(mask, received, 0.0)
    return logits, {"mask": mask, "saliency": saliency}


def prepare_quant_embed(params: dict) -> dict:
    """Serving-time weight prep for ``ViTConfig.quant_embed``: quantize the
    embed matrix to int8 ONCE (the DAC-programmed-once analogue, DESIGN.md
    §9) and stash it as ``params["embed_q"]`` so the hot serving step does
    not re-derive it every frame. Serving only — do not feed the returned
    params to an optimizer (``embed_q`` is frozen int8 prep, not a
    trainable leaf); re-run after any embed update."""
    from repro.kernels import ops  # lazy: keep the model import-light

    return {**params, "embed_q": ops.quantize_weights_int8(params["embed"])}


def _embed_tokens(params: dict, cf: CompactFeatures, cfg: ViTConfig) -> jnp.ndarray:
    """The backend's first matmul — the ONE place the wire format is
    dequantized (DESIGN.md §9).

    Default: fold the static affine into the payload
    (:func:`dequantize_features`) and matmul in float — bit-identical to
    the float-wire path. With ``cfg.quant_embed`` and a code payload, the
    codes feed the w8a8 kernel directly (``ops.quant_matmul_pre``): the
    edge ADC already performed the activation quantization, so there is no
    second rounding of activations — only the embed weights are quantized
    (int8 per-column, once via :func:`prepare_quant_embed` or per call as
    a fallback), and the affine distributes over the matmul:

        ((c·s + z) ⊙ g) @ W  =  g ⊙ (s·(c @ W8)·s_w + z @ dequant(W8))
    """
    feats = cf.features
    if feats.dtype == jnp.bool_:
        # ADC-less sign wire (DESIGN.md §13): a 1-bit payload with the
        # sign affine, NOT int8 codes with the code affine — it must not
        # enter the w8a8 kernel. Its dequant is the same one-site fold
        # ({0,1}·2v_mag + (bias - v_mag) = ±v_mag + bias), so the generic
        # route below is already exact.
        return dequantize_features(cf) @ params["embed"]
    if cfg.quant_embed and not jnp.issubdtype(feats.dtype, jnp.floating):
        from repro.kernels import ops  # lazy: keep the model import-light

        w8, s_w = params.get("embed_q") or ops.quantize_weights_int8(params["embed"])
        y = ops.quant_matmul_pre(feats, cf.scale, w8, s_w)
        zero_term = cf.zero @ (w8.astype(jnp.float32) * s_w[None, :])
        return (y + zero_term) * cf.gain[..., None]
    return dequantize_features(cf) @ params["embed"]


def _forward_compact_fused(
    params: dict,
    rgb: jnp.ndarray,
    cfg: ViTConfig,
    indices,
    mask,
    project_fn,
    precomputed,
    cache,
    wire,
    k_cap,
    stale_cap,
) -> tuple[jnp.ndarray, dict]:
    """The megakernel compact path (DESIGN.md §11): one Pallas kernel
    gathers the selected patches, projects, converts, and performs the
    w8a8 embed matmul — the staged select -> project -> wire ->
    ``_embed_tokens`` seam collapses and the int8 codes never leave VMEM.
    Logits are bitwise-equal the staged code-wire path for the same
    selection (tests/test_megakernel.py): the kernel's epilogue is the
    exact ``quant_matmul`` arithmetic and the affine/gain algebra below is
    the exact ``_embed_tokens`` expression."""
    fe_cfg = cfg.frontend
    if not cfg.quant_embed:
        raise ValueError(
            "fused_embed requires quant_embed=True: the megakernel's "
            "embed stage IS the w8a8 code consumption (DESIGN.md §9/§11)")
    if not fe_cfg.analog:
        raise ValueError(
            "fused_embed requires an analog frontend: the fused seam "
            "exists in ADC code space; the float simulation has no codes")
    if wire == "float":
        raise ValueError(
            "fused_embed has no float wire: codes are consumed in-kernel "
            "and never materialized — use fused_embed=False for the STE "
            "float view")
    if project_fn is not None:
        raise ValueError(
            "fused_embed IS the projector (one megakernel); a project_fn "
            "cannot be substituted into it — use fused_embed=False")
    if cache is not None or stale_cap is not None:
        raise ValueError(
            "fused_embed does not thread the temporal cache (held codes "
            "live outside the kernel); use fused_embed=False with a "
            "FeatureCache — the gated path reuses the same ragged "
            "machinery via row_counts=n_stale")
    from repro.kernels import ops  # lazy: keep the model import-light

    sel = select_compact(
        params["ip2"], rgb, fe_cfg,
        mask=mask, indices=indices, precomputed=precomputed, k_cap=k_cap,
    )
    # per-slot real-row count: valid is a prefix mask, so the ragged
    # megakernel skips shed/filler rows entirely (zero FLOPs/bytes)
    counts = jnp.sum(sel.valid, axis=-1).astype(jnp.int32)
    w8, s_w = params.get("embed_q") or ops.quantize_weights_int8(params["embed"])
    y = ops.ip2_fused_embed(
        sel.patches, sel.weights, sel.indices, fe_cfg.patch, fe_cfg.adc,
        w8, s_w, row_counts=counts,
    )
    scale, zero = feature_scale_zero(params["ip2"], fe_cfg)
    gain = sel.valid.astype(jnp.float32)
    # exactly _embed_tokens' affine: (y + zero @ dequant(W8)) * gain. Shed
    # rows are zero in y AND zero in gain — gain multiplies BEFORE the pos
    # add, so fused (never-computed) and staged (computed-then-gained-out)
    # rows land on identical x.
    x = (y + ops.fused_embed_zero_term(zero, w8, s_w)) * gain[..., None]
    x = x + params["pos"][sel.indices]
    logits, received = _encoder(params, x, cfg, sel.valid)

    n_selected = jnp.sum(sel.valid, axis=-1).astype(jnp.float32)
    # same ungated-compact ledger as apply_frontend: every served token
    # was projected AND converted this frame, by the fused epilogue —
    # n_selected·M conversions pinned to the emitted payload rows
    events = power_mod.frontend_frame_events(
        float(fe_cfg.image_h * fe_cfg.image_w),
        fe_cfg.patch.pixels_per_patch, fe_cfg.patch.n_vectors,
        n_selected_patches=n_selected, n_converted_patches=n_selected,
    )
    received = jnp.where(sel.valid, received, 0.0)
    b = jnp.arange(received.shape[0])[:, None]
    saliency = jnp.zeros(
        (received.shape[0], fe_cfg.n_patches), jnp.float32
    ).at[b, sel.indices].max(received)
    aux = {
        "indices": sel.indices, "valid": sel.valid,
        "saliency": saliency, "energy": sel.energy, "events": events,
    }
    return logits, aux


def vit_forward_compact(
    params: dict,
    rgb: jnp.ndarray,
    cfg: ViTConfig,
    indices: jnp.ndarray | None = None,
    mask: jnp.ndarray | None = None,
    project_fn=None,
    precomputed=None,
    cache=None,
    wire: str | None = None,
    k_cap: jnp.ndarray | None = None,
    stale_cap: jnp.ndarray | None = None,
    sign_mode: jnp.ndarray | None = None,
    backend_cache=None,
    backend_eps: jnp.ndarray | None = None,
    backend_act: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, dict]:
    """Compact path: frontend projects only the k selected patches, the
    backend attends over exactly those k tokens (index-looked-up positional
    embeddings), and the attention itself scores the next saccade.

    On the analog path the frontend hands over the digital wire format —
    int8 ADC codes plus static dequant metadata (DESIGN.md §9) — and the
    first matmul (:func:`_embed_tokens`) is the only place it is
    dequantized. ``wire="float"`` selects the bit-identical STE float
    view instead (differentiable: compact-path co-design training);
    ``None`` defers to the frontend's per-config resolution (codes iff
    there is a real edge ADC).

    ``precomputed`` optionally forwards an existing ``(patches, weights)``
    pair from :func:`repro.core.frontend.sensor_patches` (the serving
    engine computes it once for its in-step bootstrap).

    ``cache`` (a :class:`repro.core.temporal.FeatureCache`) enables the
    temporal delta gate: only the stale subset of the selection is
    re-projected/converted, held codes serve the rest (DESIGN.md §6).

    ``k_cap`` / ``stale_cap`` are the power governor's per-stream data
    knobs (DESIGN.md §10), forwarded to the frontend: shed tokens past
    ``k_cap`` (they leave attention via the valid mask) and truncate the
    temporal recompute allocation to ``stale_cap`` slots. Data, not
    shape — governed and ungoverned steps share one compilation.

    ``sign_mode`` ((B,) bool) is the governor's ADC-less tier knob
    (DESIGN.md §13): flagged rows have their served int8 code wire
    degraded to its 1-bit sign view (static code-grid points from
    :func:`repro.core.adc.sign_code_points`) and this frame's ADC
    conversions re-ledgered as sign comparisons. Data only — the payload
    stays int8 and no shape changes, so governed readout switches never
    retrace; the refreshed cache keeps the REAL codes (the comparator
    readout is non-destructive), so a recovering slot resumes from
    full-precision held charge. Requires the code wire.

    Returns (logits (B, n_classes), aux) with aux:
      ``indices`` (B, k)  — the patches that were ADC-converted;
      ``valid``   (B, k)  — False only on filler slots (< k active);
      ``events``          — this frame's executed energy-event ledger
        (:class:`repro.core.power.EventCounts`, (B,) leaves): what the
        frontend actually spent — price with ``EnergyMeter`` (§10);
      ``saliency``(B, P)  — backend attention scattered back onto the patch
        grid (unobserved patches score 0): frame t+1's selection signal;
      ``energy``  (B, P)  — the in-pixel patch-energy proxy (free from the
        frontend; the saccade explore term reads it here instead of
        re-running ``sensor_patches``);
      with ``cache`` given, additionally ``cache`` (the refreshed
      FeatureCache to thread into the next frame) and ``n_stale`` (B,)
      — how many of the k patches were actually recomputed.

    ``backend_cache`` (a :class:`repro.models.backend_delta.BackendCache`)
    enables the delta-gated incremental BACKEND (DESIGN.md §14): tokens
    whose served wire row is bitwise unchanged reuse their cached
    per-layer activations, a frame with no changed valid row serves the
    cached logits/saliency outright, and ``backend_eps`` ((B,) float,
    default exact) budgets deeper-layer reuse — ``eps <= 0`` reproduces
    the dense backend bitwise; ``eps > 0`` snaps sub-eps drift back to
    the cache. The executed backend MACs land on
    ``aux["events"].backend_macs`` and the refreshed cache on
    ``aux["backend_cache"]``. ``backend_act`` ((B,) bool) optionally
    restricts the whole-batch skip predicate to the slots that actually
    advance this frame (the engine's ``active & fed``) — a held or
    empty slot must not force a compute frame on an otherwise fully
    cached fleet.

    With ``cfg.fused_embed`` (requires ``quant_embed`` + analog frontend,
    code wire, no cache/project_fn) the whole frontend-to-embed seam runs
    as ONE Pallas megakernel with ragged per-slot k (DESIGN.md §11) —
    same logits, bitwise, for the same selection.
    """
    if backend_cache is None and (backend_eps is not None
                                  or backend_act is not None):
        raise ValueError(
            "backend_eps/backend_act configure the delta-gated backend "
            "(DESIGN.md §14) and need a BackendCache to gate against — "
            "pass backend_cache, or drop them for the dense encoder")
    if cfg.fused_embed:
        if backend_cache is not None:
            raise ValueError(
                "fused_embed does not thread the backend cache (the "
                "embed seam lives in-kernel, DESIGN.md §11); use "
                "fused_embed=False for the delta-gated backend")
        if sign_mode is not None:
            raise ValueError(
                "fused_embed consumes codes in-kernel (DESIGN.md §11); "
                "the sign-tier degradation needs the staged code wire — "
                "use fused_embed=False in a sign-tier governed engine")
        return _forward_compact_fused(
            params, rgb, cfg, indices, mask, project_fn, precomputed,
            cache, wire, k_cap, stale_cap,
        )
    out = apply_frontend(
        params["ip2"], rgb, cfg.frontend,
        mask=mask, indices=indices, mode="compact", project_fn=project_fn,
        precomputed=precomputed, cache=cache, wire=wire,
        k_cap=k_cap, stale_cap=stale_cap,
    )
    new_cache = None
    if cache is not None:
        out, new_cache = out
    cf: CompactFeatures = out
    if sign_mode is not None:
        if jnp.issubdtype(cf.features.dtype, jnp.floating):
            raise ValueError(
                "sign_mode degrades the int8 code wire (DESIGN.md §13); "
                "the float wire has no codes to degrade — it is the STE "
                "training view, not a served payload")
        c_thresh, c_pos, c_neg = adc_mod.sign_code_points(
            cfg.frontend.patch.summer.v_ref, cfg.frontend.adc)
        sm = sign_mode[:, None, None]
        cf = cf._replace(features=jnp.where(
            sm,
            jnp.where(cf.features >= c_thresh, c_pos, c_neg)
               .astype(cf.features.dtype),
            cf.features))
        ev = cf.events
        cf = cf._replace(events=ev._replace(
            adc_conversions=jnp.where(sign_mode, 0.0, ev.adc_conversions),
            sign_comparisons=jnp.where(
                sign_mode, ev.adc_conversions, ev.sign_comparisons),
        ))
    new_bcache = None
    backend_macs = None
    if backend_cache is not None:
        from repro.models import backend_delta  # lazy: it imports us back

        if backend_cache.feats.dtype != cf.features.dtype:
            raise ValueError(
                f"backend cache dtype {backend_cache.feats.dtype} does "
                f"not match wire payload {cf.features.dtype}; build it "
                f"with init_backend_cache(..., dtype=<wire dtype>)")
        if backend_cache.feats.shape[-2:] != cf.features.shape[-2:]:
            raise ValueError(
                f"backend cache rows {backend_cache.feats.shape[-2:]} do "
                f"not match the served wire {cf.features.shape[-2:]}")
        eps = (jnp.zeros(cf.valid.shape[0], jnp.float32)
               if backend_eps is None
               else jnp.broadcast_to(
                   jnp.asarray(backend_eps, jnp.float32),
                   (cf.valid.shape[0],)))

        def embed_fn(cf=cf):
            # index-based positional embeddings: pos[idx], not pos over P
            return _embed_tokens(params, cf, cfg) + params["pos"][cf.indices]

        logits, received, new_bcache, backend_macs = \
            backend_delta.delta_forward(params, cfg, cf, embed_fn,
                                        backend_cache, eps,
                                        act=backend_act)
    else:
        # index-based positional embeddings: pos[idx], not pos over P
        x = _embed_tokens(params, cf, cfg) + params["pos"][cf.indices]
        logits, received = _encoder(params, x, cfg, cf.valid)

    received = jnp.where(cf.valid, received, 0.0)
    b = jnp.arange(received.shape[0])[:, None]
    saliency = jnp.zeros(
        (received.shape[0], cfg.frontend.n_patches), jnp.float32
    ).at[b, cf.indices].max(received)
    events = cf.events
    if backend_macs is not None:
        # the ledger prices the delta accelerator's EXECUTED MACs (§14);
        # the dense path deliberately ledgers none — its closed form is
        # dense_backend_macs, the governor's feed-forward estimate
        events = events._replace(backend_macs=backend_macs)
    aux = {
        "indices": cf.indices, "valid": cf.valid,
        "saliency": saliency, "energy": cf.energy, "events": events,
    }
    if new_cache is not None:
        aux["cache"] = new_cache
        aux["n_stale"] = new_cache.n_stale
    if new_bcache is not None:
        aux["backend_cache"] = new_bcache
    return logits, aux


def vit_loss(params, rgb, labels, cfg: ViTConfig):
    logits = vit_forward(params, rgb, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
