"""RecurrentGemma recurrent block: conv1d + RG-LRU (arXiv:2402.19427).

Block: x -> { branch A: linear -> causal conv1d(4) -> RG-LRU,
              branch B: linear -> gelu } -> A*B -> out linear.

RG-LRU recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = exp(c * softplus(Λ) * (-r_t))     # a = σ(Λ)^(c·r); c = 8
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t²) ⊙ (i_t ⊙ x_t)

Training uses a parallel associative scan over the linear recurrence
(sub-quadratic, O(S log S) depth); decode carries h as O(1) state — this is
why recurrentgemma runs the long_500k cell.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParallelPlan, dense_init

_C = 8.0
CONV_K = 4


def init_rglru_block(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 7)
    return {
        "w_x": dense_init(ks[0], d, d, dtype),
        "w_gate": dense_init(ks[1], d, d, dtype),
        "w_out": dense_init(ks[2], d, d, dtype),
        "conv_w": (jax.random.normal(ks[3], (CONV_K, d)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d,), dtype),
        "wa": dense_init(ks[4], d, d, dtype),
        "ba": jnp.zeros((d,), dtype),
        "wxg": dense_init(ks[5], d, d, dtype),
        "bxg": jnp.zeros((d,), dtype),
        # Λ init so a ∈ (0.9, 0.999) at r=1 (paper's init range)
        "lam": (jax.random.uniform(ks[6], (d,), minval=2.0, maxval=6.0)).astype(dtype),
    }


def spec_rglru_block(cfg: ModelConfig, plan: ParallelPlan) -> dict:
    from jax.sharding import PartitionSpec as P

    w_in = plan.fsdp_axis if plan.fsdp else None
    tp = plan.tp_axis
    return {
        "w_x": P(w_in, tp), "w_gate": P(w_in, tp), "w_out": P(tp, w_in),
        "conv_w": P(None, tp), "conv_b": P(tp),
        "wa": P(w_in, tp), "ba": P(tp),
        "wxg": P(w_in, tp), "bxg": P(tp),
        "lam": P(tp),
    }


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                   state: jnp.ndarray | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv, kernel CONV_K. x (B,S,D); state (B,K-1,D)."""
    if state is None:
        state = jnp.zeros((x.shape[0], CONV_K - 1, x.shape[-1]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)
    out = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(CONV_K)) + b
    return out, xp[:, -(CONV_K - 1) :, :]


def _gates(p: dict, xc: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """log(a_t) and the input branch i_t ⊙ x_t, both fp32."""
    x32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(x32 @ p["wa"].astype(jnp.float32) + p["ba"].astype(jnp.float32))
    i = jax.nn.sigmoid(x32 @ p["wxg"].astype(jnp.float32) + p["bxg"].astype(jnp.float32))
    log_a = -_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    a2 = jnp.exp(2.0 * log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - a2, 1e-9)) * (i * x32)
    return log_a, gated_in


def rglru_scan(p: dict, xc: jnp.ndarray, h0: jnp.ndarray | None = None
               ) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Parallel linear-recurrence scan. xc (B,S,D) -> (h (B,S,D), h_last)."""
    log_a, gi = _gates(p, xc)                      # (B,S,D) fp32

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 + a2, b1 * jnp.exp(a2) + b2

    if h0 is not None:
        gi = gi.at[:, 0, :].add(h0.astype(jnp.float32) * jnp.exp(log_a[:, 0, :]))
    la, h = jax.lax.associative_scan(combine, (log_a, gi), axis=1)
    return h.astype(xc.dtype), h[:, -1, :]


def rglru_step(p: dict, xc: jnp.ndarray, h: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """One decode step. xc (B,1,D), h (B,D) -> (out (B,1,D), h_new)."""
    log_a, gi = _gates(p, xc)
    h_new = jnp.exp(log_a[:, 0, :]) * h.astype(jnp.float32) + gi[:, 0, :]
    return h_new[:, None, :].astype(xc.dtype), h_new


def recurrent_block_forward(
    p: dict, x: jnp.ndarray, state: dict | None = None
) -> tuple[jnp.ndarray, dict]:
    """Full block. state = {"h": (B,D) fp32, "conv": (B,K-1,D)} or None."""
    gate = jax.nn.gelu(x @ p["w_gate"])
    xb = x @ p["w_x"]
    conv_state = None if state is None else state["conv"]
    xc, conv_new = _causal_conv1d(xb, p["conv_w"], p["conv_b"], conv_state)
    if x.shape[1] == 1 and state is not None:
        h_seq, h_last = rglru_step(p, xc, state["h"])
    else:
        h0 = None if state is None else state["h"]
        h_seq, h_last = rglru_scan(p, xc, h0)
    out = (h_seq * gate) @ p["w_out"]
    return out, {"h": h_last, "conv": conv_new}


def init_rglru_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    return {
        "h": jnp.zeros((batch, d), jnp.float32),
        "conv": jnp.zeros((batch, CONV_K - 1, d), jnp.bfloat16),
    }
