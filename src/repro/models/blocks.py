"""Per-kind block init/spec/apply dispatch + pattern-scan stacking.

A model is ``block_pattern`` tiled over n_layers. Consecutive full repeats
of the pattern are stacked and executed with one ``lax.scan`` (compact HLO
even for 94-layer models); remainder layers run unrolled. Each pattern
position has its own param stack, so heterogeneous patterns (RG-LRU /
local-attn, mLSTM / sLSTM) scan cleanly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import (
    ATTN,
    LOCAL_ATTN,
    MLSTM,
    MOE,
    RECURRENT,
    SLSTM,
    ModelConfig,
)
from repro.models import attention as attn_mod
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import ParallelPlan, init_mlp, rms_norm, spec_mlp
from repro.models.sharding_ctx import constrain


# ---------------------------------------------------------------------------
# init / spec per kind
# ---------------------------------------------------------------------------

def init_block(key, kind: str, cfg: ModelConfig, plan: ParallelPlan, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p: dict = {"norm1": jnp.ones((d,), dtype)}
    if kind in (ATTN, LOCAL_ATTN, MOE):
        p["attn"] = attn_mod.init_attention(k1, cfg, plan, dtype)
        p["norm2"] = jnp.ones((d,), dtype)
        if kind == MOE:
            p["moe"] = moe_mod.init_moe(k2, cfg, dtype)
        else:
            p["mlp"] = init_mlp(k2, d, cfg.d_ff, cfg.mlp_kind, dtype)
    elif kind == RECURRENT:
        p["rec"] = rglru_mod.init_rglru_block(k1, cfg, dtype)
        p["norm2"] = jnp.ones((d,), dtype)
        p["mlp"] = init_mlp(k2, d, cfg.d_ff, cfg.mlp_kind, dtype)
    elif kind == MLSTM:
        p["mlstm"] = xlstm_mod.init_mlstm_block(k1, cfg, dtype)
    elif kind == SLSTM:
        p["slstm"] = xlstm_mod.init_slstm_block(k1, cfg, dtype)
    else:
        raise ValueError(kind)
    return p


def spec_block(kind: str, cfg: ModelConfig, plan: ParallelPlan) -> dict:
    from jax.sharding import PartitionSpec as P

    s: dict = {"norm1": P(None)}
    if kind in (ATTN, LOCAL_ATTN, MOE):
        s["attn"] = attn_mod.spec_attention(cfg, plan)
        s["norm2"] = P(None)
        if kind == MOE:
            s["moe"] = moe_mod.spec_moe(cfg, plan)
        else:
            s["mlp"] = spec_mlp(cfg.mlp_kind, plan)
    elif kind == RECURRENT:
        s["rec"] = rglru_mod.spec_rglru_block(cfg, plan)
        s["norm2"] = P(None)
        s["mlp"] = spec_mlp(cfg.mlp_kind, plan)
    elif kind == MLSTM:
        s["mlstm"] = xlstm_mod.spec_mlstm_block(cfg, plan)
    elif kind == SLSTM:
        s["slstm"] = xlstm_mod.spec_slstm_block(cfg, plan)
    return s


# ---------------------------------------------------------------------------
# apply (full sequence) — returns (x, new_state, aux)
# ---------------------------------------------------------------------------

def _cache_from_prefill(k: jnp.ndarray, t: int, cache_dtype) -> jnp.ndarray:
    """Lay prefill keys/values into the (possibly rolling) cache buffer so
    decode's slot arithmetic (slot = pos % t) lines up."""
    s = k.shape[1]
    if s < t:
        pad = [(0, 0), (0, t - s), (0, 0), (0, 0)]
        return jnp.pad(k.astype(cache_dtype), pad)
    kk = k[:, -t:].astype(cache_dtype)
    return jnp.roll(kk, s % t, axis=1)


def _scale_from_prefill(sc: jnp.ndarray, t: int) -> jnp.ndarray:
    """Same layout for the (B, S, H) int8-cache scales."""
    s = sc.shape[1]
    if s < t:
        return jnp.pad(sc, [(0, 0), (0, t - s), (0, 0)], constant_values=1.0)
    return jnp.roll(sc[:, -t:], s % t, axis=1)


def apply_block(
    p: dict,
    kind: str,
    x: jnp.ndarray,
    cfg: ModelConfig,
    positions: jnp.ndarray,
    state: dict | None,
    causal: bool = True,
    decode_pos: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, dict | None, jnp.ndarray]:
    aux = jnp.zeros((), jnp.float32)
    new_state = state
    if kind in (ATTN, LOCAL_ATTN, MOE):
        window = cfg.local_window if kind == LOCAL_ATTN else None
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        if state is not None and x.shape[1] == 1:
            scales = (
                {"k": state["k_scale"], "v": state["v_scale"]}
                if "k_scale" in state else None
            )
            out, nk, nv, nsc = attn_mod.attention_decode(
                p["attn"], h, state["k"], state["v"], decode_pos, cfg,
                window=window, cache_scales=scales,
            )
            new_state = {"k": nk, "v": nv}
            if nsc is not None:
                new_state["k_scale"], new_state["v_scale"] = nsc["k"], nsc["v"]
        else:
            out, (k, v) = attn_mod.attention_forward(
                p["attn"], h, cfg, positions, causal=causal, window=window
            )
            if state is not None:
                t = state["k"].shape[1]
                if state["k"].dtype == jnp.int8:
                    k8, ks = attn_mod.quantize_kv(k)
                    v8, vs = attn_mod.quantize_kv(v)
                    new_state = {
                        "k": _cache_from_prefill(k8, t, jnp.int8),
                        "v": _cache_from_prefill(v8, t, jnp.int8),
                        "k_scale": _scale_from_prefill(ks, t),
                        "v_scale": _scale_from_prefill(vs, t),
                    }
                else:
                    new_state = {
                        "k": _cache_from_prefill(k, t, state["k"].dtype),
                        "v": _cache_from_prefill(v, t, state["v"].dtype),
                    }
        x = constrain(x + out, "act")
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        if kind == MOE:
            from repro.models.sharding_ctx import get_moe_ctx

            moe_ctx = get_moe_ctx()
            if moe_ctx is not None:
                from repro.models.moe_a2a import apply_moe_a2a

                out, aux = apply_moe_a2a(
                    p["moe"], h, cfg, moe_ctx["mesh"], moe_ctx["dp"], moe_ctx["tp"]
                )
            else:
                out, aux = moe_mod.apply_moe(p["moe"], h, cfg)
        else:
            from repro.models.layers import apply_mlp

            out = apply_mlp(p["mlp"], h, cfg.mlp_kind)
        x = constrain(x + out, "act")
    elif kind == RECURRENT:
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        out, new_state = rglru_mod.recurrent_block_forward(p["rec"], h, state)
        x = constrain(x + out, "act")
        h = rms_norm(x, p["norm2"], cfg.norm_eps)
        from repro.models.layers import apply_mlp

        x = constrain(x + apply_mlp(p["mlp"], h, cfg.mlp_kind), "act")
    elif kind == MLSTM:
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        out, new_state = xlstm_mod.mlstm_block_forward(
            p["mlstm"], h, state, chunk_size=cfg.xlstm_chunk)
        x = constrain(x + out, "act")
    elif kind == SLSTM:
        h = rms_norm(x, p["norm1"], cfg.norm_eps)
        out, new_state = xlstm_mod.slstm_forward(p["slstm"], h, state)
        x = constrain(x + out, "act")
    return x, new_state, aux


# ---------------------------------------------------------------------------
# decode-state init per kind
# ---------------------------------------------------------------------------

def init_block_state(
    kind: str, cfg: ModelConfig, plan: ParallelPlan, batch: int, max_len: int,
    cache_dtype=jnp.bfloat16,
) -> dict:
    if kind in (ATTN, MOE, LOCAL_ATTN):
        window = cfg.local_window if kind == LOCAL_ATTN else None
        k, v = attn_mod.make_cache(
            cfg, plan, batch, max_len, window=window, dtype=cache_dtype
        )
        st = {"k": k, "v": v}
        if cache_dtype == jnp.int8:
            sc = attn_mod.make_cache_scales(cfg, plan, batch, max_len, window=window)
            st["k_scale"], st["v_scale"] = sc["k"], sc["v"]
        return st
    if kind == RECURRENT:
        return rglru_mod.init_rglru_state(cfg, batch)
    if kind == MLSTM:
        return xlstm_mod.init_mlstm_state(cfg, batch)
    if kind == SLSTM:
        return xlstm_mod.init_slstm_state(cfg, batch)
    raise ValueError(kind)


def state_specs(kind: str, cfg: ModelConfig, plan: ParallelPlan,
                cache_dtype=jnp.bfloat16) -> dict:
    """PartitionSpecs for one block's decode state (batch over dp, heads/
    features over tp where the shape allows)."""
    from jax.sharding import PartitionSpec as P

    dp = plan.dp_axes
    tp = plan.tp_axis
    if kind in (ATTN, MOE, LOCAL_ATTN):
        s = {"k": P(dp, None, tp, None), "v": P(dp, None, tp, None)}
        if cache_dtype == jnp.int8:
            s["k_scale"] = P(dp, None, tp)
            s["v_scale"] = P(dp, None, tp)
        return s
    if kind == RECURRENT:
        return {"h": P(dp, tp), "conv": P(dp, None, tp)}
    if kind == MLSTM:
        return {"conv": P(dp, None, tp), "C": P(dp, None, None, tp),
                "n": P(dp, None, tp), "m": P(dp, None)}
    if kind == SLSTM:
        return {"c": P(dp, None, tp), "n": P(dp, None, tp),
                "m": P(dp, None, tp), "h": P(dp, tp)}
    raise ValueError(kind)
