"""Small CNN baseline (the paper's comparison point: patch-based linear
projection "can perform as well as the CNN"). 3 conv blocks + GAP head,
implemented with lax.conv_general_dilated — no frontend, full-frame RGB."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def init_cnn(key, n_classes: int = 4, width: int = 32) -> dict:
    ks = jax.random.split(key, 4)
    def conv(k, cin, cout):
        return (jax.random.normal(k, (3, 3, cin, cout)) / jnp.sqrt(9 * cin))
    return {
        "c1": conv(ks[0], 3, width),
        "c2": conv(ks[1], width, width * 2),
        "c3": conv(ks[2], width * 2, width * 4),
        "head": (jax.random.normal(ks[3], (width * 4, n_classes)) * 0.02),
    }


def _conv(x, w, stride=2):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def cnn_forward(params: dict, rgb: jnp.ndarray) -> jnp.ndarray:
    x = jax.nn.relu(_conv(rgb, params["c1"]))
    x = jax.nn.relu(_conv(x, params["c2"]))
    x = jax.nn.relu(_conv(x, params["c3"]))
    pooled = jnp.mean(x, axis=(1, 2))
    return pooled @ params["head"]


def cnn_loss(params, rgb, labels):
    logits = cnn_forward(params, rgb)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    loss = jnp.mean(logz - gold)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, acc
