"""xLSTM blocks — mLSTM (matrix memory) + sLSTM (scalar memory), arXiv:2405.04517.

mLSTM: attention-like parallel form for training/prefill (stabilized
exponential gating), O(1)-state recurrent form for decode — xlstm-1.3b
therefore runs the long_500k cell with constant memory.

    C_t = f_t C_{t-1} + i_t v_t k_t^T      (matrix memory, per head)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = (C_t q_t) / max(|n_t · q_t|, exp(-m_t))

sLSTM: strictly sequential scalar-memory cell with block-diagonal
recurrent weights (one block per head); lax.scan over time.

Block layout (d_ff = 0 in the assigned config — the blocks carry their own
up/down projections, proj_factor 2):
  mLSTM block: LN -> up(2·di) -> [conv4 -> silu -> q,k | v] -> mLSTM
               -> GN -> ⊙ silu(z) -> down
  sLSTM block: LN -> sLSTM cell (4 gates, recurrent h) -> GN -> down
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ParallelPlan, dense_init
from repro.models.rglru import _causal_conv1d, CONV_K


def _heads(cfg: ModelConfig) -> tuple[int, int, int]:
    di = cfg.d_inner_xlstm
    nh = cfg.n_heads
    return di, nh, di // nh


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def init_mlstm_block(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    di, nh, dh = _heads(cfg)
    ks = jax.random.split(key, 9)
    blk = lambda k: (jax.random.normal(k, (nh, dh, dh)) / jnp.sqrt(dh)).astype(dtype)
    return {
        "w_up": dense_init(ks[0], d, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (CONV_K, di)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": blk(ks[2]), "wk": blk(ks[3]), "wv": blk(ks[4]),
        "w_i": dense_init(ks[5], di, nh, dtype),
        "b_i": jnp.zeros((nh,), dtype),
        "w_f": dense_init(ks[6], di, nh, dtype),
        "b_f": jnp.full((nh,), 3.0, dtype),      # forget-gate bias: remember
        "gn": jnp.ones((di,), dtype),
        "w_down": dense_init(ks[7], di, d, dtype),
    }


def spec_mlstm_block(cfg: ModelConfig, plan: ParallelPlan) -> dict:
    from jax.sharding import PartitionSpec as P

    w_in = plan.fsdp_axis if plan.fsdp else None
    tp = plan.tp_axis
    return {
        "w_up": P(w_in, tp),
        "conv_w": P(None, tp), "conv_b": P(tp),
        # heads (nh=4) generally don't divide tp=16 -> shard the dh dims
        "wq": P(None, None, tp), "wk": P(None, None, tp), "wv": P(None, None, tp),
        "w_i": P(tp, None), "b_i": P(None),
        "w_f": P(tp, None), "b_f": P(None),
        "gn": P(tp),
        "w_down": P(tp, w_in),
    }


def _group_norm(x: jnp.ndarray, scale: jnp.ndarray, nh: int) -> jnp.ndarray:
    """Per-head RMS norm over the head channels. x (..., di)."""
    shp = x.shape
    xh = x.reshape(*shp[:-1], nh, shp[-1] // nh).astype(jnp.float32)
    var = jnp.mean(xh * xh, axis=-1, keepdims=True)
    xh = xh * jax.lax.rsqrt(var + 1e-6)
    return (xh.reshape(shp) * scale.astype(jnp.float32)).astype(x.dtype)


def _mlstm_qkvif(p: dict, x: jnp.ndarray, conv_state=None):
    """x (B,S,D) -> q,k,v (B,S,NH,dh), i,f raw gates (B,S,NH), z, conv_state."""
    nh = p["wq"].shape[0]
    di = p["conv_b"].shape[0]
    up = x @ p["w_up"]
    xi, z = jnp.split(up, 2, axis=-1)
    xc, conv_new = _causal_conv1d(xi, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    b, s, _ = x.shape
    xch = xc.reshape(b, s, nh, di // nh)
    xih = xi.reshape(b, s, nh, di // nh)
    q = jnp.einsum("bsnd,nde->bsne", xch, p["wq"])
    k = jnp.einsum("bsnd,nde->bsne", xch, p["wk"])
    v = jnp.einsum("bsnd,nde->bsne", xih, p["wv"])
    i_raw = (xi @ p["w_i"] + p["b_i"]).astype(jnp.float32)
    f_raw = (xi @ p["w_f"] + p["b_f"]).astype(jnp.float32)
    return q, k, v, i_raw, f_raw, z, conv_new


def mlstm_parallel(q, k, v, i_raw, f_raw) -> jnp.ndarray:
    """Stabilized parallel (quadratic) form. q/k/v (B,S,NH,dh) -> (B,S,NH,dh)."""
    b, s, nh, dh = q.shape
    lf = jax.nn.log_sigmoid(f_raw)                     # (B,S,NH)
    lfc = jnp.cumsum(lf, axis=1)                       # inclusive Σ log f
    # pair weight (t, j): lfc_t - lfc_j + i_j, j <= t
    dmat = lfc[:, :, None, :] - lfc[:, None, :, :] + i_raw[:, None, :, :]
    tpos = jnp.arange(s)
    causal = tpos[:, None] >= tpos[None, :]
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)   # (B,T,J,NH)
    m = jnp.max(dmat, axis=2)                          # (B,T,NH)
    dexp = jnp.exp(dmat - m[:, :, None, :])
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    sc = jnp.einsum("btnd,bjnd->btjn", q.astype(jnp.float32) * scale,
                    k.astype(jnp.float32)) * dexp
    num = jnp.einsum("btjn,bjnd->btnd", sc, v.astype(jnp.float32))
    denom = jnp.maximum(jnp.abs(jnp.sum(sc, axis=2)), jnp.exp(-m))  # (B,T,NH)
    return (num / denom[..., None]).astype(q.dtype)


def mlstm_step(state: dict, q, k, v, i_raw, f_raw):
    """Recurrent step. q/k/v (B,NH,dh); state {C (B,NH,dh,dh), n, m}."""
    lf = jax.nn.log_sigmoid(f_raw)                     # (B,NH)
    m_new = jnp.maximum(lf + state["m"], i_raw)
    fp = jnp.exp(lf + state["m"] - m_new)[..., None]
    ip = jnp.exp(i_raw - m_new)[..., None]
    k32, v32, q32 = (t.astype(jnp.float32) for t in (k, v, q))
    c_new = fp[..., None] * state["C"] + ip[..., None] * (v32[..., :, None] * k32[..., None, :])
    n_new = fp * state["n"] + ip * k32
    dh = q.shape[-1]
    q32 = q32 / jnp.sqrt(jnp.asarray(dh, jnp.float32))
    num = jnp.einsum("bnvk,bnk->bnv", c_new, q32)
    den = jnp.maximum(jnp.abs(jnp.einsum("bnk,bnk->bn", n_new, q32)), jnp.exp(-m_new))
    h = (num / den[..., None]).astype(q.dtype)
    return {"C": c_new, "n": n_new, "m": m_new}, h


def mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk: int) -> tuple[jnp.ndarray, dict]:
    """Chunkwise-parallel mLSTM (§Perf X1): O(S·L) memory instead of the
    O(S²) stabilized gate matrix — intra-chunk quadratic attention +
    inter-chunk recurrent state carry, numerically identical (same
    stabilizer algebra) to the parallel form.

    Returns (h (B,S,NH,dh), final recurrent cell state)."""
    b, s, nh, dh = q.shape
    if s % chunk:
        pad = chunk - s % chunk
        padw = [(0, 0), (0, pad), (0, 0), (0, 0)]
        q, k, v = (jnp.pad(t, padw) for t in (q, k, v))
        i_raw = jnp.pad(i_raw, [(0, 0), (0, pad), (0, 0)], constant_values=-1e30)
        f_raw = jnp.pad(f_raw, [(0, 0), (0, pad), (0, 0)], constant_values=30.0)
    n_chunks = q.shape[1] // chunk

    def split(t):
        return t.reshape(b, n_chunks, chunk, *t.shape[2:]).swapaxes(0, 1)

    qc, kc, vc, ic, fc = (split(t.astype(jnp.float32)) for t in (q, k, v, i_raw, f_raw))
    scale = 1.0 / jnp.sqrt(jnp.asarray(dh, jnp.float32))

    def step(carry, inp):
        c_run, n_run, m_run = carry
        qq, kk, vv, ii, ff = inp                       # (B, L, NH, dh)/(B, L, NH)
        lf = jax.nn.log_sigmoid(ff)
        lfc = jnp.cumsum(lf, axis=1)                   # in-chunk Σ log f
        # intra pair weights (t, j): lfc_t - lfc_j + i_j, j <= t
        dmat = lfc[:, :, None, :] - lfc[:, None, :, :] + ii[:, None, :, :]
        tpos = jnp.arange(chunk)
        causal = tpos[:, None] >= tpos[None, :]
        dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
        m_intra = jnp.max(dmat, axis=2)                # (B, L, NH)
        w_inter = lfc + m_run[:, None, :]              # carry weight at t
        m_t = jnp.maximum(m_intra, w_inter)
        dexp = jnp.exp(dmat - m_t[:, :, None, :])
        sc = jnp.einsum("btnd,bjnd->btjn", qq * scale, kk) * dexp
        num = jnp.einsum("btjn,bjnd->btnd", sc, vv)
        den = jnp.sum(sc, axis=2)                      # (B, L, NH)
        e_int = jnp.exp(w_inter - m_t)                 # (B, L, NH)
        num = num + e_int[..., None] * jnp.einsum(
            "bnvk,btnk->btnv", c_run, qq * scale
        )
        den = den + e_int * jnp.einsum("bnk,btnk->btn", n_run, qq * scale)
        h = num / jnp.maximum(jnp.abs(den), jnp.exp(-m_t))[..., None]
        # fold the chunk into the carry
        w_end = lfc[:, -1:, :] - lfc + ii              # (B, L, NH)
        m_fold = jnp.maximum(jnp.max(w_end, axis=1), lfc[:, -1, :] + m_run)
        we = jnp.exp(w_end - m_fold[:, None, :])
        carry_w = jnp.exp(lfc[:, -1, :] + m_run - m_fold)
        c_new = carry_w[..., None, None] * c_run + jnp.einsum(
            "btn,btnv,btnk->bnvk", we, vv, kk
        )
        n_new = carry_w[..., None] * n_run + jnp.einsum("btn,btnk->bnk", we, kk)
        return (c_new, n_new, m_fold), h

    c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, nh, dh), jnp.float32)
    m0 = jnp.full((b, nh), -1e30, jnp.float32)
    (c_f, n_f, m_f), hs = jax.lax.scan(step, (c0, n0, m0), (qc, kc, vc, ic, fc))
    h = hs.swapaxes(0, 1).reshape(b, n_chunks * chunk, nh, dh)[:, :s]
    return h.astype(q.dtype), {"C": c_f, "n": n_f, "m": m_f}


def mlstm_final_state(k, v, i_raw, f_raw) -> dict:
    """Fold a full sequence into the end-of-sequence recurrent state:
    C_S = Σ_j exp(lfc_S - lfc_j + i_j - m_S) v_j k_j^T (stabilized)."""
    lf = jax.nn.log_sigmoid(f_raw)
    lfc = jnp.cumsum(lf, axis=1)                       # (B,S,NH)
    w = lfc[:, -1:, :] - lfc + i_raw                   # (B,S,NH)
    m = jnp.max(w, axis=1)                             # (B,NH)
    ww = jnp.exp(w - m[:, None, :])
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)
    c = jnp.einsum("bsn,bsnv,bsnk->bnvk", ww, v32, k32)
    n = jnp.einsum("bsn,bsnk->bnk", ww, k32)
    return {"C": c, "n": n, "m": m}


def mlstm_block_forward(p: dict, x: jnp.ndarray, state: dict | None = None,
                        chunk_size: int = 0) -> tuple[jnp.ndarray, dict]:
    nh = p["wq"].shape[0]
    conv_state = None if state is None else state["conv"]
    q, k, v, i_raw, f_raw, z, conv_new = _mlstm_qkvif(p, x, conv_state)
    if x.shape[1] == 1 and state is not None:
        cell = {"C": state["C"], "n": state["n"], "m": state["m"]}
        cell_new, h = mlstm_step(
            cell, q[:, 0], k[:, 0], v[:, 0], i_raw[:, 0], f_raw[:, 0]
        )
        h = h[:, None]
        new_state = {"conv": conv_new, **cell_new}
    elif chunk_size and x.shape[1] > chunk_size:
        # §Perf X1: chunkwise-parallel form, O(S·L) memory
        h, cell = mlstm_chunkwise(q, k, v, i_raw, f_raw, chunk_size)
        new_state = {"conv": conv_new, **cell}
    else:
        h = mlstm_parallel(q, k, v, i_raw, f_raw)
        # fold the sequence into the final recurrent state (prefill -> decode)
        cell = mlstm_final_state(k, v, i_raw, f_raw)
        new_state = {"conv": conv_new, **cell}
    b, s, _, dh = h.shape
    hflat = h.reshape(b, s, nh * dh)
    out = (_group_norm(hflat, p["gn"], nh) * jax.nn.silu(z)) @ p["w_down"]
    return out, new_state


def init_mlstm_state_cell(batch: int, nh: int, dh: int) -> dict:
    return {
        "C": jnp.zeros((batch, nh, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
    }


def init_mlstm_state(cfg: ModelConfig, batch: int) -> dict:
    di, nh, dh = _heads(cfg)
    return {
        "conv": jnp.zeros((batch, CONV_K - 1, di), jnp.bfloat16),
        **init_mlstm_state_cell(batch, nh, dh),
    }


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def init_slstm_block(key, cfg: ModelConfig, dtype=jnp.float32) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    ks = jax.random.split(key, 4)
    return {
        "w": dense_init(ks[0], d, 4 * d, dtype),               # z,i,f,o from x
        "r": (jax.random.normal(ks[1], (4, nh, dh, dh)) / jnp.sqrt(dh)).astype(dtype),
        "b": jnp.concatenate(
            [jnp.zeros((2 * d,), dtype), jnp.full((d,), 3.0, dtype), jnp.zeros((d,), dtype)]
        ),
        "gn": jnp.ones((d,), dtype),
        "w_down": dense_init(ks[2], d, d, dtype),
    }


def spec_slstm_block(cfg: ModelConfig, plan: ParallelPlan) -> dict:
    from jax.sharding import PartitionSpec as P

    w_in = plan.fsdp_axis if plan.fsdp else None
    tp = plan.tp_axis
    return {
        "w": P(w_in, tp),
        "r": P(None, None, None, tp),
        "b": P(tp),
        "gn": P(tp),
        "w_down": P(tp, w_in),
    }


def slstm_forward(p: dict, x: jnp.ndarray, state: dict | None = None
                  ) -> tuple[jnp.ndarray, dict]:
    """x (B,S,D). Sequential scan over time (sLSTM is not parallelizable)."""
    b, s, d = x.shape
    nh = p["r"].shape[1]
    dh = d // nh
    if state is None:
        state = {
            "c": jnp.zeros((b, nh, dh), jnp.float32),
            "n": jnp.zeros((b, nh, dh), jnp.float32),
            "m": jnp.full((b, nh, dh), -1e30, jnp.float32),
            "h": jnp.zeros((b, d), jnp.float32),
        }
    gx = (x @ p["w"] + p["b"]).astype(jnp.float32)             # (B,S,4D)
    gx = gx.reshape(b, s, 4, nh, dh)

    def step(carry, g_t):
        c, n, m, h = carry
        hh = h.reshape(b, nh, dh)
        rec = jnp.einsum("bnd,gnde->gbne", hh, p["r"].astype(jnp.float32))
        z_r, i_r, f_r, o_r = (g_t[:, gi] + rec[gi] for gi in range(4))
        z = jnp.tanh(z_r)
        o = jax.nn.sigmoid(o_r)
        lf = jax.nn.log_sigmoid(f_r)
        m_new = jnp.maximum(lf + m, i_r)
        ip = jnp.exp(i_r - m_new)
        fp = jnp.exp(lf + m - m_new)
        c_new = fp * c + ip * z
        n_new = fp * n + ip
        h_new = (o * c_new / jnp.maximum(n_new, 1e-6)).reshape(b, d)
        return (c_new, n_new, m_new, h_new), h_new

    carry0 = (state["c"], state["n"], state["m"], state["h"])
    (c, n, m, h), hs = jax.lax.scan(step, carry0, gx.transpose(1, 0, 2, 3, 4))
    hs = hs.transpose(1, 0, 2)                                 # (B,S,D)
    out = _group_norm(hs.astype(x.dtype), p["gn"], nh) @ p["w_down"]
    return out, {"c": c, "n": n, "m": m, "h": h}


def init_slstm_state(cfg: ModelConfig, batch: int) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    return {
        "c": jnp.zeros((batch, nh, dh), jnp.float32),
        "n": jnp.zeros((batch, nh, dh), jnp.float32),
        "m": jnp.full((batch, nh, dh), -1e30, jnp.float32),
        "h": jnp.zeros((batch, d), jnp.float32),
    }
