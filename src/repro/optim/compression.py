"""Int8 error-feedback gradient compression for DP all-reduce.

Distributed-optimization trick (beyond-paper, but in the spirit of the
paper's quantize-the-multiply insight applied to the comm fabric): before
the data-parallel all-reduce, each replica quantizes its gradient shard to
int8 with a per-tensor scale and keeps the quantization residual in a
local error-feedback buffer that is added back next step — unbiased in the
long run (Seide et al. 1-bit SGD / EF-SGD). Cross-pod DP traffic drops 4x
(fp32) or 2x (bf16).

Implemented with shard_map + psum so the quantize -> sum -> dequant
sequence is explicit per replica (a plain pjit all-reduce would sum in
full precision). ``make_compressed_grad_fn`` wraps a per-replica gradient
function; convergence under compression is covered by tests.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P


def quantize_ef(
    g: jnp.ndarray, err: jnp.ndarray, scale: jnp.ndarray
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(int8 codes, new error buffer) for a given (shared) scale."""
    corrected = g.astype(jnp.float32) + err
    codes = jnp.clip(jnp.round(corrected / scale), -127, 127).astype(jnp.int8)
    new_err = corrected - codes.astype(jnp.float32) * scale
    return codes, new_err


def compressed_psum_tree(grads, err_tree, axis_name: str, n_replicas: int):
    """Per-replica: pmax-shared scale -> quantize+EF -> psum(int32) ->
    dequant-mean. With a shared scale the int32 sum is exact up to one
    rounding per element (the tiny pmax collective is 4 bytes/tensor).
    Returns (mean_grads, new_err_tree)."""
    def one(g, err):
        corrected = g.astype(jnp.float32) + err
        amax = jax.lax.pmax(jnp.max(jnp.abs(corrected)), axis_name)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        codes, new_err = quantize_ef(g, err, scale)
        codes_sum = jax.lax.psum(codes.astype(jnp.int32), axis_name)
        return codes_sum.astype(jnp.float32) * scale / n_replicas, new_err

    out = jax.tree.map(one, grads, err_tree)
    mean = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_err = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    return mean, new_err


def init_error_buffers(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def make_compressed_allreduce(mesh: Mesh, axis: str = "data"):
    """Returns fn(grads, err) -> (mean_grads, err') running under shard_map
    over the DP axis; grads enter replicated over `axis` per-replica values
    stacked on leading dim (tests drive it with explicit per-replica data)."""
    n = mesh.devices.shape[list(mesh.axis_names).index(axis)]

    def inner(g_shard, err_shard):
        g = jax.tree.map(lambda x: x[0], g_shard)      # drop leading shard dim
        e = jax.tree.map(lambda x: x[0], err_shard)
        mean, new_err = compressed_psum_tree(g, e, axis, n)
        add = jax.tree.map(lambda x: x[None], (mean, new_err))
        return add

    def fn(grads_stacked, err_stacked):
        specs_in = jax.tree.map(lambda _: P(axis), grads_stacked)
        especs = jax.tree.map(lambda _: P(axis), err_stacked)
        out = shard_map(
            inner, mesh=mesh,
            in_specs=(specs_in, especs),
            out_specs=(jax.tree.map(lambda _: P(axis), grads_stacked),
                       jax.tree.map(lambda _: P(axis), err_stacked)),
        )(grads_stacked, err_stacked)
        return out

    return fn
