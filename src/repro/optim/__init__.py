from repro.optim.adamw import AdamWConfig, adamw_update, global_norm, init_opt_state, opt_state_specs
from repro.optim.schedule import cosine_with_warmup

__all__ = [
    "AdamWConfig", "adamw_update", "global_norm", "init_opt_state",
    "opt_state_specs", "cosine_with_warmup",
]
