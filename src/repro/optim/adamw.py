"""AdamW with shard-following optimizer state and optional low-precision
moments (bf16 m/v for trillion-param configs; see EXPERIMENTS.md kimi-k2
memory notes). Pure-functional, no external deps.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    moment_dtype: Any = jnp.float32   # jnp.bfloat16 halves optimizer memory


def init_opt_state(params, cfg: AdamWConfig) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def opt_state_specs(param_specs) -> dict:
    """Moments shard exactly like their parameters."""
    from jax.sharding import PartitionSpec as P

    return {
        "m": param_specs,
        "v": param_specs,
        "step": P(),
    }


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    grads, opt_state: dict, params, cfg: AdamWConfig, lr_t: jnp.ndarray
):
    """Returns (new_params, new_opt_state, metrics)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * clip
        m32 = m.astype(jnp.float32) * b1 + g * (1.0 - b1)
        v32 = v.astype(jnp.float32) * b2 + g * g * (1.0 - b2)
        mhat = m32 / c1
        vhat = v32 / c2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype)
        return newp, m32.astype(cfg.moment_dtype), v32.astype(cfg.moment_dtype)

    out = jax.tree.map(upd, grads, opt_state["m"], opt_state["v"], params)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
    return (
        new_params,
        {"m": new_m, "v": new_v, "step": step},
        {"grad_norm": gnorm},
    )
