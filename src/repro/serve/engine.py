"""Multi-stream saccadic serving engine (DESIGN.md §5).

The paper's switched-cap readout is non-destructive precisely to enable
processing parallelism at <30 mW/MP; the backend half of that story is
serving MANY camera streams through ONE compiled program. This module
batches N independent streams through the compact saccade path
(`serve_step.make_saccade_step`'s exact per-frame semantics) in a single
jitted step:

* **Slots, not streams.** The engine owns ``capacity`` fixed slots; every
  device tensor is slot-major with a static leading axis, so the batched
  step is a pure function of ``(params, frames, fed, state)`` and
  compiles exactly once. Streams join/leave between frames via host-side
  bookkeeping (``admit`` / ``evict``) that only rewrites state rows —
  never shapes — so an admit→evict→admit cycle causes ZERO recompiles
  (asserted in tests via the engine's trace counter).

* **Partial-frame async steps** (DESIGN.md §12). ``step(frames)`` takes
  any SUBSET of the admitted streams — streams at different frame rates
  (a 30 Hz door camera next to a 7.5 Hz parking-lot camera) coexist in
  one engine. Which slots are fed this tick is a ``fed`` (S,) bool DATA
  argument of the same compiled program, so mixed-rate serving never
  retraces. An admitted-but-un-fed slot is a *hold*: its gaze state,
  frame age, temporal cache, and energy meters pass through bitwise
  unchanged (events accrue zero — the stream spent nothing this tick;
  the cache's droop clock advances once per SERVED frame, mirroring a
  dedicated per-stream loop), and the fed slots' outputs are bitwise
  identical to a full-cover step (per-slot independence; asserted in
  tests/test_serve_engine.py).

* **Fed-rows-only scatter ingest + coalesced churn** (DESIGN.md §12,
  §15). Frames live in a PERSISTENT device-resident ``(S, H, W, 3)``
  buffer: each tick uploads only the F fed rows (staged compactly on
  the host, one H2D copy of F·H·W·3 floats) and scatters them into the
  donated buffer with a tiny jitted ``at[slots].set`` — there is no
  full-capacity ``jnp.asarray(buf)`` per tick, so ingest bytes scale
  with the fed fraction exactly like every other per-tick cost.
  Un-fed rows keep the bytes of the last tick that fed them; their
  slots hold, so the stale payload never reaches state or logits.
  Admit/evict churn is continuously batched the same way:
  ``admit``/``evict`` only record host-side bookkeeping, and all
  pending row-writes (admit resets, evict flag-clears, governor budget
  re-splits) coalesce into ONE jitted flush right before the next step
  (or any state read) — k admits between two frames cost one device
  dispatch, not k.

* **Device-resident rollouts + async dispatch** (DESIGN.md §15).
  ``step_rollout(frames_by_tick)`` serves T ticks in ONE dispatch: a
  ``lax.scan`` (``serve_step.make_rollout``) carries the full
  :class:`StreamState` on device — indices, EMA, caches, meters,
  governor controls — with per-tick fed masks and frame payloads as
  scanned inputs, bitwise identical to T sequential ``step()`` calls in
  every engine mode. ``step(..., block=False)`` is the single-tick
  async path: it returns a :class:`StepHandle` over the device-resident
  logits, fetched lazily, so a caller (the fleet layer) can dispatch
  many engines before blocking on any.

* **Per-stream gaze state.** :class:`StreamState` carries each slot's
  current patch indices, an attention-score EMA (temporal smoothing of
  the saccade policy; ``ema_decay=0`` reproduces the single-stream step
  frame-for-frame), the frame age (age 0 ⇒ in-step bootstrap from the
  patch-energy proxy), and the slot-occupied flag.

* **In-step bootstrap.** Freshly admitted slots select their first gaze
  from the in-pixel energy proxy *inside* the batched step
  (``sensor_patches`` runs once and is forwarded to the compact forward
  via ``precomputed``), so admission needs no per-stream compiled
  bootstrap call and mixed-age batches stay one program.

* **Sharding.** With a mesh, the slot axis is sharded over the mesh's
  data axis via ``shard_map`` — the step is per-slot parallel with
  replicated params, so no collectives cross the slot axis. State
  buffers are donated, so steady-state serving is allocation-free on
  accelerators that support donation.

* **Energy metering** (DESIGN.md §10). Every step accumulates each
  slot's executed energy events (``aux["events"]`` from the compact
  forward: ADC conversions, cap charges, DAC loads, CDS, comparator and
  OpAmp windows) into per-slot cumulative meters in
  :class:`StreamState` — slot-major counts, donated and sharded like
  the rest of the state. ``engine.power_mw(sid)`` /
  ``engine.fleet_power_mw()`` price them with the calibrated
  :class:`repro.core.power.EnergyMeter`, so serving reports MEASURED
  frontend milliwatts, not the analytical steady-state assumption.

* **Power governor** (``governor=GovernorSpec(...)``, requires
  ``temporal=True``; `serve/governor.py`). Closes the loop on a chip
  mW budget: per-slot data knobs (recompute cap ``j_cap``, token tier
  ``k_eff``) are updated inside the jitted step from this frame's
  measured events and applied to the next frame's gate. Data, not
  shapes — a governed engine still compiles exactly once, and a slack
  budget is a bitwise no-op. Both knobs also bound the ragged kernels'
  per-slot row counts (DESIGN.md §11), so what the governor sheds is
  work the MXU never does and bytes VMEM never moves — not
  computed-then-masked tokens.

Use the engine when streams come and go or when one host serves many
cameras; use bare ``make_saccade_step`` for a single fixed-batch stream
(training-style evaluation, co-design sweeps).
"""

from __future__ import annotations

from typing import Any, Hashable, Mapping, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.power import EnergyMeter, EventCounts, dense_backend_macs
from repro.core.temporal import FeatureCache, init_feature_cache
from repro.models import backend_delta as bdel
from repro.serve import governor as gov_mod
from repro.serve.serve_step import make_rollout, saccade_scores


class StepHandle:
    """Non-blocking single-tick result (DESIGN.md §15).

    Holds the DEVICE-resident ``(S, n_classes)`` logits of one engine
    step plus the sid→slot map of the fed streams; :meth:`result`
    fetches them to the host (one blocking transfer) and caches the
    dict, so the fetch happens at most once and only when the caller
    actually wants the numbers. The handle stays valid across later
    engine calls — step outputs are fresh buffers, never donated — but
    holding many unfetched handles pins their logits in device memory;
    fetch (or drop) them within a tick or two.
    """

    __slots__ = ("_logits", "_slots", "_out")

    def __init__(self, logits, slots: dict):
        self._logits = logits
        self._slots = slots
        self._out = None

    def result(self) -> dict[Hashable, np.ndarray]:
        """Block until the logits are on the host; stream id -> (n_classes,)
        logits for exactly the fed streams. Idempotent."""
        if self._out is None:
            arr = None if self._logits is None else np.asarray(self._logits)
            self._out = {sid: arr[s] for sid, s in self._slots.items()}
            self._logits = None          # drop the device reference
        return self._out


class RolloutHandle:
    """Non-blocking rollout result: device-resident ``(T, S, n_classes)``
    logits plus the per-tick sid→slot maps; :meth:`result` fetches the
    whole rollout in ONE transfer and caches the per-tick dicts. Same
    lifetime contract as :class:`StepHandle`."""

    __slots__ = ("_logits", "_slot_maps", "_out")

    def __init__(self, logits, slot_maps: list):
        self._logits = logits
        self._slot_maps = slot_maps
        self._out = None

    def result(self) -> list[dict[Hashable, np.ndarray]]:
        """Block until the rollout's logits are on the host; one dict per
        tick (stream id -> (n_classes,) logits for that tick's fed
        streams). Idempotent."""
        if self._out is None:
            arr = None if self._logits is None else np.asarray(self._logits)
            self._out = [
                {sid: arr[t, s] for sid, s in m.items()}
                for t, m in enumerate(self._slot_maps)
            ]
            self._logits = None
        return self._out


class StreamState(NamedTuple):
    """Per-slot gaze state; every leaf is slot-major with static shape.

    ``cache`` is None unless the engine runs with ``temporal=True``, in
    which case it carries each slot's held-charge feature cache (incl.
    the per-patch age array driving the droop budget; DESIGN.md §6). The
    cache payload is stored in the digital wire format — int8 ADC codes
    (DESIGN.md §9) — so per-slot held state is 4x smaller than a float32
    cache; every mutation (step / admit wipe / freeze) preserves that
    dtype.

    ``events_last`` / ``events_mean`` are the per-slot energy meters
    (DESIGN.md §10): the events the slot's frontend executed on its last
    served frame, and the running per-frame MEAN since admit (inactive
    slots accrue nothing). The cumulative meter is a mean, not a sum, on
    purpose: counts stay at per-frame magnitude, so a week-long stream
    cannot saturate the float32 accumulator the way a monotone total
    would (increment < ulp ⇒ frozen meter); totals are derived as
    mean × frames at read time. Counts only — pricing happens at read
    time with the engine's :class:`EnergyMeter`, so recalibrating
    constants never touches device state. ``controls`` is the per-slot
    governor state (None unless the engine is governed).

    ``bcache`` is None unless the engine runs with ``backend_delta=True``
    (DESIGN.md §14): each slot's incremental-backend reuse state — the
    served wire rows it last computed on plus per-layer block outputs and
    cached logits/saliency — slot-major, wiped on admit, frozen on holds,
    exactly the ``cache`` playbook.
    """

    indices: jnp.ndarray    # (S, k) int32 — next frame's patch selection
    ema: jnp.ndarray        # (S, P) float32 — attention-score EMA
    frame_age: jnp.ndarray  # (S,) int32 — frames served since admit (0 = bootstrap)
    active: jnp.ndarray     # (S,) bool — slot occupied
    cache: FeatureCache | None = None   # per-slot temporal cache (temporal mode)
    events_last: EventCounts = EventCounts()    # (S,) leaves — last frame
    events_mean: EventCounts = EventCounts()    # (S,) leaves — mean/frame
    controls: gov_mod.GovernorControls | None = None  # governed mode only
    bcache: "bdel.BackendCache | None" = None  # backend-delta mode only (§14)


def _zero_events(capacity: int) -> EventCounts:
    return EventCounts(*(jnp.zeros((capacity,), jnp.float32)
                         for _ in EventCounts._fields))


def init_stream_state(
    cfg, capacity: int, temporal: bool = False, governed: bool = False,
    backend: bool = False,
) -> StreamState:
    """All slots free; indices are a placeholder (age 0 bootstraps in-step)."""
    k = cfg.frontend.n_active
    p = cfg.frontend.n_patches
    j_max = cfg.frontend.temporal.budget(k)
    return StreamState(
        indices=jnp.tile(jnp.arange(k, dtype=jnp.int32), (capacity, 1)),
        ema=jnp.zeros((capacity, p), jnp.float32),
        frame_age=jnp.zeros((capacity,), jnp.int32),
        active=jnp.zeros((capacity,), bool),
        cache=init_feature_cache(cfg.frontend, (capacity,)) if temporal else None,
        events_last=_zero_events(capacity),
        events_mean=_zero_events(capacity),
        controls=gov_mod.init_controls(capacity, j_max) if governed else None,
        # dtype from the ADC code wire — the same payload the FeatureCache
        # holds, so the two caches cannot disagree (§14)
        bcache=(bdel.init_backend_cache(
            cfg, k, batch_shape=(capacity,),
            dtype=cfg.frontend.adc.code_dtype) if backend else None),
    )


def _freeze_rows(act: jnp.ndarray, new, old):
    """Per-leaf ``where(active_slot, new, old)`` with act broadcast from
    (S,) up to each leaf's rank (slot-major leaves)."""
    def leaf(n, o):
        a = act.reshape(act.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)

    return jax.tree.map(leaf, new, old)


def make_engine_step(cfg, explore: float = 0.1, ema_decay: float = 0.0,
                     project_fn=None, temporal: bool = False,
                     governor: "gov_mod.GovernorSpec | None" = None,
                     meter: EnergyMeter = EnergyMeter(),
                     frame_hz: float = 30.0, backend: bool = False):
    """Batched slot step:
    (params, frames (S,H,W,3), fed (S,) bool, state) -> (logits, state).

    Per slot this is exactly one ``make_saccade_step`` frame — same compact
    forward, same :func:`saccade_scores` policy — plus the engine-only
    pieces: in-step bootstrap at age 0, EMA blending of the scores, and
    freezing of inactive OR un-fed slots (their rows pass through
    unchanged and their logits are zeroed; DESIGN.md §12 hold semantics).
    ``fed`` is DATA: feeding any subset of the slots is the same compiled
    program. Pure and jit-stable: nothing here depends on which slots are
    occupied or fed except through ``state`` and ``fed`` values.

    With ``temporal=True`` the per-slot temporal cache (held-charge
    feature reuse, DESIGN.md §6) is threaded through ``state.cache``; a
    fresh slot's cache rows are invalidated in-step (belt to the admit
    reset, so a recycled slot can never serve its previous occupant's
    held features).

    Always metered (DESIGN.md §10): each slot's executed events land in
    ``state.events_last`` / fold into the running mean ``state.events_mean``
    (inactive slots accrue nothing). With ``governor`` given, the
    per-slot control knobs in ``state.controls`` are applied to this
    frame's gate (``stale_cap`` / ``k_cap`` — data, not shapes) and
    updated from this frame's measured events for the next.

    With ``backend=True`` the per-slot :class:`BackendCache` is threaded
    through ``state.bcache`` (DESIGN.md §14): tokens whose served wire
    row is bitwise unchanged reuse their cached backend work, and a
    frame whose whole selection held serves the cached logits/saliency
    outright with zero backend MACs. A governed engine feeds
    ``state.controls.eps`` in as the per-slot snap budget (the
    ``backend_eps`` knob of stage 3c) and hands the governor the dense
    backend's feed-forward mW estimate so the system floor accounts for
    the compute it can shed.
    """
    from repro.core import frontend as fe
    from repro.core import saliency as sal
    from repro.models.vit import vit_forward_compact

    fcfg = cfg.frontend
    k = fcfg.n_active
    j_max = fcfg.temporal.budget(k)
    n_pixels = float(fcfg.image_h * fcfg.image_w)
    backend_mw = 0.0
    if backend:
        # the governor's plant model for the backend: what a DENSE
        # backend frame costs at this frame rate — the delta path can
        # only spend less (measured events report what it actually did)
        backend_mw = (dense_backend_macs(
            k, cfg.n_layers, fcfg.patch.n_vectors, cfg.d_model,
            cfg.d_ff, cfg.n_classes)
            * meter.k.e_backend_mac_j * frame_hz * 1e3)

    def step(params, frames, fed, state: StreamState):
        # a slot advances only when it is occupied AND fed this tick —
        # un-fed slots are a data-only hold (DESIGN.md §12): every row
        # below passes through unchanged, exactly like an inactive slot
        act = state.active & fed
        # optics/mosaic/CDS once; forwarded to the compact forward below
        patches, weights = fe.sensor_patches(params["ip2"], frames, fcfg)
        boot = sal.topk_patch_indices(sal.patch_energy(patches), k)
        fresh = state.frame_age == 0
        indices = jnp.where(fresh[:, None], boot, state.indices)

        cache = None
        if temporal:
            cache = state.cache._replace(
                valid=state.cache.valid & ~fresh[:, None]
            )
        bcache = eps = None
        if backend:
            # belt to the admit wipe, like the temporal cache above: a
            # fresh slot must never reuse its predecessor's activations
            bcache = state.bcache._replace(
                valid=state.bcache.valid & ~fresh
            )
            if governor is not None:
                eps = state.controls.eps
        k_cap = stale_cap = sign_mode = None
        if governor is not None:
            k_cap = gov_mod.tier_k_eff(governor, state.controls.tier, k)
            stale_cap = state.controls.j_cap
            if governor.sign_tier:
                # ADC-less tier (DESIGN.md §13): a (S,) bool DATA knob —
                # flagged slots serve the 1-bit sign view of the code
                # wire and re-ledger conversions as sign comparisons;
                # the cache keeps full-precision codes for recovery
                sign_mode = gov_mod.tier_is_sign(governor, state.controls.tier)
        logits, aux = vit_forward_compact(
            params, frames, cfg, indices=indices,
            project_fn=project_fn, precomputed=(patches, weights),
            cache=cache, k_cap=k_cap, stale_cap=stale_cap,
            sign_mode=sign_mode, backend_cache=bcache, backend_eps=eps,
            backend_act=act if backend else None,
        )
        scores = saccade_scores(aux, explore)
        ema = jnp.where(
            fresh[:, None], scores,
            ema_decay * state.ema + (1.0 - ema_decay) * scores,
        )
        next_idx = sal.topk_patch_indices(ema, k)

        # energy meters: only served slots spend events (held streams
        # accrue zero — they converted nothing this tick). The cumulative
        # meter is a RUNNING MEAN (Welford step over the frames served
        # since admit): per-frame magnitude, so long-lived streams never
        # freeze a float32 accumulator (see StreamState)
        ev_last = EventCounts(*(
            jnp.where(act, e, o)
            for e, o in zip(aux["events"], state.events_last)
        ))
        n_served = (state.frame_age + 1).astype(jnp.float32)     # incl. this
        ev_mean = EventCounts(*(
            jnp.where(act, m + (e - m) / n_served, m)
            for m, e in zip(state.events_mean, ev_last)
        ))
        controls = None
        if governor is not None:
            controls = gov_mod.control_update(
                governor, state.controls,
                EventCounts(*(e * act.astype(jnp.float32)
                              for e in aux["events"])),
                act, meter, frame_hz,
                n_pixels, fcfg.patch.pixels_per_patch, fcfg.patch.n_vectors,
                j_max, k, backend_mw=backend_mw,
            )
        new_state = StreamState(
            indices=jnp.where(act[:, None], next_idx, state.indices),
            ema=jnp.where(act[:, None], ema, state.ema),
            frame_age=jnp.where(act, state.frame_age + 1, state.frame_age),
            active=state.active,
            cache=(_freeze_rows(act, aux["cache"], state.cache)
                   if temporal else None),
            events_last=ev_last,
            events_mean=ev_mean,
            controls=controls,
            bcache=(_freeze_rows(act, aux["backend_cache"], state.bcache)
                    if backend else None),
        )
        logits = jnp.where(act[:, None], logits, 0.0)
        return logits, new_state

    return step


def _make_churn(k: int, j_max: int, governed: bool):
    """ONE coalesced churn flush (DESIGN.md §12): every admit row-reset,
    evict flag-clear, and governor budget re-split that accumulated since
    the last step is applied in a single jitted call over *traced* (S,)
    hit masks — continuous batching of slot churn, one device dispatch
    per frame no matter how many streams joined or left between frames.

    ``admit_hit`` rows are fully reset (a recycled slot can never serve
    its previous occupant's state); ``evict_hit`` rows only drop the
    active flag (their stale rows are garbage until the next admit resets
    them, same as the old per-call evict). A slot admitted after an evict
    in the same window is just an admit (the reset supersedes the clear —
    host bookkeeping collapses the ops last-wins per slot)."""

    def churn(state: StreamState, admit_hit, evict_hit,
              budgets=None) -> StreamState:
        hit = admit_hit
        cache = state.cache
        if cache is not None:
            # full row wipe: a recycled slot starts with no held charge.
            # zeros_like keeps the code dtype — where(..., 0.0, int8) would
            # silently promote the wire-format cache to float32 (§9)
            cache = FeatureCache(
                features=jnp.where(
                    hit[:, None, None],
                    jnp.zeros((), cache.features.dtype), cache.features,
                ),
                energy=jnp.where(hit[:, None], 0.0, cache.energy),
                age=jnp.where(hit[:, None], 0, cache.age),
                valid=cache.valid & ~hit[:, None],
                n_stale=jnp.where(hit, 0, cache.n_stale),
            )
        bcache = state.bcache
        if bcache is not None:
            # same contract as the feature-cache wipe: dtype-preserving
            # broadcast zeroing, so a recycled slot can never serve its
            # previous occupant's activations (§14)
            bcache = bdel.wipe_rows(bcache, hit)
        wiped = EventCounts(*(jnp.where(hit, 0.0, e)
                              for e in state.events_last))
        wiped_mean = EventCounts(*(jnp.where(hit, 0.0, e)
                                   for e in state.events_mean))
        controls = state.controls
        if controls is not None:
            controls = gov_mod.reset_rows(controls, hit, j_max)
            if governed:
                controls = controls._replace(budget_mw=budgets)
        return StreamState(
            indices=jnp.where(hit[:, None],
                              jnp.arange(k, dtype=jnp.int32)[None], state.indices),
            ema=jnp.where(hit[:, None], 0.0, state.ema),
            frame_age=jnp.where(hit, 0, state.frame_age),
            active=(state.active & ~evict_hit) | hit,
            cache=cache,
            events_last=wiped,
            events_mean=wiped_mean,
            controls=controls,
            bcache=bcache,
        )

    return churn


class SaccadeEngine:
    """Slot-based multi-stream saccadic server.

    Host-side bookkeeping maps stream ids to slots; all device state lives
    in :class:`StreamState` and is only ever rewritten by two jitted pure
    functions (the batched step, and ONE coalesced churn flush batching
    every pending admit/evict/budget row-write — DESIGN.md §12), each
    compiled exactly once. ``n_traces`` counts retraces of the batched
    step — the zero-recompile contract is ``engine.n_traces == 1`` no
    matter how streams churn.

    ``step(frames)`` serves any SUBSET of the admitted streams (partial-
    frame async serving, DESIGN.md §12): streams at different frame rates
    coexist — un-fed slots hold bitwise (state frozen, zero events), fed
    slots are bitwise identical to a full-cover step. Which slots are fed
    is data, so mixed-rate serving stays one compile.

    ``engine.state`` is the inspection surface (reading it flushes any
    pending churn first), but its buffers are DONATED to the next
    step/churn call: always read through the attribute
    (``engine.state.frame_age[...]``), never hold a ``StreamState``
    reference across a mutation — on backends that implement donation
    (TPU/GPU) the held buffers are invalidated.

    Args:
      cfg: ViTConfig for the backend.
      params: model params (held by the engine; the step stays pure).
      capacity: number of slots (static batch of the compiled step).
      mesh: optional device mesh; the slot axis shards over ``axis`` via
        shard_map when capacity divides the axis size (else replicated).
      axis: mesh axis name for the slot dimension (default "data").
      explore / project_fn: as in ``make_saccade_step``.
      ema_decay: attention-EMA smoothing; 0.0 (default) = per-frame scores,
        matching the single-stream step exactly.
      temporal: enable the per-slot temporal delta gate (DESIGN.md §6) —
        each slot carries a held-charge :class:`FeatureCache` in
        ``state.cache``; only the stale subset of each frame's selection
        is re-projected/ADC-converted (``cfg.frontend.temporal`` sets the
        threshold/budget), and admit wipes the recycled slot's cache row.
      meter / frame_hz: the :class:`EnergyMeter` pricing the per-slot
        event meters and the sensor frame rate it prices at (DESIGN.md
        §10). Metering is always on; these only affect the readout.
      governor: a :class:`repro.serve.governor.GovernorSpec` — closes
        the loop on a chip mW budget (requires ``temporal=True``: the
        recompute cap is a knob of the temporal gate). Budget shares are
        priority-weighted over admitted streams (``admit(priority=...)``)
        and reallocated on every admit/evict (data-only row writes).
      backend_delta: thread a per-slot incremental-backend cache
        (:class:`repro.models.backend_delta.BackendCache`, DESIGN.md
        §14) through the step — tokens whose served wire row is bitwise
        unchanged reuse their cached backend work; a fully-held frame
        serves the cached logits with zero backend MACs. Pairs naturally
        with ``temporal=True`` (held charge is what holds the wire rows
        still) but is independent of it. A governed engine additionally
        drives the per-slot snap budget ``eps`` from the power loop when
        ``governor.backend_eps > 0`` (which *requires* this flag).
    """

    def __init__(self, cfg, params, capacity: int = 8, *, mesh=None,
                 axis: str = "data", explore: float = 0.1,
                 ema_decay: float = 0.0, project_fn=None,
                 temporal: bool = False,
                 meter: EnergyMeter = EnergyMeter(),
                 frame_hz: float = 30.0,
                 governor: "gov_mod.GovernorSpec | None" = None,
                 backend_delta: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        if governor is not None and not temporal:
            raise ValueError(
                "governor requires temporal=True: the recompute cap "
                "governs the temporal gate's per-frame allocation "
                "(DESIGN.md §10)"
            )
        if (governor is not None and governor.backend_eps > 0.0
                and not backend_delta):
            raise ValueError(
                "governor.backend_eps budgets the delta-gated backend "
                "(DESIGN.md §14); build the engine with "
                "backend_delta=True or drop backend_eps"
            )
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.mesh = mesh
        self.temporal = temporal
        self.backend = backend_delta
        self.meter = meter
        self.frame_hz = frame_hz
        self.governor = governor
        self._priority: dict[Hashable, float] = {}
        self._slots: list[Hashable | None] = [None] * capacity
        # cached sid -> slot map: the hot per-tick lookup (the list scan
        # in slot_of cost O(S) per fed stream per tick); maintained by
        # admit/evict, asserted == the slot list in tests
        self._slot_index: dict[Hashable, int] = {}
        self._n_traces = 0
        self._n_rollout_traces = 0
        # continuous batching of churn (DESIGN.md §12): slot -> "admit" |
        # "evict", last-op-wins; flushed in ONE jitted call before the
        # next step or state read
        self._pending: dict[int, str] = {}
        self._budgets_dirty = False
        self._budget_mw = None if governor is None else governor.budget_mw
        # fed-rows-only ingest (DESIGN.md §15): compact host staging for
        # the F fed rows (+ their slot ids) and the preallocated fed
        # mask, reused every tick — steady-state serving stages no fresh
        # host allocations
        self._stage = np.zeros(
            (capacity, cfg.frontend.image_h, cfg.frontend.image_w, 3),
            np.float32)
        self._stage_slots = np.zeros((capacity,), np.int32)
        self._fed = np.zeros((capacity,), bool)
        # rollout staging, cached per distinct T (matching the one-trace-
        # per-T compile contract). Un-fed rows keep stale bytes from the
        # previous rollout of the same T — safe for the same reason the
        # per-tick path's persistent device buffer is: the scanned fed
        # mask gates every un-fed row out of the computation
        self._roll_stage: dict[int, tuple[np.ndarray, np.ndarray]] = {}

        fn = make_engine_step(cfg, explore=explore, ema_decay=ema_decay,
                              project_fn=project_fn, temporal=temporal,
                              governor=governor, meter=meter,
                              frame_hz=frame_hz, backend=backend_delta)

        self._slot_spec = P()
        if mesh is not None:
            from repro.launch.shardings import fit_spec

            spec = fit_spec(P(axis), (capacity,), mesh)
            # fit_spec replicates an indivisible axis by returning P(None) —
            # only shard_map when the slot axis actually survived
            if any(a is not None for a in spec):
                self._slot_spec = spec
                # per-slot parallel, params replicated — no collectives
                fn = shard_map(
                    fn, mesh=mesh,
                    in_specs=(P(), self._slot_spec, self._slot_spec,
                              self._slot_spec),
                    out_specs=(self._slot_spec, self._slot_spec),
                )

        def counted(params, frames, fed, state):
            # trace-time side effect: jit re-traces exactly once per compile,
            # so this counts compilations (the zero-recompile contract)
            self._n_traces += 1
            return fn(params, frames, fed, state)

        rollout = make_rollout(fn)

        def counted_rollout(params, frames_seq, fed_seq, state):
            # one trace PER DISTINCT T (the scan length is static);
            # reused Ts hit the jit cache — asserted in tests
            self._n_rollout_traces += 1
            return rollout(params, frames_seq, fed_seq, state)

        k = cfg.frontend.n_active
        self._step_fn = jax.jit(counted, donate_argnums=(3,))
        self._rollout_fn = jax.jit(counted_rollout, donate_argnums=(3,))
        self._churn_fn = jax.jit(
            _make_churn(k, cfg.frontend.temporal.budget(k),
                        governed=governor is not None),
            donate_argnums=(0,))

        def scatter(buf, rows, slots):
            # fed-rows-only ingest (DESIGN.md §15): (F, H, W, 3) staged
            # rows land in the donated persistent device frame buffer
            return buf.at[slots].set(rows)

        self._scatter_fn = jax.jit(scatter, donate_argnums=(0,))

        state = init_stream_state(cfg, capacity, temporal=temporal,
                                  governed=governor is not None,
                                  backend=backend_delta)
        # the persistent device frame buffer the scatter writes into and
        # the step reads from; sharded/placed like the slot-major state
        frames_dev = jnp.zeros(
            (capacity, cfg.frontend.image_h, cfg.frontend.image_w, 3),
            jnp.float32)
        if mesh is not None and self._slot_spec != P():
            sh = NamedSharding(mesh, self._slot_spec)
            state = jax.tree.map(lambda x: jax.device_put(x, sh), state)
            frames_dev = jax.device_put(frames_dev, sh)
        self._state = state
        self._frames_dev = frames_dev

    # ---- host-side slot bookkeeping ------------------------------------
    @property
    def state(self) -> StreamState:
        """Device state with any pending churn flushed first — the
        coalescing is invisible to readers."""
        self._flush_churn()
        return self._state

    @property
    def n_traces(self) -> int:
        return self._n_traces

    @property
    def n_rollout_traces(self) -> int:
        """Compilations of the rollout program — one per DISTINCT rollout
        length T ever dispatched (T is static per compile; reused Ts hit
        the jit cache)."""
        return self._n_rollout_traces

    @property
    def stream_ids(self) -> list[Hashable]:
        return [s for s in self._slots if s is not None]

    @property
    def free_slots(self) -> int:
        return self._slots.count(None)

    def slot_of(self, stream_id: Hashable) -> int:
        try:
            return self._slot_index[stream_id]
        except KeyError:
            raise KeyError(f"stream {stream_id!r} not admitted") from None

    def admit(self, stream_id: Hashable, priority: float = 1.0) -> int:
        """Claim a free slot for a new stream; its first frame bootstraps
        from the in-pixel energy proxy inside the next step() call.
        ``priority`` weights the stream's share of a governed engine's
        power budget (ignored ungoverned). Host bookkeeping only — the
        device row-reset coalesces into the next churn flush."""
        if stream_id in self._slots:
            raise ValueError(f"stream {stream_id!r} already admitted")
        if priority <= 0:
            raise ValueError(f"priority must be > 0, got {priority}")
        try:
            slot = self._slots.index(None)
        except ValueError:
            raise RuntimeError(
                f"engine at capacity ({self.capacity}); evict a stream first"
            ) from None
        self._slots[slot] = stream_id
        self._slot_index[stream_id] = slot
        self._priority[stream_id] = float(priority)
        self._pending[slot] = "admit"
        self._budgets_dirty = True
        return slot

    def evict(self, stream_id: Hashable) -> None:
        slot = self.slot_of(stream_id)
        self._slots[slot] = None
        del self._slot_index[stream_id]
        self._priority.pop(stream_id, None)
        self._pending[slot] = "evict"        # last-op-wins per slot
        self._budgets_dirty = True

    def set_budget_mw(self, budget_mw: float) -> None:
        """Rewrite this engine's total power budget (the fleet layer's
        host-level knob, DESIGN.md §12): per-slot shares are re-split at
        the next churn flush — data-only, never a recompile."""
        if self.governor is None:
            raise RuntimeError("engine was built without a governor")
        if budget_mw <= 0:
            raise ValueError(f"budget_mw must be > 0, got {budget_mw}")
        self._budget_mw = float(budget_mw)
        self._budgets_dirty = True

    @property
    def budget_mw(self) -> float | None:
        """The engine-total power budget currently being split over slots
        (None when ungoverned)."""
        return self._budget_mw

    def _flush_churn(self) -> None:
        """Apply every pending admit/evict row-write (plus the governed
        budget re-split, DESIGN.md §10/§12) in ONE jitted call."""
        dirty_budget = self.governor is not None and self._budgets_dirty
        if not self._pending and not dirty_budget:
            return
        admit_hit = np.zeros((self.capacity,), bool)
        evict_hit = np.zeros((self.capacity,), bool)
        for slot, op in self._pending.items():
            (admit_hit if op == "admit" else evict_hit)[slot] = True
        args = ()
        if self.governor is not None:
            w = np.zeros((self.capacity,), np.float64)
            for slot, sid in enumerate(self._slots):
                if sid is not None:
                    w[slot] = self._priority[sid]
            args = (jnp.asarray(gov_mod.allocate_budgets(
                self.governor, w, total_mw=self._budget_mw)),)
        self._state = self._churn_fn(
            self._state, jnp.asarray(admit_hit), jnp.asarray(evict_hit),
            *args)
        self._pending.clear()
        self._budgets_dirty = False

    # ---- serving -------------------------------------------------------
    def _stage_tick(self, frames: Mapping[Hashable, Any]
                    ) -> tuple[np.ndarray, dict[Hashable, int]]:
        """Stage one tick's frames for dispatch: validate ids, record the
        F fed rows compactly in the reused host staging buffers, and set
        the preallocated fed mask. Returns (fed mask view, sid->slot)."""
        fed = self._fed
        fed[:] = False
        slots_by_sid: dict[Hashable, int] = {}
        f = 0
        for sid, frame in frames.items():
            try:
                slot = self._slot_index[sid]
            except KeyError:
                unknown = set(frames) - self._slot_index.keys()
                raise ValueError(
                    f"frames for streams never admitted: "
                    f"unknown={sorted(map(str, unknown))}"
                ) from None
            self._stage[f] = frame          # f32 copy into the staging row
            self._stage_slots[f] = slot
            fed[slot] = True
            slots_by_sid[sid] = slot
            f += 1
        return fed, slots_by_sid

    def step(self, frames: Mapping[Hashable, Any], block: bool = True
             ) -> "dict[Hashable, np.ndarray] | StepHandle":
        """Serve one frame for any subset of the admitted streams.

        ``frames`` maps stream id -> (H, W, 3) RGB frame. Admitted
        streams without a frame this tick HOLD (partial-frame async
        serving, DESIGN.md §12): their per-stream clocks, gaze state,
        temporal cache, and meters do not advance, and the fed streams
        are served bitwise as if every stream had been fed. Unknown
        stream ids raise.

        Ingest uploads ONLY the fed rows (DESIGN.md §15): the F staged
        rows are one compact H2D copy scattered into the persistent
        donated device frame buffer — never a full-capacity upload.

        With ``block=True`` (default) returns stream id -> (n_classes,)
        logits for exactly the fed streams. With ``block=False`` the
        call returns as soon as the step is DISPATCHED: you get a
        :class:`StepHandle` over the device-resident logits and fetch
        them later via ``handle.result()`` — the async path that lets
        the fleet layer overlap many engines' device work (DESIGN.md
        §15). For T known ticks, prefer :meth:`step_rollout` — one
        dispatch instead of T.
        """
        if not frames:
            # nothing fed: all slots hold, no device dispatch
            return {} if block else StepHandle(None, {})
        fed, slots_by_sid = self._stage_tick(frames)
        self._flush_churn()
        f = len(slots_by_sid)
        self._frames_dev = self._scatter_fn(
            self._frames_dev, jnp.asarray(self._stage[:f]),
            jnp.asarray(self._stage_slots[:f]))
        logits, self._state = self._step_fn(
            self.params, self._frames_dev, jnp.asarray(fed), self._state)
        handle = StepHandle(logits, slots_by_sid)
        return handle.result() if block else handle

    def step_rollout(self, frames_by_tick, block: bool = True
                     ) -> "list[dict[Hashable, np.ndarray]] | RolloutHandle":
        """Serve T ticks in ONE device dispatch (DESIGN.md §15).

        ``frames_by_tick`` is a sequence of T per-tick frame dicts, each
        exactly what :meth:`step` takes (any subset of the admitted
        streams; an empty dict is a legal all-hold tick). The whole
        closed saccade loop — selection, temporal gate, backend,
        governor control law, meters — runs device-resident under a
        ``lax.scan`` over the T ticks: logits and the final
        :class:`StreamState` are BITWISE identical to T sequential
        ``step()`` calls (tests/test_rollout.py), but the per-tick host
        round-trip (python staging, upload, dispatch, fetch) is paid
        once per rollout instead of once per tick.

        The stream cohort is fixed for the rollout: churn (admit/evict,
        budget re-splits) happens at rollout BOUNDARIES — pending churn
        flushes before dispatch, new ops apply to the next call. The
        governor's control law still runs per tick, in-scan. T is
        static per compile: each distinct T traces once
        (``n_rollout_traces``), reused Ts hit the jit cache.

        With ``block=True`` returns a list of T dicts (stream id ->
        logits for that tick's fed streams); ``block=False`` returns a
        :class:`RolloutHandle` fetching all T ticks in one transfer.
        """
        ticks = list(frames_by_tick)
        t_len = len(ticks)
        if t_len == 0:
            return [] if block else RolloutHandle(None, [])
        slot_maps: list[dict[Hashable, int]] = []
        for t, fr in enumerate(ticks):
            unknown = set(fr) - self._slot_index.keys()
            if unknown:
                raise ValueError(
                    f"tick {t}: frames for streams never admitted: "
                    f"unknown={sorted(map(str, unknown))}"
                )
            slot_maps.append({sid: self._slot_index[sid] for sid in fr})
        self._flush_churn()
        try:
            frames_seq, fed_seq = self._roll_stage[t_len]
        except KeyError:
            frames_seq = np.zeros((t_len,) + self._stage.shape, np.float32)
            fed_seq = np.zeros((t_len, self.capacity), bool)
            self._roll_stage[t_len] = (frames_seq, fed_seq)
        fed_seq[:] = False
        for t, fr in enumerate(ticks):
            for sid, frame in fr.items():
                slot = slot_maps[t][sid]
                frames_seq[t, slot] = frame
                fed_seq[t, slot] = True
        logits_seq, self._state = self._rollout_fn(
            self.params, jnp.asarray(frames_seq), jnp.asarray(fed_seq),
            self._state)
        handle = RolloutHandle(logits_seq, slot_maps)
        return handle.result() if block else handle

    def recompute_fraction(self, stream_id: Hashable) -> float:
        """Fraction of this stream's k selected patches that were actually
        re-projected/ADC-converted on its last served frame (temporal mode
        only). 1.0 on the bootstrap frame; drops toward 0 on static scenes
        as held charge serves the selection (DESIGN.md §6)."""
        if not self.temporal:
            raise RuntimeError("engine was built without temporal=True")
        slot = self.slot_of(stream_id)
        if int(self.state.frame_age[slot]) == 0:
            raise RuntimeError(
                f"stream {stream_id!r} has not served a frame yet"
            )
        # a governed slot only selects its tier's k_eff tokens, not the
        # static k — dividing by cfg n_active would understate recompute
        # on shed slots (e.g. 8 stale of a 16-token tier is 0.5, not 0.25)
        denom = (self.k_tier(stream_id) if self.governor is not None
                 else self.cfg.frontend.n_active)
        return float(self.state.cache.n_stale[slot]) / denom

    # ---- energy metering (DESIGN.md §10) -------------------------------
    def _fetch_meters(self, window: str) -> tuple[EventCounts, np.ndarray]:
        """ONE batched device->host fetch of (meter counts, frame ages) —
        every metering read costs exactly one sync no matter the slot
        count (asserted in tests/test_serve_engine.py)."""
        st = self.state
        src = st.events_last if window == "last" else st.events_mean
        host, ages = jax.device_get((src, st.frame_age))
        return (EventCounts(*(np.asarray(e) for e in host)),
                np.asarray(ages))

    def events(self, stream_id: Hashable, window: str = "last") -> EventCounts:
        """This stream's executed energy events: ``window="last"`` — the
        last served frame; ``"mean"`` — the per-frame mean since admit;
        ``"total"`` — cumulative since admit (derived as mean × frames in
        float64 at read time; the device meter stays at per-frame
        magnitude so it cannot saturate, see :class:`StreamState`)."""
        if window not in ("last", "mean", "total"):
            raise ValueError(
                f"window must be 'last', 'mean' or 'total', got {window!r}")
        slot = self.slot_of(stream_id)
        host, ages = self._fetch_meters(
            "last" if window == "last" else "mean")
        ev = EventCounts(*(float(e[slot]) for e in host))
        if window == "total":
            return ev.scale(float(ages[slot]))
        return ev

    def power_mw(self, stream_id: Hashable, window: str = "last") -> float:
        """MEASURED frontend power of this stream in mW, priced from its
        executed events by the engine's meter: ``window="last"`` — the
        last served frame's instantaneous power; ``"mean"`` — the average
        over every frame served since admit."""
        if window not in ("last", "mean"):
            raise ValueError(f"window must be 'last' or 'mean', got {window!r}")
        slot = self.slot_of(stream_id)
        host, ages = self._fetch_meters(window)
        if window == "mean" and ages[slot] == 0:
            raise RuntimeError(
                f"stream {stream_id!r} has not served a frame yet")
        return float(self.meter.power_mw(
            EventCounts(*(float(e[slot]) for e in host)), self.frame_hz))

    def fleet_power_mw(self, window: str = "last") -> float:
        """Measured frontend power summed over all admitted streams —
        the quantity a governed engine holds against its chip budget.
        Streams admitted but not yet served carry zero events and are
        skipped (they have no frame to average). Priced VECTORIZED over
        the slot axis from one batched fetch — O(1) syncs and one
        broadcast pricing pass regardless of capacity."""
        if window not in ("last", "mean"):
            raise ValueError(f"window must be 'last' or 'mean', got {window!r}")
        host, ages = self._fetch_meters(window)
        served = np.array(
            [s is not None for s in self._slots]) & (ages > 0)
        # EnergyMeter.power_mw is pure leaf arithmetic — (S,) counts in,
        # (S,) milliwatts out
        per_slot = np.asarray(self.meter.power_mw(host, self.frame_hz))
        return float(np.where(served, per_slot, 0.0).sum())

    def energy_report(self, stream_id: Hashable) -> dict:
        """Per-component joules this stream has spent since admit."""
        return self.meter.energy_j(
            self.events(stream_id, "total"), self.frame_hz)

    def recompute_cap(self, stream_id: Hashable) -> int:
        """The governor's current per-frame recompute allocation for this
        stream (governed engines only)."""
        if self.governor is None:
            raise RuntimeError("engine was built without a governor")
        return int(self.state.controls.j_cap[self.slot_of(stream_id)])

    def k_tier(self, stream_id: Hashable) -> int:
        """The governor's current active-token count for this stream
        (k_eff of its tier; governed engines only). The sign tier keeps
        the finest k tier's token count — it degrades the readout, not
        the selection (DESIGN.md §13)."""
        if self.governor is None:
            raise RuntimeError("engine was built without a governor")
        tier = int(self.state.controls.tier[self.slot_of(stream_id)])
        tokens = self.governor.tier_tokens(self.cfg.frontend.n_active)
        return tokens[min(tier, len(tokens) - 1)]

    def sign_readout(self, stream_id: Hashable) -> bool:
        """True while the governor holds this stream in the ADC-less
        sign-readout tier (DESIGN.md §13; governed engines only)."""
        if self.governor is None:
            raise RuntimeError("engine was built without a governor")
        tier = int(self.state.controls.tier[self.slot_of(stream_id)])
        return bool(self.governor.sign_tier
                    and tier >= len(self.governor.k_tiers))

    def backend_eps(self, stream_id: Hashable) -> float:
        """The governor's current backend snap budget for this stream
        (0.0 = exact reuse; DESIGN.md §14; governed backend-delta
        engines only)."""
        if self.governor is None:
            raise RuntimeError("engine was built without a governor")
        if not self.backend:
            raise RuntimeError("engine was built without backend_delta=True")
        return float(self.state.controls.eps[self.slot_of(stream_id)])

    def backend_cached(self, stream_id: Hashable) -> bool:
        """True when this stream's last served frame was answered entirely
        from its :class:`BackendCache` — zero backend MACs executed
        (DESIGN.md §14; backend-delta engines only)."""
        if not self.backend:
            raise RuntimeError("engine was built without backend_delta=True")
        slot = self.slot_of(stream_id)
        st = self.state
        if int(st.frame_age[slot]) == 0:
            raise RuntimeError(
                f"stream {stream_id!r} has not served a frame yet")
        return float(st.events_last.backend_macs[slot]) == 0.0

    def gaze(self, stream_id: Hashable) -> np.ndarray:
        """The (k,) patch indices this stream will ADC-convert next frame.

        Undefined before the stream's first frame — a fresh admit selects
        its first gaze from the in-pixel energy proxy *inside* the next
        step() call, so there is nothing to report yet (raises).
        """
        slot = self.slot_of(stream_id)
        if int(self.state.frame_age[slot]) == 0:
            raise RuntimeError(
                f"stream {stream_id!r} has not served a frame yet; its first "
                f"gaze is the in-step energy bootstrap of the next step()"
            )
        return np.asarray(self.state.indices[slot])
