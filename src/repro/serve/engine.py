"""Multi-stream saccadic serving engine (DESIGN.md §5).

The paper's switched-cap readout is non-destructive precisely to enable
processing parallelism at <30 mW/MP; the backend half of that story is
serving MANY camera streams through ONE compiled program. This module
batches N independent streams through the compact saccade path
(`serve_step.make_saccade_step`'s exact per-frame semantics) in a single
jitted step:

* **Slots, not streams.** The engine owns ``capacity`` fixed slots; every
  device tensor is slot-major with a static leading axis, so the batched
  step is a pure function of ``(params, frames, state)`` and compiles
  exactly once. Streams join/leave between frames via host-side
  bookkeeping (``admit`` / ``evict``) that only rewrites state rows —
  never shapes — so an admit→evict→admit cycle causes ZERO recompiles
  (asserted in tests via the engine's trace counter).

* **Per-stream gaze state.** :class:`StreamState` carries each slot's
  current patch indices, an attention-score EMA (temporal smoothing of
  the saccade policy; ``ema_decay=0`` reproduces the single-stream step
  frame-for-frame), the frame age (age 0 ⇒ in-step bootstrap from the
  patch-energy proxy), and the slot-occupied flag.

* **In-step bootstrap.** Freshly admitted slots select their first gaze
  from the in-pixel energy proxy *inside* the batched step
  (``sensor_patches`` runs once and is forwarded to the compact forward
  via ``precomputed``), so admission needs no per-stream compiled
  bootstrap call and mixed-age batches stay one program.

* **Sharding.** With a mesh, the slot axis is sharded over the mesh's
  data axis via ``shard_map`` — the step is per-slot parallel with
  replicated params, so no collectives cross the slot axis. State
  buffers are donated, so steady-state serving is allocation-free on
  accelerators that support donation.

Use the engine when streams come and go or when one host serves many
cameras; use bare ``make_saccade_step`` for a single fixed-batch stream
(training-style evaluation, co-design sweeps).
"""

from __future__ import annotations

from typing import Any, Hashable, Mapping, NamedTuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.temporal import FeatureCache, init_feature_cache
from repro.serve.serve_step import saccade_scores


class StreamState(NamedTuple):
    """Per-slot gaze state; every leaf is slot-major with static shape.

    ``cache`` is None unless the engine runs with ``temporal=True``, in
    which case it carries each slot's held-charge feature cache (incl.
    the per-patch age array driving the droop budget; DESIGN.md §6). The
    cache payload is stored in the digital wire format — int8 ADC codes
    (DESIGN.md §9) — so per-slot held state is 4x smaller than a float32
    cache; every mutation (step / admit wipe / freeze) preserves that
    dtype.
    """

    indices: jnp.ndarray    # (S, k) int32 — next frame's patch selection
    ema: jnp.ndarray        # (S, P) float32 — attention-score EMA
    frame_age: jnp.ndarray  # (S,) int32 — frames served since admit (0 = bootstrap)
    active: jnp.ndarray     # (S,) bool — slot occupied
    cache: FeatureCache | None = None   # per-slot temporal cache (temporal mode)


def init_stream_state(cfg, capacity: int, temporal: bool = False) -> StreamState:
    """All slots free; indices are a placeholder (age 0 bootstraps in-step)."""
    k = cfg.frontend.n_active
    p = cfg.frontend.n_patches
    return StreamState(
        indices=jnp.tile(jnp.arange(k, dtype=jnp.int32), (capacity, 1)),
        ema=jnp.zeros((capacity, p), jnp.float32),
        frame_age=jnp.zeros((capacity,), jnp.int32),
        active=jnp.zeros((capacity,), bool),
        cache=init_feature_cache(cfg.frontend, (capacity,)) if temporal else None,
    )


def _freeze_rows(act: jnp.ndarray, new, old):
    """Per-leaf ``where(active_slot, new, old)`` with act broadcast from
    (S,) up to each leaf's rank (slot-major leaves)."""
    def leaf(n, o):
        a = act.reshape(act.shape + (1,) * (n.ndim - 1))
        return jnp.where(a, n, o)

    return jax.tree.map(leaf, new, old)


def make_engine_step(cfg, explore: float = 0.1, ema_decay: float = 0.0,
                     project_fn=None, temporal: bool = False):
    """Batched slot step: (params, frames (S,H,W,3), state) -> (logits, state).

    Per slot this is exactly one ``make_saccade_step`` frame — same compact
    forward, same :func:`saccade_scores` policy — plus the engine-only
    pieces: in-step bootstrap at age 0, EMA blending of the scores, and
    freezing of inactive slots (their rows pass through unchanged and
    their logits are zeroed). Pure and jit-stable: nothing here depends on
    which slots are occupied except through ``state`` values.

    With ``temporal=True`` the per-slot temporal cache (held-charge
    feature reuse, DESIGN.md §6) is threaded through ``state.cache``; a
    fresh slot's cache rows are invalidated in-step (belt to the admit
    reset, so a recycled slot can never serve its previous occupant's
    held features).
    """
    from repro.core import frontend as fe
    from repro.core import saliency as sal
    from repro.models.vit import vit_forward_compact

    fcfg = cfg.frontend
    k = fcfg.n_active

    def step(params, frames, state: StreamState):
        # optics/mosaic/CDS once; forwarded to the compact forward below
        patches, weights = fe.sensor_patches(params["ip2"], frames, fcfg)
        boot = sal.topk_patch_indices(sal.patch_energy(patches), k)
        fresh = state.frame_age == 0
        indices = jnp.where(fresh[:, None], boot, state.indices)

        cache = None
        if temporal:
            cache = state.cache._replace(
                valid=state.cache.valid & ~fresh[:, None]
            )
        logits, aux = vit_forward_compact(
            params, frames, cfg, indices=indices,
            project_fn=project_fn, precomputed=(patches, weights),
            cache=cache,
        )
        scores = saccade_scores(aux, explore)
        ema = jnp.where(
            fresh[:, None], scores,
            ema_decay * state.ema + (1.0 - ema_decay) * scores,
        )
        next_idx = sal.topk_patch_indices(ema, k)

        act = state.active
        new_state = StreamState(
            indices=jnp.where(act[:, None], next_idx, state.indices),
            ema=jnp.where(act[:, None], ema, state.ema),
            frame_age=jnp.where(act, state.frame_age + 1, state.frame_age),
            active=act,
            cache=(_freeze_rows(act, aux["cache"], state.cache)
                   if temporal else None),
        )
        logits = jnp.where(act[:, None], logits, 0.0)
        return logits, new_state

    return step


def _make_admit(capacity: int, k: int):
    """Row reset with a *traced* slot scalar — one compile for any slot."""

    def admit(state: StreamState, slot) -> StreamState:
        hit = jnp.arange(capacity) == slot
        cache = state.cache
        if cache is not None:
            # full row wipe: a recycled slot starts with no held charge.
            # zeros_like keeps the code dtype — where(..., 0.0, int8) would
            # silently promote the wire-format cache to float32 (§9)
            cache = FeatureCache(
                features=jnp.where(
                    hit[:, None, None],
                    jnp.zeros((), cache.features.dtype), cache.features,
                ),
                energy=jnp.where(hit[:, None], 0.0, cache.energy),
                age=jnp.where(hit[:, None], 0, cache.age),
                valid=cache.valid & ~hit[:, None],
                n_stale=jnp.where(hit, 0, cache.n_stale),
            )
        return StreamState(
            indices=jnp.where(hit[:, None],
                              jnp.arange(k, dtype=jnp.int32)[None], state.indices),
            ema=jnp.where(hit[:, None], 0.0, state.ema),
            frame_age=jnp.where(hit, 0, state.frame_age),
            active=state.active | hit,
            cache=cache,
        )

    return admit


def _make_evict(capacity: int):
    def evict(state: StreamState, slot) -> StreamState:
        hit = jnp.arange(capacity) == slot
        return state._replace(active=state.active & ~hit)

    return evict


class SaccadeEngine:
    """Slot-based multi-stream saccadic server.

    Host-side bookkeeping maps stream ids to slots; all device state lives
    in :class:`StreamState` and is only ever rewritten by three jitted
    pure functions (step / admit-row-reset / evict-flag-clear), each
    compiled exactly once. ``n_traces`` counts retraces of the batched
    step — the zero-recompile contract is ``engine.n_traces == 1`` no
    matter how streams churn.

    ``engine.state`` is the inspection surface, but its buffers are
    DONATED to the next step/admit/evict call: always read through the
    attribute (``engine.state.frame_age[...]``), never hold a
    ``StreamState`` reference across a mutation — on backends that
    implement donation (TPU/GPU) the held buffers are invalidated.

    Args:
      cfg: ViTConfig for the backend.
      params: model params (held by the engine; the step stays pure).
      capacity: number of slots (static batch of the compiled step).
      mesh: optional device mesh; the slot axis shards over ``axis`` via
        shard_map when capacity divides the axis size (else replicated).
      axis: mesh axis name for the slot dimension (default "data").
      explore / project_fn: as in ``make_saccade_step``.
      ema_decay: attention-EMA smoothing; 0.0 (default) = per-frame scores,
        matching the single-stream step exactly.
      temporal: enable the per-slot temporal delta gate (DESIGN.md §6) —
        each slot carries a held-charge :class:`FeatureCache` in
        ``state.cache``; only the stale subset of each frame's selection
        is re-projected/ADC-converted (``cfg.frontend.temporal`` sets the
        threshold/budget), and admit wipes the recycled slot's cache row.
    """

    def __init__(self, cfg, params, capacity: int = 8, *, mesh=None,
                 axis: str = "data", explore: float = 0.1,
                 ema_decay: float = 0.0, project_fn=None,
                 temporal: bool = False):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.cfg = cfg
        self.params = params
        self.capacity = capacity
        self.mesh = mesh
        self.temporal = temporal
        self._slots: list[Hashable | None] = [None] * capacity
        self._n_traces = 0

        fn = make_engine_step(cfg, explore=explore, ema_decay=ema_decay,
                              project_fn=project_fn, temporal=temporal)

        self._slot_spec = P()
        if mesh is not None:
            from repro.launch.shardings import fit_spec

            spec = fit_spec(P(axis), (capacity,), mesh)
            # fit_spec replicates an indivisible axis by returning P(None) —
            # only shard_map when the slot axis actually survived
            if any(a is not None for a in spec):
                self._slot_spec = spec
                # per-slot parallel, params replicated — no collectives
                fn = shard_map(
                    fn, mesh=mesh,
                    in_specs=(P(), self._slot_spec, self._slot_spec),
                    out_specs=(self._slot_spec, self._slot_spec),
                )

        def counted(params, frames, state):
            # trace-time side effect: jit re-traces exactly once per compile,
            # so this counts compilations (the zero-recompile contract)
            self._n_traces += 1
            return fn(params, frames, state)

        self._step_fn = jax.jit(counted, donate_argnums=(2,))
        self._admit_fn = jax.jit(
            _make_admit(capacity, cfg.frontend.n_active), donate_argnums=(0,))
        self._evict_fn = jax.jit(_make_evict(capacity), donate_argnums=(0,))

        state = init_stream_state(cfg, capacity, temporal=temporal)
        if mesh is not None and self._slot_spec != P():
            sh = NamedSharding(mesh, self._slot_spec)
            state = jax.tree.map(lambda x: jax.device_put(x, sh), state)
        self.state = state

    # ---- host-side slot bookkeeping ------------------------------------
    @property
    def n_traces(self) -> int:
        return self._n_traces

    @property
    def stream_ids(self) -> list[Hashable]:
        return [s for s in self._slots if s is not None]

    @property
    def free_slots(self) -> int:
        return self._slots.count(None)

    def slot_of(self, stream_id: Hashable) -> int:
        try:
            return self._slots.index(stream_id)
        except ValueError:
            raise KeyError(f"stream {stream_id!r} not admitted") from None

    def admit(self, stream_id: Hashable) -> int:
        """Claim a free slot for a new stream; its first frame bootstraps
        from the in-pixel energy proxy inside the next step() call."""
        if stream_id in self._slots:
            raise ValueError(f"stream {stream_id!r} already admitted")
        try:
            slot = self._slots.index(None)
        except ValueError:
            raise RuntimeError(
                f"engine at capacity ({self.capacity}); evict a stream first"
            ) from None
        self._slots[slot] = stream_id
        self.state = self._admit_fn(self.state, jnp.int32(slot))
        return slot

    def evict(self, stream_id: Hashable) -> None:
        slot = self.slot_of(stream_id)
        self._slots[slot] = None
        self.state = self._evict_fn(self.state, jnp.int32(slot))

    # ---- serving -------------------------------------------------------
    def step(self, frames: Mapping[Hashable, Any]) -> dict[Hashable, np.ndarray]:
        """Serve one frame for every admitted stream.

        ``frames`` maps stream id -> (H, W, 3) RGB frame and must cover
        exactly the admitted streams (the engine advances all per-stream
        clocks together). Returns stream id -> (n_classes,) logits.
        """
        ids = set(self.stream_ids)
        if not ids and not frames:
            return {}                    # idle engine: nothing to serve
        if set(frames) != ids:
            missing, unknown = ids - set(frames), set(frames) - ids
            raise ValueError(
                f"frames must cover exactly the admitted streams; "
                f"missing={sorted(map(str, missing))} "
                f"unknown={sorted(map(str, unknown))}"
            )
        f = self.cfg.frontend
        buf = np.zeros((self.capacity, f.image_h, f.image_w, 3), np.float32)
        for sid, frame in frames.items():
            buf[self.slot_of(sid)] = np.asarray(frame, np.float32)
        logits, self.state = self._step_fn(self.params, jnp.asarray(buf), self.state)
        logits = np.asarray(logits)
        return {sid: logits[self.slot_of(sid)] for sid in frames}

    def recompute_fraction(self, stream_id: Hashable) -> float:
        """Fraction of this stream's k selected patches that were actually
        re-projected/ADC-converted on its last served frame (temporal mode
        only). 1.0 on the bootstrap frame; drops toward 0 on static scenes
        as held charge serves the selection (DESIGN.md §6)."""
        if not self.temporal:
            raise RuntimeError("engine was built without temporal=True")
        slot = self.slot_of(stream_id)
        if int(self.state.frame_age[slot]) == 0:
            raise RuntimeError(
                f"stream {stream_id!r} has not served a frame yet"
            )
        return float(self.state.cache.n_stale[slot]) / self.cfg.frontend.n_active

    def gaze(self, stream_id: Hashable) -> np.ndarray:
        """The (k,) patch indices this stream will ADC-convert next frame.

        Undefined before the stream's first frame — a fresh admit selects
        its first gaze from the in-pixel energy proxy *inside* the next
        step() call, so there is nothing to report yet (raises).
        """
        slot = self.slot_of(stream_id)
        if int(self.state.frame_age[slot]) == 0:
            raise RuntimeError(
                f"stream {stream_id!r} has not served a frame yet; its first "
                f"gaze is the in-step energy bootstrap of the next step()"
            )
        return np.asarray(self.state.indices[slot])
