"""Serving steps: batched prefill and single-token decode with greedy /
temperature sampling. Factories return pure functions for jit."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.layers import ParallelPlan


def make_prefill_step(cfg: ModelConfig, plan: ParallelPlan):
    def prefill_step(params, batch, state):
        return lm.prefill(params, batch, cfg, plan, state)

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: ParallelPlan, temperature: float = 0.0):
    def decode_one(params, state, tokens, pos, rng):
        logits, state = lm.decode_step(params, state, tokens, pos, cfg, plan)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), logits, state

    return decode_one
