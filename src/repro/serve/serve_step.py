"""Serving steps: batched prefill and single-token decode with greedy /
temperature sampling, plus the IP2 closed saccade loop. Factories return
pure functions for jit."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.layers import ParallelPlan


def make_prefill_step(cfg: ModelConfig, plan: ParallelPlan):
    def prefill_step(params, batch, state):
        return lm.prefill(params, batch, cfg, plan, state)

    return prefill_step


def make_decode_step(cfg: ModelConfig, plan: ParallelPlan, temperature: float = 0.0):
    def decode_one(params, state, tokens, pos, rng):
        logits, state = lm.decode_step(params, state, tokens, pos, cfg, plan)
        if temperature > 0.0:
            nxt = jax.random.categorical(rng, logits / temperature, axis=-1)
        else:
            nxt = jnp.argmax(logits, axis=-1)
        return nxt.astype(jnp.int32), logits, state
    return decode_one


# ---------------------------------------------------------------------------
# IP2 saccadic serving (paper §1 "shifted attention"; DESIGN.md §4)
# ---------------------------------------------------------------------------

def make_bootstrap_indices(cfg):
    """First-frame selection, before any backend attention exists: the
    in-pixel patch-energy proxy (cheap analog event detection) picks the
    initial k patches. Returns a jit-ready fn rgb (B,H,W,3) -> (B,k) int32.
    """
    from repro.core import frontend as fe
    from repro.core import saliency as sal

    fcfg = cfg.frontend

    def bootstrap(params, rgb):
        patches, _ = fe.sensor_patches(params["ip2"], rgb, fcfg)
        return sal.topk_patch_indices(sal.patch_energy(patches), fcfg.n_active)

    return bootstrap


def saccade_scores(aux: dict, explore: float) -> jnp.ndarray:
    """Next-frame selection scores (B, P) from one compact forward's aux.

    This is THE saccade policy, shared by :func:`make_saccade_step`, the
    multi-stream engine (``serve/engine.py``), and the dense-path oracle in
    the tests — one scoring function, three consumers (DESIGN.md §5).

    Unobserved patches score the mean observed attention (absence of
    evidence, not zero saliency) — raw attention mass on observed tokens
    would otherwise structurally dominate and freeze the gaze on the
    bootstrap set forever. ``explore`` weights the (per-frame
    max-normalized) in-pixel patch-energy proxy added on top, letting
    bright unobserved events pull the gaze; an infinitesimal energy term is
    kept even at explore=0 so otherwise-tied unobserved candidates rank by
    scene content rather than by top_k's lowest-index tie-break (which
    would drift the gaze toward patch 0). At explore=0 selection changes
    only when a patch out-attends the observed mean, and the freed slot
    goes to the brightest unobserved patch.

    The energy comes from ``aux["energy"]`` — the frontend already computed
    it on this frame's CDS patch voltages, so the policy costs no second
    ``sensor_patches`` pass.
    """
    att = aux["saliency"]                               # (B, P), 0 unobserved
    b = jnp.arange(att.shape[0])[:, None]
    observed = jnp.zeros(att.shape, bool).at[b, aux["indices"]].max(aux["valid"])
    # unobserved patches carry the mean observed attention as a prior:
    # below-average tokens get shed, unseen patches get a fair shot
    n_obs = jnp.maximum(observed.sum(-1, keepdims=True), 1)
    baseline = att.sum(-1, keepdims=True) / n_obs
    scores = jnp.where(observed, att, baseline)
    energy = aux["energy"]
    energy = energy / jnp.maximum(jnp.max(energy, axis=-1, keepdims=True), 1e-9)
    # baseline-scaled; the 1e-3 floor is a content-aware tie-break only
    return scores + max(explore, 1e-3) * baseline * energy


def make_rollout(step_fn):
    """Device-resident saccade rollout (DESIGN.md §15): a ``lax.scan``
    over T engine ticks that never touches the host between frames.

    ``step_fn`` is one batched engine tick — ``(params, frames (S,…),
    fed (S,), state) -> (logits, state)`` from
    :func:`repro.serve.engine.make_engine_step` (possibly already
    shard_map'd over the slot axis; the scan composes either way). The
    rollout scans it over a leading TIME axis: frame payloads
    ``frames_seq (T, S, …)`` and per-tick fed masks ``fed_seq (T, S)``
    are the scanned inputs, the FULL :class:`StreamState` — indices,
    EMA, frame age, temporal :class:`FeatureCache`, ``bcache``, energy
    meters, and governor controls — is the carry, and the per-tick
    logits stack into the (T, S, n_classes) output.

    One dispatch therefore serves T ticks: the per-tick python staging
    loop, H2D upload, dispatch, and D2H fetch that bound the fleet bench
    collapse into a single XLA while-loop. Because the scan body IS the
    engine step (same jaxpr, compiled once as the loop body), a length-T
    rollout is bitwise identical to T sequential ``step_fn`` calls —
    logits and every carried state leaf — in every engine mode
    (asserted across temporal / backend-delta / sign-tier / governed
    configs in tests/test_rollout.py and re-derived live by
    benchmarks/check_rollout_accounting.py).

    Governor semantics (DESIGN.md §15): the control law runs IN-SCAN —
    ``control_update`` is part of ``step_fn``, so per-slot knobs evolve
    tick-by-tick inside the rollout exactly as they would across T
    single-tick calls. Host-side budget re-splits (admit/evict churn,
    ``set_budget_mw``) remain rollout-BOUNDARY events: they ride the
    coalesced churn flush that precedes every dispatch, which is also
    the only place churn can happen (admit/evict are host ops — there
    is no mid-rollout churn by construction).

    Returns ``rollout(params, frames_seq, fed_seq, state) ->
    (logits_seq, state)``, pure and jit-able; T is static per compile
    (one trace per distinct T, cached thereafter).
    """

    def rollout(params, frames_seq, fed_seq, state):
        def body(carry, xs):
            frames, fed = xs
            logits, carry = step_fn(params, frames, fed, carry)
            return carry, logits

        state, logits_seq = jax.lax.scan(body, state, (frames_seq, fed_seq))
        return logits_seq, state

    return rollout


def make_saccade_step(cfg, explore: float = 0.1, project_fn=None,
                      temporal: bool = False, backend: bool = False):
    """Closed-loop serving step on the compact path end to end.

    Frame t: the frontend gathers and projects ONLY the k patches the
    backend attended to on frame t-1; the backend classifies the k compact
    tokens; its attention over those tokens — scattered back onto the patch
    grid — is frame t+1's selection (see :func:`saccade_scores` for the
    policy). Nothing in the loop ever materializes the dense (P, M)
    feature grid, so compute, ADC conversions, and streamed bytes all
    scale with the active fraction.

    Args:
      cfg: ViTConfig (imported lazily to keep serve import-light).
      explore: see :func:`saccade_scores`.
      project_fn: optional kernel-backed projection (e.g.
        ``ops.ip2_project_fn(cfg.frontend.patch, interpret=...)``) applied
        to the gathered active patches. Orthogonally,
        ``cfg.fused_embed=True`` routes the whole frontend->embed seam
        through the fused megakernel (DESIGN.md §11) — bitwise the staged
        trajectory (tests/test_megakernel.py).
      temporal: enable the temporal delta gate (``cfg.frontend.temporal``;
        DESIGN.md §6). The step then takes and returns a
        :class:`repro.core.temporal.FeatureCache` — only the stale subset
        of each frame's selection is re-projected/ADC-converted, the rest
        is served from held charge — multiplying the spatial (k/P)
        savings by the temporal reuse factor on slowly-changing scenes.

    Returns step(params, rgb, indices) -> (logits, next_indices, aux),
    pure and jit-able; ``indices`` for the first frame come from
    :func:`make_bootstrap_indices`. With ``temporal=True`` the signature
    is step(params, rgb, indices, cache) -> (logits, next_indices, aux,
    cache); seed the cache with
    :func:`repro.core.temporal.init_feature_cache`.

    ``backend=True`` additionally threads a
    :class:`repro.models.backend_delta.BackendCache` (DESIGN.md §14): the
    step takes it as its last positional state arg plus an optional
    ``eps`` keyword ((B,) float, default exact) and returns it refreshed
    as its last result — tokens whose served wire row is bitwise
    unchanged reuse their cached backend work; seed with
    :func:`repro.models.backend_delta.init_backend_cache`. For many
    concurrent streams use :class:`repro.serve.engine.SaccadeEngine`,
    which batches this exact step over fixed slots with per-stream state.
    """
    from repro.core import saliency as sal
    from repro.models.vit import vit_forward_compact

    fcfg = cfg.frontend

    def _finish(logits, aux):
        scores = saccade_scores(aux, explore)
        next_indices = sal.topk_patch_indices(scores, fcfg.n_active)
        return logits, next_indices, aux

    def step(params, rgb, indices):
        logits, aux = vit_forward_compact(
            params, rgb, cfg, indices=indices, project_fn=project_fn
        )
        return _finish(logits, aux)

    def step_temporal(params, rgb, indices, cache):
        logits, aux = vit_forward_compact(
            params, rgb, cfg, indices=indices, project_fn=project_fn,
            cache=cache,
        )
        logits, next_indices, aux = _finish(logits, aux)
        return logits, next_indices, aux, aux.pop("cache")

    def step_backend(params, rgb, indices, bcache, eps=None):
        logits, aux = vit_forward_compact(
            params, rgb, cfg, indices=indices, project_fn=project_fn,
            backend_cache=bcache, backend_eps=eps,
        )
        logits, next_indices, aux = _finish(logits, aux)
        return logits, next_indices, aux, aux.pop("backend_cache")

    def step_temporal_backend(params, rgb, indices, cache, bcache, eps=None):
        logits, aux = vit_forward_compact(
            params, rgb, cfg, indices=indices, project_fn=project_fn,
            cache=cache, backend_cache=bcache, backend_eps=eps,
        )
        logits, next_indices, aux = _finish(logits, aux)
        return (logits, next_indices, aux, aux.pop("cache"),
                aux.pop("backend_cache"))

    if backend:
        return step_temporal_backend if temporal else step_backend
    return step_temporal if temporal else step
