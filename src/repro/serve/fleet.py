"""Multi-host fleet coordinator over per-host serving engines (DESIGN.md
§12).

One :class:`~repro.serve.engine.SaccadeEngine` scales to one mesh's
slots; the paper's parallelism claim is thousands of cameras, which means
MANY hosts each running their own engine. This module is the thin,
host-side layer on top:

* **Per-host engines.** Each host owns a :class:`SaccadeEngine` built on
  its own device mesh (:func:`make_fleet_meshes` partitions the visible
  devices into per-host meshes; in production each process sees only its
  local devices and the coordinator runs on the controller). Engines
  never talk to each other — streams are fully independent, so fleet
  scaling is pure horizontal slot capacity and every engine keeps its
  one-compile contract independently.

* **Per-host admit queues with priority classes.** ``submit(sid,
  priority_class=...)`` enqueues a stream on the least-loaded host;
  ``drain()`` (implicit in every ``step``) admits queued streams into
  free slots HIGHEST CLASS FIRST (FIFO within a class), so when churn
  outruns capacity, realtime streams never wait behind background ones.
  The class weight doubles as the stream's governor priority.

* **Budget hierarchy fleet -> host -> slot.** A governed fleet splits the
  fleet-level mW budget over hosts with the SAME proportional law the
  engine uses over slots (:func:`repro.serve.governor.allocate_budgets`,
  ``total_mw=`` override): host weight = the priority mass its admitted
  streams carry, then each engine re-splits its host share over its slots
  (DESIGN.md §10). Rebalancing happens on churn only, is data-only row
  writes end to end, and a slack fleet budget stays a bitwise no-op per
  the PR-5 governor contract (each engine's slack share is itself slack).

* **Async end to end.** ``fleet.step(frames)`` takes any subset of the
  admitted streams (the engines' partial-frame hold semantics, DESIGN.md
  §12), routes each frame to its host, and only dispatches engines that
  have fed slots this tick — an idle host costs nothing. Dispatch is
  NON-BLOCKING per host (DESIGN.md §15): every fed engine is dispatched
  via ``engine.step(..., block=False)`` BEFORE any result is fetched,
  so the per-host device work overlaps instead of serializing behind
  each host's blocking ``np.asarray`` fetch; ``fleet.step(...,
  block=False)`` exposes the same handle contract to the caller.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Hashable, Mapping

import numpy as np

from repro.core.power import EnergyMeter
from repro.serve import governor as gov_mod
from repro.serve.engine import SaccadeEngine

# Default priority classes: weight = share of a governed budget, and the
# admit-queue rank. Matching the paper's deployment story: a few
# latency-critical streams over a sea of best-effort ones.
PRIORITY_CLASSES: dict[str, float] = {
    "realtime": 4.0,
    "interactive": 2.0,
    "standard": 1.0,
    "background": 0.25,
}


def make_fleet_meshes(n_hosts: int, axis: str = "data"):
    """Partition the visible devices into ``n_hosts`` contiguous per-host
    meshes (1-D, named ``axis``) — the test/bench stand-in for one process
    per host, each seeing only its local devices. Returns a list of
    ``n_hosts`` meshes (None entries when a host would get zero devices
    is impossible: n_hosts must divide the device count)."""
    import jax
    from jax.sharding import Mesh

    devs = jax.devices()
    if n_hosts < 1:
        raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
    if len(devs) % n_hosts != 0:
        raise ValueError(
            f"{len(devs)} devices do not split over {n_hosts} hosts")
    per = len(devs) // n_hosts
    return [Mesh(np.asarray(devs[h * per:(h + 1) * per]), (axis,))
            for h in range(n_hosts)]


class FleetHandle:
    """Merged non-blocking fleet result (DESIGN.md §15): wraps the fed
    hosts' :class:`~repro.serve.engine.StepHandle`\\ s (one tick) or
    :class:`~repro.serve.engine.RolloutHandle`\\ s (a rollout) and
    merges them at fetch time. ``result()`` blocks host by host — by
    then every host's work was already dispatched, so the waits
    overlap. Idempotent, same lifetime contract as the per-engine
    handles."""

    __slots__ = ("_handles", "_n_ticks", "_out")

    def __init__(self, handles: list, n_ticks: int | None = None):
        self._handles = handles
        self._n_ticks = n_ticks          # None: single tick -> one dict
        self._out = None

    def result(self):
        if self._out is None:
            if self._n_ticks is None:
                out: Any = {}
                for h in self._handles:
                    out.update(h.result())
            else:
                out = [{} for _ in range(self._n_ticks)]
                for h in self._handles:
                    for t, d in enumerate(h.result()):
                        out[t].update(d)
            self._out = out
            self._handles = []
        return self._out


@dataclasses.dataclass
class _Queued:
    """One waiting admit request."""
    stream_id: Hashable
    weight: float
    seq: int            # FIFO tiebreak within a class


class SaccadeFleet:
    """Fleet of per-host :class:`SaccadeEngine`\\ s behind one API.

    Args:
      cfg / params: as for the engine (params are shared — replicated per
        host mesh by the engines themselves).
      n_hosts: number of per-host engines.
      capacity: slots PER HOST (fleet capacity = n_hosts * capacity).
      meshes: optional list of n_hosts meshes (``make_fleet_meshes``);
        None runs every engine unsharded on the default device.
      governor: a fleet-level :class:`GovernorSpec`; its ``budget_mw`` is
        the FLEET budget, split over hosts by admitted priority mass and
        re-split over slots inside each engine.
      priority_classes: name -> weight map (default
        :data:`PRIORITY_CLASSES`).
      engine_kw: forwarded to every engine (temporal, meter, frame_hz,
        explore, ...).
    """

    def __init__(self, cfg, params, *, n_hosts: int = 1, capacity: int = 8,
                 meshes=None, governor: "gov_mod.GovernorSpec | None" = None,
                 priority_classes: Mapping[str, float] | None = None,
                 **engine_kw):
        if n_hosts < 1:
            raise ValueError(f"n_hosts must be >= 1, got {n_hosts}")
        if meshes is not None and len(meshes) != n_hosts:
            raise ValueError(
                f"got {len(meshes)} meshes for {n_hosts} hosts")
        self.governor = governor
        self.classes = dict(priority_classes or PRIORITY_CLASSES)
        if any(w <= 0 for w in self.classes.values()):
            raise ValueError(f"class weights must be > 0: {self.classes}")
        self.engines: list[SaccadeEngine] = [
            SaccadeEngine(cfg, params, capacity=capacity,
                          mesh=None if meshes is None else meshes[h],
                          governor=governor, **engine_kw)
            for h in range(n_hosts)
        ]
        self._queues: list[list[_Queued]] = [[] for _ in range(n_hosts)]
        self._host_of: dict[Hashable, int] = {}
        self._queued_ids: set[Hashable] = set()
        self._seq = 0

    # ---- fleet shape ---------------------------------------------------
    @property
    def n_hosts(self) -> int:
        return len(self.engines)

    @property
    def capacity(self) -> int:
        return sum(e.capacity for e in self.engines)

    @property
    def stream_ids(self) -> list[Hashable]:
        return [sid for e in self.engines for sid in e.stream_ids]

    @property
    def free_slots(self) -> int:
        return sum(e.free_slots for e in self.engines)

    @property
    def queued(self) -> int:
        return sum(len(q) for q in self._queues)

    @property
    def n_traces(self) -> list[int]:
        """Per-engine compile counts — the fleet contract is all-ones."""
        return [e.n_traces for e in self.engines]

    def host_of(self, stream_id: Hashable) -> int:
        try:
            return self._host_of[stream_id]
        except KeyError:
            raise KeyError(f"stream {stream_id!r} not admitted") from None

    # ---- admission -----------------------------------------------------
    def submit(self, stream_id: Hashable,
               priority_class: str = "standard") -> int:
        """Enqueue a stream on the least-loaded host's admit queue; it is
        admitted (highest class first) by the next ``drain``/``step``.
        Returns the chosen host index."""
        if stream_id in self._host_of or stream_id in self._queued_ids:
            raise ValueError(f"stream {stream_id!r} already submitted")
        if priority_class not in self.classes:
            raise ValueError(
                f"unknown priority class {priority_class!r}; "
                f"have {sorted(self.classes)}")
        # least-loaded: most free slots after the already-queued admits
        host = max(
            range(self.n_hosts),
            key=lambda h: self.engines[h].free_slots - len(self._queues[h]),
        )
        self._queues[host].append(
            _Queued(stream_id, self.classes[priority_class], self._seq))
        self._queued_ids.add(stream_id)
        self._seq += 1
        return host

    def drain(self) -> list[Hashable]:
        """Admit queued streams into free slots, highest priority class
        first (FIFO within a class); leftover requests stay queued.
        Rebalances the fleet budget when anything changed. Returns the
        stream ids admitted this call."""
        admitted = []
        for host, q in enumerate(self._queues):
            eng = self.engines[host]
            q.sort(key=lambda r: (-r.weight, r.seq))
            while q and eng.free_slots > 0:
                r = q.pop(0)
                eng.admit(r.stream_id, priority=r.weight)
                self._host_of[r.stream_id] = host
                self._queued_ids.discard(r.stream_id)
                admitted.append(r.stream_id)
        if admitted:
            self._rebalance_budgets()
        return admitted

    def evict(self, stream_id: Hashable) -> None:
        """Evict an admitted stream (or cancel a queued one)."""
        if stream_id in self._queued_ids:
            for q in self._queues:
                q[:] = [r for r in q if r.stream_id != stream_id]
            self._queued_ids.discard(stream_id)
            return
        host = self.host_of(stream_id)
        self.engines[host].evict(stream_id)
        del self._host_of[stream_id]
        self._rebalance_budgets()

    def _rebalance_budgets(self) -> None:
        """fleet -> host: same proportional law as host -> slot (DESIGN.md
        §10/§12), reusing ``allocate_budgets`` with the fleet budget as
        the pool and each host's admitted priority mass as its weight."""
        if self.governor is None:
            return
        w = np.zeros((self.n_hosts,), np.float64)
        for h, eng in enumerate(self.engines):
            w[h] = sum(eng._priority[sid] for sid in eng.stream_ids)
        shares = gov_mod.allocate_budgets(
            self.governor, w, total_mw=self.governor.budget_mw)
        for eng, share in zip(self.engines, shares):
            if share > 0:
                eng.set_budget_mw(float(share))

    # ---- serving -------------------------------------------------------
    def step(self, frames: Mapping[Hashable, Any], block: bool = True
             ) -> "dict[Hashable, np.ndarray] | FleetHandle":
        """Drain the admit queues, then serve one async tick: route each
        frame to its stream's host engine and step only the engines with
        fed slots (everyone else's streams hold).

        Dispatch is non-blocking per host (DESIGN.md §15): every fed
        engine is dispatched before ANY result is fetched, so per-host
        device work overlaps. With ``block=True`` (default) the merged
        stream id -> logits dict for exactly the fed streams is
        returned; ``block=False`` returns a :class:`FleetHandle` whose
        ``result()`` fetches (and merges) later — the dispatch/fetch
        split the fleet bench meters separately."""
        self.drain()
        per_host: list[dict] = [{} for _ in range(self.n_hosts)]
        for sid, frame in frames.items():
            per_host[self.host_of(sid)][sid] = frame
        # dispatch ALL fed hosts first — no fetch until every engine's
        # step is in flight (the whole point of the async path)
        handles = [eng.step(fh, block=False)
                   for eng, fh in zip(self.engines, per_host) if fh]
        handle = FleetHandle(handles)
        return handle.result() if block else handle

    def step_rollout(self, frames_by_tick, block: bool = True):
        """Serve T ticks per host in ONE dispatch per host (DESIGN.md
        §15): each tick's frames route to their host engines, every fed
        engine gets the full T-tick schedule as one
        :meth:`SaccadeEngine.step_rollout` dispatch (un-fed ticks hold
        in-scan), and — like :meth:`step` — every host is dispatched
        before any is fetched. Churn drains once, at the rollout
        boundary. Returns a list of T merged per-tick dicts (or a
        :class:`FleetHandle` over them with ``block=False``)."""
        self.drain()
        ticks = list(frames_by_tick)
        per_host: list[list[dict]] = [
            [{} for _ in ticks] for _ in range(self.n_hosts)]
        for t, fr in enumerate(ticks):
            for sid, frame in fr.items():
                per_host[self.host_of(sid)][t][sid] = frame
        handles = [eng.step_rollout(sched, block=False)
                   for eng, sched in zip(self.engines, per_host)
                   if any(sched)]
        handle = FleetHandle(handles, n_ticks=len(ticks))
        return handle.result() if block else handle

    # ---- metering (DESIGN.md §10) --------------------------------------
    def fleet_power_mw(self, window: str = "last") -> float:
        """Measured frontend power summed over every host's admitted
        streams — the fleet-budget tracking quantity."""
        return sum(e.fleet_power_mw(window) for e in self.engines)

    def power_mw(self, stream_id: Hashable, window: str = "last") -> float:
        return self.engines[self.host_of(stream_id)].power_mw(
            stream_id, window)

    def events(self, stream_id: Hashable, window: str = "last"):
        return self.engines[self.host_of(stream_id)].events(
            stream_id, window)
