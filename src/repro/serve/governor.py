"""Closed-loop power governor for the saccadic serving engine (DESIGN.md
§10).

The paper's <30 mW/MP figure assumes 25 % of the patches convert every
frame — an *open-loop* assumption. Real scenes don't cooperate: a
full-motion stream demands k conversions per frame, a static one almost
none. This module closes the loop: given a chip power budget in mW, it
steers each stream's per-frame recompute allocation (and, under severe
budgets, its active-token tier) so the *measured* frontend power — priced
from the events the runtime actually executed (`core/power.py`) — tracks
the budget across motion regimes.

Everything is STATIC-SHAPE: the two knobs are data, not shapes —

* ``j_cap`` truncates the temporal gate's needed set to its first
  ``j_cap`` ranked slots (`temporal.select_stale(cap=...)`); slots past
  the cap behave exactly like budget-deferred overflow and age toward a
  future slot (starvation-free by the gate's own ranking).
* ``k_eff`` (a tier from ``GovernorSpec.k_tiers``) sheds the
  lowest-scoring selection slots via the valid mask
  (`apply_frontend(k_cap=...)`): shed tokens are not served, not
  converted, and their patches dump like deselected ones.

— so a governed engine compiles exactly once, same as an ungoverned one,
and a slack budget is a bitwise no-op (asserted in tests/test_governor.py).

**Control law** (`control_update`, runs inside the jitted engine step,
once per slot — per-slot only, no cross-slot collectives, so the slot
axis still shards cleanly):

1. *Feedforward target.* The meter makes the plant model trivial:
   measured power is ``fixed(k_eff) + n_stale · slot_mw`` where
   ``slot_mw`` is the marginal power of one recompute slot
   (`EnergyMeter.slot_recompute_power_w`) and ``fixed`` prices the
   per-frame events that gating cannot avoid (CDS, DAC broadcast,
   dumps). The affordable allocation is therefore
   ``floor((budget_i - fixed) / slot_mw)``, clipped to
   ``[floor, j_max]`` — the starvation floor beats the power budget:
   a stream is degraded, never stalled.
2. *Hysteresis.* The cap moves toward the target by at most ``slew``
   slots per frame, and holds whenever measured power sits inside the
   ``±deadband`` band around the budget with the cap already at or
   below target — demand flicker around a tier boundary cannot make
   the knobs oscillate.
3. *k tier.* Served-token staleness is bounded by requiring every
   served token a refresh slot within ``refresh_horizon`` frames:
   the tier target is the largest tier with
   ``tier_k <= j_cap · refresh_horizon``; tiers move one step per
   frame, and tiering UP (more tokens) additionally requires the
   stricter ``(1 - deadband)`` margin so a boundary demand cannot
   flip the tier every frame.

Per-stream budget shares are allocated HOST-side
(:func:`allocate_budgets`, priority-weighted over the admitted streams)
and written into the controls as data on admit/evict — fleet-level
tracking is then the sum of per-slot tracking, with no collective inside
the step.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import numpy as np

import jax.numpy as jnp

from repro.core.power import EnergyMeter, EventCounts, frontend_frame_events


@dataclasses.dataclass(frozen=True)
class GovernorSpec:
    """Static configuration of the power governor.

    budget_mw: chip budget for the fleet's imager frontends, split over
      admitted streams by :func:`allocate_budgets`.
    floor: starvation-free minimum recompute slots per stream per frame
      (a governed stream is degraded, never stalled — droop refresh and
      novelty always make progress).
    deadband: hysteresis band as a fraction of the per-stream budget;
      inside it the cap holds.
    slew: max recompute-cap slots moved per frame (rate limit).
    k_tiers: active-token tiers as fractions of k, best first. Tier 0
      must be 1.0 (the ungoverned token count — slack budgets are a
      bitwise no-op).
    refresh_horizon: bound on served-token staleness — the tier target
      keeps ``k_eff <= j_cap · refresh_horizon`` so every served token
      wins a refresh slot within that many frames.
    sign_tier: enables one extra degradation tier BELOW the whole k
      ladder (DESIGN.md §13): tier index ``len(k_tiers)`` keeps the
      finest tier's token count but swaps the edge ADC for the ADC-less
      sign readout — near-zero conversion energy, 1-bit features. A slot
      degrades into it only when the budget cannot cover even the finest
      k tier's floor allocation, and recovers out of it with the stricter
      ``(1 - deadband)`` margin. Like every other knob it is DATA: the
      per-slot tier index selects it, shapes never change, and the
      engine applies the sign degradation to the already-converted code
      wire (`adc.sign_code_points`) — zero recompiles.
    backend_eps: the delta-gated backend's engaged epsilon (DESIGN.md
      §14) — one more per-slot DATA knob, on the SYSTEM power loop
      rather than the frontend one: when a slot's budget cannot cover
      the dense backend on top of the finest frontend floor, its
      ``controls.eps`` engages to this value so held tokens stop
      re-propagating sub-eps drift (droop, flicker) through the encoder;
      it recovers to 0.0 (the exact, bitwise regime) with the stricter
      ``(1 - deadband)`` margin. 0.0 disables the knob. Requires a
      backend-delta engine (the knob gates against its BackendCache).
    """

    budget_mw: float
    floor: int = 1
    deadband: float = 0.05
    slew: int = 2
    k_tiers: tuple[float, ...] = (1.0, 0.75, 0.5, 0.25)
    refresh_horizon: int = 8
    sign_tier: bool = False
    backend_eps: float = 0.0

    def __post_init__(self):
        if self.budget_mw <= 0:
            raise ValueError(f"budget_mw must be > 0, got {self.budget_mw}")
        if self.backend_eps < 0:
            raise ValueError(
                f"backend_eps must be >= 0 (0 disables the backend knob), "
                f"got {self.backend_eps}")
        if self.floor < 1:
            raise ValueError(f"floor must be >= 1, got {self.floor}")
        if self.k_tiers[0] != 1.0:
            raise ValueError(
                f"k_tiers[0] must be 1.0 (the ungoverned tier), got "
                f"{self.k_tiers}"
            )
        if list(self.k_tiers) != sorted(self.k_tiers, reverse=True):
            raise ValueError(f"k_tiers must be descending, got {self.k_tiers}")

    def tier_tokens(self, k: int) -> tuple[int, ...]:
        """The k_eff value of each tier for a k-token selection."""
        return tuple(max(1, int(round(t * k))) for t in self.k_tiers)


class GovernorControls(NamedTuple):
    """Per-slot governor state; slot-major, shards/donates with the rest
    of ``StreamState`` (DESIGN.md §10). All DATA — no field ever changes
    a compiled shape."""

    j_cap: jnp.ndarray      # (S,) int32 — recompute slots allowed per frame
    tier: jnp.ndarray       # (S,) int32 — index into GovernorSpec.k_tiers
    budget_mw: jnp.ndarray  # (S,) float32 — host-allocated budget share
    eps: jnp.ndarray        # (S,) float32 — backend delta-gate epsilon
                            # (0.0 = exact regime, DESIGN.md §14)


def init_controls(capacity: int, j_max: int) -> GovernorControls:
    """Fresh slots start ungoverned (cap = j_max, tier 0, exact backend)
    and unbudgeted; the host writes budget shares on admit
    (:func:`allocate_budgets`)."""
    return GovernorControls(
        j_cap=jnp.full((capacity,), j_max, jnp.int32),
        tier=jnp.zeros((capacity,), jnp.int32),
        budget_mw=jnp.zeros((capacity,), jnp.float32),
        eps=jnp.zeros((capacity,), jnp.float32),
    )


def reset_rows(controls: GovernorControls, hit: jnp.ndarray,
               j_max: int) -> GovernorControls:
    """Admit-time row reset (``hit`` (S,) bool): back to the ungoverned
    defaults; the budget share is rewritten by the host right after."""
    return GovernorControls(
        j_cap=jnp.where(hit, j_max, controls.j_cap),
        tier=jnp.where(hit, 0, controls.tier),
        budget_mw=jnp.where(hit, 0.0, controls.budget_mw),
        eps=jnp.where(hit, 0.0, controls.eps),
    )


def tier_k_eff(spec: GovernorSpec, tier: jnp.ndarray, k: int) -> jnp.ndarray:
    """(S,) tier indices -> (S,) k_eff token counts.

    With ``sign_tier`` enabled the tier index range grows by one; the
    sign tier keeps the finest k tier's token count (it degrades the
    readout, not the selection), so indices clamp to the last k entry."""
    tokens = jnp.asarray(spec.tier_tokens(k), jnp.int32)
    return jnp.take(tokens, jnp.minimum(tier, len(spec.k_tiers) - 1))


def tier_is_sign(spec: GovernorSpec, tier: jnp.ndarray) -> jnp.ndarray:
    """(S,) bool — slots currently degraded to the ADC-less sign readout
    (tier index past the whole k ladder). Always False when the spec has
    no sign tier."""
    if not spec.sign_tier:
        return jnp.zeros_like(tier, dtype=bool)
    return tier >= len(spec.k_tiers)


def fixed_power_mw(
    spec_meter: EnergyMeter,
    n_pixels: float,
    pixels_per_patch: int,
    n_vectors: int,
    k_eff: jnp.ndarray,
    frame_hz: float,
) -> jnp.ndarray:
    """Per-frame power that gating cannot avoid at the current token
    tier: CDS samples, the DAC weight broadcast, and the deselected-patch
    dumps (which grow as the tier sheds tokens). Derived from the SAME
    event arithmetic the runtime meters against (zero converted patches),
    so the plant model can never drift from the measurement."""
    ev = frontend_frame_events(
        n_pixels, pixels_per_patch, n_vectors,
        n_selected_patches=k_eff.astype(jnp.float32),
        n_converted_patches=jnp.zeros_like(k_eff, jnp.float32),
    )
    return spec_meter.power_mw(ev, frame_hz)


def control_update(
    spec: GovernorSpec,
    controls: GovernorControls,
    events_last: EventCounts,
    active: jnp.ndarray,
    meter: EnergyMeter,
    frame_hz: float,
    n_pixels: float,
    pixels_per_patch: int,
    n_vectors: int,
    j_max: int,
    k: int,
    backend_mw: float = 0.0,
) -> GovernorControls:
    """One governor tick — pure, per-slot, jit-inside-the-engine-step.

    ``events_last`` are THIS frame's executed events (inactive slots
    zeroed); the new controls apply from the NEXT frame (one frame of
    control latency, like any sampled controller).

    ``backend_mw`` is the DENSE backend's per-slot power estimate
    (``dense_backend_macs`` priced by the meter — the feedforward plant
    model for the §14 epsilon knob); 0.0 when the engine serves the
    dense backend (no BackendCache to gate against).
    """
    slot_mw = 1e3 * meter.slot_recompute_power_w(
        pixels_per_patch, n_vectors, frame_hz
    )
    measured = meter.power_mw(events_last, frame_hz)              # (S,)
    budget = controls.budget_mw

    # 1. feedforward affordable allocation at the current tier
    k_eff_now = tier_k_eff(spec, controls.tier, k)
    fixed = fixed_power_mw(
        meter, n_pixels, pixels_per_patch, n_vectors, k_eff_now, frame_hz
    )
    afford = jnp.floor((budget - fixed) / slot_mw).astype(jnp.int32)
    target = jnp.clip(afford, spec.floor, j_max)

    # 2. slew-limited move with a deadband hold (hysteresis): inside the
    # band and not above target -> hold; above target always bleeds down
    err = measured - budget
    hold = (jnp.abs(err) <= spec.deadband * budget) & (controls.j_cap <= target)
    step = jnp.clip(target - controls.j_cap, -spec.slew, spec.slew)
    j_new = jnp.clip(
        jnp.where(hold, controls.j_cap, controls.j_cap + step),
        spec.floor, j_max,
    )

    # 3. token tier: largest tier whose k_eff is refreshable within the
    # horizon at the new cap; one tier step per frame; tiering up needs
    # the stricter (1 - deadband) margin (tier hysteresis)
    tiers = jnp.asarray(spec.tier_tokens(k), jnp.int32)           # (T,)
    room = (j_new * spec.refresh_horizon)[:, None]                # (S, 1)
    fits = tiers[None, :] <= room                                 # (S, T)
    fits = fits.at[:, -1].set(True)       # last tier is always available
    t_target = jnp.argmax(fits, axis=-1).astype(jnp.int32)        # first fit
    fits_up = tiers[None, :] <= (
        room.astype(jnp.float32) * (1.0 - spec.deadband)
    )
    fits_up = fits_up.at[:, -1].set(True)
    t_up = jnp.argmax(fits_up, axis=-1).astype(jnp.int32)

    # 3b. ADC-less sign tier (DESIGN.md §13): one more rung below the
    # whole k ladder. A slot falls into it only when the budget cannot
    # cover even the finest k tier's floor allocation (fixed power at
    # the minimum token count plus `floor` recompute slots), and climbs
    # back out only with the stricter (1 - deadband) margin — the same
    # hysteresis shape as the k ladder, so a boundary budget cannot
    # flip the readout every frame.
    if spec.sign_tier:
        n_kt = jnp.int32(len(spec.k_tiers))
        k_min = jnp.full_like(j_new, int(spec.tier_tokens(k)[-1]))
        fixed_min = fixed_power_mw(
            meter, n_pixels, pixels_per_patch, n_vectors, k_min, frame_hz
        )
        floor_mw = fixed_min + spec.floor * slot_mw
        want_sign = budget < floor_mw
        recover_ok = budget * (1.0 - spec.deadband) >= floor_mw
        t_target = jnp.where(want_sign, n_kt, t_target)
        t_up = jnp.where(recover_ok, t_up, n_kt)

    t_cur = controls.tier
    t_new = jnp.where(
        t_target > t_cur, t_cur + 1,                              # degrade
        jnp.where(t_up < t_cur, t_cur - 1, t_cur),                # recover
    )

    # 3c. backend epsilon (DESIGN.md §14): the knob on the SYSTEM power
    # loop. The budget must fund the frontend's floor PLUS the dense
    # backend; when it cannot, the slot's delta gate engages
    # spec.backend_eps so held tokens stop re-propagating sub-eps drift,
    # and it recovers to the exact regime (eps 0) only with the stricter
    # (1 - deadband) margin — the sign-tier hysteresis shape.
    eps_new = controls.eps
    if spec.backend_eps > 0.0:
        floor_sys = fixed + spec.floor * slot_mw + backend_mw
        want_eps = budget < floor_sys
        recover_eps = budget * (1.0 - spec.deadband) >= floor_sys
        eps_new = jnp.where(
            want_eps, jnp.float32(spec.backend_eps),
            jnp.where(recover_eps, 0.0, controls.eps),
        )

    frozen = ~active
    return GovernorControls(
        j_cap=jnp.where(frozen, controls.j_cap, j_new),
        tier=jnp.where(frozen, controls.tier, t_new),
        budget_mw=budget,
        eps=jnp.where(frozen, controls.eps, eps_new),
    )


def allocate_budgets(
    spec: GovernorSpec,
    slot_priority: np.ndarray,
    total_mw: float | None = None,
) -> np.ndarray:
    """HOST-side budget split: ``slot_priority`` is (S,) with the priority
    weight of each admitted stream and 0.0 on free slots; the budget is
    divided proportionally over the admitted streams. Returns (S,)
    float32 per-slot budget shares (0 on free slots). Called on
    admit/evict — a data-only row rewrite, never a recompile.

    ``total_mw`` overrides ``spec.budget_mw`` as the pool being split —
    the SAME proportional law then stacks into the fleet hierarchy
    (DESIGN.md §12): the fleet coordinator splits the fleet budget over
    hosts (weights = each host's admitted priority mass), and each
    engine splits its host share over slots."""
    w = np.asarray(slot_priority, np.float64)
    total = w.sum()
    if total <= 0:
        return np.zeros_like(w, dtype=np.float32)
    pool = spec.budget_mw if total_mw is None else float(total_mw)
    return (pool * w / total).astype(np.float32)
