"""Render EXPERIMENTS.md tables from results/dryrun.json.

    PYTHONPATH=src python -m repro.roofline.report results/dryrun.json
"""

from __future__ import annotations

import json
import sys


def fmt_table(results: dict, mesh: str = "single") -> str:
    rows = []
    hdr = ("| cell | mb | peak/dev GiB | fits | t_compute s | t_memory s | "
           "t_collective s | bottleneck | MODEL/HLO flops | t_mem floor s |")
    sep = "|" + "---|" * 10
    rows.append(hdr)
    rows.append(sep)
    for k in sorted(results):
        if not k.endswith("/" + mesh):
            continue
        v = results[k]
        if "error" in v:
            rows.append(f"| {k[: -len(mesh) - 1]} | ERROR | | | | | | | | |")
            continue
        m = v["memory"]
        rl = v.get("roofline", {})
        rows.append(
            f"| {k[: -len(mesh) - 1]} | {v.get('microbatches', '-')} "
            f"| {m['approx_peak_per_device'] / 2**30:.2f} "
            f"| {'Y' if m['fits_hbm_16g'] else 'N'} "
            f"| {rl.get('t_compute_s', float('nan')):.4f} "
            f"| {rl.get('t_memory_s', float('nan')):.3f} "
            f"| {rl.get('t_collective_s', float('nan')):.4f} "
            f"| {rl.get('bottleneck', '-')} "
            f"| {rl.get('useful_flops_ratio', float('nan')):.3f} "
            f"| {v.get('t_memory_floor_s', float('nan')):.4f} |"
        )
    return "\n".join(rows)


def fmt_dryrun_table(results: dict) -> str:
    rows = ["| cell | mesh | compile s | peak/dev GiB | fits 16GiB | collectives (counts) |",
            "|---|---|---|---|---|---|"]
    for k in sorted(results):
        v = results[k]
        if "error" in v:
            rows.append(f"| {k} | ERROR | | | | |")
            continue
        m = v["memory"]
        coll = ", ".join(f"{kk}:{vv}" for kk, vv in sorted(v["full_collectives"].items()))
        arch_shape, mesh = k.rsplit("/", 1)
        rows.append(
            f"| {arch_shape} | {mesh} | {v['compile_s']} "
            f"| {m['approx_peak_per_device'] / 2**30:.2f} "
            f"| {'Y' if m['fits_hbm_16g'] else 'N'} | {coll} |"
        )
    return "\n".join(rows)


def roofline_fraction(cell: dict, use_floor: bool = False) -> float | None:
    """MODEL_FLOPS time / binding-term time — the fraction of the chip's
    peak the step's *useful* math achieves if the step runs exactly at its
    roofline bound. use_floor swaps the fusion-blind XLA byte count for
    the fusion-aware argument-traffic floor."""
    from repro.launch.mesh import PEAK_FLOPS_BF16

    rl = cell.get("roofline")
    if not rl:
        return None
    t_model = rl["model_flops_per_chip"] / PEAK_FLOPS_BF16
    t_mem = cell.get("t_memory_floor_s", 0.0) if use_floor else rl["t_memory_s"]
    t_bound = max(rl["t_compute_s"], t_mem, rl["t_collective_s"])
    return t_model / t_bound if t_bound else None


def fmt_fraction_table(base: dict, opt: dict) -> str:
    rows = ["| cell | frac (XLA-bytes) base→opt | frac (traffic-floor) base→opt |",
            "|---|---|---|"]
    for k in sorted(opt):
        if not k.endswith("/single"):
            continue
        fb = roofline_fraction(base.get(k, {}))
        fo = roofline_fraction(opt[k])
        gb = roofline_fraction(base.get(k, {}), use_floor=True)
        go = roofline_fraction(opt[k], use_floor=True)
        if fo is None:
            continue
        rows.append(
            f"| {k[:-7]} | {fb or 0:.4f} → {fo:.4f} | {gb or 0:.3f} → {go or 0:.3f} |"
        )
    return "\n".join(rows)


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun.json"
    with open(path) as f:
        results = json.load(f)
    print("## Roofline (single-pod 16x16)\n")
    print(fmt_table(results, "single"))
    print("\n## Dry-run gate (both meshes)\n")
    print(fmt_dryrun_table(results))
    if len(sys.argv) > 2:
        with open(sys.argv[2]) as f:
            opt = json.load(f)
        print("\n## Roofline fractions (baseline -> optimized)\n")
        print(fmt_fraction_table(results, opt))


if __name__ == "__main__":
    main()
