"""Roofline extraction from compiled dry-run artifacts.

Three terms per (arch, shape, mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_chip / peak_FLOPs
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = collective_bytes_per_chip / ICI_bw

Sources: ``compiled.cost_analysis()`` (per-partition flops / bytes
accessed) and the partitioned HLO text for collective operand bytes.

XLA's cost analysis counts a ``while`` (lax.scan) body ONCE regardless of
trip count, so per-layer costs of scanned stacks are recovered by
two-point extrapolation: lower the model UNROLLED at 1x and 2x the block
pattern, take the difference as the per-repeat cost, and extrapolate to
the full depth. This is exact for homogeneous stacks (the difference
cancels embed/head/optimizer overheads) and is validated against the
analytic MODEL_FLOPS = 6·N·D in the tests.
"""

from __future__ import annotations

import dataclasses
import re

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9_\[\]\{\},\. ]+?)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(", re.I,
)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _line_result_bytes(line: str) -> int:
    """Bytes of the result shape(s) on an HLO op line ('%x = TYPE op(...').

    Results may be tuple-shaped — '%x = (f32[8,128], u32[]) all-reduce-start(…'
    — where everything before the first '(' is empty; the result shapes
    then live inside the leading parenthesized group, which must be kept
    (only the operand list after the op name is excluded)."""
    lhs = line.split("=", 1)[1] if "=" in line else line
    lhs = lhs.lstrip()
    if lhs.startswith("("):
        # tuple result: scan up to its closing paren, not the first '('
        close = lhs.find(")")
        head = lhs[: close + 1] if close != -1 else lhs
    else:
        head = lhs.split("(", 1)[0]
    total = 0
    for dt, dims in _SHAPE_RE.findall(head):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device bytes moved by each collective kind (result-shape sized)."""
    out: dict[str, int] = {}
    count: dict[str, int] = {}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group(1).lower()
        if "-done" in line.split("(")[0]:
            continue  # avoid double count of start/done pairs
        b = _line_result_bytes(line)
        out[kind] = out.get(kind, 0) + b
        count[kind] = count.get(kind, 0) + 1
    out["total"] = sum(v for k, v in out.items() if k != "total")
    out["counts"] = count
    return out


@dataclasses.dataclass
class RooflineTerms:
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS_BF16

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / ICI_BW

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def mxu_occupancy(self) -> float:
        """Fraction of the bound time the MXU is doing useful math:
        t_compute / t_bound — 1.0 when compute-bound, < 1 when memory or
        collective traffic stalls the systolic array. The block-shape
        sweep (benchmarks/bench_roofline.py) maximizes this."""
        t = self.t_bound
        return self.t_compute / t if t > 0 else 0.0

    def as_dict(self) -> dict:
        return {
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "mxu_occupancy": self.mxu_occupancy,
        }


def extrapolate(point1: dict, point2: dict, n_rep1: int, n_rep2: int,
                n_rep_full: int) -> RooflineTerms:
    """Two-point linear extrapolation of per-chip costs to full depth."""
    def extr(key):
        v1, v2 = point1[key], point2[key]
        slope = (v2 - v1) / max(n_rep2 - n_rep1, 1)
        return v1 + slope * (n_rep_full - n_rep1)

    return RooflineTerms(
        flops_per_chip=extr("flops"),
        bytes_per_chip=extr("bytes"),
        coll_bytes_per_chip=extr("coll_bytes"),
    )


def cost_point(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, list):
        ca = ca[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total"]),
        "coll_detail": {k: v for k, v in coll.items() if k not in ("total",)},
    }


def megakernel_cost(
    row_counts,
    k: int,
    n2: int,
    m: int,
    d: int | None = None,
    block_r: int = 8,
    block_m: int = 128,
    block_k: int = 256,
    out_bytes: int = 1,
) -> dict:
    """Analytic (flops, bytes) model of the ragged frontend megakernel
    (DESIGN.md §11) at given per-slot ``row_counts``.

    XLA's static cost analysis prices every grid step, so runtime
    raggedness — banks whose MXU work is skipped by ``pl.when`` and whose
    DMAs the pipeliner elides on unchanged block indices — is invisible to
    :func:`cost_point`. This model prices what the kernel ACTUALLY does: a
    row bank of ``block_r`` slots only computes/streams when its first row
    position is below its slot's count, so FLOPs and bytes scale with
    ``sum(ceil(count/block_r))`` active banks, not with slots·k. Output
    writes cover every bank (inactive banks write zeros — the defined
    shed-row payload). ``d`` prices the fused embed stage (codes @ W8)
    on top; ``d=None`` is the ragged projection alone with ``out_bytes``
    per emitted element (1 for the int8 code wire). Same keys as
    :func:`cost_point` so :class:`RooflineTerms` consumes either.
    """
    k_pad = -(-n2 // block_k) * block_k
    m_pad = -(-m // block_m) * block_m
    n_banks = -(-k // block_r)
    counts = [max(0, min(int(c), k)) for c in row_counts]
    active_banks = sum(-(-c // block_r) for c in counts)
    total_banks = len(counts) * n_banks

    flops = active_banks * 2.0 * block_r * k_pad * m_pad
    bytes_ = active_banks * block_r * k_pad * 4.0       # gathered patch rows
    bytes_ += active_banks * k_pad * m_pad * 4.0        # weight stream/bank
    if d is None:
        bytes_ += total_banks * block_r * m_pad * float(out_bytes)
    else:
        d_pad = -(-d // 128) * 128
        flops += active_banks * 2.0 * block_r * m_pad * d_pad
        bytes_ += m_pad * d_pad * 1.0 + d_pad * 4.0     # embed w8 + scales
        bytes_ += total_banks * block_r * d_pad * 4.0   # f32 embed output
    return {
        "flops": flops,
        "bytes": bytes_,
        "coll_bytes": 0.0,
        "detail": {"active_banks": active_banks, "total_banks": total_banks},
    }


def delta_attention_cost(
    j: int,
    k: int,
    d_model: int,
    n_heads: int,
    block_q: int = 8,
    lane: int = 128,
) -> dict:
    """Analytic (flops, bytes) model of the ragged stale-Q attention
    kernel (DESIGN.md §14) for ONE (slot, layer): ``j`` stale query rows
    against ``k`` cached keys.

    Mirrors :func:`megakernel_cost`'s reasoning: ``pl.when`` + clamped
    index_maps mean only ``ceil(j/block_q)`` query banks compute and
    stream, each paying the FULL key/value block (attention is all-to-
    all on the key side — that is the kernel's irreducible term), so
    cost scales with the stale prefix, not with k². Head dim is
    lane-padded exactly as the kernel pads it. ``time_s`` is the
    roofline bound (max of compute/memory), the quantity
    :func:`repro.kernels.vit_delta_attention.pick_block_q` minimizes.
    """
    dh = max(d_model // n_heads, 1)
    dh_p = -(-dh // lane) * lane
    k_pad = -(-k // block_q) * block_q
    active = -(-max(min(j, k), 0) // block_q)
    total = -(-k // block_q)

    # per active bank, per head: scores (bq x k_pad x dh_p) + mix back
    flops = active * n_heads * 2.0 * (2.0 * block_q * k_pad * dh_p)
    bytes_ = active * n_heads * block_q * dh_p * 4.0          # Q banks
    bytes_ += (n_heads * 2.0 * k_pad * dh_p * 4.0             # K + V
               * (1.0 if active > 0 else 0.0))
    bytes_ += k_pad * 4.0 * (1.0 if active > 0 else 0.0)      # key mask
    bytes_ += total * n_heads * block_q * dh_p * 4.0          # output banks
    t = RooflineTerms(flops, bytes_, 0.0)
    return {
        "flops": flops,
        "bytes": bytes_,
        "coll_bytes": 0.0,
        "time_s": t.t_bound,
        "detail": {"active_banks": active, "total_banks": total,
                   "bottleneck": t.bottleneck},
    }


def delta_backend_cost(
    j_embed: float,
    j_qkv,
    q_attn,
    k: int,
    m: int,
    d_model: int,
    n_heads: int,
    d_ff: int,
    n_classes: int,
    block_q: int = 8,
) -> dict:
    """Analytic per-frame cost of the whole delta-gated backend
    (DESIGN.md §14): embed + per-layer QKV/attention/MLP + head, at the
    stale populations the gate actually touched (``j_qkv``/``q_attn``
    are per-layer sequences — the same populations
    :func:`repro.core.power.backend_frame_macs` prices in MACs; this
    model adds the roofline bytes so block shapes and speedup claims
    derive from one place). FLOPs = 2·MACs on the row terms; attention
    terms defer to :func:`delta_attention_cost` per layer.
    """
    d = d_model
    flops = 2.0 * j_embed * m * d + 2.0 * float(n_classes * d)
    bytes_ = j_embed * (m * 1.0 + d * 4.0) + m * d * 1.0
    detail = {"layers": []}
    for j_l, q_l in zip(j_qkv, q_attn):
        attn = delta_attention_cost(
            int(q_l), k, d_model, n_heads, block_q=block_q)
        lf = 2.0 * (j_l * 3.0 * d * d + q_l * (d * d + 2.0 * d * d_ff))
        lb = (j_l + q_l) * d * 4.0 * 2.0 + (3.0 * d * d + 2.0 * d * d_ff) * 4.0
        flops += lf + attn["flops"]
        bytes_ += lb + attn["bytes"]
        detail["layers"].append({"row_flops": lf, "attn": attn["detail"]})
    t = RooflineTerms(flops, bytes_, 0.0)
    return {
        "flops": flops,
        "bytes": bytes_,
        "coll_bytes": 0.0,
        "time_s": t.t_bound,
        "detail": detail,
    }


def model_flops(n_active_params: int, tokens: int, is_train: bool) -> float:
    """MODEL_FLOPS = 6·N·D (train: fwd+bwd) or 2·N·D (inference fwd)."""
    return (6.0 if is_train else 2.0) * n_active_params * tokens
