"""IP2 core — the paper's contribution: in-pixel analog compute simulation.

Layers: pwm (PWM/DAC quantizers) -> switched_cap (charge sharing, leakage,
OpAmp) -> projection (patch MVM) -> adc (edge readout) composed by
frontend.IP2Frontend; saliency gates patches spatially; temporal reuses
held charge across frames (delta gate + droop-budgeted FeatureCache);
bayer models the mosaic + anti-alias optics; power/throughput reproduce
Table 1 and Fig. 3; qth_attention is the Fig. 4 extension.
"""

from repro.core.adc import (
    ADCCodes,
    ADCSpec,
    adc_quantize,
    dequantize,
    digital_codes,
    digital_readout,
    encode,
    readout_scale_zero,
)
from repro.core.analog_nl import AnalogNLSpec, analog_nonlinearity
from repro.core.bayer import antialias, bayer_channel_map, mosaic, strike_columns
from repro.core.frontend import (
    CompactFeatures,
    FrontendConfig,
    apply_frontend,
    compact_features,
    dequantize_features,
    feature_scale_zero,
    init_frontend_params,
    project_wire,
    sensor_patches,
)
from repro.core.power import (
    AreaBudget,
    EnergyConstants,
    EnergyMeter,
    EventCounts,
    PowerBreakdown,
    PowerReport,
    SensorConfig,
    data_reduction,
    frontend_frame_events,
    power_report,
    steady_state_events,
)
from repro.core.projection import (
    PatchSpec,
    analog_project_frame,
    analog_project_patches,
    extract_patches,
)
from repro.core.pwm import QuantSpec, pwm_quantize, quantize_weights, weight_codes
from repro.core.qth_attention import QTHSpec, pow2_quantize, qth_attention
from repro.core.saliency import (
    apply_patch_mask,
    compact_active,
    gather_patches,
    indices_from_mask,
    mask_from_indices,
    patch_energy,
    topk_patch_indices,
    topk_patch_mask,
)
from repro.core.switched_cap import (
    SummerSpec,
    TAU_LEAK_65NM_S,
    capacitor_divider,
    charge_share_sum,
    passive_droop_trace,
)
from repro.core.temporal import (
    FeatureCache,
    TemporalSpec,
    gated_frame_events,
    held_features,
    held_gain,
    init_feature_cache,
    refresh,
    select_stale,
    take_rows,
)
from repro.core.throughput import figure3_sweep, frame_rate, rate_point

__all__ = [
    "ADCCodes", "ADCSpec", "adc_quantize", "dequantize", "digital_codes",
    "digital_readout", "encode", "readout_scale_zero",
    "AnalogNLSpec", "analog_nonlinearity",
    "antialias", "bayer_channel_map", "mosaic", "strike_columns",
    "CompactFeatures", "FrontendConfig", "apply_frontend", "compact_features",
    "dequantize_features", "feature_scale_zero",
    "init_frontend_params", "project_wire", "sensor_patches",
    "AreaBudget", "EnergyConstants", "EnergyMeter", "EventCounts",
    "PowerBreakdown", "PowerReport", "SensorConfig", "data_reduction",
    "frontend_frame_events", "power_report", "steady_state_events",
    "PatchSpec", "analog_project_frame", "analog_project_patches", "extract_patches",
    "QuantSpec", "pwm_quantize", "quantize_weights", "weight_codes",
    "QTHSpec", "pow2_quantize", "qth_attention",
    "apply_patch_mask", "compact_active", "gather_patches", "indices_from_mask",
    "mask_from_indices", "patch_energy", "topk_patch_indices", "topk_patch_mask",
    "SummerSpec", "TAU_LEAK_65NM_S", "capacitor_divider", "charge_share_sum",
    "passive_droop_trace",
    "FeatureCache", "TemporalSpec", "gated_frame_events", "held_features",
    "held_gain",
    "init_feature_cache", "refresh", "select_stale", "take_rows",
    "figure3_sweep", "frame_rate", "rate_point",
]
