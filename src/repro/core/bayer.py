"""Bayer mosaic + anti-aliasing model (paper §2.1.5).

The HW sensor produces a raw mosaiced Bayer image (RGGB); no demosaicing is
performed in hardware. The trained RGB projection matrix A is transformed
to A' by *striking out the columns* of A that have no corresponding element
in the Bayer vector — i.e. each pixel site keeps only its own color's
weight column.

Anti-aliasing: micro-lenses give near-unity fill factor; the combined
optics are modelled as Gaussian low-pass filters with -3 dB cutoff at 0.5
or 0.25 of Nyquist. The paper reports training accuracy is virtually
unaffected even at 0.25 Nyquist (slight defocus is a good AA filter).
"""

from __future__ import annotations

import math

import jax

import jax.numpy as jnp

# RGGB unit cell: channel index at (row%2, col%2)
_BAYER_RGGB = ((0, 1), (1, 2))  # R G / G B


def bayer_channel_map(h: int, w: int) -> jnp.ndarray:
    """(H, W) int32 array of the color-channel index of each pixel site."""
    rows = jnp.arange(h)[:, None] % 2
    cols = jnp.arange(w)[None, :] % 2
    cell = jnp.asarray(_BAYER_RGGB, dtype=jnp.int32)
    return cell[rows, cols]


def mosaic(rgb: jnp.ndarray) -> jnp.ndarray:
    """(..., H, W, 3) RGB -> (..., H, W) raw Bayer frame."""
    h, w = rgb.shape[-3], rgb.shape[-2]
    onehot = jax.nn.one_hot(bayer_channel_map(h, w), 3, dtype=rgb.dtype)
    return jnp.einsum("...hwc,hwc->...hw", rgb, onehot)


def strike_columns(a_rgb: jnp.ndarray, patch_h: int, patch_w: int) -> jnp.ndarray:
    """Trained matrix A (M, N²·3) -> A' (M, N²) for the Bayer sensor.

    For pixel site i with Bayer color c(i), keep only column (i, c(i)) of
    the vectorized-RGB matrix; all other color columns have no corresponding
    hardware element and are struck out (paper §2.1.5).
    """
    m, n2x3 = a_rgb.shape
    n2 = patch_h * patch_w
    if n2x3 != n2 * 3:
        raise ValueError(f"A has {n2x3} cols, expected {n2 * 3}")
    ch = bayer_channel_map(patch_h, patch_w).reshape(-1)  # (N²,)
    a = a_rgb.reshape(m, n2, 3)
    return jnp.take_along_axis(a, ch[None, :, None], axis=-1)[..., 0]


def gaussian_kernel_1d(cutoff_nyquist: float, radius: int | None = None) -> jnp.ndarray:
    """1-D Gaussian whose magnitude response is -3 dB at cutoff·Nyquist.

    |H(f)| = exp(-2 (pi sigma f)^2); solving |H(fc)|² = 1/2 at
    fc = cutoff·0.5 cycles/px gives sigma = sqrt(ln 2)/(2 pi fc) / sqrt(2).
    """
    fc = cutoff_nyquist * 0.5  # cycles / pixel
    sigma = math.sqrt(math.log(2.0) / 2.0) / (2.0 * math.pi * fc)
    if radius is None:
        radius = max(1, int(math.ceil(3.0 * sigma)))
    x = jnp.arange(-radius, radius + 1, dtype=jnp.float32)
    k = jnp.exp(-0.5 * (x / sigma) ** 2)
    return k / jnp.sum(k)


def antialias(frame: jnp.ndarray, cutoff_nyquist: float = 0.5) -> jnp.ndarray:
    """Separable Gaussian AA filter on (..., H, W) (reflect padding)."""
    k = gaussian_kernel_1d(cutoff_nyquist)
    r = (k.shape[0] - 1) // 2

    def conv_last(x):
        xp = jnp.concatenate(
            [x[..., 1 : r + 1][..., ::-1], x, x[..., -r - 1 : -1][..., ::-1]], axis=-1
        )
        windows = jnp.stack([xp[..., i : i + x.shape[-1]] for i in range(2 * r + 1)], axis=-1)
        return jnp.einsum("...k,k->...", windows, k)

    out = conv_last(frame)                     # along W
    out = conv_last(out.swapaxes(-1, -2)).swapaxes(-1, -2)  # along H
    return out


def downsample2(frame: jnp.ndarray) -> jnp.ndarray:
    """½-resolution sensor option (paper: 1920x1080 RGB -> 960x540 Bayer)."""
    return frame[..., ::2, ::2]
