"""IP2Frontend — the full sensor-to-features path (paper Fig. 1/2).

scene RGB -> lenslet/optics AA filter -> Bayer mosaic -> CDS sample
          -> salient patch selection (<=25 %) -> analog patch projection
          (PWM x switched-cap, M vectors/patch) -> edge ADC -> digital
          features + V_R - b subtraction.

Two selectable paths compute the projection:

* ``analog=True``  — the paper's circuit: Bayer single-channel patches,
  A' = strike_columns(A), PWM/DAC quantization, charge-share /N², droop,
  optional 2T nonlinearity, edge ADC. This is the hardware digital twin.
* ``analog=False`` — the float "algorithm simulation" the paper trains
  against: full-RGB patches through the unquantized matrix A.

And two execution modes select the dataflow (see DESIGN.md §3 for when to
choose each):

* ``mode="dense"``   — project every patch, then zero-mask the deselected
  ones. Features keep the full (..., P, M) grid shape; used for training
  and the accuracy/bits/active-fraction co-design studies where gradients
  must reach every patch position.
* ``mode="compact"`` — *select -> gather -> project*: only the (exactly k)
  active patches are gathered ahead of the projection, so analog compute,
  ADC conversions and streamed features all scale with the active
  fraction — the dataflow the hardware actually implements and the source
  of the paper's 10x bandwidth / <30 mW/MP claims. Returns static-shape
  (..., k, M) features plus the patch indices.

Compact-mode output on the analog path is the digital WIRE FORMAT by
default (DESIGN.md §9): int8 ADC codes plus static (scale, zero) dequant
metadata — what the hardware actually streams, 4x fewer bytes than
float32 — dequantized in exactly one place, the backend's first matmul
(:func:`dequantize_features`). ``wire="float"`` selects the
bit-identical STE float view instead. The float simulation
(``analog=False``) has no edge ADC and therefore no code wire: its
compact payload resolves to the (unquantized) float view.

Both the dense path and the float-wire compact path are differentiable
(STE through the quantizers; the compact gather is a differentiable
take), enabling the co-design studies of §1 and §2.1.3 on either
dataflow; integer codes carry no gradients, so training uses those views.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import adc as adc_mod
from repro.core import bayer as bayer_mod
from repro.core import power as power_mod
from repro.core import projection as proj_mod
from repro.core import saliency as sal_mod
from repro.core import temporal as temporal_mod


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    image_h: int = 256
    image_w: int = 256
    patch: proj_mod.PatchSpec = proj_mod.PatchSpec(patch_h=32, patch_w=32, n_vectors=400)
    analog: bool = True
    bayer: bool = True                 # raw mosaic input (HW); False = RGB (sim)
    aa_cutoff: float | None = 0.5      # Gaussian AA at 0.5/0.25 Nyquist; None = off
    active_fraction: float = 0.25
    adc: adc_mod.ADCSpec = adc_mod.ADCSpec()
    temporal: temporal_mod.TemporalSpec = temporal_mod.TemporalSpec()

    @property
    def grid(self) -> tuple[int, int]:
        return (self.image_h // self.patch.patch_h, self.image_w // self.patch.patch_w)

    @property
    def n_patches(self) -> int:
        gh, gw = self.grid
        return gh * gw

    @property
    def n_active(self) -> int:
        return max(1, int(round(self.n_patches * self.active_fraction)))


class CompactFeatures(NamedTuple):
    """The bandwidth-true frontend output: only active patches exist, in
    the digital wire format (DESIGN.md §9).

    ``features[..., i, :]`` is the ADC conversion of patch
    ``indices[..., i]`` — by default the raw int8 ADC *codes* (exactly
    what the hardware streams off-sensor; ``features.nbytes`` IS the
    per-frame wire traffic), or the float32 STE readout under the
    ``wire="float"`` training/diagnostic path. ``valid[..., i]`` is False
    only when fewer than k patches were active and slot i is a repeated
    filler (never the case when selection comes from the exactly-k
    index-first API).

    ``scale``/``zero`` are the static affine dequant metadata (ADC LSB and
    ``v_min + half·lsb - V_R + bias``); ``gain`` is the per-token
    digital-side multiplier (valid mask × held-charge droop ``d^age``;
    identically 1.0 on fresh valid conversions). The ONE place these may
    be folded into the payload is :func:`dequantize_features` — the
    backend's first matmul (DESIGN.md §9).

    ``energy`` is the in-pixel patch-energy proxy over the FULL grid — an
    analog-domain signal (the photodiodes integrate light regardless of
    selection, so it is free) that never crosses the feature wire; the
    saccade loop consumes it from here instead of re-running
    :func:`sensor_patches` (DESIGN.md §5).

    ``events`` is this frame's executed energy-event ledger
    (:class:`repro.core.power.EventCounts`, per batch element; DESIGN.md
    §10): the ADC conversions / cap charges / DAC loads / CDS samples /
    comparator+OpAmp windows that the frontend ACTUALLY spent producing
    this payload — ``k·M`` conversions on the ungated compact path,
    ``n_stale·M`` under the temporal gate (holds are free). Price it
    with :class:`repro.core.power.EnergyMeter`. Like ``energy``, it is
    O(1) metadata, never part of the wire payload.
    """

    features: jnp.ndarray   # (..., k, M) int8 ADC codes (or f32, wire="float")
    indices: jnp.ndarray    # (..., k) int32 patch indices
    valid: jnp.ndarray      # (..., k) bool
    energy: jnp.ndarray     # (..., P) float32 patch-energy proxy (analog domain)
    scale: jnp.ndarray      # () float32 — ADC LSB (volts per code)
    zero: jnp.ndarray       # (M,) float32 — dequant offset incl. V_R - b
    gain: jnp.ndarray       # (..., k) float32 — valid × droop d^age
    events: power_mod.EventCounts = power_mod.EventCounts()  # (...,) leaves


def dequantize_features(cf: CompactFeatures) -> jnp.ndarray:
    """The one permitted dequant site (DESIGN.md §9): codes -> float32
    readout via the static affine, times the per-token ``gain`` (valid
    mask and held-charge droop). Float-wire payloads skip the affine —
    on the analog path they are already the (bit-identical) dequantized
    readout, so both wires produce the same floats here."""
    feats = cf.features
    if not jnp.issubdtype(feats.dtype, jnp.floating):
        feats = adc_mod.dequantize(feats, cf.scale, cf.zero)
    return feats * cf.gain[..., None]


def init_frontend_params(key: jax.Array, cfg: FrontendConfig) -> dict:
    """A is always trained in vectorized-RGB space (M, N²·3); the analog path
    strikes columns to A' at apply time (paper §2.1.5).

    Full-scale matching (co-design): the charge-share sum divides by N², so
    the weight DAC full-scale current must be ~√N² larger than a classic
    1/√fan_in init or the OpAmp output sits below one ADC LSB and the edge
    ADC quantizes every feature to zero. σ_W = 0.4·√N² puts Out_v's std at
    ≈0.25 of the ±1 V rail (pixels ~U[0,1], A' keeps N² of the 3N² cols).
    """
    n2 = cfg.patch.pixels_per_patch
    m = cfg.patch.n_vectors
    scale = 0.4 * jnp.sqrt(jnp.asarray(n2, jnp.float32))
    a = jax.random.normal(key, (m, n2 * 3), jnp.float32) * scale
    return {"a_rgb": a, "bias": jnp.zeros((m,), jnp.float32)}


ProjectFn = Callable[[jnp.ndarray, jnp.ndarray, proj_mod.PatchSpec], jnp.ndarray]


def _call_project_fn(fn, patches, weights, spec, row_counts):
    """Invoke a ProjectFn, forwarding the ragged per-slot row counts only
    to adapters that advertise ``supports_row_counts`` (DESIGN.md §11) —
    plain callables keep the original 3-arg signature. ``row_counts`` is
    DATA (no recompile); rows at positions >= their slot's count come back
    ZERO from a ragged adapter, so callers must only pass counts when the
    tail rows are discarded (temporal gate) or gained out (k_cap shed)."""
    if row_counts is not None and getattr(fn, "supports_row_counts", False):
        return fn(patches, weights, spec, row_counts=row_counts)
    return fn(patches, weights, spec)


def sensor_patches(
    params: dict, rgb: jnp.ndarray, cfg: FrontendConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Optics + mosaic + patch extraction: rgb (..., H, W, 3) ->
    (patches (..., P, N), effective weights (M, N)).

    This is the part of the frontend that is physically unavoidable — every
    photodiode integrates light regardless of selection — and therefore
    shared verbatim by the dense and compact dataflows.
    """
    p = cfg.patch
    if cfg.aa_cutoff is not None:
        rgb = jnp.stack(
            [bayer_mod.antialias(rgb[..., c], cfg.aa_cutoff) for c in range(3)], axis=-1
        )

    if cfg.analog or cfg.bayer:
        frame = bayer_mod.mosaic(rgb)                                # (..., H, W)
        patches = proj_mod.extract_patches(frame, p.patch_h, p.patch_w)
        weights = bayer_mod.strike_columns(params["a_rgb"], p.patch_h, p.patch_w)
    else:
        # float simulation path: vectorized RGB patches
        per_c = [
            proj_mod.extract_patches(rgb[..., c], p.patch_h, p.patch_w) for c in range(3)
        ]
        patches = jnp.concatenate(per_c, axis=-1)                    # (..., P, N²·3)
        weights = params["a_rgb"]

    return patches, weights


def project_readout(
    patches: jnp.ndarray,
    weights: jnp.ndarray,
    params: dict,
    cfg: FrontendConfig,
    project_fn: ProjectFn | None,
    row_counts=None,
) -> jnp.ndarray:
    """Analog projection + edge ADC (or the float simulation) over whatever
    set of patches it is handed — the full grid (dense) or the gathered
    active set (compact). Float view: ``digital_readout`` is the STE
    dequant of the ADC codes, bit-identical to the code wire by
    construction (DESIGN.md §9). ``row_counts`` rides to ragged-capable
    kernel adapters only (see :func:`_call_project_fn`)."""
    if project_fn is not None and getattr(project_fn, "emits_codes", False):
        raise ValueError(
            "project_fn emits wire-format codes (ops.ip2_codes_fn) but this "
            "is a float path (dense mode or wire='float'): its int8 output "
            "is not analog voltage. Use ops.ip2_project_fn here, or "
            "mode='compact' with wire='codes'."
        )
    if project_fn is not None and getattr(project_fn, "emits_sign", False):
        raise ValueError(
            "project_fn emits the 1-bit sign wire (ops.ip2_sign_fn) but "
            "this is a float path (dense mode or wire='float'): its bool "
            "output is not analog voltage. Use ops.ip2_project_fn here, or "
            "mode='compact' with wire='sign'."
        )
    if cfg.analog:
        fn = project_fn or proj_mod.analog_project_patches
        out_v = _call_project_fn(fn, patches, weights, cfg.patch, row_counts)
        return adc_mod.digital_readout(out_v, cfg.patch.summer.v_ref, params["bias"], cfg.adc)
    n_in = patches.shape[-1]
    return jnp.einsum("...pi,vi->...pv", patches, weights) / n_in + params["bias"]


def feature_scale_zero(
    params: dict, cfg: FrontendConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The static (scale, zero) dequant metadata of this frontend's wire
    format — a function of (ADCSpec, V_R, bias) only, never of the frame."""
    return adc_mod.readout_scale_zero(
        cfg.patch.summer.v_ref, params["bias"], cfg.adc
    )


def project_wire(
    patches: jnp.ndarray,
    weights: jnp.ndarray,
    params: dict,
    cfg: FrontendConfig,
    project_fn: ProjectFn | None,
    wire: str,
    row_counts=None,
) -> jnp.ndarray:
    """Project a gathered patch set onto the requested wire format.

    ``wire="codes"`` (analog only — the float simulation has no ADC, so
    there are no codes to emit): int8 ADC codes — from the kernel's fused
    epilogue when ``project_fn`` advertises ``emits_codes`` (the
    conversion happens exactly once, at the array edge, inside the
    kernel), else by encoding the analog output here.

    ``wire="float"``: the STE dequant view (differentiable; on the analog
    path, bit-identical values to dequantizing the codes).

    ``wire="sign"`` (analog only, DESIGN.md §13): the ADC-less 1-bit
    comparator wire — bool payload, from the kernel's sign epilogue when
    ``project_fn`` advertises ``emits_sign`` (``ops.ip2_sign_fn``), else
    by comparing the analog output against V_R here.

    ``row_counts`` (DESIGN.md §11): per-slot real-row counts forwarded to
    ragged-capable kernel adapters so rows past the count cost zero
    FLOPs/bytes instead of masked-but-computed work; other projectors
    ignore it (they compute every handed row).
    """
    if wire == "float":
        return project_readout(
            patches, weights, params, cfg, project_fn, row_counts=row_counts)
    if not cfg.analog:
        raise ValueError(
            f"wire={wire!r} requires analog=True: the float simulation has "
            "no edge ADC or comparator, so there is no digital wire — use "
            "wire='float' (the default resolution for analog=False)"
        )
    if wire == "sign":
        if project_fn is not None and getattr(project_fn, "emits_codes", False):
            raise ValueError(
                "project_fn emits wire-format ADC codes (ops.ip2_codes_fn) "
                "but wire='sign' carries 1-bit comparator output — use "
                "ops.ip2_sign_fn (or a plain projector) here"
            )
        if project_fn is not None and getattr(project_fn, "emits_sign", False):
            return _call_project_fn(
                project_fn, patches, weights, cfg.patch, row_counts)
        fn = project_fn or proj_mod.analog_project_patches
        out_v = _call_project_fn(fn, patches, weights, cfg.patch, row_counts)
        return adc_mod.sign_encode(out_v, cfg.patch.summer.v_ref)
    if project_fn is not None and getattr(project_fn, "emits_sign", False):
        raise ValueError(
            "project_fn emits the 1-bit sign wire (ops.ip2_sign_fn) but "
            "wire='codes' carries int8 ADC codes — use ops.ip2_codes_fn "
            "(or a plain projector) here"
        )
    if project_fn is not None and getattr(project_fn, "emits_codes", False):
        return _call_project_fn(
            project_fn, patches, weights, cfg.patch, row_counts)
    fn = project_fn or proj_mod.analog_project_patches
    out_v = _call_project_fn(fn, patches, weights, cfg.patch, row_counts)
    return adc_mod.encode(out_v, cfg.adc)


class CompactSelection(NamedTuple):
    """The resolved compact selection, before any projection is spent:
    the dense CDS patch voltages and effective weights from
    :func:`sensor_patches`, the exactly-k ranked patch indices, their
    prefix validity mask (``valid[..., i]`` implies ``valid[..., i-1]`` —
    fillers and governor-shed slots always trail), and the free
    analog-domain patch-energy proxy. This is the input contract of both
    the staged compact path (``apply_frontend(mode="compact")``) and the
    fused megakernel path (``vit_forward_compact`` with
    ``fused_embed=True``, DESIGN.md §11)."""

    patches: jnp.ndarray    # (..., P, N) dense CDS patch voltages
    weights: jnp.ndarray    # (M, N) effective projection weights
    indices: jnp.ndarray    # (..., k) int32 ranked patch indices
    valid: jnp.ndarray      # (..., k) bool prefix mask
    energy: jnp.ndarray     # (..., P) float32 patch-energy proxy


def select_compact(
    params: dict,
    rgb: jnp.ndarray,
    cfg: FrontendConfig,
    mask: jnp.ndarray | None = None,
    indices: jnp.ndarray | None = None,
    precomputed: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    k_cap: jnp.ndarray | None = None,
) -> CompactSelection:
    """Resolve the compact selection (select, do not yet project): sensor
    stage, patch energy, exactly-k indices with the same precedence as
    :func:`apply_frontend` (``indices`` > ``mask`` > energy top-k), and
    the governor's ``k_cap`` shed applied to the validity prefix.
    Shared by the staged and fused compact paths so their selections are
    identical by construction."""
    if k_cap is not None and mask is not None and indices is None:
        raise ValueError(
            "k_cap sheds trailing selection slots and therefore needs a "
            "selection ranked most-salient-first; mask-derived indices "
            "come out in ascending patch order (indices_from_mask), so "
            "the shed tokens would be arbitrary — pass ranked indices "
            "instead (see topk_patch_indices)"
        )
    k = cfg.n_active
    if precomputed is not None:
        patches, weights = precomputed
    else:
        patches, weights = sensor_patches(params, rgb, cfg)
    energy = sal_mod.patch_energy(patches)
    if indices is not None:
        idx = indices.astype(jnp.int32)
        if idx.shape[-1] != k:
            raise ValueError(f"indices last dim {idx.shape[-1]} != n_active {k}")
        valid = jnp.ones(idx.shape, bool)
    elif mask is not None:
        idx, valid = sal_mod.indices_from_mask(mask, k)
    else:
        idx = sal_mod.topk_patch_indices(energy, k)
        valid = jnp.ones(idx.shape, bool)
    if k_cap is not None:
        # governor k-tier: selection indices are score-ranked, so shedding
        # the trailing slots keeps exactly the top-k_cap tokens (data-only:
        # same shapes, capped tokens flagged invalid and served as zero)
        valid = valid & (jnp.arange(k) < k_cap[..., None])
    return CompactSelection(patches, weights, idx, valid, energy)


def apply_frontend(
    params: dict,
    rgb: jnp.ndarray,
    cfg: FrontendConfig,
    mask: jnp.ndarray | None = None,
    project_fn: ProjectFn | None = None,
    mode: str = "dense",
    indices: jnp.ndarray | None = None,
    precomputed: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    cache: temporal_mod.FeatureCache | None = None,
    wire: str | None = None,
    k_cap: jnp.ndarray | None = None,
    stale_cap: jnp.ndarray | None = None,
):
    """rgb (..., H, W, 3) in [0,1] -> frontend features.

    Selection inputs (the backend's saccadic prediction for this frame):
    ``indices`` (..., k) takes precedence, then ``mask`` (..., P); if both
    are None a patch-energy top-k stand-in is used. ``project_fn`` lets the
    Pallas kernel replace the reference einsum (same signature/semantics;
    a kernel adapter advertising ``emits_codes`` — ``ops.ip2_codes_fn`` —
    emits the wire format straight from its fused ADC epilogue).
    ``precomputed`` is an optional ``(patches, weights)`` pair from an
    earlier :func:`sensor_patches` call on the same frame, so callers that
    already needed the CDS patch voltages (e.g. the serving engine's
    in-step bootstrap) don't pay for the optics/mosaic stage twice.

    ``wire`` (compact mode only) selects the payload format of
    :class:`CompactFeatures` (DESIGN.md §9): ``"codes"`` — int8 ADC
    codes, what the hardware streams, 4x fewer bytes; ``"float"`` — the
    STE dequant view, bit-identical values after
    :func:`dequantize_features`, differentiable for compact-path
    co-design. ``None`` (default) resolves per config: ``"codes"`` when
    ``cfg.analog`` (there is a real edge ADC) and ``"float"`` for the
    float simulation (``analog=False`` — no ADC, no code wire; requesting
    ``"codes"`` there raises).

    ``cache`` (compact mode only) enables the temporal delta gate
    (DESIGN.md §6): of the k selected patches, only the stale subset —
    CDS energy moved by >= ``cfg.temporal.delta_threshold`` since last
    recompute, never computed, or drooped past the LSB budget — is
    gathered/projected/converted (exactly ``cfg.temporal`` budget-j slots,
    static shape); the rest are served from the held charge modelled by
    the cache. The cache dtype must match the wire (code caches for
    ``wire="codes"``). The return value becomes
    ``(CompactFeatures, FeatureCache)``.

    ``k_cap`` / ``stale_cap`` (compact mode only) are the power
    governor's per-stream DATA knobs (DESIGN.md §10) — neither changes a
    shape, so governing never recompiles. ``k_cap`` (..., ) int32 marks
    selection slots ``>= k_cap`` invalid (the tokens are shed: not
    served, not converted, their patches dump like deselected ones);
    ``stale_cap`` (..., ) int32 truncates the temporal gate's needed set
    to its first ``stale_cap`` ranked slots (requires ``cache``). Both
    are bitwise no-ops at ``k_cap >= k`` / ``stale_cap >= j``.

    ``k_cap`` sheds TRAILING slots, so it requires a selection ranked
    most-salient-first: the default energy top-k and the engine's
    score top-k are; caller-supplied ``indices`` must be (as
    ``topk_patch_indices`` emits them). ``mask``-derived selections come
    out in ascending patch order — shedding their tail would drop
    arbitrary patches, not the least salient — so that combination
    raises.

    Returns (mode="dense"):   (features (..., P, M), mask (..., P)) with
      deselected patches zeroed — compute scales with P. Always float
      (the STE training path); ``wire`` does not apply.
    Returns (mode="compact"): :class:`CompactFeatures` with (..., k, M)
      features — compute scales with k (select -> gather -> project);
      with ``cache`` given, ``(CompactFeatures, FeatureCache)`` and
      per-frame projection/ADC work scales with the recompute budget j.
    """
    if mode not in ("dense", "compact"):
        raise ValueError(f"mode must be 'dense' or 'compact', got {mode!r}")
    if wire is None:
        wire = "codes" if cfg.analog else "float"
    if wire not in ("codes", "float", "sign"):
        raise ValueError(
            f"wire must be 'codes', 'float' or 'sign', got {wire!r}")
    if cache is not None and mode != "compact":
        raise ValueError(
            "the temporal cache only applies to mode='compact'; dense "
            "(training) execution must bypass it — see DESIGN.md §6"
        )
    if (k_cap is not None or stale_cap is not None) and mode != "compact":
        raise ValueError(
            "k_cap/stale_cap are governor knobs of the compact serving "
            "path (DESIGN.md §10); dense execution has no gate to cap"
        )
    if stale_cap is not None and cache is None:
        raise ValueError(
            "stale_cap caps the temporal gate's recompute allocation; "
            "pass a FeatureCache (there is no gate to cap without one)"
        )
    if k_cap is not None and mask is not None and indices is None:
        raise ValueError(
            "k_cap sheds trailing selection slots and therefore needs a "
            "selection ranked most-salient-first; mask-derived indices "
            "come out in ascending patch order (indices_from_mask), so "
            "the shed tokens would be arbitrary — pass ranked indices "
            "instead (see topk_patch_indices)"
        )
    if precomputed is not None:
        patches, weights = precomputed
    else:
        patches, weights = sensor_patches(params, rgb, cfg)

    if mode == "dense":
        if indices is not None:                  # same precedence as compact
            mask = sal_mod.mask_from_indices(indices, cfg.n_patches)
        elif mask is None:
            mask = sal_mod.topk_patch_mask(
                sal_mod.patch_energy(patches), cfg.active_fraction
            )
        feats = project_readout(patches, weights, params, cfg, project_fn)
        return sal_mod.apply_patch_mask(feats, mask), mask

    # compact: resolve the selection to exactly-k indices, gather the active
    # patches, and only then spend analog compute / ADC conversions on them.
    k = cfg.n_active
    sel = select_compact(
        params, rgb, cfg, mask=mask, indices=indices,
        precomputed=(patches, weights), k_cap=k_cap,
    )
    idx, valid, energy = sel.indices, sel.valid, sel.energy

    n_pixels = float(cfg.image_h * cfg.image_w)
    n_selected = jnp.sum(valid, axis=-1).astype(jnp.float32)
    # sign wire: 1-bit payload, ±v_mag reconstruction affine (DESIGN.md
    # §13); its conversions are comparator firings, not ADC conversions
    readout = "sign" if wire == "sign" else "adc"
    if wire == "sign":
        scale, zero = adc_mod.sign_scale_zero(params["bias"])
    else:
        scale, zero = feature_scale_zero(params, cfg)
    if cache is None:
        active = sal_mod.gather_patches(patches, idx)                # (..., k, N)
        # governed streams hand ragged-capable kernels the per-slot valid
        # count (valid is a prefix): shed tokens then cost zero FLOPs and
        # zero VMEM traffic instead of compute-then-gain-to-zero. Shed
        # rows come back as zero payload — identical after gain either way.
        row_counts = (
            jnp.sum(valid, axis=-1).astype(jnp.int32)
            if k_cap is not None else None
        )
        payload = project_wire(
            active, weights, params, cfg, project_fn, wire,
            row_counts=row_counts)
        gain = valid.astype(jnp.float32)
        # ungated compact path: every served token was projected AND
        # converted this frame — n_selected·M real ADC conversions
        events = power_mod.frontend_frame_events(
            n_pixels, cfg.patch.pixels_per_patch, cfg.patch.n_vectors,
            n_selected_patches=n_selected, n_converted_patches=n_selected,
            readout=readout,
        )
        return CompactFeatures(
            payload, idx, valid, energy, scale, zero, gain, events)

    # temporal delta gate: recompute only the stale subset of the selection,
    # scatter-merge into the held-charge cache, serve the selection from it
    # (raw payload + droop/charge gain; dequantize_features folds them).
    cdt = cache.features.dtype
    cache_ok = (
        jnp.issubdtype(cdt, jnp.floating) if wire == "float"
        else cdt == jnp.bool_ if wire == "sign"
        else jnp.issubdtype(cdt, jnp.signedinteger)
    )
    if not cache_ok:
        raise ValueError(
            f"cache dtype {cdt} does not match wire={wire!r}; "
            "build it with init_feature_cache(cfg, ..., dtype=...) to match"
        )
    tspec = cfg.temporal
    stale_idx, needed, n_stale = temporal_mod.select_stale(
        energy, idx, cache, tspec, cfg.patch.summer, cfg.adc,
        sel_valid=valid, cap=stale_cap,
    )
    stale_patches = sal_mod.gather_patches(patches, stale_idx)       # (..., j, N)
    # the needed set is ranked stale-first, so n_stale is a prefix count:
    # ragged-capable kernels skip the (j - n_stale) filler rows entirely
    # (their zeroed outputs are discarded — refresh merges needed rows only)
    new_feats = project_wire(
        stale_patches, weights, params, cfg, project_fn, wire,
        row_counts=n_stale.astype(jnp.int32))
    cache = temporal_mod.refresh(
        cache, stale_idx, needed, new_feats, energy, n_stale
    )
    payload = temporal_mod.take_rows(cache.features, idx)            # (..., k, M)
    gain = (
        temporal_mod.held_gain(cache, idx, cfg.patch.summer)
        * valid.astype(jnp.float32)
    )
    # gated path: only the n_stale recomputed patches paid for projection
    # and conversion — holds are free (non-destructive readout, §2.1.2)
    events = temporal_mod.gated_frame_events(
        n_pixels, cfg.patch.pixels_per_patch, cfg.patch.n_vectors,
        n_selected=n_selected, n_stale=n_stale.astype(jnp.float32),
        readout=readout,
    )
    return CompactFeatures(
        payload, idx, valid, energy, scale, zero, gain, events), cache


def compact_features(
    feats: jnp.ndarray, mask: jnp.ndarray, cfg: FrontendConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bandwidth-true view of already-computed dense features: gather the
    active patches. Prefer ``apply_frontend(..., mode="compact")``, which
    avoids computing the deselected patches in the first place."""
    return sal_mod.compact_active(feats, mask, cfg.n_active)
