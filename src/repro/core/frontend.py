"""IP2Frontend — the full sensor-to-features path (paper Fig. 1/2).

scene RGB -> lenslet/optics AA filter -> Bayer mosaic -> CDS sample
          -> salient patch selection (<=25 %) -> analog patch projection
          (PWM x switched-cap, M vectors/patch) -> edge ADC -> digital
          features + V_R - b subtraction.

Two selectable paths compute the projection:

* ``analog=True``  — the paper's circuit: Bayer single-channel patches,
  A' = strike_columns(A), PWM/DAC quantization, charge-share /N², droop,
  optional 2T nonlinearity, edge ADC. This is the hardware digital twin.
* ``analog=False`` — the float "algorithm simulation" the paper trains
  against: full-RGB patches through the unquantized matrix A.

And two execution modes select the dataflow (see DESIGN.md §3 for when to
choose each):

* ``mode="dense"``   — project every patch, then zero-mask the deselected
  ones. Features keep the full (..., P, M) grid shape; used for training
  and the accuracy/bits/active-fraction co-design studies where gradients
  must reach every patch position.
* ``mode="compact"`` — *select -> gather -> project*: only the (exactly k)
  active patches are gathered ahead of the projection, so analog compute,
  ADC conversions and streamed features all scale with the active
  fraction — the dataflow the hardware actually implements and the source
  of the paper's 10x bandwidth / <30 mW/MP claims. Returns static-shape
  (..., k, M) features plus the patch indices.

Both paths are differentiable (STE through the quantizers; the compact
gather is a differentiable take), enabling the co-design studies of §1 and
§2.1.3 on either dataflow.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import adc as adc_mod
from repro.core import bayer as bayer_mod
from repro.core import projection as proj_mod
from repro.core import saliency as sal_mod
from repro.core import temporal as temporal_mod


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    image_h: int = 256
    image_w: int = 256
    patch: proj_mod.PatchSpec = proj_mod.PatchSpec(patch_h=32, patch_w=32, n_vectors=400)
    analog: bool = True
    bayer: bool = True                 # raw mosaic input (HW); False = RGB (sim)
    aa_cutoff: float | None = 0.5      # Gaussian AA at 0.5/0.25 Nyquist; None = off
    active_fraction: float = 0.25
    adc: adc_mod.ADCSpec = adc_mod.ADCSpec()
    temporal: temporal_mod.TemporalSpec = temporal_mod.TemporalSpec()

    @property
    def grid(self) -> tuple[int, int]:
        return (self.image_h // self.patch.patch_h, self.image_w // self.patch.patch_w)

    @property
    def n_patches(self) -> int:
        gh, gw = self.grid
        return gh * gw

    @property
    def n_active(self) -> int:
        return max(1, int(round(self.n_patches * self.active_fraction)))


class CompactFeatures(NamedTuple):
    """The bandwidth-true frontend output: only active patches exist.

    ``features[..., i, :]`` is the ADC-converted projection of patch
    ``indices[..., i]``; ``valid[..., i]`` is False only when fewer than k
    patches were active and slot i is a repeated filler (never the case
    when selection comes from the exactly-k index-first API).

    ``energy`` is the in-pixel patch-energy proxy over the FULL grid — the
    photodiodes integrate light regardless of selection, so this signal is
    free; the saccade loop consumes it from here instead of re-running
    :func:`sensor_patches` (DESIGN.md §5).
    """

    features: jnp.ndarray   # (..., k, M)
    indices: jnp.ndarray    # (..., k) int32 patch indices
    valid: jnp.ndarray      # (..., k) bool
    energy: jnp.ndarray     # (..., P) float32 patch-energy proxy


def init_frontend_params(key: jax.Array, cfg: FrontendConfig) -> dict:
    """A is always trained in vectorized-RGB space (M, N²·3); the analog path
    strikes columns to A' at apply time (paper §2.1.5).

    Full-scale matching (co-design): the charge-share sum divides by N², so
    the weight DAC full-scale current must be ~√N² larger than a classic
    1/√fan_in init or the OpAmp output sits below one ADC LSB and the edge
    ADC quantizes every feature to zero. σ_W = 0.4·√N² puts Out_v's std at
    ≈0.25 of the ±1 V rail (pixels ~U[0,1], A' keeps N² of the 3N² cols).
    """
    n2 = cfg.patch.pixels_per_patch
    m = cfg.patch.n_vectors
    scale = 0.4 * jnp.sqrt(jnp.asarray(n2, jnp.float32))
    a = jax.random.normal(key, (m, n2 * 3), jnp.float32) * scale
    return {"a_rgb": a, "bias": jnp.zeros((m,), jnp.float32)}


ProjectFn = Callable[[jnp.ndarray, jnp.ndarray, proj_mod.PatchSpec], jnp.ndarray]


def sensor_patches(
    params: dict, rgb: jnp.ndarray, cfg: FrontendConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Optics + mosaic + patch extraction: rgb (..., H, W, 3) ->
    (patches (..., P, N), effective weights (M, N)).

    This is the part of the frontend that is physically unavoidable — every
    photodiode integrates light regardless of selection — and therefore
    shared verbatim by the dense and compact dataflows.
    """
    p = cfg.patch
    if cfg.aa_cutoff is not None:
        rgb = jnp.stack(
            [bayer_mod.antialias(rgb[..., c], cfg.aa_cutoff) for c in range(3)], axis=-1
        )

    if cfg.analog or cfg.bayer:
        frame = bayer_mod.mosaic(rgb)                                # (..., H, W)
        patches = proj_mod.extract_patches(frame, p.patch_h, p.patch_w)
        weights = bayer_mod.strike_columns(params["a_rgb"], p.patch_h, p.patch_w)
    else:
        # float simulation path: vectorized RGB patches
        per_c = [
            proj_mod.extract_patches(rgb[..., c], p.patch_h, p.patch_w) for c in range(3)
        ]
        patches = jnp.concatenate(per_c, axis=-1)                    # (..., P, N²·3)
        weights = params["a_rgb"]

    return patches, weights


def project_readout(
    patches: jnp.ndarray,
    weights: jnp.ndarray,
    params: dict,
    cfg: FrontendConfig,
    project_fn: ProjectFn | None,
) -> jnp.ndarray:
    """Analog projection + edge ADC (or the float simulation) over whatever
    set of patches it is handed — the full grid (dense) or the gathered
    active set (compact)."""
    if cfg.analog:
        fn = project_fn or proj_mod.analog_project_patches
        out_v = fn(patches, weights, cfg.patch)                      # (..., n, M)
        return adc_mod.digital_readout(out_v, cfg.patch.summer.v_ref, params["bias"], cfg.adc)
    n_in = patches.shape[-1]
    return jnp.einsum("...pi,vi->...pv", patches, weights) / n_in + params["bias"]


def apply_frontend(
    params: dict,
    rgb: jnp.ndarray,
    cfg: FrontendConfig,
    mask: jnp.ndarray | None = None,
    project_fn: ProjectFn | None = None,
    mode: str = "dense",
    indices: jnp.ndarray | None = None,
    precomputed: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    cache: temporal_mod.FeatureCache | None = None,
):
    """rgb (..., H, W, 3) in [0,1] -> frontend features.

    Selection inputs (the backend's saccadic prediction for this frame):
    ``indices`` (..., k) takes precedence, then ``mask`` (..., P); if both
    are None a patch-energy top-k stand-in is used. ``project_fn`` lets the
    Pallas kernel replace the reference einsum (same signature/semantics).
    ``precomputed`` is an optional ``(patches, weights)`` pair from an
    earlier :func:`sensor_patches` call on the same frame, so callers that
    already needed the CDS patch voltages (e.g. the serving engine's
    in-step bootstrap) don't pay for the optics/mosaic stage twice.

    ``cache`` (compact mode only) enables the temporal delta gate
    (DESIGN.md §6): of the k selected patches, only the stale subset —
    CDS energy moved by >= ``cfg.temporal.delta_threshold`` since last
    recompute, never computed, or drooped past the LSB budget — is
    gathered/projected/converted (exactly ``cfg.temporal`` budget-j slots,
    static shape); the rest are served from the held charge modelled by
    the cache. The return value becomes ``(CompactFeatures, FeatureCache)``.

    Returns (mode="dense"):   (features (..., P, M), mask (..., P)) with
      deselected patches zeroed — compute scales with P.
    Returns (mode="compact"): :class:`CompactFeatures` with (..., k, M)
      features — compute scales with k (select -> gather -> project);
      with ``cache`` given, ``(CompactFeatures, FeatureCache)`` and
      per-frame projection/ADC work scales with the recompute budget j.
    """
    if mode not in ("dense", "compact"):
        raise ValueError(f"mode must be 'dense' or 'compact', got {mode!r}")
    if cache is not None and mode != "compact":
        raise ValueError(
            "the temporal cache only applies to mode='compact'; dense "
            "(training) execution must bypass it — see DESIGN.md §6"
        )
    k = cfg.n_active
    if precomputed is not None:
        patches, weights = precomputed
    else:
        patches, weights = sensor_patches(params, rgb, cfg)

    if mode == "dense":
        if indices is not None:                  # same precedence as compact
            mask = sal_mod.mask_from_indices(indices, cfg.n_patches)
        elif mask is None:
            mask = sal_mod.topk_patch_mask(
                sal_mod.patch_energy(patches), cfg.active_fraction
            )
        feats = project_readout(patches, weights, params, cfg, project_fn)
        return sal_mod.apply_patch_mask(feats, mask), mask

    # compact: resolve the selection to exactly-k indices, gather the active
    # patches, and only then spend analog compute / ADC conversions on them.
    energy = sal_mod.patch_energy(patches)
    if indices is not None:
        idx = indices.astype(jnp.int32)
        if idx.shape[-1] != k:
            raise ValueError(f"indices last dim {idx.shape[-1]} != n_active {k}")
        valid = jnp.ones(idx.shape, bool)
    elif mask is not None:
        idx, valid = sal_mod.indices_from_mask(mask, k)
    else:
        idx = sal_mod.topk_patch_indices(energy, k)
        valid = jnp.ones(idx.shape, bool)

    if cache is None:
        active = sal_mod.gather_patches(patches, idx)                # (..., k, N)
        feats = project_readout(active, weights, params, cfg, project_fn)
        feats = feats * valid[..., None].astype(feats.dtype)
        return CompactFeatures(feats, idx, valid, energy)

    # temporal delta gate: recompute only the stale subset of the selection,
    # scatter-merge into the held-charge cache, serve the selection from it.
    tspec = cfg.temporal
    stale_idx, needed, n_stale = temporal_mod.select_stale(
        energy, idx, cache, tspec, cfg.patch.summer, cfg.adc
    )
    stale_patches = sal_mod.gather_patches(patches, stale_idx)       # (..., j, N)
    new_feats = project_readout(stale_patches, weights, params, cfg, project_fn)
    cache = temporal_mod.refresh(
        cache, stale_idx, needed, new_feats, energy, n_stale
    )
    feats = temporal_mod.held_features(cache, idx, cfg.patch.summer)  # (..., k, M)
    feats = feats * valid[..., None].astype(feats.dtype)
    return CompactFeatures(feats, idx, valid, energy), cache


def compact_features(
    feats: jnp.ndarray, mask: jnp.ndarray, cfg: FrontendConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bandwidth-true view of already-computed dense features: gather the
    active patches. Prefer ``apply_frontend(..., mode="compact")``, which
    avoids computing the deselected patches in the first place."""
    return sal_mod.compact_active(feats, mask, cfg.n_active)
