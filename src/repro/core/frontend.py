"""IP2Frontend — the full sensor-to-features path (paper Fig. 1/2).

scene RGB -> lenslet/optics AA filter -> Bayer mosaic -> CDS sample
          -> salient patch selection (<=25 %) -> analog patch projection
          (PWM x switched-cap, M vectors/patch) -> edge ADC -> digital
          features + V_R - b subtraction.

Two selectable paths compute the projection:

* ``analog=True``  — the paper's circuit: Bayer single-channel patches,
  A' = strike_columns(A), PWM/DAC quantization, charge-share /N², droop,
  optional 2T nonlinearity, edge ADC. This is the hardware digital twin.
* ``analog=False`` — the float "algorithm simulation" the paper trains
  against: full-RGB patches through the unquantized matrix A.

Both paths are differentiable (STE through the quantizers), enabling the
accuracy/bits/active-fraction co-design studies of §1 and §2.1.3.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.core import adc as adc_mod
from repro.core import bayer as bayer_mod
from repro.core import projection as proj_mod
from repro.core import saliency as sal_mod


@dataclasses.dataclass(frozen=True)
class FrontendConfig:
    image_h: int = 256
    image_w: int = 256
    patch: proj_mod.PatchSpec = proj_mod.PatchSpec(patch_h=32, patch_w=32, n_vectors=400)
    analog: bool = True
    bayer: bool = True                 # raw mosaic input (HW); False = RGB (sim)
    aa_cutoff: float | None = 0.5      # Gaussian AA at 0.5/0.25 Nyquist; None = off
    active_fraction: float = 0.25
    adc: adc_mod.ADCSpec = adc_mod.ADCSpec()

    @property
    def grid(self) -> tuple[int, int]:
        return (self.image_h // self.patch.patch_h, self.image_w // self.patch.patch_w)

    @property
    def n_patches(self) -> int:
        gh, gw = self.grid
        return gh * gw

    @property
    def n_active(self) -> int:
        return max(1, int(round(self.n_patches * self.active_fraction)))


def init_frontend_params(key: jax.Array, cfg: FrontendConfig) -> dict:
    """A is always trained in vectorized-RGB space (M, N²·3); the analog path
    strikes columns to A' at apply time (paper §2.1.5).

    Full-scale matching (co-design): the charge-share sum divides by N², so
    the weight DAC full-scale current must be ~√N² larger than a classic
    1/√fan_in init or the OpAmp output sits below one ADC LSB and the edge
    ADC quantizes every feature to zero. σ_W = 0.4·√N² puts Out_v's std at
    ≈0.25 of the ±1 V rail (pixels ~U[0,1], A' keeps N² of the 3N² cols).
    """
    n2 = cfg.patch.pixels_per_patch
    m = cfg.patch.n_vectors
    scale = 0.4 * jnp.sqrt(jnp.asarray(n2, jnp.float32))
    a = jax.random.normal(key, (m, n2 * 3), jnp.float32) * scale
    return {"a_rgb": a, "bias": jnp.zeros((m,), jnp.float32)}


ProjectFn = Callable[[jnp.ndarray, jnp.ndarray, proj_mod.PatchSpec], jnp.ndarray]


def apply_frontend(
    params: dict,
    rgb: jnp.ndarray,
    cfg: FrontendConfig,
    mask: jnp.ndarray | None = None,
    project_fn: ProjectFn | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """rgb (..., H, W, 3) in [0,1] -> (features (..., P, M), mask (..., P)).

    ``mask`` is the backend's saccadic patch selection for this frame; if
    None, a patch-energy top-k stand-in is used. ``project_fn`` lets the
    Pallas kernel replace the reference einsum (same signature/semantics).
    """
    p = cfg.patch
    if cfg.aa_cutoff is not None:
        rgb = jnp.stack(
            [bayer_mod.antialias(rgb[..., c], cfg.aa_cutoff) for c in range(3)], axis=-1
        )

    if cfg.analog or cfg.bayer:
        frame = bayer_mod.mosaic(rgb)                                # (..., H, W)
        patches = proj_mod.extract_patches(frame, p.patch_h, p.patch_w)
        weights = bayer_mod.strike_columns(params["a_rgb"], p.patch_h, p.patch_w)
    else:
        # float simulation path: vectorized RGB patches
        per_c = [
            proj_mod.extract_patches(rgb[..., c], p.patch_h, p.patch_w) for c in range(3)
        ]
        patches = jnp.concatenate(per_c, axis=-1)                    # (..., P, N²·3)
        weights = params["a_rgb"]

    if mask is None:
        mask = sal_mod.topk_patch_mask(sal_mod.patch_energy(patches), cfg.active_fraction)

    if cfg.analog:
        fn = project_fn or proj_mod.analog_project_patches
        out_v = fn(patches, weights, p)                              # (..., P, M)
        feats = adc_mod.digital_readout(out_v, p.summer.v_ref, params["bias"], cfg.adc)
    else:
        n_in = patches.shape[-1]
        feats = jnp.einsum("...pi,vi->...pv", patches, weights) / n_in + params["bias"]

    return sal_mod.apply_patch_mask(feats, mask), mask


def compact_features(
    feats: jnp.ndarray, mask: jnp.ndarray, cfg: FrontendConfig
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Bandwidth-true output: only the ADC-converted (active) patches."""
    return sal_mod.compact_active(feats, mask, cfg.n_active)
