"""PWM / weight-DAC quantization models (paper §2.1).

The in-pixel multiply is realized by charging a capacitor with a
weight-programmed current for a pixel-programmed duration:

    Q = I(w) * t(P)   =>   Q ∝ w * P

Both factors are quantized by the circuit:

* ``t(P)`` — the pixel value is converted to a pulse width by a ramp
  comparator clocked at the PWM clock; the pulse width therefore takes one
  of ``2**pwm_bits`` discrete values ("time quantization").
* ``I(w)`` — the weight current is produced by a ``w_bits`` signed DAC
  (negative weights reverse the current polarity, §2.1 "Weighted sum").

The paper's simulations indicate ~6-bit effective in-pixel accuracy
(§2.1.3); both quantizers default to 6 bits.

All quantizers are exact (deterministic mid-rise uniform quantization) and
carry straight-through-estimator (STE) gradients so the frontend can be
trained end-to-end with the backend model — the co-design loop the paper
describes ("studying the reduction of output features as a function of
accuracy").
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

DEFAULT_PWM_BITS = 6
DEFAULT_WEIGHT_BITS = 6


@dataclasses.dataclass(frozen=True)
class QuantSpec:
    """Static description of the analog quantization in the pixel array."""

    pwm_bits: int = DEFAULT_PWM_BITS        # pixel -> pulse-width converter
    weight_bits: int = DEFAULT_WEIGHT_BITS  # weight current DAC (signed)
    ste: bool = True                        # straight-through gradients

    @property
    def pwm_levels(self) -> int:
        return 2 ** self.pwm_bits

    @property
    def weight_levels(self) -> int:
        # signed DAC: symmetric around zero, e.g. 6 bits -> [-31, 31]
        return 2 ** (self.weight_bits - 1) - 1


def _ste(exact: jnp.ndarray, quantized: jnp.ndarray, enable: bool) -> jnp.ndarray:
    """Straight-through estimator: forward=quantized, backward=identity."""
    if not enable:
        return quantized
    return exact + jax.lax.stop_gradient(quantized - exact)


def pwm_quantize(pixels: jnp.ndarray, spec: QuantSpec = QuantSpec()) -> jnp.ndarray:
    """Pixel intensity -> pulse width, quantized to the PWM clock grid.

    Pixels are normalized intensities in [0, 1] (the CDS output swing).
    Returns values on the grid k / (2**pwm_bits - 1), k integer.
    """
    n = spec.pwm_levels - 1
    clipped = jnp.clip(pixels, 0.0, 1.0)
    q = jnp.round(clipped * n) / n
    return _ste(clipped, q, spec.ste)


def pwm_codes(pixels: jnp.ndarray, spec: QuantSpec = QuantSpec()) -> jnp.ndarray:
    """Integer PWM codes (the counter values driving the pulse generator)."""
    n = spec.pwm_levels - 1
    return jnp.round(jnp.clip(pixels, 0.0, 1.0) * n).astype(jnp.int32)


def quantize_weights(
    weights: jnp.ndarray,
    spec: QuantSpec = QuantSpec(),
    per_output_scale: bool = True,
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Weight matrix -> signed DAC codes * analog scale.

    Mirrors the weight-current DAC: each output vector ("weight line" in
    Fig. 3a) has a programmable full-scale current, so the quantization
    scale is per output row by default.

    Args:
      weights: (..., n_out, n_in) float weights.
      per_output_scale: one DAC full-scale per output row (True) or one
        global full-scale (False).

    Returns:
      (w_q, scale): w_q = dequantized weights (float, on the DAC grid),
      scale with shape (..., n_out, 1) (or scalar) s.t.
      ``codes = round(weights / scale)`` are integers in [-L, L].
    """
    levels = spec.weight_levels
    if per_output_scale:
        amax = jnp.max(jnp.abs(weights), axis=-1, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(weights))
    scale = jnp.maximum(amax, 1e-12) / levels
    codes = jnp.clip(jnp.round(weights / scale), -levels, levels)
    w_q = codes * scale
    return _ste(weights, w_q, spec.ste), scale


def weight_codes(
    weights: jnp.ndarray, spec: QuantSpec = QuantSpec(), per_output_scale: bool = True
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Integer DAC codes + float scale (for the integer-domain kernel path)."""
    levels = spec.weight_levels
    if per_output_scale:
        amax = jnp.max(jnp.abs(weights), axis=-1, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(weights))
    scale = jnp.maximum(amax, 1e-12) / levels
    codes = jnp.clip(jnp.round(weights / scale), -levels, levels).astype(jnp.int8)
    return codes, scale


def analog_multiply(
    pixels: jnp.ndarray, weights: jnp.ndarray, spec: QuantSpec = QuantSpec()
) -> jnp.ndarray:
    """The per-pixel charge Q_i = I(w_i) * t(P_i), both factors quantized.

    This is the element-wise product *before* charge sharing; the summation
    happens in :mod:`repro.core.switched_cap`.
    """
    p_q = pwm_quantize(pixels, spec)
    w_q, _ = quantize_weights(weights, spec)
    return w_q * p_q
