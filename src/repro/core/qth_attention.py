"""Fig. 4 — analog self-attention with power-of-2 quantized coefficients.

The paper's extension circuit maps each neighbourhood's attention
coefficient through a quantizer-thresholder (QTH) onto a power-of-2 weight,
so the value multiply becomes a capacitor-ratio shift (binary-weighted cap
bank) instead of a full PWM multiply. Values live in a second layer of
patch-processing modules without photodiodes.

Digital twin: quantize post-softmax attention probabilities to
``2^round(log2 p)`` with an underflow threshold (QTH); optionally
renormalize so rows still sum to 1. STE gradients keep it trainable.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

NEG_INF = -1e30   # finite: an all-masked row softmaxes to uniform, not NaN


@dataclasses.dataclass(frozen=True)
class QTHSpec:
    min_exp: int = -8        # coefficients below 2^min_exp are dropped (threshold)
    renormalize: bool = True
    ste: bool = True


def pow2_quantize(p: jnp.ndarray, spec: QTHSpec = QTHSpec()) -> jnp.ndarray:
    """Probabilities (...,) in [0,1] -> nearest power of two, thresholded."""
    eps = 2.0 ** spec.min_exp
    safe = jnp.maximum(p, eps * 0.5)
    expo = jnp.round(jnp.log2(safe))
    q = jnp.where(p < eps, 0.0, jnp.exp2(expo))
    q = jnp.minimum(q, 1.0)
    if spec.ste:
        q = p + jax.lax.stop_gradient(q - p)
    return q


def qth_attention_weights(
    scores: jnp.ndarray,
    spec: QTHSpec = QTHSpec(),
    key_valid: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Softmax -> QTH pow-2 quantization -> optional renormalize.

    scores: (..., q, k) pre-softmax logits. ``key_valid`` (..., k) excludes
    keys entirely (powered-down patches on the dense path, filler slots on
    the compact path): their coefficient is exactly 0 — in circuit terms
    the value module simply has no stored charge to share.
    """
    if key_valid is not None:
        scores = jnp.where(key_valid[..., None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    q = pow2_quantize(p, spec)
    if spec.renormalize:
        denom = jnp.sum(q, axis=-1, keepdims=True)
        q = q / jnp.maximum(denom, 2.0 ** spec.min_exp)
    return q


def qth_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                  spec: QTHSpec = QTHSpec(),
                  key_valid: jnp.ndarray | None = None) -> jnp.ndarray:
    """Full QTH attention: (..., s, d) tensors, scaled dot product. The
    sequence axis can be the full patch grid (dense) or the gathered
    active-token set (compact) — the circuit sees only converted patches."""
    d = q.shape[-1]
    scores = jnp.einsum("...qd,...kd->...qk", q, k) / jnp.sqrt(jnp.asarray(d, q.dtype))
    w = qth_attention_weights(scores, spec, key_valid=key_valid).astype(v.dtype)
    return jnp.einsum("...qk,...kd->...qd", w, v)
