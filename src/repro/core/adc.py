"""Edge ADC model (paper §2.1).

Only the outputs of the selected salient patches (<25 %) are converted; the
ADC is at the array edge, one (or a few) per column group. The digital side
subtracts ``V_R - b`` to recover the signed projection plus the learned
bias b:

    digital_v = ADC(Out_v) - (V_R - b) = Σ(W·P)/N² + b   (up to quantization)
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ADCSpec:
    bits: int = 8
    v_min: float = -1.0
    v_max: float = 1.0
    ste: bool = True

    @property
    def levels(self) -> int:
        return 2 ** self.bits


def adc_quantize(v: jnp.ndarray, spec: ADCSpec = ADCSpec()) -> jnp.ndarray:
    """Uniform mid-rise ADC over [v_min, v_max] with STE gradients."""
    span = spec.v_max - spec.v_min
    lsb = span / (spec.levels - 1)
    clipped = jnp.clip(v, spec.v_min, spec.v_max)
    q = jnp.round((clipped - spec.v_min) / lsb) * lsb + spec.v_min
    if spec.ste:
        return clipped + jax.lax.stop_gradient(q - clipped)
    return q


def digital_readout(
    out_v: jnp.ndarray,
    v_ref: float,
    bias: jnp.ndarray | float = 0.0,
    spec: ADCSpec = ADCSpec(),
) -> jnp.ndarray:
    """ADC conversion followed by the digital ``V_R - b`` subtraction."""
    return adc_quantize(out_v, spec) - (v_ref - bias)
