"""Edge ADC model (paper §2.1) and the digital wire format (DESIGN.md §9).

Only the outputs of the selected salient patches (<25 %) are converted; the
ADC is at the array edge, one (or a few) per column group. What crosses the
imager boundary is the ADC *code* — an ``ADCSpec.bits``-wide integer — not
a float: the paper's 10x bandwidth / <30 mW/MP claims are claims about code
width. This module therefore defines two views of the same conversion:

* **Codes** (:func:`digital_codes`) — the canonical wire format: signed
  integer codes (int8 for bits <= 8) plus static ``(scale, zero)`` affine
  metadata derived from the :class:`ADCSpec` and the digital ``V_R - b``
  subtraction. ``dequantize(codes, scale, zero)`` recovers the readout.
* **Floats** (:func:`digital_readout`) — the training/simulation view,
  *defined as* ``dequantize(digital_codes(...))`` plus an STE residual, so
  the float path is bit-identical to dequantized codes by construction.

The digital side subtracts ``V_R - b`` to recover the signed projection
plus the learned bias b:

    digital_v = ADC(Out_v) - (V_R - b) = Σ(W·P)/N² + b   (up to quantization)

which in code space is the affine map ``digital_v = code * scale + zero``
with ``scale = lsb`` and ``zero = v_min + (levels//2)*lsb - V_R + b``.
"""

from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ADCSpec:
    bits: int = 8
    v_min: float = -1.0
    v_max: float = 1.0
    ste: bool = True

    @property
    def levels(self) -> int:
        return 2 ** self.bits

    @property
    def lsb(self) -> float:
        return (self.v_max - self.v_min) / (self.levels - 1)

    @property
    def code_dtype(self):
        """Smallest signed integer dtype that holds the (centered) codes."""
        if self.bits <= 8:
            return jnp.int8
        if self.bits <= 16:
            return jnp.int16
        return jnp.int32


class ADCCodes(NamedTuple):
    """One frame's conversions in wire format: integer codes plus the
    static affine metadata that dequantizes them. ``codes`` is the only
    O(k·M) payload; ``scale`` is a scalar and ``zero`` broadcasts with the
    per-vector bias, so the wire stays at code width."""

    codes: jnp.ndarray   # (..., M) signed integer codes (code_dtype)
    scale: jnp.ndarray   # () float32 — volts per LSB
    zero: jnp.ndarray    # (M,) or () float32 — v_min + half·lsb - (V_R - b)


def _code_grid(v: jnp.ndarray, spec: ADCSpec) -> jnp.ndarray:
    """Centered code values as float32 (shared by the jnp path and the
    Pallas kernel epilogues so the two quantize bit-identically)."""
    half = spec.levels // 2
    clipped = jnp.clip(v, spec.v_min, spec.v_max)
    return jnp.round((clipped - spec.v_min) / spec.lsb) - half


def encode(v: jnp.ndarray, spec: ADCSpec = ADCSpec()) -> jnp.ndarray:
    """Voltage -> signed integer code (no gradients: codes are integers;
    the STE lives in :func:`digital_readout`'s float view)."""
    return _code_grid(v, spec).astype(spec.code_dtype)


def readout_scale_zero(
    v_ref: float, bias: jnp.ndarray | float = 0.0, spec: ADCSpec = ADCSpec()
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """The (scale, zero) metadata of :func:`digital_codes` for a given
    reference/bias — static per (ADCSpec, V_R, b); recomputable anywhere
    without touching the payload."""
    half = spec.levels // 2
    scale = jnp.float32(spec.lsb)
    zero = jnp.float32(spec.v_min + half * spec.lsb - v_ref) + jnp.asarray(
        bias, jnp.float32
    )
    return scale, zero


def dequantize(
    codes: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray
) -> jnp.ndarray:
    """codes -> float readout: the ONE affine that is allowed to leave code
    space (DESIGN.md §9 permits it only at the backend's first matmul)."""
    return codes.astype(jnp.float32) * scale + zero


def digital_codes(
    out_v: jnp.ndarray,
    v_ref: float,
    bias: jnp.ndarray | float = 0.0,
    spec: ADCSpec = ADCSpec(),
) -> ADCCodes:
    """ADC conversion in wire format: codes + (scale, zero) such that
    ``dequantize(codes, scale, zero) == digital_readout(out_v, ...)``
    exactly (the float readout is defined as this dequant)."""
    scale, zero = readout_scale_zero(v_ref, bias, spec)
    return ADCCodes(encode(out_v, spec), scale, zero)


# ---------------------------------------------------------------------------
# ADC-less sign readout (DESIGN.md §13)
# ---------------------------------------------------------------------------
# A single comparator against V_R replaces the full conversion: the wire
# carries one BIT per vector (bool payload), and the readout is recovered
# through the SAME dequantize affine as the code wire — scale = 2·v_mag,
# zero = b - v_mag maps {0, 1} onto {-v_mag, +v_mag} + b, so the one
# dequant site (models.vit._embed_tokens) needs no new arithmetic.

#: representative reconstruction magnitude of a sign-only readout — matches
#: the event meter's mean-signal calibration (EnergyConstants.mean_signal_v)
SIGN_V_MAG = 0.1


def sign_scale_zero(
    bias: jnp.ndarray | float = 0.0, v_mag: float = SIGN_V_MAG
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(scale, zero) metadata of the sign wire: ``dequantize(bit, scale,
    zero) = ±v_mag + bias`` for bit in {0, 1}. Static per (bias, v_mag),
    recomputable anywhere — same contract as :func:`readout_scale_zero`."""
    scale = jnp.float32(2.0 * v_mag)
    zero = jnp.asarray(bias, jnp.float32) - jnp.float32(v_mag)
    return scale, zero


def sign_encode(out_v: jnp.ndarray, v_ref: float) -> jnp.ndarray:
    """The comparator: one bit per vector, ``out_v >= V_R``. No ramp, no
    SAR steps — the near-zero-energy readout the governor's ADC-less tier
    prices as ``sign_comparisons`` instead of ``adc_conversions``."""
    return out_v >= v_ref


def sign_code_points(
    v_ref: float, spec: ADCSpec = ADCSpec(), v_mag: float = SIGN_V_MAG
) -> tuple[int, int, int]:
    """The sign degradation expressed ON the int8 code grid — the engine's
    data-only ADC-less tier (DESIGN.md §13) maps an already-converted code
    wire onto two reconstruction points without changing dtype or shape:

        c' = c_pos if c >= c_thresh else c_neg

    ``c_thresh`` is the code of the comparator boundary ``out_v == V_R``;
    ``c_pos``/``c_neg`` dequantize (through the wire's own ``(scale,
    zero)``) to ±v_mag + bias. All three are bias-independent ints, static
    per (ADCSpec, V_R, v_mag) — pure data for a compiled engine step."""
    half = spec.levels // 2
    lo, hi = -half, spec.levels - 1 - half
    v_r = min(max(v_ref, spec.v_min), spec.v_max)
    c_thresh = round((v_r - spec.v_min) / spec.lsb) - half
    # code*lsb + (v_min + half*lsb - v_ref) = ±v_mag  (bias cancels)
    off = spec.v_min + half * spec.lsb - v_ref
    c_pos = min(max(round((v_mag - off) / spec.lsb), lo), hi)
    c_neg = min(max(round((-v_mag - off) / spec.lsb), lo), hi)
    return c_thresh, c_pos, c_neg


def adc_quantize(v: jnp.ndarray, spec: ADCSpec = ADCSpec()) -> jnp.ndarray:
    """Uniform mid-rise ADC over [v_min, v_max] with STE gradients —
    the voltage-grid view (quantize-then-hold, no V_R - b subtraction),
    expressed on the same code grid as :func:`encode`."""
    half = spec.levels // 2
    q = (_code_grid(v, spec) + half) * spec.lsb + spec.v_min
    if spec.ste:
        # exact-forward STE: lin - stop_grad(lin) is identically 0.0, so the
        # value is q bit-for-bit while the gradient is the clip passthrough
        lin = jnp.clip(v, spec.v_min, spec.v_max)
        return q + (lin - jax.lax.stop_gradient(lin))
    return q


def digital_readout(
    out_v: jnp.ndarray,
    v_ref: float,
    bias: jnp.ndarray | float = 0.0,
    spec: ADCSpec = ADCSpec(),
) -> jnp.ndarray:
    """ADC conversion followed by the digital ``V_R - b`` subtraction.

    Defined as ``dequantize(digital_codes(out_v, ...))`` so the float and
    code paths are bit-identical by construction; ``spec.ste`` adds the
    straight-through residual (gradient 1 w.r.t. ``out_v`` inside the
    rails, 1 w.r.t. ``bias``) for the co-design studies.
    """
    codes = digital_codes(out_v, v_ref, bias, spec)
    deq = dequantize(*codes)
    if spec.ste:
        # exact-forward STE (value is deq bit-for-bit — the wire contract
        # dequantize(digital_codes(v)) == digital_readout(v) is exact):
        # lin - stop_grad(lin) contributes 0.0 to the value and the
        # straight-through gradient (clip passthrough w.r.t. out_v; the
        # bias gradient arrives through ``zero`` inside deq).
        lin = jnp.clip(out_v, spec.v_min, spec.v_max)
        return deq + (lin - jax.lax.stop_gradient(lin))
    return deq
