"""Switched-capacitor charge-sharing summation + leakage model (paper §2.1.2).

Physics being modelled
----------------------

*Charge sharing.* Each of the N² pixels in a patch holds a charge
``Q_i = C * V_i`` on an identical capacitor ``C``. Closing the summing
switches connects all caps to a common node; charge is conserved, total
capacitance is ``N²·C``, so the node settles at

    V_out = Σ Q_i / (N² C) = Σ V_i / N²

— the weighted sum *divided by the patch size* (the paper's
``Out_v = V_R + Σ (W·P)/N²``). The 1/N² factor is physical, not a design
choice, and is kept exact in every code path.

*Leakage.* Thin-oxide MOSFET switches leak; the paper's 65 nm simulation of
768 caps at 1 V summed with 768 caps at 0 V (expected 0.5 V) shows the
*passive* summer drooping by ~10 % in under 10 µs. We model droop as a
first-order RC discharge per capacitor,

    V(t) = V0 * exp(-t / tau_leak)

and calibrate ``tau_leak`` for 65 nm so that a 10 µs hold loses exactly 10 %
(tau = -10e-6 / ln(0.9) ≈ 94.9 µs). A 22 nm FDSOI corner with ~100x lower
leakage is provided as well (paper: "amplifiers can be removed when using a
lower leakage technology").

*OpAmp compensation.* Summing into the feedback cap of an amplifier pins
the summing node at virtual ground, so switch leakage is sourced by the
amplifier output instead of the signal charge: droop is suppressed to the
amplifier's residual error (finite gain A0 -> gain error 1/(1+A0·β)).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp

# --- leakage corners ------------------------------------------------------

# Calibrated so the passive summer loses 10% in 10 microseconds (paper datum).
TAU_LEAK_65NM_S = -10e-6 / math.log(0.9)  # ≈ 94.91 µs
# 22 nm FDSOI thick-ox switches: ~two decades lower leakage.
TAU_LEAK_22NM_FDX_S = TAU_LEAK_65NM_S * 100.0


@dataclasses.dataclass(frozen=True)
class SummerSpec:
    """Static config of the per-patch summing circuit."""

    mode: str = "opamp"            # "opamp" | "passive"
    tau_leak_s: float = TAU_LEAK_65NM_S
    hold_time_s: float = 10e-6     # time from switch close to ADC sample
    opamp_dc_gain: float = 10_000.0  # A0, 80 dB typical for a small OTA
    v_ref: float = 0.0             # V_R bias added at the amplifier

    def droop_factor(self) -> float:
        """Multiplicative signal retention after hold_time."""
        if self.mode == "passive":
            return math.exp(-self.hold_time_s / self.tau_leak_s)
        # OpAmp virtual ground: leakage is replenished; only the closed-loop
        # gain error remains (beta = 1 for the unity-feedback charge summer).
        return self.opamp_dc_gain / (1.0 + self.opamp_dc_gain)


def charge_share_sum(
    charges: jnp.ndarray,
    spec: SummerSpec = SummerSpec(),
    axis: int = -1,
) -> jnp.ndarray:
    """Charge-conserving summation onto the patch node.

    Args:
      charges: per-capacitor voltages ``W_i * P_i`` (any leading batch dims).
      axis: axis enumerating the N² capacitors of one patch.

    Returns:
      ``V_R + droop * mean(charges, axis)`` — the OpAmp output the ADC sees.
    """
    mean = jnp.mean(charges, axis=axis)
    return spec.v_ref + spec.droop_factor() * mean


def passive_droop_trace(
    v0: jnp.ndarray, times_s: jnp.ndarray, tau_leak_s: float = TAU_LEAK_65NM_S
) -> jnp.ndarray:
    """V(t) of a passive summing node (for the §2.1.2 reproduction bench)."""
    return v0 * jnp.exp(-times_s[..., :] / tau_leak_s)


def capacitor_divider(v: jnp.ndarray, n_extra_caps: int) -> jnp.ndarray:
    """Quantized division (paper §2.1 'Quantized division').

    Charging one cap to V then switching ``n_extra_caps`` discharged caps in
    parallel divides the voltage by (1 + n_extra_caps) — charge conservation
    over the enlarged capacitance. Divisors are therefore integers.
    """
    return v / (1.0 + float(n_extra_caps))


def series_add(v_a: jnp.ndarray, v_b: jnp.ndarray, subtract: bool = False) -> jnp.ndarray:
    """Weighted-sum add/subtract of two cap voltages (series connection).

    Subtraction reverses the polarity of the second capacitor before the
    series connection (paper §2.1 'Weighted sum').
    """
    return v_a - v_b if subtract else v_a + v_b
