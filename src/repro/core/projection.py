"""Patch-based analog linear projection (paper §2.1, §2.1.1).

The array computes, for every (non-overlapping) N×N patch and every output
vector element v = 1..M:

    Out_v = V_R + Σ_{i=1..N²} (W_{i,v} · P_i) / N²

M passes of the PWM/charge-share sequence produce an M-dim analog vector
per patch ("This analog vector computation is performed M times").

Programmable patch size (§2.1.1): the silicon has one OpAmp per 8×8 tile;
larger patches (16/24/32 per axis) gang multiple 8×8 tiles onto one summing
amplifier. We implement patches as compositions of BASE=8 tiles, so any
(8a)×(8b) patch with a,b ∈ {1,2,3,4} is expressible — e.g. 8×32, 24×16.

This module is the *reference* (pure-jnp) implementation; the Pallas TPU
kernel in :mod:`repro.kernels.ip2_project` computes the same function with
MXU-aligned tiling and is validated against this path.
"""

from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core import pwm as pwm_mod
from repro.core import switched_cap as sc
from repro.core.analog_nl import AnalogNLSpec, analog_nonlinearity

BASE_TILE = 8  # minimum patch size / OpAmp granularity (paper §2.1.1)


@dataclasses.dataclass(frozen=True)
class PatchSpec:
    """Geometry of the analog projection array."""

    patch_h: int = 32
    patch_w: int = 32
    n_vectors: int = 400          # M output vector elements per patch
    quant: pwm_mod.QuantSpec = pwm_mod.QuantSpec()
    summer: sc.SummerSpec = sc.SummerSpec()
    nl: AnalogNLSpec = AnalogNLSpec(kind="none")

    def __post_init__(self):
        for d, name in ((self.patch_h, "patch_h"), (self.patch_w, "patch_w")):
            if d % BASE_TILE != 0 or not (BASE_TILE <= d <= 4 * BASE_TILE):
                raise ValueError(
                    f"{name}={d}: patches are ganged 8x8 tiles, sizes 8/16/24/32"
                )

    @property
    def pixels_per_patch(self) -> int:
        return self.patch_h * self.patch_w


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    """Geometry of the conv-in-pixel mode (DESIGN.md §13).

    The same ganged-8×8-tile fabric as :class:`PatchSpec`, reprogrammed:
    the DAC weight bank holds a K×K kernel per output channel, and the
    patch selector walks the frame with ``stride`` instead of tiling it —
    overlapping windows are separate charge-share cycles over the same
    (non-destructively read) pixels. K inherits the OpAmp ganging
    constraint (8/16/24/32 per axis); the stride is free."""

    kernel: int = 8               # K — ganged 8x8 tiles, like patch dims
    stride: int = 8               # window step in pixels (< K overlaps)
    n_channels: int = 16          # output channels (the conv "M")
    quant: pwm_mod.QuantSpec = pwm_mod.QuantSpec()
    summer: sc.SummerSpec = sc.SummerSpec()
    nl: AnalogNLSpec = AnalogNLSpec(kind="none")

    def __post_init__(self):
        if self.stride < 1:
            raise ValueError(f"stride={self.stride}: must be >= 1")
        # kernel geometry is validated by the PatchSpec view below
        self.patch_spec()

    def patch_spec(self) -> PatchSpec:
        """The projection-array view of one conv window: a K×K 'patch'
        with ``n_channels`` output vectors — the kernel wrappers and the
        event meter consume conv through this view."""
        return PatchSpec(
            patch_h=self.kernel, patch_w=self.kernel,
            n_vectors=self.n_channels, quant=self.quant,
            summer=self.summer, nl=self.nl,
        )

    def out_grid(self, h: int, w: int) -> tuple[int, int]:
        if (h - self.kernel) % self.stride or (w - self.kernel) % self.stride:
            raise ValueError(
                f"frame {h}x{w} not covered by K={self.kernel} "
                f"stride={self.stride} windows"
            )
        return ((h - self.kernel) // self.stride + 1,
                (w - self.kernel) // self.stride + 1)


def extract_patches(frame: jnp.ndarray, patch_h: int, patch_w: int) -> jnp.ndarray:
    """(H, W) or (B, H, W) frame -> (..., n_patches, patch_h*patch_w).

    Non-overlapping tiling (the circuit supports a 4-pixel offset per
    vector; offsets are applied by the caller shifting the frame).
    """
    batched = frame.ndim == 3
    if not batched:
        frame = frame[None]
    b, h, w = frame.shape
    if h % patch_h or w % patch_w:
        raise ValueError(f"frame {h}x{w} not divisible by patch {patch_h}x{patch_w}")
    gh, gw = h // patch_h, w // patch_w
    x = frame.reshape(b, gh, patch_h, gw, patch_w)
    x = x.transpose(0, 1, 3, 2, 4).reshape(b, gh * gw, patch_h * patch_w)
    return x if batched else x[0]


def extract_windows(frame: jnp.ndarray, kernel: int, stride: int) -> jnp.ndarray:
    """(H, W) or (B, H, W) frame -> (..., n_windows, kernel²) strided im2col.

    The conv-in-pixel selector: every K×K window at ``stride`` steps, in
    row-major window order with row-major pixels inside each window — the
    same pixel layout as :func:`extract_patches`, so
    ``extract_windows(f, k, k) == extract_patches(f, k, k)`` exactly
    (non-overlapping conv IS the patch tiling)."""
    batched = frame.ndim == 3
    if not batched:
        frame = frame[None]
    b, h, w = frame.shape
    if (h - kernel) % stride or (w - kernel) % stride:
        raise ValueError(
            f"frame {h}x{w} not covered by K={kernel} stride={stride} windows"
        )
    gh = (h - kernel) // stride + 1
    gw = (w - kernel) // stride + 1
    rows = (jnp.arange(gh) * stride)[:, None] + jnp.arange(kernel)[None, :]
    cols = (jnp.arange(gw) * stride)[:, None] + jnp.arange(kernel)[None, :]
    # (b, gh, kernel, w) -> (b, gh, kernel, gw, kernel)
    x = frame[:, rows, :][:, :, :, cols]
    x = x.transpose(0, 1, 3, 2, 4).reshape(b, gh * gw, kernel * kernel)
    return x if batched else x[0]


def analog_project_patches(
    patches: jnp.ndarray,
    weights: jnp.ndarray,
    spec: PatchSpec,
) -> jnp.ndarray:
    """The analog MVM over already-extracted patches.

    Args:
      patches: (..., n_patches, N²) CDS pixel voltages in [0, 1].
      weights: (M, N²) float weights (the programmed DAC currents).

    Returns:
      (..., n_patches, M) analog patch features =
      V_R + droop * (W_q @ P_q) / N², through the optional 2T nonlinearity.
    """
    n2 = patches.shape[-1]
    if weights.shape != (spec.n_vectors, n2):
        raise ValueError(f"weights {weights.shape} != ({spec.n_vectors}, {n2})")
    p_q = pwm_mod.pwm_quantize(patches, spec.quant)
    w_q, _ = pwm_mod.quantize_weights(weights, spec.quant)
    # charge on each cap is w*p; charge sharing divides by N² (exact physics)
    acc = jnp.einsum("...pi,vi->...pv", p_q, w_q) / n2
    out = spec.summer.v_ref + spec.summer.droop_factor() * acc
    return analog_nonlinearity(out, spec.nl)


def analog_project_frame(
    frame: jnp.ndarray, weights: jnp.ndarray, spec: PatchSpec
) -> jnp.ndarray:
    """Frame -> per-patch analog feature vectors (reference path)."""
    patches = extract_patches(frame, spec.patch_h, spec.patch_w)
    return analog_project_patches(patches, weights, spec)


def grid_shape(h: int, w: int, spec: PatchSpec) -> tuple[int, int]:
    return h // spec.patch_h, w // spec.patch_w
