"""2-transistor current-mode nonlinearity (paper §2.1 'ReLU activation').

The circuit: a voltage-controlled current source (2T) drives the
drain-source voltage of a FET biased in its linear region. Depending on the
bias point the transfer curve is a rectifier (ReLU) or an S-curve (sigmoid).

We model the transfer as an ideal nonlinearity with a supply-rail
saturation: the output cannot exceed the rail swing ``v_sat``. The
saturation is the physically-honest part — an analog ReLU clips.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AnalogNLSpec:
    kind: str = "relu"     # "relu" | "sigmoid" | "none"
    v_sat: float = 1.0     # output rail (normalized full scale)
    sigmoid_gain: float = 4.0  # transconductance slope at the bias point


def analog_nonlinearity(v: jnp.ndarray, spec: AnalogNLSpec = AnalogNLSpec()) -> jnp.ndarray:
    if spec.kind == "none":
        return jnp.clip(v, -spec.v_sat, spec.v_sat)
    if spec.kind == "relu":
        return jnp.clip(v, 0.0, spec.v_sat)
    if spec.kind == "sigmoid":
        # jax.nn.sigmoid is the log-sum-exp-stable form: the naive
        # v_sat / (1 + exp(-gain·v)) overflows the exp intermediate to inf
        # once gain·|v| >= ~89 in f32, which NaNs the STE gradients of the
        # differentiable frontend even though the forward value saturates.
        return jax.nn.sigmoid(spec.sigmoid_gain * v) * spec.v_sat
    raise ValueError(f"unknown analog nonlinearity {spec.kind!r}")
