"""Salient patch selection / partial observation (paper §1, §2.1).

Only the outputs of a selected set of salient patches (e.g. <25 %) are
converted to the digital domain. The selection comes from the backend
model's saccadic prediction of the previous frame ("shifted attention");
deselected patches drain their photodiodes and power down, so they cost
neither ADC conversions nor bandwidth.

The primary representation is **index-first** (DESIGN.md §3): a static-size
list of exactly-k active patch indices, which drives the gather *before*
the analog projection so compute scales with the active fraction. Boolean
masks remain as a derived view for the dense (training / co-design) path:

* ``topk_patch_indices`` — exactly-k selector with deterministic
  tie-breaking (equal scores -> lowest patch index wins);
* ``topk_patch_mask`` — boolean view of the same selection (always exactly
  k true entries, even with tied scores);
* ``indices_from_mask`` / ``mask_from_indices`` — conversions between the
  two views, static shapes for jit;
* ``gather_patches`` — the select->gather step: pick the active rows of a
  (..., P, N) array ahead of projection;
* ``apply_patch_mask`` — zero deselected patch features (dense path);
* ``compact_active`` — gather of only the active patch features, the
  bandwidth-true representation streamed off-sensor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_patch_indices(scores: jnp.ndarray, k: int) -> jnp.ndarray:
    """Exactly-k most-salient patch indices, deterministically tie-broken.

    ``jax.lax.top_k`` guarantees that among equal scores the lower-index
    element appears first; we lean on that contract so the selection is a
    pure function of the scores (a ``scores >= thresh`` mask is not: every
    patch tied at the threshold gets selected, breaking exactly-k).

    Args:
      scores: (..., n_patches) saliency scores (patch energy or the
        backend's attention rollout).
      k: number of patches to keep (static).

    Returns:
      (..., k) int32 indices, sorted by descending score (ties: ascending
      patch index).
    """
    n = scores.shape[-1]
    if not 1 <= k <= n:
        raise ValueError(f"k={k} out of range for {n} patches")
    _, idx = jax.lax.top_k(scores, k)
    return idx.astype(jnp.int32)


def mask_from_indices(indices: jnp.ndarray, n_patches: int) -> jnp.ndarray:
    """(..., k) indices -> (..., n_patches) boolean mask."""
    one_hot = jax.nn.one_hot(indices, n_patches, dtype=jnp.bool_)
    return jnp.any(one_hot, axis=-2)


def indices_from_mask(mask: jnp.ndarray, k: int) -> tuple[jnp.ndarray, jnp.ndarray]:
    """(..., P) boolean mask -> ((..., k) indices, (..., k) valid).

    Static shape for jit: if fewer than k patches are active the tail
    repeats inactive slots (marked ``valid=False``); if more are active the
    lowest k indices win. Active indices come out in ascending order.
    """
    idx = jnp.argsort(~mask, axis=-1, stable=True)[..., :k].astype(jnp.int32)
    valid = jnp.take_along_axis(mask, idx, axis=-1)
    return idx, valid


def topk_patch_mask(scores: jnp.ndarray, active_fraction: float) -> jnp.ndarray:
    """Boolean mask keeping exactly the top ``active_fraction`` of patches.

    Built on the index-first selector, so tied scores can never over-select
    (a plain ``scores >= thresh`` comparison selects *every* patch at the
    threshold value, breaking the exactly-k contract of the compact path).
    """
    n = scores.shape[-1]
    k = max(1, int(round(n * active_fraction)))
    return mask_from_indices(topk_patch_indices(scores, k), n)


def patch_energy(patches: jnp.ndarray) -> jnp.ndarray:
    """Simple saliency proxy: AC energy of each patch (..., P, N²) -> (..., P)."""
    centered = patches - jnp.mean(patches, axis=-1, keepdims=True)
    return jnp.mean(centered * centered, axis=-1)


def apply_patch_mask(features: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Zero out deselected patches: (..., P, M) * (..., P, 1)."""
    return features * mask[..., None].astype(features.dtype)


def gather_patches(patches: jnp.ndarray, indices: jnp.ndarray) -> jnp.ndarray:
    """Select->gather: (..., P, N) rows at (..., k) indices -> (..., k, N).

    Differentiable (scatter-add transpose), so the STE co-design gradients
    flow through the compact path into the frontend weights.
    """
    return jnp.take_along_axis(patches, indices[..., None], axis=-2)


def compact_active(
    features: jnp.ndarray, mask: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gather exactly-k active patch features (static shape for jit).

    Returns (compact_features (..., k, M), indices (..., k)). If fewer than
    k patches are active the tail repeats inactive patches (masked
    downstream); if more, the lowest-index k win (mask should be top-k).
    """
    idx, _ = indices_from_mask(mask, k)
    return gather_patches(features, idx), idx


def active_fraction(mask: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(mask.astype(jnp.float32), axis=-1)
