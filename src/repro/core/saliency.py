"""Salient patch selection / partial observation (paper §1, §2.1).

Only the outputs of a selected set of salient patches (e.g. <25 %) are
converted to the digital domain. The selection mask comes from the backend
model's saccadic prediction of the previous frame ("shifted attention");
deselected patches drain their photodiodes and power down, so they cost
neither ADC conversions nor bandwidth.

The framework treats the mask as an input (produced by the backend); this
module provides:

* ``topk_patch_mask`` — an energy/attention-score top-k selector used by the
  examples and benches as a stand-in for the backend's saccade prediction;
* ``apply_patch_mask`` — zeroes deselected patch features (what the digital
  side receives) and reports the active fraction (drives the power model);
* ``compact_active`` — gather of only the active patch features, the
  bandwidth-true representation streamed off-sensor.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def topk_patch_mask(scores: jnp.ndarray, active_fraction: float) -> jnp.ndarray:
    """Boolean mask over patches keeping the top ``active_fraction``.

    Args:
      scores: (..., n_patches) saliency scores (e.g. patch energy or the
        backend's attention rollout).
    """
    n = scores.shape[-1]
    k = max(1, int(round(n * active_fraction)))
    thresh = jax.lax.top_k(scores, k)[0][..., -1:]
    return scores >= thresh


def patch_energy(patches: jnp.ndarray) -> jnp.ndarray:
    """Simple saliency proxy: AC energy of each patch (..., P, N²) -> (..., P)."""
    centered = patches - jnp.mean(patches, axis=-1, keepdims=True)
    return jnp.mean(centered * centered, axis=-1)


def apply_patch_mask(features: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Zero out deselected patches: (..., P, M) * (..., P, 1)."""
    return features * mask[..., None].astype(features.dtype)


def compact_active(
    features: jnp.ndarray, mask: jnp.ndarray, k: int
) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Gather exactly-k active patch features (static shape for jit).

    Returns (compact_features (..., k, M), indices (..., k)). If fewer than
    k patches are active the tail repeats the last active patch (masked
    downstream); if more, the highest-score k win (mask should be top-k).
    """
    idx = jnp.argsort(~mask, axis=-1, stable=True)[..., :k]
    taken = jnp.take_along_axis(features, idx[..., None], axis=-2)
    return taken, idx


def active_fraction(mask: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(mask.astype(jnp.float32), axis=-1)
