"""Fig. 3 processing-rate model (paper §2.1.4).

The weights for one output vector are broadcast to all patches over C
weight-voltage lines per pixel column (C ∈ {1,2,4,8}); a patch with
``patch_rows`` rows therefore needs ``ceil(patch_rows / C)`` weight-load
cycles per vector, followed by one PWM compute window:

    t_vector = t_load · ceil(patch_rows / C) + t_pwm
    t_frame  = M · t_vector          (all patches compute in parallel)
    rate     = sensor_pixels / t_frame   [pix/s]

Constants are calibrated jointly with §2.1.2: the PWM window equals the
10 µs summing/hold window, and t_load = 1.1 µs reproduces the paper's
operating point — 1080p, C=2, 400 vectors per 32×32 patch -> ~90 Hz — and
8×8 patches at 192 vectors/patch -> well above 30 Hz.
"""

from __future__ import annotations

import dataclasses
import math

T_LOAD_S = 1.1e-6   # weight-line DAC settle per row-group
T_PWM_S = 10.0e-6   # PWM charging + charge-share window (= §2.1.2 hold time)

SENSOR_FORMATS = {
    "720p": (1280, 720),
    "1080p": (1920, 1080),
}


@dataclasses.dataclass(frozen=True)
class RatePoint:
    fmt: str
    c_lines: int
    patch: int
    n_vectors: int
    frame_hz: float
    mpix_per_s: float
    t_vector_s: float


def vector_time(patch_rows: int, c_lines: int,
                t_load: float = T_LOAD_S, t_pwm: float = T_PWM_S) -> float:
    return t_load * math.ceil(patch_rows / c_lines) + t_pwm


def frame_rate(patch: int, n_vectors: int, c_lines: int) -> float:
    return 1.0 / (n_vectors * vector_time(patch, c_lines))


def rate_point(fmt: str, c_lines: int, patch: int, n_vectors: int) -> RatePoint:
    w, h = SENSOR_FORMATS[fmt]
    tv = vector_time(patch, c_lines)
    hz = 1.0 / (n_vectors * tv)
    return RatePoint(fmt, c_lines, patch, n_vectors, hz, w * h * hz / 1e6, tv)


def figure3_sweep() -> list[RatePoint]:
    """The Fig. 3b grid: 720p/1080p × 400/768 vectors per 32×32 × C∈{1,2,4,8}."""
    out = []
    for fmt in ("720p", "1080p"):
        for nv in (400, 768):
            for c in (1, 2, 4, 8):
                out.append(rate_point(fmt, c, 32, nv))
    return out
