"""Temporal delta-gated execution: reuse held charge across frames
(paper §2.1.2 non-destructive readout; DESIGN.md §6).

The switched-cap readout is *non-destructive*: the projection of a patch
is charge held on the summing caps, so a patch whose content has not
changed between frames does not need re-projection or re-conversion —
only droop-limited refresh. This module adds that temporal layer on top
of the spatial gating (select -> gather -> project only the ~25 % active
patches): of the k selected patches, only the *stale* ones are
recomputed; the rest are served from a per-patch :class:`FeatureCache`
that models the held (and slowly drooping) charge.

Three pieces:

* **Change detection** — a patch is *stale* when the in-pixel CDS
  energy proxy has moved by at least ``delta_threshold`` since the
  patch was last recomputed (the photodiodes integrate light regardless
  of selection, so this signal is free), when it has never been
  computed, or when its held charge has drooped past the LSB budget.
  ``delta_threshold = 0`` makes every selected patch stale — the gate
  degenerates to PR 2's always-recompute compact path bit for bit.

* **Static-shape stale set** — :func:`select_stale` returns *exactly j*
  patch indices to recompute (``recompute_budget``; default j = k), so
  every downstream shape is static and jit-stable. Genuinely stale
  patches rank first, ordered by hold age plus normalized energy delta
  — age guarantees that overflow staleness deferred past the budget
  makes progress every frame (no starvation), delta breaks same-age
  ties toward the biggest content change. When fewer than j are stale,
  the spare slots idle: they re-project an already-fresh patch but
  their output is never converted or merged (``needed=False``), so ADC
  count and streamed bytes track the true stale count. When more than
  j are stale the overflow keeps serving held charge and remains stale
  — its reference energy is only updated at recompute — so it wins a
  slot within at most ceil(k/j) frames.

* **Droop-aware cache** — :class:`FeatureCache` holds the last computed
  feature of every patch. A held entry ages one hold per frame; its
  served value is ``value * SummerSpec.droop_factor() ** age`` (the
  retention of the summing node, folded in lazily at serve time by
  :func:`held_features`). :meth:`TemporalSpec.max_hold_frames` converts
  the ``droop_lsb_budget`` into the largest hold count whose worst-case
  accumulated droop stays under that many ADC LSBs; older entries are
  forced stale regardless of the energy delta.

The cache stores the WIRE FORMAT (int8 ADC codes, DESIGN.md §9) by
default — 4x smaller held state, aged integer-safely at serve time via
:func:`held_gain` on the dequantized value. The float-wire variant
(``init_feature_cache(..., dtype=jnp.float32)``) keeps the gather/scatter
chain differentiable end to end (the projection keeps its STE
quantizers) for co-design diagnostics; integer codes carry no gradients.
Either way dense *training* must bypass the cache — gradients through a
frame-t feature would otherwise flow into frame t-1's parameters (see
DESIGN.md §6 for the contract).
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import adc as adc_mod
from repro.core import switched_cap as sc


class FeatureCache(NamedTuple):
    """Held per-patch features over the FULL grid (the summing caps exist
    for every patch; only *recomputation* is gated).

    ``features`` is stored in the WIRE FORMAT (DESIGN.md §9): int8 ADC
    codes by default — the digital side can only ever have cached what
    crossed the imager boundary, and that is codes, so the held state is
    4x smaller than a float32 cache. The ``(scale, zero)`` metadata needed
    to dequantize is static (ADCSpec + V_R + bias) and is NOT stored per
    entry; the one permitted dequant site supplies it. A float32 cache
    (``init_feature_cache(..., dtype=jnp.float32)``) remains available
    for the differentiable float-wire path (co-design diagnostics).

    Droop is applied *lazily* and integer-safely: ``features`` stores the
    code as converted (the charge at refresh time, never mutated by
    aging — no cumulative integer rounding) and the serve-time epilogue
    multiplies the *dequantized* value by ``droop_factor ** age`` — an
    O(k·M) epilogue on the gathered selection instead of an O(P·M) decay
    pass over the whole cache every frame (which would cost as much as
    the projection the gate is there to avoid).

    Leading dims are arbitrary batch/slot dims, matching the frames fed
    through the frontend.
    """

    features: jnp.ndarray   # (..., P, M) int8 ADC codes (or f32, float wire)
    energy: jnp.ndarray     # (..., P) f32 — CDS energy at last recompute (delta reference)
    age: jnp.ndarray        # (..., P) int32 — frames since last recompute
    valid: jnp.ndarray      # (..., P) bool — entry has ever been computed
    n_stale: jnp.ndarray    # (...,) int32 — genuinely stale patches recomputed last frame


@dataclasses.dataclass(frozen=True)
class TemporalSpec:
    """Static config of the temporal gate.

    delta_threshold: a selected patch is stale when
      ``|energy_now - energy_at_last_recompute| >= delta_threshold``.
      0.0 (default) marks everything stale — gating off, bitwise equal to
      the ungated compact path.
    recompute_budget: j — the static number of patch slots projected per
      frame. None (default) means j = k (can always recompute the whole
      active set). Smaller j caps per-frame analog compute / ADC
      conversions; overflow staleness is served from held charge and
      refreshed on later frames.
    droop_lsb_budget: forced-refresh budget. A held entry may droop by at
      most this many ADC LSBs (worst case, full-scale signal) before it
      is forced stale.
    """

    delta_threshold: float = 0.0
    recompute_budget: int | None = None
    droop_lsb_budget: float = 0.5

    def budget(self, k: int) -> int:
        j = k if self.recompute_budget is None else self.recompute_budget
        if j < 1:
            raise ValueError(f"recompute_budget must be >= 1, got {j}")
        return min(j, k)

    def max_hold_frames(
        self, summer: sc.SummerSpec, adc: adc_mod.ADCSpec
    ) -> int:
        """Largest number of frame holds whose accumulated droop stays
        within ``droop_lsb_budget`` LSBs, checked in the cache's own
        units (LSB counts — the cache stores ADC codes, DESIGN.md §9): a
        worst-case held entry sits at ``code_fs = v_fs / lsb`` LSBs of
        full scale and retains d^h after h holds, so the served error is
        ``code_fs * (1 - d^h) <= droop_lsb_budget`` LSBs. 0 means even
        one hold violates the budget — every entry is stale every frame
        (``age >= 0`` always holds) and nothing is ever served held.
        """
        d = summer.droop_factor()
        code_fs = max(abs(adc.v_min), abs(adc.v_max)) / adc.lsb
        tol = self.droop_lsb_budget / code_fs
        if d >= 1.0 or tol >= 1.0:
            return 2**31 - 2            # no droop (ideal summer): hold forever
        if tol <= 0.0:
            return 0                    # zero budget: refresh every frame
        return int(math.floor(math.log(1.0 - tol) / math.log(d)))


def init_feature_cache(
    cfg, batch_shape: tuple[int, ...] = (), dtype=None
) -> FeatureCache:
    """Empty (all-invalid) cache for ``cfg`` (anything with ``n_patches``,
    ``patch.n_vectors`` and ``adc`` — a FrontendConfig) over
    ``batch_shape`` leading dims. ``dtype`` defaults to the ADC code
    dtype (the wire format); pass ``jnp.float32`` only for the
    differentiable float-wire path."""
    p = cfg.n_patches
    m = cfg.patch.n_vectors
    if dtype is None:
        dtype = cfg.adc.code_dtype
    return FeatureCache(
        features=jnp.zeros((*batch_shape, p, m), dtype),
        energy=jnp.zeros((*batch_shape, p), jnp.float32),
        age=jnp.zeros((*batch_shape, p), jnp.int32),
        valid=jnp.zeros((*batch_shape, p), bool),
        n_stale=jnp.zeros(batch_shape, jnp.int32),
    )


def take_rows(arr: jnp.ndarray, idx: jnp.ndarray) -> jnp.ndarray:
    """Batched row gather: arr (..., P[, M]) at idx (..., k)."""
    if arr.ndim == idx.ndim:                      # (..., P)
        return jnp.take_along_axis(arr, idx, axis=-1)
    return jnp.take_along_axis(arr, idx[..., None], axis=-2)


_take = take_rows


def _scatter_rows(dst: jnp.ndarray, idx: jnp.ndarray, src: jnp.ndarray) -> jnp.ndarray:
    """Batched row scatter (set): dst (..., P[, M]) rows at idx (..., j)
    replaced by src. Differentiable; duplicate indices are benign here
    because duplicates always carry identical values (same patch,
    same frame)."""
    lead = idx.shape[:-1]
    j = idx.shape[-1]
    if not lead:
        return dst.at[idx].set(src)
    b = math.prod(lead)
    flat_dst = dst.reshape((b,) + dst.shape[len(lead):])
    flat_idx = idx.reshape(b, j)
    flat_src = src.reshape((b,) + src.shape[len(lead):])
    rows = jnp.arange(b)[:, None]
    out = flat_dst.at[rows, flat_idx].set(flat_src)
    return out.reshape(dst.shape)


def select_stale(
    energy: jnp.ndarray,
    indices: jnp.ndarray,
    cache: FeatureCache,
    spec: TemporalSpec,
    summer: sc.SummerSpec,
    adc: adc_mod.ADCSpec,
    sel_valid: jnp.ndarray | None = None,
    cap: jnp.ndarray | None = None,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """The gate: which of this frame's k selected patches to recompute.

    Args:
      energy: (..., P) this frame's in-pixel patch-energy proxy.
      indices: (..., k) the saccade selection (exactly-k patch indices).
      cache: held state from the previous frame.
      spec / summer / adc: static gate + droop configuration.
      sel_valid: optional (..., k) bool — False marks selection slots
        that will not be served (filler slots, or tokens shed by the
        power governor's k-tier, DESIGN.md §10); they never claim a
        recompute slot or an ADC conversion. All-True is a bitwise
        no-op.
      cap: optional (...,) int32 — the power governor's per-frame
        recompute allocation (a DATA value, not a shape: the static j
        slots are kept and the needed mask is truncated to the first
        ``cap`` ranked slots, so governing never recompiles). Slots past
        the cap behave exactly like budget-deferred overflow: they keep
        serving held charge and age toward a future slot. ``cap >= j``
        is a bitwise no-op.

    Returns:
      ``(stale_idx, needed, n_stale)``:
      stale_idx (..., j) — the exactly-j patch indices to gather/project
        this frame (a subset of ``indices``);
      needed (..., j) — True where the slot holds a genuinely stale patch
        (False = idle spare slot: its projection output is never
        converted or merged — see :func:`refresh`);
      n_stale (..., ) int32 — how many of the j slots were genuinely
        stale (the recompute-fraction numerator == real ADC conversions;
        overflow staleness beyond j or past ``cap`` is deferred, not
        counted). Because the ranking is stale-first, ``n_stale`` is a
        PREFIX count of the slot axis — the gated frontend feeds it
        straight to the ragged projection kernel as per-slot row counts
        (DESIGN.md §11), so idle spare slots cost zero kernel work, not
        projected-then-discarded work.
    """
    k = indices.shape[-1]
    j = spec.budget(k)
    max_hold = spec.max_hold_frames(summer, adc)

    e_now = _take(energy, indices)                     # (..., k)
    e_ref = _take(cache.energy, indices)
    age = _take(cache.age, indices)
    valid = _take(cache.valid, indices)

    delta = jnp.abs(e_now - e_ref)
    stale = (~valid) | (delta >= spec.delta_threshold) | (age >= max_hold)
    if sel_valid is not None:
        stale = stale & sel_valid

    # Rank: stale patches strictly first; among stale, hold age plus the
    # row-normalized delta — age must take part (and eventually dominate)
    # so that overflow staleness deferred past the budget makes progress
    # every frame instead of starving behind a fixed first-j positional
    # winner set; the [0,1) delta term breaks same-age ties toward the
    # biggest content change. Spare slots rank fresh patches by age but
    # stay idle. The three bands (fresh [0,1), stale >= 2) are kept small
    # so f32 rounding cannot swallow the tie-break terms (at the old
    # 1e9 offset one ulp was 64 — delta and age both rounded away).
    agef = age.astype(jnp.float32)
    dmax = jnp.max(delta, axis=-1, keepdims=True)
    dn = delta / jnp.maximum(dmax, 1e-12)              # [0, 1] per row
    fresh_rank = 1.0 - 1.0 / (1.0 + agef)              # [0, 1): oldest first
    score = jnp.where(stale, 2.0 + agef + dn, fresh_rank)
    _, pos = jax.lax.top_k(score, j)                   # (..., j) positions in [0, k)
    stale_idx = _take(indices, pos)
    needed = _take(stale, pos)
    if cap is not None:
        # governed allocation: stale-first ranking means truncating to the
        # first cap slots sheds exactly the lowest-priority staleness
        needed = needed & (jnp.arange(j) < cap[..., None])
    n_stale = jnp.sum(needed, axis=-1).astype(jnp.int32)
    return stale_idx, needed, n_stale


def refresh(
    cache: FeatureCache,
    stale_idx: jnp.ndarray,
    needed: jnp.ndarray,
    new_features: jnp.ndarray,
    energy: jnp.ndarray,
    n_stale: jnp.ndarray,
) -> FeatureCache:
    """Age every held entry by one frame, then scatter-merge the freshly
    recomputed *stale* patches (droop reset, new delta reference, age 0).

    Only the ``needed`` slots are merged: spare budget slots (fewer stale
    patches than j) re-project a fresh patch whose held value is still
    within the droop budget, so their output never leaves the array —
    no ADC conversion, no streamed bytes, no cache write. ``n_stale``
    therefore counts exactly the merged (recomputed-and-converted) rows,
    and the droop clock of held patches keeps ticking until the LSB
    budget forces a real refresh.

    Droop itself is NOT applied here (see :class:`FeatureCache`): only
    the per-patch age advances; :func:`held_features` folds
    ``droop_factor ** age`` in at serve time.
    """
    age = jnp.where(cache.valid, cache.age + 1, cache.age)

    old_rows = _take(cache.features, stale_idx)
    feats = _scatter_rows(
        cache.features, stale_idx,
        jnp.where(needed[..., None], new_features, old_rows),
    )
    e_ref = _scatter_rows(
        cache.energy, stale_idx,
        jnp.where(needed, _take(energy, stale_idx), _take(cache.energy, stale_idx)),
    )
    age = _scatter_rows(
        age, stale_idx, jnp.where(needed, 0, _take(age, stale_idx))
    )
    valid = _scatter_rows(
        cache.valid, stale_idx, needed | _take(cache.valid, stale_idx)
    )
    return FeatureCache(feats, e_ref, age, valid, n_stale)


def held_gain(
    cache: FeatureCache, indices: jnp.ndarray, summer: sc.SummerSpec
) -> jnp.ndarray:
    """Per-served-row droop/charge multiplier for the (..., k) selection:
    ``d^age`` for held entries (d^0 == 1 on entries refreshed this frame,
    so fresh conversions serve bit-exactly) and 0 on never-computed
    entries (an uncharged summing cap serves zero). Applied to the
    *dequantized* value at the serve epilogue — the stored codes are never
    aged in place (integer-safe: no cumulative rounding)."""
    age = _take(cache.age, indices).astype(jnp.float32)
    d = jnp.float32(summer.droop_factor())
    return jnp.power(d, age) * _take(cache.valid, indices).astype(jnp.float32)


def gated_frame_events(
    n_pixels: float,
    pixels_per_patch: int,
    n_vectors: int,
    n_selected: jnp.ndarray,
    n_stale: jnp.ndarray,
    readout: str = "adc",
):
    """The energy-costing events ONE gated frame executes (DESIGN.md §10):
    only the ``n_stale`` recomputed patches pay for projection (cap
    charges, PWM/OpAmp windows) and conversion (ADC — or one comparator
    each under ``readout="sign"``, DESIGN.md §13) — *holds are free*
    by the paper's non-destructive-readout argument (§2.1.2): serving
    held charge moves no charge and converts nothing. Spare idle slots
    contribute nothing either (their output is never converted or
    merged, see :func:`refresh`). The per-frame fixed costs (CDS, DAC
    broadcast, deselected-patch dumps) are selection-scale, not
    staleness-scale."""
    from repro.core import power as power_mod

    return power_mod.frontend_frame_events(
        n_pixels=n_pixels,
        pixels_per_patch=pixels_per_patch,
        n_vectors=n_vectors,
        n_selected_patches=n_selected,
        n_converted_patches=n_stale,
        readout=readout,
    )


def held_features(
    cache: FeatureCache,
    indices: jnp.ndarray,
    summer: sc.SummerSpec,
    scale: jnp.ndarray | None = None,
    zero: jnp.ndarray | None = None,
) -> jnp.ndarray:
    """Serve the selection from held charge as floats: gather the
    (..., k) selected rows, dequantize (code caches need the static
    ``(scale, zero)`` metadata; float caches ignore it) and apply each
    entry's accumulated droop via :func:`held_gain`."""
    feats = _take(cache.features, indices)                  # (..., k, M)
    if not jnp.issubdtype(feats.dtype, jnp.floating):
        if scale is None or zero is None:
            raise ValueError(
                "code-format cache: held_features needs the (scale, zero) "
                "metadata from repro.core.adc.readout_scale_zero"
            )
        feats = adc_mod.dequantize(feats, scale, zero)
    return feats * held_gain(cache, indices, summer)[..., None]
