"""Area (Table 1) and energy/power (§2.1.3) models of the IP2 front-end.

Area — Table 1 is reproduced exactly (65 nm, 8 µm pixel, 30 fF caps, one
OpAmp per patch, wiring estimate): 485 µm² -> 22.0 µm pitch.

Energy — the front-end's power is priced from *discrete events* (DESIGN.md
§10). :class:`EventCounts` enumerates the things that cost energy — ADC
conversions, DAC weight loads, cap charge events, CDS samples, photodiode
dumps — plus the two static-current windows (PWM comparators, per-patch
OpAmps) expressed in pixel-frames / patch-frames. :class:`EnergyMeter`
prices any such bag of events with the calibrated 65 nm
:class:`EnergyConstants`, so ONE pricing function serves two views:

* **Analytical** — :func:`steady_state_events` writes down the paper's
  closed-form per-frame event counts (sensor of X pixels, patch N², M
  vectors/patch, active fraction f):

      ADC conversions  = (X/N²)·f·M      (only active patches convert)
      DAC weight loads = M·N²            (weights broadcast to all patches
                                          over shared lines)
      cap charge events= X·f·M           (each active pixel, each vector)
      CDS samples      = 2·X             (global shutter, clamp+sample)
      pixel dumps      = X·(1-f)         (deselected-patch photodiode clear)
      PWM comparators  = X·f pixel-frames  (static during compute window)
      OpAmp on-time    = (X/N²)·f patch-frames

  and :func:`power_report` IS the meter evaluated on those counts — the
  closed-form report and the runtime meter cannot drift apart because
  they are the same arithmetic by construction.

* **Measured** — the runtime (``frontend.apply_frontend`` compact path,
  the temporal gate, the serving engine) emits the events it *actually
  executed* each frame via :func:`frontend_frame_events` (temporal holds
  are free: non-destructive readout, paper §2.1.2), and the same meter
  turns them into mW. `serve/governor.py` closes the loop by steering
  the recompute budget so measured power tracks a chip budget.

Calibrated to the paper's claims: < 30 mW/Mpix at the imager front-end
(ADC+DAC included); < 60 mW for 2 Mpix @ 30 Hz; "the majority of the
power is for the ADC conversion"; 25 % of the patches generate an output
every frame.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple


# --------------------------------------------------------------------------
# Table 1 — in-pixel circuit size per pixel, 65 nm
# --------------------------------------------------------------------------

TABLE1_ROWS = (
    # name, count, unit size (µm²)
    ("Photo Sensor", 1, 64.0),
    ("Cap 30 fF", 3, 64.0),
    ("Transistors", 41, 5.0),
    ("Wiring", 1, 16.0),
    ("Margin", 1, 8.0),
)


@dataclasses.dataclass(frozen=True)
class AreaBudget:
    rows: tuple = TABLE1_ROWS

    def totals(self) -> dict:
        total = sum(n * s for _, n, s in self.rows)
        out = {
            name: {
                "count": n,
                "unit_um2": s,
                "total_um2": n * s,
                "occupancy": n * s / total,
            }
            for name, n, s in self.rows
        }
        out["Total"] = {"total_um2": total, "pitch_um": math.sqrt(total)}
        return out


# --------------------------------------------------------------------------
# Event-metered energy model (DESIGN.md §10)
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    """Per-event energies / static currents, 65 nm-plausible defaults."""

    e_adc_j: float = 4.0e-9        # per conversion: 10b column SAR + refs + readout
    e_dac_j: float = 0.5e-9        # per weight-line DAC settle (global broadcast)
    cap_f: float = 30e-15          # Table 1 caps
    v_dd: float = 1.0
    mean_signal_v: float = 0.1     # E[|w·p|] over natural images & trained weights
    i_pwm_comparator_a: float = 20e-9   # per-pixel ramp comparator (inverter-based)
    i_opamp_a: float = 2e-6        # per-patch OTA quiescent
    compute_duty: float = 0.5      # fraction of frame the analog compute is live
    e_pixel_dump_j: float = 1e-15  # deselected-patch photodiode clear
    # DESIGN.md §13 — reconfigurable-mode events
    e_sign_cmp_j: float = 5e-14    # one ADC-less comparator decision (no ramp,
                                   # no SAR steps: ~1e-5 of a full conversion)
    e_dac_reprogram_j: float = 2e-9  # rewrite + settle one weight-DAC register
                                     # (a register write on top of the settle,
                                     # ~4x the broadcast-only e_dac_j)
    # DESIGN.md §14 — incremental backend events
    e_backend_mac_j: float = 1e-12   # one digital int8/f32 MAC in the edge
                                     # backend accelerator (~1 pJ at 65 nm)


@dataclasses.dataclass(frozen=True)
class SensorConfig:
    n_pixels: float = 2.0e6
    frame_hz: float = 30.0
    patch_h: int = 32
    patch_w: int = 32
    n_vectors: int = 400
    active_fraction: float = 0.25


class EventCounts(NamedTuple):
    """One frame's (or one accumulation window's) energy-costing events.

    A pytree of scalars or arrays (leading dims = batch/slot axes), so it
    jits, batches, shards and donates like any other runtime state. Plain
    counts, no energies: pricing is the :class:`EnergyMeter`'s job, so
    recalibrating :class:`EnergyConstants` never requires re-serving.

    The two ``*_frames`` fields are static-current *windows*, not events:
    pixel-frames of PWM-comparator on-time and patch-frames of OpAmp
    on-time. The meter converts them to joules with ``compute_duty`` and
    the frame period — the only place wall-clock time enters.
    """

    adc_conversions: object = 0.0   # feature samples converted at the edge ADC
    dac_loads: object = 0.0         # weight-line DAC settles (M·N² per frame)
    cap_charges: object = 0.0       # pixel-cap charge events (active px × vectors)
    cds_samples: object = 0.0       # CDS clamp+sample events (2 per pixel per frame)
    pixel_dumps: object = 0.0       # deselected-patch photodiode clears
    pwm_pixel_frames: object = 0.0  # comparator on-window, pixel·frames
    opamp_patch_frames: object = 0.0  # OTA on-window, patch·frames
    # DESIGN.md §13 — reconfigurable-mode events (defaults keep every
    # 7-field producer/consumer — stored artifacts included — valid)
    sign_comparisons: object = 0.0  # ADC-less 1-bit comparator decisions
    dac_reprograms: object = 0.0    # weight-DAC register REWRITES (kernel-bank
                                    # cycling); 0 for a statically programmed bank
    # DESIGN.md §14 — incremental-backend events (default keeps every
    # older producer/consumer — stored artifacts included — valid)
    backend_macs: object = 0.0      # digital backend MACs actually executed
                                    # (delta-gated encoder; 0 on cached frames)

    def add(self, other: "EventCounts") -> "EventCounts":
        return EventCounts(*(a + b for a, b in zip(self, other)))

    def scale(self, s) -> "EventCounts":
        return EventCounts(*(a * s for a in self))

    @classmethod
    def zeros(cls) -> "EventCounts":
        return cls()


def frontend_frame_events(
    n_pixels: float,
    pixels_per_patch: int,
    n_vectors: int,
    n_selected_patches,
    n_converted_patches,
    readout: str = "adc",
) -> EventCounts:
    """The events ONE compact frontend frame executes (DESIGN.md §10).

    ``n_selected_patches`` is the size of the saccade selection (valid
    tokens — deselected patches dump their photodiodes and power down);
    ``n_converted_patches`` is how many of those were actually
    re-projected AND ADC-converted this frame — equal to the selection on
    the ungated path, ``n_stale`` under the temporal gate (held patches
    are free: the readout is non-destructive, paper §2.1.2). Both may be
    scalars or batched arrays; the counts broadcast accordingly.

    ``readout`` selects the conversion epilogue (DESIGN.md §13): the
    default ``"adc"`` converts every (patch, vector) output at the edge
    ADC; ``"sign"`` fires one comparator instead — same count, priced as
    ``sign_comparisons`` (near-zero energy), zero ``adc_conversions``.
    Everything upstream of the conversion (caps, PWM, OpAmps, CDS, DAC
    broadcast, dumps) is readout-independent.

    Per-frame fixed costs (selection-independent): the DAC broadcasts all
    M·N² weight values over shared lines once per frame, and every pixel
    CDS-samples twice (global shutter) — the photodiodes integrate light
    regardless of gating.
    """
    if readout not in ("adc", "sign"):
        raise ValueError(f"unknown readout mode {readout!r}")
    n2 = pixels_per_patch
    m = n_vectors
    converted_px = n_converted_patches * n2
    conversions = n_converted_patches * m
    # the "+ 0·count" terms broadcast the per-frame constants up to the
    # batch shape of the gated counts (and stay plain floats unbatched)
    return EventCounts(
        adc_conversions=conversions if readout == "adc" else 0.0 * conversions,
        dac_loads=0.0 * n_converted_patches + float(m * n2),
        cap_charges=converted_px * m,
        cds_samples=0.0 * n_converted_patches + 2.0 * n_pixels,
        pixel_dumps=n_pixels - n_selected_patches * n2,
        pwm_pixel_frames=converted_px,
        opamp_patch_frames=1.0 * n_converted_patches,
        sign_comparisons=conversions if readout == "sign" else 0.0 * conversions,
        dac_reprograms=0.0 * n_converted_patches,
        backend_macs=0.0 * n_converted_patches,
    )


def conv_frame_events(
    n_pixels: float,
    pixels_per_window: int,
    n_channels: int,
    n_windows,
    readout: str = "adc",
    reprogram: bool = False,
) -> EventCounts:
    """The events ONE conv-in-pixel frame executes (DESIGN.md §13).

    Conv is dense over the frame: every K×K window (``n_windows`` of them,
    overlapping when stride < K) runs one charge-share cycle per output
    channel, so a pixel under ``w`` windows is PWM-read and cap-charged
    ``w`` times — the overlap cost is explicit in the counts, never
    averaged away. No patches deselect, so no photodiode dumps.

    The weight DAC is the mode's distinguishing cost: the bank holds ONE
    K²×C kernel, broadcast like the projection weights every frame
    (``dac_loads``). A static kernel is programmed once at deploy and
    costs nothing per frame; ``reprogram=True`` models cycling kernel
    banks through the one physical array — C·K² register REWRITES per
    frame, priced as ``dac_reprograms`` (the meter must see the
    difference between program-once and reprogram-per-frame).
    """
    if readout not in ("adc", "sign"):
        raise ValueError(f"unknown readout mode {readout!r}")
    k2 = pixels_per_window
    c = n_channels
    window_px = n_windows * k2
    conversions = n_windows * c
    return EventCounts(
        adc_conversions=conversions if readout == "adc" else 0.0 * conversions,
        dac_loads=0.0 * n_windows + float(c * k2),
        cap_charges=window_px * c,
        cds_samples=0.0 * n_windows + 2.0 * n_pixels,
        pixel_dumps=0.0 * n_windows,
        pwm_pixel_frames=window_px,
        opamp_patch_frames=1.0 * n_windows,
        sign_comparisons=conversions if readout == "sign" else 0.0 * conversions,
        dac_reprograms=(0.0 * n_windows + float(c * k2)) if reprogram
        else 0.0 * n_windows,
        backend_macs=0.0 * n_windows,
    )


def backend_frame_macs(
    n_vectors: int,
    d_model: int,
    d_ff: int,
    n_classes: int,
    j_embed,
    j_qkv,
    q_attn,
    n_keys,
    computed=1.0,
):
    """Closed-form MAC count of one delta-gated backend frame (DESIGN.md §14).

    The delta encoder's work splits into per-row terms, so the count is a
    sum over the stale populations the gate actually touched:

    - ``j_embed``    — rows whose wire code changed: re-embed (M·d MACs each).
    - ``j_qkv[l]``   — rows whose layer-``l`` input changed: fresh Q/K/V
      projections (3·d² MACs each).
    - ``q_attn[l]``  — query rows re-attended + re-MLP'd at layer ``l``:
      score+mix against ``n_keys`` valid keys (2·n_keys·d), output
      projection (d²), and the two MLP matmuls (2·d·d_ff).
    - ``computed``   — 1.0 when the frame ran at all, 0.0 when it was
      served entirely from the cache; gates the pool+head term (C·d).

    ``j_qkv``/``q_attn`` are length-``n_layers`` sequences of per-layer
    counts; every count may be a scalar or a slot-major array (the counts
    broadcast, same discipline as the frame-event builders above).
    Passing the full token count for every term prices the dense backend
    (the governor's feed-forward estimate — :func:`dense_backend_macs`).
    """
    d = d_model
    per_attn = 2.0 * n_keys * d + float(d * d) + 2.0 * d * d_ff
    layers = 0.0
    for j_l, q_l in zip(j_qkv, q_attn):
        layers = layers + j_l * (3.0 * d * d) + q_l * per_attn
    return (
        j_embed * (float(n_vectors) * d)
        + layers
        + computed * float(n_classes * d)
    )


def dense_backend_macs(
    n_tokens, n_layers: int, n_vectors: int, d_model: int, d_ff: int,
    n_classes: int,
):
    """MACs of the dense (ungated) backend on ``n_tokens`` valid rows —
    :func:`backend_frame_macs` with every stale population at full k."""
    return backend_frame_macs(
        n_vectors, d_model, d_ff, n_classes,
        j_embed=n_tokens,
        j_qkv=[n_tokens] * n_layers,
        q_attn=[n_tokens] * n_layers,
        n_keys=n_tokens,
        computed=1.0,
    )


def steady_state_events(cfg: SensorConfig, readout: str = "adc") -> EventCounts:
    """The analytical per-frame event counts of the paper's steady state:
    a fraction ``f`` of the patches is selected AND converted every frame
    (no temporal reuse). :func:`power_report` is the meter on exactly
    these counts. ``readout`` as in :func:`frontend_frame_events`."""
    n2 = cfg.patch_h * cfg.patch_w
    n_patches = cfg.n_pixels / n2
    f = cfg.active_fraction
    return frontend_frame_events(
        n_pixels=cfg.n_pixels,
        pixels_per_patch=n2,
        n_vectors=cfg.n_vectors,
        n_selected_patches=n_patches * f,
        n_converted_patches=n_patches * f,
        readout=readout,
    )


class PowerBreakdown(NamedTuple):
    """Priced events: per-component watts + their sum. ``components`` and
    the total are SEPARATE structures (never mixed into one dict), so new
    components can be added without any name-filtering at the consumers."""

    components: dict            # name -> W (scalars or arrays, batched ok)
    total_w: object             # sum of components

    def share(self) -> dict:
        return {k: v / self.total_w for k, v in self.components.items()}

    @property
    def dominant(self) -> str:
        """Largest component by scalar value (reports/tests; call on
        unbatched breakdowns)."""
        return max(self.components, key=lambda k: float(self.components[k]))


@dataclasses.dataclass(frozen=True)
class EnergyMeter:
    """Prices :class:`EventCounts` with :class:`EnergyConstants`.

    Pure arithmetic over the event-count leaves — works identically on
    python floats (analytical reports) and jnp arrays (runtime meters
    inside a jitted serving step), so the closed-form and measured views
    share one pricing function by construction.
    """

    k: EnergyConstants = EnergyConstants()

    def energy_j(self, ev: EventCounts, frame_hz: float) -> dict:
        """Per-component joules for one bag of events. ``frame_hz`` only
        converts the static-current windows (pixel-frames / patch-frames)
        into on-seconds; the discrete events are rate-independent."""
        k = self.k
        # charging a cap to mean_signal_v from the rail via a current source
        e_cap = k.cap_f * k.mean_signal_v * k.v_dd
        e_cds = 0.5 * k.cap_f * k.v_dd ** 2
        window_s = k.compute_duty / frame_hz
        return {
            "adc": ev.adc_conversions * k.e_adc_j,
            "weight_dac": ev.dac_loads * k.e_dac_j,
            "cap_charging": ev.cap_charges * e_cap,
            "pwm_comparators": ev.pwm_pixel_frames
            * k.i_pwm_comparator_a * k.v_dd * window_s,
            "opamps": ev.opamp_patch_frames * k.i_opamp_a * k.v_dd * window_s,
            "cds_sampling": ev.cds_samples * e_cds,
            "pixel_dump": ev.pixel_dumps * k.e_pixel_dump_j,
            "sign_comparators": ev.sign_comparisons * k.e_sign_cmp_j,
            "weight_reprogram": ev.dac_reprograms * k.e_dac_reprogram_j,
            "backend": ev.backend_macs * k.e_backend_mac_j,
        }

    def power_w(
        self, ev: EventCounts, frame_hz: float, n_frames: float = 1.0
    ) -> PowerBreakdown:
        """Average power of ``ev`` spread over ``n_frames`` frames at
        ``frame_hz`` (per-frame events with the default ``n_frames=1``:
        instantaneous frame power)."""
        e = self.energy_j(ev, frame_hz)
        scale = frame_hz / n_frames
        comp = {name: v * scale for name, v in e.items()}
        total = sum(comp.values())
        return PowerBreakdown(comp, total)

    def power_mw(self, ev: EventCounts, frame_hz: float, n_frames: float = 1.0):
        """Total milliwatts only — the governor's hot-path quantity."""
        return self.power_w(ev, frame_hz, n_frames).total_w * 1e3

    def slot_recompute_power_w(
        self, pixels_per_patch: int, n_vectors: int, frame_hz: float
    ) -> float:
        """Marginal power of re-projecting + converting ONE extra patch
        every frame — the governor's control gain (budget / this = the
        affordable per-frame recompute allocation)."""
        ev = EventCounts(
            adc_conversions=float(n_vectors),
            cap_charges=float(pixels_per_patch * n_vectors),
            pwm_pixel_frames=float(pixels_per_patch),
            opamp_patch_frames=1.0,
        )
        return self.power_w(ev, frame_hz).total_w


class PowerReport(NamedTuple):
    """The analytical front-end power report (meter × steady-state
    events): components and totals in separate structures. ``share`` and
    ``dominant`` delegate to :class:`PowerBreakdown` so the two views
    cannot drift."""

    components: dict            # name -> W
    total_w: float
    mw_per_mpix: float

    def _breakdown(self) -> PowerBreakdown:
        return PowerBreakdown(self.components, self.total_w)

    def share(self) -> dict:
        return self._breakdown().share()

    @property
    def dominant(self) -> str:
        return self._breakdown().dominant

    @property
    def adc_dominated(self) -> bool:
        return self.dominant == "adc"


def power_report(
    cfg: SensorConfig, k: EnergyConstants = EnergyConstants()
) -> PowerReport:
    """Per-component front-end power + totals. DEFINED as the
    :class:`EnergyMeter` evaluated on the analytical steady-state event
    counts (:func:`steady_state_events`), so the closed-form report and
    the runtime event meter agree exactly by construction (asserted in
    tests/test_power.py). Excludes the digital interface (the paper's
    figure excludes it too)."""
    bd = EnergyMeter(k).power_w(steady_state_events(cfg), cfg.frame_hz)
    return PowerReport(
        components=bd.components,
        total_w=bd.total_w,
        mw_per_mpix=bd.total_w * 1e3 / (cfg.n_pixels / 1e6),
    )


def data_reduction(cfg: SensorConfig, vs_rgb: bool = False) -> float:
    """Input samples per frame / output feature count per frame (paper: 10x,
    30x when credited against the Bayer->RGB interpolation)."""
    n2 = cfg.patch_h * cfg.patch_w
    n_patches = cfg.n_pixels / n2
    out = n_patches * cfg.active_fraction * cfg.n_vectors
    inp = cfg.n_pixels * (3.0 if vs_rgb else 1.0)
    return inp / out
