"""Area (Table 1) and power (§2.1.3) models of the IP2 front-end.

Area — Table 1 is reproduced exactly (65 nm, 8 µm pixel, 30 fF caps, one
OpAmp per patch, wiring estimate): 485 µm² -> 22.0 µm pitch.

Power — component energy model with 65 nm-plausible constants, calibrated
to the paper's claims:

  * < 30 mW per Mpix at the imager front-end, ADC+DAC included;
  * < 60 mW for a 2 Mpix sensor @ 30 Hz capture+processing;
  * "the majority of the power is for the ADC conversion";
  * assumes 25 % of the patches generate an output every frame.

Event counts per second (sensor of X pixels, patch N², M vectors/patch,
active fraction f, frame rate R):

  ADC conversions  = (X/N²)·f·M·R              (only active patches convert)
  DAC weight loads = M·N²·R                    (weights broadcast to all
                                                patches over shared lines)
  cap charge events= X·f·M·R                   (each active pixel, each vector)
  PWM comparators  = X·f static during compute (inverter-threshold ramps)
  CDS samples      = 2·X·R                     (global shutter, clamp+sample)
  OpAmp static     = (X/N²)·f during compute window
"""

from __future__ import annotations

import dataclasses
import math


# --------------------------------------------------------------------------
# Table 1 — in-pixel circuit size per pixel, 65 nm
# --------------------------------------------------------------------------

TABLE1_ROWS = (
    # name, count, unit size (µm²)
    ("Photo Sensor", 1, 64.0),
    ("Cap 30 fF", 3, 64.0),
    ("Transistors", 41, 5.0),
    ("Wiring", 1, 16.0),
    ("Margin", 1, 8.0),
)


@dataclasses.dataclass(frozen=True)
class AreaBudget:
    rows: tuple = TABLE1_ROWS

    def totals(self) -> dict:
        total = sum(n * s for _, n, s in self.rows)
        out = {
            name: {
                "count": n,
                "unit_um2": s,
                "total_um2": n * s,
                "occupancy": n * s / total,
            }
            for name, n, s in self.rows
        }
        out["Total"] = {"total_um2": total, "pitch_um": math.sqrt(total)}
        return out


# --------------------------------------------------------------------------
# Power model
# --------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    """Per-event energies / static currents, 65 nm-plausible defaults."""

    e_adc_j: float = 4.0e-9        # per conversion: 10b column SAR + refs + readout
    e_dac_j: float = 0.5e-9        # per weight-line DAC settle (global broadcast)
    cap_f: float = 30e-15          # Table 1 caps
    v_dd: float = 1.0
    mean_signal_v: float = 0.1     # E[|w·p|] over natural images & trained weights
    i_pwm_comparator_a: float = 20e-9   # per-pixel ramp comparator (inverter-based)
    i_opamp_a: float = 2e-6        # per-patch OTA quiescent
    compute_duty: float = 0.5      # fraction of frame the analog compute is live
    e_pixel_dump_j: float = 1e-15  # deselected-patch photodiode clear


@dataclasses.dataclass(frozen=True)
class SensorConfig:
    n_pixels: float = 2.0e6
    frame_hz: float = 30.0
    patch_h: int = 32
    patch_w: int = 32
    n_vectors: int = 400
    active_fraction: float = 0.25


def power_report(cfg: SensorConfig, k: EnergyConstants = EnergyConstants()) -> dict:
    """Per-component power (W) + totals. Excludes the digital interface
    (the paper's figure excludes it too)."""
    n2 = cfg.patch_h * cfg.patch_w
    n_patches = cfg.n_pixels / n2
    f, m, r = cfg.active_fraction, cfg.n_vectors, cfg.frame_hz

    adc_rate = n_patches * f * m * r
    dac_rate = m * n2 * r
    cap_rate = cfg.n_pixels * f * m * r
    cds_rate = 2.0 * cfg.n_pixels * r
    dump_rate = cfg.n_pixels * (1.0 - f) * r

    # charging a cap to mean_signal_v from the rail via a current source
    e_cap = k.cap_f * k.mean_signal_v * k.v_dd
    e_cds = 0.5 * k.cap_f * k.v_dd ** 2

    p = {
        "adc": adc_rate * k.e_adc_j,
        "weight_dac": dac_rate * k.e_dac_j,
        "cap_charging": cap_rate * e_cap,
        "pwm_comparators": cfg.n_pixels * f * k.i_pwm_comparator_a * k.v_dd * k.compute_duty,
        "opamps": n_patches * f * k.i_opamp_a * k.v_dd * k.compute_duty,
        "cds_sampling": cds_rate * e_cds,
        "pixel_dump": dump_rate * k.e_pixel_dump_j,
    }
    total = sum(p.values())
    p["total"] = total
    p["mw_per_mpix"] = total * 1e3 / (cfg.n_pixels / 1e6)
    p["adc_dominated"] = p["adc"] == max(
        v for kk, v in p.items() if kk not in ("total", "mw_per_mpix", "adc_dominated")
    )
    return p


def data_reduction(cfg: SensorConfig, vs_rgb: bool = False) -> float:
    """Input samples per frame / output feature count per frame (paper: 10x,
    30x when credited against the Bayer->RGB interpolation)."""
    n2 = cfg.patch_h * cfg.patch_w
    n_patches = cfg.n_pixels / n2
    out = n_patches * cfg.active_fraction * cfg.n_vectors
    inp = cfg.n_pixels * (3.0 if vs_rgb else 1.0)
    return inp / out
