"""Training step: bf16 compute cast, grad, AdamW update, optional
microbatch gradient accumulation (scan). Pure function factory — the
launcher wraps it in jit with the full sharding pytrees.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.models.layers import ParallelPlan
from repro.optim import AdamWConfig, adamw_update, cosine_with_warmup


def cast_tree(tree, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        tree,
    )


def make_train_step(
    cfg: ModelConfig,
    plan: ParallelPlan,
    opt: AdamWConfig,
    compute_dtype=jnp.bfloat16,
    warmup: int = 200,
    total_steps: int = 10_000,
    microbatches: int = 1,
):
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    ``microbatches > 1`` accumulates grads over a scan of batch slices —
    activation memory drops by the factor, collectives overlap per slice.
    """

    def loss_for(params, batch):
        cparams = cast_tree(params, compute_dtype)
        return lm.loss_fn(cparams, batch, cfg, plan)

    def grads_of(params, batch):
        if microbatches == 1:
            (loss, metrics), grads = jax.value_and_grad(loss_for, has_aux=True)(
                params, batch
            )
            return loss, metrics, grads

        def slice_mb(x, i):
            mb = x.shape[0] // microbatches
            return jax.lax.dynamic_slice_in_dim(x, i * mb, mb, axis=0)

        def body(carry, i):
            acc, loss_acc = carry
            mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
            (loss, _), g = jax.value_and_grad(loss_for, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, gg: a + gg.astype(a.dtype), acc, g)
            return (acc, loss_acc + loss), None

        # §Perf A3: the accumulator follows the optimizer moment dtype —
        # for 100B+ (bf16-moment) configs a fp32 copy of the grads is the
        # single largest training buffer (kimi: 15.6 GiB/chip at 256 chips)
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, opt.moment_dtype), params)
        (gsum, loss_sum), _ = jax.lax.scan(
            body, (zeros, jnp.zeros((), jnp.float32)), jnp.arange(microbatches)
        )
        inv = 1.0 / microbatches
        grads = jax.tree.map(lambda g: g * inv, gsum)
        return loss_sum * inv, {}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        lr_t = cosine_with_warmup(opt_state["step"], opt.lr, warmup, total_steps)
        new_params, new_opt, opt_metrics = adamw_update(
            grads, opt_state, params, opt, lr_t
        )
        out = {"loss": loss, "lr": lr_t, **opt_metrics}
        if isinstance(metrics, dict):
            out.update({k: v for k, v in metrics.items() if k != "loss"})
        return new_params, new_opt, out

    return train_step
