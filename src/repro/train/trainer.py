"""Fault-tolerant training loop.

Production behaviours implemented and tested on host devices:

  * checkpoint/restart — periodic async atomic saves; on start, auto-resume
    from the latest commit; the data pipeline is seekable (pure fn of step)
    so the token stream continues exactly;
  * preemption drain — SIGTERM/SIGINT set a flag; the loop finishes the
    current step, writes a blocking checkpoint, exits cleanly (the normal
    TPU-pod eviction path);
  * failure injection — ``fail_at_step`` raises mid-run *after* optimizer
    update but *before* the checkpoint of that step, proving restart
    correctness (test: resumed run is bitwise-identical to uninterrupted);
  * elastic restart — restore() re-places saved logical arrays against the
    current mesh, which may have a different device count (see
    checkpoint/manager.py); tested in tests/test_fault_tolerance.py;
  * straggler mitigation hook — per-step wall time is tracked; steps
    slower than ``straggler_factor``x the trailing median are counted and
    surfaced in metrics (on a real pod this feeds the reshard/evict
    decision; here it drives logging + tests).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from typing import Callable

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    fail_at_step: int | None = None      # failure injection (tests)
    straggler_factor: float = 3.0


class Trainer:
    def __init__(
        self,
        step_fn: Callable,                 # (params, opt, batch) -> (params, opt, metrics)
        data_fn: Callable[[int], dict],    # step -> batch (seekable)
        tcfg: TrainerConfig,
    ):
        self.step_fn = step_fn
        self.data_fn = data_fn
        self.tcfg = tcfg
        self.ckpt = CheckpointManager(tcfg.ckpt_dir, keep=tcfg.keep)
        self._preempted = False
        self.step_times: list[float] = []
        self.n_stragglers = 0

    def _install_signals(self):
        def handler(signum, frame):
            self._preempted = True

        try:
            signal.signal(signal.SIGTERM, handler)
            signal.signal(signal.SIGINT, handler)
        except ValueError:
            pass  # not on main thread (tests)

    def run(self, params, opt_state, start_step: int = 0, shardings=None):
        """Returns (params, opt_state, history). Auto-resumes if checkpoints
        exist (restart-after-failure path)."""
        tcfg = self.tcfg
        self._install_signals()
        state = {"params": params, "opt": opt_state}
        latest = self.ckpt.latest_step()
        step = start_step
        if latest is not None and latest >= start_step:
            state, step = self.ckpt.restore(state, shardings=shardings)
            step += 1  # saved after completing `step`
        params, opt_state = state["params"], state["opt"]

        history = []
        while step < tcfg.total_steps:
            t0 = time.time()
            batch = self.data_fn(step)
            params, opt_state, metrics = self.step_fn(params, opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.time() - t0
            self.step_times.append(dt)
            med = float(np.median(self.step_times[-20:]))
            if len(self.step_times) > 5 and dt > tcfg.straggler_factor * med:
                self.n_stragglers += 1
            if step % tcfg.log_every == 0:
                history.append({"step": step, "loss": float(metrics["loss"]),
                                "dt": dt})

            if tcfg.fail_at_step is not None and step == tcfg.fail_at_step:
                raise RuntimeError(f"injected failure at step {step}")

            if step % tcfg.ckpt_every == 0 or step == tcfg.total_steps - 1:
                self.ckpt.save(step, {"params": params, "opt": opt_state})
            if self._preempted:
                self.ckpt.save(step, {"params": params, "opt": opt_state},
                               blocking=True)
                break
            step += 1

        self.ckpt.wait()
        return params, opt_state, history
