"""ShapeDtypeStruct stand-ins for every model input — the dry-run lowers
against these (weak-type-correct, shardable, no device allocation).

For VLM/audio archs the modality frontend is a stub per the assignment:
``input_specs`` provides precomputed patch/frame embeddings. Sequence
accounting: VLM train/prefill shapes split seq_len into n_image_tokens of
image prefix + text remainder; enc-dec shapes use seq_len decoder tokens
against n_encoder_frames stub frames.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig

VISION_STUB_DIM = 1024


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Training / prefill batch (full sequences)."""
    b, s = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    out: dict = {}
    if cfg.is_vlm:
        n_img = min(cfg.n_image_tokens, s // 2)
        out["tokens"] = sds((b, s - n_img), jnp.int32)
        out["image_embeds"] = sds((b, n_img, VISION_STUB_DIM), jnp.bfloat16)
        if cfg.vision_frontend == "ip2":
            del out["image_embeds"]
            edge = cfg.ip2_patch * int(n_img ** 0.5)
            out["images_rgb"] = sds((b, edge, edge, 3), jnp.float32)
    elif cfg.is_encoder_decoder:
        out["tokens"] = sds((b, s), jnp.int32)
        out["frames"] = sds((b, cfg.n_encoder_frames, cfg.d_model), jnp.bfloat16)
    else:
        out["tokens"] = sds((b, s), jnp.int32)
    return out


def decode_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Decode step inputs: one new token, absolute position scalar."""
    b = shape.global_batch
    return {
        "tokens": jax.ShapeDtypeStruct((b,), jnp.int32),
        "pos": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_specs(arch: str, shape_name: str) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of one cell —
    the dry-run's lowering inputs (weak-type-correct, no allocation)."""
    from repro.configs import SHAPES, get_config

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    return batch_specs(cfg, shape)


def batch_spec_shardings(cfg: ModelConfig, shape: ShapeConfig, plan) -> dict:
    """PartitionSpec tree matching batch_specs (batch over dp axes)."""
    from jax.sharding import PartitionSpec as P

    dp = plan.dp_axes
    out = {"tokens": P(dp, None)}
    if cfg.is_vlm:
        if cfg.vision_frontend == "ip2":
            out["images_rgb"] = P(dp, None, None, None)
        else:
            out["image_embeds"] = P(dp, None, None)
    elif cfg.is_encoder_decoder:
        out["frames"] = P(dp, None, None)
    return out
