import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run driver (deliverable e) + roofline point collection (g).

For every (arch x shape x mesh) cell:

  1. FULL compile (lax.scan layer stacks): proves the sharding config is
     coherent at depth — memory_analysis (bytes/device), collective
     schedule, compile wall time. This is the dry-run gate.
  2. Roofline points: the same program UNROLLED at 1x and 2x the block
     pattern; XLA cost_analysis counts while-bodies once, so per-repeat
     costs come from the 2x-1x difference and extrapolate linearly to full
     depth (exact for homogeneous stacks; see roofline/analysis.py).
     sLSTM time-scans are corrected analytically.

Results append incrementally to --out (JSON), keyed "arch/shape/mesh",
so reruns skip completed cells.

Usage:
  python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun.json
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, applicable_shapes, arch_shape_cells, get_config
from repro.configs.base import ModelConfig, ShapeConfig, SLSTM
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (
    constrainer_ctx,
    plan_for,
    shardings_for,
    train_plan_for,
)
from repro.launch.specs import batch_spec_shardings, batch_specs, decode_input_specs
from repro.models import lm
from repro.models.layers import ParallelPlan
from repro.optim import AdamWConfig, init_opt_state, opt_state_specs
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.train_step import make_train_step

from jax.sharding import PartitionSpec as P


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# cell construction: returns (lowered,) per variant
# ---------------------------------------------------------------------------

def lower_cell(cfg: ModelConfig, shape: ShapeConfig, mesh, plan: ParallelPlan,
               microbatches: int = 1, cache_dtype=jnp.bfloat16,
               moe_a2a: bool = False):
    """Lower one cell on one mesh. Returns jax .lower() result."""
    tplan = train_plan_for(cfg)
    opt = AdamWConfig(moment_dtype=_dtype(tplan.moment_dtype))

    pspecs = lm.param_specs(cfg, plan)
    if shape.is_train:
        pdt = _dtype(tplan.param_dtype)
        params_shape = jax.eval_shape(
            lambda: lm.init_params(jax.random.PRNGKey(0), cfg, plan, dtype=pdt)
        )
        opt_shape = jax.eval_shape(lambda: init_opt_state(params_shape, opt))
        ospecs = opt_state_specs(pspecs)
        bspecs = batch_specs(cfg, shape)
        bshard = batch_spec_shardings(cfg, shape, plan)

        p_sh = shardings_for(pspecs, params_shape, mesh)
        o_sh = shardings_for(ospecs, opt_shape, mesh)
        b_sh = shardings_for(bshard, bspecs, mesh)

        step = make_train_step(cfg, plan, opt, microbatches=microbatches)
        with constrainer_ctx(mesh, plan, moe_a2a=moe_a2a):
            jitted = jax.jit(
                step, in_shardings=(p_sh, o_sh, b_sh),
                out_shardings=(p_sh, o_sh, None),
                donate_argnums=(0, 1),   # params/opt update in place
            )
            return jitted.lower(params_shape, opt_shape, bspecs)

    # inference: params in bf16
    params_shape = jax.eval_shape(
        lambda: lm.init_params(jax.random.PRNGKey(0), cfg, plan, dtype=jnp.bfloat16)
    )
    p_sh = shardings_for(pspecs, params_shape, mesh)
    state_shape = jax.eval_shape(
        lambda: lm.init_decode_state(
            cfg, plan, shape.global_batch, shape.seq_len, cache_dtype=cache_dtype
        )
    )
    sspecs = lm.decode_state_specs(cfg, plan, cache_dtype=cache_dtype)
    s_sh = shardings_for(sspecs, state_shape, mesh)

    if shape.kind == "prefill":
        bspecs = batch_specs(cfg, shape)
        bshard = batch_spec_shardings(cfg, shape, plan)
        b_sh = shardings_for(bshard, bspecs, mesh)
        stepfn = make_prefill_step(cfg, plan)
        with constrainer_ctx(mesh, plan, moe_a2a=moe_a2a):
            jitted = jax.jit(
                stepfn, in_shardings=(p_sh, b_sh, s_sh), out_shardings=(None, s_sh)
            )
            return jitted.lower(params_shape, bspecs, state_shape)

    # decode: one token against a seq_len cache
    din = decode_input_specs(cfg, shape)
    tok_sh = shardings_for({"t": P(plan.dp_axes)}, {"t": din["tokens"]}, mesh)["t"]
    stepfn = make_decode_step(cfg, plan)
    rng_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
    with constrainer_ctx(mesh, plan, moe_a2a=moe_a2a):
        jitted = jax.jit(
            stepfn,
            in_shardings=(p_sh, s_sh, tok_sh, None, None),
            out_shardings=(tok_sh, None, s_sh),
            donate_argnums=(1,),     # KV cache updates in place
        )
        return jitted.lower(
            params_shape, state_shape, din["tokens"], din["pos"], rng_shape
        )


# ---------------------------------------------------------------------------
# analytic corrections for time-scans cost_analysis cannot see
# ---------------------------------------------------------------------------

def slstm_flops_correction(cfg: ModelConfig, shape: ShapeConfig, n_layers: int,
                           n_chips: int) -> float:
    """sLSTM scans over time; add its per-token gate/recurrence FLOPs."""
    kinds = cfg.layer_kinds[:n_layers]
    n_sl = sum(1 for k in kinds if k == SLSTM)
    if n_sl == 0:
        return 0.0
    d = cfg.d_model
    dh = d // cfg.n_heads
    per_tok_fwd = 2 * (4 * d * d + 4 * d * dh + 8 * d)
    mult = 3.0 if shape.is_train else 1.0
    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    return n_sl * tokens * per_tok_fwd * mult / n_chips


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, do_roofline: bool = True,
             cache_dtype_name: str = "bfloat16", moe_a2a: bool = False,
             xlstm_chunk: int = 0) -> dict:
    from repro.roofline.analysis import cost_point, extrapolate, model_flops

    cache_dtype = {"bfloat16": jnp.bfloat16, "int8": jnp.int8}[cache_dtype_name]
    cfg = get_config(arch)
    if xlstm_chunk:
        cfg = dataclasses.replace(cfg, xlstm_chunk=xlstm_chunk)
    shape = SHAPES[shape_name]
    if shape.kind == "decode":
        # §Perf A2 note: all_to_all dispatch REGRESSES single-token decode
        # (fixed-minimum per-expert buffers >> 1 token/chip); measured on
        # kimi decode_32k: t_coll 0.11 -> 5.22 s. Keep GSPMD for decode.
        moe_a2a = False
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    n_chips = mesh.devices.size
    plan = plan_for(cfg, mesh)
    rec: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "chips": n_chips, "plan": {"tp": plan.tp, "fsdp": plan.fsdp},
    }

    # -- 1. FULL compile (the dry-run gate) ---------------------------------
    # Training cells auto-scale gradient-accumulation microbatches until the
    # step fits 16 GiB HBM; the escalation path is recorded.
    from repro.roofline.analysis import collective_bytes

    hbm = 16 * 1024**3
    mb_trail = []
    if shape.is_train:
        dp_total = n_chips // plan.tp
        mb_cap = max(1, shape.global_batch // dp_total)
        mb_options = [m for m in (1, 4, 8, 16, 32) if m <= mb_cap] or [1]
    else:
        mb_options = [1]
    for mb in mb_options:
        t0 = time.time()
        lowered = lower_cell(cfg, shape, mesh, plan, microbatches=mb,
                             cache_dtype=cache_dtype, moe_a2a=moe_a2a)
        lower_s = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = round(time.time() - t1, 1)
        ma = compiled.memory_analysis()
        peak = int(
            ma.argument_size_in_bytes + ma.temp_size_in_bytes
            + ma.output_size_in_bytes - ma.alias_size_in_bytes
        )
        mb_trail.append({"microbatches": mb, "peak_per_device": peak})
        if peak <= hbm or mb == mb_options[-1]:
            break
        del compiled, lowered

    rec["lower_s"], rec["compile_s"] = lower_s, compile_s
    rec["microbatches"] = mb
    rec["microbatch_trail"] = mb_trail
    rec["memory"] = {
        "argument_bytes_per_device": int(ma.argument_size_in_bytes),
        "output_bytes_per_device": int(ma.output_size_in_bytes),
        "temp_bytes_per_device": int(ma.temp_size_in_bytes),
        "alias_bytes_per_device": int(ma.alias_size_in_bytes),
        "approx_peak_per_device": peak,
        "fits_hbm_16g": bool(peak <= hbm),
    }
    rec["full_collectives"] = collective_bytes(compiled.as_text())["counts"]
    # fusion-aware HBM traffic floor: every argument byte is read once; train
    # additionally writes params/opt back. XLA:CPU "bytes accessed" is
    # fusion-blind and overestimates; this floor brackets reality from below.
    from repro.launch.mesh import HBM_BW

    k = 3.0 if shape.is_train else 1.0
    rec["t_memory_floor_s"] = k * ma.argument_size_in_bytes / HBM_BW
    del compiled, lowered

    if not do_roofline:
        return rec

    # -- 2. roofline points: unrolled 1x / 2x pattern -----------------------
    pat = len(cfg.block_pattern)
    pts = []
    for mult in (1, 2):
        rcfg = dataclasses.replace(
            cfg, n_layers=pat * mult, unroll_layers=True
        )
        lw = lower_cell(rcfg, shape, mesh, plan, cache_dtype=cache_dtype,
                        moe_a2a=moe_a2a)
        pts.append(cost_point(lw.compile()))
        del lw
    n_rep_full = cfg.n_layers / pat
    terms = extrapolate(pts[0], pts[1], 1, 2, n_rep_full)
    terms.flops_per_chip += slstm_flops_correction(cfg, shape, cfg.n_layers, n_chips)

    tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
    mf = model_flops(cfg.active_param_count(), tokens, shape.is_train)
    rec["roofline"] = terms.as_dict()
    rec["roofline"]["model_flops_per_chip"] = mf / n_chips
    rec["roofline"]["useful_flops_ratio"] = (
        (mf / n_chips) / terms.flops_per_chip if terms.flops_per_chip else 0.0
    )
    rec["roofline"]["points"] = pts
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--no-roofline", action="store_true")
    ap.add_argument("--cache-dtype", default="bfloat16", choices=["bfloat16", "int8"])
    ap.add_argument("--moe-dispatch", default="gspmd", choices=["gspmd", "a2a"])
    ap.add_argument("--xlstm-chunk", type=int, default=0)
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args()

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        cells = arch_shape_cells()
    else:
        cfg = get_config(args.arch)
        shapes = [args.shape] if args.shape else applicable_shapes(cfg)
        cells = [(args.arch, s) for s in shapes]

    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    for arch, shape_name in cells:
        for mesh_kind in meshes:
            key = f"{arch}/{shape_name}/{mesh_kind}"
            if key in results and "error" not in results[key]:
                print(f"[skip] {key}")
                continue
            print(f"[run ] {key}", flush=True)
            t0 = time.time()
            try:
                rec = run_cell(arch, shape_name, mesh_kind,
                               do_roofline=not args.no_roofline,
                               cache_dtype_name=args.cache_dtype,
                               moe_a2a=(args.moe_dispatch == "a2a"),
                               xlstm_chunk=args.xlstm_chunk)
                rec["wall_s"] = round(time.time() - t0, 1)
                results[key] = rec
                rl = rec.get("roofline", {})
                print(
                    f"  ok {rec['wall_s']}s compile={rec['compile_s']}s "
                    f"peak/dev={rec['memory']['approx_peak_per_device']/2**30:.2f}GiB "
                    f"bottleneck={rl.get('bottleneck', '-')}",
                    flush=True,
                )
            except Exception as e:
                results[key] = {"error": f"{type(e).__name__}: {e}",
                                "traceback": traceback.format_exc()[-2000:]}
                print(f"  FAIL {type(e).__name__}: {e}", flush=True)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)

    n_ok = sum(1 for v in results.values() if "error" not in v)
    print(f"done: {n_ok}/{len(results)} cells ok")


if __name__ == "__main__":
    main()
