"""Sharding utilities: plan construction, spec fitting, constrainer.

``fit_spec`` is the universal safety net: any PartitionSpec whose axis
product does not divide the corresponding array dimension drops that axis
(replicates instead). Small archs (9-head smollm, 6-head whisper) thus
compile on the 16-way tensor axis with partial replication rather than
failing; padding in attention.head_geometry already handles the hot dims.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models.layers import ParallelPlan
from repro.models.sharding_ctx import set_constrainer


@dataclasses.dataclass(frozen=True)
class TrainPlan:
    """Numeric policy per arch (DESIGN.md §4 + EXPERIMENTS.md memory notes)."""

    param_dtype: str = "float32"   # master weights
    compute_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    cache_dtype: str = "bfloat16"


def plan_for(cfg: ModelConfig, mesh: Mesh) -> ParallelPlan:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axes.get("model", 1)
    dp_axes = tuple(a for a in ("pod", "data") if a in axes)
    # §Perf C1: 10B+ models FSDP their params/opt over the data axis —
    # at 12B the replicated fp32 master+Adam state alone is ~16.6 GiB/chip
    # (TP16), a fixed floor no microbatching can remove.
    # §Perf M2: on the multi-pod mesh, FSDP over BOTH ("pod","data") —
    # sharding state over "data" alone replicates it across pods (kimi:
    # 85.9 GiB/chip at 512 chips, same as 256).
    big = cfg.param_count() >= 10e9
    fsdp_axis = ("pod", "data") if "pod" in axes else "data"
    return ParallelPlan(tp=tp, fsdp=big, dp_axes=dp_axes, fsdp_axis=fsdp_axis)


def train_plan_for(cfg: ModelConfig) -> TrainPlan:
    # §Perf A1: 100B+ MoE trains in bf16 params + bf16 moments — halves the
    # FSDP all-gather bytes (the dominant collective) and the state memory.
    if cfg.param_count() >= 100e9:
        return TrainPlan(param_dtype="bfloat16", moment_dtype="bfloat16")
    return TrainPlan()


def _axis_size(mesh: Mesh, axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, (tuple, list)):
        return math.prod(_axis_size(mesh, a) for a in axis)
    return mesh.devices.shape[mesh.axis_names.index(axis)]


def fit_spec(spec: P, shape: tuple[int, ...], mesh: Mesh) -> P:
    """Drop spec axes that don't divide their dimension (replicate there)."""
    if spec is None:
        return P()
    parts = list(spec)
    while len(parts) < len(shape):
        parts.append(None)
    out = []
    for dim, axis in zip(shape, parts[: len(shape)]):
        if axis is None:
            out.append(None)
            continue
        if dim % _axis_size(mesh, axis) == 0:
            out.append(axis)
        elif isinstance(axis, (tuple, list)):
            # try a prefix of the compound axes
            kept = []
            for a in axis:
                if dim % _axis_size(mesh, tuple(kept + [a])) == 0:
                    kept.append(a)
            out.append(tuple(kept) if kept else None)
        else:
            out.append(None)
    return P(*out)


def shardings_for(spec_tree, shape_tree, mesh: Mesh):
    """Pytree of NamedShardings with fit_spec applied leaf-wise."""
    def mk(spec, shp):
        return NamedSharding(mesh, fit_spec(spec, shp.shape, mesh))

    return jax.tree.map(
        mk, spec_tree, shape_tree, is_leaf=lambda x: isinstance(x, P)
    )


# ---------------------------------------------------------------------------
# Activation constrainer (installed around jit traces by the launcher)
# ---------------------------------------------------------------------------

def make_constrainer(mesh: Mesh, plan: ParallelPlan, seq_shard: bool = True):
    """Logical-name -> with_sharding_constraint on this mesh.

    act:    (B, S, D)  B over dp, S over tp (sequence parallelism — the
            residual stream is the dominant live tensor under remat)
    logits: (B, S, V)  V over tp
    moe_buf:(E, C, D)  E over tp, C over dp
    """
    dp = plan.dp_axes
    tp = plan.tp_axis

    table = {
        "act": P(dp, tp if seq_shard else None, None),
        "logits": P(dp, None, tp),
        "tokens": P(dp, None),
        "moe_buf": P(tp, dp, None),
        "moe_tokens": P((*dp, tp) if seq_shard else dp, None),
        "kv": P(dp, None, tp, None),
    }

    def constrain(x, name):
        spec = table.get(name)
        if spec is None:
            return x
        spec = fit_spec(spec, x.shape, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))

    return constrain


class constrainer_ctx:
    """Context manager installing the activation constrainer (and optionally
    the §Perf A2 all_to_all MoE dispatch) during trace."""

    def __init__(self, mesh: Mesh | None, plan: ParallelPlan, seq_shard=True,
                 moe_a2a: bool = False):
        self.fn = (
            make_constrainer(mesh, plan, seq_shard) if mesh is not None else None
        )
        self.moe = (
            {"mesh": mesh, "dp": plan.dp_axes, "tp": plan.tp_axis}
            if (moe_a2a and mesh is not None) else None
        )

    def __enter__(self):
        set_constrainer(self.fn)
        if self.moe is not None:
            from repro.models.sharding_ctx import set_moe_ctx

            set_moe_ctx(self.moe)
        return self

    def __exit__(self, *a):
        set_constrainer(None)
        from repro.models.sharding_ctx import set_moe_ctx

        set_moe_ctx(None)
        return False
