"""Production meshes (functions — importing this module never touches jax
device state).

Single pod: 256 TPU v5e chips, mesh (16, 16) = ("data", "model").
Multi-pod: 2 pods = 512 chips, mesh (2, 16, 16) = ("pod", "data", "model")
— "pod" is the slow (DCN) axis; only DP gradient all-reduce (or pipeline
stages) crosses it.
"""

from __future__ import annotations

import jax

# TPU v5e hardware constants (per chip) — used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12          # FLOP/s
HBM_BW = 819e9                    # bytes/s
ICI_BW = 50e9                     # bytes/s per link (≈ per-chip usable)
HBM_BYTES = 16 * 1024**3          # capacity


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 2, model: int = 2):
    """Small mesh over CPU host devices (tests w/ XLA_FLAGS device_count)."""
    return jax.make_mesh((data, model), ("data", "model"))
