"""Architecture registry: ``--arch <id>`` resolution + reduced smoke configs."""

from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig, MoEConfig, applicable_shapes

_ARCH_MODULES = {
    "llama3-8b": "repro.configs.llama3_8b",
    "qwen2.5-32b": "repro.configs.qwen2_5_32b",
    "mistral-nemo-12b": "repro.configs.mistral_nemo_12b",
    "smollm-135m": "repro.configs.smollm_135m",
    "pixtral-12b": "repro.configs.pixtral_12b",
    "qwen3-moe-235b-a22b": "repro.configs.qwen3_moe_235b_a22b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "whisper-tiny": "repro.configs.whisper_tiny",
    "recurrentgemma-2b": "repro.configs.recurrentgemma_2b",
    "xlstm-1.3b": "repro.configs.xlstm_1_3b",
    # the paper's own backend: patch-token transformer fed by the IP2 frontend
    "ip2-vit": "repro.configs.ip2_vit",
}

ARCH_IDS = tuple(k for k in _ARCH_MODULES if k != "ip2-vit")


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return importlib.import_module(_ARCH_MODULES[arch]).CONFIG


def all_configs() -> dict[str, ModelConfig]:
    return {a: get_config(a) for a in _ARCH_MODULES}


def arch_shape_cells(include_paper_arch: bool = False) -> list[tuple[str, str]]:
    """The assigned (arch x shape) grid — 40 baseline cells (+skips noted)."""
    cells = []
    ids = _ARCH_MODULES if include_paper_arch else ARCH_IDS
    for arch in ids:
        cfg = get_config(arch)
        for shape in applicable_shapes(cfg):
            cells.append((arch, shape))
    return cells


def smoke_config(arch: str) -> ModelConfig:
    """Reduced same-family config: small widths/layers/experts/vocab so one
    forward/train step runs on CPU. Full configs are only dry-run lowered."""
    cfg = get_config(arch)
    pat = tuple(cfg.block_pattern)
    n_layers = min(cfg.n_layers, max(2, len(pat)))
    kv = max(1, min(cfg.n_kv_heads, 2))
    heads = max(kv, min(cfg.n_heads, 4))
    heads = (heads // kv) * kv  # keep GQA divisibility
    moe = None
    if cfg.moe is not None:
        # capacity_factor = n_experts makes the smoke dispatch dropless:
        # with an untrained (biased) router the real factor drops tokens,
        # and which tokens get dropped depends on batch composition — so
        # decode == forward only holds when capacity never binds.
        moe = MoEConfig(
            n_experts=4, top_k=2, d_expert=64,
            capacity_factor=4.0,
            n_shared_experts=min(cfg.moe.n_shared_experts, 1),
        )
    return dataclasses.replace(
        cfg,
        n_layers=n_layers,
        d_model=128,
        n_heads=heads,
        n_kv_heads=kv,
        head_dim=32,
        d_ff=256 if cfg.d_ff else 0,
        vocab=512,
        moe=moe,
        n_encoder_layers=min(cfg.n_encoder_layers, 2),
        n_encoder_frames=min(cfg.n_encoder_frames, 16),
        n_image_tokens=min(cfg.n_image_tokens, 8) if cfg.is_vlm else 0,
        ip2_patch=8,
        ip2_vectors=16,
        local_window=64,
        remat=False,
    )
