"""pixtral-12b — pixtral-ViT frontend (stub per assignment) + mistral-nemo
backbone [hf:mistralai/Pixtral-12B-2409].

This is the paper-representative architecture: with
``vision_frontend="ip2"`` the patch embeddings are produced by the IP2
analog in-pixel projection (PWM 6-bit, charge-share, 25% salient patches)
instead of the precomputed ViT stub.
"""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab=131072, head_dim=128,
    block_pattern=(ATTN,), mlp_kind="swiglu", rope_theta=1_000_000.0,
    is_vlm=True, n_image_tokens=1024, vision_frontend="stub",
    ip2_patch=32, ip2_vectors=400,
)
