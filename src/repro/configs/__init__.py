from repro.configs.base import (
    ATTN,
    LOCAL_ATTN,
    MLSTM,
    MOE,
    RECURRENT,
    SHAPES,
    SLSTM,
    ModelConfig,
    MoEConfig,
    ShapeConfig,
    applicable_shapes,
)
from repro.configs.registry import (
    ARCH_IDS,
    all_configs,
    arch_shape_cells,
    get_config,
    smoke_config,
)

__all__ = [
    "ATTN", "LOCAL_ATTN", "MLSTM", "MOE", "RECURRENT", "SLSTM", "SHAPES",
    "ModelConfig", "MoEConfig", "ShapeConfig", "applicable_shapes",
    "ARCH_IDS", "all_configs", "arch_shape_cells", "get_config", "smoke_config",
]
