"""ip2-vit — the paper's own backend: a patch-token transformer classifier
fed by the IP2 analog frontend (paper §1 "transformer-based backend model
for object classification and detection"). Used by the examples and the
accuracy benches; not part of the assigned 40-cell LM grid."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="ip2-vit", family="vision",
    n_layers=6, d_model=256, n_heads=4, n_kv_heads=4,
    d_ff=1024, vocab=0, head_dim=64,
    block_pattern=(ATTN,), mlp_kind="gelu", qkv_bias=True,
    is_vlm=True, n_image_tokens=64, vision_frontend="ip2",
    ip2_patch=32, ip2_vectors=192,
)
