"""recurrentgemma-2b — RG-LRU + local attention, 1 attn : 2 recurrent
[arXiv:2402.19427]. Sub-quadratic -> runs long_500k."""
from repro.configs.base import LOCAL_ATTN, RECURRENT, ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b", family="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1,
    d_ff=7680, vocab=256000, head_dim=256,
    block_pattern=(RECURRENT, RECURRENT, LOCAL_ATTN),
    mlp_kind="geglu", local_window=2048, logit_softcap=30.0,
)
