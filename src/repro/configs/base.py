"""Config system: model architecture, input shapes, parallelism plan.

Every assigned architecture is a ``ModelConfig`` built in its own
``configs/<id>.py`` module and registered in ``configs.registry``. The
shape set (train_4k / prefill_32k / decode_32k / long_500k) is global to
the LM family; per-arch applicability (decode/long skips) is computed from
the architecture's attention class.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

# ---------------------------------------------------------------------------
# Block kinds
# ---------------------------------------------------------------------------

ATTN = "attn"            # global causal self-attention (dense transformer)
LOCAL_ATTN = "local"     # sliding-window attention
RECURRENT = "rglru"      # RecurrentGemma RG-LRU recurrent block
MLSTM = "mlstm"          # xLSTM matrix-LSTM block
SLSTM = "slstm"          # xLSTM scalar-LSTM block
MOE = "moe"              # attention + MoE FFN
ENCDEC = "encdec"        # whisper-style encoder-decoder (handled by model kind)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden dim
    capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    n_shared_experts: int = 0     # dense experts always active (kimi-style)


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                   # dense | moe | vlm | audio | hybrid | ssm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None   # default d_model // n_heads
    block_pattern: Sequence[str] = (ATTN,)   # tiled over n_layers
    mlp_kind: str = "swiglu"      # swiglu | geglu | gelu | none
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    local_window: int = 2048      # for LOCAL_ATTN blocks
    logit_softcap: float | None = None
    # enc-dec (audio): encoder frames are precomputed stubs per assignment
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    n_encoder_frames: int = 1500
    # vlm: image tokens prepended to text (stub or IP2 frontend)
    is_vlm: bool = False
    n_image_tokens: int = 0
    vision_frontend: str = "stub"   # stub | ip2
    ip2_patch: int = 32             # Bayer patch edge for the IP2 frontend
    ip2_vectors: int = 400          # M vectors/patch off the analog array
    # xlstm
    xlstm_proj_factor: float = 2.0
    xlstm_chunk: int = 0          # >0: chunkwise-parallel mLSTM (§Perf X1)
    # training
    dtype: str = "bfloat16"
    remat: bool = True
    remat_policy: str = "nothing"   # nothing | dots  (saveable residuals)
    # roofline instrumentation: run the layer stack as a python loop instead
    # of lax.scan so XLA cost_analysis counts every layer (see launch/dryrun)
    unroll_layers: bool = False

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)

    # -- derived -----------------------------------------------------------

    @property
    def layer_kinds(self) -> tuple[str, ...]:
        pat = tuple(self.block_pattern)
        reps = math.ceil(self.n_layers / len(pat))
        return (pat * reps)[: self.n_layers]

    @property
    def is_subquadratic(self) -> bool:
        """True if no layer uses global attention (long_500k eligible)."""
        return all(k != ATTN and k != MOE for k in self.layer_kinds) or self.family in (
            "hybrid",
            "ssm",
        )

    @property
    def d_inner_xlstm(self) -> int:
        return int(self.d_model * self.xlstm_proj_factor)

    def param_count(self) -> int:
        """Analytic parameter count (used for MODEL_FLOPS = 6·N·D)."""
        d, hd = self.d_model, self.head_dim
        total = self.vocab * d                      # embed
        if not self.tie_embeddings:
            total += self.vocab * d                 # lm_head
        for kind in self.layer_kinds:
            total += 2 * d                          # norms
            if kind in (ATTN, LOCAL_ATTN, MOE):
                q = d * self.n_heads * hd
                kv = 2 * d * self.n_kv_heads * hd
                o = self.n_heads * hd * d
                total += q + kv + o
                if self.qkv_bias:
                    total += (self.n_heads + 2 * self.n_kv_heads) * hd
            elif kind == RECURRENT:
                dr = d  # recurrent width = d_model
                total += 2 * d * dr + dr * d        # in (x,gate) + out proj
                total += 4 * dr + dr * 4            # conv1d(4) + RG-LRU gates
                total += 2 * dr * dr // 8           # block-diag gate proj (8 blocks)
            elif kind == MLSTM:
                di = self.d_inner_xlstm
                total += 2 * d * di + di * d        # up (x2) + down
                total += 3 * di * di // 4           # qkv block-diag (4 blocks)
                total += 3 * di                     # i,f,o gate projections
            elif kind == SLSTM:
                di = self.d_model
                total += 4 * d * di + 4 * di * di // 4 + di * d
            if kind == MOE:
                m = self.moe
                total += d * m.n_experts            # router
                total += m.n_experts * 3 * d * m.d_expert
                total += m.n_shared_experts * 3 * d * m.d_expert
            elif kind in (ATTN, LOCAL_ATTN):
                if self.mlp_kind == "swiglu" or self.mlp_kind == "geglu":
                    total += 3 * d * self.d_ff
                elif self.mlp_kind == "gelu":
                    total += 2 * d * self.d_ff
        if self.is_encoder_decoder:
            # encoder layers: attn + gelu mlp; decoder cross-attn already not
            # counted above -> add cross attn per decoder layer
            for _ in range(self.n_encoder_layers):
                total += 4 * (self.d_model * self.n_heads * self.head_dim)
                total += 2 * self.d_model * self.d_ff + 2 * self.d_model
            total += self.n_layers * 4 * (self.d_model * self.n_heads * self.head_dim)
        if self.is_vlm:
            total += self.ip2_vectors * self.d_model  # vision adapter
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only);
        MODEL_FLOPS = 6 · N_active · D."""
        total = self.param_count()
        if self.moe is None:
            return total
        m = self.moe
        expert_p = 3 * self.d_model * m.d_expert
        n_moe_layers = sum(1 for k in self.layer_kinds if k == "moe")
        total -= n_moe_layers * m.n_experts * expert_p
        total += n_moe_layers * m.top_k * expert_p
        return int(total)

    moe: MoEConfig | None = None


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Shape cells for this arch. long_500k only for sub-quadratic archs
    (skips recorded in DESIGN.md §Arch-applicability)."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.is_subquadratic:
        names.append("long_500k")
    return names
