"""xlstm-1.3b — mLSTM + sLSTM blocks (7:1), no FFN (d_ff=0)
[arXiv:2405.04517]. Sub-quadratic -> runs long_500k."""
from repro.configs.base import MLSTM, SLSTM, ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b", family="ssm",
    n_layers=48, d_model=2048, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab=50304, head_dim=None,
    block_pattern=(MLSTM,) * 7 + (SLSTM,),
    mlp_kind="none", xlstm_proj_factor=2.0,
)
