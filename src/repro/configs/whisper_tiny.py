"""whisper-tiny — enc-dec; conv/audio frontend is a STUB per assignment
(input_specs provide precomputed frame embeddings) [arXiv:2212.04356]."""
from repro.configs.base import ATTN, ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny", family="audio",
    n_layers=4, d_model=384, n_heads=6, n_kv_heads=6,
    d_ff=1536, vocab=51865, head_dim=64,
    block_pattern=(ATTN,), mlp_kind="gelu", qkv_bias=True,
    is_encoder_decoder=True, n_encoder_layers=4, n_encoder_frames=1500,
)
