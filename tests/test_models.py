"""Per-arch smoke tests (reduced same-family configs, one forward/train
step on CPU, output shapes + no NaNs) and decode==forward consistency."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as M
from repro.configs import ARCH_IDS, applicable_shapes, get_config, smoke_config

KEY = jax.random.PRNGKey(0)
B, S = 2, 24


def make_batch(cfg, key=KEY, s=S):
    b = {"tokens": jax.random.randint(key, (B, s), 0, cfg.vocab)}
    if cfg.is_vlm:
        if cfg.vision_frontend == "ip2":
            edge = cfg.ip2_patch * 2
            b["images_rgb"] = jax.random.uniform(key, (B, edge, edge, 3))
        else:
            b["image_embeds"] = jax.random.normal(
                key, (B, cfg.n_image_tokens, 1024)
            )
    if cfg.is_encoder_decoder:
        b["frames"] = jax.random.normal(key, (B, cfg.n_encoder_frames, cfg.d_model))
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
class TestArchSmoke:
    def test_forward_shapes_no_nan(self, arch):
        cfg = smoke_config(arch)
        params = M.init_params(KEY, cfg)
        logits, aux = M.forward(params, make_batch(cfg), cfg)
        n_img = cfg.n_image_tokens if cfg.is_vlm and cfg.vision_frontend != "ip2" else (
            4 if cfg.is_vlm else 0   # ip2 smoke: 2x2 grid of 8px patches
        )
        assert logits.shape == (B, S + n_img, cfg.vocab)
        assert not bool(jnp.isnan(logits).any())

    def test_train_step_decreases_loss(self, arch):
        from repro.optim import AdamWConfig, init_opt_state
        from repro.train.train_step import make_train_step

        cfg = smoke_config(arch)
        params = M.init_params(KEY, cfg)
        opt = AdamWConfig(lr=5e-3)
        opt_state = init_opt_state(params, opt)
        step = jax.jit(
            make_train_step(cfg, M.DEFAULT_PLAN, opt, compute_dtype=jnp.float32)
        )
        batch = make_batch(cfg)
        losses = []
        for _ in range(4):
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
            assert not np.isnan(losses[-1])
        assert losses[-1] < losses[0]   # same batch: loss must drop


@pytest.mark.parametrize("arch", [
    "llama3-8b", "qwen2.5-32b", "smollm-135m", "qwen3-moe-235b-a22b",
    "kimi-k2-1t-a32b", "whisper-tiny", "recurrentgemma-2b", "xlstm-1.3b",
    "mistral-nemo-12b",
])
def test_decode_matches_forward(arch):
    """prefill + token-by-token decode == full forward (the serving
    correctness invariant, covering KV caches, rolling local windows,
    RG-LRU states, mLSTM folding, sLSTM scan, cross-attn)."""
    cfg = dataclasses.replace(smoke_config(arch), remat=False)
    params = M.init_params(KEY, cfg)
    batch = make_batch(cfg, s=16)
    tokens = batch["tokens"]
    logits_full, _ = M.forward(params, batch, cfg)
    half = 8
    state = M.init_decode_state(cfg, M.DEFAULT_PLAN, B, 16, cache_dtype=jnp.float32)
    lg, state = M.prefill(
        params, dict(batch, tokens=tokens[:, :half]), cfg, M.DEFAULT_PLAN, state
    )
    np.testing.assert_allclose(
        np.asarray(lg), np.asarray(logits_full[:, half - 1]), atol=2e-4
    )
    for t in range(half, 16):
        lg, state = M.decode_step(params, state, tokens[:, t], jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]), atol=2e-4,
            err_msg=f"divergence at position {t}",
        )


def test_local_attention_window_decode():
    """Rolling-buffer decode must match forward when S exceeds the window."""
    cfg = dataclasses.replace(
        smoke_config("recurrentgemma-2b"), local_window=6, remat=False
    )
    params = M.init_params(KEY, cfg)
    s = 20
    tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab)
    logits_full, _ = M.forward(params, {"tokens": tokens}, cfg)
    state = M.init_decode_state(cfg, M.DEFAULT_PLAN, B, s, cache_dtype=jnp.float32)
    lg, state = M.prefill(
        params, {"tokens": tokens[:, :10]}, cfg, M.DEFAULT_PLAN, state
    )
    for t in range(10, s):
        lg, state = M.decode_step(params, state, tokens[:, t], jnp.int32(t), cfg)
        np.testing.assert_allclose(
            np.asarray(lg), np.asarray(logits_full[:, t]), atol=2e-4,
            err_msg=f"divergence at position {t}",
        )


def test_unroll_layers_equals_scan():
    """The roofline-instrumented (unrolled) program computes the same fn."""
    cfg = dataclasses.replace(smoke_config("llama3-8b"), n_layers=4, remat=False)
    params = M.init_params(KEY, cfg)
    batch = make_batch(cfg)
    a, _ = M.forward(params, batch, cfg)
    b_, _ = M.forward(params, batch, dataclasses.replace(cfg, unroll_layers=True))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-5)


def test_long_shape_applicability():
    assert "long_500k" in applicable_shapes(get_config("xlstm-1.3b"))
    assert "long_500k" in applicable_shapes(get_config("recurrentgemma-2b"))
    assert "long_500k" not in applicable_shapes(get_config("llama3-8b"))
    assert "long_500k" not in applicable_shapes(get_config("kimi-k2-1t-a32b"))


def test_param_counts_match_published():
    """Analytic counts hit the published sizes (the configs are real)."""
    expect = {
        "llama3-8b": 8.0e9, "qwen2.5-32b": 32.8e9, "mistral-nemo-12b": 12.2e9,
        "smollm-135m": 0.135e9, "qwen3-moe-235b-a22b": 235e9,
        "kimi-k2-1t-a32b": 1.04e12, "recurrentgemma-2b": 2.3e9,
    }
    for arch, n in expect.items():
        got = get_config(arch).param_count()
        assert abs(got - n) / n < 0.06, (arch, got, n)
