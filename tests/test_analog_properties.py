"""Property-based tests for the analog primitives (`core/switched_cap.py`,
`core/adc.py`): charge-share linearity and scale invariance, passive droop
monotone decay and its consistency with `SummerSpec.droop_factor`, and the
ADC encode->decode round-trip within 1 LSB.

Same pattern as `test_saliency_properties.py`: each invariant is a plain
checker; hypothesis drives them with adversarial inputs when installed
(requirements-dev), and a seeded deterministic battery always runs so the
physics invariants stay covered even without hypothesis (e.g. a bare-jax
container)."""

import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.adc import ADCSpec, adc_quantize, digital_readout
from repro.core.analog_nl import AnalogNLSpec, analog_nonlinearity
from repro.core.switched_cap import (
    SummerSpec,
    TAU_LEAK_65NM_S,
    charge_share_sum,
    passive_droop_trace,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# invariant checkers (shared by the hypothesis and deterministic drivers)
# ---------------------------------------------------------------------------

def check_charge_share_linearity(
    x: np.ndarray, y: np.ndarray, a: float, b: float, spec: SummerSpec
) -> None:
    """Charge conservation makes the summer linear in the charges:
    f(a*x + b*y) - V_R == a*(f(x) - V_R) + b*(f(y) - V_R)."""
    f = lambda v: np.asarray(charge_share_sum(jnp.asarray(v), spec))
    lhs = f(a * x + b * y) - spec.v_ref
    rhs = a * (f(x) - spec.v_ref) + b * (f(y) - spec.v_ref)
    scale = max(1.0, np.abs(lhs).max(), np.abs(rhs).max())
    np.testing.assert_allclose(lhs, rhs, atol=1e-5 * scale)


def check_charge_share_is_scaled_mean(x: np.ndarray, spec: SummerSpec) -> None:
    """The summing node settles at V_R + droop * mean(charges): the 1/N²
    factor is physics (total capacitance N²·C), not a design choice."""
    out = np.asarray(charge_share_sum(jnp.asarray(x), spec))
    want = spec.v_ref + spec.droop_factor() * x.mean(axis=-1)
    np.testing.assert_allclose(out, want, rtol=1e-5, atol=1e-6)


def check_droop_trace_monotone_and_calibrated(
    v0: float, times_us: np.ndarray
) -> None:
    """V(t) = v0 * exp(-t/tau): strictly monotone toward 0, and the 65 nm
    calibration point (10 % loss at 10 µs) falls on the curve."""
    t = np.sort(times_us) * 1e-6
    v = np.asarray(passive_droop_trace(jnp.float32(v0), jnp.asarray(t)))
    dv = np.diff(v)
    if v0 > 0:
        assert (dv <= 1e-7).all(), "positive hold voltage must decay"
    elif v0 < 0:
        assert (dv >= -1e-7).all(), "negative hold voltage must rise to 0"
    assert (np.abs(v) <= abs(v0) + 1e-7).all()
    v10 = float(passive_droop_trace(jnp.float32(v0), jnp.asarray([10e-6]))[0])
    np.testing.assert_allclose(v10, 0.9 * v0, rtol=1e-5, atol=1e-7)


def check_droop_factor_matches_trace(hold_us: float) -> None:
    """SummerSpec(mode='passive').droop_factor() must equal the trace's
    retention at hold_time for the same tau — one leakage model, two
    entry points."""
    spec = SummerSpec(mode="passive", hold_time_s=hold_us * 1e-6,
                      tau_leak_s=TAU_LEAK_65NM_S)
    trace = float(passive_droop_trace(jnp.float32(1.0),
                                      jnp.asarray([hold_us * 1e-6]))[0])
    np.testing.assert_allclose(spec.droop_factor(), trace, rtol=1e-6)


def check_adc_roundtrip_within_1_lsb(v: np.ndarray, bits: int) -> None:
    """encode->decode: inside the rails the code recovers the voltage to
    within LSB/2 (mid-rise quantizer); outside it clips to the rails. The
    full digital_readout additionally recovers sigma(W·P)/N² + b from
    Out_v = V_R + sigma within 1 LSB."""
    spec = ADCSpec(bits=bits)
    lsb = (spec.v_max - spec.v_min) / (spec.levels - 1)
    q = np.asarray(adc_quantize(jnp.asarray(v), spec))
    clipped = np.clip(v, spec.v_min, spec.v_max)
    assert (np.abs(q - clipped) <= lsb / 2 + 1e-7).all()
    # codes land on the grid (atol in code units: f32 voltage rounding is
    # ~1e-7/lsb codes, far below the 0.5 that would mean a wrong code)
    codes = (q - spec.v_min) / lsb
    np.testing.assert_allclose(codes, np.round(codes), atol=5e-3)

    v_ref, bias = 0.25, 0.03125
    sigma = clipped - v_ref                     # representable signal range
    dig = np.asarray(digital_readout(
        jnp.asarray(sigma + v_ref), v_ref, bias, spec))
    assert (np.abs(dig - (sigma + bias)) <= lsb / 2 + 1e-7).all()


# ---------------------------------------------------------------------------
# hypothesis drivers (adversarial inputs; skipped without hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    charges = st.integers(1, 64).flatmap(
        lambda n: st.lists(
            st.floats(-1.0, 1.0, allow_nan=False, width=32),
            min_size=n, max_size=n,
        ).map(lambda v: np.asarray(v, np.float32))
    )
    summer_specs = st.sampled_from([
        SummerSpec(),
        SummerSpec(v_ref=0.5),
        SummerSpec(mode="passive"),
        SummerSpec(mode="passive", hold_time_s=1e-6),
        SummerSpec(opamp_dc_gain=100.0),
    ])

    class TestHypothesis:
        @settings(max_examples=50, deadline=None)
        @given(charges, st.floats(-2, 2, width=32), st.floats(-2, 2, width=32),
               summer_specs)
        def test_charge_share_linearity(self, x, a, b, spec):
            y = x[::-1].copy()
            check_charge_share_linearity(x, y, float(a), float(b), spec)

        @settings(max_examples=50, deadline=None)
        @given(charges, summer_specs)
        def test_charge_share_is_scaled_mean(self, x, spec):
            check_charge_share_is_scaled_mean(x, spec)

        @settings(max_examples=40, deadline=None)
        @given(st.floats(-1, 1, allow_nan=False, width=32),
               st.lists(st.floats(0, 100, allow_nan=False, width=32),
                        min_size=2, max_size=16))
        def test_droop_trace(self, v0, times_us):
            check_droop_trace_monotone_and_calibrated(
                float(v0), np.asarray(times_us, np.float64))

        @settings(max_examples=30, deadline=None)
        @given(st.floats(0.01, 100.0, width=32))
        def test_droop_factor_matches_trace(self, hold_us):
            check_droop_factor_matches_trace(float(hold_us))

        @settings(max_examples=50, deadline=None)
        @given(st.integers(2, 12),
               st.lists(st.floats(-2, 2, allow_nan=False, width=32),
                        min_size=1, max_size=32))
        def test_adc_roundtrip(self, bits, volts):
            check_adc_roundtrip_within_1_lsb(
                np.asarray(volts, np.float32), bits)


# ---------------------------------------------------------------------------
# deterministic battery (always runs)
# ---------------------------------------------------------------------------

_SPECS = [
    SummerSpec(),
    SummerSpec(v_ref=0.5),
    SummerSpec(mode="passive"),
    SummerSpec(mode="passive", hold_time_s=1e-6),
    SummerSpec(opamp_dc_gain=100.0),
]


@pytest.mark.parametrize("spec", _SPECS, ids=lambda s: f"{s.mode}-vr{s.v_ref:g}")
@pytest.mark.parametrize("seed", range(4))
def test_charge_share_battery(spec, seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 65))
    x = rng.uniform(-1, 1, size=n).astype(np.float32)
    y = rng.uniform(-1, 1, size=n).astype(np.float32)
    a, b = rng.uniform(-2, 2, size=2)
    check_charge_share_linearity(x, y, float(a), float(b), spec)
    check_charge_share_is_scaled_mean(x, spec)
    # batched: one patch per row, same physics
    check_charge_share_is_scaled_mean(
        rng.uniform(-1, 1, size=(3, n)).astype(np.float32), spec)


@pytest.mark.parametrize("v0", [1.0, 0.5, -0.5, 0.0, 1e-3])
def test_droop_trace_battery(v0):
    times = np.asarray([0.0, 1.0, 2.0, 5.0, 10.0, 20.0, 50.0, 100.0])
    check_droop_trace_monotone_and_calibrated(v0, times)


@pytest.mark.parametrize("hold_us", [0.1, 1.0, 5.0, 10.0, 40.0])
def test_droop_factor_trace_consistency_battery(hold_us):
    check_droop_factor_matches_trace(hold_us)


@pytest.mark.parametrize("bits", [2, 4, 6, 8, 10, 12])
def test_adc_roundtrip_battery(bits):
    rng = np.random.default_rng(bits)
    v = np.concatenate([
        rng.uniform(-2, 2, size=64),
        np.asarray([-2.0, -1.0, -0.5, 0.0, 0.5, 1.0, 2.0]),   # rails + clip
        np.linspace(-1, 1, 2 ** min(bits, 8)),                # on/near grid
    ]).astype(np.float32)
    check_adc_roundtrip_within_1_lsb(v, bits)


def test_opamp_droop_is_gain_error_not_leak():
    """OpAmp mode pins the summing node at virtual ground: retention is
    A0/(1+A0) regardless of hold time — the 'amplifiers can be removed in
    lower-leakage technology' trade the paper discusses."""
    for hold in (1e-6, 10e-6, 1e-3):
        spec = SummerSpec(mode="opamp", hold_time_s=hold)
        assert spec.droop_factor() == pytest.approx(10_000.0 / 10_001.0)
    # passive retention does depend on hold time
    r1 = SummerSpec(mode="passive", hold_time_s=1e-6).droop_factor()
    r2 = SummerSpec(mode="passive", hold_time_s=10e-6).droop_factor()
    assert r1 > r2


# ---------------------------------------------------------------------------
# 2T analog nonlinearity (core/analog_nl.py) — DESIGN.md §13 satellite
# ---------------------------------------------------------------------------

def check_nl_clip_bounds(v: np.ndarray, spec) -> None:
    """'none' clips to the ±v_sat rails, 'relu' rectifies to [0, v_sat] —
    the supply rail is a hard bound whatever the input."""
    out = np.asarray(analog_nonlinearity(jnp.asarray(v), spec))
    lo = -spec.v_sat if spec.kind == "none" else 0.0
    assert out.min() >= lo - 1e-7 and out.max() <= spec.v_sat + 1e-7
    # inside the rails the transfer is the identity
    inside = (v > lo) & (v < spec.v_sat)
    np.testing.assert_allclose(out[inside], v[inside], rtol=1e-6)


def check_nl_grad_finite(v: np.ndarray, spec) -> None:
    g = np.asarray(jax.vmap(jax.grad(
        lambda x: analog_nonlinearity(x, spec)))(jnp.asarray(v)))
    assert np.isfinite(g).all(), f"{spec.kind}: non-finite grad"


if HAVE_HYPOTHESIS:

    @settings(deadline=None, max_examples=50)
    @given(
        v=st.lists(st.floats(-500.0, 500.0, allow_nan=False), min_size=1,
                   max_size=32),
        kind=st.sampled_from(["none", "relu", "sigmoid"]),
    )
    def test_nl_bounded_and_differentiable_hypothesis(v, kind):
        arr = np.asarray(v, np.float32)
        spec = AnalogNLSpec(kind=kind)
        if kind != "sigmoid":
            check_nl_clip_bounds(arr, spec)
        out = np.asarray(analog_nonlinearity(jnp.asarray(arr), spec))
        assert np.isfinite(out).all()
        assert np.abs(out).max() <= spec.v_sat + 1e-7
        check_nl_grad_finite(arr, spec)


@pytest.mark.parametrize("kind", ["none", "relu"])
def test_nl_clip_battery(kind):
    rng = np.random.default_rng(7)
    v = np.concatenate([
        rng.uniform(-3, 3, 64),
        [-200.0, -1.0, -0.5, 0.0, 0.5, 1.0, 200.0],
    ]).astype(np.float32)
    check_nl_clip_bounds(v, AnalogNLSpec(kind=kind))
    check_nl_grad_finite(v, AnalogNLSpec(kind=kind))


def test_nl_sigmoid_shape():
    """The S-curve: strictly monotone, open range (0, v_sat), gain sets
    the slope at the bias point."""
    spec = AnalogNLSpec(kind="sigmoid", v_sat=0.8)
    # strict monotonicity holds where f32 can still resolve the slope
    # (past gain·v ≈ ±17 the output rounds onto the rails — that flat
    # tail is the saturation, not a monotonicity bug)
    v = jnp.linspace(-2.0, 2.0, 201)
    out = np.asarray(analog_nonlinearity(v, spec))
    assert (np.diff(out) > 0).all()
    assert out.min() > 0.0 and out.max() < spec.v_sat
    wide = np.asarray(analog_nonlinearity(jnp.linspace(-300.0, 300.0, 201),
                                          spec))
    assert (np.diff(wide) >= 0).all()
    assert wide.min() >= 0.0 and wide.max() <= spec.v_sat
    assert analog_nonlinearity(jnp.float32(0.0), spec) == pytest.approx(
        spec.v_sat / 2)
    # slope at 0 is gain·v_sat/4 (d/dv sigmoid(g v)·v_sat at v=0)
    g0 = float(jax.grad(lambda x: analog_nonlinearity(x, spec))(jnp.float32(0.0)))
    assert g0 == pytest.approx(spec.sigmoid_gain * spec.v_sat / 4, rel=1e-5)


def test_nl_sigmoid_saturated_inputs_regression():
    """Regression for the overflow bug: the naive v_sat/(1+exp(-g·v))
    form overflows exp() to inf at g·v <= -89 in f32 — value AND (via
    inf/inf) STE gradient went NaN. The stable form must return a finite,
    saturated value and an exactly-zero-or-finite gradient at ±200."""
    spec = AnalogNLSpec(kind="sigmoid")
    for v in (-200.0, 200.0):
        out = float(analog_nonlinearity(jnp.float32(v), spec))
        assert np.isfinite(out)
        g = float(jax.grad(
            lambda x: analog_nonlinearity(x, spec))(jnp.float32(v)))
        assert np.isfinite(g)
    assert float(analog_nonlinearity(jnp.float32(-200.0), spec)) == 0.0
    assert float(analog_nonlinearity(jnp.float32(200.0), spec)) \
        == pytest.approx(spec.v_sat)
    # the naive form is genuinely the bug being guarded against
    naive = 1.0 / (1.0 + np.exp(np.float32(200.0 * spec.sigmoid_gain)))
    assert naive == 0.0 or not np.isfinite(
        np.exp(np.float32(200.0 * spec.sigmoid_gain)))


def test_nl_unknown_kind_raises():
    with pytest.raises(ValueError, match="unknown analog nonlinearity"):
        analog_nonlinearity(jnp.zeros(()), AnalogNLSpec(kind="tanh"))
