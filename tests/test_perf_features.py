"""Tests for the §Perf optimizations: int8 KV cache (B2), bf16 cache
contraction (B1 — covered by decode==forward tests), all_to_all MoE
dispatch (A2), quantized backend matmul."""

import dataclasses
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as M
from repro.configs import smoke_config
from repro.configs.base import MoEConfig

KEY = jax.random.PRNGKey(0)
SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_int8_kv_cache_decode_close_to_fp32():
    """int8 KV (B2) must track the fp32-cache decode within ~1.5% of the
    logit scale across a prefill + 8 decode steps."""
    cfg = dataclasses.replace(smoke_config("llama3-8b"), remat=False)
    params = M.init_params(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits_full, _ = M.forward(params, {"tokens": tokens}, cfg)
    scale = float(jnp.abs(logits_full).max())

    state = M.init_decode_state(cfg, M.DEFAULT_PLAN, 2, 16, cache_dtype=jnp.int8)
    lg, state = M.prefill(
        params, {"tokens": tokens[:, :8]}, cfg, M.DEFAULT_PLAN, state
    )
    errs = [float(jnp.abs(lg - logits_full[:, 7]).max())]
    for t in range(8, 16):
        lg, state = M.decode_step(params, state, tokens[:, t], jnp.int32(t), cfg)
        errs.append(float(jnp.abs(lg - logits_full[:, t]).max()))
    assert max(errs) / scale < 0.015, (max(errs), scale)


def test_int8_cache_state_has_scales():
    cfg = smoke_config("llama3-8b")
    st = M.init_decode_state(cfg, M.DEFAULT_PLAN, 2, 8, cache_dtype=jnp.int8)
    s0 = st["stacks"][0]
    assert s0["k"].dtype == jnp.int8 and "k_scale" in s0 and "v_scale" in s0
    specs = M.decode_state_specs(cfg, M.DEFAULT_PLAN, cache_dtype=jnp.int8)
    assert "k_scale" in specs["stacks"][0]


def test_moe_a2a_matches_reference_multihost():
    """A2 all_to_all dispatch == GSPMD reference on a (2,4) host mesh
    (ample capacity so no shard-local drops)."""
    code = """
        import json, dataclasses, jax, jax.numpy as jnp
        from repro.configs import smoke_config
        from repro.configs.base import MoEConfig
        from repro.models import moe as moe_mod
        from repro.models.moe_a2a import apply_moe_a2a
        cfg = smoke_config("qwen3-moe-235b-a22b")
        cfg = dataclasses.replace(cfg, moe=MoEConfig(
            n_experts=8, top_k=2, d_expert=32, capacity_factor=2.0))
        p = moe_mod.init_moe(jax.random.PRNGKey(0), cfg)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, cfg.d_model)) * 0.5
        ref, aux_ref = moe_mod.apply_moe(p, x, cfg)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        out, aux = jax.jit(lambda p_, x_: apply_moe_a2a(
            p_, x_, cfg, mesh, ("data",), "model"))(p, x)
        g = jax.grad(lambda p_: apply_moe_a2a(
            p_, x, cfg, mesh, ("data",), "model")[0].sum())(p)
        print(json.dumps({
            "diff": float(jnp.abs(out - ref).max()),
            "aux_diff": abs(float(aux) - float(aux_ref)),
            "gnorm": float(jnp.linalg.norm(g["w_gate"])),
        }))
    """
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["diff"] < 1e-5, res
    assert res["aux_diff"] < 1e-6, res
    assert res["gnorm"] > 0, res


def test_quant_matmul_backend_projection():
    """Beyond-paper int8 path on a backend projection keeps relative error
    at the quantization floor for realistic activations."""
    from repro.kernels import ops

    x = jax.random.normal(KEY, (7, 64)) * 0.3
    w = jax.random.normal(jax.random.PRNGKey(3), (64, 96)) * 0.1
    w8, sw = ops.quantize_weights_int8(w)
    y = ops.quant_matmul(x, w8, sw, interpret=True)
    rel = float(jnp.abs(y - x @ w).max() / jnp.abs(x @ w).max())
    assert rel < 0.03


def test_mlstm_chunkwise_matches_parallel():
    """§Perf X1: the chunked O(S·L) form must equal the O(S²) parallel form
    and produce the exact fold-state the decode path consumes."""
    from repro.models.xlstm import mlstm_chunkwise, mlstm_final_state, mlstm_parallel

    ks = jax.random.split(KEY, 5)
    b, s, nh, dh = 2, 37, 4, 16
    q = jax.random.normal(ks[0], (b, s, nh, dh))
    k = jax.random.normal(ks[1], (b, s, nh, dh))
    v = jax.random.normal(ks[2], (b, s, nh, dh))
    i = jax.random.normal(ks[3], (b, s, nh)) * 2
    f = jax.random.normal(ks[4], (b, s, nh)) * 2 + 2
    hp = mlstm_parallel(q, k, v, i, f)
    ref_cell = mlstm_final_state(k, v, i, f)
    for chunk in (8, 16, 64):
        hc, cell = mlstm_chunkwise(q, k, v, i, f, chunk)
        np.testing.assert_allclose(np.asarray(hc), np.asarray(hp), atol=1e-4)
        np.testing.assert_allclose(
            np.asarray(cell["C"]), np.asarray(ref_cell["C"]), atol=1e-4
        )


def test_xlstm_chunked_forward_matches_default():
    """Model-level: xlstm with xlstm_chunk set computes the same logits."""
    cfg = dataclasses.replace(smoke_config("xlstm-1.3b"), remat=False)
    params = M.init_params(KEY, cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    a, _ = M.forward(params, {"tokens": tokens}, cfg)
    b_, _ = M.forward(
        params, {"tokens": tokens}, dataclasses.replace(cfg, xlstm_chunk=8)
    )
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_), atol=2e-4)
