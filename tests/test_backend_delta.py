"""Delta-gated incremental backend (DESIGN.md §14): eps=0 bitwise
reproduction of the dense encoder over closed saccade-loop trajectories,
the fully-cached skip path, the ragged stale-prefix Pallas kernel, the
eps>0 error budget, and the engine-level BackendCache discipline.

Bitwise methodology: XLA fuses value-identical subgraphs differently
depending on their consumers (even two calls to the same function inside
one program can differ by 1-2 ulp), so dense-vs-delta bitwise equality
is asserted the only way it is well-defined — both encoders run as
STANDALONE compiled programs over the same MATERIALIZED wire block
(``cf``). Cross-program engine-vs-oracle comparisons follow the repo's
house discipline (atol=1e-5), same as tests/test_serve_engine.py.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import saliency as sal
from repro.core.frontend import FrontendConfig, apply_frontend
from repro.core.projection import PatchSpec
from repro.core.switched_cap import SummerSpec
from repro.core.temporal import TemporalSpec, init_feature_cache
from repro.data.pipeline import SceneStream
from repro.models import vit as vit_mod
from repro.models.backend_delta import (
    BackendCache, delta_forward, init_backend_cache, wipe_rows,
)
from repro.models.vit import ViTConfig, init_vit, vit_forward_compact
from repro.serve.engine import SaccadeEngine
from repro.serve import governor as gov_mod
from repro.serve.serve_step import (
    make_bootstrap_indices, make_saccade_step, saccade_scores,
)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    # passive droop-free summer: held gain is exactly 1.0 across frames,
    # so a static scene's wire rows are bitwise stable (the backend reuse
    # precondition); delta_threshold > 0 turns the temporal gate ON
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32,
                        summer=SummerSpec(mode="passive", hold_time_s=0.0)),
        active_fraction=0.25,
        temporal=TemporalSpec(delta_threshold=1e-3),
    )
    base = dict(frontend=fcfg, n_layers=2, d_model=32, n_heads=2, d_ff=64)
    base.update(kw)
    return ViTConfig(**base)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    return cfg, init_vit(KEY, cfg)


def _embed(params, cf, cfg):
    return (vit_mod._embed_tokens(params, cf, cfg)
            + params["pos"][cf.indices])


def _make_progs(cfg):
    """The bitwise harness: frontend, dense encoder, delta encoder as
    three standalone programs sharing materialized wire blocks."""

    @jax.jit
    def frontend(params, rgb, idx, tcache):
        return apply_frontend(params["ip2"], rgb, cfg.frontend,
                              indices=idx, mode="compact", cache=tcache)

    @jax.jit
    def dense_enc(params, cf):
        x = _embed(params, cf, cfg)
        return vit_mod._encoder(params, x, cfg, cf.valid)

    @jax.jit
    def delta_enc(params, cf, bc, eps):
        return delta_forward(params, cfg, cf,
                             lambda: _embed(params, cf, cfg), bc, eps)

    return frontend, dense_enc, delta_enc


def _select(cf, received, cfg, explore=0.1):
    rec = jnp.where(cf.valid, received, 0.0)
    b = jnp.arange(rec.shape[0])[:, None]
    saliency = jnp.zeros(
        (rec.shape[0], cfg.frontend.n_patches), jnp.float32
    ).at[b, cf.indices].max(rec)
    aux = {"saliency": saliency, "indices": cf.indices,
           "valid": cf.valid, "energy": cf.energy}
    return sal.topk_patch_indices(
        saccade_scores(aux, explore), cfg.frontend.n_active)


class TestBitwiseTrajectory:
    """The §14 acceptance gate: eps=0 reproduces the dense backend
    BITWISE over a full closed saccade-loop trajectory — through the
    compute, partial-reuse, and fully-cached skip regimes."""

    def test_eps0_bitwise_over_closed_saccade_loop(self, served):
        cfg, params = served
        k = cfg.frontend.n_active
        frontend, dense_enc, delta_enc = _make_progs(cfg)
        imgs, _ = SceneStream(image=64).batch(0, 2)
        idx = make_bootstrap_indices(cfg)(params, jnp.asarray(imgs))
        tcache = init_feature_cache(cfg.frontend, (2,))
        bc = init_backend_cache(cfg, k, (2,),
                                dtype=cfg.frontend.adc.code_dtype)
        eps0 = jnp.zeros((2,), jnp.float32)
        dense_macs = None
        macs_hist = []
        rgb = jnp.asarray(imgs)
        for t in range(16):
            if t < 8:
                # phase 1: closed loop over a slowly panning scene
                rgb = jnp.asarray(np.roll(imgs, t // 3, axis=2))
            # phase 2 (t >= 8): frozen frame + frozen gaze — the wire
            # holds bitwise and the skip regime must engage
            cf, tcache = frontend(params, rgb, idx, tcache)
            jax.block_until_ready(cf)        # materialize the shared wire
            ld, rd = dense_enc(params, cf)
            lb, rb, bc, macs = delta_enc(params, cf, bc, eps0)
            np.testing.assert_array_equal(
                np.asarray(ld), np.asarray(lb),
                err_msg=f"frame {t}: delta logits diverged from dense")
            np.testing.assert_array_equal(
                np.asarray(rd), np.asarray(rb),
                err_msg=f"frame {t}: delta saliency diverged from dense")
            macs_hist.append(np.asarray(macs))
            if dense_macs is None:
                dense_macs = float(np.max(np.asarray(macs)))
            if t < 8:
                idx = _select(cf, rd, cfg)
        # the trajectory must actually exercise all three regimes
        flat = np.stack(macs_hist)
        assert float(flat[0].max()) == dense_macs        # cold: dense work
        assert (flat[-4:] == 0.0).all(), (
            f"frozen-scene tail never reached the fully-cached skip: "
            f"{flat[-4:]}")
        mid = flat[(flat > 0.0) & (flat < dense_macs)]
        assert mid.size > 0, "trajectory never hit the partial-reuse regime"

    def test_skip_frame_serves_cached_logits_and_cache_passthrough(
            self, served):
        cfg, params = served
        k = cfg.frontend.n_active
        frontend, dense_enc, delta_enc = _make_progs(cfg)
        imgs, _ = SceneStream(image=64).batch(1, 1)
        rgb = jnp.asarray(imgs)
        idx = make_bootstrap_indices(cfg)(params, rgb)
        tcache = init_feature_cache(cfg.frontend, (1,))
        bc = init_backend_cache(cfg, k, (1,),
                                dtype=cfg.frontend.adc.code_dtype)
        eps0 = jnp.zeros((1,), jnp.float32)
        cf, tcache = frontend(params, rgb, idx, tcache)
        l1, r1, bc1, m1 = delta_enc(params, cf, bc, eps0)
        assert float(m1[0]) > 0.0
        # identical frame, identical gaze: wire holds -> whole-batch skip
        cf2, tcache = frontend(params, rgb, idx, tcache)
        l2, r2, bc2, m2 = delta_enc(params, cf2, bc1, eps0)
        assert float(m2[0]) == 0.0
        np.testing.assert_array_equal(np.asarray(l1), np.asarray(l2))
        np.testing.assert_array_equal(np.asarray(r1), np.asarray(r2))
        # the cache passes through bitwise on a skip frame
        for a, b in zip(jax.device_get(bc1), jax.device_get(bc2)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_act_mask_keeps_fleet_skip_alive(self, served):
        """A held/empty slot (cache never valid) must not force a compute
        frame on an otherwise fully-cached fleet (DESIGN.md §14)."""
        cfg, params = served
        k = cfg.frontend.n_active
        frontend, _, _ = _make_progs(cfg)
        imgs, _ = SceneStream(image=64).batch(1, 2)
        rgb = jnp.asarray(imgs)
        idx = make_bootstrap_indices(cfg)(params, rgb)
        tcache = init_feature_cache(cfg.frontend, (2,))
        bc = init_backend_cache(cfg, k, (2,),
                                dtype=cfg.frontend.adc.code_dtype)
        eps0 = jnp.zeros((2,), jnp.float32)

        @jax.jit
        def delta_act(params, cf, bc, eps, act):
            return delta_forward(params, cfg, cf,
                                 lambda: _embed(params, cf, cfg), bc, eps,
                                 act=act)

        cf, tcache = frontend(params, rgb, idx, tcache)
        act = jnp.array([True, False])
        _, _, bc, m1 = delta_act(params, cf, bc, eps0, act)
        # emulate the engine's hold freeze: the held slot's cache rows
        # are DISCARDED (it never advanced), so its cache stays invalid
        bc = wipe_rows(bc, ~act)
        cf2, tcache = frontend(params, rgb, idx, tcache)
        # slot 1's cache is still invalid (it never advanced), but only
        # slot 0 is active — the whole batch must skip
        _, _, _, m2 = delta_act(params, cf2, bc, eps0, act)
        assert float(m2[0]) == 0.0 and float(m2[1]) == 0.0
        # without the mask, the invalid held slot forces compute
        _, _, _, m3 = _make_progs(cfg)[2](params, cf2, bc, eps0)
        assert float(m3[0]) > 0.0


class TestEpsBudget:
    """eps > 0 trades a measured logit-error bound for deeper reuse."""

    def _traj_error(self, cfg, params, eps_val, frames=8):
        frontend, dense_enc, delta_enc = _make_progs(cfg)
        imgs, _ = SceneStream(image=64).batch(2, 2)
        base = imgs
        idx = make_bootstrap_indices(cfg)(params, jnp.asarray(base))
        tcache = init_feature_cache(cfg.frontend, (2,))
        bc = init_backend_cache(cfg, cfg.frontend.n_active, (2,),
                                dtype=cfg.frontend.adc.code_dtype)
        eps = jnp.full((2,), eps_val, jnp.float32)
        err, total_macs = 0.0, 0.0
        for t in range(frames):
            # low-amplitude drift: the regime eps is built to absorb
            rgb = jnp.asarray(
                np.clip(base + 0.002 * t, 0.0, 1.0).astype(np.float32))
            cf, tcache = frontend(params, rgb, idx, tcache)
            jax.block_until_ready(cf)
            ld, rd = dense_enc(params, cf)
            lb, _, bc, macs = delta_enc(params, cf, bc, eps)
            err = max(err, float(jnp.max(jnp.abs(ld - lb))))
            total_macs += float(jnp.sum(macs))
            idx = _select(cf, rd, cfg)
        return err, total_macs

    def test_eps_zero_is_exact_and_error_grows_measured(self, served):
        cfg, params = served
        err0, macs0 = self._traj_error(cfg, params, 0.0)
        err_small, macs_small = self._traj_error(cfg, params, 1e-4)
        err_big, macs_big = self._traj_error(cfg, params, 5e-1)
        assert err0 == 0.0                       # the bitwise regime
        # the bound is MEASURED: a small budget keeps logits tight
        assert err_small <= 0.05, err_small
        # and a coarse budget errs more than a tight one while doing
        # no more work (snapped rows stop propagating)
        assert err_big >= err_small
        assert macs_big <= macs_small <= macs0


class TestDeltaAttentionKernel:
    """kernels/vit_delta_attention.py: ragged stale-prefix attention vs
    the einsum reference, across prefix counts including 0 and full."""

    def _ref(self, q, k, v, key_mask, q_counts):
        dh = q.shape[-1]
        qt = jnp.einsum("bshk->bhsk", q)
        kt = jnp.einsum("bshk->bhsk", k)
        vt = jnp.einsum("bshk->bhsk", v)
        sc = jnp.einsum("bhqk,bhsk->bhqs", qt, kt) / jnp.sqrt(
            jnp.asarray(dh, jnp.float32))
        sc = jnp.where(key_mask[:, None, None, :], sc, -1e30)
        o = jnp.einsum("bhqs,bhsk->bhqk", jax.nn.softmax(sc, axis=-1), vt)
        o = jnp.einsum("bhqk->bqhk", o)
        rows = jnp.arange(q.shape[1])[None, :, None, None]
        return jnp.where(rows < q_counts[:, None, None, None], o, 0.0)

    @pytest.mark.parametrize("seed", [0, 1])
    def test_interpret_parity_random_prefixes(self, seed):
        from repro.kernels.vit_delta_attention import delta_attention_pallas

        rng = np.random.default_rng(seed)
        b, s, h, dh = 3, 8, 2, 16
        q = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
        k = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
        v = jnp.asarray(rng.normal(size=(b, s, h, dh)).astype(np.float32))
        mask = jnp.asarray(rng.random((b, s)) < 0.8)
        mask = mask.at[:, 0].set(True)          # never fully masked
        counts = jnp.asarray([0, 3, s], jnp.int32)   # empty / ragged / full
        out = delta_attention_pallas(q, k, v, mask, counts,
                                     block_q=4, interpret=True)
        ref = self._ref(q, k, v, mask, counts)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-6, rtol=1e-6)
        # rows past the prefix are EXACT zeros (the caller treats them
        # as garbage and must be able to rely on the zero fill)
        assert float(jnp.max(jnp.abs(out[0]))) == 0.0

    def test_ops_wrapper_matches_encoder_attention_on_prefix(self, served):
        """ops.delta_attention (projections + kernel + output proj) must
        match the dense _encoder_attention on the covered prefix rows."""
        from repro.kernels import ops

        cfg, params = served
        lp = params["layers"][0]
        rng = np.random.default_rng(0)
        b, s, d = 2, cfg.frontend.n_active, cfg.d_model
        h = jnp.asarray(rng.normal(size=(b, s, d)).astype(np.float32))
        valid = jnp.ones((b, s), bool)
        counts = jnp.full((b,), s, jnp.int32)
        out = ops.delta_attention(lp["attn"], h, valid, counts,
                                  cfg.n_heads, block_q=4, interpret=True)
        ref, _ = vit_mod._encoder_attention(lp, h, cfg, valid,
                                            need_probs=False)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5, rtol=1e-5)

    def test_pick_block_q_is_modeled_argmin(self):
        from repro.kernels.vit_delta_attention import pick_block_q
        from repro.roofline import analysis

        cands = (4, 8, 16, 32)
        for k_tok, d_model, heads in [(16, 64, 4), (64, 256, 8)]:
            got = pick_block_q(k_tok, d_model, heads, expect_stale=6,
                               candidates=cands)
            costs = {bq: analysis.delta_attention_cost(
                6, k_tok, d_model, heads, block_q=bq)["time_s"]
                for bq in cands}
            assert got == min(costs, key=costs.get)


class TestValidationAndDiscipline:
    def test_backend_eps_without_cache_raises(self, served):
        cfg, params = served
        rgb = jnp.zeros((1, 64, 64, 3), jnp.float32)
        with pytest.raises(ValueError, match="backend_eps"):
            vit_forward_compact(params, rgb, cfg,
                                backend_eps=jnp.zeros((1,)))

    def test_cache_dtype_mismatch_raises(self, served):
        cfg, params = served
        rgb = jnp.zeros((1, 64, 64, 3), jnp.float32)
        bad = init_backend_cache(cfg, cfg.frontend.n_active, (1,),
                                 dtype=jnp.float32)
        with pytest.raises(ValueError, match="dtype"):
            vit_forward_compact(params, rgb, cfg, backend_cache=bad)

    def test_cache_shape_mismatch_raises(self, served):
        cfg, params = served
        rgb = jnp.zeros((1, 64, 64, 3), jnp.float32)
        bad = init_backend_cache(cfg, cfg.frontend.n_active + 1, (1,),
                                 dtype=cfg.frontend.adc.code_dtype)
        with pytest.raises(ValueError, match="rows"):
            vit_forward_compact(params, rgb, cfg, backend_cache=bad)

    def test_fused_embed_rejects_backend_cache(self, served):
        cfg, params = served
        fused = dataclasses.replace(cfg, quant_embed=True, fused_embed=True)
        rgb = jnp.zeros((1, 64, 64, 3), jnp.float32)
        bc = init_backend_cache(cfg, cfg.frontend.n_active, (1,),
                                dtype=cfg.frontend.adc.code_dtype)
        with pytest.raises(ValueError, match="fused_embed"):
            vit_forward_compact(params, rgb, fused, backend_cache=bc)

    def test_wipe_rows_zeroes_hit_rows_dtype_preserving(self, served):
        cfg, _ = served
        bc = BackendCache(*(
            jnp.ones_like(leaf) if leaf.dtype != jnp.bool_
            else jnp.ones_like(leaf)
            for leaf in init_backend_cache(
                cfg, cfg.frontend.n_active, (3,),
                dtype=cfg.frontend.adc.code_dtype)))
        hit = jnp.array([True, False, True])
        wiped = wipe_rows(bc, hit)
        for before, after in zip(bc, wiped):
            assert after.dtype == before.dtype
            assert not np.asarray(after[0]).any()
            assert not np.asarray(after[2]).any()
            np.testing.assert_array_equal(np.asarray(after[1]),
                                          np.asarray(before[1]))

    def test_saliency_layers_validated(self, served):
        cfg, params = served
        bad = dataclasses.replace(cfg, saliency_layers="first")
        rgb = jnp.zeros((1, 64, 64, 3), jnp.float32)
        with pytest.raises(ValueError, match="saliency_layers"):
            vit_forward_compact(params, rgb, bad)


class TestEngineBackend:
    """SaccadeEngine(backend_delta=True): twin equivalence, per-slot
    reuse state across churn, the governed eps knob — house allclose
    discipline (cross-program oracles, atol=1e-5)."""

    def test_twin_engine_matches_dense_engine(self, served):
        cfg, params = served
        imgs, _ = SceneStream(image=64).batch(3, 2)
        eng_d = SaccadeEngine(cfg, params, capacity=2, temporal=True)
        eng_b = SaccadeEngine(cfg, params, capacity=2, temporal=True,
                              backend_delta=True)
        for e in (eng_d, eng_b):
            e.admit("a")
            e.admit("b")
        for t in range(8):
            od = eng_d.step({"a": imgs[0], "b": imgs[1]})
            ob = eng_b.step({"a": imgs[0], "b": imgs[1]})
            for sid in od:
                np.testing.assert_allclose(od[sid], ob[sid], atol=1e-5)
        assert eng_b.n_traces == 1
        assert np.array_equal(eng_d.gaze("a"), eng_b.gaze("a"))

    def test_static_stream_reaches_zero_backend_macs(self, served):
        cfg, params = served
        # the explore/baseline policy period-2 oscillates the gaze on some
        # scenes; pick one whose selection converges (batch(0,4) image 0:
        # fully cached from step 2 on)
        imgs, _ = SceneStream(image=64).batch(0, 4)
        # empty slots must not block the whole-batch skip (act mask)
        eng = SaccadeEngine(cfg, params, capacity=4, temporal=True,
                            backend_delta=True)
        eng.admit("a")
        for t in range(10):
            eng.step({"a": imgs[0]})
        assert eng.backend_cached("a")
        assert float(eng.events("a", "last").backend_macs) == 0.0

    def test_churn_wipes_backend_cache_without_retrace(self, served):
        cfg, params = served
        imgs, _ = SceneStream(image=64).batch(0, 2)
        eng = SaccadeEngine(cfg, params, capacity=2, temporal=True,
                            backend_delta=True)
        eng.admit("a")
        eng.admit("b")
        for t in range(3):
            eng.step({"a": imgs[0], "b": imgs[1]})
        assert bool(eng.state.bcache.valid[eng.slot_of("a")])
        eng.evict("a")
        eng.admit("c")
        st = eng.state
        slot = eng.slot_of("c")
        assert not bool(st.bcache.valid[slot])
        assert not np.asarray(st.bcache.feats[slot]).any()
        assert st.bcache.feats.dtype == cfg.frontend.adc.code_dtype
        eng.step({"c": imgs[0], "b": imgs[1]})
        assert eng.n_traces == 1

    def test_held_slot_backend_cache_is_bitwise_frozen(self, served):
        cfg, params = served
        imgs, _ = SceneStream(image=64).batch(0, 2)
        eng = SaccadeEngine(cfg, params, capacity=2, temporal=True,
                            backend_delta=True)
        eng.admit("a")
        eng.admit("b")
        eng.step({"a": imgs[0], "b": imgs[1]})
        before = jax.device_get(eng.state.bcache)
        eng.step({"a": imgs[0]})                 # b holds
        after = jax.device_get(eng.state.bcache)
        for x, y in zip(before, after):
            np.testing.assert_array_equal(np.asarray(x[1]), np.asarray(y[1]))

    def test_governor_eps_knob_engages_and_recovers(self, served):
        cfg, params = served
        imgs, _ = SceneStream(image=64).batch(0, 1)
        spec = gov_mod.GovernorSpec(budget_mw=1e-4, backend_eps=0.05)
        eng = SaccadeEngine(cfg, params, capacity=1, temporal=True,
                            governor=spec, backend_delta=True)
        eng.admit("a")
        for t in range(4):
            eng.step({"a": imgs[0]})
        # starved budget: the backend epsilon tier engages
        assert eng.backend_eps("a") == pytest.approx(0.05)
        # slack budget: it recovers to exact
        eng.set_budget_mw(1e6)
        for t in range(4):
            eng.step({"a": imgs[0]})
        assert eng.backend_eps("a") == 0.0
        assert eng.n_traces == 1                 # data knob, one compile

    def test_governor_backend_eps_requires_backend_delta(self, served):
        cfg, params = served
        spec = gov_mod.GovernorSpec(budget_mw=1.0, backend_eps=0.05)
        with pytest.raises(ValueError, match="backend_delta"):
            SaccadeEngine(cfg, params, capacity=1, temporal=True,
                          governor=spec)

    def test_backend_accessors_raise_when_unbuilt(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=1, temporal=True)
        eng.admit("a")
        with pytest.raises(RuntimeError, match="backend_delta"):
            eng.backend_cached("a")
        spec = gov_mod.GovernorSpec(budget_mw=1.0)
        eng_g = SaccadeEngine(cfg, params, capacity=1, temporal=True,
                              governor=spec)
        eng_g.admit("a")
        with pytest.raises(RuntimeError, match="backend_delta"):
            eng_g.backend_eps("a")


class TestStatefulFuzzBackend:
    """Random admit/evict/partial-step churn on a backend-delta engine
    against per-stream dense-backend single-stream loops: arbitrary
    stale patterns (frame pools + frame-rate skew drive arbitrary
    hold/change row mixes) must never diverge past the house tolerance."""

    @pytest.mark.parametrize("seed", [0, 1])
    def test_random_async_churn_backend_vs_dense_oracle(self, served, seed):
        cfg, params = served
        capacity = 3
        eng = SaccadeEngine(cfg, params, capacity=capacity, temporal=True,
                            backend_delta=True)
        boot = jax.jit(make_bootstrap_indices(cfg))
        step1 = jax.jit(make_saccade_step(cfg, temporal=True))
        pool = SceneStream(image=64).batch(7000 + seed, 6)[0]

        rng = np.random.default_rng(500 + seed)
        slots: list = [None] * capacity
        refs: dict = {}              # sid -> [idx, tcache, age]
        next_id = 0
        for op_i in range(30):
            op = rng.choice(["admit", "evict", "step"], p=[0.3, 0.15, 0.55])
            if op == "admit":
                if None not in slots:
                    continue
                sid = f"s{next_id}"
                next_id += 1
                slots[slots.index(None)] = sid
                eng.admit(sid)
                refs[sid] = [None, init_feature_cache(cfg.frontend, (1,)), 0]
            elif op == "evict":
                live = [s for s in slots if s is not None]
                if not live:
                    continue
                sid = live[int(rng.integers(len(live)))]
                eng.evict(sid)
                slots[slots.index(sid)] = None
                del refs[sid]
            else:
                live = [s for s in slots if s is not None]
                fed = [sid for sid in live if rng.random() < 0.7]
                frames = {
                    # repeat frames often (held rows) with occasional
                    # switches (stale rows): arbitrary reuse patterns
                    sid: pool[(slots.index(sid) + refs[sid][2] // 3)
                              % len(pool)]
                    for sid in fed
                }
                out = eng.step(frames)
                for sid in fed:
                    r = jnp.asarray(frames[sid])[None]
                    if refs[sid][0] is None:
                        refs[sid][0] = boot(params, r)
                    logits, refs[sid][0], _, refs[sid][1] = step1(
                        params, r, refs[sid][0], refs[sid][1])
                    np.testing.assert_allclose(
                        out[sid], np.asarray(logits[0]), atol=1e-5,
                        err_msg=f"op {op_i}: stream {sid} diverged")
                    refs[sid][2] += 1
        assert eng.n_traces <= 1
