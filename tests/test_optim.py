"""Optimizer / schedule / compression invariants (hypothesis property tests
run only when hypothesis is installed; see requirements-dev.txt)."""

import jax
import jax.numpy as jnp
import numpy as np

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.optim import AdamWConfig, adamw_update, cosine_with_warmup, init_opt_state
from repro.optim.compression import quantize_ef


def test_adamw_descends_quadratic_fixed_seed():
    """AdamW on f(x)=|x|² must decrease the loss (deterministic fallback for
    the hypothesis sweep below)."""
    params = {"x": jax.random.normal(jax.random.PRNGKey(3), (16,)) * 3}
    opt = AdamWConfig(lr=1e-3, weight_decay=0.0)
    state = init_opt_state(params, opt)
    loss = lambda p: jnp.sum(p["x"] ** 2)
    l0 = float(loss(params))
    for _ in range(25):
        g = jax.grad(loss)(params)
        params, state, _ = adamw_update(g, state, params, opt, jnp.float32(opt.lr))
    assert float(loss(params)) < l0


if HAVE_HYPOTHESIS:

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), lr=st.floats(1e-5, 1e-2))
    def test_adamw_descends_quadratic(seed, lr):
        """AdamW on f(x)=|x|² must decrease the loss from any start."""
        key = jax.random.PRNGKey(seed)
        params = {"x": jax.random.normal(key, (16,)) * 3}
        opt = AdamWConfig(lr=lr, weight_decay=0.0)
        state = init_opt_state(params, opt)
        loss = lambda p: jnp.sum(p["x"] ** 2)
        l0 = float(loss(params))
        for _ in range(25):
            g = jax.grad(loss)(params)
            params, state, _ = adamw_update(g, state, params, opt, jnp.float32(lr))
        assert float(loss(params)) < l0


def test_adamw_grad_clip_bounds_update():
    params = {"x": jnp.zeros((4,))}
    opt = AdamWConfig(lr=1.0, grad_clip=1.0, weight_decay=0.0)
    state = init_opt_state(params, opt)
    g = {"x": jnp.full((4,), 1e6)}                    # exploding grads
    new_params, _, m = adamw_update(g, state, params, opt, jnp.float32(0.1))
    assert float(jnp.abs(new_params["x"]).max()) < 1.0
    assert float(m["grad_norm"]) > 1e5                # norm reported unclipped


def test_adamw_bf16_moments_roundtrip():
    params = {"x": jnp.ones((8,))}
    opt = AdamWConfig(moment_dtype=jnp.bfloat16)
    state = init_opt_state(params, opt)
    assert state["m"]["x"].dtype == jnp.bfloat16
    g = {"x": jnp.full((8,), 0.1)}
    _, state, _ = adamw_update(g, state, params, opt, jnp.float32(1e-3))
    assert state["m"]["x"].dtype == jnp.bfloat16      # dtype preserved


def test_cosine_schedule_shape():
    steps = jnp.arange(0, 1000)
    lr = jax.vmap(lambda s: cosine_with_warmup(s, 1e-3, 100, 1000))(steps)
    assert float(lr[0]) == 0.0
    assert float(lr[100]) >= float(lr[999])           # decays after warmup
    assert np.argmax(np.asarray(lr)) <= 101           # peak at end of warmup
    assert float(lr[999]) >= 1e-4 - 1e-9              # floor = min_ratio*base


def _check_error_feedback_identity(seed: int):
    """codes*scale + err == corrected input (exact decomposition)."""
    g = jax.random.normal(jax.random.PRNGKey(seed), (64,))
    err0 = jax.random.normal(jax.random.PRNGKey(seed + 1), (64,)) * 0.01
    scale = jnp.max(jnp.abs(g + err0)) / 127.0
    codes, err = quantize_ef(g, err0, scale)
    np.testing.assert_allclose(
        np.asarray(codes.astype(jnp.float32) * scale + err),
        np.asarray(g + err0), rtol=1e-5, atol=1e-6,
    )
    assert float(jnp.abs(err).max()) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_identity_fixed_seed():
    _check_error_feedback_identity(0)


if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_error_feedback_identity(seed):
        _check_error_feedback_identity(seed)
