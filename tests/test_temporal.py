"""Temporal delta-gated execution (DESIGN.md §6): threshold-0 equivalence
with the always-recompute compact path, static-scene reuse within the
droop budget, budget-j deferred refresh, droop-forced refresh cycles, and
the gate threaded through the saccade step and the multi-stream engine."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

import repro.core as c
from repro.core.frontend import FrontendConfig, apply_frontend
from repro.core.projection import PatchSpec
from repro.core.switched_cap import SummerSpec
from repro.core.temporal import TemporalSpec, init_feature_cache
from repro.data.pipeline import SceneStream
from repro.kernels import ops
from repro.models.vit import ViTConfig, init_vit, vit_forward_compact
from repro.serve.engine import SaccadeEngine
from repro.serve.serve_step import make_bootstrap_indices, make_saccade_step

KEY = jax.random.PRNGKey(0)


def _fcfg(**kw):
    base = dict(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    base.update(kw)
    return FrontendConfig(**base)


def _vcfg(fcfg, **kw):
    base = dict(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    base.update(kw)
    return ViTConfig(**base)


class TestMaxHoldFrames:
    def test_opamp_holds_many_frames(self):
        spec = TemporalSpec(droop_lsb_budget=0.5)
        h = spec.max_hold_frames(SummerSpec(), c.ADCSpec())
        # d = A0/(1+A0) = 1e4/(1e4+1): ~1e-4 droop per hold, 0.5 LSB ~ 3.9e-3
        assert 30 <= h <= 50

    def test_passive_65nm_cannot_hold(self):
        """10% droop per 10us hold >> a 0.5-LSB budget: even ONE hold
        violates it, so the gate must recompute every frame (h=0), never
        serve a held value."""
        spec = TemporalSpec(droop_lsb_budget=0.5)
        h = spec.max_hold_frames(SummerSpec(mode="passive"), c.ADCSpec())
        assert h == 0

    def test_budget_monotone_in_hold_count(self):
        summer, adc = SummerSpec(), c.ADCSpec()
        holds = [TemporalSpec(droop_lsb_budget=b).max_hold_frames(summer, adc)
                 for b in (0.25, 0.5, 1.0, 2.0)]
        assert holds == sorted(holds) and holds[0] < holds[-1]

    def test_bound_is_tight(self):
        """h holds stay within budget; h+1 holds exceed it (full-scale)."""
        summer, adc = SummerSpec(), c.ADCSpec()
        spec = TemporalSpec(droop_lsb_budget=0.5)
        h = spec.max_hold_frames(summer, adc)
        d = summer.droop_factor()
        lsb = (adc.v_max - adc.v_min) / (adc.levels - 1)
        assert (1 - d ** h) * adc.v_max <= spec.droop_lsb_budget * lsb
        assert (1 - d ** (h + 1)) * adc.v_max > spec.droop_lsb_budget * lsb


class TestGateEquivalence:
    """Acceptance: threshold 0 => the gated path IS the PR-2 compact path."""

    def test_threshold0_features_bitwise(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(jax.random.PRNGKey(3), (3, 64, 64, 3))
        cf0 = apply_frontend(params, rgb, fcfg, mode="compact")
        cache = init_feature_cache(fcfg, (3,))
        for _ in range(3):   # every frame recomputes everything => bitwise
            cf1, cache = apply_frontend(
                params, rgb, fcfg, mode="compact", cache=cache)
            np.testing.assert_array_equal(
                np.asarray(cf0.features), np.asarray(cf1.features))
            np.testing.assert_array_equal(
                np.asarray(cf0.indices), np.asarray(cf1.indices))
        assert int(cache.n_stale.min()) == fcfg.n_active
        assert int(cache.age.max()) == 0    # nothing ever held

    def test_threshold0_saccade_logits_bitwise(self):
        cfg = _vcfg(_fcfg())
        params = init_vit(KEY, cfg)
        stream = SceneStream(image=64)
        plain = jax.jit(make_saccade_step(cfg))
        gated = jax.jit(make_saccade_step(cfg, temporal=True))
        idx = make_bootstrap_indices(cfg)(
            params, jnp.asarray(stream.batch(0, 2)[0]))
        idx_p = idx_g = idx
        cache = init_feature_cache(cfg.frontend, (2,))
        for t in range(3):
            rgb = jnp.asarray(stream.batch(t, 2)[0])
            lp, idx_p, _ = plain(params, rgb, idx_p)
            lg, idx_g, aux, cache = gated(params, rgb, idx_g, cache)
            np.testing.assert_array_equal(np.asarray(lp), np.asarray(lg))
            np.testing.assert_array_equal(np.asarray(idx_p), np.asarray(idx_g))
            assert int(aux["n_stale"].min()) == cfg.frontend.n_active

    def test_dense_mode_rejects_cache(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        with pytest.raises(ValueError, match="bypass"):
            apply_frontend(params, rgb, fcfg, mode="dense",
                           cache=init_feature_cache(fcfg, (1,)))


class TestStaticSceneReuse:
    """Acceptance: on a static scene, recompute fraction <= 10 % after
    frame 0 while logits stay within the droop-budget tolerance of the
    always-recompute oracle."""

    def test_t8_static_scene(self):
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6))
        cfg = _vcfg(fg, n_layers=2, d_model=64, n_heads=4, d_ff=128)
        params = init_vit(KEY, cfg)
        rgb = jnp.asarray(SceneStream(image=64).batch(0, 3)[0])  # frozen frame

        # fixed gaze (static scene): selection from the energy bootstrap
        idx = make_bootstrap_indices(cfg)(params, rgb)
        logits_oracle, _ = vit_forward_compact(params, rgb, cfg, indices=idx)

        cache = init_feature_cache(fg, (3,))
        k = fg.n_active
        fracs = []
        for t in range(8):
            logits, aux = vit_forward_compact(
                params, rgb, cfg, indices=idx, cache=cache)
            cache = aux["cache"]
            fracs.append(float(np.mean(np.asarray(aux["n_stale"])) / k))
        assert fracs[0] == 1.0                       # cold cache: all stale
        assert max(fracs[1:]) <= 0.10                # acceptance criterion

        # served features droop by at most (1 - d^7) of full scale, well
        # inside the 0.5-LSB budget; require the logits to stay within a
        # tolerance derived from that budget (k tokens x d_model mixing)
        lsb = (fg.adc.v_max - fg.adc.v_min) / (fg.adc.levels - 1)
        tol = fg.temporal.droop_lsb_budget * lsb * 10.0
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(logits_oracle), atol=tol)

    def test_changed_patch_is_detected(self):
        """Change the *content* of exactly one selected patch between
        frames: only that patch goes stale. (The detector is AC energy —
        mean-centered — so it keys on contrast, not absolute brightness:
        a global illumination shift is free.)"""
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-3))
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(9), (1, 64, 64, 3))
        cf = apply_frontend(params, rgb, fg, mode="compact")
        idx = cf.indices                              # fix the gaze

        cache = init_feature_cache(fg, (1,))
        _, cache = apply_frontend(params, rgb, fg, mode="compact",
                                  indices=idx, cache=cache)
        assert int(cache.n_stale[0]) == fg.n_active   # cold

        _, cache = apply_frontend(params, rgb, fg, mode="compact",
                                  indices=idx, cache=cache)
        assert int(cache.n_stale[0]) == 0             # static

        target = int(np.asarray(idx)[0, 0])           # flatten one patch's texture
        gh = 64 // 16
        py, px = divmod(target, gh)
        rgb2 = rgb.at[0, py * 16:(py + 1) * 16, px * 16:(px + 1) * 16, :].multiply(0.1)
        cf2, cache = apply_frontend(params, rgb2, fg, mode="compact",
                                    indices=idx, cache=cache)
        assert int(cache.n_stale[0]) == 1
        assert int(cache.age[0, target]) == 0         # refreshed now
        # the refreshed feature reflects the NEW content
        cf_fresh = apply_frontend(params, rgb2, fg, mode="compact", indices=idx)
        pos = int(np.where(np.asarray(idx)[0] == target)[0][0])
        np.testing.assert_array_equal(
            np.asarray(cf2.features[0, pos]), np.asarray(cf_fresh.features[0, pos]))


class TestBudgetAndDroop:
    def test_budget_defers_overflow_staleness(self):
        """j=1: a cold cache fills one selected patch per frame until all
        k are held; staleness beyond the budget is deferred, not lost."""
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6,
                                         recompute_budget=1))
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(4), (1, 64, 64, 3))
        idx = apply_frontend(params, rgb, fg, mode="compact").indices
        cache = init_feature_cache(fg, (1,))
        k = fg.n_active
        for t in range(k):
            _, cache = apply_frontend(params, rgb, fg, mode="compact",
                                      indices=idx, cache=cache)
            held = int(np.asarray(cache.valid[0])[np.asarray(idx)[0]].sum())
            assert int(cache.n_stale[0]) == 1
            assert held == t + 1
        _, cache = apply_frontend(params, rgb, fg, mode="compact",
                                  indices=idx, cache=cache)
        assert int(cache.n_stale[0]) == 0             # all held now

    def test_never_computed_patch_serves_uncharged_zero(self):
        """Under budget, a selected-but-not-yet-computed patch serves 0 —
        an uncharged summing cap — until its deferred refresh lands. In
        wire terms: its ``gain`` is 0, so the dequantized value is exactly
        0 whatever code sits in the (never-written) cache row."""
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6,
                                         recompute_budget=1))
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(5), (1, 64, 64, 3))
        idx = apply_frontend(params, rgb, fg, mode="compact").indices
        cache = init_feature_cache(fg, (1,))
        cf, cache = apply_frontend(params, rgb, fg, mode="compact",
                                   indices=idx, cache=cache)
        held = np.asarray(cache.valid[0])[np.asarray(idx)[0]]
        feats = np.asarray(c.dequantize_features(cf)[0])
        assert held.sum() == 1
        assert (np.abs(feats[~held]).max() == 0.0)
        assert np.abs(feats[held]).max() > 0.0

    def test_budget_overflow_rotates_without_starvation(self):
        """Persistent motion with j < k: every selected patch stays stale
        every frame, so the budget must ROTATE through them — hold age
        takes part in the stale ranking (f32-safely; a large additive
        offset would round it away) and guarantees each patch is
        refreshed within ceil(k/j) frames. A positional tie-break would
        starve the later selection positions forever."""
        j = 2
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6,
                                         recompute_budget=j))
        params = c.init_frontend_params(KEY, fg)
        k = fg.n_active
        idx = jnp.asarray([[1, 5, 9, 12]], jnp.int32)     # fixed gaze
        cache = init_feature_cache(fg, (1,))
        max_age = []
        for t in range(10):
            rgb = jax.random.uniform(jax.random.PRNGKey(100 + t),
                                     (1, 64, 64, 3))      # new content
            _, cache = apply_frontend(params, rgb, fg, mode="compact",
                                      indices=idx, cache=cache)
            assert int(cache.n_stale[0]) == j             # saturated budget
            ages = np.asarray(cache.age[0])[np.asarray(idx)[0]]
            valid = np.asarray(cache.valid[0])[np.asarray(idx)[0]]
            max_age.append(int(ages[valid].max()) if valid.any() else 0)
        # once warm, no selected patch is ever held longer than k/j frames
        assert max(max_age[k // j:]) <= k // j, max_age

    def test_passive_summer_forces_refresh_cycle(self):
        """A leaky passive summer (max_hold 1) must re-convert every other
        frame even on a static scene — the droop-limited refresh."""
        ps = PatchSpec(patch_h=16, patch_w=16, n_vectors=32,
                       summer=SummerSpec(mode="passive", hold_time_s=1e-6))
        fg = _fcfg(patch=ps,
                   temporal=TemporalSpec(delta_threshold=1e-6,
                                         droop_lsb_budget=2.0))
        assert fg.temporal.max_hold_frames(ps.summer, fg.adc) == 1
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(6), (1, 64, 64, 3))
        idx = apply_frontend(params, rgb, fg, mode="compact").indices
        cache = init_feature_cache(fg, (1,))
        stale = []
        for t in range(6):
            _, cache = apply_frontend(params, rgb, fg, mode="compact",
                                      indices=idx, cache=cache)
            stale.append(int(cache.n_stale[0]))
        k = fg.n_active
        assert stale == [k, 0, k, 0, k, 0]

    def test_zero_hold_budget_recomputes_every_frame(self):
        """max_hold 0 (one hold already violates the LSB budget): the
        gate must never serve a held value — every selected patch is
        recomputed every frame even on a static scene, and no served
        entry ever reaches age 1."""
        ps = PatchSpec(patch_h=16, patch_w=16, n_vectors=32,
                       summer=SummerSpec(mode="passive"))
        fg = _fcfg(patch=ps,
                   temporal=TemporalSpec(delta_threshold=1e-6,
                                         droop_lsb_budget=0.5))
        assert fg.temporal.max_hold_frames(ps.summer, fg.adc) == 0
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(11), (1, 64, 64, 3))
        idx = apply_frontend(params, rgb, fg, mode="compact").indices
        fresh = apply_frontend(params, rgb, fg, mode="compact", indices=idx)
        cache = init_feature_cache(fg, (1,))
        for t in range(4):
            cf, cache = apply_frontend(params, rgb, fg, mode="compact",
                                       indices=idx, cache=cache)
            assert int(cache.n_stale[0]) == fg.n_active
            assert int(np.asarray(cache.age[0])[np.asarray(idx)[0]].max()) == 0
            np.testing.assert_array_equal(          # never a drooped serve
                np.asarray(cf.features), np.asarray(fresh.features))

    def test_held_features_droop_by_factor(self):
        """A held entry's served value is the computed value times d^h."""
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6))
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(7), (1, 64, 64, 3))
        idx = apply_frontend(params, rgb, fg, mode="compact").indices
        fresh = apply_frontend(params, rgb, fg, mode="compact", indices=idx)
        cache = init_feature_cache(fg, (1,))
        h = 3
        for t in range(1 + h):
            cf, cache = apply_frontend(params, rgb, fg, mode="compact",
                                       indices=idx, cache=cache)
        d = fg.patch.summer.droop_factor()
        # the stored codes never age in place (integer-safe lazy droop)...
        np.testing.assert_array_equal(
            np.asarray(cf.features), np.asarray(fresh.features))
        # ...the droop rides in the serve-time gain on the dequantized value
        np.testing.assert_allclose(
            np.asarray(c.dequantize_features(cf)),
            np.asarray(c.dequantize_features(fresh)) * d ** h,
            rtol=1e-6)
        assert int(np.asarray(cache.age[0])[np.asarray(idx)[0]].min()) == h

    def test_gated_gradients_reach_frontend(self):
        """STE-compat: gradients flow through the gated path (gather,
        scatter-merge, projection quantizers) into the analog weights —
        on the float wire with a float cache (bit-identical values to the
        code wire; integer codes carry no gradients, DESIGN.md §9)."""
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6))
        cfg = _vcfg(fg)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cache = init_feature_cache(fg, (2,), dtype=jnp.float32)

        def loss(p):
            logits, _ = vit_forward_compact(p, rgb, cfg, cache=cache,
                                            wire="float")
            return jnp.sum(logits ** 2)

        g = jax.grad(loss)(params)
        assert float(jnp.abs(g["ip2"]["a_rgb"]).max()) > 0.0
        assert float(jnp.abs(g["ip2"]["bias"]).max()) > 0.0


class TestKernelGatedParity:
    def test_sparse_kernel_matches_gated_recompute(self):
        """The scalar-prefetch sparse kernel can serve as the gated
        projection: features it computes for the stale subset equal the
        reference gather-then-project path inside the gate."""
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6))
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(8), (2, 64, 64, 3))
        patches, weights = c.sensor_patches(params, rgb, fg)
        idx = c.topk_patch_indices(c.patch_energy(patches), fg.n_active)
        feats_k = ops.ip2_project_sparse(
            patches, weights, idx, fg.patch,
            adc=fg.adc, bias=params["bias"], interpret=True,
        )
        cache = init_feature_cache(fg, (2,))
        cf, _ = apply_frontend(params, rgb, fg, mode="compact",
                               indices=idx, cache=cache)
        np.testing.assert_allclose(
            np.asarray(feats_k), np.asarray(c.dequantize_features(cf)),
            atol=1e-5)

    def test_kernel_project_fn_in_gated_path(self):
        """ops.ip2_project_fn drops into the gated frontend (it receives
        the gathered j stale rows) and matches the reference einsum."""
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-6))
        params = c.init_frontend_params(KEY, fg)
        rgb = jax.random.uniform(jax.random.PRNGKey(8), (2, 64, 64, 3))
        cache_r = init_feature_cache(fg, (2,))
        cache_k = init_feature_cache(fg, (2,))
        cf_r, _ = apply_frontend(params, rgb, fg, mode="compact", cache=cache_r)
        cf_k, _ = apply_frontend(
            params, rgb, fg, mode="compact", cache=cache_k,
            project_fn=ops.ip2_project_fn(fg.patch, interpret=True),
        )
        np.testing.assert_allclose(
            np.asarray(cf_k.features), np.asarray(cf_r.features), atol=1e-5)


class TestEngineTemporal:
    @pytest.fixture(scope="class")
    def served(self):
        fg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        cfg = _vcfg(fg)
        return cfg, init_vit(KEY, cfg)

    def test_static_scene_fraction_drops_with_zero_recompiles(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2, temporal=True)
        eng.admit("a")
        frame = SceneStream(image=64).batch(0, 1)[0][0]
        fracs = []
        for t in range(5):
            eng.step({"a": frame})
            fracs.append(eng.recompute_fraction("a"))
        assert fracs[0] == 1.0
        assert fracs[-1] == 0.0 and fracs[-2] == 0.0
        assert eng.n_traces == 1

    def test_admit_wipes_recycled_slot_cache(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=1, temporal=True)
        stream = SceneStream(image=64)
        frame = stream.batch(0, 1)[0][0]
        eng.admit("a")
        for t in range(3):
            eng.step({"a": frame})
        slot = eng.slot_of("a")
        assert bool(eng.state.cache.valid[slot].any())
        eng.evict("a")
        eng.admit("b")                       # same slot
        assert eng.slot_of("b") == slot
        assert not bool(eng.state.cache.valid[slot].any())
        assert int(eng.state.cache.n_stale[slot]) == 0
        # and b's first frame bootstraps from a cold cache: full recompute
        eng.step({"b": frame})
        assert eng.recompute_fraction("b") == 1.0
        assert eng.n_traces == 1

    def test_temporal_engine_matches_single_stream_gated_loop(self, served):
        """Slot isolation: a stream served by the temporal engine must
        match a dedicated batch-1 gated single-stream loop frame-for-frame
        (bootstrap included), whatever the other slots do."""
        cfg, params = served
        stream = SceneStream(image=64)
        eng = SaccadeEngine(cfg, params, capacity=3, temporal=True)
        eng.admit("x")
        eng.admit("y")

        from repro.core.temporal import init_feature_cache as init_fc
        boot = jax.jit(make_bootstrap_indices(cfg))
        step = jax.jit(make_saccade_step(cfg, temporal=True))
        idx = {"x": None, "y": None}
        caches = {s: init_fc(cfg.frontend, (1,)) for s in ("x", "y")}
        for t in range(3):
            rgb, _ = stream.batch(t, 2)
            out = eng.step({"x": rgb[0], "y": rgb[1]})
            for i, sid in enumerate(("x", "y")):
                r = jnp.asarray(rgb[i:i + 1])
                if idx[sid] is None:
                    idx[sid] = boot(params, r)
                logits, idx[sid], _, caches[sid] = step(
                    params, r, idx[sid], caches[sid])
                np.testing.assert_allclose(
                    out[sid], np.asarray(logits[0]), atol=1e-5)
                assert (eng.gaze(sid) == np.asarray(idx[sid][0])).all(), (t, sid)

    def test_recompute_fraction_requires_temporal(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=1)
        eng.admit("a")
        with pytest.raises(RuntimeError, match="temporal"):
            eng.recompute_fraction("a")
