"""Power governor + engine energy metering (DESIGN.md §10).

The contracts the PR-5 acceptance pins:

* a governed engine holds MEASURED frontend power (priced from executed
  events, not assumed) within 10 % of a budget set below the ungoverned
  demand on a full-motion scene;
* with a slack budget the governed engine is BITWISE identical to the
  ungoverned temporal engine (the knobs are data-only no-ops);
* the starvation floor always leaves every stream making progress;
* the knobs do not oscillate in steady state (hysteresis);
* budget shares follow admit priorities;
* governing never recompiles (``n_traces == 1`` across churn).

Plus the always-on engine metering: per-slot cumulative meters, pricing
accessors, admit resets.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.frontend import FrontendConfig
from repro.core.power import EnergyMeter
from repro.core.projection import PatchSpec
from repro.core.temporal import TemporalSpec
from repro.models.vit import ViTConfig, init_vit
from repro.serve.engine import SaccadeEngine
from repro.serve.governor import GovernorSpec, allocate_budgets

KEY = jax.random.PRNGKey(0)
FRAME_HZ = 30.0


def make_cfg(**tkw):
    """64x64 sensor, 8x8 patches: P=64, k=16, M=64 — big enough that the
    variable (per-conversion) power dominates the fixed DAC/CDS floor, so
    governing has real authority."""
    fcfg = FrontendConfig(
        image_h=64, image_w=64, aa_cutoff=None,
        patch=PatchSpec(patch_h=8, patch_w=8, n_vectors=64),
        active_fraction=0.25,
        temporal=TemporalSpec(delta_threshold=1e-4, **tkw),
    )
    return ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)


CFG = make_cfg()
PARAMS = init_vit(KEY, CFG)
FRAMES = np.asarray(jax.random.uniform(jax.random.PRNGKey(1), (24, 64, 64, 3)))

# the engine's plant constants at this config (see GovernorSpec law)
_METER = EnergyMeter()
SLOT_MW = 1e3 * _METER.slot_recompute_power_w(64, 64, FRAME_HZ)
K = CFG.frontend.n_active


def full_motion(t):
    return FRAMES[t % len(FRAMES)]


class TestEngineMetering:
    def test_per_slot_meters_and_accessors(self):
        eng = SaccadeEngine(CFG, PARAMS, capacity=2, temporal=True,
                            frame_hz=FRAME_HZ)
        eng.admit("moving"); eng.admit("static")
        powers = []
        for t in range(5):
            eng.step({"moving": full_motion(t), "static": FRAMES[0]})
            powers.append((eng.power_mw("moving"), eng.power_mw("static")))
        # static scene: after the bootstrap conversion burst, holds are
        # free — measured power collapses to the fixed frame costs
        assert powers[-1][1] < powers[0][1]
        assert eng.recompute_fraction("static") == 0.0
        # full motion keeps paying for conversions
        assert powers[-1][0] > 2.0 * powers[-1][1]
        # mean sits between the extremes, fleet is the sum
        assert powers[-1][1] < eng.power_mw("static", "mean") <= powers[0][1]
        assert eng.fleet_power_mw() == pytest.approx(sum(powers[-1]))
        # the ledger prices the SAME events the gate reports
        ev = eng.events("moving", "last")
        frac = eng.recompute_fraction("moving")
        assert ev.adc_conversions == frac * K * 64
        rep = eng.energy_report("moving")
        assert set(rep) == {"adc", "weight_dac", "cap_charging",
                            "pwm_comparators", "opamps", "cds_sampling",
                            "pixel_dump", "sign_comparators",
                            "weight_reprogram", "backend"}
        assert all(v >= 0.0 for v in rep.values())

    def test_totals_accumulate_and_admit_resets(self):
        eng = SaccadeEngine(CFG, PARAMS, capacity=3, temporal=True,
                            frame_hz=FRAME_HZ)
        eng.admit("a")
        seen = 0.0
        for t in range(3):
            eng.step({"a": full_motion(t)})
            seen += eng.events("a", "last").adc_conversions
        # the device meter is a running per-frame mean (never saturates);
        # the total is derived as mean x frames — exact up to f32 rounding
        assert eng.events("a", "mean").adc_conversions == pytest.approx(
            seen / 3, rel=1e-6)
        assert eng.events("a", "total").adc_conversions == pytest.approx(
            seen, rel=1e-6)
        eng.evict("a")
        eng.admit("b")
        assert eng.events("b", "total").adc_conversions == 0.0
        assert eng.power_mw("b") == 0.0          # no frame served yet
        with pytest.raises(RuntimeError):
            eng.power_mw("b", "mean")
        # fleet aggregation must not trip over the admitted-but-unserved
        # stream (it has no frame to average — it is skipped, not raised)
        eng.admit("c")
        eng.step({"b": full_motion(0), "c": full_motion(1)})
        eng.admit("d")                           # d never served yet
        assert eng.fleet_power_mw("mean") > 0.0
        assert eng.fleet_power_mw("last") > 0.0

    def test_ungated_engine_meters_full_selection(self):
        eng = SaccadeEngine(CFG, PARAMS, capacity=1, frame_hz=FRAME_HZ)
        eng.admit("a")
        for t in range(3):
            eng.step({"a": full_motion(t)})
            assert eng.events("a", "last").adc_conversions == K * 64


class TestGovernor:
    def test_slack_budget_is_bitwise_noop(self):
        """Acceptance: static scene, budget far above demand — governed
        and ungoverned engines produce bit-identical logits and held
        state (the knobs never move off their no-op values)."""
        gov = GovernorSpec(budget_mw=100.0)
        plain = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True,
                              frame_hz=FRAME_HZ)
        gvd = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        plain.admit("a"); gvd.admit("a")
        for t in range(8):
            frame = FRAMES[0] if t != 5 else FRAMES[1]   # mid-run scene change
            lp = plain.step({"a": frame})["a"]
            lg = gvd.step({"a": frame})["a"]
            np.testing.assert_array_equal(lp, lg)
        np.testing.assert_array_equal(
            np.asarray(plain.state.cache.features),
            np.asarray(gvd.state.cache.features))
        np.testing.assert_array_equal(
            np.asarray(plain.state.indices), np.asarray(gvd.state.indices))
        assert gvd.recompute_cap("a") == K and gvd.k_tier("a") == K

    def test_full_motion_tracks_budget_within_10pct(self):
        """Acceptance: budget below the ungoverned full-motion demand —
        steady-state measured power within 10 % of the budget."""
        # ungoverned demand first
        plain = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True,
                              frame_hz=FRAME_HZ)
        plain.admit("a")
        for t in range(6):
            plain.step({"a": full_motion(t)})
        demand = plain.power_mw("a")

        budget = 0.66 * demand
        assert budget < demand / 1.1             # genuinely below demand
        gov = GovernorSpec(budget_mw=budget)
        eng = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        eng.admit("a")
        measured = []
        for t in range(16):
            eng.step({"a": full_motion(t)})
            measured.append(eng.power_mw("a"))
        steady = measured[-5:]
        for mw in steady:
            assert abs(mw - budget) / budget <= 0.10, (measured, budget)
        # and the governor really is throttling, not just measuring
        assert max(steady) < demand / 1.1
        assert eng.recompute_cap("a") < K

    def test_hysteresis_no_oscillation_in_steady_state(self):
        gov = GovernorSpec(budget_mw=0.14)
        eng = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        eng.admit("a")
        caps, tiers = [], []
        for t in range(20):
            eng.step({"a": full_motion(t)})
            caps.append(eng.recompute_cap("a"))
            tiers.append(eng.k_tier("a"))
        assert len(set(caps[-8:])) == 1, caps     # converged, no flicker
        assert len(set(tiers[-8:])) == 1, tiers

    def test_starvation_floor_and_tier_degradation(self):
        """A budget below even the fixed frame costs: the stream is
        degraded (floor recompute slots, smaller token tier), never
        stalled."""
        gov = GovernorSpec(budget_mw=0.07, floor=1)
        eng = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        eng.admit("a")
        for t in range(12):
            logits = eng.step({"a": full_motion(t)})["a"]
            assert np.isfinite(logits).all()
        assert int(eng.state.frame_age[0]) == 12      # never stalled
        assert eng.recompute_cap("a") == gov.floor
        assert eng.k_tier("a") < K                    # tier degraded
        # the floor keeps refresh progress: bounded staleness per token
        assert eng.k_tier("a") <= gov.floor * gov.refresh_horizon
        # still spending at least the floor's conversions
        assert eng.events("a", "last").adc_conversions >= 64

    def test_priority_weights_split_the_budget(self):
        gov = GovernorSpec(budget_mw=0.25)
        eng = SaccadeEngine(CFG, PARAMS, capacity=2, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        eng.admit("low", priority=1.0)
        eng.admit("high", priority=3.0)
        b = np.asarray(eng.state.controls.budget_mw)
        assert b[eng.slot_of("high")] == pytest.approx(3 * b[eng.slot_of("low")])
        assert b.sum() == pytest.approx(gov.budget_mw)
        for t in range(12):
            eng.step({"low": full_motion(t), "high": full_motion(t + 7)})
        assert eng.recompute_cap("high") > eng.recompute_cap("low")
        # eviction reallocates the whole budget to the survivor
        eng.evict("low")
        b = np.asarray(eng.state.controls.budget_mw)
        assert b[eng.slot_of("high")] == pytest.approx(gov.budget_mw)

    def test_governed_churn_zero_recompile(self):
        gov = GovernorSpec(budget_mw=0.2)
        eng = SaccadeEngine(CFG, PARAMS, capacity=2, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        eng.admit("a")
        eng.step({"a": full_motion(0)})
        eng.admit("b", priority=2.0)
        eng.step({"a": full_motion(1), "b": full_motion(2)})
        eng.evict("a")
        eng.step({"b": full_motion(3)})
        eng.admit("c")
        eng.step({"b": full_motion(4), "c": full_motion(5)})
        assert eng.n_traces == 1

    def test_validation(self):
        with pytest.raises(ValueError, match="temporal"):
            SaccadeEngine(CFG, PARAMS, capacity=1,
                          governor=GovernorSpec(budget_mw=1.0))
        with pytest.raises(ValueError, match="budget_mw"):
            GovernorSpec(budget_mw=0.0)
        with pytest.raises(ValueError, match="floor"):
            GovernorSpec(budget_mw=1.0, floor=0)
        with pytest.raises(ValueError, match="k_tiers"):
            GovernorSpec(budget_mw=1.0, k_tiers=(0.5, 1.0))
        with pytest.raises(ValueError, match="priority"):
            eng = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True)
            eng.admit("a", priority=0.0)

    def test_allocate_budgets_host_helper(self):
        spec = GovernorSpec(budget_mw=1.0)
        np.testing.assert_allclose(
            allocate_budgets(spec, np.array([1.0, 0.0, 3.0])),
            [0.25, 0.0, 0.75])
        # total_mw overrides the spec pool: the same proportional law
        # stacks into the fleet->host->slot hierarchy (DESIGN.md §12)
        np.testing.assert_allclose(
            allocate_budgets(spec, np.array([1.0, 0.0, 3.0]), total_mw=2.0),
            [0.5, 0.0, 1.5])
        np.testing.assert_array_equal(
            allocate_budgets(spec, np.zeros(3)), np.zeros(3))


class TestMeteringBugfixes:
    """PR-7 satellite regressions: the governed recompute-fraction
    denominator and the one-fetch vectorized pricing path."""

    def test_recompute_fraction_uses_tier_tokens(self):
        """Regression: on a shed slot (k_eff < k) the fraction must be
        n_stale / k_tier, not n_stale / k — the old static denominator
        understates recompute on governed streams by k_eff/k."""
        # severe budget + tight refresh horizon: the floor cap (1 slot)
        # only refreshes 4 tokens inside the horizon -> bottom tier
        gov = GovernorSpec(budget_mw=0.07, floor=1, refresh_horizon=4)
        eng = SaccadeEngine(CFG, PARAMS, capacity=1, temporal=True,
                            frame_hz=FRAME_HZ, governor=gov)
        eng.admit("a")
        for t in range(12):                  # enough frames to reach bottom
            eng.step({"a": full_motion(t)})
        k_eff = eng.k_tier("a")
        assert k_eff == gov.tier_tokens(K)[-1]          # finest tier
        assert k_eff < K                                # genuinely shed
        n_stale = int(eng.state.cache.n_stale[0])
        assert n_stale > 0                   # full motion: always recomputes
        frac = eng.recompute_fraction("a")
        assert frac == pytest.approx(n_stale / k_eff)
        # the pre-fix value (n_stale / K) is strictly smaller — the bug
        # this pins made shed slots look lazier than they are
        assert frac > n_stale / K

    def test_metering_reads_are_one_fetch(self, monkeypatch):
        """Regression: events/power_mw/fleet_power_mw must each cost
        exactly ONE device_get (counts and frame ages batched together),
        and the vectorized fleet pricing must equal the per-slot loop."""
        eng = SaccadeEngine(CFG, PARAMS, capacity=4, temporal=True,
                            frame_hz=FRAME_HZ)
        eng.admit("a"); eng.admit("b"); eng.admit("c")
        eng.step({"a": full_motion(0), "b": full_motion(1)})  # c holds: age 0
        _ = eng.state                                  # settle pending churn

        import repro.serve.engine as eng_mod
        calls = []
        real = jax.device_get
        monkeypatch.setattr(
            eng_mod.jax, "device_get",
            lambda x: (calls.append(1), real(x))[1])

        fleet = eng.fleet_power_mw("last")
        assert len(calls) == 1, "fleet_power_mw must be one batched fetch"
        calls.clear()
        pa = eng.power_mw("a", "last")
        assert len(calls) == 1
        calls.clear()
        ev = eng.events("a", "total")
        assert len(calls) == 1
        monkeypatch.undo()

        # value-equality with the old per-slot loop (age-0 slots skipped)
        want = sum(
            eng.meter.power_mw(eng.events(sid, "last"), FRAME_HZ)
            for sid in eng.stream_ids
            if int(eng.state.frame_age[eng.slot_of(sid)]) > 0)
        assert fleet == pytest.approx(want)
        assert pa == pytest.approx(
            eng.meter.power_mw(eng.events("a", "last"), FRAME_HZ))
        assert ev.adc_conversions == pytest.approx(
            eng.events("a", "mean").adc_conversions
            * int(eng.state.frame_age[eng.slot_of("a")]))
