"""Fleet coordinator (DESIGN.md §12): per-host engines, priority-class
admit queues, fleet->host->slot budget hierarchy, async routing."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.core.temporal import TemporalSpec
from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit
from repro.serve.engine import SaccadeEngine
from repro.serve.fleet import SaccadeFleet, make_fleet_meshes
from repro.serve.governor import GovernorSpec
from repro.serve.serve_step import make_bootstrap_indices, make_saccade_step

KEY = jax.random.PRNGKey(0)


def _cfg(temporal=False):
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
        temporal=TemporalSpec(delta_threshold=1e-4) if temporal
        else TemporalSpec(),
    )
    return ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    return cfg, init_vit(KEY, cfg)


class TestAdmission:
    def test_priority_classes_drain_highest_first(self, served):
        """With fewer free slots than queued requests, realtime admits
        before standard before background — FIFO within a class."""
        cfg, params = served
        fl = SaccadeFleet(cfg, params, n_hosts=1, capacity=2)
        fl.submit("bg", "background")
        fl.submit("rt", "realtime")
        fl.submit("std", "standard")
        admitted = fl.drain()
        assert admitted == ["rt", "std"]         # capacity 2: bg waits
        assert fl.queued == 1
        fl.evict("rt")
        assert fl.drain() == ["bg"]
        assert fl.queued == 0

    def test_submit_validation_and_cancel(self, served):
        cfg, params = served
        fl = SaccadeFleet(cfg, params, n_hosts=1, capacity=2)
        fl.submit("a")
        with pytest.raises(ValueError, match="already submitted"):
            fl.submit("a")
        with pytest.raises(ValueError, match="priority class"):
            fl.submit("b", "vip")
        fl.evict("a")                            # cancels the queued request
        assert fl.queued == 0
        with pytest.raises(KeyError):
            fl.evict("a")

    def test_least_loaded_host_placement(self, served):
        cfg, params = served
        fl = SaccadeFleet(cfg, params, n_hosts=2, capacity=2)
        hosts = [fl.submit(f"s{i}") for i in range(4)]
        assert sorted(hosts) == [0, 0, 1, 1]     # spread, not piled
        fl.drain()
        assert fl.free_slots == 0
        assert {fl.host_of(f"s{i}") for i in range(4)} == {0, 1}


class TestServing:
    def test_streams_match_dedicated_loops_across_hosts(self, served):
        """Every stream, whatever host it landed on and whatever rate it
        is fed at, matches its own dedicated batch-1 loop — the fleet
        layer adds routing, never semantics. One compile per engine."""
        cfg, params = served
        fl = SaccadeFleet(cfg, params, n_hosts=2, capacity=2)
        for i in range(3):
            fl.submit(f"s{i}")
        stream = SceneStream(image=64)
        boot = jax.jit(make_bootstrap_indices(cfg))
        step1 = jax.jit(make_saccade_step(cfg))
        refs = {f"s{i}": None for i in range(3)}
        for t in range(4):
            rgb, _ = stream.batch(t, 3)
            frames = {f"s{i}": rgb[i] for i in range(3) if (t + i) % 2 == 0}
            out = fl.step(frames)
            assert set(out) == set(frames)
            for i in range(3):
                sid = f"s{i}"
                if sid not in frames:
                    continue
                r = jnp.asarray(rgb[i:i + 1])
                if refs[sid] is None:
                    refs[sid] = boot(params, r)
                logits, refs[sid], _ = step1(params, r, refs[sid])
                np.testing.assert_allclose(
                    out[sid], np.asarray(logits[0]), atol=1e-5)
        assert fl.n_traces == [1, 1]

    def test_only_fed_hosts_dispatch(self, served):
        cfg, params = served
        fl = SaccadeFleet(cfg, params, n_hosts=2, capacity=1)
        fl.submit("a")
        fl.submit("b")
        fl.drain()
        ha, hb = fl.host_of("a"), fl.host_of("b")
        assert ha != hb
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 1)
        fl.step({"a": rgb[0]})                   # only a's host runs
        assert fl.engines[ha].n_traces == 1
        assert fl.engines[hb].n_traces == 0


class TestBudgetHierarchy:
    def test_fleet_budget_splits_host_then_slot(self):
        """fleet -> host by admitted priority mass, host -> slot by
        stream priority: the slot shares on each host sum to the host
        share, and the host shares sum to the fleet budget."""
        cfg = _cfg(temporal=True)
        params = init_vit(KEY, cfg)
        gov = GovernorSpec(budget_mw=1.0)
        fl = SaccadeFleet(cfg, params, n_hosts=2, capacity=2,
                          temporal=True, governor=gov)
        fl.submit("rt", "realtime")              # weight 4, host 0
        fl.submit("bg", "background")            # weight 0.25, host 1
        fl.submit("std", "standard")             # weight 1
        fl.drain()
        masses = [sum(e._priority[s] for s in e.stream_ids)
                  for e in fl.engines]
        total = sum(masses)
        host_shares = []
        for eng, mass in zip(fl.engines, masses):
            b = np.asarray(eng.state.controls.budget_mw)
            assert b.sum() == pytest.approx(eng.budget_mw, rel=1e-5)
            assert eng.budget_mw == pytest.approx(
                gov.budget_mw * mass / total, rel=1e-5)
            host_shares.append(b.sum())
        assert sum(host_shares) == pytest.approx(gov.budget_mw, rel=1e-5)

    def test_slack_fleet_budget_is_bitwise_noop(self):
        """PR-5 contract lifted to the fleet: a slack fleet budget leaves
        every stream bitwise identical to an ungoverned engine — each
        host's slack share is itself slack."""
        cfg = _cfg(temporal=True)
        params = init_vit(KEY, cfg)
        fl = SaccadeFleet(cfg, params, n_hosts=2, capacity=1, temporal=True,
                          governor=GovernorSpec(budget_mw=1e4))
        plain = SaccadeEngine(cfg, params, capacity=2, temporal=True)
        fl.submit("a", "realtime")
        fl.submit("b", "background")
        plain.admit("a")
        plain.admit("b")
        stream = SceneStream(image=64)
        for t in range(4):
            rgb, _ = stream.batch(t % 2, 2)
            frames = {"a": rgb[0], "b": rgb[1]}
            og = fl.step(frames)
            op = plain.step(frames)
            for sid in frames:
                np.testing.assert_array_equal(og[sid], op[sid])


class TestMeshes:
    def test_make_fleet_meshes_partitions_devices(self):
        meshes = make_fleet_meshes(1)
        assert len(meshes) == 1
        assert meshes[0].devices.size == len(jax.devices())
        with pytest.raises(ValueError, match="devices"):
            make_fleet_meshes(len(jax.devices()) + 1)
        with pytest.raises(ValueError, match="n_hosts"):
            make_fleet_meshes(0)
