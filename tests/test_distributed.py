"""Distribution tests on host devices: sharded train step correctness
(vs single-device reference), pipeline parallelism, compressed gradient
all-reduce, spec fitting, elastic restore.

These tests need multiple host devices; they re-exec themselves in a
subprocess with XLA_FLAGS so the main pytest process keeps 1 device (the
assignment requires smoke tests to see 1 device).
"""

import json
import os
import subprocess
import sys
import textwrap

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def run_with_devices(code: str, n: int = 8) -> dict:
    """Run `code` in a subprocess with n host devices; code must print a
    JSON dict as its last line."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_sharded_train_step_matches_single_device():
    """jit train step on a (2,2) mesh == the same step on 1 device."""
    res = run_with_devices("""
        import json, jax, jax.numpy as jnp, numpy as np
        from repro import models as M
        from repro.configs import smoke_config
        from repro.launch.shardings import plan_for, shardings_for, constrainer_ctx
        from repro.launch.specs import batch_spec_shardings
        from repro.optim import AdamWConfig, init_opt_state, opt_state_specs
        from repro.train.train_step import make_train_step
        from repro.configs.base import SHAPES
        import dataclasses

        cfg = smoke_config("llama3-8b")
        key = jax.random.PRNGKey(0)
        opt = AdamWConfig(lr=1e-3)
        batch = {"tokens": jax.random.randint(key, (4, 32), 0, cfg.vocab)}

        # single-device reference
        params = M.init_params(key, cfg)
        opt_state = init_opt_state(params, opt)
        step_ref = jax.jit(make_train_step(cfg, M.DEFAULT_PLAN, opt,
                                           compute_dtype=jnp.float32))
        p_ref, _, m_ref = step_ref(params, opt_state, batch)

        # sharded
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        plan = plan_for(cfg, mesh)
        params2 = M.init_params(key, cfg, plan)   # same shapes (tp padding no-op: tp=2 divides)
        opt2 = init_opt_state(params2, opt)
        pspecs = M.param_specs(cfg, plan)
        p_sh = shardings_for(pspecs, params2, mesh)
        o_sh = shardings_for(opt_state_specs(pspecs), opt2, mesh)
        from jax.sharding import NamedSharding, PartitionSpec as P
        b_sh = {"tokens": NamedSharding(mesh, P(("data",), None))}
        with constrainer_ctx(mesh, plan):
            stepfn = jax.jit(make_train_step(cfg, plan, opt, compute_dtype=jnp.float32),
                             in_shardings=(p_sh, o_sh, b_sh),
                             out_shardings=(p_sh, o_sh, None))
            p_sh_out, _, m_sh = stepfn(params2, opt2, batch)

        diffs = [float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max())
                 for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_sh_out))]
        print(json.dumps({
            "loss_ref": float(m_ref["loss"]), "loss_sh": float(m_sh["loss"]),
            "max_param_diff": max(diffs),
        }))
    """, n=4)
    assert abs(res["loss_ref"] - res["loss_sh"]) < 2e-4, res
    assert res["max_param_diff"] < 5e-5, res


def test_head_geometry_padding():
    from repro.configs import get_config
    from repro.models.attention import head_geometry
    from repro.models.layers import ParallelPlan

    plan16 = ParallelPlan(tp=16)
    cases = {
        "llama3-8b": (32, 16),        # q ok, kv lcm(8,16)=16
        "qwen2.5-32b": (48, 16),      # q 40 -> pad 48
        "smollm-135m": (16, 16),      # q 9 -> 16; lcm(3,16)=48 !| 16 -> MHA-ize
        "whisper-tiny": (16, 16),
        "qwen3-moe-235b-a22b": (64, 16),
        "recurrentgemma-2b": (16, 16),  # MQA replicated
    }
    for arch, want in cases.items():
        got = head_geometry(get_config(arch), plan16)
        assert got == want, (arch, got, want)
        hq, hkv = got
        assert hq % hkv == 0      # grouped attention divisibility invariant


def test_fit_spec_drops_indivisible():
    res = run_with_devices("""
        import json, jax
        from jax.sharding import PartitionSpec as P
        from repro.launch.shardings import fit_spec
        mesh = jax.make_mesh((2, 2), ("data", "model"))
        a = fit_spec(P("data", "model"), (4, 6), mesh)   # 6 % 2 == 0 -> keep
        b = fit_spec(P("data", "model"), (4, 7), mesh)   # 7 % 2 != 0 -> drop
        c = fit_spec(P(("data", "model"), None), (1, 8), mesh)  # batch 1 -> drop
        print(json.dumps({"a": str(a), "b": str(b), "c": str(c)}))
    """, n=4)
    assert "model" in res["a"]
    assert "model" not in res["b"]
    assert "data" not in res["c"]


def test_pipeline_parallel_matches_sequential():
    res = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_forward, split_layers_to_stages
        mesh = jax.make_mesh((4,), ("pod",))
        L, D = 8, 16
        w = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.4
        def stage_fn(params, x):
            def body(c, p): return jnp.tanh(c @ p), None
            return jax.lax.scan(body, x, params)[0]
        mbs = jax.random.normal(jax.random.PRNGKey(1), (6, 3, D))
        out = pipeline_forward(split_layers_to_stages(w, 4), mbs, stage_fn, mesh)
        def seq(x):
            def body(c, p): return jnp.tanh(c @ p), None
            return jax.lax.scan(body, x, w)[0]
        ref = jnp.stack([seq(mbs[i]) for i in range(6)])
        gpp = jax.grad(lambda w_: jnp.sum(pipeline_forward(
            split_layers_to_stages(w_, 4), mbs, stage_fn, mesh) ** 2))(w)
        gseq = jax.grad(lambda w_: jnp.sum(jnp.stack(
            [jax.lax.scan(lambda c, p: (jnp.tanh(c @ p), None), mbs[i], w_)[0]
             for i in range(6)]) ** 2))(w)
        print(json.dumps({
            "fwd_err": float(jnp.abs(out - ref).max()),
            "grad_err": float(jnp.abs(gpp - gseq).max()),
        }))
    """, n=4)
    assert res["fwd_err"] < 1e-6
    assert res["grad_err"] < 1e-5


def test_pipeline_fewer_microbatches_than_stages():
    """The GPipe schedule must stay correct when the pipe is mostly bubble
    (n_micro < n_stages) — the tail/injection masking, not just the steady
    state, is what this exercises."""
    res = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_forward, split_layers_to_stages
        mesh = jax.make_mesh((4,), ("pod",))
        L, D = 4, 8
        w = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.4
        def stage_fn(params, x):
            def body(c, p): return jnp.tanh(c @ p), None
            return jax.lax.scan(body, x, params)[0]
        mbs = jax.random.normal(jax.random.PRNGKey(1), (2, 3, D))   # 2 < 4 stages
        out = pipeline_forward(split_layers_to_stages(w, 4), mbs, stage_fn, mesh)
        def seq(x):
            def body(c, p): return jnp.tanh(c @ p), None
            return jax.lax.scan(body, x, w)[0]
        ref = jnp.stack([seq(mbs[i]) for i in range(2)])
        print(json.dumps({"fwd_err": float(jnp.abs(out - ref).max())}))
    """, n=4)
    assert res["fwd_err"] < 1e-6


def test_pipeline_bubble_nan_does_not_poison_output():
    """PR-7 satellite regression: bubble ticks feed a ZERO carry into
    stage_fn; a stage_fn that divides by its input norm emits NaN there.
    The final masking must select (jnp.where), not multiply — with the
    old ``psum(out * is_last)``, ``NaN * 0 = NaN`` poisons every real
    output through the psum."""
    res = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.distributed.pipeline import pipeline_forward, split_layers_to_stages
        mesh = jax.make_mesh((4,), ("pod",))
        L, D = 4, 8
        w = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.4
        def body(c, p):
            nrm = jnp.sqrt(jnp.sum(c * c))
            return jnp.tanh((c / nrm) @ p), None  # NaN on the zero bubble carry
        def stage_fn(params, x):
            return jax.lax.scan(body, x, params)[0]
        mbs = jax.random.normal(jax.random.PRNGKey(1), (6, 3, D))
        out = pipeline_forward(split_layers_to_stages(w, 4), mbs, stage_fn, mesh)
        def seq(x):
            return jax.lax.scan(body, x, w)[0]
        ref = jnp.stack([seq(mbs[i]) for i in range(6)])
        print(json.dumps({
            "finite": bool(jnp.isfinite(out).all()),
            "fwd_err": float(jnp.abs(out - ref).max()),
        }))
    """, n=4)
    assert res["finite"], "bubble-tick NaN poisoned the masked psum"
    assert res["fwd_err"] < 1e-6


def test_engine_sharded_slots_match_unsharded_zero_recompiles():
    """SaccadeEngine with the slot axis shard_map'd over 4 host devices:
    identical logits to the unsharded engine, state physically spread over
    the mesh, and one compilation across an admit→evict→admit cycle."""
    res = run_with_devices("""
        import json
        import numpy as np
        import jax, jax.numpy as jnp
        from repro.core.frontend import FrontendConfig
        from repro.core.projection import PatchSpec
        from repro.data.pipeline import SceneStream
        from repro.launch.mesh import make_host_mesh
        from repro.models.vit import ViTConfig, init_vit
        from repro.serve.engine import SaccadeEngine

        fcfg = FrontendConfig(image_h=64, image_w=64,
                              patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
                              active_fraction=0.25)
        cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(jax.random.PRNGKey(0), cfg)
        stream = SceneStream(image=64)
        mesh = make_host_mesh(data=4, model=1)

        e_sh = SaccadeEngine(cfg, params, capacity=8, mesh=mesh)
        e_ref = SaccadeEngine(cfg, params, capacity=8)
        for s in range(5):
            e_sh.admit(s); e_ref.admit(s)
        err = 0.0
        for t in range(3):
            rgb, _ = stream.batch(t, 5)
            frames = {i: rgb[i] for i in range(5)}
            o1, o2 = e_sh.step(frames), e_ref.step(frames)
            err = max(err, max(float(np.abs(o1[s] - o2[s]).max()) for s in frames))
        # churn: evict + admit into the freed slot, then serve again
        e_sh.evict(0); e_sh.admit(99)
        rgb, _ = stream.batch(7, 5)
        e_sh.step({99: rgb[0], **{i: rgb[i] for i in range(1, 5)}})

        # indivisible capacity (5 % 4 != 0): engine must fall back to a
        # plain jit, NOT shard_map with replicated specs (n_dev x compute)
        e_odd = SaccadeEngine(cfg, params, capacity=5, mesh=mesh)
        for s in range(3):
            e_odd.admit(s)
        rgb, _ = stream.batch(2, 3)
        frames = {i: rgb[i] for i in range(3)}
        o_odd = e_odd.step(frames)
        o_ref2 = {}
        e_ref2 = SaccadeEngine(cfg, params, capacity=5)
        for s in range(3):
            e_ref2.admit(s)
        o_ref2 = e_ref2.step(frames)
        odd_err = max(float(np.abs(o_odd[s] - o_ref2[s]).max()) for s in frames)
        print(json.dumps({
            "err": err,
            "state_devices": len(e_sh.state.ema.sharding.device_set),
            "traces_sharded": e_sh.n_traces,
            "traces_ref": e_ref.n_traces,
            "odd_sharded": e_odd._slot_spec != jax.sharding.PartitionSpec(),
            "odd_err": odd_err,
        }))
    """, n=4)
    assert res["err"] < 1e-5, res
    assert res["state_devices"] == 4, res          # slot axis really sharded
    assert res["traces_sharded"] == 1, res         # admit/evict: no recompile
    assert res["traces_ref"] == 1, res
    assert res["odd_sharded"] is False, res        # indivisible -> plain jit
    assert res["odd_err"] < 1e-5, res


def test_temporal_engine_sharded_matches_unsharded():
    """SaccadeEngine(temporal=True) with the slot axis shard_map'd: the
    per-slot FeatureCache shards with the rest of StreamState, logits and
    recompute fractions match the unsharded engine on a static scene
    (reuse kicks in identically), still one compile."""
    res = run_with_devices("""
        import json
        import numpy as np
        import jax
        from repro.core.frontend import FrontendConfig
        from repro.core.projection import PatchSpec
        from repro.core.temporal import TemporalSpec
        from repro.data.pipeline import SceneStream
        from repro.launch.mesh import make_host_mesh
        from repro.models.vit import ViTConfig, init_vit
        from repro.serve.engine import SaccadeEngine

        fcfg = FrontendConfig(image_h=64, image_w=64,
                              patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
                              active_fraction=0.25,
                              temporal=TemporalSpec(delta_threshold=1e-5))
        cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(jax.random.PRNGKey(0), cfg)
        stream = SceneStream(image=64)
        mesh = make_host_mesh(data=4, model=1)

        e_sh = SaccadeEngine(cfg, params, capacity=4, mesh=mesh, temporal=True)
        e_ref = SaccadeEngine(cfg, params, capacity=4, temporal=True)
        for s in range(3):
            e_sh.admit(s); e_ref.admit(s)
        frame0 = stream.batch(0, 3)[0]
        frames = {i: frame0[i] for i in range(3)}
        err = 0.0
        for t in range(4):                    # static scene: reuse kicks in
            o = e_sh.step(frames); r = e_ref.step(frames)
            err = max(err, max(float(np.abs(o[s] - r[s]).max()) for s in frames))
        print(json.dumps({
            "err": err,
            "cache_devices": len(e_sh.state.cache.features.sharding.device_set),
            "fr_sh": [e_sh.recompute_fraction(s) for s in range(3)],
            "fr_ref": [e_ref.recompute_fraction(s) for s in range(3)],
            "traces": e_sh.n_traces,
        }))
    """, n=4)
    assert res["err"] < 1e-5, res
    assert res["cache_devices"] == 4, res        # cache really sharded
    assert res["fr_sh"] == res["fr_ref"], res    # identical reuse decisions
    assert res["fr_sh"] == [0.0, 0.0, 0.0], res  # static scene: no recompute
    assert res["traces"] == 1, res


def test_governed_engine_sharded_matches_unsharded():
    """Governed engine (DESIGN.md §10) with the slot axis shard_map'd:
    the per-slot energy meters and governor controls shard with the rest
    of StreamState (the control law is per-slot — no collectives), and
    measured power / caps / tiers match the unsharded governed engine.
    Still one compile."""
    res = run_with_devices("""
        import json
        import numpy as np
        import jax
        from repro.core.frontend import FrontendConfig
        from repro.core.projection import PatchSpec
        from repro.core.temporal import TemporalSpec
        from repro.launch.mesh import make_host_mesh
        from repro.models.vit import ViTConfig, init_vit
        from repro.serve.engine import SaccadeEngine
        from repro.serve.governor import GovernorSpec

        fcfg = FrontendConfig(image_h=64, image_w=64, aa_cutoff=None,
                              patch=PatchSpec(patch_h=8, patch_w=8, n_vectors=64),
                              active_fraction=0.25,
                              temporal=TemporalSpec(delta_threshold=1e-4))
        cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
        params = init_vit(jax.random.PRNGKey(0), cfg)
        mesh = make_host_mesh(data=4, model=1)
        gov = GovernorSpec(budget_mw=0.30)
        scenes = np.asarray(jax.random.uniform(
            jax.random.PRNGKey(1), (12, 64, 64, 3)))

        e_sh = SaccadeEngine(cfg, params, capacity=4, mesh=mesh,
                             temporal=True, governor=gov)
        e_ref = SaccadeEngine(cfg, params, capacity=4, temporal=True,
                              governor=gov)
        for s in range(4):
            e_sh.admit(s); e_ref.admit(s)
        for t in range(10):                     # full motion: governor bites
            frames = {s: scenes[(t + s) % 12] for s in range(4)}
            e_sh.step(frames); e_ref.step(frames)
        print(json.dumps({
            "ctrl_devices": len(e_sh.state.controls.j_cap.sharding.device_set),
            "ev_devices": len(
                e_sh.state.events_mean.adc_conversions.sharding.device_set),
            "caps_sh": [e_sh.recompute_cap(s) for s in range(4)],
            "caps_ref": [e_ref.recompute_cap(s) for s in range(4)],
            "mw_sh": [round(e_sh.power_mw(s), 9) for s in range(4)],
            "mw_ref": [round(e_ref.power_mw(s), 9) for s in range(4)],
            "traces": e_sh.n_traces,
        }))
    """, n=4)
    assert res["ctrl_devices"] == 4, res         # controls really sharded
    assert res["ev_devices"] == 4, res           # meters really sharded
    assert res["caps_sh"] == res["caps_ref"], res
    assert res["mw_sh"] == res["mw_ref"], res
    assert res["traces"] == 1, res


def test_compressed_allreduce_and_error_feedback():
    res = run_with_devices("""
        import json, jax, jax.numpy as jnp
        from repro.optim.compression import make_compressed_allreduce
        mesh = jax.make_mesh((8,), ("data",))
        fn = make_compressed_allreduce(mesh, "data")
        g = jax.random.normal(jax.random.PRNGKey(2), (8, 256))
        err = {"g": jnp.zeros((8, 256))}
        # accumulate over steps: error feedback drives the running mean bias -> 0
        tot_exact, tot_comp = jnp.zeros(256), jnp.zeros(256)
        for s in range(20):
            gs = g * (1.0 + 0.01 * s)
            mean, err = fn({"g": gs}, err)
            tot_comp = tot_comp + mean["g"][0]
            tot_exact = tot_exact + gs.mean(0)
        one_rel = float(jnp.abs(mean["g"][0] - gs.mean(0)).max() / jnp.abs(gs.mean(0)).max())
        cum_rel = float(jnp.abs(tot_comp - tot_exact).max() / jnp.abs(tot_exact).max())
        print(json.dumps({"one_rel": one_rel, "cum_rel": cum_rel}))
    """, n=8)
    assert res["one_rel"] < 0.03
    assert res["cum_rel"] < res["one_rel"]   # EF cancels error over steps


def test_elastic_restore_subprocess(tmp_path):
    res = run_with_devices(f"""
        import json, jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint.manager import CheckpointManager
        mesh4 = jax.make_mesh((4, 1), ("data", "model"))
        mesh2 = jax.make_mesh((2, 2), ("data", "model"))
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh4, P("data", None)))
        cm = CheckpointManager({str(tmp_path)!r})
        cm.save(1, {{"x": xs}}, blocking=True)
        target = NamedSharding(mesh2, P("data", "model"))
        restored, _ = cm.restore({{"x": x}}, shardings={{"x": target}})
        print(json.dumps({{
            "equal": bool(jnp.array_equal(restored["x"], x)),
            "resharded": restored["x"].sharding == target,
        }}))
    """, n=4)
    assert res["equal"] and res["resharded"]
