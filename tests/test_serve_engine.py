"""Multi-stream saccadic serving engine (DESIGN.md §5): slot bookkeeping,
per-stream state isolation, equivalence with the single-stream step, and
the zero-recompile contract across admit/evict churn."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit
from repro.serve.engine import SaccadeEngine, init_stream_state
from repro.serve.serve_step import (
    make_bootstrap_indices, make_saccade_step, saccade_scores,
)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    base = dict(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    base.update(kw)
    return ViTConfig(**base)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    return cfg, init_vit(KEY, cfg)


class TestBookkeeping:
    def test_admit_evict_slot_reuse(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        assert eng.free_slots == 2
        s0 = eng.admit("a")
        s1 = eng.admit("b")
        assert {s0, s1} == {0, 1} and eng.free_slots == 0
        with pytest.raises(RuntimeError, match="capacity"):
            eng.admit("c")
        with pytest.raises(ValueError, match="already admitted"):
            eng.admit("a")
        eng.evict("a")
        assert eng.free_slots == 1 and eng.stream_ids == ["b"]
        assert eng.admit("c") == s0          # freed slot is reused
        with pytest.raises(KeyError):
            eng.evict("zzz")

    def test_step_unknown_raises_partial_cover_holds(self, served):
        """The async contract (DESIGN.md §12): frames for never-admitted
        streams still raise, but a PARTIAL cover is legal — the un-fed
        admitted streams simply hold this tick."""
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        eng.admit("a")
        eng.admit("b")
        frame = np.zeros((64, 64, 3), np.float32)
        with pytest.raises(ValueError, match="unknown"):
            eng.step({"a": frame, "c": frame})
        out = eng.step({"a": frame})         # "b" holds, no error
        assert set(out) == {"a"}
        assert int(eng.state.frame_age[eng.slot_of("a")]) == 1
        assert int(eng.state.frame_age[eng.slot_of("b")]) == 0
        assert eng.step({}) == {}            # everyone holds: no dispatch

    def test_idle_engine_step_is_a_noop(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        assert eng.step({}) == {}
        assert eng.n_traces == 0         # no streams -> no device dispatch

    def test_admit_resets_row_state(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        eng.admit("a")
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 1)
        for t in range(2):
            eng.step({"a": rgb[0]})
        slot = eng.slot_of("a")
        assert int(eng.state.frame_age[slot]) == 2
        eng.evict("a")
        assert not bool(eng.state.active[slot])
        eng.admit("a2")                      # same slot, fresh stream
        assert eng.slot_of("a2") == slot
        assert int(eng.state.frame_age[slot]) == 0
        assert float(jnp.abs(eng.state.ema[slot]).max()) == 0.0

    def test_gaze_undefined_before_first_frame(self, served):
        """A fresh admit has no gaze yet (the first selection is the
        in-step energy bootstrap) — gaze() must refuse, not report the
        arange placeholder as if it were a real selection."""
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=1)
        eng.admit("a")
        with pytest.raises(RuntimeError, match="bootstrap"):
            eng.gaze("a")
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 1)
        eng.step({"a": rgb[0]})
        assert sorted(set(eng.gaze("a").tolist())) == sorted(eng.gaze("a").tolist())

    def test_init_state_shapes(self):
        cfg = _cfg()
        st = init_stream_state(cfg, 5)
        k, p = cfg.frontend.n_active, cfg.frontend.n_patches
        assert st.indices.shape == (5, k) and st.ema.shape == (5, p)
        assert st.frame_age.shape == (5,) and st.active.shape == (5,)
        assert not bool(st.active.any())


class TestEquivalence:
    def test_engine_matches_single_stream_loop(self, served):
        """Each slot must serve its stream EXACTLY as a dedicated batch-1
        single-stream loop would (bootstrap included), regardless of what
        the other slots are doing."""
        cfg, params = served
        stream = SceneStream(image=64)
        eng = SaccadeEngine(cfg, params, capacity=4)   # 2 slots stay empty
        eng.admit("x")
        eng.admit("y")

        boot = jax.jit(make_bootstrap_indices(cfg))
        step = jax.jit(make_saccade_step(cfg))
        idx = {"x": None, "y": None}
        for t in range(3):
            rgb, _ = stream.batch(t, 2)
            out = eng.step({"x": rgb[0], "y": rgb[1]})
            for i, sid in enumerate(("x", "y")):
                r = jnp.asarray(rgb[i:i + 1])
                if idx[sid] is None:
                    idx[sid] = boot(params, r)
                logits, idx[sid], _ = step(params, r, idx[sid])
                np.testing.assert_allclose(
                    out[sid], np.asarray(logits[0]), atol=1e-5)
                assert (eng.gaze(sid) == np.asarray(idx[sid][0])).all(), (t, sid)

    def test_inactive_slots_emit_zero_logits_and_frozen_state(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=3)
        eng.admit("only")
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 1)
        eng.step({"only": rgb[0]})
        free = [s for s in range(3) if s != eng.slot_of("only")]
        st = eng.state
        assert not bool(st.active[jnp.asarray(free)].any())
        assert int(st.frame_age[jnp.asarray(free)].max()) == 0

    def test_ema_blends_scores_across_frames(self, served):
        """ema_decay smooths the saccade policy: state.ema after frame 2
        must equal decay*scores(f1) + (1-decay)*scores(f2) computed from
        the shared single-stream core."""
        cfg, params = served
        decay = 0.7
        eng = SaccadeEngine(cfg, params, capacity=1, ema_decay=decay)
        eng.admit("s")
        stream = SceneStream(image=64)
        step = jax.jit(make_saccade_step(cfg))
        boot = jax.jit(make_bootstrap_indices(cfg))

        rgb0, _ = stream.batch(0, 1)
        rgb1, _ = stream.batch(1, 1)
        eng.step({"s": rgb0[0]})
        eng.step({"s": rgb1[0]})

        r0, r1 = jnp.asarray(rgb0), jnp.asarray(rgb1)
        i0 = boot(params, r0)
        _, _, aux0 = step(params, r0, i0)
        s0 = saccade_scores(aux0, 0.1)
        # frame 1's indices = top-k of the EMA (== s0 on the first frame)
        from repro.core.saliency import topk_patch_indices
        i1 = topk_patch_indices(s0, cfg.frontend.n_active)
        _, _, aux1 = step(params, r1, i1)
        s1 = saccade_scores(aux1, 0.1)
        want = decay * s0 + (1 - decay) * s1
        np.testing.assert_allclose(
            np.asarray(eng.state.ema), np.asarray(want), atol=1e-6)


class TestBootstrapDeterminism:
    """Satellite: make_bootstrap_indices must be a pure deterministic
    function of (params, rgb) — identical under jit and eager, stable
    across calls, and batch-order equivariant. (It is: the selection is
    an energy top-k with no RNG; this pins that contract so a future
    stochastic bootstrap must be made explicit, not derived from params
    hashing.)"""

    def test_jit_matches_eager(self, served):
        cfg, params = served
        boot = make_bootstrap_indices(cfg)
        jboot = jax.jit(boot)
        stream = SceneStream(image=64)
        for t in range(3):
            rgb = jnp.asarray(stream.batch(t, 4)[0])
            np.testing.assert_array_equal(
                np.asarray(boot(params, rgb)), np.asarray(jboot(params, rgb)))

    def test_repeated_calls_identical(self, served):
        cfg, params = served
        boot = jax.jit(make_bootstrap_indices(cfg))
        rgb = jnp.asarray(SceneStream(image=64).batch(7, 2)[0])
        a = np.asarray(boot(params, rgb))
        b = np.asarray(boot(params, rgb))
        np.testing.assert_array_equal(a, b)

    def test_batch_elements_independent(self, served):
        """Each element's bootstrap depends only on its own frame."""
        cfg, params = served
        boot = jax.jit(make_bootstrap_indices(cfg))
        rgb = jnp.asarray(SceneStream(image=64).batch(3, 4)[0])
        full = np.asarray(boot(params, rgb))
        flipped = np.asarray(boot(params, rgb[::-1]))
        np.testing.assert_array_equal(full, flipped[::-1])


class TestPartialFrames:
    """Tentpole (DESIGN.md §12): partial-frame async steps. Fed slots are
    BITWISE identical to a full-cover step; held slots are bitwise frozen
    with zero event accrual; mixed-rate serving stays one compile."""

    def test_fed_slots_bitwise_identical_to_full_cover(self, served):
        """Acceptance criterion: serve {x} while y holds, vs serve {x, y}
        on a twin engine — x's logits AND x's entire state row must be
        bitwise equal (per-slot independence of the batched step)."""
        cfg, params = served
        stream = SceneStream(image=64)
        rgb0, _ = stream.batch(0, 2)
        rgb1, _ = stream.batch(1, 2)

        part = SaccadeEngine(cfg, params, capacity=4, temporal=True)
        full = SaccadeEngine(cfg, params, capacity=4, temporal=True)
        for e in (part, full):
            e.admit("x")
            e.admit("y")
            e.step({"x": rgb0[0], "y": rgb0[1]})
        out_p = part.step({"x": rgb1[0]})                     # y holds
        out_f = full.step({"x": rgb1[0], "y": rgb1[1]})       # full cover
        np.testing.assert_array_equal(out_p["x"], out_f["x"])
        sx = part.slot_of("x")
        p_leaves = jax.tree.leaves(jax.device_get(part.state))
        f_leaves = jax.tree.leaves(jax.device_get(full.state))
        for lp, lf in zip(p_leaves, f_leaves):
            np.testing.assert_array_equal(lp[sx], lf[sx])
        assert part.n_traces == 1 and full.n_traces == 1

    def test_held_slot_is_bitwise_frozen_with_zero_events(self, served):
        """A held slot's ENTIRE state row — gaze, EMA, frame age, temporal
        cache (droop clock included), and both event meters — passes
        through the step bitwise unchanged."""
        cfg, params = served
        stream = SceneStream(image=64)
        rgb0, _ = stream.batch(0, 2)
        rgb1, _ = stream.batch(3, 2)
        eng = SaccadeEngine(cfg, params, capacity=3, temporal=True)
        eng.admit("x")
        eng.admit("y")
        eng.step({"x": rgb0[0], "y": rgb0[1]})
        sy = eng.slot_of("y")
        before = [np.array(l[sy]) for l in
                  jax.tree.leaves(jax.device_get(eng.state))]
        ev_before = eng.events("y", "last")
        for t in range(3):                       # y holds for three ticks
            eng.step({"x": rgb1[t % 2]})
        after = [np.array(l[sy]) for l in
                 jax.tree.leaves(jax.device_get(eng.state))]
        for b, a in zip(before, after):
            np.testing.assert_array_equal(b, a)
        assert eng.events("y", "last") == ev_before
        assert eng.power_mw("y", "mean") == eng.meter.power_mw(
            ev_before, eng.frame_hz)
        assert eng.n_traces == 1

    def test_skewed_rates_match_dedicated_loops(self, served):
        """A 1x-rate stream and a 1/3x-rate stream in one engine each
        match their own dedicated batch-1 loop over exactly the frames
        they were fed — frame-rate skew is invisible per stream."""
        cfg, params = served
        stream = SceneStream(image=64)
        eng = SaccadeEngine(cfg, params, capacity=2)
        eng.admit("fast")
        eng.admit("slow")
        boot = jax.jit(make_bootstrap_indices(cfg))
        step1 = jax.jit(make_saccade_step(cfg))
        refs = {"fast": None, "slow": None}
        for t in range(6):
            rgb, _ = stream.batch(t, 2)
            frames = {"fast": rgb[0]}
            if t % 3 == 0:
                frames["slow"] = rgb[1]
            out = eng.step(frames)
            assert set(out) == set(frames)
            for i, sid in enumerate(("fast", "slow")):
                if sid not in frames:
                    continue
                r = jnp.asarray(rgb[i:i + 1])
                if refs[sid] is None:
                    refs[sid] = boot(params, r)
                logits, refs[sid], _ = step1(params, r, refs[sid])
                np.testing.assert_allclose(
                    out[sid], np.asarray(logits[0]), atol=1e-5)
        assert eng.n_traces == 1
        assert int(eng.state.frame_age[eng.slot_of("fast")]) == 6
        assert int(eng.state.frame_age[eng.slot_of("slow")]) == 2


class TestIngestChurnCoalescing:
    """Tentpole (DESIGN.md §12/§15): ingest uploads ONLY the fed rows,
    scattered into one persistent device frame buffer through reused
    host staging, and admit/evict churn coalesces into one flush."""

    def test_ingest_scatters_only_fed_rows(self, served):
        """The per-tick H2D transfer is the F fed rows — never a
        full-capacity upload — scattered into the persistent donated
        device frame buffer; un-fed rows keep the bytes of the last
        tick that fed them, and the host staging is never reallocated."""
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=4)
        for sid in ("a", "b", "c"):
            eng.admit(sid)
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 3)
        stage = eng._stage
        seen = []
        inner = eng._scatter_fn
        def spy(buf, rows, slots):
            seen.append((tuple(rows.shape), np.asarray(slots).tolist()))
            return inner(buf, rows, slots)
        eng._scatter_fn = spy
        eng.step({"a": rgb[0], "b": rgb[1], "c": rgb[2]})
        eng.step({"b": rgb[0]})                       # only b fed: 1 row
        (shape3, slots3), (shape1, slots1) = seen
        assert shape3[0] == 3 and shape1[0] == 1
        assert set(slots3) == {eng.slot_of(s) for s in ("a", "b", "c")}
        assert slots1 == [eng.slot_of("b")]
        assert eng._stage is stage                    # reused, no realloc
        buf = np.asarray(eng._frames_dev)
        np.testing.assert_array_equal(                # un-fed row persists
            buf[eng.slot_of("a")], np.asarray(rgb[0], np.float32))
        np.testing.assert_array_equal(                # fed row refreshed
            buf[eng.slot_of("b")], np.asarray(rgb[0], np.float32))
        np.testing.assert_array_equal(
            buf[eng.slot_of("c")], np.asarray(rgb[2], np.float32))

    def test_churn_coalesces_to_one_flush(self, served):
        """k admits/evicts between two frames must cost ONE jitted churn
        dispatch, not k — counted by wrapping the compiled churn fn."""
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=4)
        calls = []
        inner = eng._churn_fn
        eng._churn_fn = lambda *a: (calls.append(1), inner(*a))[1]
        eng.admit("a")
        eng.admit("b")
        eng.admit("c")
        eng.evict("b")
        eng.admit("d")                       # reuses b's slot, last-op-wins
        assert calls == []                   # nothing dispatched yet
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 3)
        out = eng.step({"a": rgb[0], "c": rgb[1], "d": rgb[2]})
        assert len(calls) == 1               # one flush for 5 churn ops
        assert set(out) == {"a", "c", "d"}
        st = eng.state
        assert len(calls) == 1               # nothing pending -> no flush
        assert int(np.asarray(st.active).sum()) == 3
        slot_c = eng.slot_of("c")
        eng.evict("c")
        assert not bool(eng.state.active[slot_c])  # state read flushes lazily
        assert len(calls) == 2


class TestStatefulFuzz:
    """Satellite: random admit/evict/PARTIAL-step sequences against a
    pure-Python slot-bookkeeping oracle AND per-stream reference
    single-stream loops — slot reuse, free_slots, one compile, output
    isolation, and per-slot meter correctness for held (un-fed) frames
    must all survive arbitrary churn with frame-rate skew."""

    @pytest.mark.parametrize("seed", [0, 1])
    def test_random_async_churn_against_oracle(self, served, seed):
        cfg, params = served
        capacity = 3
        eng = SaccadeEngine(cfg, params, capacity=capacity)
        boot = jax.jit(make_bootstrap_indices(cfg))
        step1 = jax.jit(make_saccade_step(cfg))
        stream = SceneStream(image=64)
        pool = stream.batch(9000 + seed, 8)[0]          # frame pool

        rng = np.random.default_rng(1000 + seed)
        slots: list = [None] * capacity                  # the oracle
        refs: dict = {}                      # sid -> [idx, age, last_events]
        next_id = 0
        stepped = False

        for op_i in range(40):
            op = rng.choice(["admit", "evict", "step"], p=[0.35, 0.2, 0.45])
            if op == "admit":
                sid = f"s{next_id}"
                if None not in slots:
                    with pytest.raises(RuntimeError, match="capacity"):
                        eng.admit(sid)
                    continue
                got = eng.admit(sid)
                want = slots.index(None)                 # lowest free slot
                slots[want] = sid
                refs[sid] = [None, 0, None]
                next_id += 1
                assert got == want, f"op {op_i}: slot reuse broke"
            elif op == "evict":
                live = [s for s in slots if s is not None]
                if not live:
                    with pytest.raises(KeyError):
                        eng.evict("nope")
                    continue
                sid = live[int(rng.integers(len(live)))]
                eng.evict(sid)
                slots[slots.index(sid)] = None
                del refs[sid]
            else:
                live = [s for s in slots if s is not None]
                # frame-rate skew: each live stream is fed with p=0.6 —
                # the rest HOLD this tick (async partial cover)
                fed = [sid for sid in live if rng.random() < 0.6]
                frames = {
                    sid: pool[(slots.index(sid) + 2 * refs[sid][1]) % len(pool)]
                    for sid in fed
                }
                out = eng.step(frames)
                if fed:
                    stepped = True
                assert set(out) == set(fed)
                # per-stream isolation: every FED stream matches its own
                # dedicated batch-1 loop over exactly the frames it was
                # fed, whatever its neighbours did or held
                for sid in fed:
                    r = jnp.asarray(frames[sid])[None]
                    if refs[sid][0] is None:
                        refs[sid][0] = boot(params, r)
                    logits, refs[sid][0], _ = step1(params, r, refs[sid][0])
                    np.testing.assert_allclose(
                        out[sid], np.asarray(logits[0]), atol=1e-5,
                        err_msg=f"op {op_i}: stream {sid} diverged")
                    refs[sid][1] += 1
                    refs[sid][2] = eng.events(sid, "last")
                # held streams' meters must not have moved (zero accrual)
                for sid in live:
                    if sid not in fed and refs[sid][2] is not None:
                        assert eng.events(sid, "last") == refs[sid][2], (
                            f"op {op_i}: held stream {sid} accrued events")

            # bookkeeping invariants after every op
            assert eng.free_slots == slots.count(None)
            assert eng.stream_ids == [s for s in slots if s is not None]
            # satellite: the cached sid->slot map can never drift from
            # the slot list it replaced (zero behavior change)
            assert eng._slot_index == {
                sid: i for i, sid in enumerate(slots) if sid is not None}
            for s_i, sid in enumerate(slots):
                if sid is not None:
                    assert eng.slot_of(sid) == s_i
                    assert int(eng.state.frame_age[s_i]) == refs[sid][1]
            assert int(np.asarray(eng.state.active).sum()) == (
                capacity - slots.count(None))

        assert stepped and eng.n_traces == 1, (
            f"churn caused {eng.n_traces} compiles")


class TestZeroRecompile:
    def test_one_compile_across_admit_evict_admit(self, served):
        """The acceptance-criterion contract: a full admit -> evict ->
        admit cycle with steps in between never retraces the batched
        step — the program is a pure function of fixed slot shapes."""
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=3)
        stream = SceneStream(image=64)

        eng.admit("a")
        eng.admit("b")
        rgb, _ = stream.batch(0, 3)
        eng.step({"a": rgb[0], "b": rgb[1]})
        assert eng.n_traces == 1
        eng.evict("a")
        eng.step({"b": rgb[1]})
        eng.admit("c")                       # reuses a's slot, fresh state
        eng.step({"b": rgb[1], "c": rgb[2]})
        eng.admit("d")
        eng.step({"b": rgb[0], "c": rgb[1], "d": rgb[2]})
        assert eng.n_traces == 1, "admit/evict churn caused a recompile"

    def test_aux_energy_replaces_second_sensor_pass(self, served):
        """Satellite regression: the saccade step's explore term reads the
        patch energy from aux (computed once in the frontend) — the aux
        must carry it and it must equal a direct sensor_patches pass."""
        cfg, params = served
        from repro.core import frontend as fe
        from repro.core import saliency as sal

        stream = SceneStream(image=64)
        rgb = jnp.asarray(stream.batch(0, 2)[0])
        boot = make_bootstrap_indices(cfg)(params, rgb)
        _, _, aux = make_saccade_step(cfg)(params, rgb, boot)
        assert "energy" in aux
        patches, _ = fe.sensor_patches(params["ip2"], rgb, cfg.frontend)
        np.testing.assert_allclose(
            np.asarray(aux["energy"]),
            np.asarray(sal.patch_energy(patches)), atol=1e-6)
