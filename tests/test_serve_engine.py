"""Multi-stream saccadic serving engine (DESIGN.md §5): slot bookkeeping,
per-stream state isolation, equivalence with the single-stream step, and
the zero-recompile contract across admit/evict churn."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit
from repro.serve.engine import SaccadeEngine, init_stream_state
from repro.serve.serve_step import (
    make_bootstrap_indices, make_saccade_step, saccade_scores,
)

KEY = jax.random.PRNGKey(0)


def _cfg(**kw):
    fcfg = FrontendConfig(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    base = dict(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    base.update(kw)
    return ViTConfig(**base)


@pytest.fixture(scope="module")
def served():
    cfg = _cfg()
    return cfg, init_vit(KEY, cfg)


class TestBookkeeping:
    def test_admit_evict_slot_reuse(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        assert eng.free_slots == 2
        s0 = eng.admit("a")
        s1 = eng.admit("b")
        assert {s0, s1} == {0, 1} and eng.free_slots == 0
        with pytest.raises(RuntimeError, match="capacity"):
            eng.admit("c")
        with pytest.raises(ValueError, match="already admitted"):
            eng.admit("a")
        eng.evict("a")
        assert eng.free_slots == 1 and eng.stream_ids == ["b"]
        assert eng.admit("c") == s0          # freed slot is reused
        with pytest.raises(KeyError):
            eng.evict("zzz")

    def test_step_requires_exact_stream_cover(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        eng.admit("a")
        frame = np.zeros((64, 64, 3), np.float32)
        with pytest.raises(ValueError, match="unknown"):
            eng.step({"a": frame, "b": frame})
        with pytest.raises(ValueError, match="missing"):
            eng.step({})

    def test_idle_engine_step_is_a_noop(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        assert eng.step({}) == {}
        assert eng.n_traces == 0         # no streams -> no device dispatch

    def test_admit_resets_row_state(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=2)
        eng.admit("a")
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 1)
        for t in range(2):
            eng.step({"a": rgb[0]})
        slot = eng.slot_of("a")
        assert int(eng.state.frame_age[slot]) == 2
        eng.evict("a")
        assert not bool(eng.state.active[slot])
        eng.admit("a2")                      # same slot, fresh stream
        assert eng.slot_of("a2") == slot
        assert int(eng.state.frame_age[slot]) == 0
        assert float(jnp.abs(eng.state.ema[slot]).max()) == 0.0

    def test_gaze_undefined_before_first_frame(self, served):
        """A fresh admit has no gaze yet (the first selection is the
        in-step energy bootstrap) — gaze() must refuse, not report the
        arange placeholder as if it were a real selection."""
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=1)
        eng.admit("a")
        with pytest.raises(RuntimeError, match="bootstrap"):
            eng.gaze("a")
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 1)
        eng.step({"a": rgb[0]})
        assert sorted(set(eng.gaze("a").tolist())) == sorted(eng.gaze("a").tolist())

    def test_init_state_shapes(self):
        cfg = _cfg()
        st = init_stream_state(cfg, 5)
        k, p = cfg.frontend.n_active, cfg.frontend.n_patches
        assert st.indices.shape == (5, k) and st.ema.shape == (5, p)
        assert st.frame_age.shape == (5,) and st.active.shape == (5,)
        assert not bool(st.active.any())


class TestEquivalence:
    def test_engine_matches_single_stream_loop(self, served):
        """Each slot must serve its stream EXACTLY as a dedicated batch-1
        single-stream loop would (bootstrap included), regardless of what
        the other slots are doing."""
        cfg, params = served
        stream = SceneStream(image=64)
        eng = SaccadeEngine(cfg, params, capacity=4)   # 2 slots stay empty
        eng.admit("x")
        eng.admit("y")

        boot = jax.jit(make_bootstrap_indices(cfg))
        step = jax.jit(make_saccade_step(cfg))
        idx = {"x": None, "y": None}
        for t in range(3):
            rgb, _ = stream.batch(t, 2)
            out = eng.step({"x": rgb[0], "y": rgb[1]})
            for i, sid in enumerate(("x", "y")):
                r = jnp.asarray(rgb[i:i + 1])
                if idx[sid] is None:
                    idx[sid] = boot(params, r)
                logits, idx[sid], _ = step(params, r, idx[sid])
                np.testing.assert_allclose(
                    out[sid], np.asarray(logits[0]), atol=1e-5)
                assert (eng.gaze(sid) == np.asarray(idx[sid][0])).all(), (t, sid)

    def test_inactive_slots_emit_zero_logits_and_frozen_state(self, served):
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=3)
        eng.admit("only")
        stream = SceneStream(image=64)
        rgb, _ = stream.batch(0, 1)
        eng.step({"only": rgb[0]})
        free = [s for s in range(3) if s != eng.slot_of("only")]
        st = eng.state
        assert not bool(st.active[jnp.asarray(free)].any())
        assert int(st.frame_age[jnp.asarray(free)].max()) == 0

    def test_ema_blends_scores_across_frames(self, served):
        """ema_decay smooths the saccade policy: state.ema after frame 2
        must equal decay*scores(f1) + (1-decay)*scores(f2) computed from
        the shared single-stream core."""
        cfg, params = served
        decay = 0.7
        eng = SaccadeEngine(cfg, params, capacity=1, ema_decay=decay)
        eng.admit("s")
        stream = SceneStream(image=64)
        step = jax.jit(make_saccade_step(cfg))
        boot = jax.jit(make_bootstrap_indices(cfg))

        rgb0, _ = stream.batch(0, 1)
        rgb1, _ = stream.batch(1, 1)
        eng.step({"s": rgb0[0]})
        eng.step({"s": rgb1[0]})

        r0, r1 = jnp.asarray(rgb0), jnp.asarray(rgb1)
        i0 = boot(params, r0)
        _, _, aux0 = step(params, r0, i0)
        s0 = saccade_scores(aux0, 0.1)
        # frame 1's indices = top-k of the EMA (== s0 on the first frame)
        from repro.core.saliency import topk_patch_indices
        i1 = topk_patch_indices(s0, cfg.frontend.n_active)
        _, _, aux1 = step(params, r1, i1)
        s1 = saccade_scores(aux1, 0.1)
        want = decay * s0 + (1 - decay) * s1
        np.testing.assert_allclose(
            np.asarray(eng.state.ema), np.asarray(want), atol=1e-6)


class TestZeroRecompile:
    def test_one_compile_across_admit_evict_admit(self, served):
        """The acceptance-criterion contract: a full admit -> evict ->
        admit cycle with steps in between never retraces the batched
        step — the program is a pure function of fixed slot shapes."""
        cfg, params = served
        eng = SaccadeEngine(cfg, params, capacity=3)
        stream = SceneStream(image=64)

        eng.admit("a")
        eng.admit("b")
        rgb, _ = stream.batch(0, 3)
        eng.step({"a": rgb[0], "b": rgb[1]})
        assert eng.n_traces == 1
        eng.evict("a")
        eng.step({"b": rgb[1]})
        eng.admit("c")                       # reuses a's slot, fresh state
        eng.step({"b": rgb[1], "c": rgb[2]})
        eng.admit("d")
        eng.step({"b": rgb[0], "c": rgb[1], "d": rgb[2]})
        assert eng.n_traces == 1, "admit/evict churn caused a recompile"

    def test_aux_energy_replaces_second_sensor_pass(self, served):
        """Satellite regression: the saccade step's explore term reads the
        patch energy from aux (computed once in the frontend) — the aux
        must carry it and it must equal a direct sensor_patches pass."""
        cfg, params = served
        from repro.core import frontend as fe
        from repro.core import saliency as sal

        stream = SceneStream(image=64)
        rgb = jnp.asarray(stream.batch(0, 2)[0])
        boot = make_bootstrap_indices(cfg)(params, rgb)
        _, _, aux = make_saccade_step(cfg)(params, rgb, boot)
        assert "energy" in aux
        patches, _ = fe.sensor_patches(params["ip2"], rgb, cfg.frontend)
        np.testing.assert_allclose(
            np.asarray(aux["energy"]),
            np.asarray(sal.patch_energy(patches)), atol=1e-6)
