"""Digital wire format (DESIGN.md §9): dtype discipline and round-trip
exactness of the ADC-code-native dataflow.

Two contracts:

* **Dtype discipline** — no float32 feature payload may leak into a wire
  or cache pytree leaf: ``CompactFeatures.features``,
  ``FeatureCache.features`` and the engine's ``StreamState.cache.features``
  must all stay at ADC code width (int8) through every mutation (step,
  admit wipe, evict, refresh). Scale/zero/gain metadata are O(M)/O(k)
  floats by design; the O(k·M) payload is the wire.

* **Round-trip exactness** — ``dequantize(digital_codes(v)) ==
  digital_readout(v)`` bit-for-bit for ANY v (the float view is defined
  as the dequant), and the affine inverts the encode exactly over the ADC
  grid. Property-driven under hypothesis, with an always-on deterministic
  battery so a bare-jax container still runs the checks.
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

import repro.core as c
from repro.core import adc as adc_mod
from repro.core.frontend import FrontendConfig, apply_frontend, dequantize_features
from repro.core.projection import PatchSpec
from repro.core.temporal import TemporalSpec, init_feature_cache
from repro.data.pipeline import SceneStream
from repro.models.vit import ViTConfig, init_vit, vit_forward_compact
from repro.serve.engine import SaccadeEngine
from repro.serve.serve_step import make_bootstrap_indices, make_saccade_step

KEY = jax.random.PRNGKey(0)


def _fcfg(**kw):
    base = dict(
        image_h=64, image_w=64,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
        active_fraction=0.25,
    )
    base.update(kw)
    return FrontendConfig(**base)


def _vcfg(fcfg, **kw):
    base = dict(frontend=fcfg, n_layers=1, d_model=32, n_heads=2, d_ff=64)
    base.update(kw)
    return ViTConfig(**base)


def _payload_leaves(tree):
    """Every pytree leaf that is a feature payload (a ``features`` field of
    CompactFeatures / FeatureCache, at any nesting depth)."""
    leaves = []
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        names = [getattr(p, "name", None) for p in path]
        if names and names[-1] == "features":
            leaves.append((jax.tree_util.keystr(path), leaf))
    return leaves


def _assert_code_payloads(tree, cfg):
    leaves = _payload_leaves(tree)
    assert leaves, "pytree carries no feature payload leaf"
    want = jnp.dtype(cfg.adc.code_dtype)
    for name, leaf in leaves:
        assert leaf.dtype == want, f"{name}: {leaf.dtype} leaked into the wire"
        assert leaf.nbytes == leaf.size * want.itemsize


class TestDtypeDiscipline:
    def test_apply_frontend_compact_payload_is_codes(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cf = apply_frontend(params, rgb, fcfg, mode="compact")
        _assert_code_payloads(cf, fcfg)
        # the wire payload is exactly k * M codes = k * M bytes at 8 bits
        assert cf.features.nbytes == 2 * fcfg.n_active * fcfg.patch.n_vectors

    def test_feature_cache_payload_is_codes(self):
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cache = init_feature_cache(fcfg, (2,))
        _assert_code_payloads(cache, fcfg)
        for _ in range(3):
            cf, cache = apply_frontend(params, rgb, fcfg, mode="compact",
                                       cache=cache)
            _assert_code_payloads((cf, cache), fcfg)

    def test_stream_state_payload_stays_codes_under_churn(self):
        """step / admit (recycled slot) / evict never promote the held
        cache to float — the admit row wipe is the classic offender
        (where(hit, 0.0, int8) would silently upcast)."""
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        cfg = _vcfg(fcfg)
        params = init_vit(KEY, cfg)
        eng = SaccadeEngine(cfg, params, capacity=2, temporal=True)
        frame = SceneStream(image=64).batch(0, 1)[0][0]
        _assert_code_payloads(eng.state, fcfg)
        eng.admit("a")
        _assert_code_payloads(eng.state, fcfg)
        eng.step({"a": frame})
        _assert_code_payloads(eng.state, fcfg)
        eng.evict("a")
        eng.admit("b")          # recycled slot: full cache-row wipe
        _assert_code_payloads(eng.state, fcfg)
        eng.step({"b": frame})
        _assert_code_payloads(eng.state, fcfg)

    def test_saccade_step_aux_cache_is_codes(self):
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        cfg = _vcfg(fcfg)
        params = init_vit(KEY, cfg)
        rgb = jnp.asarray(SceneStream(image=64).batch(0, 2)[0])
        step = jax.jit(make_saccade_step(cfg, temporal=True))
        idx = make_bootstrap_indices(cfg)(params, rgb)
        cache = init_feature_cache(fcfg, (2,))
        _, _, _, cache = step(params, rgb, idx, cache)
        _assert_code_payloads(cache, fcfg)

    def test_cache_wire_mismatch_raises(self):
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        f32_cache = init_feature_cache(fcfg, (1,), dtype=jnp.float32)
        with pytest.raises(ValueError, match="does not match wire"):
            apply_frontend(params, rgb, fcfg, mode="compact", cache=f32_cache)
        code_cache = init_feature_cache(fcfg, (1,))
        with pytest.raises(ValueError, match="does not match wire"):
            apply_frontend(params, rgb, fcfg, mode="compact",
                           cache=code_cache, wire="float")

    def test_narrow_adc_still_int8_wide_adc_widens(self):
        assert jnp.dtype(adc_mod.ADCSpec(bits=4).code_dtype) == jnp.int8
        assert jnp.dtype(adc_mod.ADCSpec(bits=10).code_dtype) == jnp.int16

    def test_float_simulation_has_no_code_wire(self):
        """analog=False (the paper's algorithm simulation) has no edge
        ADC: the default wire resolves to the unquantized float view —
        keeping dense==compact equivalence exact for that config — and an
        explicit codes request raises."""
        fcfg = _fcfg(analog=False, bayer=False)
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        dense, mask = apply_frontend(params, rgb, fcfg)
        cf = apply_frontend(params, rgb, fcfg, mask=mask, mode="compact")
        assert cf.features.dtype == jnp.float32
        gathered = jnp.take_along_axis(dense, cf.indices[..., None], axis=-2)
        np.testing.assert_array_equal(
            np.asarray(dequantize_features(cf)), np.asarray(gathered))
        with pytest.raises(ValueError, match="requires analog=True"):
            apply_frontend(params, rgb, fcfg, mode="compact", wire="codes")

    def test_codes_adapter_rejected_on_float_paths(self):
        """A codes-emitting kernel adapter must not be silently consumed
        as analog voltage by the dense or float-wire paths."""
        from repro.kernels import ops

        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        codes_fn = ops.ip2_codes_fn(fcfg.patch, fcfg.adc, interpret=True)
        with pytest.raises(ValueError, match="emits wire-format codes"):
            apply_frontend(params, rgb, fcfg, mode="dense", project_fn=codes_fn)
        with pytest.raises(ValueError, match="emits wire-format codes"):
            apply_frontend(params, rgb, fcfg, mode="compact",
                           project_fn=codes_fn, wire="float")


def check_roundtrip_exact(v: np.ndarray, v_ref: float, bias, bits: int) -> None:
    """dequantize(digital_codes(v)) == digital_readout(v) BITWISE — the
    float path is defined as the dequant (DESIGN.md §9)."""
    spec = adc_mod.ADCSpec(bits=bits)
    va = jnp.asarray(v, jnp.float32)
    codes = adc_mod.digital_codes(va, v_ref, bias, spec)
    deq = adc_mod.dequantize(*codes)
    ro = adc_mod.digital_readout(va, v_ref, bias, spec)
    np.testing.assert_array_equal(np.asarray(deq), np.asarray(ro))
    # codes fit the advertised width and hit every voltage within lsb/2
    assert codes.codes.dtype == spec.code_dtype
    half_lsb = spec.lsb / 2 + 1e-7
    in_rails = (v >= spec.v_min) & (v <= spec.v_max)
    volts = np.asarray(deq) + np.asarray(
        jnp.asarray(v_ref - jnp.asarray(bias, jnp.float32))
    )
    assert np.abs(volts - v)[in_rails].max() <= half_lsb


def check_grid_identity(bits: int) -> None:
    """Over the exact ADC grid the conversion is the identity: every
    representable voltage encodes to itself (codes lose nothing that was
    ever on the wire — requant-free seams are exact)."""
    spec = adc_mod.ADCSpec(bits=bits)
    grid = spec.v_min + np.arange(spec.levels) * spec.lsb
    codes = adc_mod.encode(jnp.asarray(grid, jnp.float32), spec)
    assert len(np.unique(np.asarray(codes))) == spec.levels
    scale, zero = adc_mod.readout_scale_zero(0.0, 0.0, spec)
    back = np.asarray(adc_mod.dequantize(codes, scale, zero))
    np.testing.assert_allclose(back, grid, atol=spec.lsb * 1e-3)


class TestRoundTripDeterministic:
    """Always-on battery (runs without hypothesis)."""

    @pytest.mark.parametrize("bits", [4, 6, 8, 10])
    def test_grid_identity(self, bits):
        check_grid_identity(bits)

    @pytest.mark.parametrize("bits", [4, 8])
    def test_roundtrip_exact(self, bits):
        rng = np.random.default_rng(0)
        v = rng.uniform(-1.5, 1.5, size=257).astype(np.float32)
        bias = jnp.asarray(rng.normal(size=()) * 0.1, jnp.float32)
        check_roundtrip_exact(v, 0.3, bias, bits)

    def test_frontend_scale_zero_matches_adc(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        scale, zero = c.feature_scale_zero(params, fcfg)
        s2, z2 = adc_mod.readout_scale_zero(
            fcfg.patch.summer.v_ref, params["bias"], fcfg.adc)
        np.testing.assert_array_equal(np.asarray(scale), np.asarray(s2))
        np.testing.assert_array_equal(np.asarray(zero), np.asarray(z2))


if HAVE_HYPOTHESIS:

    @settings(max_examples=40, deadline=None)
    @given(
        bits=st.integers(2, 10),
        v_ref=st.floats(-0.5, 0.5),
        bias=st.floats(-0.2, 0.2),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_roundtrip_property(bits, v_ref, bias, seed):
        rng = np.random.default_rng(seed)
        v = rng.uniform(-2.0, 2.0, size=64).astype(np.float32)
        check_roundtrip_exact(v, v_ref, jnp.float32(bias), bits)

    @settings(max_examples=20, deadline=None)
    @given(bits=st.integers(2, 12))
    def test_grid_identity_property(bits):
        check_grid_identity(bits)


class TestSeamEquivalence:
    """The end-to-end obligation: the code path dequantizes to the float
    path exactly at every seam where no requant occurs."""

    def test_code_wire_equals_float_wire_bitwise(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (3, 64, 64, 3))
        cfc = apply_frontend(params, rgb, fcfg, mode="compact")
        cff = apply_frontend(params, rgb, fcfg, mode="compact", wire="float")
        assert cfc.features.dtype == jnp.int8
        assert cff.features.dtype == jnp.float32
        np.testing.assert_array_equal(
            np.asarray(dequantize_features(cfc)),
            np.asarray(dequantize_features(cff)))

    def test_saccade_loop_logits_code_vs_float_wire(self):
        """Full closed-loop trajectory: logits AND selections from the
        code-native step equal the float-wire step bit for bit (same
        ADCSpec end to end — no requant anywhere)."""
        cfg = _vcfg(_fcfg(), n_layers=2, d_model=64, n_heads=4, d_ff=128)
        params = init_vit(KEY, cfg)
        stream = SceneStream(image=64)

        def make(wire):
            def step(p, rgb, idx):
                return vit_forward_compact(p, rgb, cfg, indices=idx, wire=wire)
            return jax.jit(step)

        s_code, s_float = make("codes"), make("float")
        idx = make_bootstrap_indices(cfg)(
            params, jnp.asarray(stream.batch(0, 3)[0]))
        for t in range(3):
            rgb = jnp.asarray(stream.batch(t, 3)[0])
            lc, auxc = s_code(params, rgb, idx)
            lf, auxf = s_float(params, rgb, idx)
            np.testing.assert_array_equal(np.asarray(lc), np.asarray(lf))
            np.testing.assert_array_equal(
                np.asarray(auxc["saliency"]), np.asarray(auxf["saliency"]))
            idx = c.topk_patch_indices(auxc["saliency"] + auxc["energy"] * 1e-3,
                                       cfg.frontend.n_active)

    def test_quant_embed_within_lsb_budget(self):
        """The w8a8 consumption path (codes straight into quant_matmul, no
        second activation rounding) stays within a couple of ADC LSBs of
        the exact dequant path — the weight-side int8 quantization is the
        only approximation."""
        fcfg = _fcfg()
        cfg = _vcfg(fcfg, n_layers=2, d_model=64, n_heads=4, d_ff=128)
        cfg_q = dataclasses.replace(cfg, quant_embed=True)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(jax.random.PRNGKey(5), (3, 64, 64, 3))
        exact, _ = vit_forward_compact(params, rgb, cfg)
        quant, _ = vit_forward_compact(params, rgb, cfg_q)
        lsb = fcfg.adc.lsb
        assert float(jnp.abs(exact - quant).max()) <= 2.0 * lsb
        # programmed-once weight prep (prepare_quant_embed) is bitwise the
        # same as the per-call fallback
        from repro.models.vit import prepare_quant_embed

        prepped, _ = vit_forward_compact(prepare_quant_embed(params), rgb, cfg_q)
        np.testing.assert_array_equal(np.asarray(prepped), np.asarray(quant))

    def test_changed_adcspec_requant_bounded_by_one_lsb(self):
        """The only seam allowed to move values: serving a cache written
        under one ADCSpec through a changed spec's dequant is a requant —
        bounded by one (coarser) LSB, exact when the spec is unchanged."""
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cf = apply_frontend(params, rgb, fcfg, mode="compact")
        # same spec: exact (identity requant)
        re_enc = adc_mod.encode(
            dequantize_features(cf)
            + (fcfg.patch.summer.v_ref - params["bias"]), fcfg.adc)
        np.testing.assert_array_equal(np.asarray(re_enc), np.asarray(cf.features))
        # coarser spec: each value moves by at most half its (coarser) LSB
        coarse = adc_mod.ADCSpec(bits=6)
        volts = dequantize_features(cf) + (fcfg.patch.summer.v_ref - params["bias"])
        s, z = adc_mod.readout_scale_zero(fcfg.patch.summer.v_ref,
                                          params["bias"], coarse)
        requant = adc_mod.dequantize(adc_mod.encode(volts, coarse), s, z)
        err = jnp.abs(requant - dequantize_features(cf))
        assert float(err.max()) <= coarse.lsb


def _assert_sign_payloads(tree):
    """Sign-wire variant of :func:`_assert_code_payloads`: every feature
    payload leaf is the 1-bit comparator wire (bool, NOT int8 codes)."""
    leaves = _payload_leaves(tree)
    assert leaves, "pytree carries no feature payload leaf"
    for name, leaf in leaves:
        assert leaf.dtype == jnp.bool_, \
            f"{name}: {leaf.dtype} leaked into the sign wire"


class TestSignWireDtype:
    """DESIGN.md §13: wire='sign' is a third wire format with its own
    dtype discipline — the walks that pin the code wire pin it too."""

    def test_apply_frontend_sign_payload_is_bool(self):
        fcfg = _fcfg()
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        cf = apply_frontend(params, rgb, fcfg, mode="compact", wire="sign")
        _assert_sign_payloads(cf)
        # metadata carries the sign affine, not the ADC affine
        scale, zero = adc_mod.sign_scale_zero(params["bias"])
        np.testing.assert_allclose(np.asarray(cf.scale),
                                   np.asarray(scale), rtol=1e-6)
        np.testing.assert_allclose(np.asarray(cf.zero),
                                   np.asarray(zero), rtol=1e-6)

    def test_sign_cache_stays_bool_under_mutation(self):
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        params = c.init_frontend_params(KEY, fcfg)
        cache = init_feature_cache(fcfg, (2,), dtype=bool)
        _assert_sign_payloads(cache)
        for t in range(3):
            rgb = jax.random.uniform(jax.random.PRNGKey(t), (2, 64, 64, 3))
            cf, cache = apply_frontend(params, rgb, fcfg, mode="compact",
                                       wire="sign", cache=cache)
            _assert_sign_payloads((cf, cache))

    def test_sign_cache_wire_mismatch_raises_both_ways(self):
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        params = c.init_frontend_params(KEY, fcfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        with pytest.raises(ValueError, match="does not match wire"):
            apply_frontend(params, rgb, fcfg, mode="compact", wire="sign",
                           cache=init_feature_cache(fcfg, (2,)))
        with pytest.raises(ValueError, match="does not match wire"):
            apply_frontend(params, rgb, fcfg, mode="compact", wire="codes",
                           cache=init_feature_cache(fcfg, (2,), dtype=bool))
        with pytest.raises(ValueError, match="does not match wire"):
            apply_frontend(params, rgb, fcfg, mode="compact", wire="float",
                           cache=init_feature_cache(fcfg, (2,), dtype=bool))


class TestBackendCacheDiscipline:
    """DESIGN.md §14: the BackendCache's reuse KEY rides the same wire
    format as the FeatureCache (int8 codes — the key is a bitwise
    comparison against served codes, so a float copy would both 4x the
    footprint and break exactness), while the activation payload
    ``x_out`` is deliberately float32 (it caches encoder outputs, not
    wire bytes). Every mutation — engine step, admit row-wipe, hold
    freeze — must preserve both dtypes, and the whole cache must stay a
    slot-major pytree (static shapes, shard/donate with the slot axis)."""

    def _beng(self, capacity=2):
        fcfg = _fcfg(temporal=TemporalSpec(delta_threshold=1e-5))
        cfg = _vcfg(fcfg)
        params = init_vit(KEY, cfg)
        eng = SaccadeEngine(cfg, params, capacity=capacity, temporal=True,
                            backend_delta=True)
        return cfg, eng

    def _assert_backend_cache(self, bc, cfg):
        want = jnp.dtype(cfg.frontend.adc.code_dtype)
        assert bc.feats.dtype == want, (
            f"backend reuse key left the wire: {bc.feats.dtype}")
        assert bc.x_out.dtype == jnp.float32
        assert bc.gain.dtype == jnp.float32
        assert bc.indices.dtype == jnp.int32
        assert bc.tvalid.dtype == jnp.bool_
        assert bc.valid.dtype == jnp.bool_

    def test_backend_cache_payload_stays_codes_under_churn(self):
        cfg, eng = self._beng()
        frame = SceneStream(image=64).batch(0, 1)[0][0]
        capacity = eng.capacity
        self._assert_backend_cache(eng.state.bcache, cfg)
        eng.admit("a")
        eng.step({"a": frame})
        self._assert_backend_cache(eng.state.bcache, cfg)
        eng.evict("a")
        eng.admit("b")              # recycled slot: full row wipe
        st = eng.state
        self._assert_backend_cache(st.bcache, cfg)
        assert not bool(st.bcache.valid[eng.slot_of("b")])
        eng.step({"b": frame})
        self._assert_backend_cache(eng.state.bcache, cfg)
        # slot-major discipline: every leaf keeps the static (S, ...) shape
        for leaf in jax.tree_util.tree_leaves(eng.state.bcache):
            assert leaf.shape[0] == capacity

    def test_backend_cache_wire_mismatch_raises_both_ways(self):
        from repro.models.backend_delta import init_backend_cache

        fcfg = _fcfg()
        cfg = _vcfg(fcfg)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        f32_bc = init_backend_cache(cfg, fcfg.n_active, (1,),
                                    dtype=jnp.float32)
        with pytest.raises(ValueError, match="does not match wire"):
            vit_forward_compact(params, rgb, cfg, backend_cache=f32_bc)
        code_bc = init_backend_cache(cfg, fcfg.n_active, (1,),
                                     dtype=fcfg.adc.code_dtype)
        with pytest.raises(ValueError, match="does not match wire"):
            vit_forward_compact(params, rgb, cfg, wire="float",
                                backend_cache=code_bc)

    def test_backend_cache_float_wire_pairs_with_float_key(self):
        """The float STE wire is a legal backend-delta pairing — the key
        comparison is still bitwise, just over f32 payloads."""
        from repro.models.backend_delta import init_backend_cache

        fcfg = _fcfg()
        cfg = _vcfg(fcfg)
        params = init_vit(KEY, cfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        bc = init_backend_cache(cfg, fcfg.n_active, (1,), dtype=jnp.float32)
        logits, aux = vit_forward_compact(params, rgb, cfg, wire="float",
                                          backend_cache=bc)
        assert aux["backend_cache"].feats.dtype == jnp.float32
        logits2, aux2 = vit_forward_compact(
            params, rgb, cfg, wire="float",
            backend_cache=aux["backend_cache"])
        np.testing.assert_array_equal(np.asarray(logits),
                                      np.asarray(logits2))
