"""Property-based tests for the index-first selection API
(`core/saliency.py`, DESIGN.md §3.1): round-trip between the index and
mask views, the exactly-k contract under arbitrary ties, deterministic
tie-breaking, and the gather's scatter-add transpose.

Each invariant is a plain checker over (scores|mask, k); hypothesis
drives them with adversarial inputs when installed (requirements-dev),
and a seeded deterministic battery — heavy on ties, the known failure
mode of threshold-style selection — always runs so the invariants stay
covered even without hypothesis (e.g. a bare-jax container).
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.saliency import (
    gather_patches,
    indices_from_mask,
    mask_from_indices,
    topk_patch_indices,
    topk_patch_mask,
)

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# invariant checkers (shared by the hypothesis and deterministic drivers)
# ---------------------------------------------------------------------------

def check_exactly_k_and_tiebreak(scores: np.ndarray, k: int) -> None:
    """topk_patch_indices returns k DISTINCT indices equal to the first k
    of a stable sort by descending score (ties -> ascending index), and
    the mask view has exactly k True entries."""
    n = scores.shape[-1]
    idx = np.asarray(topk_patch_indices(jnp.asarray(scores), k))
    assert idx.shape == (k,) and len(set(idx.tolist())) == k
    oracle = np.argsort(-scores, kind="stable")[:k]
    np.testing.assert_array_equal(idx, oracle)
    mask = np.asarray(mask_from_indices(jnp.asarray(idx), n))
    assert int(mask.sum()) == k
    frac_mask = np.asarray(topk_patch_mask(jnp.asarray(scores), k / n))
    np.testing.assert_array_equal(mask, frac_mask)


def check_indices_mask_roundtrip(scores: np.ndarray, k: int) -> None:
    """indices -> mask -> indices recovers the same selection (as a set;
    index view is score-ordered, mask view is ascending) with all-valid."""
    n = scores.shape[-1]
    idx = topk_patch_indices(jnp.asarray(scores), k)
    mask = mask_from_indices(idx, n)
    idx2, valid2 = indices_from_mask(mask, k)
    assert bool(valid2.all())
    assert set(np.asarray(idx).tolist()) == set(np.asarray(idx2).tolist())


def check_mask_indices_roundtrip(mask: np.ndarray, k: int) -> None:
    """mask -> indices -> mask: exact reconstruction when <= k active
    (fillers are flagged invalid), lowest-k active indices when over."""
    c = int(mask.sum())
    idx, valid = indices_from_mask(jnp.asarray(mask), k)
    assert int(valid.sum()) == min(c, k)
    back = np.zeros_like(mask)
    sel = np.asarray(idx)[np.asarray(valid)]
    back[sel] = True
    if c <= k:
        np.testing.assert_array_equal(back, mask)
    else:
        want = np.zeros_like(mask)
        want[np.flatnonzero(mask)[:k]] = True
        np.testing.assert_array_equal(back, want)


def check_gather_grad_is_scatter_add(
    patches: np.ndarray, indices: np.ndarray, cotangent: np.ndarray
) -> None:
    """d/dx sum(gather(x, idx) * g) == scatter-add of g at idx — duplicate
    indices must ACCUMULATE (the STE co-design gradient contract)."""
    x = jnp.asarray(patches)
    idx = jnp.asarray(indices, jnp.int32)
    g = jnp.asarray(cotangent)
    grad = jax.grad(lambda p: jnp.sum(gather_patches(p, idx) * g))(x)
    want = np.zeros_like(patches)
    np.add.at(want, np.asarray(indices), np.asarray(cotangent))
    np.testing.assert_allclose(np.asarray(grad), want, atol=1e-6)


# ---------------------------------------------------------------------------
# hypothesis drivers (adversarial inputs; skipped without hypothesis)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:
    # scores drawn from a tiny value set => dense ties by construction
    tied_scores = st.integers(2, 24).flatmap(
        lambda n: st.lists(
            st.sampled_from([0.0, -1.0, 1.0, 0.5, 3.25]), min_size=n, max_size=n
        ).map(lambda v: np.asarray(v, np.float32))
    )
    float_scores = st.integers(2, 24).flatmap(
        lambda n: st.lists(
            st.floats(-100, 100, allow_nan=False, width=32),
            min_size=n, max_size=n,
        ).map(lambda v: np.asarray(v, np.float32))
    )

    class TestHypothesis:
        @settings(max_examples=60, deadline=None)
        @given(st.data(), st.one_of(tied_scores, float_scores))
        def test_exactly_k_and_tiebreak(self, data, scores):
            k = data.draw(st.integers(1, scores.shape[-1]))
            check_exactly_k_and_tiebreak(scores, k)

        @settings(max_examples=40, deadline=None)
        @given(st.data(), st.one_of(tied_scores, float_scores))
        def test_indices_mask_roundtrip(self, data, scores):
            k = data.draw(st.integers(1, scores.shape[-1]))
            check_indices_mask_roundtrip(scores, k)

        @settings(max_examples=40, deadline=None)
        @given(st.data(), st.integers(2, 24))
        def test_mask_indices_roundtrip(self, data, n):
            mask = np.asarray(
                data.draw(st.lists(st.booleans(), min_size=n, max_size=n)))
            k = data.draw(st.integers(1, n))
            check_mask_indices_roundtrip(mask, k)

        @settings(max_examples=30, deadline=None)
        @given(st.data(), st.integers(2, 8), st.integers(1, 6), st.integers(1, 4))
        def test_gather_grad_is_scatter_add(self, data, p, k, nfeat):
            rng = np.random.default_rng(data.draw(st.integers(0, 2**31)))
            idx = np.asarray(
                data.draw(st.lists(st.integers(0, p - 1), min_size=k, max_size=k)))
            check_gather_grad_is_scatter_add(
                rng.normal(size=(p, nfeat)).astype(np.float32), idx,
                rng.normal(size=(k, nfeat)).astype(np.float32))


# ---------------------------------------------------------------------------
# deterministic battery (always runs; tie-heavy by construction)
# ---------------------------------------------------------------------------

def _score_battery():
    cases = [
        np.zeros(7, np.float32),                       # all tied
        np.ones(16, np.float32) * -2.5,                # all tied, negative
        np.asarray([1, 0, 1, 0, 1, 0, 1, 0], np.float32),   # two-value comb
        np.asarray([3, 3, 3, 1, 1, 1, 2, 2], np.float32),   # tied plateaus
        np.asarray([0.5] * 5 + [1.0], np.float32),     # unique max, tied rest
    ]
    rng = np.random.default_rng(1234)
    for n in (2, 5, 13, 24):
        cases.append(rng.choice([0.0, 1.0, -1.0], size=n).astype(np.float32))
        cases.append(rng.normal(size=n).astype(np.float32))
    return cases


@pytest.mark.parametrize("scores", _score_battery(), ids=lambda s: f"n{len(s)}")
def test_exactly_k_and_tiebreak_battery(scores):
    for k in {1, len(scores) // 2, len(scores)} - {0}:
        check_exactly_k_and_tiebreak(scores, k)
        check_indices_mask_roundtrip(scores, k)


@pytest.mark.parametrize("seed", range(8))
def test_mask_roundtrip_battery(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 24))
    mask = rng.random(n) < rng.random()    # varying densities incl. 0 and 1
    for k in {1, max(1, n // 2), n}:
        check_mask_indices_roundtrip(mask, k)


@pytest.mark.parametrize("seed", range(6))
def test_gather_grad_battery(seed):
    rng = np.random.default_rng(100 + seed)
    p, nfeat = int(rng.integers(2, 9)), int(rng.integers(1, 5))
    k = int(rng.integers(1, 7))
    idx = rng.integers(0, p, size=k)       # duplicates likely: accumulation
    check_gather_grad_is_scatter_add(
        rng.normal(size=(p, nfeat)).astype(np.float32), idx,
        rng.normal(size=(k, nfeat)).astype(np.float32))
