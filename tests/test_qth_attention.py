"""Fig. 4 QTH power-of-2 attention (core/qth_attention.py): grid
membership, renormalization, the min_exp threshold, STE gradients, and
the wired-in backend path (ViTConfig.qth)."""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.frontend import FrontendConfig
from repro.core.projection import PatchSpec
from repro.core.qth_attention import (
    QTHSpec, pow2_quantize, qth_attention, qth_attention_weights,
)
from repro.models.vit import ViTConfig, init_vit, vit_forward, vit_forward_compact

KEY = jax.random.PRNGKey(0)


def _scores(shape=(2, 3, 5, 5), seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


class TestPow2Grid:
    def test_quantized_weights_live_on_the_pow2_grid(self):
        """Every nonzero coefficient must be exactly 2^e for an integer e
        in [min_exp, 0] — the binary-weighted cap bank has no other
        ratios to offer."""
        spec = QTHSpec(min_exp=-8, renormalize=False)
        w = qth_attention_weights(_scores(), spec)
        vals = np.asarray(w).ravel()
        grid = {0.0} | {2.0 ** e for e in range(spec.min_exp, 1)}
        assert set(np.unique(vals)) <= grid, sorted(set(np.unique(vals)) - grid)

    def test_pow2_quantize_rounds_to_nearest_exponent(self):
        spec = QTHSpec(min_exp=-8, renormalize=False)
        # 0.3 -> 2^round(log2 0.3) = 2^-2; 0.6 -> 2^-1; 0.9 -> 2^0
        p = jnp.asarray([0.3, 0.6, 0.9])
        np.testing.assert_allclose(np.asarray(pow2_quantize(p, spec)),
                                   [0.25, 0.5, 1.0])

    def test_quantize_never_exceeds_one(self):
        spec = QTHSpec(min_exp=-4)
        p = jnp.linspace(0.0, 1.0, 101)
        assert float(jnp.max(pow2_quantize(p, spec))) <= 1.0

    @pytest.mark.parametrize("min_exp", [-2, -4, -6, -10])
    def test_min_exp_threshold_drops_small_coefficients(self, min_exp):
        """Sweep the QTH underflow threshold: probabilities below
        2^min_exp must quantize to EXACTLY zero (the thresholder simply
        never fires), and coarser thresholds drop more mass."""
        spec = QTHSpec(min_exp=min_exp, renormalize=False)
        p = jax.nn.softmax(_scores(), axis=-1)
        q = np.asarray(pow2_quantize(p, spec))
        pn = np.asarray(p)
        assert (q[pn < 2.0 ** min_exp] == 0.0).all()
        assert (q[pn >= 2.0 ** min_exp] > 0.0).all()

    def test_coarser_threshold_is_sparser(self):
        p = jax.nn.softmax(_scores(shape=(4, 2, 16, 16)), axis=-1)
        nnz = [
            int(jnp.sum(pow2_quantize(
                p, QTHSpec(min_exp=e, renormalize=False)) > 0))
            for e in (-10, -6, -3, -1)
        ]
        assert nnz == sorted(nnz, reverse=True)
        assert nnz[-1] < nnz[0]


class TestRenormalize:
    def test_renormalized_rows_sum_to_one(self):
        w = qth_attention_weights(_scores(), QTHSpec(renormalize=True))
        np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0,
                                   atol=1e-6)

    def test_unrenormalized_rows_keep_raw_pow2_mass(self):
        """renormalize=False serves the raw cap-ratio shares: row mass is
        within the quantizer's worst-case bound of 1 (each coefficient
        moves by at most a factor of sqrt(2)), never exactly renormed."""
        spec = QTHSpec(renormalize=False)
        w = qth_attention_weights(_scores(), spec)
        sums = np.asarray(jnp.sum(w, -1))
        assert (sums <= np.sqrt(2.0) + 1e-6).all()
        assert (sums >= 1.0 / np.sqrt(2.0) - 1e-6).all()
        assert not np.allclose(sums, 1.0)        # quantization is visible

    def test_renormalize_on_off_share_support(self):
        """The two modes must agree on WHICH keys get charge — renorm
        only rescales rows, it never revives a thresholded coefficient."""
        s = _scores()
        on = qth_attention_weights(s, QTHSpec(renormalize=True))
        off = qth_attention_weights(s, QTHSpec(renormalize=False))
        np.testing.assert_array_equal(np.asarray(on > 0),
                                      np.asarray(off > 0))

    def test_key_valid_masks_coefficients_to_exact_zero(self):
        s = _scores(shape=(2, 2, 4, 6))
        valid = jnp.asarray([[True] * 4 + [False] * 2,
                             [True] * 6])
        # key_valid shares scores' leading dims: (B, k) needs an explicit
        # head axis, same as the wired path (vit.py passes
        # ``token_valid[:, None]``)
        w = qth_attention_weights(s, QTHSpec(), key_valid=valid[:, None])
        assert float(jnp.max(jnp.abs(w[0, :, :, 4:]))) == 0.0
        np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0,
                                   atol=1e-6)


class TestGradients:
    def test_ste_gradients_are_finite_and_nonzero(self):
        """The STE must pass softmax gradients through the quantizer —
        a hard pow2 round has zero gradient almost everywhere and would
        freeze co-design training."""
        def loss(s):
            w = qth_attention_weights(s, QTHSpec(ste=True))
            return jnp.sum(w ** 2)

        g = jax.grad(loss)(_scores())
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.max(jnp.abs(g))) > 0.0

    def test_qth_attention_grads_flow_to_values(self):
        rng = np.random.default_rng(1)
        q, k, v = (jnp.asarray(rng.normal(size=(2, 5, 8)).astype(np.float32))
                   for _ in range(3))

        def loss(v):
            return jnp.sum(qth_attention(q, k, v) ** 2)

        g = jax.grad(loss)(v)
        assert bool(jnp.all(jnp.isfinite(g)))
        assert float(jnp.max(jnp.abs(g))) > 0.0


class TestWiredBackend:
    """cfg.qth=True routes every encoder layer's probabilities through
    the QTH quantizer — dense and compact paths both."""

    def _cfg(self, **kw):
        fcfg = FrontendConfig(
            image_h=64, image_w=64,
            patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
            active_fraction=0.25,
        )
        base = dict(frontend=fcfg, n_layers=1, d_model=32, n_heads=2,
                    d_ff=64)
        base.update(kw)
        return ViTConfig(**base)

    def test_qth_changes_compact_logits_but_stays_close(self):
        cfg = self._cfg()
        qcfg = dataclasses.replace(cfg, qth=True)
        params = init_vit(KEY, cfg)
        rng = np.random.default_rng(0)
        rgb = jnp.asarray(rng.uniform(size=(2, 64, 64, 3)).astype(np.float32))
        l0, _ = vit_forward_compact(params, rgb, cfg)
        l1, aux = vit_forward_compact(params, rgb, qcfg)
        assert not np.array_equal(np.asarray(l0), np.asarray(l1))
        # pow-2 rounding moves each coefficient < sqrt(2)x: logits stay
        # in the same regime (sanity that qth is a quantizer, not noise)
        assert float(jnp.max(jnp.abs(l0 - l1))) < 1.0
        # saliency is still a valid distribution over observed patches
        assert bool(jnp.all(aux["saliency"] >= 0.0))

    def test_qth_dense_and_compact_agree_on_full_cover(self):
        """active_fraction=1 compact vs dense forward under qth: same
        tokens, same quantizer — logits must agree to float tolerance
        (same discipline as the non-qth full-cover equivalence)."""
        fcfg = FrontendConfig(
            image_h=32, image_w=32,
            patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
            active_fraction=1.0,
        )
        cfg = ViTConfig(frontend=fcfg, n_layers=1, d_model=32, n_heads=2,
                        d_ff=64, qth=True)
        params = init_vit(KEY, cfg)
        rng = np.random.default_rng(2)
        rgb = jnp.asarray(rng.uniform(size=(2, 32, 32, 3)).astype(np.float32))
        ld = vit_forward(params, rgb, cfg)
        lc, _ = vit_forward_compact(params, rgb, cfg)
        np.testing.assert_allclose(np.asarray(ld), np.asarray(lc),
                                   atol=1e-5)
