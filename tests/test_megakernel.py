"""Fused frontend megakernel + ragged per-slot k (DESIGN.md §11).

The contracts PR 6's acceptance pins:

* the fused megakernel (projection + fused ADC + w8a8 embed in ONE
  kernel) is BITWISE equal to the staged
  ``ip2_project_sparse(codes=True)`` -> ``quant_matmul_pre`` seam for the
  same selection, across block shapes, pad remainders, and k edges;
* the closed saccade loop driven by the fused model reproduces the staged
  trajectory exactly — identical logits AND identical next-frame
  selections at every step;
* ragged per-slot row counts are a pure data knob: the valid prefix is
  bitwise the full computation, the shed tail is exactly zero, and no
  count value triggers a retrace (one compile across governor tiers;
  engine churn stays ``n_traces == 1``);
* ``ops.program_weights`` (offline DAC programming) is bitwise the
  per-call quantization it replaces;
* ``quant_matmul_pre`` threads the requested ``out_dtype`` into the
  kernel instead of casting after the fact;
* the roofline extractor parses tuple-shaped HLO results and the analytic
  ``megakernel_cost`` model prices ragged shedding (XLA's static cost
  analysis cannot see it).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import adc as adc_mod
from repro.core import projection as proj
from repro.core.frontend import FrontendConfig
from repro.kernels import ops
from repro.models import vit as vit_mod
from repro.serve import serve_step

KEY = jax.random.PRNGKey(0)


def _fused_operands(n2=64, n_vec=24, n_patches=16, k=6, batch=2, d=16,
                    adc_bits=8, seed=0):
    """Patches, DAC weights, a selection, and int8 embed weights — the
    operand set both the staged seam and the fused megakernel consume."""
    spec = proj.PatchSpec(
        patch_h=int(n2 ** 0.5), patch_w=int(n2 ** 0.5), n_vectors=n_vec)
    adc = adc_mod.ADCSpec(bits=adc_bits)
    patches = jax.random.uniform(
        jax.random.PRNGKey(seed), (batch, n_patches, n2))
    w = jax.random.normal(jax.random.PRNGKey(seed + 1), (n_vec, n2)) * 2.0
    idx = jnp.stack([
        jax.random.permutation(jax.random.PRNGKey(seed + 2 + b),
                               jnp.arange(n_patches))[:k]
        for b in range(batch)
    ])
    embed = jax.random.normal(
        jax.random.PRNGKey(seed + 9), (n_vec, d)) * 0.1
    w8, s_w = ops.quantize_weights_int8(embed)
    return spec, adc, patches, w, idx, w8, s_w


def _staged(patches, w, idx, spec, adc, w8, s_w, **kw):
    codes = ops.ip2_project_sparse(
        patches, w, idx, spec, adc=adc, codes=True, **kw)
    return ops.quant_matmul_pre(codes, jnp.float32(adc.lsb), w8, s_w)


class TestFusedKernelParity:
    def test_fused_equals_staged_bitwise(self):
        spec, adc, patches, w, idx, w8, s_w = _fused_operands()
        want = _staged(patches, w, idx, spec, adc, w8, s_w)
        got = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w)
        assert got.dtype == jnp.float32 and got.shape == want.shape
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("br,bm,bk", [
        (1, 128, 128),       # k=1-sized row banks
        (4, 128, 256),
        (8, 256, 128),       # non-divisible M and N2 pad into both blocks
        (8, 512, 256),       # the roofline-picked m_steps=1 shape
        (16, 128, 128),      # bank wider than k: clamped to k rows
    ])
    def test_fused_block_shape_sweep_bitwise(self, br, bm, bk):
        """Satellite battery: block shapes are pure perf knobs — every
        tiling reproduces the staged seam bit for bit, including pad
        remainders (M=24 -> 128/256-lane blocks, N2=64 -> 128/256 K)."""
        spec, adc, patches, w, idx, w8, s_w = _fused_operands()
        want = _staged(patches, w, idx, spec, adc, w8, s_w)
        got = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w,
                                  block_r=br, block_m=bm, block_k=bk)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("k", [1, 16])
    def test_fused_k_edges(self, k):
        """k=1 (single saccade) and k=P (compact degenerates to dense)."""
        spec, adc, patches, w, idx, w8, s_w = _fused_operands(
            n_patches=16, k=k)
        want = _staged(patches, w, idx, spec, adc, w8, s_w)
        got = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fused_codes_within_2lsb_of_float_readout(self):
        """The ISSUE's accuracy gate: the code-space features the fused
        kernel consumes stay within 2 ADC LSB of the un-quantized analog
        float readout (they differ by one ADC rounding, <= 0.5 LSB away
        from clip edges)."""
        spec, adc, patches, w, idx, w8, s_w = _fused_operands()
        codes = ops.ip2_project_sparse(
            patches, w, idx, spec, adc=adc, codes=True)
        scale, zero = adc_mod.readout_scale_zero(
            spec.summer.v_ref, jnp.zeros(()), adc)
        dequant = adc_mod.dequantize(codes.astype(jnp.float32), scale, zero)
        float_feat = ops.ip2_project_sparse(patches, w, idx, spec)
        err = np.max(np.abs(np.asarray(dequant) - np.asarray(float_feat)))
        assert err <= 2.0 * adc.lsb, f"code wire {err} > 2 LSB ({2 * adc.lsb})"

    def test_fused_requires_adc(self):
        spec, adc, patches, w, idx, w8, s_w = _fused_operands()
        with pytest.raises(ValueError, match="code space"):
            ops.ip2_fused_embed(patches, w, idx, spec, None, w8, s_w)

    def test_fused_rejects_mismatched_embed_rows(self):
        spec, adc, patches, w, idx, w8, s_w = _fused_operands()
        with pytest.raises(ValueError, match="embed rows"):
            ops.ip2_fused_embed(patches, w, idx, spec, adc, w8[:-1], s_w)


class TestProgramWeights:
    def test_program_weights_bitwise_per_call(self):
        """Satellite 2: offline DAC programming == per-call quantization,
        on the dense, sparse, and fused entries."""
        spec, adc, patches, w, idx, w8, s_w = _fused_operands()
        pw = ops.program_weights(w, spec)
        assert isinstance(pw, ops.ProgrammedWeights)
        np.testing.assert_array_equal(
            np.asarray(ops.ip2_project(patches, pw, spec)),
            np.asarray(ops.ip2_project(patches, w, spec)))
        np.testing.assert_array_equal(
            np.asarray(ops.ip2_project_sparse(patches, pw, idx, spec,
                                              adc=adc, codes=True)),
            np.asarray(ops.ip2_project_sparse(patches, w, idx, spec,
                                              adc=adc, codes=True)))
        np.testing.assert_array_equal(
            np.asarray(ops.ip2_fused_embed(patches, pw, idx, spec, adc,
                                           w8, s_w)),
            np.asarray(ops.ip2_fused_embed(patches, w, idx, spec, adc,
                                           w8, s_w)))

    def test_programmed_weights_are_on_the_dac_grid(self):
        spec, _, _, w, _, _, _ = _fused_operands()
        pw = ops.program_weights(w, spec)
        again = ops.program_weights(pw, spec)    # idempotent resolve
        np.testing.assert_array_equal(np.asarray(again.w_q),
                                      np.asarray(pw.w_q))


class TestOutDtypeThreading:
    def test_quant_matmul_pre_threads_out_dtype(self):
        """Satellite 6: the requested out_dtype reaches the kernel epilogue
        (one rounding) instead of being cast after a float32 round trip."""
        a8 = jnp.asarray(
            jax.random.randint(KEY, (3, 40), -127, 128), jnp.int8)
        s_a = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (3,))) + 0.1
        w8, s_w = ops.quantize_weights_int8(
            jax.random.normal(jax.random.PRNGKey(2), (40, 24)))
        out = ops.quant_matmul_pre(a8, s_a, w8, s_w, out_dtype=jnp.bfloat16)
        assert out.dtype == jnp.bfloat16
        f32 = ops.quant_matmul_pre(a8, s_a, w8, s_w)
        assert f32.dtype == jnp.float32
        np.testing.assert_array_equal(
            np.asarray(out, np.float32),
            np.asarray(f32.astype(jnp.bfloat16), np.float32))


class TestRaggedK:
    def test_ragged_prefix_bitwise_tail_zero(self):
        """row_counts is the ragged-k contract: rows < count are bitwise
        the full computation, rows >= count are exactly zero."""
        spec, adc, patches, w, idx, w8, s_w = _fused_operands(k=6)
        counts = jnp.asarray([2, 5], jnp.int32)
        full = ops.ip2_project_sparse(patches, w, idx, spec,
                                      adc=adc, codes=True)
        rag = ops.ip2_project_sparse(patches, w, idx, spec, adc=adc,
                                     codes=True, row_counts=counts)
        for b, c in enumerate([2, 5]):
            np.testing.assert_array_equal(np.asarray(rag[b, :c]),
                                          np.asarray(full[b, :c]))
            assert not np.any(np.asarray(rag[b, c:]))

    def test_fused_ragged_prefix_bitwise_tail_zero(self):
        spec, adc, patches, w, idx, w8, s_w = _fused_operands(k=6)
        counts = jnp.asarray([1, 4], jnp.int32)
        full = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w)
        rag = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w,
                                  row_counts=counts)
        for b, c in enumerate([1, 4]):
            np.testing.assert_array_equal(np.asarray(rag[b, :c]),
                                          np.asarray(full[b, :c]))
            assert not np.any(np.asarray(rag[b, c:]))

    def test_ragged_count_edges_clip(self):
        """counts > k behave as full; counts <= 0 shed everything."""
        spec, adc, patches, w, idx, w8, s_w = _fused_operands(k=4)
        full = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w)
        over = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w,
                                   row_counts=jnp.asarray([99, 4]))
        np.testing.assert_array_equal(np.asarray(over), np.asarray(full))
        none = ops.ip2_fused_embed(patches, w, idx, spec, adc, w8, s_w,
                                   row_counts=jnp.asarray([0, -3]))
        assert not np.any(np.asarray(none))

    def test_row_counts_are_data_one_trace_across_tiers(self):
        """The governor's k_eff tiers change only the count VALUES: one
        jit trace serves every tier (ragged k never retraces)."""
        spec, adc, patches, w, idx, w8, s_w = _fused_operands(k=6)
        traces = {"n": 0}

        @jax.jit
        def fwd(pp, ii, counts):
            traces["n"] += 1
            return ops.ip2_fused_embed(pp, w, ii, spec, adc, w8, s_w,
                                       row_counts=counts)

        outs = [fwd(patches, idx, jnp.asarray([c, 6 - c], jnp.int32))
                for c in (6, 3, 1)]
        assert traces["n"] == 1, f"tier changes retraced {traces['n']}x"
        assert all(o.shape == outs[0].shape for o in outs)


def _vit_cfgs(fused):
    fe = FrontendConfig(
        image_h=64, image_w=64,
        patch=proj.PatchSpec(patch_h=16, patch_w=16, n_vectors=48),
        analog=True, active_fraction=0.25,
    )
    return vit_mod.ViTConfig(
        frontend=fe, n_layers=2, d_model=32, n_heads=2, d_ff=64,
        quant_embed=True, fused_embed=fused)


class TestFusedModel:
    def _setup(self):
        cfg_s, cfg_f = _vit_cfgs(False), _vit_cfgs(True)
        params = vit_mod.prepare_quant_embed(
            vit_mod.init_vit(jax.random.PRNGKey(0), cfg_s))
        rgb = jax.random.uniform(jax.random.PRNGKey(1), (2, 64, 64, 3))
        return cfg_s, cfg_f, params, rgb

    def test_fused_model_bitwise_staged(self):
        cfg_s, cfg_f, params, rgb = self._setup()
        ls, as_ = vit_mod.vit_forward_compact(params, rgb, cfg_s)
        lf, af = vit_mod.vit_forward_compact(params, rgb, cfg_f)
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lf))
        np.testing.assert_array_equal(np.asarray(as_["saliency"]),
                                      np.asarray(af["saliency"]))

    def test_fused_model_under_k_cap_bitwise_staged(self):
        cfg_s, cfg_f, params, rgb = self._setup()
        cap = jnp.asarray([1, 3], jnp.int32)
        ls, as_ = vit_mod.vit_forward_compact(params, rgb, cfg_s, k_cap=cap)
        lf, af = vit_mod.vit_forward_compact(params, rgb, cfg_f, k_cap=cap)
        np.testing.assert_array_equal(np.asarray(ls), np.asarray(lf))
        np.testing.assert_array_equal(np.asarray(as_["valid"]),
                                      np.asarray(af["valid"]))
        for (n1, v1), (n2, v2) in zip(
                sorted(as_["events"]._asdict().items()),
                sorted(af["events"]._asdict().items())):
            np.testing.assert_array_equal(np.asarray(v1), np.asarray(v2),
                                          err_msg=n1)

    def test_fused_saccade_trajectory_matches_staged(self):
        """Closed loop, T frames: the fused model must not perturb the
        saccade policy — identical logits AND identical next selections
        every frame (a single flipped bit would fork the trajectory).
        Op-by-op execution is bitwise; under whole-step jit XLA may lower
        DOWNSTREAM transformer reductions differently for the two graphs
        (a fusion-order property, not a kernel one), so the jitted loop
        additionally pins the selection trajectory and logits to 1e-6."""
        cfg_s, cfg_f, params, rgb0 = self._setup()
        step_s = serve_step.make_saccade_step(cfg_s)
        step_f = serve_step.make_saccade_step(cfg_f)
        idx_s = idx_f = serve_step.make_bootstrap_indices(cfg_s)(params, rgb0)
        for t in range(3):
            rgb = jax.random.uniform(jax.random.PRNGKey(10 + t),
                                     (2, 64, 64, 3))
            ls, idx_s, _ = step_s(params, rgb, idx_s)
            lf, idx_f, _ = step_f(params, rgb, idx_f)
            np.testing.assert_array_equal(np.asarray(ls), np.asarray(lf),
                                          err_msg=f"frame {t} logits")
            np.testing.assert_array_equal(np.asarray(idx_s),
                                          np.asarray(idx_f),
                                          err_msg=f"frame {t} selection")

        jit_s = jax.jit(step_s)
        jit_f = jax.jit(step_f)
        idx_s = idx_f = serve_step.make_bootstrap_indices(cfg_s)(params, rgb0)
        for t in range(3):
            rgb = jax.random.uniform(jax.random.PRNGKey(10 + t),
                                     (2, 64, 64, 3))
            ls, idx_s, _ = jit_s(params, rgb, idx_s)
            lf, idx_f, _ = jit_f(params, rgb, idx_f)
            np.testing.assert_array_equal(np.asarray(idx_s),
                                          np.asarray(idx_f),
                                          err_msg=f"jit frame {t} selection")
            np.testing.assert_allclose(np.asarray(ls), np.asarray(lf),
                                       atol=1e-6, rtol=1e-6)

    def test_fused_engine_churn_single_trace(self):
        """Admit/evict churn on a fused-model engine: still one compile."""
        from repro.serve.engine import SaccadeEngine

        cfg_f = _vit_cfgs(True)
        params = vit_mod.prepare_quant_embed(
            vit_mod.init_vit(jax.random.PRNGKey(0), cfg_f))
        eng = SaccadeEngine(cfg_f, params, capacity=2)
        frame = lambda s: np.asarray(jax.random.uniform(
            jax.random.PRNGKey(s), (64, 64, 3)))
        eng.admit("a")
        eng.step({"a": frame(0)})
        eng.admit("b")
        eng.step({"a": frame(1), "b": frame(2)})
        eng.evict("a")
        eng.admit("c")
        eng.step({"b": frame(3), "c": frame(4)})
        assert eng.n_traces == 1, f"churn caused {eng.n_traces} compiles"

    def test_fused_model_validation(self):
        cfg_s, cfg_f, params, rgb = self._setup()
        with pytest.raises(ValueError, match="quant_embed"):
            vit_mod.vit_forward_compact(
                params, rgb,
                cfg_f._replace(quant_embed=False)
                if hasattr(cfg_f, "_replace") else
                __import__("dataclasses").replace(cfg_f, quant_embed=False))
        with pytest.raises(ValueError, match="float"):
            vit_mod.vit_forward_compact(params, rgb, cfg_f, wire="float")


class TestRooflineExtractor:
    def test_tuple_result_bytes(self):
        """Satellite 1 regression: tuple-shaped HLO results — e.g. the
        ``(payload, context) all-reduce-start`` pairs async collectives
        emit — must be sized, not dropped."""
        from repro.roofline.analysis import _line_result_bytes

        line = ("  %ar = (f32[8,128]{1,0}, u32[]) "
                "all-reduce-start(f32[8,128] %p), replica_groups={}")
        assert _line_result_bytes(line) == 8 * 128 * 4 + 4
        plain = "  %add.1 = f32[4,4]{1,0} add(f32[4,4] %a, f32[4,4] %b)"
        assert _line_result_bytes(plain) == 4 * 4 * 4
        scalar = "  %s = pred[] compare(s32[] %i, s32[] %n), direction=LT"
        assert _line_result_bytes(scalar) == 1

    def test_collective_bytes_counts_tuple_starts_once(self):
        from repro.roofline.analysis import collective_bytes

        hlo = "\n".join([
            "ENTRY %main {",
            "  %ar = (f32[16,128]{1,0}, u32[]) all-reduce-start(%p)",
            "  %d = f32[16,128]{1,0} all-reduce-done(%ar)",
            "  %ag = (bf16[4,256]{1,0}, bf16[8,256]{1,0}) "
            "all-gather-start(%q)",
            "}",
        ])
        got = collective_bytes(hlo)
        assert got["all-reduce"] == 16 * 128 * 4 + 4     # start, not done
        assert got["all-gather"] == 4 * 256 * 2 + 8 * 256 * 2
        assert got["counts"] == {"all-reduce": 1, "all-gather": 1}

    def test_megakernel_cost_prices_ragged_shedding(self):
        """XLA's static cost analysis cannot see pl.when-skipped banks;
        the analytic model must: FLOPs/bytes scale with active banks."""
        from repro.roofline.analysis import RooflineTerms, megakernel_cost

        full = megakernel_cost([64] * 4, 64, 256, 400, d=128)
        tier = megakernel_cost([16] * 4, 64, 256, 400, d=128)
        assert full["detail"]["active_banks"] == 32
        assert tier["detail"]["active_banks"] == 8
        assert full["flops"] / tier["flops"] == pytest.approx(4.0)
        assert full["bytes"] > 2.0 * tier["bytes"]
        zero = megakernel_cost([0] * 4, 64, 256, 400, d=128)
        assert zero["flops"] == 0.0
        # occupancy is well defined across the model's output range
        occ = RooflineTerms(full["flops"], full["bytes"], 0.0).mxu_occupancy
        assert 0.0 < occ <= 1.0

    def test_megakernel_cost_projection_only_vs_fused(self):
        from repro.roofline.analysis import megakernel_cost

        proj_only = megakernel_cost([8] * 2, 8, 256, 400)
        fused = megakernel_cost([8] * 2, 8, 256, 400, d=128)
        assert fused["flops"] > proj_only["flops"]
        assert fused["bytes"] > proj_only["bytes"]
