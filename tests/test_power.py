"""core/power.py invariants (DESIGN.md §10): the event-metered energy
subsystem.

Three contracts:

* **Meter == closed form.** ``power_report`` is DEFINED as the
  :class:`EnergyMeter` evaluated on the analytical steady-state event
  counts; asserting exact equality here pins that construction so a
  future "optimization" cannot split the two views apart.
* **Physical monotonicity + the paper's claims.** Front-end power is
  monotone in active fraction, frame rate and vectors/patch, and the ADC
  stays the majority consumer across the paper's operating envelope.
* **Runtime emission.** The events ``apply_frontend`` reports are the
  events it executed: k·M conversions on the ungated compact path,
  n_stale·M under the temporal gate, identical across wire formats and
  kernel adapters (the fused-ADC epilogue's count is the wrapper's
  ``frame_conversions``), and exactly the analytical counts at a matched
  operating point.

Hypothesis drives the adversarial sweeps where available; a
deterministic battery keeps every contract exercised on a bare-jax
container (mirroring tests/test_saliency_properties.py).
"""

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.frontend import FrontendConfig, apply_frontend, init_frontend_params
from repro.core.power import (
    EnergyConstants,
    EnergyMeter,
    EventCounts,
    PowerReport,
    SensorConfig,
    frontend_frame_events,
    power_report,
    steady_state_events,
)
from repro.core.projection import PatchSpec
from repro.core.temporal import TemporalSpec, init_feature_cache
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)

# the paper's operating envelope (§2.1.3/§2.1.4): 32x32 patches, >=192
# vectors (the 8x8 point uses 192), a meaningful saccade gate, video rates
PAPER_SWEEP = [
    SensorConfig(n_pixels=x, frame_hz=r, n_vectors=m, active_fraction=f)
    for x in (1.0e6, 2.0e6, 4.0e6)
    for r in (15.0, 30.0, 60.0, 90.0)
    for m in (192, 400, 768)
    for f in (0.2, 0.25, 0.35, 0.5)
]


def _fcfg(**kw):
    base = dict(
        image_h=256, image_w=256,
        patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=400),
        aa_cutoff=None, active_fraction=0.25,
    )
    base.update(kw)
    return FrontendConfig(**base)


# --------------------------------------------------------------------------
# meter == closed form, and report structure
# --------------------------------------------------------------------------

class TestMeterEqualsClosedForm:
    def test_exact_equality_at_paper_point(self):
        rep = power_report(SensorConfig())
        bd = EnergyMeter().power_w(
            steady_state_events(SensorConfig()), SensorConfig().frame_hz)
        assert rep.components == bd.components      # exact, every component
        assert rep.total_w == bd.total_w

    def test_exact_equality_across_sweep(self):
        for cfg in PAPER_SWEEP[:: 7]:
            rep = power_report(cfg)
            bd = EnergyMeter().power_w(steady_state_events(cfg), cfg.frame_hz)
            assert rep.components == bd.components, cfg
            assert rep.total_w == bd.total_w == sum(bd.components.values())

    def test_report_structure_separates_components_and_totals(self):
        """Satellite of PR 5: no name-filtering — components is pure
        component watts, totals live in their own fields."""
        rep = power_report(SensorConfig())
        assert isinstance(rep, PowerReport)
        assert set(rep.components) == {
            "adc", "weight_dac", "cap_charging", "pwm_comparators",
            "opamps", "cds_sampling", "pixel_dump",
            "sign_comparators", "weight_reprogram", "backend",
        }
        assert rep.total_w == sum(rep.components.values())
        assert rep.share()["adc"] == rep.components["adc"] / rep.total_w
        assert sum(rep.share().values()) == pytest.approx(1.0)
        assert rep.dominant in rep.components

    def test_mw_per_mpix_claim_held(self):
        rep = power_report(SensorConfig())
        assert rep.mw_per_mpix < 30.0
        assert power_report(SensorConfig(n_pixels=1e6)).mw_per_mpix < 30.0


class TestPhysicalMonotonicity:
    def _total(self, **kw):
        return power_report(SensorConfig(**kw)).total_w

    def test_monotone_in_active_fraction(self):
        ts = [self._total(active_fraction=f) for f in (0.1, 0.25, 0.5, 1.0)]
        assert ts == sorted(ts) and ts[-1] > ts[0]

    def test_monotone_in_frame_rate(self):
        ts = [self._total(frame_hz=r) for r in (15.0, 30.0, 60.0, 120.0)]
        assert ts == sorted(ts) and ts[-1] > ts[0]

    def test_monotone_in_vectors(self):
        ts = [self._total(n_vectors=m) for m in (100, 192, 400, 768)]
        assert ts == sorted(ts) and ts[-1] > ts[0]

    def test_adc_majority_across_paper_sweep(self):
        for cfg in PAPER_SWEEP:
            rep = power_report(cfg)
            assert rep.adc_dominated, (cfg, rep.components)

    def test_event_counts_arithmetic(self):
        a = EventCounts(adc_conversions=3.0, cds_samples=4.0)
        b = EventCounts(adc_conversions=1.0, pixel_dumps=2.0)
        s = a.add(b)
        assert s.adc_conversions == 4.0 and s.cds_samples == 4.0
        assert s.pixel_dumps == 2.0
        assert a.scale(2.0).adc_conversions == 6.0
        assert EventCounts.zeros().adc_conversions == 0.0


if HAVE_HYPOTHESIS:

    class TestMonotonicityHypothesis:
        @given(
            f=st.floats(0.05, 0.95),
            bump=st.floats(1.05, 4.0),
            r=st.floats(5.0, 100.0),
            m=st.integers(16, 768),
        )
        @settings(max_examples=40, deadline=None)
        def test_more_activity_rate_or_vectors_never_cheaper(self, f, bump, r, m):
            base = SensorConfig(active_fraction=f, frame_hz=r, n_vectors=m)
            t0 = power_report(base).total_w
            assert power_report(
                dataclasses.replace(base, active_fraction=min(1.0, f * bump))
            ).total_w >= t0
            assert power_report(
                dataclasses.replace(base, frame_hz=r * bump)).total_w > t0
            assert power_report(
                dataclasses.replace(base, n_vectors=int(m * bump))).total_w > t0

        @given(
            f=st.floats(0.05, 1.0),
            r=st.floats(5.0, 100.0),
            m=st.integers(16, 768),
            x=st.floats(0.25e6, 8e6),
        )
        @settings(max_examples=40, deadline=None)
        def test_meter_equals_closed_form_everywhere(self, f, r, m, x):
            cfg = SensorConfig(
                n_pixels=x, frame_hz=r, n_vectors=m, active_fraction=f)
            rep = power_report(cfg)
            bd = EnergyMeter().power_w(steady_state_events(cfg), r)
            assert rep.components == bd.components
            assert rep.total_w == bd.total_w


# --------------------------------------------------------------------------
# runtime emission: the ledger reports what was executed
# --------------------------------------------------------------------------

class TestRuntimeEmission:
    def test_compact_ungated_counts(self):
        cfg = _fcfg()
        params = init_frontend_params(KEY, cfg)
        rgb = jax.random.uniform(KEY, (2, 256, 256, 3))
        cf = apply_frontend(params, rgb, cfg, mode="compact")
        k, n2, m = cfg.n_active, cfg.patch.pixels_per_patch, cfg.patch.n_vectors
        x = 256 * 256
        ev = jax.tree.map(np.asarray, cf.events)
        assert ev.adc_conversions.shape == (2,)
        np.testing.assert_array_equal(ev.adc_conversions, k * m)
        np.testing.assert_array_equal(ev.cap_charges, k * n2 * m)
        np.testing.assert_array_equal(ev.dac_loads, m * n2)
        np.testing.assert_array_equal(ev.cds_samples, 2 * x)
        np.testing.assert_array_equal(ev.pixel_dumps, x - k * n2)
        np.testing.assert_array_equal(ev.pwm_pixel_frames, k * n2)
        np.testing.assert_array_equal(ev.opamp_patch_frames, k)

    def test_events_identical_across_wire_formats(self):
        cfg = _fcfg()
        params = init_frontend_params(KEY, cfg)
        rgb = jax.random.uniform(KEY, (1, 256, 256, 3))
        ev_c = apply_frontend(params, rgb, cfg, mode="compact", wire="codes").events
        ev_f = apply_frontend(params, rgb, cfg, mode="compact", wire="float").events
        for a, b in zip(ev_c, ev_f):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_measured_equals_analytical_at_matched_point(self):
        """A real frontend run at the paper's 25 % operating geometry
        (32x32 patches, 400 vectors) must report EXACTLY the analytical
        steady-state counts — the measured-vs-claimed bridge of DESIGN.md
        §10. The <30 mW/MP normalization itself only amortizes at
        megapixel scale (the DAC broadcast is a fixed M·N² cost per
        frame, regardless of sensor size); the bench measures it on a
        true 2 MP run, here we pin count equality and the per-MP match."""
        cfg = _fcfg(patch=PatchSpec(patch_h=32, patch_w=32, n_vectors=400))
        params = init_frontend_params(KEY, cfg)      # 256², P=64, k=16
        rgb = jax.random.uniform(KEY, (1, 256, 256, 3))
        cf = apply_frontend(params, rgb, cfg, mode="compact")
        scfg = SensorConfig(n_pixels=float(256 * 256), n_vectors=400,
                            active_fraction=0.25)
        analytical = steady_state_events(scfg)
        for name, a, b in zip(EventCounts._fields, cf.events, analytical):
            assert float(np.asarray(a)[0]) == float(b), name
        mw = EnergyMeter().power_mw(
            jax.tree.map(lambda e: float(np.asarray(e)[0]), cf.events), 30.0)
        rep = power_report(scfg)
        assert mw / (scfg.n_pixels / 1e6) == pytest.approx(
            rep.mw_per_mpix, rel=1e-6)
        # the claim at the paper's own sensor scale, same geometry
        assert power_report(SensorConfig()).mw_per_mpix < 30.0

    def test_temporal_counts_track_n_stale(self):
        cfg = _fcfg(
            image_h=64, image_w=64,
            patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
            temporal=TemporalSpec(delta_threshold=1e-4),
        )
        params = init_frontend_params(KEY, cfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        m = cfg.patch.n_vectors
        cache = init_feature_cache(cfg, (2,))
        for t in range(4):
            cf, cache = apply_frontend(params, rgb, cfg, mode="compact",
                                       cache=cache)
            np.testing.assert_array_equal(
                np.asarray(cf.events.adc_conversions),
                np.asarray(cache.n_stale) * m,
            )
        # static scene: steady-state holds are free — zero conversions
        assert int(np.asarray(cache.n_stale).sum()) == 0
        assert float(np.asarray(cf.events.adc_conversions).sum()) == 0.0
        # but the per-frame fixed costs never disappear
        assert float(np.asarray(cf.events.cds_samples).min()) == 2.0 * 64 * 64

    def test_kernel_adapter_counts_match_fused_epilogue(self):
        """The wrapper's advertised conversion count is the emitted
        payload — M per REAL row, MXU padding never priced."""
        cfg = _fcfg(image_h=64, image_w=64,
                    patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
                    active_fraction=0.2)     # k=3: forces block_r padding
        params = init_frontend_params(KEY, cfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        k, m = cfg.n_active, cfg.patch.n_vectors
        assert k == 3
        fn = ops.ip2_codes_fn(cfg.patch, cfg.adc)
        cf = apply_frontend(params, rgb, cfg, mode="compact", project_fn=fn)
        assert fn.frame_conversions(k) == k * m
        assert cf.features.size == 1 * k * m == fn.frame_conversions(k)
        assert float(np.asarray(cf.events.adc_conversions)[0]) == k * m
        # the no-fused-ADC adapter converts nothing itself
        assert ops.ip2_project_fn(cfg.patch).frame_conversions(k) == 0
        assert ops.fused_adc_conversions(k, cfg.patch, cfg.adc) == k * m

    def test_k_cap_sheds_conversions_and_dumps_patches(self):
        cfg = _fcfg(image_h=64, image_w=64,
                    patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32))
        params = init_frontend_params(KEY, cfg)
        rgb = jax.random.uniform(KEY, (2, 64, 64, 3))
        k, n2, m = cfg.n_active, cfg.patch.pixels_per_patch, cfg.patch.n_vectors
        cap = jnp.asarray([2, k], jnp.int32)
        cf = apply_frontend(params, rgb, cfg, mode="compact", k_cap=cap)
        np.testing.assert_array_equal(
            np.asarray(cf.events.adc_conversions), [2 * m, k * m])
        np.testing.assert_array_equal(
            np.asarray(cf.events.pixel_dumps),
            [64 * 64 - 2 * n2, 64 * 64 - k * n2])
        # shed tokens are invalid and served as zero
        v = np.asarray(cf.valid)
        assert v[0].sum() == 2 and v[1].sum() == k
        np.testing.assert_array_equal(np.asarray(cf.gain)[0, 2:], 0.0)
        # k_cap = k is a bitwise no-op
        base = apply_frontend(params, rgb, cfg, mode="compact")
        full = apply_frontend(params, rgb, cfg, mode="compact",
                              k_cap=jnp.asarray([k, k], jnp.int32))
        np.testing.assert_array_equal(np.asarray(base.features),
                                      np.asarray(full.features))
        np.testing.assert_array_equal(np.asarray(base.valid),
                                      np.asarray(full.valid))

    def test_stale_cap_truncates_recompute(self):
        cfg = _fcfg(image_h=64, image_w=64,
                    patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32),
                    temporal=TemporalSpec(delta_threshold=1e-4))
        params = init_frontend_params(KEY, cfg)
        k, m = cfg.n_active, cfg.patch.n_vectors
        cache = init_feature_cache(cfg, (1,))
        rgbs = jax.random.uniform(KEY, (3, 1, 64, 64, 3))
        cap = jnp.asarray([2], jnp.int32)
        for t in range(3):                      # full motion: all stale
            cf, cache = apply_frontend(params, rgbs[t], cfg, mode="compact",
                                       cache=cache, stale_cap=cap)
            assert int(np.asarray(cache.n_stale)[0]) <= 2
            assert float(np.asarray(cf.events.adc_conversions)[0]) <= 2 * m
        # without the cap the full-motion demand is the whole selection
        cf2, cache2 = apply_frontend(params, rgbs[0], cfg, mode="compact",
                                     cache=init_feature_cache(cfg, (1,)))
        assert int(np.asarray(cache2.n_stale)[0]) == k

    def test_governor_knobs_require_compact_or_cache(self):
        cfg = _fcfg(image_h=64, image_w=64,
                    patch=PatchSpec(patch_h=16, patch_w=16, n_vectors=32))
        params = init_frontend_params(KEY, cfg)
        rgb = jax.random.uniform(KEY, (1, 64, 64, 3))
        with pytest.raises(ValueError, match="compact"):
            apply_frontend(params, rgb, cfg, mode="dense",
                           k_cap=jnp.asarray([1], jnp.int32))
        with pytest.raises(ValueError, match="FeatureCache"):
            apply_frontend(params, rgb, cfg, mode="compact",
                           stale_cap=jnp.asarray([1], jnp.int32))
        # k_cap sheds TRAILING slots: a mask-derived selection is in
        # ascending patch order, not saliency order — refused, not
        # silently mis-shed
        mask = jnp.zeros((1, cfg.n_patches), bool).at[:, :cfg.n_active].set(True)
        with pytest.raises(ValueError, match="ranked"):
            apply_frontend(params, rgb, cfg, mode="compact", mask=mask,
                           k_cap=jnp.asarray([1], jnp.int32))

    def test_custom_constants_reprice_without_reserving(self):
        """Counts are constants-free: one emitted ledger prices under any
        calibration (recalibration never touches device state)."""
        cfg = _fcfg()
        params = init_frontend_params(KEY, cfg)
        rgb = jax.random.uniform(KEY, (1, 256, 256, 3))
        ev = jax.tree.map(
            lambda e: float(np.asarray(e)[0]),
            apply_frontend(params, rgb, cfg, mode="compact").events)
        cheap = EnergyMeter(EnergyConstants(e_adc_j=1.0e-9))
        dear = EnergyMeter(EnergyConstants(e_adc_j=8.0e-9))
        assert dear.power_mw(ev, 30.0) > EnergyMeter().power_mw(ev, 30.0) \
            > cheap.power_mw(ev, 30.0)
