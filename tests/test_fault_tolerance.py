"""Fault tolerance: checkpoint/restart determinism, failure injection,
atomic commits, elastic (re-sharded) restore, preemption drain."""

import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import models as M
from repro.checkpoint.manager import CheckpointManager
from repro.configs import smoke_config
from repro.data.pipeline import DataConfig, TokenStream
from repro.optim import AdamWConfig, init_opt_state
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig

KEY = jax.random.PRNGKey(0)


def _setup(tmp, total=12, fail_at=None, ckpt_every=4):
    cfg = smoke_config("smollm-135m")
    params = M.init_params(KEY, cfg)
    opt = AdamWConfig(lr=1e-3)
    opt_state = init_opt_state(params, opt)
    step = jax.jit(make_train_step(cfg, M.DEFAULT_PLAN, opt, compute_dtype=jnp.float32))
    stream = TokenStream(DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4))
    data = lambda s: {"tokens": jnp.asarray(stream.batch(s)["tokens"])}
    tcfg = TrainerConfig(
        total_steps=total, ckpt_every=ckpt_every, ckpt_dir=tmp,
        log_every=1, fail_at_step=fail_at,
    )
    return params, opt_state, step, data, tcfg


def test_restart_bitwise_identical(tmp_path):
    """Interrupted-then-resumed training equals uninterrupted training."""
    d1, d2 = str(tmp_path / "a"), str(tmp_path / "b")
    # uninterrupted
    p, o, s, data, tcfg = _setup(d1)
    pA, _, _ = Trainer(s, data, tcfg).run(p, o)
    # interrupted at step 6 (after ckpt@4), then resumed
    p, o, s, data, tcfg = _setup(d2, fail_at=6)
    with pytest.raises(RuntimeError, match="injected failure"):
        Trainer(s, data, tcfg).run(p, o)
    p, o, s, data, tcfg = _setup(d2, fail_at=None)
    pB, _, _ = Trainer(s, data, tcfg).run(p, o)
    for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_commit_ignores_partial(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.ones((4,))}
    cm.save(3, tree, blocking=True)
    # simulate crash mid-save: orphan .tmp directory
    os.makedirs(str(tmp_path / "step_00000009.tmp"))
    assert cm.latest_step() == 3
    restored, step = cm.restore(tree)
    assert step == 3


def test_checkpoint_gc_keeps_last(tmp_path):
    cm = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.ones((2,))}
    for s in (1, 2, 3, 4):
        cm.save(s, tree, blocking=True)
    assert cm.all_steps() == [3, 4]


def test_tree_mismatch_rejected(tmp_path):
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"w": jnp.ones((2,))}, blocking=True)
    with pytest.raises(ValueError, match="mismatch"):
        cm.restore({"wrong_name": jnp.ones((2,))})


def test_elastic_restore_resharded(tmp_path):
    """Save under one sharding, restore onto a different mesh shape —
    the node-failure/elastic-scaling path."""
    if jax.device_count() < 4:
        pytest.skip("needs >=4 host devices (run via test_distributed wrapper)")
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh4 = jax.make_mesh((4, 1), ("data", "model"))
    mesh2 = jax.make_mesh((2, 2), ("data", "model"))
    x = jnp.arange(64.0).reshape(8, 8)
    xs = jax.device_put(x, NamedSharding(mesh4, P("data", None)))
    cm = CheckpointManager(str(tmp_path))
    cm.save(1, {"x": xs}, blocking=True)
    target = NamedSharding(mesh2, P("data", "model"))
    restored, _ = cm.restore({"x": x}, shardings={"x": target})
    np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
    assert restored["x"].sharding == target


def test_straggler_counter(tmp_path):
    import time as _time

    p, o, s, data, tcfg = _setup(str(tmp_path), total=10, ckpt_every=100)
    tr = Trainer(s, data, tcfg)
    orig = tr.step_fn
    calls = {"n": 0}

    def slow_step(*a):
        calls["n"] += 1
        if calls["n"] == 9:
            _time.sleep(1.0)       # inject a straggler step
        return orig(*a)

    tr.step_fn = slow_step
    tr.run(p, o)
    assert tr.n_stragglers >= 1


def test_data_pipeline_seekable():
    stream = TokenStream(DataConfig(seed=9))
    a = stream.batch(17)["tokens"]
    b = stream.batch(17)["tokens"]
    np.testing.assert_array_equal(a, b)          # pure fn of step
    c = stream.batch(18)["tokens"]
    assert not np.array_equal(a, c)


def test_data_pipeline_host_sharding():
    cfg = DataConfig(global_batch=8)
    stream = TokenStream(cfg)
    h0 = stream.batch(3, host_id=0, n_hosts=2)["tokens"]
    h1 = stream.batch(3, host_id=1, n_hosts=2)["tokens"]
    assert h0.shape == (4, cfg.seq_len)
    assert not np.array_equal(h0, h1)            # different shards
