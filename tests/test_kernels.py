"""Per-kernel allclose vs the pure-jnp oracle (interpret=True on CPU),
swept over shapes/dtypes + hypothesis property tests (the property tests
are skipped when hypothesis is not installed; see requirements-dev.txt)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core import adc as adc_mod
from repro.core import projection as proj
from repro.core.pwm import QuantSpec
from repro.kernels import ops, ref
from repro.kernels.ip2_project import IP2KernelParams, ip2_project_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("patch,n_vec,n_patches", [
    (8, 16, 5),          # min patch
    (16, 192, 12),       # mid, n_vec not mult of 128
    (32, 400, 3),        # paper's 32x32/400-vector operating point
    (32, 768, 1),        # paper's 768-vector point
])
def test_ip2_kernel_vs_core_reference(patch, n_vec, n_patches):
    spec = proj.PatchSpec(patch_h=patch, patch_w=patch, n_vectors=n_vec)
    patches = jax.random.uniform(KEY, (n_patches, patch * patch))
    w = jax.random.normal(jax.random.PRNGKey(1), (n_vec, patch * patch)) * 2.0
    out_k = ops.ip2_project(patches, w, spec, interpret=True)
    out_r = proj.analog_project_patches(patches, w, spec)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)


@pytest.mark.parametrize("pwm_bits,adc_bits,nl", [(6, 8, "none"), (4, 6, "relu"), (8, 10, "none")])
def test_ip2_kernel_quant_nl_adc_sweep(pwm_bits, adc_bits, nl):
    from repro.core.analog_nl import AnalogNLSpec

    spec = proj.PatchSpec(
        patch_h=8, patch_w=8, n_vectors=24,
        quant=QuantSpec(pwm_bits=pwm_bits),
        nl=AnalogNLSpec(kind=nl),
    )
    adc = adc_mod.ADCSpec(bits=adc_bits)
    patches = jax.random.uniform(KEY, (4, 7, 64))
    w = jax.random.normal(jax.random.PRNGKey(2), (24, 64)) * 3.0
    bias = jax.random.normal(jax.random.PRNGKey(3), (24,)) * 0.1
    out_k = ops.ip2_project(patches, w, spec, adc=adc, bias=bias, interpret=True)
    ref_analog = proj.analog_project_patches(patches, w, spec)
    out_r = adc_mod.digital_readout(ref_analog, spec.summer.v_ref, bias, adc)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)


def test_ip2_kernel_block_shape_sweep():
    """Different BlockSpec tilings must not change results."""
    spec = proj.PatchSpec(patch_h=16, patch_w=16, n_vectors=64)
    patches = jax.random.uniform(KEY, (40, 256))
    w = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
    base = ops.ip2_project(patches, w, spec, interpret=True)
    for bp, bm, bk in [(8, 128, 128), (128, 128, 512), (16, 256, 256)]:
        out = ops.ip2_project(
            patches, w, spec, block_p=bp, block_m=bm, block_k=bk, interpret=True
        )
        np.testing.assert_allclose(np.asarray(out), np.asarray(base), atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(3, 100, 200), (1, 511, 130)])
def test_quant_matmul_vs_oracle(dtype, shape):
    b, k, m = shape
    a = (jax.random.normal(KEY, (b, k)) * 2).astype(dtype)
    w = jax.random.normal(jax.random.PRNGKey(1), (k, m))
    w8, sw = ops.quantize_weights_int8(w)
    got = ops.quant_matmul(a, w8, sw, interpret=True)
    a8, sa = ref.quantize_activations_ref(a.astype(jnp.float32).reshape(-1, k))
    want = ref.quant_matmul_ref(a8, sa, w8, sw).reshape(b, m).astype(dtype)
    # bf16 output rounding: lsb ≈ 0.8% of magnitude
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    scale = float(jnp.abs(want.astype(jnp.float32)).max())
    np.testing.assert_allclose(
        np.asarray(got, np.float32) / scale, np.asarray(want, np.float32) / scale,
        atol=tol,
    )


def test_quant_matmul_pre_skips_second_rounding():
    """The pre-quantized entry consumes int8 codes + scales as-is (the
    ADC-code path, §9): no host re-quantization, oracle-exact, and the
    host-quantizing wrapper is exactly pre(quantize(a))."""
    a = jax.random.normal(KEY, (6, 40)) * 2
    a8, sa = ref.quantize_activations_ref(a)
    w = jax.random.normal(jax.random.PRNGKey(1), (40, 24))
    w8, sw = ops.quantize_weights_int8(w)
    got = ops.quant_matmul_pre(a8, sa, w8, sw, interpret=True)
    want = ref.quant_matmul_ref(a8, sa, w8, sw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
    via_host = ops.quant_matmul(a, w8, sw, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(via_host), atol=1e-6)
    # scalar per-row scale broadcast (the ADC's single static LSB)
    got_s = ops.quant_matmul_pre(a8, jnp.float32(0.25), w8, sw, interpret=True)
    want_s = ref.quant_matmul_ref(a8, jnp.full((6,), 0.25, jnp.float32), w8, sw)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s), atol=1e-5)


def test_quant_matmul_accuracy_vs_float():
    a = jax.random.normal(KEY, (16, 300))
    w = jax.random.normal(jax.random.PRNGKey(1), (300, 200))
    w8, sw = ops.quantize_weights_int8(w)
    y = ops.quant_matmul(a, w8, sw, interpret=True)
    rel = float(jnp.abs(y - a @ w).max() / jnp.abs(a @ w).max())
    assert rel < 0.03


# ---------------------------------------------------------------------------
# sparse (active-patch-only) projection kernel
# ---------------------------------------------------------------------------

class TestSparseProjection:
    def _dense_gather(self, patches, w, idx, spec, **kw):
        dense = ops.ip2_project(patches, w, spec, interpret=True, **kw)
        return jnp.take_along_axis(dense, idx[..., None], axis=-2)

    @pytest.mark.parametrize("patch,n_vec,n_patches,k", [
        (8, 16, 16, 4),
        (16, 192, 12, 3),      # n_vec not a multiple of 128
        (16, 32, 16, 16),      # k == P (compact degenerates to dense)
    ])
    def test_sparse_matches_dense_gather_random_sets(self, patch, n_vec, n_patches, k):
        spec = proj.PatchSpec(patch_h=patch, patch_w=patch, n_vectors=n_vec)
        patches = jax.random.uniform(KEY, (2, n_patches, patch * patch))
        w = jax.random.normal(jax.random.PRNGKey(1), (n_vec, patch * patch)) * 2.0
        idx = jax.random.permutation(
            jax.random.PRNGKey(2), jnp.arange(n_patches)
        )[None, :k].repeat(2, 0)
        out_s = ops.ip2_project_sparse(patches, w, idx, spec, interpret=True)
        want = self._dense_gather(patches, w, idx, spec)
        assert out_s.shape == (2, k, n_vec)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(want), atol=1e-5)

    def test_sparse_with_fused_adc_and_bias(self):
        spec = proj.PatchSpec(patch_h=8, patch_w=8, n_vectors=24)
        adc = adc_mod.ADCSpec(bits=6)
        patches = jax.random.uniform(KEY, (3, 9, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 3.0
        bias = jax.random.normal(jax.random.PRNGKey(2), (24,)) * 0.1
        idx = jnp.array([[0, 8, 4], [7, 1, 2], [3, 3, 5]], jnp.int32)
        out_s = ops.ip2_project_sparse(
            patches, w, idx, spec, adc=adc, bias=bias, interpret=True
        )
        want = self._dense_gather(patches, w, idx, spec, adc=adc, bias=bias)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(want), atol=1e-5)

    def test_sparse_repeated_indices_fewer_than_k_active(self):
        """< k active patches: the selector pads by repeating indices; the
        kernel must simply project the repeated bank again."""
        spec = proj.PatchSpec(patch_h=8, patch_w=8, n_vectors=16)
        patches = jax.random.uniform(KEY, (1, 8, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        idx = jnp.array([[2, 5, 5, 5]], jnp.int32)      # only 2 distinct active
        out_s = ops.ip2_project_sparse(patches, w, idx, spec, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out_s[0, 1]), np.asarray(out_s[0, 2]), atol=0
        )
        want = self._dense_gather(patches, w, idx, spec)
        np.testing.assert_allclose(np.asarray(out_s), np.asarray(want), atol=1e-5)

    def test_sparse_kernel_vs_padded_oracle(self):
        """Direct padded-shape parity: pallas entry vs ref oracle, at every
        row-bank size dividing the row table."""
        from repro.kernels.ip2_project_sparse import ip2_project_sparse_pallas

        params = IP2KernelParams(n2=64, adc_enable=False)
        patches = jax.random.uniform(KEY, (16, 256))
        w = jax.random.normal(jax.random.PRNGKey(1), (256, 128))
        bias = jnp.zeros((128,))
        idx = jnp.array([3, 15, 0, 7, 7, 11], jnp.int32)
        want = ref.ip2_project_sparse_ref(idx, patches, w, bias, params)
        for block_r in (1, 2, 3, 6):
            got = ip2_project_sparse_pallas(
                idx, patches, w, bias, params,
                block_r=block_r, block_m=128, block_k=256, interpret=True,
            )
            np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)

    def test_sparse_block_r_does_not_change_results(self):
        """The wrapper's sublane-aligned row banking (block_r) is a pure
        perf knob: any bank size (including non-dividing ones, padded and
        sliced internally) yields identical features."""
        spec = proj.PatchSpec(patch_h=8, patch_w=8, n_vectors=24)
        patches = jax.random.uniform(KEY, (3, 9, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 2.0
        idx = jnp.array([[0, 8, 4], [7, 1, 2], [3, 3, 5]], jnp.int32)
        base = ops.ip2_project_sparse(patches, w, idx, spec,
                                      block_r=1, interpret=True)
        for block_r in (None, 2, 4, 8, 16):
            out = ops.ip2_project_sparse(patches, w, idx, spec,
                                         block_r=block_r, interpret=True)
            np.testing.assert_allclose(np.asarray(out), np.asarray(base),
                                       atol=1e-6)

    def test_kernels_emit_wire_codes(self):
        """codes=True: both projection kernels emit int8 ADC codes from the
        fused epilogue whose dequant matches the float fused-ADC output
        (within fused-multiply-add reassociation, far below 1 LSB)."""
        from repro.core.adc import dequantize, readout_scale_zero

        spec = proj.PatchSpec(patch_h=8, patch_w=8, n_vectors=24)
        adc = adc_mod.ADCSpec(bits=8)
        patches = jax.random.uniform(KEY, (2, 9, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (24, 64)) * 3.0
        bias = jax.random.normal(jax.random.PRNGKey(2), (24,)) * 0.1
        scale, zero = readout_scale_zero(spec.summer.v_ref, bias, adc)

        f_dense = ops.ip2_project(patches, w, spec, adc=adc, bias=bias,
                                  interpret=True)
        c_dense = ops.ip2_project(patches, w, spec, adc=adc, bias=bias,
                                  codes=True, interpret=True)
        assert c_dense.dtype == jnp.int8
        np.testing.assert_allclose(np.asarray(dequantize(c_dense, scale, zero)),
                                   np.asarray(f_dense), atol=1e-6)

        idx = jnp.array([[0, 8, 4], [7, 1, 2]], jnp.int32)
        c_sparse = ops.ip2_project_sparse(patches, w, idx, spec, adc=adc,
                                          bias=bias, codes=True, interpret=True)
        assert c_sparse.dtype == jnp.int8
        np.testing.assert_array_equal(
            np.asarray(c_sparse),
            np.asarray(jnp.take_along_axis(c_dense, idx[..., None], axis=-2)),
        )

    @pytest.mark.parametrize("k", [1, 3, 9])     # single saccade .. k == P
    @pytest.mark.parametrize("bp_r,bm,bk", [
        (1, 128, 128),
        (8, 128, 256),       # shipped defaults
        (8, 256, 128),       # non-divisible M=50 and N2=576 pad both blocks
        (16, 512, 256),      # the roofline-picked m_steps=1 shape
    ])
    def test_block_sweep_parity_battery_all_three_kernels(self, k, bp_r, bm, bk):
        """Satellite battery (DESIGN.md §11): the dense kernel, the sparse
        gather kernel, and the ragged megakernel path emit BITWISE-identical
        int8 wire codes for the same selection at every block tiling —
        including pad remainders (M=50, N2=576) and the k=1 / k=P edges.
        ``bp_r`` doubles as block_p (dense) and block_r (sparse/ragged)."""
        spec = proj.PatchSpec(patch_h=24, patch_w=24, n_vectors=50)
        adc = adc_mod.ADCSpec(bits=8)
        patches = jax.random.uniform(KEY, (2, 9, 576))
        w = jax.random.normal(jax.random.PRNGKey(1), (50, 576)) * 2.0
        idx = jnp.stack([
            jax.random.permutation(jax.random.PRNGKey(2 + b),
                                   jnp.arange(9))[:k]
            for b in range(2)
        ])
        c_dense = ops.ip2_project(patches, w, spec, adc=adc, codes=True,
                                  block_p=bp_r, block_m=bm, block_k=bk,
                                  interpret=True)
        want = jnp.take_along_axis(c_dense, idx[..., None], axis=-2)
        c_sparse = ops.ip2_project_sparse(
            patches, w, idx, spec, adc=adc, codes=True,
            block_r=bp_r, block_m=bm, block_k=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_sparse), np.asarray(want))
        c_ragged = ops.ip2_project_sparse(
            patches, w, idx, spec, adc=adc, codes=True,
            row_counts=jnp.full((2,), k, jnp.int32),
            block_r=bp_r, block_m=bm, block_k=bk, interpret=True)
        np.testing.assert_array_equal(np.asarray(c_ragged), np.asarray(want))

    def test_codes_require_adc(self):
        spec = proj.PatchSpec(patch_h=8, patch_w=8, n_vectors=16)
        patches = jax.random.uniform(KEY, (1, 4, 64))
        w = jax.random.normal(KEY, (16, 64))
        with pytest.raises(ValueError, match="codes=True requires"):
            ops.ip2_project(patches, w, spec, codes=True, interpret=True)


# ---------------------------------------------------------------------------
# hypothesis property tests
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @settings(max_examples=20, deadline=None)
    @given(
        n_patches=st.integers(1, 9),
        n_vec=st.integers(1, 40),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_ip2_kernel_property_allclose(n_patches, n_vec, seed):
        spec = proj.PatchSpec(patch_h=8, patch_w=8, n_vectors=n_vec)
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        patches = jax.random.uniform(k1, (n_patches, 64))
        w = jax.random.normal(k2, (n_vec, 64)) * 2.0
        out_k = ops.ip2_project(patches, w, spec, interpret=True)
        out_r = proj.analog_project_patches(patches, w, spec)
        np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r), atol=1e-5)

    @settings(max_examples=10, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 9))
    def test_sparse_kernel_property_allclose(seed, k):
        """Sparse == gather(dense) for arbitrary random active sets."""
        spec = proj.PatchSpec(patch_h=8, patch_w=8, n_vectors=16)
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        patches = jax.random.uniform(k1, (9, 64))
        w = jax.random.normal(k2, (16, 64)) * 2.0
        idx = jax.random.randint(k3, (k,), 0, 9)
        out_s = ops.ip2_project_sparse(patches, w, idx, spec, interpret=True)
        dense = ops.ip2_project(patches, w, spec, interpret=True)
        np.testing.assert_allclose(
            np.asarray(out_s), np.asarray(dense[idx]), atol=1e-5
        )

    @settings(max_examples=20, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1))
    def test_ip2_output_bounded_by_rails(seed):
        """Analog outputs can never exceed the voltage rails (physics)."""
        from repro.core.analog_nl import AnalogNLSpec

        spec = proj.PatchSpec(
            patch_h=8, patch_w=8, n_vectors=8, nl=AnalogNLSpec(kind="relu", v_sat=1.0)
        )
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        patches = jax.random.uniform(k1, (3, 64))
        w = jax.random.normal(k2, (8, 64)) * 50.0   # absurd weight currents
        out = ops.ip2_project(patches, w, spec, interpret=True)
        assert float(out.min()) >= 0.0 and float(out.max()) <= 1.0

    @settings(max_examples=15, deadline=None)
    @given(seed=st.integers(0, 2**31 - 1), bits=st.integers(2, 8))
    def test_pwm_monotone_property(seed, bits):
        """PWM quantization is monotone non-decreasing (a comparator ramp)."""
        from repro.core.pwm import pwm_quantize

        x = jnp.sort(jax.random.uniform(jax.random.PRNGKey(seed), (100,)))
        q = pwm_quantize(x, QuantSpec(pwm_bits=bits))
        assert bool(jnp.all(jnp.diff(q) >= 0))
