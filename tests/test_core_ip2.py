"""Paper-core unit tests: PWM/DAC quantizers, switched-cap physics,
projection, Bayer/AA, saliency, ADC, QTH attention, power/throughput."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.core as c
from repro.core.switched_cap import SummerSpec, TAU_LEAK_65NM_S, TAU_LEAK_22NM_FDX_S


KEY = jax.random.PRNGKey(0)


class TestPWM:
    def test_levels(self):
        spec = c.QuantSpec(pwm_bits=6)
        x = jnp.linspace(0, 1, 1000)
        q = c.pwm_quantize(x, spec)
        assert len(np.unique(np.asarray(q))) == 64

    def test_clipping(self):
        q = c.pwm_quantize(jnp.array([-0.5, 1.5]))
        assert q[0] == 0.0 and q[1] == 1.0

    def test_ste_gradient_identity(self):
        g = jax.grad(lambda x: c.pwm_quantize(x).sum())(jnp.array([0.3, 0.7]))
        np.testing.assert_allclose(g, 1.0)

    def test_weight_quantization_signed(self):
        w = jax.random.normal(KEY, (8, 64))
        wq, scale = c.quantize_weights(w, c.QuantSpec(weight_bits=6))
        codes = np.asarray(jnp.round(wq / scale))
        assert np.abs(codes).max() <= 31  # 6-bit signed DAC
        # quantization error bounded by half an LSB
        assert float(jnp.abs(wq - w).max()) <= float(scale.max()) * 0.5 + 1e-6


class TestSwitchedCap:
    def test_paper_leakage_datum(self):
        """§2.1.2: passive summer of 768@1V + 768@0V droops ~10% in 10µs."""
        v = jnp.concatenate([jnp.ones(768), jnp.zeros(768)])
        passive = c.charge_share_sum(v, SummerSpec(mode="passive"))
        np.testing.assert_allclose(float(passive), 0.45, atol=1e-3)  # 0.5 * 0.9

    def test_opamp_compensation(self):
        v = jnp.concatenate([jnp.ones(768), jnp.zeros(768)])
        active = c.charge_share_sum(v, SummerSpec(mode="opamp"))
        assert abs(float(active) - 0.5) < 1e-3  # gain error only

    def test_tau_calibration(self):
        assert math.isclose(math.exp(-10e-6 / TAU_LEAK_65NM_S), 0.9, rel_tol=1e-9)
        assert TAU_LEAK_22NM_FDX_S == pytest.approx(100 * TAU_LEAK_65NM_S)

    def test_droop_trace_monotone(self):
        t = jnp.linspace(0, 50e-6, 10)
        tr = c.passive_droop_trace(jnp.array(1.0), t)
        assert bool(jnp.all(jnp.diff(tr) < 0))

    def test_capacitor_divider(self):
        assert float(c.capacitor_divider(jnp.array(1.0), 3)) == pytest.approx(0.25)

    def test_charge_conservation_mean(self):
        v = jax.random.uniform(KEY, (100,))
        s = c.charge_share_sum(v, SummerSpec(mode="opamp", opamp_dc_gain=1e12))
        np.testing.assert_allclose(float(s), float(v.mean()), rtol=1e-6)


class TestProjection:
    def test_matches_ideal_at_high_bits(self):
        """With many bits + ideal summer the analog path -> exact matmul/N²."""
        spec = c.PatchSpec(
            patch_h=8, patch_w=8, n_vectors=16,
            quant=c.QuantSpec(pwm_bits=16, weight_bits=16),
            summer=SummerSpec(opamp_dc_gain=1e12),
        )
        patches = jax.random.uniform(KEY, (5, 64))
        w = jax.random.normal(jax.random.PRNGKey(1), (16, 64))
        out = c.analog_project_patches(patches, w, spec)
        ref = patches @ w.T / 64
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-3)

    def test_programmable_patch_sizes(self):
        for ph, pw in [(8, 8), (8, 32), (24, 16), (32, 32)]:
            spec = c.PatchSpec(patch_h=ph, patch_w=pw, n_vectors=4)
            frame = jax.random.uniform(KEY, (96, 96))
            out = c.analog_project_frame(frame, jnp.ones((4, ph * pw)), spec)
            assert out.shape == ((96 // ph) * (96 // pw), 4)

    def test_invalid_patch_size_raises(self):
        with pytest.raises(ValueError):
            c.PatchSpec(patch_h=12, patch_w=8)

    def test_extract_patches_layout(self):
        frame = jnp.arange(16.0).reshape(4, 4)
        p = c.extract_patches(frame, 2, 2)
        np.testing.assert_allclose(np.asarray(p[0]), [0, 1, 4, 5])


class TestBayer:
    def test_mosaic_rggb(self):
        rgb = jnp.stack([jnp.full((4, 4), 0.1), jnp.full((4, 4), 0.5),
                         jnp.full((4, 4), 0.9)], axis=-1)
        m = c.mosaic(rgb)
        assert float(m[0, 0]) == pytest.approx(0.1)  # R
        assert float(m[0, 1]) == pytest.approx(0.5)  # G
        assert float(m[1, 0]) == pytest.approx(0.5)  # G
        assert float(m[1, 1]) == pytest.approx(0.9)  # B

    def test_strike_columns_identity(self):
        """A' applied to Bayer frame == A applied to RGB masked to Bayer."""
        a = jax.random.normal(KEY, (6, 8 * 8 * 3))
        ap = c.strike_columns(a, 8, 8)
        assert ap.shape == (6, 64)
        rgb = jax.random.uniform(jax.random.PRNGKey(2), (8, 8, 3))
        bayer_vec = c.mosaic(rgb).reshape(-1)
        ch = np.asarray(c.bayer_channel_map(8, 8)).reshape(-1)
        rgb_vec = rgb.reshape(-1, 3)
        manual = sum(
            float(a[0, i * 3 + ch[i]]) * float(rgb_vec[i, ch[i]]) for i in range(64)
        ) if False else None
        # A'(bayer) must equal selecting matched columns of A
        a3 = a.reshape(6, 64, 3)
        expected = jnp.einsum(
            "mv,v->m", a3[:, jnp.arange(64), ch], bayer_vec
        )
        got = ap @ bayer_vec
        np.testing.assert_allclose(np.asarray(got), np.asarray(expected), rtol=1e-5)

    def test_antialias_dc_preserving(self):
        x = jnp.full((16, 16), 0.7)
        y = c.antialias(x, 0.25)
        np.testing.assert_allclose(np.asarray(y), 0.7, rtol=1e-5)

    def test_antialias_cutoff_order(self):
        """0.25-Nyquist filter removes more high-freq energy than 0.5."""
        x = jnp.asarray(np.indices((32, 32)).sum(0) % 2, jnp.float32)  # checker
        hf = lambda z: float(jnp.var(z))
        assert hf(c.antialias(x, 0.25)) < hf(c.antialias(x, 0.5)) < hf(x)


class TestSaliencyADC:
    def test_topk_fraction(self):
        scores = jax.random.uniform(KEY, (3, 64))
        mask = c.topk_patch_mask(scores, 0.25)
        np.testing.assert_allclose(np.asarray(mask.sum(-1)), 16)

    def test_topk_mask_tied_scores_exactly_k(self):
        """Regression: equal scores must never over-select. The old
        ``scores >= thresh`` comparison returned every tied patch, breaking
        compact_active's exactly-k contract."""
        scores = jnp.ones((2, 16))                       # all tied
        mask = c.topk_patch_mask(scores, 0.25)
        np.testing.assert_allclose(np.asarray(mask.sum(-1)), 4)
        # deterministic tie-break: lowest patch indices win
        assert bool(mask[:, :4].all()) and not bool(mask[:, 4:].any())
        # partial tie at the threshold value
        scores = jnp.array([[0.9, 0.5, 0.5, 0.5, 0.5, 0.1, 0.0, 0.0]])
        mask = c.topk_patch_mask(scores, 0.25)           # k = 2
        np.testing.assert_allclose(np.asarray(mask), [[True, True] + [False] * 6])

    def test_topk_indices_deterministic_and_sorted_by_score(self):
        scores = jnp.array([[0.1, 0.7, 0.7, 0.9, 0.0]])
        idx = c.topk_patch_indices(scores, 3)
        np.testing.assert_array_equal(np.asarray(idx), [[3, 1, 2]])

    def test_mask_index_roundtrip(self):
        scores = jax.random.uniform(KEY, (4, 32))
        idx = c.topk_patch_indices(scores, 8)
        mask = c.mask_from_indices(idx, 32)
        np.testing.assert_allclose(np.asarray(mask.sum(-1)), 8)
        idx2, valid = c.indices_from_mask(mask, 8)
        assert bool(valid.all())
        np.testing.assert_array_equal(
            np.sort(np.asarray(idx), -1), np.asarray(idx2)   # ascending order
        )

    def test_indices_from_mask_fewer_than_k(self):
        mask = jnp.zeros((1, 8), bool).at[0, 2].set(True).at[0, 6].set(True)
        idx, valid = c.indices_from_mask(mask, 4)
        np.testing.assert_array_equal(np.asarray(idx[0, :2]), [2, 6])
        np.testing.assert_array_equal(np.asarray(valid), [[True, True, False, False]])

    def test_compact_active_exactly_k_on_ties(self):
        feats = jax.random.normal(KEY, (2, 16, 4))
        mask = c.topk_patch_mask(jnp.ones((2, 16)), 0.25)
        compact, idx = c.compact_active(feats, mask, 4)
        assert compact.shape == (2, 4, 4) and idx.shape == (2, 4)
        np.testing.assert_allclose(
            np.asarray(compact), np.asarray(feats[:, :4])    # ties -> lowest idx
        )

    def test_adc_levels(self):
        spec = c.ADCSpec(bits=8)
        x = jnp.linspace(-1, 1, 3000)
        q = c.adc_quantize(x, spec)
        assert len(np.unique(np.asarray(q))) == 256

    def test_digital_readout_recovers_bias(self):
        spec = c.ADCSpec(bits=14)
        out_v = jnp.array([0.3])
        got = c.digital_readout(out_v, v_ref=0.1, bias=0.05, spec=spec)
        np.testing.assert_allclose(float(got[0]), 0.3 - 0.1 + 0.05, atol=1e-3)


class TestQTH:
    def test_pow2_values(self):
        p = jnp.array([0.5, 0.25, 0.1, 1e-6])
        q = c.pow2_quantize(p, c.QTHSpec(min_exp=-8, ste=False))
        assert float(q[0]) == 0.5 and float(q[1]) == 0.25
        assert float(q[3]) == 0.0  # thresholded
        assert math.log2(float(q[2])) == round(math.log2(float(q[2])))

    def test_qth_attention_close_to_softmax(self):
        q = jax.random.normal(KEY, (2, 8, 16))
        k = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 16))
        v = jax.random.normal(jax.random.PRNGKey(2), (2, 8, 16))
        exact = jax.nn.softmax(
            jnp.einsum("bqd,bkd->bqk", q, k) / 4.0, -1
        ) @ v
        approx = c.qth_attention(q, k, v)
        rel = float(jnp.abs(approx - exact).max() / jnp.abs(exact).max())
        assert rel < 0.35  # pow-2 coefficients approximate softmax


class TestPowerThroughput:
    def test_table1_totals(self):
        t = c.AreaBudget().totals()
        assert t["Total"]["total_um2"] == pytest.approx(485.0)
        assert t["Total"]["pitch_um"] == pytest.approx(22.0, abs=0.05)
        assert t["Cap 30 fF"]["occupancy"] == pytest.approx(0.40, abs=0.005)

    def test_power_claims(self):
        rep = c.power_report(c.SensorConfig())            # 2 Mpix @ 30 Hz
        assert rep.total_w < 0.060                        # < 60 mW
        assert rep.mw_per_mpix < 30.0                     # < 30 mW/Mpix
        assert rep.adc_dominated                          # ADC is the majority

    def test_data_reduction_10x_30x(self):
        assert c.data_reduction(c.SensorConfig()) >= 10.0
        assert c.data_reduction(c.SensorConfig(), vs_rgb=True) >= 30.0

    def test_fig3_operating_points(self):
        p = c.rate_point("1080p", 2, 32, 400)
        assert 85.0 <= p.frame_hz <= 95.0                  # ~90 Hz claim
        assert c.frame_rate(8, 192, 2) > 30.0              # 8x8/192vec > 30 Hz

    def test_fig3_monotone_in_weight_lines(self):
        rates = [c.rate_point("1080p", cl, 32, 400).frame_hz for cl in (1, 2, 4, 8)]
        assert rates == sorted(rates)
